PY ?= python
export PYTHONPATH := src:$(PYTHONPATH)

.PHONY: test test-fast bench-smoke bench train-smoke

# tier-1 suite (the CI gate)
test:
	$(PY) -m pytest -x -q

# skip the slow multi-device subprocess tests
test-fast:
	$(PY) -m pytest -q --ignore=tests/test_distributed.py

# fast benchmark subset: planner model + placement + memory model
bench-smoke:
	PYTHONPATH=src:. $(PY) -m benchmarks.run --only fig7,fig10,table5

bench:
	PYTHONPATH=src:. $(PY) -m benchmarks.run

# 20 pipeline steps with real gradient accumulation (target 2048, micro 512)
train-smoke:
	$(PY) -m repro.launch.train --arch lightgcn --steps 20 \
	    --ckpt-dir /tmp/repro_ckpt_smoke
