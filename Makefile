PY ?= python
export PYTHONPATH := src:$(PYTHONPATH)

.PHONY: test test-fast test-slow test-multidevice check-plan lint audit bench-smoke bench serve-bench train-smoke examples check-bytecode

# tier-1 suite (the CI gate) + pass/fail delta vs the seed baseline,
# then the placement-plan golden-snapshot gate (per-topology)
test:
	$(PY) tools/check_test_delta.py
	$(PY) tools/check_plan_snapshot.py

# placement-plan golden snapshots only (tools/plan_snapshots.json)
check-plan:
	$(PY) tools/check_plan_snapshot.py

# layer 1 static analysis (AST + registry rules) vs the ratchet baseline
# (tools/lint_baseline.json); fix new findings, shrink with --update
lint:
	$(PY) tools/lint.py --check-baseline

# layer 2 HLO invariant audit: lowers train + serve for the smoke
# preset at 4 (mesh, compression) points and checks dtype/placement/
# collective invariants on the lowered text
audit:
	$(PY) tools/lint.py --hlo

# fast subset: skip slow property/parity sweeps + multi-device subprocess tests
test-fast:
	$(PY) -m pytest -q -m "not slow" --ignore=tests/test_distributed.py

# slow tier: property-based + kernel-parity sweeps (CI's second job)
test-slow:
	$(PY) -m pytest -q -m slow

# sharded execution under a forced multi-device host platform: the ring/
# mesh parity tests plus the whole pipeline suite with 4 CPU devices
# visible (CI's multidevice job)
test-multidevice:
	XLA_FLAGS=--xla_force_host_platform_device_count=4 \
	    $(PY) -m pytest -q -m "not slow" tests/test_distributed.py tests/test_pipeline.py

# fast benchmark subset: planner model + placement + memory model
bench-smoke:
	PYTHONPATH=src:. $(PY) -m benchmarks.run --only fig7,fig10,table5

bench:
	PYTHONPATH=src:. $(PY) -m benchmarks.run

# serving subsystem: Zipf-stream cache arms, ANN retrieval recall/
# speedup at 131072 items, open/closed-loop coalescing load sim;
# writes BENCH_serving.json (root + results/ mirror)
serve-bench:
	PYTHONPATH=src:. $(PY) -m benchmarks.run --only serving

# 20 pipeline steps with real gradient accumulation (target 2048, micro 512)
train-smoke:
	$(PY) -m repro.launch.train --arch lightgcn --steps 20 \
	    --ckpt-dir /tmp/repro_ckpt_smoke

# both examples end to end through the Experiment API (CI's examples job)
examples:
	$(PY) examples/quickstart.py
	$(PY) examples/serve_recsys.py

# fail if compiled bytecode is tracked (CI's examples job runs this too)
check-bytecode:
	@if git ls-files | grep -E '\.pyc$$'; then \
	    echo "tracked .pyc files found"; exit 1; \
	else echo "no tracked bytecode"; fi
