"""The paper's headline experiment as a runnable example: single-machine
full-graph training vs DistDGL-style subgraph training, depth 1-3.

Run:  PYTHONPATH=src python examples/fullgraph_vs_subgraph.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bpr, lightgcn
from repro.core.graph import bipartite_from_numpy
from repro.data import synth
from repro.dist.subgraph import SubgraphTrainer


def main():
    data = synth.scaled("gowalla", 10000, seed=0)
    g = bipartite_from_numpy(data.user, data.item, data.n_users, data.n_items)
    params = lightgcn.init_params(jax.random.PRNGKey(0), data.n_users,
                                  data.n_items, 32)
    x_all = jnp.concatenate([params["user_embed"], params["item_embed"]])
    rng = np.random.default_rng(0)

    print(f"{'layers':>7} {'full-graph':>12} {'subgraph':>12} "
          f"{'build%':>7} {'expanded':>9}")
    for layers in (1, 2, 3):
        @jax.jit
        def full_step(params):
            u, i, n = [jnp.asarray(a) for a in bpr.sample_bpr_batch(
                rng, data.user, data.item, data.n_items, 256)]

            def loss_fn(p):
                ue, ie = lightgcn.forward(p, g, n_layers=layers)
                return bpr.bpr_loss(ue, ie, u, i, n)
            return jax.grad(loss_fn)(params)

        jax.block_until_ready(full_step(params))
        t0 = time.perf_counter()
        jax.block_until_ready(full_step(params))
        t_full = time.perf_counter() - t0

        src = np.concatenate([data.user, data.item + data.n_users])
        dst = np.concatenate([data.item + data.n_users, data.user])
        tr = SubgraphTrainer(src, dst, data.n_users + data.n_items,
                             n_layers=layers, fanout=10, n_workers=2)
        seeds = rng.integers(0, data.n_users, 256).astype(np.int32)
        tr.step(seeds, x_all, lambda e, s: jnp.mean(e ** 2),
                record=False)                                 # compile
        _, st = tr.step(seeds, x_all, lambda e, s: jnp.mean(e ** 2))
        t_sub = st.sample_s + st.forward_s + st.backward_s
        build = st.sample_s / t_sub * 100
        print(f"{layers:>7} {t_full*1e3:>10.1f}ms {t_sub*1e3:>10.1f}ms "
              f"{build:>6.0f}% {st.expanded_vertices:>9}")
    print("\npaper: full-graph wins at depth>=2 (43-356x on real clusters); "
          "subgraph expansion grows exponentially with depth")


if __name__ == "__main__":
    main()
