"""Quickstart: the paper's pipeline end to end in ~a minute on CPU.

1. synthesize a movielens-statistics bipartite graph,
2. train LightGCN full-graph with BPR (the paper's §7 recipe: linear LR
   scaling + warm-up batch),
3. evaluate recall@20,
4. show the tiered-memory plan the system would use at paper scale.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bpr, lightgcn
from repro.core.graph import bipartite_from_numpy
from repro.core.large_batch import LargeBatchSchedule
from repro.core.tiered_memory import gnn_recsys_profiles, plan_placement
from repro.data import synth
from repro.eval import Recommender, evaluate_embeddings


def main():
    # --- data (paper Table 2 statistics, CPU-scaled)
    data = synth.scaled("movielens-10m", 8000, seed=0)
    train, test = synth.train_test_split(data, 0.1)
    g = bipartite_from_numpy(train.user, train.item, data.n_users,
                             data.n_items)
    print(f"graph: {data.n_users} users x {data.n_items} items, "
          f"{train.n_edges} train edges (density {data.density:.3%})")

    # --- large-batch schedule (paper §7.1)
    sched = LargeBatchSchedule(base_lr=0.02, base_batch=64,
                               target_batch=1024, warmup_epochs=2)
    params = lightgcn.init_params(jax.random.PRNGKey(0), data.n_users,
                                  data.n_items, 32)
    rng = np.random.default_rng(0)

    @jax.jit
    def step(params, lr, u, i, n):
        def loss_fn(p):
            ue, ie = lightgcn.forward(p, g, n_layers=2)
            return bpr.bpr_loss(ue, ie, u, i, n)
        loss, grads = jax.value_and_grad(loss_fn)(params)
        return jax.tree.map(lambda p, gr: p - lr * gr, params, grads), loss

    for epoch in range(6):
        batch = sched.batch_for_epoch(epoch)
        lr = sched.lr_for_epoch(epoch)
        for _ in range(max(train.n_edges // batch, 1)):
            u, i, n = bpr.sample_bpr_batch(rng, train.user, train.item,
                                           data.n_items, batch)
            params, loss = step(params, lr, jnp.asarray(u), jnp.asarray(i),
                                jnp.asarray(n))
        print(f"epoch {epoch}: batch={batch} lr={lr:.4f} "
              f"loss={float(loss):.4f}")

    # --- held-out metrics (paper's recall@20 + NDCG/MRR) through the
    # streaming top-K path: item blocks + CSR seen-mask, never U×I
    ue, ie = lightgcn.forward(params, g, n_layers=2)
    indptr, items = bpr.build_user_csr(train.user, train.item, data.n_users)
    test_pos = synth.group_by_user(test.user, test.item, data.n_users)
    m = evaluate_embeddings(ue, ie, test_pos, k=20, seen_indptr=indptr,
                            seen_items=items)
    print(" ".join(f"{k}={v:.4f}" for k, v in sorted(m.items())))

    # --- serving facade: planner-placed embedding snapshot, batched top-K
    rec = Recommender(ue, ie, seen_indptr=indptr, seen_items=items, k=5)
    print(rec.describe())
    ids, _scores = rec.recommend([0, 1, 2])
    for u, row in zip((0, 1, 2), ids):
        print(f"  user {u}: top-5 unseen items {row.tolist()}")

    # --- the paper's technique at production scale: where do the tensors
    # live when the model is m-x25-sized and HBM is 16 GiB/chip?
    profiles = gnn_recsys_profiles(349_000, 53_000, 250_000_000, 128, 3)
    plan = plan_placement(profiles, hbm_budget=64 * 2**30)  # 4 chips' worth
    print("\ntiered-memory plan (m-x25 scale, 64 GiB fast-tier budget):")
    for p in profiles:
        print(f"  {p.name:16s} {p.nbytes/2**30:7.2f} GiB -> "
              f"{plan.tier(p.name)}")
    print(f"  est. step penalty from slow tier: "
          f"{plan.est_step_penalty_s*1e3:.1f} ms")


if __name__ == "__main__":
    main()
