"""Quickstart: the paper's pipeline end to end in ~a minute on CPU,
driven entirely by the unified Experiment API.

1. one declarative ``ExperimentSpec`` (the ``quickstart`` preset):
   a movielens-statistics bipartite graph + LightGCN + the paper's §7
   recipe (warm-up batch, linear LR scaling, microbatch accumulation),
2. ``fit()`` under the fault-tolerant loop with periodic held-out eval,
3. streaming recall@20 / NDCG / MRR (never materializes U×I),
4. batched serving through the planner-placed Recommender facade,
5. the tiered-memory plan the system would use at paper scale.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
from repro.api import Experiment, get_preset
from repro.memory import get_policy, get_topology, gnn_recsys_profiles


def main():
    # --- one declarative spec: data + model + plan + loop + eval
    exp = Experiment.from_preset("quickstart", {"loop.eval_every": 30})
    print(exp)
    print(exp.spec.to_json())

    run = exp.build()
    d = run.train_data
    print(f"graph: {d.n_users} users x {d.n_items} items, "
          f"{d.n_edges} train edges (density {d.density:.3%})")
    print(run.describe())

    # --- train under the fault-tolerant loop (§7.1 schedule inside)
    report = run.fit()
    print(f"trained {report.steps_run} steps, "
          f"loss {report.losses[0]:.4f} -> {report.losses[-1]:.4f}")
    for step, m in report.eval_history:
        print(f"  eval@{step}: " +
              " ".join(f"{k}={v:.4f}" for k, v in sorted(m.items())))

    # --- held-out metrics (paper's recall@20 + NDCG/MRR) through the
    # streaming top-K path: item blocks + CSR seen-mask, never U×I
    m = run.evaluate()
    print(" ".join(f"{k}={v:.4f}" for k, v in sorted(m.items())))

    # --- serving facade: planner-placed embedding snapshot, batched top-K
    rec = run.recommender(k=5)
    print(rec.describe())
    ids, _scores = rec.recommend([0, 1, 2])
    for u, row in zip((0, 1, 2), ids):
        print(f"  user {u}: top-5 unseen items {row.tolist()}")

    # --- the paper's technique at production scale: where do the tensors
    # live when the model is m-x25-sized (the lightgcn-full preset) and
    # the fast tier is 4 chips' worth of HBM?  Topology and policy are
    # swappable by name (repro.memory; MemoryCfg on the spec) — the
    # paper's Memory-Mode-vs-AppDirect comparison is the same call with
    # a different topology string.
    full = get_preset("lightgcn-full")
    profiles = gnn_recsys_profiles(full.data.n_users, full.data.n_items,
                                   full.data.edges, full.model.embed_dim,
                                   full.model.n_layers)
    for topo_name in ("tpu-hbm-host", "dram-optane-appdirect"):
        topo = get_topology(topo_name)
        plan = get_policy("greedy")(
            profiles, topo, budgets={topo.fast.name: 64 * 2**30})
        print(f"\ntiered-memory plan ({full.name} scale, topology="
              f"{topo_name}, 64 GiB fast-tier budget):")
        for p in profiles:
            print(f"  {p.name:16s} {p.nbytes/2**30:7.2f} GiB -> "
                  f"{plan.tier(p.name)}")
        print(f"  est. step penalty from slow tier: "
              f"{plan.est_step_penalty_s*1e3:.1f} ms")


if __name__ == "__main__":
    main()
