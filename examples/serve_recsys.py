"""Serving example: batched CTR scoring + top-k retrieval with DLRM.

Covers the three serving shapes of the assignment (p99 online batches,
bulk offline scoring, 1-vs-1M candidate retrieval) at CPU scale.

Run:  PYTHONPATH=src python examples/serve_recsys.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import recsys_models as rm


def main():
    cfg = configs.get("dlrm_rm2").SMOKE
    params = rm.dlrm_init(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    score = jax.jit(lambda d, i: rm.dlrm_forward(cfg, params, d, i))
    retrieve = jax.jit(lambda d, i, c: rm.dlrm_retrieve(cfg, params, d, i, c))

    # online p99-style small batches
    for batch, tag in [(16, "serve_p99"), (512, "serve_bulk")]:
        dense = jnp.asarray(rng.standard_normal((batch, cfg.n_dense))
                            .astype(np.float32))
        ids = jnp.asarray(rng.integers(0, cfg.vocab, (batch, cfg.n_sparse))
                          .astype(np.int32))
        out = jax.block_until_ready(score(dense, ids))
        t0 = time.perf_counter()
        out = jax.block_until_ready(score(dense, ids))
        dt = (time.perf_counter() - t0) * 1e6
        print(f"{tag}: batch={batch} -> scores {out.shape}, "
              f"{dt:.0f} us/batch ({dt/batch:.1f} us/req)")

    # retrieval: one user, many candidates, batched dot (not a loop)
    n_cand = 4096
    dense = jnp.asarray(rng.standard_normal((1, cfg.n_dense)).astype(np.float32))
    ids = jnp.asarray(rng.integers(0, cfg.vocab, (1, cfg.n_sparse))
                      .astype(np.int32))
    cand = jnp.asarray(rng.integers(0, cfg.vocab, n_cand).astype(np.int32))
    scores = jax.block_until_ready(retrieve(dense, ids, cand))
    topk = jax.lax.top_k(scores, 5)
    print(f"retrieval: {n_cand} candidates -> top5 ids "
          f"{np.asarray(cand)[np.asarray(topk[1])]}")

    # BERT4Rec next-item retrieval (sequential recsys)
    bcfg = configs.get("bert4rec").SMOKE
    bparams = rm.bert4rec_init(bcfg, jax.random.PRNGKey(1))
    seq = jnp.asarray(rng.integers(0, bcfg.n_items, (2, bcfg.seq_len))
                      .astype(np.int32))
    smask = jnp.ones_like(seq, bool)
    cand = jnp.arange(bcfg.n_items, dtype=jnp.int32)
    s = rm.bert4rec_retrieve(bcfg, bparams, seq, smask, cand)
    print(f"bert4rec: catalogue scores {s.shape}, "
          f"top item per user {np.asarray(jnp.argmax(s, -1))}")


if __name__ == "__main__":
    main()
