"""Serving example: train once through the Experiment API, then serve
batched top-K recommendations through the planner-placed facade.

Covers the three serving shapes of the assignment at CPU scale:
  * p99-style small online batches (16 users/query),
  * bulk offline scoring (512 users/query),
  * 1-vs-whole-catalogue retrieval for a single user.

The ``Recommender`` snapshot is placed by the same TieredMemoryPlanner
that places training tensors (item table streams fully per query batch,
user table is only row-gathered), and every query runs the streaming
top-K scorer — peak memory O(batch × (K + block)), never U×I.

Run:  PYTHONPATH=src python examples/serve_recsys.py
"""
import time

import numpy as np

from repro.api import Experiment


def main():
    # --- one declarative spec; a short fit gives us trained embeddings
    exp = Experiment.from_preset("quickstart", {"loop.steps": 20})
    run = exp.build()
    run.fit()
    print(run.describe())

    rec = run.recommender(k=10)
    print(rec.describe())
    rng = np.random.default_rng(0)

    # --- online p99-style small batches vs bulk offline scoring
    for batch, tag in [(16, "serve_p99"), (512, "serve_bulk")]:
        users = rng.integers(0, rec.n_users, batch).astype(np.int32)
        rec.recommend(users)                       # warmup/compile
        t0 = time.perf_counter()
        ids, _scores = rec.recommend(users)
        dt = (time.perf_counter() - t0) * 1e6
        print(f"{tag}: batch={batch} -> top-{rec.k} ids {ids.shape}, "
              f"{dt:.0f} us/batch ({dt/batch:.1f} us/req)")

    # --- retrieval: one user against the whole catalogue (seen excluded)
    rec.recommend([0])                             # warmup/compile
    t0 = time.perf_counter()
    ids, scores = rec.recommend([0])
    dt = (time.perf_counter() - t0) * 1e6
    print(f"retrieval: user 0 vs {rec.n_items}-item catalogue in "
          f"{dt:.0f} us -> top-{rec.k} unseen items {ids[0].tolist()}")

    # --- the same queries through the Run convenience wrapper
    ids, _ = run.recommend([0, 1, 2], k=5)
    for u, row in zip((0, 1, 2), ids):
        print(f"  user {u}: top-5 unseen items {row.tolist()}")


if __name__ == "__main__":
    main()
