"""The seed-baseline delta reporter and the placement-plan snapshot
gate behind ``make test``."""
import json
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).parents[1] / "tools"))
from check_plan_snapshot import SNAPSHOT_PATH, build_snapshots  # noqa: E402
from check_test_delta import BASELINE_PATH, parse_summary  # noqa: E402


def test_parse_summary_variants():
    assert parse_summary("127 passed, 1 skipped, 89 deselected in 309s") == \
        {"passed": 127, "failed": 0, "skipped": 1, "error": 0}
    assert parse_summary("2 failed, 61 passed, 2 warnings in 26.49s") == \
        {"passed": 61, "failed": 2, "skipped": 0, "error": 0}
    assert parse_summary("1 failed, 10 passed, 2 errors in 1.0s") == \
        {"passed": 10, "failed": 1, "skipped": 0, "error": 2}
    assert parse_summary("no tests ran in 0.01s") == \
        {"passed": 0, "failed": 0, "skipped": 0, "error": 0}


def test_baseline_records_seed_outcome():
    baseline = json.loads(BASELINE_PATH.read_text())
    assert baseline["passed"] == 113 and baseline["skipped"] == 1


def test_plan_snapshots_match_golden():
    """The committed golden plan snapshots must equal a fresh derivation
    for every registered topology (the same gate `make test`/CI runs —
    placement drift fails like a test-count regression)."""
    got = build_snapshots()
    want = json.loads(SNAPSHOT_PATH.read_text())
    assert set(got) == set(want)
    for topo in got:
        assert got[topo] == want[topo], f"plan drifted for {topo!r}"


def test_plan_snapshots_cover_all_topologies():
    from repro.memory import topology_names
    want = json.loads(SNAPSHOT_PATH.read_text())
    assert set(topology_names()) <= set(want)
