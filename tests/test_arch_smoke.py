"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes + no NaNs (assignment requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as config_registry
from repro.optim import adam

LM_ARCHS = ["nemotron_4_340b", "gemma2_2b", "granite_3_8b", "mixtral_8x7b",
            "kimi_k2_1t_a32b"]
RECSYS_ARCHS = ["deepfm", "xdeepfm", "dlrm_rm2"]


def _finite(tree):
    return all(bool(jnp.isfinite(l).all()) for l in jax.tree.leaves(tree)
               if jnp.issubdtype(l.dtype, jnp.floating))


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_train_and_serve(arch):
    from repro.models import transformer as tfm
    cfg = config_registry.get(arch).SMOKE
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    logits = tfm.forward(cfg, params, toks)
    assert logits.shape == (2, 16, cfg.vocab)
    assert _finite(logits)
    opt = adam(1e-3)
    p2, _, loss = tfm.train_step(cfg, opt, params, opt.init(params),
                                 toks, toks, n_microbatches=2)
    assert jnp.isfinite(loss)
    assert _finite(p2)
    lg, cache = tfm.prefill(cfg, params, toks)
    assert lg.shape == (2, cfg.vocab)
    c = tfm.init_kv_cache(cfg, 2, 24)
    lg2, c2 = tfm.decode_step(cfg, params, toks[:, :1], c, jnp.int32(0))
    assert lg2.shape == (2, cfg.vocab) and _finite(lg2)


def test_full_configs_match_assignment():
    """The FULL configs must carry the exact assigned hyperparameters."""
    n = config_registry.get("nemotron_4_340b").FULL
    assert (n.n_layers, n.d_model, n.n_heads, n.n_kv_heads, n.d_ff,
            n.vocab) == (96, 18432, 96, 8, 73728, 256000)
    assert n.activation == "squared_relu"
    g = config_registry.get("gemma2_2b").FULL
    assert (g.n_layers, g.d_model, g.n_heads, g.n_kv_heads, g.d_ff,
            g.vocab) == (26, 2304, 8, 4, 9216, 256000)
    assert g.attn_type == "local_global" and g.attn_softcap == 50.0
    m = config_registry.get("mixtral_8x7b").FULL
    assert (m.n_experts, m.top_k, m.moe_d_ff) == (8, 2, 14336)
    assert m.attn_type == "swa"
    k = config_registry.get("kimi_k2_1t_a32b").FULL
    assert (k.n_layers, k.d_model, k.n_heads, k.n_experts, k.top_k) == \
        (61, 7168, 64, 384, 8)
    # ~1T total, ~32B active
    assert 0.9e12 < k.param_count() < 1.2e12
    assert 25e9 < k.active_param_count() < 40e9
    assert 300e9 < n.param_count() < 380e9


def test_gcn_smoke_all_shapes():
    from repro.models import gcn
    cfg = config_registry.get("gcn_cora").SMOKE
    rng = np.random.default_rng(0)
    params = gcn.init_params(cfg, jax.random.PRNGKey(0))
    # full graph
    from repro.core.graph import from_numpy
    src = rng.integers(0, 50, 300).astype(np.int32)
    dst = rng.integers(0, 50, 300).astype(np.int32)
    g = from_numpy(src, dst, 50)
    x = jnp.asarray(rng.standard_normal((50, cfg.d_feat)).astype(np.float32))
    logits = gcn.forward(cfg, params, g, x)
    assert logits.shape == (50, cfg.n_classes) and _finite(logits)
    # one train step reduces loss on random labels (overfit direction)
    labels = jnp.asarray(rng.integers(0, cfg.n_classes, 50).astype(np.int32))
    lmask = jnp.ones((50,), jnp.float32)
    loss0, grads = jax.value_and_grad(
        lambda p: gcn.loss_fn(cfg, p, g, x, labels, lmask))(params)
    p2 = jax.tree.map(lambda p, gr: p - 0.5 * gr, params, grads)
    loss1 = gcn.loss_fn(cfg, p2, g, x, labels, lmask)
    assert float(loss1) < float(loss0)
    # batched molecule-style
    gids = jnp.asarray(np.repeat(np.arange(5), 10).astype(np.int32))
    out = gcn.forward_batched(cfg, params, jnp.asarray(src[:40] % 50),
                              jnp.asarray(dst[:40] % 50),
                              jnp.ones(40, bool), x, gids, 5)
    assert out.shape == (5, cfg.n_classes) and _finite(out)


@pytest.mark.parametrize("arch", RECSYS_ARCHS)
def test_recsys_smoke(arch):
    from repro.models import recsys_models as rm
    mod = config_registry.get(arch)
    cfg = mod.SMOKE
    key = jax.random.PRNGKey(0)
    rng = np.random.default_rng(0)
    b = 16
    if arch == "dlrm_rm2":
        params = rm.dlrm_init(cfg, key)
        dense = jnp.asarray(rng.standard_normal((b, cfg.n_dense)).astype(np.float32))
        ids = jnp.asarray(rng.integers(0, cfg.vocab, (b, cfg.n_sparse)).astype(np.int32))
        out = rm.dlrm_forward(cfg, params, dense, ids)
        feats = (dense, ids)
        fwd = lambda p: rm.dlrm_forward(cfg, p, *feats)
    else:
        init = rm.deepfm_init if arch == "deepfm" else rm.xdeepfm_init
        f = rm.deepfm_forward if arch == "deepfm" else rm.xdeepfm_forward
        params = init(cfg, key)
        ids = jnp.asarray(rng.integers(0, cfg.vocab, (b, cfg.n_sparse)).astype(np.int32))
        out = f(cfg, params, ids)
        fwd = lambda p: f(cfg, p, ids)
    assert out.shape == (b,) and _finite(out)
    labels = jnp.asarray(rng.integers(0, 2, b).astype(np.float32))
    loss0, grads = jax.value_and_grad(
        lambda p: rm.bce_loss(fwd(p), labels))(params)
    assert jnp.isfinite(loss0) and _finite(grads)


def test_bert4rec_smoke():
    from repro.models import recsys_models as rm
    cfg = config_registry.get("bert4rec").SMOKE
    params = rm.bert4rec_init(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    b, s, m, n = 4, cfg.seq_len, 3, 8
    seq = jnp.asarray(rng.integers(0, cfg.n_items, (b, s)).astype(np.int32))
    smask = jnp.ones((b, s), bool)
    hid = rm.bert4rec_encode(cfg, params, seq, smask)
    assert hid.shape == (b, s, cfg.embed_dim) and _finite(hid)
    mpos = jnp.asarray(rng.integers(0, s, (b, m)).astype(np.int32))
    labels = jnp.asarray(rng.integers(0, cfg.n_items, (b, m)).astype(np.int32))
    negs = jnp.asarray(rng.integers(0, cfg.n_items, (b, m, n)).astype(np.int32))
    loss = rm.bert4rec_sampled_loss(cfg, params, seq, smask, mpos, labels, negs)
    assert jnp.isfinite(loss)
    cand = jnp.arange(cfg.n_items, dtype=jnp.int32)
    scores = rm.bert4rec_retrieve(cfg, params, seq, smask, cand)
    assert scores.shape == (b, cfg.n_items) and _finite(scores)
    u, slate = rm.bert4rec_serve(cfg, params, seq, smask, cand[None, :16]
                                 .repeat(b, 0))
    assert u.shape == (b, cfg.embed_dim) and slate.shape == (b, 16)


def test_dlrm_retrieval_matches_forward():
    """retrieval_cand path (swap field 1) must equal running the model
    batched with the candidate id substituted."""
    from repro.models import recsys_models as rm
    cfg = config_registry.get("dlrm_rm2").SMOKE
    params = rm.dlrm_init(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(2)
    dense = jnp.asarray(rng.standard_normal((1, cfg.n_dense)).astype(np.float32))
    ids = jnp.asarray(rng.integers(0, cfg.vocab, (1, cfg.n_sparse)).astype(np.int32))
    cand = jnp.asarray(rng.integers(0, cfg.vocab, 7).astype(np.int32))
    fast = rm.dlrm_retrieve(cfg, params, dense, ids, cand)
    slow = []
    for c in np.asarray(cand):
        ids2 = ids.at[0, 0].set(int(c))
        slow.append(float(rm.dlrm_forward(cfg, params,
                                          dense, ids2)[0]))
    np.testing.assert_allclose(fast, np.array(slow), rtol=1e-4, atol=1e-5)


def test_all_archs_and_cells_enumerate():
    """Every assigned arch has 4 shapes (incl skips) and configs import."""
    total = 0
    for arch in config_registry.ASSIGNED:
        mod = config_registry.get(arch)
        n = len(mod.SHAPES) + len(mod.SKIP)
        assert n == 4, f"{arch}: {n} cells"
        total += n
    assert total == 40
