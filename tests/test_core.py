"""Core behaviour tests: dataflow-opt equivalence, models, BPR, planner,
data pipeline."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bpr, lightgcn, ngcf
from repro.core.graph import bipartite_from_numpy
from repro.core.large_batch import LargeBatchSchedule
from repro.core.message_passing import bipartite_sym_coeff
from repro.core.tiered_memory import (AccessProfile, gnn_recsys_profiles,
                                      plan_placement, plan_placement_exact)
from repro.data import kronecker, synth
from repro.data.loader import EdgeLoader
from repro.data.sampler import build_csr, sample_blocks, subgraph_redundancy


def small_graph(nu=12, ni=9, e=40, seed=0, e_pad=None):
    rng = np.random.default_rng(seed)
    u = rng.integers(0, nu, e).astype(np.int32)
    i = rng.integers(0, ni, e).astype(np.int32)
    return bipartite_from_numpy(u, i, nu, ni, e_pad=e_pad)


# ---------------------------------------------------------------- dataflow
@pytest.mark.parametrize("level_pair", [(0, 1), (1, 3), (2, 3)])
def test_ngcf_opt_levels_equivalent(level_pair):
    """Paper §4: O1/O2/O3 are exact rewrites (O0 differs only by float
    reassociation)."""
    g = small_graph()
    params = ngcf.init_params(jax.random.PRNGKey(0), g.n_users, g.n_items, 16, 2)
    a, b = level_pair
    ua, ia = ngcf.forward(params, g, opt_level=a)
    ub, ib = ngcf.forward(params, g, opt_level=b)
    np.testing.assert_allclose(ua, ub, rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(ia, ib, rtol=2e-4, atol=2e-5)


def test_ngcf_output_shape_and_finite():
    g = small_graph()
    params = ngcf.init_params(jax.random.PRNGKey(1), g.n_users, g.n_items, 8, 3)
    u, i = ngcf.forward(params, g)
    assert u.shape == (g.n_users, 8 * 4) and i.shape == (g.n_items, 8 * 4)
    assert jnp.isfinite(u).all() and jnp.isfinite(i).all()


def test_lightgcn_respects_padding():
    """Padded edges must not contribute: compare padded vs unpadded graph."""
    g1 = small_graph(e_pad=64)
    g2 = small_graph(e_pad=None)
    params = lightgcn.init_params(jax.random.PRNGKey(2), g1.n_users, g1.n_items, 8, 2)
    u1, i1 = lightgcn.forward(params, g1)
    u2, i2 = lightgcn.forward(params, g2)
    np.testing.assert_allclose(u1, u2, rtol=1e-6)
    np.testing.assert_allclose(i1, i2, rtol=1e-6)


def test_sym_coeff_masks_padding():
    g = small_graph(e_pad=64)
    c = bipartite_sym_coeff(g)
    assert c.shape == (64,)
    assert (np.asarray(c)[40:] == 0).all()
    assert (np.asarray(c)[:40] > 0).all()


# ---------------------------------------------------------------- training
def test_bpr_training_reduces_loss():
    """A few LightGCN BPR steps on a tiny graph must reduce the loss."""
    g = small_graph(nu=30, ni=20, e=200)
    params = lightgcn.init_params(jax.random.PRNGKey(3), 30, 20, 16, 2)
    rng = np.random.default_rng(0)
    tu, ti = np.asarray(g.user)[:200], np.asarray(g.item)[:200]

    @jax.jit
    def loss_fn(p, users, pos, neg):
        ue, ie = lightgcn.forward(p, g)
        return bpr.bpr_loss(ue, ie, users, pos, neg)

    grad_fn = jax.jit(jax.value_and_grad(loss_fn))
    batch = bpr.sample_bpr_batch(rng, tu, ti, 20, 64)
    l0, _ = grad_fn(params, *[jnp.asarray(b) for b in batch])
    lr = 0.05
    for _ in range(30):
        b = [jnp.asarray(x) for x in bpr.sample_bpr_batch(rng, tu, ti, 20, 64)]
        _, grads = grad_fn(params, *b)
        params = jax.tree.map(
            lambda p, gr: p - lr * gr if isinstance(p, jnp.ndarray) else p,
            params, grads)
    l1, _ = grad_fn(params, *[jnp.asarray(x) for x in batch])
    assert float(l1) < float(l0)


def test_recall_at_k_perfect_and_zero():
    ue = np.eye(3, dtype=np.float32)
    ie = np.eye(3, dtype=np.float32)
    train_mask = np.zeros((3, 3), bool)
    test_pos = [np.array([0]), np.array([1]), np.array([2])]
    assert bpr.recall_at_k(ue, ie, train_mask, test_pos, k=1) == 1.0
    anti = [np.array([1]), np.array([2]), np.array([0])]
    assert bpr.recall_at_k(ue, ie, train_mask, anti, k=1) == 0.0


def test_large_batch_schedule_matches_paper():
    s = LargeBatchSchedule(base_lr=1e-4, base_batch=1000, target_batch=150_000)
    assert s.batch_for_epoch(0) == 15_000      # paper: warm-up = target/10
    assert s.batch_for_epoch(1) == 15_000
    assert s.batch_for_epoch(2) == 150_000
    assert s.linear_scaled_lr(150_000) == pytest.approx(1e-4 * 150)
    assert s.sqrt_scaled_lr(150_000) == pytest.approx(1e-4 * 150 ** 0.5)


# ---------------------------------------------------------------- planner
def test_planner_prefers_write_heavy_in_hbm():
    """Write-intensive tensors (SDDMM messages) must win HBM residency over
    read-only same-size tensors when capacity is tight — the Fig 8
    asymmetry."""
    writey = AccessProfile("messages", 100, reads_per_step=1, writes_per_step=3)
    ready = AccessProfile("graph", 100, reads_per_step=4, writes_per_step=0)
    plan = plan_placement([writey, ready], hbm_budget=100)
    assert plan.tier("messages") == "hbm"
    assert plan.tier("graph") == "host"


def test_planner_greedy_matches_exact():
    profiles = gnn_recsys_profiles(1000, 800, 20_000, 64, 3)
    budget = sum(p.nbytes for p in profiles) // 3
    greedy = plan_placement(profiles, hbm_budget=budget)
    exact = plan_placement_exact(profiles, hbm_budget=budget)
    assert greedy.est_step_penalty_s <= exact.est_step_penalty_s * 1.05


def test_planner_memory_model_matches_paper_scale():
    """Paper §2.1: 1M vertices / 300M edges / 3 layers / dim 128 ≈ 500 GB."""
    profiles = gnn_recsys_profiles(500_000, 500_000, 300_000_000, 128, 3)
    total = sum(p.nbytes for p in profiles)
    assert 300e9 < total < 800e9  # same order as the paper's 500 GB


def test_planner_raises_when_pinned_exceeds_budget():
    p = AccessProfile("x", 1000, pinned="hbm")
    with pytest.raises(MemoryError):
        plan_placement([p], hbm_budget=10)


# ---------------------------------------------------------------- data
def test_synth_density_matches_request():
    d = synth.generate_bipartite(500, 400, 5000, seed=1)
    assert d.n_edges > 4500
    assert abs(d.density - 5000 / (500 * 400)) < 0.01


def test_power_law_degree_distribution():
    d = synth.generate_bipartite(2000, 1500, 30_000, seed=2)
    deg = np.bincount(d.item, minlength=1500)
    top1pct = np.sort(deg)[-15:].sum()
    assert top1pct > 0.1 * d.n_edges  # heavy head, like paper Fig 13


def test_kronecker_expansion_preserves_density_and_count():
    base = synth.generate_bipartite(100, 80, 1000, seed=3)
    out = kronecker.expand_by_factor(base, 25, seed=0)
    assert out.n_edges == base.n_edges * 25
    assert out.n_users == 5 * 100 and out.n_items == 5 * 80
    assert out.density == pytest.approx(base.density, rel=1e-6)


def test_train_test_split_disjoint():
    d = synth.generate_bipartite(100, 80, 1000, seed=4)
    tr, te = synth.train_test_split(d, 0.1, seed=0)
    assert tr.n_edges + te.n_edges == d.n_edges
    k1 = set(zip(tr.user.tolist(), tr.item.tolist()))
    k2 = set(zip(te.user.tolist(), te.item.tolist()))
    assert not (k1 & k2)


def test_loader_resumable():
    u = np.arange(100, dtype=np.int32)
    it = np.arange(100, dtype=np.int32)
    a = EdgeLoader(u, it, batch=16, seed=7)
    next(a); next(a)
    st = a.state_dict()
    b1 = next(a)
    b = EdgeLoader(u, it, batch=16, seed=7)
    b.load_state_dict(st)
    b2 = next(b)
    np.testing.assert_array_equal(b1[0], b2[0])


def test_loader_shards_partition():
    u = np.arange(100, dtype=np.int32)
    seen = []
    for s in range(4):
        l = EdgeLoader(u, u, batch=25, seed=1, shard_id=s, num_shards=4,
                       drop_last=False)
        seen.append(next(l)[0])
    allv = np.concatenate(seen)
    assert len(np.unique(allv)) == 100


def test_sampler_fanout_and_redundancy():
    rng = np.random.default_rng(0)
    src = rng.integers(0, 200, 2000).astype(np.int32)
    dst = rng.integers(0, 200, 2000).astype(np.int32)
    g = build_csr(src, dst, 200)
    blocks = sample_blocks(g, np.arange(8, dtype=np.int32), [10, 5], rng)
    assert len(blocks) == 2
    # deepest-first: last block's dst must be the seeds
    np.testing.assert_array_equal(np.sort(blocks[-1].dst_nodes), np.arange(8))
    # fanout respected: hop-1 block (last after reversal) uses fanouts[0]
    assert blocks[-1].edge_mask.sum() <= 8 * 10
    # deepest block (first) uses fanouts[1] over its own frontier
    assert blocks[0].edge_mask.sum() <= blocks[0].n_dst * 5
    # redundancy metric across two overlapping batches > 1
    b2 = sample_blocks(g, np.arange(4, 12, dtype=np.int32), [10, 5], rng)
    assert subgraph_redundancy([blocks, b2]) > 1.0
