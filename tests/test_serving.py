"""Serving hot-path tests (fast tier): fused-kernel routing, the
block-major staging fix, uniform user-id validation, the HotRowCache,
its planner pricing, ServeCfg, and the BENCH artifact plumbing.

All equality checks are exact (integer-valued embeddings make f32 dot
products exact), so "bit-identical" below means assert_array_equal."""
import json

import numpy as np
import pytest

import benchmarks.common as bench_common
from repro.api import ExperimentSpec, ServeCfg
from repro.eval.recommender import Recommender
from repro.eval.topk import streaming_topk, validate_user_ids
from repro.kernels import ops as kops
from repro.memory import (CacheStats, HostResident, HotRowCache,
                          QuantizedHostResident, TieredExecutor, get_policy,
                          get_topology)
from repro.pipeline.plan import serving_profiles


def _tables(seed=0, nu=30, ni=50, d=16):
    rng = np.random.default_rng(seed)
    ue = rng.integers(-4, 5, (nu, d)).astype(np.float32)
    ie = rng.integers(-4, 5, (ni, d)).astype(np.float32)
    ne = nu * 3
    user = rng.integers(0, nu, ne)
    item = rng.integers(0, ni, ne)
    order = np.lexsort((item, user))
    user, item = user[order], item[order]
    indptr = np.searchsorted(user, np.arange(nu + 1))
    return ue, ie, indptr.astype(np.int64), item.astype(np.int64)


# ----------------------------------------------------------- fused routing
def test_fused_auto_matches_unfused_bitwise():
    ue, ie, indptr, items = _tables()
    kw = dict(seen_indptr=indptr, seen_items=items, user_batch=7,
              item_block=16)
    s_auto, i_auto = streaming_topk(ue, ie, 5, **kw)            # auto-fused
    s_off, i_off = streaming_topk(ue, ie, 5, fused=False, **kw)
    s_on, i_on = streaming_topk(ue, ie, 5, fused=True, **kw)
    np.testing.assert_array_equal(i_auto, i_off)
    np.testing.assert_array_equal(s_auto, s_off)
    np.testing.assert_array_equal(i_auto, i_on)
    np.testing.assert_array_equal(s_auto, s_on)


def test_fused_pallas_matches_xla():
    ue, ie, indptr, items = _tables(seed=3)
    a = streaming_topk(ue, ie, 6, seen_indptr=indptr, seen_items=items,
                       item_block=16, impl="xla")
    b = streaming_topk(ue, ie, 6, seen_indptr=indptr, seen_items=items,
                       item_block=16, impl="pallas")
    np.testing.assert_array_equal(a[0], b[0])
    np.testing.assert_array_equal(a[1], b[1])


def test_fused_requires_device_resident_items():
    ue, ie, *_ = _tables()
    with pytest.raises(ValueError, match="fused"):
        streaming_topk(ue, HostResident(ie), 5, fused=True)
    # auto mode silently falls back to the block-major streamed sweep
    s, i = streaming_topk(ue, HostResident(ie), 5)
    s2, i2 = streaming_topk(ue, ie, 5, fused=False)
    np.testing.assert_array_equal(i, i2)
    np.testing.assert_array_equal(s, s2)


# -------------------------------------------------- block staging (bugfix)
class _CountingHostResident(HostResident):
    def __init__(self, arr):
        super().__init__(arr)
        self.block_calls = 0
        self.take_calls = 0

    def take(self, ids):
        self.take_calls += 1
        return super().take(ids)

    def block(self, ids):
        self.block_calls += 1
        return super().block(ids)


def test_item_blocks_stream_once_per_sweep():
    """Regression: item blocks used to be re-uploaded once per user
    batch (Q× the catalogue bytes per sweep)."""
    ue, ie, indptr, items = _tables(nu=20, ni=50)
    host = _CountingHostResident(ie)
    n_blocks = -(-50 // 16)
    s, i = streaming_topk(ue, host, 5, seen_indptr=indptr, seen_items=items,
                          user_batch=3, item_block=16)   # 7 user batches
    assert host.block_calls == n_blocks                  # NOT 7 * n_blocks
    s2, i2 = streaming_topk(ue, ie, 5, seen_indptr=indptr, seen_items=items,
                            user_batch=3, item_block=16, fused=False)
    np.testing.assert_array_equal(i, i2)
    np.testing.assert_array_equal(s, s2)


def test_device_gathers_once_per_block(monkeypatch):
    """Same fix on the device-resident unfused path, counted in kernel
    dispatches: one item gather per block + one user gather per batch."""
    ue, ie, indptr, items = _tables(nu=20, ni=50)
    calls = []
    orig = kops.embedding_bag

    def counting(*a, **kw):
        calls.append(a[1].shape)
        return orig(*a, **kw)

    monkeypatch.setattr("repro.eval.topk.kops.embedding_bag", counting)
    streaming_topk(ue, ie, 5, seen_indptr=indptr, seen_items=items,
                   user_batch=3, item_block=16, fused=False)
    n_user_batches, n_blocks = 7, -(-50 // 16)
    assert len(calls) == n_user_batches + n_blocks


# ------------------------------------------------------ id validation
def test_user_id_validation_uniform_across_placements():
    ue, ie, indptr, items = _tables()
    fast = Recommender(ue, ie, seen_indptr=indptr, seen_items=items, k=5,
                       topology="uniform")
    demoted = Recommender(ue, ie, seen_indptr=indptr, seen_items=items, k=5,
                          topology="uniform",
                          pins={"serve/user_embed": "slow",
                                "serve/item_embed": "slow"})
    assert demoted.n_offloaded == 2
    for bad in ([-1], [ue.shape[0]], [0, -7], [2**31 - 1]):
        for rec in (fast, demoted):
            with pytest.raises(ValueError, match="out of range"):
                rec.recommend(np.asarray(bad))
    # valid ids agree bit-for-bit between the two placements
    q = np.asarray([0, 3, 29, 3])
    i_f, s_f = fast.recommend(q)
    i_d, s_d = demoted.recommend(q)
    np.testing.assert_array_equal(i_f, i_d)
    np.testing.assert_array_equal(s_f, s_d)
    with pytest.raises(ValueError):
        validate_user_ids(np.asarray([5]), 5)
    validate_user_ids(np.asarray([], np.int32), 0)       # empty is fine


# ------------------------------------------------------------- HotRowCache
def test_cache_counters_and_bit_identity():
    rng = np.random.default_rng(2)
    tab = rng.standard_normal((40, 8)).astype(np.float32)
    cache = HotRowCache(HostResident(tab), rows=4)
    ids = np.asarray([1, 5, 1, 9, 5])
    out = cache.take(ids)
    np.testing.assert_array_equal(out, tab[ids])
    # distinct-rows accounting: 3 distinct rows, all cold
    assert (cache.stats.hits, cache.stats.misses) == (0, 3)
    assert cache.stats.bytes_streamed == 3 * 8 * 4
    out = cache.take(ids)
    np.testing.assert_array_equal(out, tab[ids])
    assert (cache.stats.hits, cache.stats.misses) == (3, 3)
    assert cache.stats.hit_rate == 0.5
    assert cache.resident_rows == 3


def test_cache_lfu_admission_and_eviction():
    tab = np.arange(60, dtype=np.float32).reshape(20, 3)
    cache = HotRowCache(HostResident(tab), rows=2)
    cache.take([0]); cache.take([0]); cache.take([1])     # freq 0:2, 1:1
    assert cache.resident_rows == 2
    # a one-shot scan row (freq 1) must not displace row 1 (freq 1):
    # admission needs *strictly* higher frequency
    cache.take([2])
    assert cache.stats.evictions == 0
    np.testing.assert_array_equal(cache.take([2]), tab[[2]])  # still correct
    # row 2 now at freq 2 > row 1's freq 1 -> deterministic eviction
    cache.take([2])
    assert cache.stats.evictions == 1
    assert cache._slot_of[1] == -1 and cache._slot_of[2] >= 0


def test_cache_capacity_clamp_and_prefill():
    tab = np.ones((5, 4), np.float32)
    cache = HotRowCache(HostResident(tab), rows=100)
    assert cache.rows == 5                                # clamped to table
    cache.prefill(np.arange(5))
    assert cache.resident_rows == 5
    assert (cache.stats.hits, cache.stats.misses) == (0, 0)  # not traffic
    cache.take([0, 4])
    assert cache.stats.misses == 0 and cache.stats.hits == 2


def test_cache_over_quantized_backing_bit_identical():
    rng = np.random.default_rng(5)
    tab = rng.standard_normal((30, 8)).astype(np.float32)
    q = QuantizedHostResident(tab)
    cache = HotRowCache(q, rows=8)
    ids = np.asarray([3, 7, 3, 11])
    first = cache.take(ids)
    np.testing.assert_array_equal(first, q.take(ids))     # dequant bits
    np.testing.assert_array_equal(cache.take(ids), first)  # cached == fresh


def test_recommender_cache_on_equals_off():
    ue, ie, indptr, items = _tables(seed=7, nu=40, ni=60)
    kw = dict(seen_indptr=indptr, seen_items=items, k=6, user_batch=8,
              topology="uniform", pins={"serve/user_embed": "slow"})
    plain = Recommender(ue, ie, **kw)
    cached = Recommender(ue, ie, cache_rows=16, **kw)
    rng = np.random.default_rng(0)
    for _ in range(4):
        q = rng.integers(0, 40, 24)
        i0, s0 = plain.recommend(q)
        i1, s1 = cached.recommend(q)
        np.testing.assert_array_equal(i0, i1)
        np.testing.assert_array_equal(s0, s1)
    stats = cached.cache_stats()["serve/user_embed"]
    assert stats["hits"] > 0 and stats["bytes_streamed"] > 0
    assert "cache[" in cached.describe()
    assert "item_embed->" in cached.describe()
    assert plain.cache_stats() == {}


# ------------------------------------------------------------ plan pricing
def test_cache_rows_priced_against_fast_tier():
    profs = serving_profiles(1000, 1000, row=128, cache_rows=10)
    names = [p.name for p in profs]
    assert names == ["serve/user_embed", "serve/item_embed",
                     "serve/hot_cache"]
    cache_prof = profs[-1]
    assert cache_prof.pinned == "fast"
    assert cache_prof.nbytes == 2 * 10 * 128
    plan = get_policy("greedy")(profs, get_topology("uniform"))
    assert plan.is_fast("serve/hot_cache")
    assert plan.hbm_used >= cache_prof.nbytes
    # cache_rows=0 keeps the exact legacy profile set
    assert [p.name for p in serving_profiles(1000, 1000, row=128)] == \
        ["serve/user_embed", "serve/item_embed"]


def test_cache_reservation_can_demote_a_table():
    # fast budget fits both tables OR one table + the cache, not all
    ue, ie, *_ = _tables(nu=16, ni=16, d=16)
    budget = ue.nbytes + ie.nbytes + 256
    with_cache = Recommender(ue, ie, hbm_budget=budget, topology="uniform",
                             cache_rows=16)
    assert with_cache.plan.is_fast("serve/hot_cache")
    assert with_cache.n_offloaded >= 1                  # something demoted
    without = Recommender(ue, ie, hbm_budget=budget, topology="uniform")
    assert without.n_offloaded == 0


def test_executor_cache_stats_and_describe():
    profs = serving_profiles(400, 400, row=16, cache_rows=4)
    plan = get_policy("greedy")(profs, get_topology("uniform"),
                                pins={"serve/item_embed": "slow"})
    ex = TieredExecutor(plan, prefixes=(), cache_rows=4)
    table = np.ones((25, 4), np.float32)
    placed = ex.host_table("serve/item_embed", table)
    assert isinstance(placed, HotRowCache)
    placed.take([1, 2])
    ex.prefetch_rows("serve/item_embed", [3])
    ex.prefetch_rows("no-such-table", [0])               # no-op
    stats = ex.cache_stats()["serve/item_embed"]
    assert stats["misses"] == 2 and stats["fills"] == 3
    assert "cache[" in ex.describe()
    with pytest.raises(ValueError, match="cache_rows"):
        TieredExecutor(plan, cache_rows=-1)


# ----------------------------------------------------------------- ServeCfg
def test_serve_cfg_round_trip_and_validation():
    spec = ExperimentSpec(serve=ServeCfg(cache_rows=128, fused=True))
    again = ExperimentSpec.from_json(spec.to_json())
    assert again == spec
    assert again.serve.cache_rows == 128 and again.serve.fused is True
    assert ExperimentSpec().serve == ServeCfg()          # identity default
    spec2 = spec.override({"serve.cache_rows": 0, "serve.fused": None})
    assert spec2.serve == ServeCfg()
    with pytest.raises(ValueError, match="cache_rows"):
        ServeCfg(cache_rows=-5)
    with pytest.raises(ValueError, match="unknown"):
        ExperimentSpec.from_dict({"serve": {"bogus": 1}})


# --------------------------------------------------------- BENCH artifacts
def test_write_bench_json_emits_root_and_mirror(tmp_path, monkeypatch):
    root = tmp_path / "repo"
    results = tmp_path / "repo" / "results"
    root.mkdir()
    monkeypatch.setattr(bench_common, "REPO_ROOT", str(root))
    monkeypatch.setattr(bench_common, "BENCH_DIR", str(results))
    path = bench_common.write_bench_json("demo", "sec_a", {"x": 1})
    bench_common.write_bench_json("demo", "sec_b", {"y": 2})
    assert path == str(root / "BENCH_demo.json")
    for p in (root / "BENCH_demo.json", results / "BENCH_demo.json"):
        data = json.loads(p.read_text())
        # sections merge instead of clobbering
        assert data == {"sec_a": {"x": 1}, "sec_b": {"y": 2}}


def test_serving_bench_artifact_is_committed_and_shows_wins():
    """The root-level BENCH_serving.json perf-trajectory artifact exists
    and records the fused+cached arm beating the unfused baseline."""
    import os
    path = os.path.join(bench_common.REPO_ROOT, "BENCH_serving.json")
    with open(path) as f:
        data = json.load(f)["power_law_stream"]
    assert data["fused_speedup_p50"] > 1.0
    assert data["fused_cached_vs_unfused_p50"] > 1.0
    assert 0.0 < data["fused_cached"]["hit_rate"] <= 1.0
    assert data["cache_bytes_saved_frac"] > 0.0


def test_cache_stats_dataclass():
    s = CacheStats()
    assert s.hit_rate == 0.0
    s.hits, s.misses = 3, 1
    assert s.hit_rate == 0.75
    assert s.to_dict()["hit_rate"] == 0.75
