"""Serving hot-path tests (fast tier): fused-kernel routing, the
block-major staging fix, uniform user-id validation, the HotRowCache,
its planner pricing, the ANN block-pruned index, the request-coalescing
queue + RecommenderService, ServeCfg, and the BENCH artifact plumbing.

All equality checks are exact (integer-valued embeddings make f32 dot
products exact), so "bit-identical" below means assert_array_equal."""
import json

import numpy as np
import pytest

import benchmarks.common as bench_common
from repro.api import ExperimentSpec, ServeCfg
from repro.eval.recommender import Recommender
from repro.eval.topk import streaming_topk, validate_user_ids
from repro.kernels import ops as kops
from repro.memory import (CacheStats, HostResident, HotRowCache,
                          QuantizedHostResident, TieredExecutor, get_policy,
                          get_topology)
from repro.pipeline.plan import serving_profiles
from repro.serving import (AnnIndex, ManualClock, QueueFull,
                           RecommenderService, RequestQueue, ann_index_nbytes,
                           ann_topk, bucket_for, recall_against)


def _tables(seed=0, nu=30, ni=50, d=16):
    rng = np.random.default_rng(seed)
    ue = rng.integers(-4, 5, (nu, d)).astype(np.float32)
    ie = rng.integers(-4, 5, (ni, d)).astype(np.float32)
    ne = nu * 3
    user = rng.integers(0, nu, ne)
    item = rng.integers(0, ni, ne)
    order = np.lexsort((item, user))
    user, item = user[order], item[order]
    indptr = np.searchsorted(user, np.arange(nu + 1))
    return ue, ie, indptr.astype(np.int64), item.astype(np.int64)


# ----------------------------------------------------------- fused routing
def test_fused_auto_matches_unfused_bitwise():
    ue, ie, indptr, items = _tables()
    kw = dict(seen_indptr=indptr, seen_items=items, user_batch=7,
              item_block=16)
    s_auto, i_auto = streaming_topk(ue, ie, 5, **kw)            # auto-fused
    s_off, i_off = streaming_topk(ue, ie, 5, fused=False, **kw)
    s_on, i_on = streaming_topk(ue, ie, 5, fused=True, **kw)
    np.testing.assert_array_equal(i_auto, i_off)
    np.testing.assert_array_equal(s_auto, s_off)
    np.testing.assert_array_equal(i_auto, i_on)
    np.testing.assert_array_equal(s_auto, s_on)


def test_fused_pallas_matches_xla():
    ue, ie, indptr, items = _tables(seed=3)
    a = streaming_topk(ue, ie, 6, seen_indptr=indptr, seen_items=items,
                       item_block=16, impl="xla")
    b = streaming_topk(ue, ie, 6, seen_indptr=indptr, seen_items=items,
                       item_block=16, impl="pallas")
    np.testing.assert_array_equal(a[0], b[0])
    np.testing.assert_array_equal(a[1], b[1])


def test_fused_requires_device_resident_items():
    ue, ie, *_ = _tables()
    with pytest.raises(ValueError, match="fused"):
        streaming_topk(ue, HostResident(ie), 5, fused=True)
    # auto mode silently falls back to the block-major streamed sweep
    s, i = streaming_topk(ue, HostResident(ie), 5)
    s2, i2 = streaming_topk(ue, ie, 5, fused=False)
    np.testing.assert_array_equal(i, i2)
    np.testing.assert_array_equal(s, s2)


# -------------------------------------------------- block staging (bugfix)
class _CountingHostResident(HostResident):
    def __init__(self, arr):
        super().__init__(arr)
        self.block_calls = 0
        self.take_calls = 0

    def take(self, ids):
        self.take_calls += 1
        return super().take(ids)

    def block(self, ids):
        self.block_calls += 1
        return super().block(ids)


def test_item_blocks_stream_once_per_sweep():
    """Regression: item blocks used to be re-uploaded once per user
    batch (Q× the catalogue bytes per sweep)."""
    ue, ie, indptr, items = _tables(nu=20, ni=50)
    host = _CountingHostResident(ie)
    n_blocks = -(-50 // 16)
    s, i = streaming_topk(ue, host, 5, seen_indptr=indptr, seen_items=items,
                          user_batch=3, item_block=16)   # 7 user batches
    assert host.block_calls == n_blocks                  # NOT 7 * n_blocks
    s2, i2 = streaming_topk(ue, ie, 5, seen_indptr=indptr, seen_items=items,
                            user_batch=3, item_block=16, fused=False)
    np.testing.assert_array_equal(i, i2)
    np.testing.assert_array_equal(s, s2)


def test_device_gathers_once_per_block(monkeypatch):
    """Same fix on the device-resident unfused path, counted in kernel
    dispatches: one item gather per block + one user gather per batch."""
    ue, ie, indptr, items = _tables(nu=20, ni=50)
    calls = []
    orig = kops.embedding_bag

    def counting(*a, **kw):
        calls.append(a[1].shape)
        return orig(*a, **kw)

    monkeypatch.setattr("repro.eval.topk.kops.embedding_bag", counting)
    streaming_topk(ue, ie, 5, seen_indptr=indptr, seen_items=items,
                   user_batch=3, item_block=16, fused=False)
    n_user_batches, n_blocks = 7, -(-50 // 16)
    assert len(calls) == n_user_batches + n_blocks


# ------------------------------------------------------ id validation
def test_user_id_validation_uniform_across_placements():
    ue, ie, indptr, items = _tables()
    fast = Recommender(ue, ie, seen_indptr=indptr, seen_items=items, k=5,
                       topology="uniform")
    demoted = Recommender(ue, ie, seen_indptr=indptr, seen_items=items, k=5,
                          topology="uniform",
                          pins={"serve/user_embed": "slow",
                                "serve/item_embed": "slow"})
    assert demoted.n_offloaded == 2
    for bad in ([-1], [ue.shape[0]], [0, -7], [2**31 - 1]):
        for rec in (fast, demoted):
            with pytest.raises(ValueError, match="out of range"):
                rec.recommend(np.asarray(bad))
    # valid ids agree bit-for-bit between the two placements
    q = np.asarray([0, 3, 29, 3])
    i_f, s_f = fast.recommend(q)
    i_d, s_d = demoted.recommend(q)
    np.testing.assert_array_equal(i_f, i_d)
    np.testing.assert_array_equal(s_f, s_d)
    with pytest.raises(ValueError):
        validate_user_ids(np.asarray([5]), 5)
    validate_user_ids(np.asarray([], np.int32), 0)       # empty is fine


# ------------------------------------------------------------- HotRowCache
def test_cache_counters_and_bit_identity():
    rng = np.random.default_rng(2)
    tab = rng.standard_normal((40, 8)).astype(np.float32)
    cache = HotRowCache(HostResident(tab), rows=4)
    ids = np.asarray([1, 5, 1, 9, 5])
    out = cache.take(ids)
    np.testing.assert_array_equal(out, tab[ids])
    # distinct-rows accounting: 3 distinct rows, all cold
    assert (cache.stats.hits, cache.stats.misses) == (0, 3)
    assert cache.stats.bytes_streamed == 3 * 8 * 4
    out = cache.take(ids)
    np.testing.assert_array_equal(out, tab[ids])
    assert (cache.stats.hits, cache.stats.misses) == (3, 3)
    assert cache.stats.hit_rate == 0.5
    assert cache.resident_rows == 3


def test_cache_lfu_admission_and_eviction():
    tab = np.arange(60, dtype=np.float32).reshape(20, 3)
    cache = HotRowCache(HostResident(tab), rows=2)
    cache.take([0]); cache.take([0]); cache.take([1])     # freq 0:2, 1:1
    assert cache.resident_rows == 2
    # a one-shot scan row (freq 1) must not displace row 1 (freq 1):
    # admission needs *strictly* higher frequency
    cache.take([2])
    assert cache.stats.evictions == 0
    np.testing.assert_array_equal(cache.take([2]), tab[[2]])  # still correct
    # row 2 now at freq 2 > row 1's freq 1 -> deterministic eviction
    cache.take([2])
    assert cache.stats.evictions == 1
    assert cache._slot_of[1] == -1 and cache._slot_of[2] >= 0


def test_cache_capacity_clamp_and_prefill():
    tab = np.ones((5, 4), np.float32)
    cache = HotRowCache(HostResident(tab), rows=100)
    assert cache.rows == 5                                # clamped to table
    cache.prefill(np.arange(5))
    assert cache.resident_rows == 5
    assert (cache.stats.hits, cache.stats.misses) == (0, 0)  # not traffic
    cache.take([0, 4])
    assert cache.stats.misses == 0 and cache.stats.hits == 2


def test_cache_over_quantized_backing_bit_identical():
    rng = np.random.default_rng(5)
    tab = rng.standard_normal((30, 8)).astype(np.float32)
    q = QuantizedHostResident(tab)
    cache = HotRowCache(q, rows=8)
    ids = np.asarray([3, 7, 3, 11])
    first = cache.take(ids)
    np.testing.assert_array_equal(first, q.take(ids))     # dequant bits
    np.testing.assert_array_equal(cache.take(ids), first)  # cached == fresh


def test_recommender_cache_on_equals_off():
    ue, ie, indptr, items = _tables(seed=7, nu=40, ni=60)
    kw = dict(seen_indptr=indptr, seen_items=items, k=6, user_batch=8,
              topology="uniform", pins={"serve/user_embed": "slow"})
    plain = Recommender(ue, ie, **kw)
    cached = Recommender(ue, ie, cache_rows=16, **kw)
    rng = np.random.default_rng(0)
    for _ in range(4):
        q = rng.integers(0, 40, 24)
        i0, s0 = plain.recommend(q)
        i1, s1 = cached.recommend(q)
        np.testing.assert_array_equal(i0, i1)
        np.testing.assert_array_equal(s0, s1)
    stats = cached.cache_stats()["serve/user_embed"]
    assert stats["hits"] > 0 and stats["bytes_streamed"] > 0
    assert "cache[" in cached.describe()
    assert "item_embed->" in cached.describe()
    assert plain.cache_stats() == {}


# ------------------------------------------------------------ plan pricing
def test_cache_rows_priced_against_fast_tier():
    profs = serving_profiles(1000, 1000, row=128, cache_rows=10)
    names = [p.name for p in profs]
    assert names == ["serve/user_embed", "serve/item_embed",
                     "serve/hot_cache"]
    cache_prof = profs[-1]
    assert cache_prof.pinned == "fast"
    assert cache_prof.nbytes == 2 * 10 * 128
    plan = get_policy("greedy")(profs, get_topology("uniform"))
    assert plan.is_fast("serve/hot_cache")
    assert plan.hbm_used >= cache_prof.nbytes
    # cache_rows=0 keeps the exact legacy profile set
    assert [p.name for p in serving_profiles(1000, 1000, row=128)] == \
        ["serve/user_embed", "serve/item_embed"]


def test_cache_reservation_can_demote_a_table():
    # fast budget fits both tables OR one table + the cache, not all
    ue, ie, *_ = _tables(nu=16, ni=16, d=16)
    budget = ue.nbytes + ie.nbytes + 256
    with_cache = Recommender(ue, ie, hbm_budget=budget, topology="uniform",
                             cache_rows=16)
    assert with_cache.plan.is_fast("serve/hot_cache")
    assert with_cache.n_offloaded >= 1                  # something demoted
    without = Recommender(ue, ie, hbm_budget=budget, topology="uniform")
    assert without.n_offloaded == 0


def test_executor_cache_stats_and_describe():
    profs = serving_profiles(400, 400, row=16, cache_rows=4)
    plan = get_policy("greedy")(profs, get_topology("uniform"),
                                pins={"serve/item_embed": "slow"})
    ex = TieredExecutor(plan, prefixes=(), cache_rows=4)
    table = np.ones((25, 4), np.float32)
    placed = ex.host_table("serve/item_embed", table)
    assert isinstance(placed, HotRowCache)
    placed.take([1, 2])
    ex.prefetch_rows("serve/item_embed", [3])
    ex.prefetch_rows("no-such-table", [0])               # no-op
    stats = ex.cache_stats()["serve/item_embed"]
    assert stats["misses"] == 2 and stats["fills"] == 3
    assert "cache[" in ex.describe()
    with pytest.raises(ValueError, match="cache_rows"):
        TieredExecutor(plan, cache_rows=-1)


# ------------------------------------------------------------- ANN: parity
@pytest.mark.parametrize("block", [16, 13])      # aligned + ragged tail
def test_ann_keep_all_bitwise_matches_streaming(block):
    """keep_frac=1.0 scans every block and must be bit-identical to the
    exact streamed sweep — scores, ids, and the (score desc, id asc)
    tie order — including seen-exclusion."""
    ue, ie, indptr, items = _tables(seed=11, nu=25, ni=70)
    index = AnnIndex(ie, block=block)
    kw = dict(seen_indptr=indptr, seen_items=items, user_batch=7,
              item_block=16)
    qs = np.asarray([0, 3, 24, 3, 17], np.int32)
    es, ei = streaming_topk(ue, ie, 5, user_ids=qs, **kw)
    ps, pi = ann_topk(index, ue, ie, 5, keep_frac=1.0, user_ids=qs, **kw)
    np.testing.assert_array_equal(es, ps)
    np.testing.assert_array_equal(ei, pi)


@pytest.mark.parametrize("store", ["int8", "cached"])
def test_ann_keep_all_bitwise_through_placements(store):
    """The index is built from the *served* bytes, so keep_frac=1.0
    stays bit-identical when the item table is int8-stored or sits
    behind the HotRowCache."""
    ue, ie, indptr, items = _tables(seed=13, nu=20, ni=64)
    kw = dict(seen_indptr=indptr, seen_items=items, k=6, user_batch=8,
              topology="uniform", pins={"serve/item_embed": "slow"})
    if store == "int8":
        kw["embed_store"] = "int8"
    else:
        kw["cache_rows"] = 16
    exact = Recommender(ue, ie, **kw)
    ann = Recommender(ue, ie, ann=True, keep_frac=1.0, ann_block=16, **kw)
    q = np.asarray([1, 5, 19, 5])
    i0, s0 = exact.recommend(q)
    i1, s1 = ann.recommend(q)
    np.testing.assert_array_equal(i0, i1)
    np.testing.assert_array_equal(s0, s1)
    assert "ann[" in ann.describe()


def test_ann_bound_dominates_member_scores():
    """block_bounds is a valid per-block score upper bound: no member's
    exact score may exceed its block's bound (Cauchy-Schwarz + the
    quantization-error inflation)."""
    rng = np.random.default_rng(3)
    ie = rng.standard_normal((500, 12)).astype(np.float32)
    ue = rng.standard_normal((9, 12)).astype(np.float32)
    index = AnnIndex(ie, block=32)
    bounds = index.block_bounds(ue, len(ue), impl="xla")
    exact = ue @ ie.T                                       # [9, 500]
    for b in range(index.n_blocks):
        members = index.order[b * index.blk:(b + 1) * index.blk]
        best = exact[:, members].max(axis=1)
        assert np.all(best <= bounds[:, b] + 1e-4), f"block {b}"


def test_ann_pruned_recall_floor_on_zipf_stream():
    """A genuinely pruned configuration (keep_frac=0.25) must keep
    recall@10 >= 0.95 against the exact sweep on a power-law stream
    over a clustered catalogue."""
    rng = np.random.default_rng(3)
    n_items, dim, nc = 8192, 16, 64
    centers = rng.normal(0, 1.0, (nc, dim)).astype(np.float32)
    ie = (centers[rng.integers(0, nc, n_items)]
          + 0.05 * rng.normal(0, 1, (n_items, dim))).astype(np.float32)
    ue = (centers[rng.integers(0, nc, 256)]
          + 0.3 * rng.normal(0, 1, (256, dim))).astype(np.float32)
    perm = rng.permutation(256)
    stream = perm[np.minimum(rng.zipf(1.3, 256) - 1, 255)][:64] \
        .astype(np.int32)
    index = AnnIndex(ie, block=32)
    _, exact_ids = streaming_topk(ue, ie, 10, user_ids=stream, user_batch=8)
    _, ann_ids = ann_topk(index, ue, ie, 10, keep_frac=0.25,
                          user_ids=stream, user_batch=8)
    rec = recall_against(exact_ids, ann_ids)
    assert rec >= 0.95, f"pruned recall@10 {rec:.3f} < 0.95"
    # pruning really happened: the shortlist is a strict block subset
    assert index.n_keep(0.25) < index.n_blocks
    assert recall_against(exact_ids, exact_ids) == 1.0


def test_ann_select_blocks_rank_voting_and_determinism():
    rng = np.random.default_rng(0)
    index = AnnIndex(rng.standard_normal((256, 8)).astype(np.float32),
                     block=8)                       # 32 blocks
    aff = rng.standard_normal((4, index.n_blocks)).astype(np.float32)
    kept = index.select_blocks(aff, 0.25)           # n_keep = 8 >= batch
    assert np.array_equal(kept, index.select_blocks(aff.copy(), 0.25))
    assert np.array_equal(kept, np.sort(kept))      # ascending contract
    for u in range(4):                              # every argmax survives
        assert int(np.argmax(aff[u])) in kept
    # all-equal affinities: ties break toward lower block id
    flat = np.zeros((2, index.n_blocks), np.float32)
    np.testing.assert_array_equal(index.select_blocks(flat, 0.25),
                                  np.arange(8))


def test_ann_knob_validation_and_pricing():
    rng = np.random.default_rng(1)
    ie = rng.standard_normal((100, 8)).astype(np.float32)
    index = AnnIndex(ie, block=16)
    for bad in (0.0, -0.1, 1.5):
        with pytest.raises(ValueError, match="keep_frac"):
            index.n_keep(bad)
    with pytest.raises(ValueError, match="reorder"):
        AnnIndex(ie, reorder="kmeans")
    # the static pricing formula equals the built index's footprint
    assert ann_index_nbytes(100, 8, 16) == index.nbytes
    # planner profile: pinned fast, only present when ann is on
    profs = serving_profiles(1000, 1000, row=32,
                             ann_index_bytes=index.nbytes)
    ann_prof = {p.name: p for p in profs}["serve/ann_index"]
    assert ann_prof.pinned == "fast" and ann_prof.nbytes == index.nbytes
    assert "serve/ann_index" not in {
        p.name for p in serving_profiles(1000, 1000, row=32)}
    rec = Recommender(ie[:50], ie, ann=True, ann_block=16,
                      topology="uniform")
    assert rec.plan.is_fast("serve/ann_index")
    with pytest.raises(ValueError, match="keep_frac"):
        Recommender(ie[:50], ie, ann=True, keep_frac=0.0,
                    topology="uniform")


# ------------------------------------------------------- coalescing queue
def test_bucket_ladder():
    assert [bucket_for(n, 64) for n in (1, 2, 3, 5, 9, 64)] == \
        [1, 2, 4, 8, 16, 64]
    assert bucket_for(65, 64) == 64                 # capped at max_batch
    with pytest.raises(ValueError, match="n >= 1"):
        bucket_for(0, 64)


def test_queue_two_trigger_dispatch_under_manual_clock():
    clock = ManualClock()
    q = RequestQueue(max_batch=4, max_wait_us=100, clock=clock)
    q.submit(7)
    assert not q.ready() and q.next_batch() is None  # neither trigger yet
    assert q.next_deadline_us() == 100
    clock.advance(99)
    assert not q.ready()
    clock.advance(1)                                 # deadline trigger
    assert q.ready()
    batch = q.next_batch()
    assert batch.user_ids == (7,) and batch.bucket == 1
    assert batch.wait_us == (100,)
    for uid in (1, 2, 3, 4):                         # occupancy trigger
        q.submit(uid)
    assert q.ready()                                 # full, no wait needed
    batch = q.next_batch()
    assert batch.user_ids == (1, 2, 3, 4) and batch.occupancy == 1.0
    # pad-to-bucket: 3 pending -> bucket 4, pad slots repeat user id 0
    q.submit(5); q.submit(6); q.submit(8)
    batch = q.next_batch(force=True)
    assert batch.bucket == 4 and batch.user_ids == (5, 6, 8, 0)
    assert len(batch.requests) == 3 and batch.occupancy == 0.75


def test_queue_backpressure_and_stats():
    q = RequestQueue(max_batch=2, max_wait_us=0, max_depth=3,
                     clock=ManualClock())
    for uid in range(3):
        q.submit(uid)
    with pytest.raises(QueueFull):
        q.submit(99)
    assert q.stats()["rejected"] == 1 and q.stats()["depth"] == 3
    q.next_batch(); q.next_batch()
    s = q.stats()
    assert s["dispatched"] == 3 and s["batches"] == 2 and s["depth"] == 0
    assert 0.0 < s["mean_occupancy"] <= 1.0
    with pytest.raises(ValueError, match="max_depth"):
        RequestQueue(max_batch=8, max_depth=4)
    with pytest.raises(ValueError, match="max_batch"):
        RequestQueue(max_batch=0)
    with pytest.raises(ValueError, match="advance"):
        ManualClock().advance(-1)


def test_queue_determinism_same_trace_same_batches():
    """Batch composition is a pure function of the (trace, clock) pair:
    replaying the same submissions at the same virtual times yields
    identical batches."""
    def play():
        clock = ManualClock()
        q = RequestQueue(max_batch=4, max_wait_us=50, clock=clock)
        out = []
        for step, uid in enumerate([5, 3, 9, 1, 7, 2, 8, 4, 6]):
            q.submit(uid)
            clock.advance(17)
            b = q.next_batch()
            if b is not None:
                out.append((b.user_ids, b.bucket, b.t_dispatch_us,
                            tuple(r.req_id for r in b.requests)))
        while len(q):
            clock.advance(50)
            b = q.next_batch()
            if b is not None:
                out.append((b.user_ids, b.bucket, b.t_dispatch_us,
                            tuple(r.req_id for r in b.requests)))
        return out
    first, second = play(), play()
    assert first == second and len(first) > 1


# --------------------------------------------------------------- service
def test_service_end_to_end_matches_recommender():
    ue, ie, indptr, items = _tables(seed=17, nu=30, ni=50)
    rec = Recommender(ue, ie, seen_indptr=indptr, seen_items=items, k=5,
                      user_batch=8, topology="uniform")
    svc = RecommenderService(rec, max_batch=4, max_wait_us=200,
                             clock=ManualClock())
    users = [3, 11, 3, 29, 0, 7, 15, 22, 9]
    for uid in users:
        svc.submit(uid)
    responses = svc.drain()
    assert [r.user_id for r in responses] == users
    want_ids, want_scores = rec.recommend(np.asarray(users, np.int32))
    for row, r in enumerate(responses):
        np.testing.assert_array_equal(r.ids, want_ids[row])
        np.testing.assert_array_equal(r.scores, want_scores[row])
        assert r.total_us == r.wait_us + r.service_us
    s = svc.stats()
    assert s["completed"] == len(users) and s["depth"] == 0
    assert s["batches"] == 3                        # 4 + 4 + 1
    assert s["service_p50_us"] > 0 and s["total_p99_us"] >= s["total_p50_us"]
    assert s["cache_hit_rate"] == {}
    assert "RecommenderService[" in svc.describe()
    # virtual time advanced by the measured batch compute
    assert svc.clock.now_us() > 0


def test_service_backpressure_reexport():
    ue, ie, *_ = _tables()
    svc = RecommenderService(Recommender(ue, ie, k=3, topology="uniform"),
                             max_batch=1, max_depth=1, max_wait_us=0,
                             clock=ManualClock())
    svc.submit(0)
    with pytest.raises(QueueFull):
        svc.submit(1)
    assert len(svc.poll(force=True)) == 1


# ----------------------------------------------------------------- ServeCfg
def test_serve_cfg_round_trip_and_validation():
    spec = ExperimentSpec(serve=ServeCfg(cache_rows=128, fused=True))
    again = ExperimentSpec.from_json(spec.to_json())
    assert again == spec
    assert again.serve.cache_rows == 128 and again.serve.fused is True
    assert ExperimentSpec().serve == ServeCfg()          # identity default
    spec2 = spec.override({"serve.cache_rows": 0, "serve.fused": None})
    assert spec2.serve == ServeCfg()
    with pytest.raises(ValueError, match="cache_rows"):
        ServeCfg(cache_rows=-5)
    with pytest.raises(ValueError, match="unknown"):
        ExperimentSpec.from_dict({"serve": {"bogus": 1}})


def test_serve_cfg_ann_and_queue_fields_round_trip_and_validation():
    spec = ExperimentSpec(serve=ServeCfg(ann=True, keep_frac=0.25,
                                         queue_max_batch=16,
                                         queue_max_wait_us=500))
    again = ExperimentSpec.from_json(spec.to_json())
    assert again == spec
    assert again.serve.ann is True and again.serve.keep_frac == 0.25
    assert again.serve.queue_max_batch == 16
    assert again.serve.queue_max_wait_us == 500
    assert spec.override({"serve.keep_frac": 1.0, "serve.ann": False,
                          "serve.queue_max_batch": 64,
                          "serve.queue_max_wait_us": 1000}).serve == \
        ServeCfg()
    for bad in ({"keep_frac": 0.0}, {"keep_frac": 1.5},
                {"queue_max_batch": 0}, {"queue_max_wait_us": -1}):
        with pytest.raises(ValueError):
            ServeCfg(**bad)


# --------------------------------------------------------- BENCH artifacts
def test_write_bench_json_emits_root_and_mirror(tmp_path, monkeypatch):
    root = tmp_path / "repo"
    results = tmp_path / "repo" / "results"
    root.mkdir()
    monkeypatch.setattr(bench_common, "REPO_ROOT", str(root))
    monkeypatch.setattr(bench_common, "BENCH_DIR", str(results))
    path = bench_common.write_bench_json("demo", "sec_a", {"x": 1})
    bench_common.write_bench_json("demo", "sec_b", {"y": 2})
    assert path == str(root / "BENCH_demo.json")
    for p in (root / "BENCH_demo.json", results / "BENCH_demo.json"):
        data = json.loads(p.read_text())
        # sections merge instead of clobbering
        assert data == {"sec_a": {"x": 1}, "sec_b": {"y": 2}}


def test_serving_bench_artifact_is_committed_and_shows_wins():
    """The root-level BENCH_serving.json perf-trajectory artifact exists
    and records the fused+cached arm beating the unfused baseline."""
    import os
    path = os.path.join(bench_common.REPO_ROOT, "BENCH_serving.json")
    with open(path) as f:
        data = json.load(f)
    stream = data["power_law_stream"]
    assert stream["fused_speedup_p50"] > 1.0
    assert stream["fused_cached_vs_unfused_p50"] > 1.0
    assert 0.0 < stream["fused_cached"]["hit_rate"] <= 1.0
    assert stream["cache_bytes_saved_frac"] > 0.0
    # the steady-state arm is prefilled; the cold transient is reported
    # in its own arm instead of polluting the steady p99
    assert stream["fused_cached_cold"]["hit_rate"] <= \
        stream["fused_cached"]["hit_rate"]
    ann = data["ann_retrieval"]
    assert ann["n_items"] >= 65536
    assert ann["recall_at_10"] >= 0.95
    assert ann["speedup_p50"] >= 3.0
    assert ann["keep_all_bitwise"] is True
    load = data["load"]
    assert load["coalescing_wins"] is True
    assert load["coalescing_throughput_gain"] > 1.0
    assert load["open_loop"]["coalesced"]["total_p99_us"] <= \
        load["open_loop"]["per_request"]["total_p99_us"]


def test_cache_stats_dataclass():
    s = CacheStats()
    assert s.hit_rate == 0.0
    s.hits, s.misses = 3, 1
    assert s.hit_rate == 0.75
    assert s.to_dict()["hit_rate"] == 0.75
