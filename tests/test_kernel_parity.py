"""Kernel parity on adversarial shapes + gradient checks (slow tier).

Pallas kernels (interpret mode) vs the ``kernels/ref.py`` oracles on the
shapes that break naive tilings: empty destination rows, edge counts
that are not a multiple of the edge block, feature widths that are not a
multiple of 128 (the TPU lane width), row/bag counts that don't divide
their block.  Plus finite-difference checks of the custom-VJP SpMM ops
in ``pipeline/sparse.py`` on both dispatch paths.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.embedding_bag import embedding_bag_pallas
from repro.kernels.sddmm import sddmm_pallas
from repro.kernels.spmm import build_csr_by_dst, spmm_csr_pallas
from repro.pipeline.sparse import BipartiteCSR

pytestmark = pytest.mark.slow


# ------------------------------------------------------------------- spmm
@pytest.mark.parametrize("reduce", ["sum", "max"])
@pytest.mark.parametrize("gather", [False, True])
@pytest.mark.parametrize("n,e,d,rb", [
    (9, 30, 100, 4),     # D not a multiple of 128, n % row_block != 0
    (13, 21, 37, 8),     # everything ragged
    (6, 12, 130, 4),     # D just over one lane tile
    (5, 1, 8, 4),        # single edge
])
def test_spmm_adversarial_shapes(reduce, gather, n, e, d, rb):
    rng = np.random.default_rng(hash((reduce, gather, n, e, d)) % 2**31)
    src = rng.integers(0, n, e).astype(np.int32)
    # adversarial: all edges land on a strict subset of rows, so several
    # destination rows are empty (the -inf -> 0 path for 'max')
    dst = rng.integers(0, max(n // 2, 1), e).astype(np.int32)
    indptr, src_sorted, perm = build_csr_by_dst(dst, src, n)
    if gather:
        values = rng.standard_normal((n, d)).astype(np.float32)
    else:
        values = rng.standard_normal((e, d)).astype(np.float32)[perm]
    got = spmm_csr_pallas(reduce, jnp.asarray(values), jnp.asarray(indptr),
                          jnp.asarray(src_sorted), n, row_block=rb,
                          gather=gather)
    want = ref.spmm_csr_ref(reduce, jnp.asarray(values), jnp.asarray(indptr),
                            jnp.asarray(src_sorted), n, gather=gather)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
    # empty rows really exist and are exactly zero in both
    empty = np.diff(indptr) == 0
    assert empty.any()
    np.testing.assert_array_equal(np.asarray(got)[empty], 0.0)


# ------------------------------------------------------------------ sddmm
@pytest.mark.parametrize("op", ["mul", "add", "dot", "copy"])
@pytest.mark.parametrize("n,e,d,eb", [
    (7, 13, 100, 8),     # E % edge_block != 0, D % 128 != 0
    (5, 1, 37, 16),      # single edge, block > E
    (11, 33, 130, 16),   # D just over one lane tile
])
def test_sddmm_adversarial_shapes(op, n, e, d, eb):
    rng = np.random.default_rng(hash((op, n, e, d)) % 2**31)
    x = rng.standard_normal((n, d)).astype(np.float32)
    y = rng.standard_normal((n, d)).astype(np.float32)
    src = rng.integers(0, n, e).astype(np.int32)
    dst = rng.integers(0, n, e).astype(np.int32)
    mask = rng.random(e) > 0.3
    coeff = rng.standard_normal(e).astype(np.float32) if op == "copy" else None
    args = (jnp.asarray(x), jnp.asarray(y), jnp.asarray(src),
            jnp.asarray(dst), jnp.asarray(mask),
            None if coeff is None else jnp.asarray(coeff))
    got = sddmm_pallas(op, *args, edge_block=eb)
    want = ref.sddmm_ref(op, *args)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------- embedding bag
@pytest.mark.parametrize("combiner", ["sum", "mean"])
@pytest.mark.parametrize("v,b,l,d,bb", [
    (17, 5, 3, 100, 4),   # B % bag_block != 0, D % 128 != 0
    (9, 1, 4, 37, 8),     # single bag
    (33, 7, 2, 130, 4),
])
def test_embedding_bag_adversarial_shapes(combiner, v, b, l, d, bb):
    rng = np.random.default_rng(hash((combiner, v, b, l, d)) % 2**31)
    table = rng.standard_normal((v, d)).astype(np.float32)
    ids = rng.integers(0, v, (b, l)).astype(np.int32)
    mask = rng.random((b, l)) > 0.4
    mask[0, :] = False                       # a fully-empty bag
    got = embedding_bag_pallas(jnp.asarray(table), jnp.asarray(ids),
                               jnp.asarray(mask), combiner, bag_block=bb)
    want = ref.embedding_bag_ref(jnp.asarray(table), jnp.asarray(ids),
                                 jnp.asarray(mask), combiner)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(got)[0], 0.0)  # empty bag -> 0


# ------------------------------------------- custom-VJP SpMM grad checks
def _fd_check(loss, x, probes, eps=1e-2, rtol=2e-2):
    """Central finite differences along a few unit probes vs autodiff."""
    g = jax.grad(loss)(x)
    for idx in probes:
        probe = jnp.zeros_like(x).at[idx].set(1.0)
        fd = (loss(x + eps * probe) - loss(x - eps * probe)) / (2 * eps)
        np.testing.assert_allclose(np.asarray(g)[idx], fd, rtol=rtol,
                                   atol=1e-3)


@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_adj_matmul_custom_vjp_finite_difference(impl):
    """d/dx sum(f(A x)) via the custom VJP (reverse-direction SpMM) must
    match central finite differences on both dispatch paths."""
    rng = np.random.default_rng(0)
    nu, ni, e, d = 8, 6, 18, 4
    user = rng.integers(0, nu, e).astype(np.int32)
    item = rng.integers(0, ni, e).astype(np.int32)
    g = BipartiteCSR(user, item, nu, ni, impl=impl)
    x = jnp.asarray(rng.standard_normal((nu, d)).astype(np.float32))

    def loss(x):
        return jnp.sum(g.agg_u2i(x) ** 2) + jnp.sum(g.agg_i2u(g.agg_u2i(x)))

    _fd_check(loss, x, [(0, 0), (3, 2), (7, 3)])


@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_edge_agg_custom_vjp_finite_difference(impl):
    """d/dvalues of the edge aggregation (SDDMM-copy gather VJP)."""
    rng = np.random.default_rng(1)
    nu, ni, e, d = 6, 7, 15, 3
    user = rng.integers(0, nu, e).astype(np.int32)
    item = rng.integers(0, ni, e).astype(np.int32)
    g = BipartiteCSR(user, item, nu, ni, impl=impl)
    values = jnp.asarray(rng.standard_normal((e, d)).astype(np.float32))

    def loss(v):
        return jnp.sum(jnp.tanh(g.edge_agg_item(v)))

    _fd_check(loss, values, [(0, 0), (7, 1), (14, 2)])


def test_custom_vjp_matches_plain_autodiff_of_ref():
    """The hand-written VJP equals XLA autodiff of the reference SpMM
    contraction (the paper's grad-is-the-reverse-SpMM identity)."""
    rng = np.random.default_rng(2)
    nu, ni, e, d = 10, 9, 30, 5
    user = rng.integers(0, nu, e).astype(np.int32)
    item = rng.integers(0, ni, e).astype(np.int32)
    g = BipartiteCSR(user, item, nu, ni, impl="xla")
    x = jnp.asarray(rng.standard_normal((nu, d)).astype(np.float32))
    a = np.zeros((ni, nu), np.float32)
    np.add.at(a, (item, user), 1.0)
    a = jnp.asarray(a)

    def via_custom(x):
        return jnp.sum(jnp.sin(g.agg_u2i(x)))

    def via_dense(x):
        return jnp.sum(jnp.sin(a @ x))

    np.testing.assert_allclose(jax.grad(via_custom)(x),
                               jax.grad(via_dense)(x), rtol=1e-4, atol=1e-5)
