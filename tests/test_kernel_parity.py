"""Kernel parity on adversarial shapes + gradient checks (slow tier).

Pallas kernels (interpret mode) vs the ``kernels/ref.py`` oracles on the
shapes that break naive tilings: empty destination rows, edge counts
that are not a multiple of the edge block, feature widths that are not a
multiple of 128 (the TPU lane width), row/bag counts that don't divide
their block.  Plus finite-difference checks of the custom-VJP SpMM ops
in ``pipeline/sparse.py`` on both dispatch paths.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.embedding_bag import embedding_bag_pallas
from repro.kernels.sddmm import sddmm_pallas
from repro.kernels.spmm import build_csr_by_dst, spmm_csr_pallas
from repro.pipeline.sparse import BipartiteCSR

pytestmark = pytest.mark.slow


# ------------------------------------------------------------------- spmm
@pytest.mark.parametrize("reduce", ["sum", "max"])
@pytest.mark.parametrize("gather", [False, True])
@pytest.mark.parametrize("n,e,d,rb", [
    (9, 30, 100, 4),     # D not a multiple of 128, n % row_block != 0
    (13, 21, 37, 8),     # everything ragged
    (6, 12, 130, 4),     # D just over one lane tile
    (5, 1, 8, 4),        # single edge
])
def test_spmm_adversarial_shapes(reduce, gather, n, e, d, rb):
    rng = np.random.default_rng(hash((reduce, gather, n, e, d)) % 2**31)
    src = rng.integers(0, n, e).astype(np.int32)
    # adversarial: all edges land on a strict subset of rows, so several
    # destination rows are empty (the -inf -> 0 path for 'max')
    dst = rng.integers(0, max(n // 2, 1), e).astype(np.int32)
    indptr, src_sorted, perm = build_csr_by_dst(dst, src, n)
    if gather:
        values = rng.standard_normal((n, d)).astype(np.float32)
    else:
        values = rng.standard_normal((e, d)).astype(np.float32)[perm]
    got = spmm_csr_pallas(reduce, jnp.asarray(values), jnp.asarray(indptr),
                          jnp.asarray(src_sorted), n, row_block=rb,
                          gather=gather)
    want = ref.spmm_csr_ref(reduce, jnp.asarray(values), jnp.asarray(indptr),
                            jnp.asarray(src_sorted), n, gather=gather)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
    # empty rows really exist and are exactly zero in both
    empty = np.diff(indptr) == 0
    assert empty.any()
    np.testing.assert_array_equal(np.asarray(got)[empty], 0.0)


# ------------------------------------------------------------------ sddmm
@pytest.mark.parametrize("op", ["mul", "add", "dot", "copy"])
@pytest.mark.parametrize("n,e,d,eb", [
    (7, 13, 100, 8),     # E % edge_block != 0, D % 128 != 0
    (5, 1, 37, 16),      # single edge, block > E
    (11, 33, 130, 16),   # D just over one lane tile
])
def test_sddmm_adversarial_shapes(op, n, e, d, eb):
    rng = np.random.default_rng(hash((op, n, e, d)) % 2**31)
    x = rng.standard_normal((n, d)).astype(np.float32)
    y = rng.standard_normal((n, d)).astype(np.float32)
    src = rng.integers(0, n, e).astype(np.int32)
    dst = rng.integers(0, n, e).astype(np.int32)
    mask = rng.random(e) > 0.3
    coeff = rng.standard_normal(e).astype(np.float32) if op == "copy" else None
    args = (jnp.asarray(x), jnp.asarray(y), jnp.asarray(src),
            jnp.asarray(dst), jnp.asarray(mask),
            None if coeff is None else jnp.asarray(coeff))
    got = sddmm_pallas(op, *args, edge_block=eb)
    want = ref.sddmm_ref(op, *args)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------- embedding bag
@pytest.mark.parametrize("combiner", ["sum", "mean"])
@pytest.mark.parametrize("v,b,l,d,bb", [
    (17, 5, 3, 100, 4),   # B % bag_block != 0, D % 128 != 0
    (9, 1, 4, 37, 8),     # single bag
    (33, 7, 2, 130, 4),
])
def test_embedding_bag_adversarial_shapes(combiner, v, b, l, d, bb):
    rng = np.random.default_rng(hash((combiner, v, b, l, d)) % 2**31)
    table = rng.standard_normal((v, d)).astype(np.float32)
    ids = rng.integers(0, v, (b, l)).astype(np.int32)
    mask = rng.random((b, l)) > 0.4
    mask[0, :] = False                       # a fully-empty bag
    got = embedding_bag_pallas(jnp.asarray(table), jnp.asarray(ids),
                               jnp.asarray(mask), combiner, bag_block=bb)
    want = ref.embedding_bag_ref(jnp.asarray(table), jnp.asarray(ids),
                                 jnp.asarray(mask), combiner)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(got)[0], 0.0)  # empty bag -> 0


# ------------------------------------------- custom-VJP SpMM grad checks
def _fd_check(loss, x, probes, eps=1e-2, rtol=2e-2):
    """Central finite differences along a few unit probes vs autodiff."""
    g = jax.grad(loss)(x)
    for idx in probes:
        probe = jnp.zeros_like(x).at[idx].set(1.0)
        fd = (loss(x + eps * probe) - loss(x - eps * probe)) / (2 * eps)
        np.testing.assert_allclose(np.asarray(g)[idx], fd, rtol=rtol,
                                   atol=1e-3)


@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_adj_matmul_custom_vjp_finite_difference(impl):
    """d/dx sum(f(A x)) via the custom VJP (reverse-direction SpMM) must
    match central finite differences on both dispatch paths."""
    rng = np.random.default_rng(0)
    nu, ni, e, d = 8, 6, 18, 4
    user = rng.integers(0, nu, e).astype(np.int32)
    item = rng.integers(0, ni, e).astype(np.int32)
    g = BipartiteCSR(user, item, nu, ni, impl=impl)
    x = jnp.asarray(rng.standard_normal((nu, d)).astype(np.float32))

    def loss(x):
        return jnp.sum(g.agg_u2i(x) ** 2) + jnp.sum(g.agg_i2u(g.agg_u2i(x)))

    _fd_check(loss, x, [(0, 0), (3, 2), (7, 3)])


@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_edge_agg_custom_vjp_finite_difference(impl):
    """d/dvalues of the edge aggregation (SDDMM-copy gather VJP)."""
    rng = np.random.default_rng(1)
    nu, ni, e, d = 6, 7, 15, 3
    user = rng.integers(0, nu, e).astype(np.int32)
    item = rng.integers(0, ni, e).astype(np.int32)
    g = BipartiteCSR(user, item, nu, ni, impl=impl)
    values = jnp.asarray(rng.standard_normal((e, d)).astype(np.float32))

    def loss(v):
        return jnp.sum(jnp.tanh(g.edge_agg_item(v)))

    _fd_check(loss, values, [(0, 0), (7, 1), (14, 2)])


def test_custom_vjp_matches_plain_autodiff_of_ref():
    """The hand-written VJP equals XLA autodiff of the reference SpMM
    contraction (the paper's grad-is-the-reverse-SpMM identity)."""
    rng = np.random.default_rng(2)
    nu, ni, e, d = 10, 9, 30, 5
    user = rng.integers(0, nu, e).astype(np.int32)
    item = rng.integers(0, ni, e).astype(np.int32)
    g = BipartiteCSR(user, item, nu, ni, impl="xla")
    x = jnp.asarray(rng.standard_normal((nu, d)).astype(np.float32))
    a = np.zeros((ni, nu), np.float32)
    np.add.at(a, (item, user), 1.0)
    a = jnp.asarray(a)

    def via_custom(x):
        return jnp.sum(jnp.sin(g.agg_u2i(x)))

    def via_dense(x):
        return jnp.sum(jnp.sin(a @ x))

    np.testing.assert_allclose(jax.grad(via_custom)(x),
                               jax.grad(via_dense)(x), rtol=1e-4, atol=1e-5)


# ------------------------------------------------------ hadamard_spmm
def _hadamard_case(seed, n_src, n_dst, e, integer=False):
    """dst-sorted CSR + per-edge (x_idx, y_idx) gather indices; edges
    land on a strict subset of destinations so empty rows exist."""
    rng = np.random.default_rng(seed)
    dst = np.sort(rng.integers(0, max(n_dst // 2, 1), e)).astype(np.int32)
    indptr = np.searchsorted(dst, np.arange(n_dst + 1)).astype(np.int32)
    x_idx = rng.integers(0, n_src, e).astype(np.int32)
    y_idx = rng.integers(0, n_dst, e).astype(np.int32)

    def feats(n, d):
        if integer:
            return rng.integers(-3, 4, (n, d)).astype(np.float32)
        return rng.standard_normal((n, d)).astype(np.float32)

    return indptr, x_idx, y_idx, dst, feats


@pytest.mark.parametrize("n_src,n_dst,e,d,rb", [
    (9, 7, 30, 100, 4),    # D % 128 != 0, n_dst % row_block != 0
    (13, 11, 21, 37, 8),   # everything ragged
    (6, 5, 1, 130, 4),     # single edge, D just over one lane tile
    (8, 6, 0, 16, 4),      # zero edges: all rows empty
])
def test_hadamard_spmm_adversarial_shapes(n_src, n_dst, e, d, rb):
    from repro.kernels.hadamard_spmm import hadamard_spmm_pallas
    indptr, x_idx, y_idx, _, feats = _hadamard_case(
        hash((n_src, n_dst, e, d)) % 2**31, n_src, n_dst, e)
    x, y = feats(n_src, d), feats(n_dst, d)
    got = hadamard_spmm_pallas(jnp.asarray(x), jnp.asarray(y),
                               jnp.asarray(indptr), jnp.asarray(x_idx),
                               jnp.asarray(y_idx), n_dst, row_block=rb)
    want = ref.hadamard_spmm_ref(jnp.asarray(x), jnp.asarray(y),
                                 jnp.asarray(indptr), jnp.asarray(x_idx),
                                 jnp.asarray(y_idx), n_dst)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
    empty = np.diff(indptr) == 0
    assert empty.any()
    np.testing.assert_array_equal(np.asarray(got)[empty], 0.0)


def test_hadamard_spmm_integer_exact():
    """Integer-valued embeddings: accumulation order cannot matter, so
    the fused kernel must match the oracle BIT-exactly."""
    from repro.kernels.hadamard_spmm import hadamard_spmm_pallas
    indptr, x_idx, y_idx, _, feats = _hadamard_case(7, 12, 9, 40,
                                                    integer=True)
    x, y = feats(12, 24), feats(9, 24)
    got = hadamard_spmm_pallas(jnp.asarray(x), jnp.asarray(y),
                               jnp.asarray(indptr), jnp.asarray(x_idx),
                               jnp.asarray(y_idx), 9, row_block=4)
    want = ref.hadamard_spmm_ref(jnp.asarray(x), jnp.asarray(y),
                                 jnp.asarray(indptr), jnp.asarray(x_idx),
                                 jnp.asarray(y_idx), 9)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_hadamard_spmm_fused_epilogue():
    """Degree-norm scale + leaky-relu applied in-VMEM must match the
    oracle's epilogue composition."""
    from repro.kernels.hadamard_spmm import hadamard_spmm_pallas
    n_src, n_dst, e, d = 10, 8, 25, 36
    indptr, x_idx, y_idx, _, feats = _hadamard_case(11, n_src, n_dst, e)
    x, y = feats(n_src, d), feats(n_dst, d)
    rng = np.random.default_rng(12)
    scale = rng.standard_normal(n_dst).astype(np.float32)
    args = (jnp.asarray(x), jnp.asarray(y), jnp.asarray(indptr),
            jnp.asarray(x_idx), jnp.asarray(y_idx), n_dst)
    got = hadamard_spmm_pallas(*args, scale=jnp.asarray(scale), slope=0.2,
                               row_block=4)
    want = ref.hadamard_spmm_ref(*args, scale=jnp.asarray(scale), slope=0.2)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("structure", ["y_is_dst", "x_eq_y"])
def test_hadamard_spmm_structure_variants_match_oracle(structure):
    """The structured XLA routes (no [E, D] intermediate) must equal the
    naive gather/segment oracle when the asserted structure holds."""
    from repro.kernels.hadamard_spmm import hadamard_spmm_xla
    n_src, n_dst, e, d = 9, 7, 28, 20
    indptr, x_idx, y_idx, dst, feats = _hadamard_case(13, n_src, n_dst, e)
    if structure == "y_is_dst":
        y_idx = dst.copy()                      # y rides the destination
        n_y = n_dst
    else:
        y_idx = x_idx.copy()                    # both gathers share an index
        n_y = n_src
    x, y = feats(n_src, d), feats(n_y, d)
    args = (jnp.asarray(x), jnp.asarray(y), jnp.asarray(indptr),
            jnp.asarray(x_idx), jnp.asarray(y_idx), n_dst)
    got = hadamard_spmm_xla(*args, structure=structure)
    want = ref.hadamard_spmm_ref(*args)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_hadamard_spmm_ops_dispatch_parity():
    """kernels.ops dispatch: impl='pallas' and impl='xla' agree."""
    from repro.kernels import ops as kops
    n_src, n_dst, e, d = 8, 6, 20, 12
    indptr, x_idx, y_idx, _, feats = _hadamard_case(17, n_src, n_dst, e)
    x, y = feats(n_src, d), feats(n_dst, d)
    args = (jnp.asarray(x), jnp.asarray(y), jnp.asarray(indptr),
            jnp.asarray(x_idx), jnp.asarray(y_idx), n_dst)
    a = kops.hadamard_spmm(*args, impl="xla")
    b = kops.hadamard_spmm(*args, impl="pallas")
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)


def test_hadamard_spmm_bad_structure_raises():
    from repro.kernels.hadamard_spmm import hadamard_spmm_xla
    with pytest.raises(ValueError, match="structure"):
        hadamard_spmm_xla(jnp.zeros((2, 3)), jnp.zeros((2, 3)),
                          jnp.zeros(3, jnp.int32), jnp.zeros(1, jnp.int32),
                          jnp.zeros(1, jnp.int32), 2, structure="nope")


@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_hadamard_agg_rematerializing_vjp_finite_difference(impl):
    """The fused Hadamard aggregation's rematerializing VJP (residuals
    are node embeddings only; cotangents are themselves fused calls)
    must match central finite differences in BOTH arguments."""
    rng = np.random.default_rng(3)
    nu, ni, e, d = 7, 6, 16, 4
    user = rng.integers(0, nu, e).astype(np.int32)
    item = rng.integers(0, ni, e).astype(np.int32)
    g = BipartiteCSR(user, item, nu, ni, impl=impl, hadamard="fused")
    xu = jnp.asarray(rng.standard_normal((nu, d)).astype(np.float32))
    xi = jnp.asarray(rng.standard_normal((ni, d)).astype(np.float32))

    def loss_u(xu):
        return jnp.sum(jnp.tanh(g.hadamard_agg_item(xu, xi)))

    def loss_i(xi):
        return jnp.sum(jnp.tanh(g.hadamard_agg_item(xu, xi))) \
            + jnp.sum(g.hadamard_agg_user(xi, xu) ** 2)

    _fd_check(loss_u, xu, [(0, 0), (3, 2), (6, 3)])
    _fd_check(loss_i, xi, [(0, 0), (2, 1), (5, 3)])


def test_hadamard_agg_vjp_matches_autodiff_of_oracle():
    """Fused hadamard_agg gradients equal XLA autodiff of the naive
    gather-multiply-segment composition (which stores [E, D] residuals;
    ours rematerializes them)."""
    rng = np.random.default_rng(4)
    nu, ni, e, d = 9, 8, 26, 5
    user = rng.integers(0, nu, e).astype(np.int32)
    item = rng.integers(0, ni, e).astype(np.int32)
    g = BipartiteCSR(user, item, nu, ni, impl="xla", hadamard="fused")
    xu = rng.standard_normal((nu, d)).astype(np.float32)
    xi = rng.standard_normal((ni, d)).astype(np.float32)

    def fused(xu, xi):
        return jnp.sum(jnp.sin(g.hadamard_agg_item(xu, xi)))

    def naive(xu, xi):
        msgs = xu[g.ui_src] * xi[g.ui_dst]
        agg = jax.ops.segment_sum(msgs, g.ui_dst, num_segments=ni)
        return jnp.sum(jnp.sin(agg))

    gu_f, gi_f = jax.grad(fused, argnums=(0, 1))(jnp.asarray(xu),
                                                 jnp.asarray(xi))
    gu_n, gi_n = jax.grad(naive, argnums=(0, 1))(jnp.asarray(xu),
                                                 jnp.asarray(xi))
    np.testing.assert_allclose(gu_f, gu_n, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(gi_f, gi_n, rtol=1e-4, atol=1e-5)


# ------------------------------------------------- fused serving kernel
def _fused_both(ue, ie, seen, mask, k, blk):
    """(xla-ref, pallas-interpret) results of the fused serving kernel."""
    from repro.kernels import ops as kops
    ni = ie.shape[0]
    a = kops.fused_topk_score(jnp.asarray(ue), jnp.asarray(ie),
                              jnp.asarray(seen), jnp.asarray(mask),
                              k=k, n_items=ni, item_block=blk, impl="xla")
    b = kops.fused_topk_score(jnp.asarray(ue), jnp.asarray(ie),
                              jnp.asarray(seen), jnp.asarray(mask),
                              k=k, n_items=ni, item_block=blk, impl="pallas")
    return a, b


def _streamed_reference(ue, ie, seen, mask, k, blk):
    """The pre-fused streamed sweep as oracle: block-major _merge_block
    calls over the same block schedule (bit-exact tie contract)."""
    from repro.eval import topk as streaming
    b_users = ue.shape[0]
    ni = ie.shape[0]
    carry_s = jnp.full((b_users, k), -np.inf, jnp.float32)
    carry_i = jnp.full((b_users, k), -1, jnp.int32)
    for b0 in range(0, -(-ni // blk) * blk, blk):
        ids_np = np.arange(b0, b0 + blk)
        valid = ids_np < ni
        block_ids = jnp.asarray(np.where(valid, ids_np, -1).astype(np.int32))
        ie_blk = jnp.asarray(ie[np.where(valid, ids_np, 0)])
        carry_s, carry_i = streaming._merge_block(
            jnp.asarray(ue), ie_blk, block_ids, jnp.asarray(seen),
            jnp.asarray(mask), jnp.int32(b0), carry_s, carry_i, k=k)
    return np.asarray(carry_s), np.asarray(carry_i)


@pytest.mark.parametrize("case", [
    "integer_ties",      # many exactly-equal scores -> id-asc order
    "neg_zero",          # -0.0 scores must canonicalize to +0.0
    "k_gt_catalogue",    # K > I: tail slots are (-inf, -1)
    "fully_masked",      # a user with every item seen
    "ragged_d",          # D % 128 != 0, B % tile != 0, I % blk != 0
    "empty_seen",        # zero-width seen CSR
])
def test_fused_kernel_adversarial_parity(case):
    rng = np.random.default_rng(abs(hash(case)) % 2**31)
    b, ni, d, k, blk, L = 9, 37, 12, 5, 8, 4
    ue = rng.integers(-2, 3, (b, d)).astype(np.float32)
    ie = rng.integers(-2, 3, (ni, d)).astype(np.float32)
    seen = rng.integers(0, ni, (b, L)).astype(np.int32)
    mask = rng.random((b, L)) < 0.5
    if case == "integer_ties":
        ie = np.repeat(ie[: ni // 3 + 1], 3, axis=0)[:ni]  # duplicate rows
    elif case == "neg_zero":
        ue = np.full((b, d), -1.0, np.float32)
        ie[::2] = 0.0                       # (-1)·0 = -0.0 pre-canonical
    elif case == "k_gt_catalogue":
        ni, k = 6, 11
        ie = ie[:ni]
        seen = np.minimum(seen, ni - 1)
    elif case == "fully_masked":
        ni, L = 6, 6
        ie = ie[:ni]
        seen = np.broadcast_to(np.arange(ni, dtype=np.int32), (b, ni)).copy()
        mask = np.ones((b, ni), bool)       # every candidate masked
    elif case == "ragged_d":
        d, b, blk = 130, 7, 5               # nothing divides anything
        ue = rng.integers(-2, 3, (b, d)).astype(np.float32)
        ie = rng.integers(-2, 3, (ni, d)).astype(np.float32)
        seen = seen[:b]
        mask = mask[:b]
    elif case == "empty_seen":
        seen = np.zeros((b, 0), np.int32)
        mask = np.zeros((b, 0), bool)
    (s_x, i_x), (s_p, i_p) = _fused_both(ue, ie, seen, mask, k, blk)
    s_ref, i_ref = _streamed_reference(ue, ie, seen, mask, k, blk)
    np.testing.assert_array_equal(np.asarray(s_x), s_ref)
    np.testing.assert_array_equal(np.asarray(i_x), i_ref)
    np.testing.assert_array_equal(np.asarray(s_p), s_ref)
    np.testing.assert_array_equal(np.asarray(i_p), i_ref)
    if case == "fully_masked":
        assert (np.asarray(i_x) == -1).all()
        assert np.isneginf(np.asarray(s_x)).all()
    if case == "k_gt_catalogue":
        assert (np.asarray(i_x)[:, ni:] == -1).all()
        assert np.isneginf(np.asarray(s_x)[:, ni:]).all()


@pytest.mark.parametrize("embed_store", ["fp32", "int8"])
def test_cache_on_off_bit_identity_sweep(embed_store):
    """Randomized serving sweeps: cache-enabled recommendations are
    bit-identical to cache-off for every placement/store combination."""
    from repro.eval.recommender import Recommender
    for seed in range(6):
        rng = np.random.default_rng(seed)
        nu, ni, d = int(rng.integers(5, 40)), int(rng.integers(5, 50)), 8
        ue = rng.integers(-3, 4, (nu, d)).astype(np.float32)
        ie = rng.integers(-3, 4, (ni, d)).astype(np.float32)
        ne = int(rng.integers(0, nu * 3))
        user = np.sort(rng.integers(0, nu, ne))
        item = rng.integers(0, ni, ne)
        indptr = np.searchsorted(user, np.arange(nu + 1)).astype(np.int64)
        kw = dict(seen_indptr=indptr, seen_items=item.astype(np.int64),
                  k=int(rng.integers(1, 9)), user_batch=4,
                  topology="uniform", embed_store=embed_store,
                  pins={"serve/user_embed": "slow",
                        "serve/item_embed": "slow"})
        plain = Recommender(ue, ie, **kw)
        cached = Recommender(ue, ie, cache_rows=int(rng.integers(1, 16)),
                             **kw)
        for _ in range(3):
            q = rng.integers(0, nu, int(rng.integers(1, 20)))
            i0, s0 = plain.recommend(q)
            i1, s1 = cached.recommend(q)
            np.testing.assert_array_equal(i0, i1)
            np.testing.assert_array_equal(s0, s1)


# --------------------------------------------------- ann coarse kernel
@pytest.mark.parametrize("b,nb,d", [
    (9, 37, 12),       # nothing tile-aligned
    (1, 1, 130),       # single user, single block, D over one lane tile
    (7, 129, 8),       # n_blocks just over the 128-lane tile
])
def test_ann_block_scores_pallas_matches_xla(b, nb, d):
    """The ANN coarse stage (int8 centroid dot + norm·radius bound) on
    adversarial shapes: pallas interpret vs the kernels/ref.py oracle,
    through the ops dispatch both ways."""
    from repro.kernels import ops as kops
    rng = np.random.default_rng(hash((b, nb, d)) % 2**31)
    ue = rng.standard_normal((b, d)).astype(np.float32)
    cq = rng.integers(-127, 128, (nb, d)).astype(np.int8)
    scale = rng.uniform(1e-3, 0.1, nb).astype(np.float32)
    radius = rng.uniform(0.0, 2.0, nb).astype(np.float32)
    args = (jnp.asarray(ue), jnp.asarray(cq), jnp.asarray(scale),
            jnp.asarray(radius))
    want = ref.ann_block_scores_ref(*args)
    got = kops.ann_block_scores(*args, impl="pallas")
    assert got.shape == (b, nb)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(
        np.asarray(kops.ann_block_scores(*args, impl="xla")),
        np.asarray(want))
    # radius=0 degenerates to the pure centroid affinity (what the
    # serving index ranks blocks by)
    aff = kops.ann_block_scores(args[0], args[1], args[2],
                                jnp.zeros(nb, jnp.float32), impl="pallas")
    np.testing.assert_allclose(
        np.asarray(aff), np.asarray(ue @ (cq.astype(np.float32)
                                          * scale[:, None]).T),
        rtol=1e-5, atol=1e-5)
