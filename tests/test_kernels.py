"""Per-kernel allclose sweeps: Pallas (interpret=True) vs pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.embedding_bag import embedding_bag_pallas
from repro.kernels.sddmm import sddmm_pallas
from repro.kernels.spmm import build_csr_by_dst, spmm_csr_pallas


def rand_graph(rng, n, e, d):
    x = rng.standard_normal((n, d)).astype(np.float32)
    y = rng.standard_normal((n, d)).astype(np.float32)
    src = rng.integers(0, n, e).astype(np.int32)
    dst = rng.integers(0, n, e).astype(np.int32)
    mask = np.ones(e, bool)
    mask[e - max(1, e // 8):] = False  # padded tail
    return x, y, src, dst, mask


@pytest.mark.parametrize("op", ["mul", "add", "dot", "copy"])
@pytest.mark.parametrize("n,e,d", [(16, 33, 8), (64, 128, 16), (7, 20, 128)])
def test_sddmm_matches_ref(op, n, e, d):
    rng = np.random.default_rng(hash((op, n, e, d)) % 2**31)
    x, y, src, dst, mask = rand_graph(rng, n, e, d)
    coeff = rng.standard_normal(e).astype(np.float32) if op == "copy" else None
    got = sddmm_pallas(op, jnp.asarray(x), jnp.asarray(y), jnp.asarray(src),
                       jnp.asarray(dst), jnp.asarray(mask),
                       None if coeff is None else jnp.asarray(coeff),
                       edge_block=16)
    want = ref.sddmm_ref(op, jnp.asarray(x), jnp.asarray(y), jnp.asarray(src),
                         jnp.asarray(dst), jnp.asarray(mask),
                         None if coeff is None else jnp.asarray(coeff))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("reduce", ["sum", "max"])
@pytest.mark.parametrize("gather", [False, True])
@pytest.mark.parametrize("n,e,d", [(16, 40, 8), (32, 100, 32)])
def test_spmm_matches_ref(reduce, gather, n, e, d):
    rng = np.random.default_rng(hash((reduce, gather, n, e, d)) % 2**31)
    src = rng.integers(0, n, e).astype(np.int32)
    dst = rng.integers(0, n, e).astype(np.int32)
    indptr, src_sorted, perm = build_csr_by_dst(dst, src, n)
    if gather:
        values = rng.standard_normal((n, d)).astype(np.float32)
    else:
        msg = rng.standard_normal((e, d)).astype(np.float32)
        values = msg[perm]  # dst-sorted messages
    got = spmm_csr_pallas(reduce, jnp.asarray(values), jnp.asarray(indptr),
                          jnp.asarray(src_sorted), n, row_block=4,
                          gather=gather)
    want = ref.spmm_csr_ref(reduce, jnp.asarray(values), jnp.asarray(indptr),
                            jnp.asarray(src_sorted), n, gather=gather)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("combiner", ["sum", "mean"])
@pytest.mark.parametrize("v,b,l,d", [(32, 9, 4, 8), (128, 16, 7, 32)])
def test_embedding_bag_matches_ref(combiner, v, b, l, d):
    rng = np.random.default_rng(hash((combiner, v, b, l, d)) % 2**31)
    table = rng.standard_normal((v, d)).astype(np.float32)
    ids = rng.integers(0, v, (b, l)).astype(np.int32)
    mask = rng.random((b, l)) > 0.3
    mask[:, 0] = True
    got = embedding_bag_pallas(jnp.asarray(table), jnp.asarray(ids),
                               jnp.asarray(mask), combiner, bag_block=4)
    want = ref.embedding_bag_ref(jnp.asarray(table), jnp.asarray(ids),
                                 jnp.asarray(mask), combiner)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_sddmm_empty_mask_edge():
    """All-masked graph must produce zeros (no NaN from padded ids)."""
    x = jnp.ones((4, 8))
    src = jnp.zeros(12, jnp.int32)
    dst = jnp.zeros(12, jnp.int32)
    mask = jnp.zeros(12, bool)
    out = sddmm_pallas("mul", x, x, src, dst, mask, edge_block=8)
    assert not jnp.isnan(out).any()
    np.testing.assert_allclose(out, 0.0)


def test_xla_and_pallas_grads_agree():
    """Autodiff through the XLA path == finite-difference sanity (paper:
    SDDMM/SpMM gradients are themselves SDDMM/SpMM)."""
    from repro.core import sparse_ops
    n, e, d = 10, 24, 4
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((n, d)).astype(np.float32))
    src = jnp.asarray(rng.integers(0, n, e).astype(np.int32))
    dst = jnp.asarray(rng.integers(0, n, e).astype(np.int32))
    mask = jnp.ones(e, bool)

    def loss(x):
        m = sparse_ops.sddmm("mul", x, x, src, dst, mask)
        h = sparse_ops.spmm("sum", m, dst, n, mask)
        return jnp.sum(h ** 2)

    g = jax.grad(loss)(x)
    eps = 1e-3
    probe = jnp.zeros_like(x).at[3, 2].set(1.0)
    fd = (loss(x + eps * probe) - loss(x - eps * probe)) / (2 * eps)
    np.testing.assert_allclose(g[3, 2], fd, rtol=1e-2)
