"""Runtime-layer tests: optimizers, compression, checkpoint/restart,
fault-tolerant loop, subgraph baseline."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.optim import adafactor, adam, sgd
from repro.optim.compression import (ErrorFeedback, dequantize_int8,
                                     make_int8_compressor,
                                     make_topk_compressor, quantize_int8,
                                     topk_densify, topk_sparsify)
from repro.optim.optimizers import WarmupLinearLR, global_norm_clip
from repro.runtime.loop import LoopConfig, run_training


def quad_problem():
    target = jnp.asarray(np.random.default_rng(0).standard_normal((4, 8)),
                         jnp.float32)

    def loss(p):
        return jnp.mean((p["w"] - target) ** 2)

    params = {"w": jnp.zeros((4, 8))}
    return loss, params


@pytest.mark.parametrize("opt_name", ["sgd", "adam", "adafactor"])
def test_optimizers_converge(opt_name):
    loss, params = quad_problem()
    opt = {"sgd": sgd(5.0), "adam": adam(0.1),
           "adafactor": adafactor(0.3)}[opt_name]
    state = opt.init(params)
    l0 = float(loss(params))
    for _ in range(60):
        grads = jax.grad(loss)(params)
        params, state = opt.update(grads, state, params)
    assert float(loss(params)) < l0 * 0.05


def test_adafactor_bf16_grads():
    """adafactor must accept bf16 grads without materializing f32 copies
    (the API contract used by the 340B/1T train steps)."""
    loss, params = quad_problem()
    params = jax.tree.map(lambda p: p.astype(jnp.bfloat16), params)
    opt = adafactor(0.3)
    state = opt.init(params)
    for _ in range(40):
        g = jax.grad(lambda p: loss(jax.tree.map(
            lambda x: x.astype(jnp.float32), p)))(params)
        params, state = opt.update(g, state, params)
    assert float(loss(jax.tree.map(lambda x: x.astype(jnp.float32),
                                   params))) < 0.5


def test_warmup_lr():
    fn = WarmupLinearLR(peak_lr=1.0, warmup_steps=10)
    assert float(fn(jnp.int32(5))) == pytest.approx(0.5)
    assert float(fn(jnp.int32(100))) == pytest.approx(1.0)


def test_global_norm_clip():
    g = {"a": jnp.full((10,), 3.0)}
    clipped, norm = global_norm_clip(g, 1.0)
    assert float(norm) == pytest.approx(np.sqrt(90), rel=1e-5)
    n2 = jnp.sqrt(jnp.sum(clipped["a"] ** 2))
    assert float(n2) == pytest.approx(1.0, rel=1e-5)


# ---------------------------------------------------------------- compression
def test_int8_quantization_unbiased():
    rng = jax.random.PRNGKey(0)
    g = jax.random.normal(jax.random.PRNGKey(1), (1000,))
    acc = jnp.zeros_like(g)
    n = 30
    for i in range(n):
        q, s = quantize_int8(g, jax.random.fold_in(rng, i))
        acc = acc + dequantize_int8(q, s)
    np.testing.assert_allclose(acc / n, g, atol=0.02)


def test_topk_roundtrip():
    g = jnp.asarray(np.random.default_rng(2).standard_normal((64,)), jnp.float32)
    vals, idx, residual = topk_sparsify(g, 8)
    dense = topk_densify(vals, idx, g.shape)
    np.testing.assert_allclose(dense + residual, g, rtol=1e-6)
    assert (jnp.abs(dense[idx]) > 0).all()


def test_error_feedback_preserves_signal():
    """With error feedback, repeated compression of a constant gradient
    must transmit the full magnitude over time."""
    g = {"w": jnp.asarray(np.random.default_rng(3).standard_normal((128,)),
                          jnp.float32)}
    compress = make_topk_compressor(0.1)
    errors = ErrorFeedback.init(g)
    sent = jnp.zeros_like(g["w"])
    for _ in range(50):
        g_hat, errors = ErrorFeedback.apply(g, errors, compress)
        sent = sent + g_hat["w"]
    np.testing.assert_allclose(sent / 50, g["w"], atol=0.25)


def test_int8_compressor_error_feedback():
    g = {"w": jnp.linspace(-1, 1, 256)}
    compress = make_int8_compressor(jax.random.PRNGKey(0))
    errors = ErrorFeedback.init(g)
    g_hat, errors = ErrorFeedback.apply(g, errors, compress)
    np.testing.assert_allclose(g_hat["w"], g["w"], atol=0.02)


# ---------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(10), "b": [jnp.ones((3, 3)), jnp.zeros(2)],
            "t": jnp.int32(7)}
    save_checkpoint(str(tmp_path), 5, tree)
    assert latest_step(str(tmp_path)) == 5
    restored, step = restore_checkpoint(str(tmp_path), tree)
    assert step == 5
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(a, b)


def test_checkpoint_atomic_commit(tmp_path):
    tree = {"a": jnp.arange(4)}
    save_checkpoint(str(tmp_path), 1, tree)
    # a torn write (no COMMITTED marker) must be ignored
    os.makedirs(tmp_path / "step_2")
    with open(tmp_path / "step_2" / "manifest.json", "w") as f:
        f.write("{}")
    assert latest_step(str(tmp_path)) == 1


def test_checkpoint_async(tmp_path):
    tree = {"a": jnp.arange(100)}
    t = save_checkpoint(str(tmp_path), 3, tree, async_=True)
    t.join()
    restored, _ = restore_checkpoint(str(tmp_path), tree)
    np.testing.assert_array_equal(restored["a"], tree["a"])


def test_checkpoint_structure_mismatch_raises(tmp_path):
    save_checkpoint(str(tmp_path), 1, {"a": jnp.arange(4)})
    with pytest.raises(ValueError):
        restore_checkpoint(str(tmp_path), {"a": jnp.arange(4),
                                           "b": jnp.arange(2)})


# ---------------------------------------------------------------- loop
def test_loop_checkpoints_and_resumes(tmp_path):
    loss_fn, params = quad_problem()
    opt = sgd(0.2)

    def make_state():
        return {"params": params, "opt": opt.init(params)}

    def step_fn(state, step):
        grads = jax.grad(loss_fn)(state["params"])
        p, o = opt.update(grads, state["opt"], state["params"])
        return {"params": p, "opt": o}, loss_fn(p)

    cfg = LoopConfig(ckpt_dir=str(tmp_path), ckpt_every=5, max_steps=12,
                     async_ckpt=False)
    rep1 = run_training(cfg, make_state(), step_fn)
    assert rep1.steps_run == 12 and rep1.resumed_from is None
    # crash-restart: run again -> resumes from the final checkpoint
    cfg2 = LoopConfig(ckpt_dir=str(tmp_path), ckpt_every=5, max_steps=20,
                      async_ckpt=False)
    rep2 = run_training(cfg2, make_state(), step_fn)
    assert rep2.resumed_from == 12
    assert rep2.steps_run == 8
    assert rep2.losses[-1] < rep1.losses[0]


def test_loop_straggler_detection(tmp_path):
    import time
    calls = {"relayout": 0}

    def step_fn(state, step):
        time.sleep(0.02)
        return state, 0.0

    def on_relayout(state):
        calls["relayout"] += 1
        return state

    cfg = LoopConfig(ckpt_dir=str(tmp_path), ckpt_every=100, max_steps=8,
                     step_deadline_s=0.001, max_strays=3, async_ckpt=False)
    rep = run_training(cfg, {"x": jnp.zeros(1)}, step_fn, on_relayout)
    assert rep.relayout_requests >= 2
    assert calls["relayout"] >= 2


# ---------------------------------------------------------------- subgraph
def test_subgraph_trainer_step_and_redundancy():
    from repro.dist.subgraph import SubgraphTrainer
    rng = np.random.default_rng(0)
    n = 300
    src = rng.integers(0, n, 3000).astype(np.int32)
    dst = rng.integers(0, n, 3000).astype(np.int32)
    x = jnp.asarray(rng.standard_normal((n, 8)).astype(np.float32))
    tr = SubgraphTrainer(src, dst, n, n_layers=2, fanout=5, n_workers=2)

    def loss_fn(emb, seeds):
        return jnp.mean(emb ** 2)

    seeds = rng.integers(0, n, 32).astype(np.int32)
    grads, stats = tr.step(seeds, x, loss_fn)
    assert grads.shape == x.shape
    assert stats.sample_s > 0 and stats.backward_s > 0
    assert stats.expanded_vertices > 32
    tr.step(seeds, x, loss_fn)  # overlapping batch
    assert tr.redundancy() > 1.0


def test_max_subgraph_batch_decreases_with_depth():
    from repro.dist.subgraph import max_subgraph_batch
    kw = dict(n_nodes_est_per_seed=1.0, embed_dim=128, mem_bytes=1e9,
              fanout=10, avg_degree=50)
    b1 = max_subgraph_batch(n_layers=1, **kw)
    b2 = max_subgraph_batch(n_layers=2, **kw)
    b3 = max_subgraph_batch(n_layers=3, **kw)
    assert b1 > b2 > b3  # paper Table 5: exponential shrink with depth
