"""Streaming top-K eval/serving vs the dense oracle — bit-for-bit.

Strategy: integer-valued embeddings make every user-item dot product
exactly representable in float32 regardless of summation order, so the
streamed block-merged ranking must equal a stable dense argsort
*exactly* — including tie handling (ties are common with integer
scores, which is the point: the (score desc, id asc) contract is
actually exercised).  On top of id equality, the metrics computed from
both rankings must be identical floats.

Property sweeps run under hypothesis when it is installed (see
requirements-dev.txt) and fall back to a seeded random sweep otherwise,
so the invariants are exercised either way.  The sweeps cover the
adversarial cases from the issue: K > candidate count, users with zero
test items, block sizes that don't divide the item count, fully-masked
users.
"""
import numpy as np
import pytest

from repro.core import bpr
from repro.data import synth
from repro.eval import (Recommender, evaluate_embeddings, ranked_hits,
                        ranking_metrics, streaming_topk)

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def _property(n_examples: int = 30):
    """Run the wrapped ``f(seed)`` under hypothesis when available, else
    as a seeded sweep — the property is checked either way."""
    def deco(f):
        if HAVE_HYPOTHESIS:
            return settings(max_examples=n_examples, deadline=None)(
                given(seed=st.integers(0, 2**16))(f))
        return pytest.mark.parametrize("seed", range(n_examples))(f)
    return deco


# ------------------------------------------------------------ case builder
def _random_case(seed: int):
    rng = np.random.default_rng(seed)
    nu = int(rng.integers(2, 20))
    ni = int(rng.integers(2, 26))
    d = int(rng.integers(1, 9))
    k = int(rng.integers(1, ni + 6))            # sometimes > catalogue
    blk = int(rng.integers(1, ni + 4))          # rarely divides ni
    ub = int(rng.integers(1, nu + 3))
    ue = rng.integers(-4, 5, (nu, d)).astype(np.float32)
    ie = rng.integers(-4, 5, (ni, d)).astype(np.float32)
    # random unique train edges (some users fully saturated sometimes)
    ne = int(rng.integers(0, nu * ni // 2 + 1))
    keys = np.unique(rng.integers(0, nu * ni, ne)) if ne else \
        np.zeros(0, np.int64)
    user = (keys // ni).astype(np.int64)
    item = (keys % ni).astype(np.int64)
    indptr, items = bpr.build_user_csr(user, item, nu)
    # random held-out lists; many users get none
    test_pos = []
    for u in range(nu):
        t = int(rng.integers(0, 4))
        test_pos.append(np.unique(rng.integers(0, ni, t)) if t else
                        np.zeros(0, np.int64))
    return ue, ie, indptr, items, test_pos, k, blk, ub


def _dense_oracle_topk(ue, ie, indptr, items, k):
    """Stable dense ranking: (score desc, id asc); seen -> -inf; slots
    beyond the scoreable candidates are (-inf, -1); padded to k."""
    scores = (ue @ ie.T).astype(np.float32)
    rows = np.repeat(np.arange(len(indptr) - 1), np.diff(indptr))
    scores[rows, items] = -np.inf
    ni = scores.shape[1]
    kk = min(k, ni)
    order = np.argsort(-scores, axis=1, kind="stable")[:, :kk]
    vals = np.take_along_axis(scores, order, axis=1)
    ids = np.where(np.isneginf(vals), -1, order).astype(np.int32)
    vals = np.where(ids < 0, -np.inf, vals).astype(np.float32)
    pad = k - kk
    if pad:
        ids = np.pad(ids, ((0, 0), (0, pad)), constant_values=-1)
        vals = np.pad(vals, ((0, 0), (0, pad)), constant_values=-np.inf)
    return vals, ids


def _check_streamed_equals_oracle(seed: int):
    ue, ie, indptr, items, test_pos, k, blk, ub = _random_case(seed)
    got_s, got_i = streaming_topk(ue, ie, k, seen_indptr=indptr,
                                  seen_items=items, user_batch=ub,
                                  item_block=blk)
    want_s, want_i = _dense_oracle_topk(ue, ie, indptr, items, k)
    np.testing.assert_array_equal(got_i, want_i)
    np.testing.assert_array_equal(got_s, want_s)      # exact, incl. -inf
    m_got = ranking_metrics(got_i, test_pos, ks=(1, min(k, 5), k))
    m_want = ranking_metrics(want_i, test_pos, ks=(1, min(k, 5), k))
    assert m_got == m_want                            # bit-for-bit floats


# --------------------------------------------------------------- properties
@pytest.mark.slow
@_property(30)
def test_streamed_topk_matches_dense_oracle(seed):
    _check_streamed_equals_oracle(seed)


def test_streamed_topk_matches_dense_oracle_smoke():
    """Tier-1 pin of the property (three fixed seeds)."""
    for seed in (0, 1, 2):
        _check_streamed_equals_oracle(seed)


# ----------------------------------------------------------- directed edges
def test_k_exceeds_catalogue_pads_invalid_slots():
    rng = np.random.default_rng(3)
    ue = rng.integers(-3, 4, (4, 3)).astype(np.float32)
    ie = rng.integers(-3, 4, (5, 3)).astype(np.float32)
    s, ids = streaming_topk(ue, ie, 9, item_block=2)
    assert ids.shape == (4, 9)
    assert (ids[:, :5] >= 0).all() and (ids[:, 5:] == -1).all()
    assert np.isneginf(s[:, 5:]).all()
    # every catalogue item appears exactly once per user
    for row in ids[:, :5]:
        assert sorted(row.tolist()) == [0, 1, 2, 3, 4]


def test_empty_catalogue_returns_invalid_slots():
    s, ids = streaming_topk(np.ones((3, 2), np.float32),
                            np.zeros((0, 2), np.float32), 4)
    assert ids.shape == (3, 4) and (ids == -1).all()
    assert np.isneginf(s).all()


def test_fully_masked_user_returns_no_items():
    ue = np.ones((2, 2), np.float32)
    ie = np.ones((3, 2), np.float32)
    # user 0 has seen the whole catalogue, user 1 nothing
    indptr, items = bpr.build_user_csr(
        np.array([0, 0, 0]), np.array([0, 1, 2]), 2)
    _, ids = streaming_topk(ue, ie, 2, seen_indptr=indptr, seen_items=items,
                            item_block=2)
    assert (ids[0] == -1).all()
    assert (ids[1] >= 0).all()


def test_block_not_dividing_catalogue():
    rng = np.random.default_rng(7)
    ue = rng.integers(-4, 5, (3, 4)).astype(np.float32)
    ie = rng.integers(-4, 5, (11, 4)).astype(np.float32)
    for blk in (1, 2, 3, 4, 7, 11, 13):
        _, ids = streaming_topk(ue, ie, 4, item_block=blk)
        _, want = _dense_oracle_topk(
            ue, ie, np.zeros(4, np.int64), np.zeros(0, np.int64), 4)
        np.testing.assert_array_equal(ids, want)


def test_streaming_handles_catalogue_too_big_for_dense():
    """A catalogue where the dense U×I score matrix would be ~22 GiB:
    the streaming path scores a query batch in O(batch × (K + block))."""
    rng = np.random.default_rng(11)
    nu, ni, d = 60_000, 100_000, 8
    ue = rng.standard_normal((64, d)).astype(np.float32)   # queried users
    ie = rng.standard_normal((ni, d)).astype(np.float32)
    full_u = np.zeros((nu, d), np.float32)
    full_u[:64] = ue
    s, ids = streaming_topk(full_u, ie, 10, user_ids=np.arange(64),
                            user_batch=64, item_block=4096)
    assert ids.shape == (64, 10)
    assert (ids >= 0).all()
    # descending scores per row
    assert (np.diff(s, axis=1) <= 0).all()


# ------------------------------------------------------------------ metrics
def test_metrics_hand_computed():
    topk = np.array([[3, 1, 2]], np.int32)
    test_pos = [np.array([1, 7])]
    m = ranking_metrics(topk, test_pos, ks=(3,))
    assert m["recall@3"] == pytest.approx(0.5)
    dcg = 1.0 / np.log2(3.0)                   # hit at rank 2
    idcg = 1.0 + 1.0 / np.log2(3.0)            # min(|test|=2, k)=2 ideal
    assert m["ndcg@3"] == pytest.approx(dcg / idcg)
    assert m["mrr"] == pytest.approx(0.5)


def test_metrics_exclude_zero_test_users_and_invalid_slots():
    topk = np.array([[0, 1], [-1, -1], [1, 0]], np.int32)
    test_pos = [np.array([0]), np.zeros(0, np.int64), np.array([2])]
    m = ranking_metrics(topk, test_pos, ks=(2,))
    # user 1 (no test items) excluded; user 2 has no hits
    assert m["recall@2"] == pytest.approx(0.5)
    assert m["mrr"] == pytest.approx(0.5)
    hits = ranked_hits(topk, test_pos)
    assert hits.sum() == 1


def test_evaluate_embeddings_empty_test():
    ue = np.ones((3, 2), np.float32)
    ie = np.ones((4, 2), np.float32)
    m = evaluate_embeddings(ue, ie, [np.zeros(0, np.int64)] * 3, k=2)
    assert m == {"recall@2": 0.0, "ndcg@2": 0.0, "mrr": 0.0}


# ---------------------------------------------------- recall_at_k CSR + shim
@pytest.mark.slow
@_property(20)
def test_recall_at_k_csr_matches_dense_shim(seed):
    ue, ie, indptr, items, test_pos, k, _, _ = _random_case(seed)
    nu, ni = ue.shape[0], ie.shape[0]
    mask = np.zeros((nu, ni), bool)
    rows = np.repeat(np.arange(nu), np.diff(indptr))
    mask[rows, items] = True
    # both paths mask the same cells of an identical score matrix, so the
    # results must agree exactly even through argpartition ties
    r_csr = bpr.recall_at_k(ue, ie, (indptr, items), test_pos, k=k)
    r_dense = bpr.recall_at_k(ue, ie, mask, test_pos, k=k)
    assert r_csr == r_dense


def test_recall_at_k_rejects_non_mask_array():
    with pytest.raises(TypeError):
        bpr.recall_at_k(np.ones((2, 2), np.float32),
                        np.ones((2, 2), np.float32),
                        np.zeros((2, 2), np.float32),  # not bool
                        [np.array([0]), np.array([1])])


def test_streaming_recall_matches_dense_oracle_on_floats():
    """Cross-implementation sanity on real (float) embeddings: streamed
    recall@20 == the dense recall_at_k oracle (fixed seed, small graph,
    scores well-separated at this scale)."""
    data = synth.generate_bipartite(40, 30, 300, seed=5)
    train, test = synth.train_test_split(data)
    rng = np.random.default_rng(5)
    ue = rng.standard_normal((data.n_users, 16)).astype(np.float32)
    ie = rng.standard_normal((data.n_items, 16)).astype(np.float32)
    csr = bpr.build_user_csr(train.user, train.item, data.n_users)
    test_pos = synth.group_by_user(test.user, test.item, data.n_users)
    m = evaluate_embeddings(ue, ie, test_pos, k=20, seen_indptr=csr[0],
                            seen_items=csr[1], user_batch=7, item_block=13)
    r = bpr.recall_at_k(ue, ie, csr, test_pos, k=20)
    assert m["recall@20"] == pytest.approx(r, abs=1e-12)


# ------------------------------------------------------------------ serving
def test_recommender_from_pipeline_and_seen_exclusion():
    from repro.pipeline import PipelineConfig, build_pipeline
    data = synth.generate_bipartite(30, 25, 250, seed=2)
    train, test = synth.train_test_split(data)
    cfg = PipelineConfig(arch="lightgcn", embed_dim=8, n_layers=1,
                         base_batch=64, target_batch=64, microbatch=64)
    pipe = build_pipeline(cfg, train)
    state = pipe.init_state()
    rec = Recommender.from_pipeline(pipe, state, k=5, item_block=7)
    ids, scores = rec.recommend(np.arange(data.n_users))
    assert ids.shape == (data.n_users, 5)
    indptr, items = pipe.g.seen_csr()
    for u in range(data.n_users):
        seen = set(items[indptr[u]:indptr[u + 1]].tolist())
        got = set(int(i) for i in ids[u] if i >= 0)
        assert not (got & seen)
    assert "item_embed->" in rec.describe()
    # exclude_seen=False ranks the full catalogue
    ids_all, _ = rec.recommend([0], k=3, exclude_seen=False)
    assert (ids_all >= 0).all()


def test_serving_placement_demotes_user_table_first():
    from repro.core.tiered_memory import plan_placement
    from repro.pipeline.plan import serving_profiles
    profs = serving_profiles(user_nbytes=1000, item_nbytes=1000, row=128)
    plan = plan_placement(profs, hbm_budget=1000)
    assert plan.tier("serve/item_embed") == "hbm"
    assert plan.tier("serve/user_embed") == "host"


# ------------------------------------------------------- engine integration
def test_pipeline_eval_history_in_report(tmp_path):
    from repro.pipeline import PipelineConfig, build_pipeline
    from repro.runtime.loop import LoopConfig, run_pipeline
    data = synth.generate_bipartite(40, 30, 400, seed=0)
    train, test = synth.train_test_split(data)
    cfg = PipelineConfig(arch="lightgcn", embed_dim=8, n_layers=1,
                         base_batch=64, target_batch=128, microbatch=64,
                         eval_k=10, eval_item_block=16)
    pipe = build_pipeline(cfg, train, holdout=test)
    assert pipe.eval_fn is not None
    report = run_pipeline(
        LoopConfig(ckpt_dir=str(tmp_path / "ck"), ckpt_every=10,
                   max_steps=4, async_ckpt=False, eval_every=2), pipe)
    assert [s for s, _ in report.eval_history] == [2, 4]
    for _, m in report.eval_history:
        assert set(m) == {"recall@10", "ndcg@10", "mrr"}
        assert 0.0 <= m["recall@10"] <= 1.0
    # direct evaluate() equals the eval_fn output at the same state
    state = pipe.init_state()
    assert pipe.evaluate(state) == pipe.eval_fn(state, 0)


def test_eval_user_batch_derivation():
    from repro.pipeline.plan import derive_eval_batch
    b = derive_eval_batch(2**30, out_dim=64, k=20, item_block=1024)
    assert b & (b - 1) == 0 and b >= 32          # pow2, floored
    assert derive_eval_batch(0, 64, 20, 1024) == 32
    assert derive_eval_batch(2**40, 64, 20, 1024) == 4096  # capped
