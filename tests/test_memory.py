"""Memory-tier subsystem tests (``repro.memory`` + the ``MemoryCfg``
spec surface): topology/policy registries, the pinned-penalty
accounting fix, greedy-vs-exact certification swept across every
registered topology, spec round-trips and CLI parity, and the tiered
executor's bit-identity contract (a host-demoted table trains and
serves bit-identically on the ``uniform`` topology)."""
import dataclasses

import numpy as np
import pytest

from repro.api import ExperimentSpec, MemoryCfg, build, get_preset
from repro.memory import (AccessProfile, HostResident, get_policy,
                          get_topology, gnn_recsys_profiles, place_exact,
                          place_greedy, policy_names, topology_names)


def _smoke(**overrides) -> ExperimentSpec:
    return get_preset("lightgcn-smoke").override(overrides)


# ------------------------------------------------------------- registries
def test_topology_and_policy_registries():
    assert {"tpu-hbm-host", "dram-optane-appdirect",
            "dram-optane-memorymode", "uniform"} <= set(topology_names())
    assert {"greedy", "exact", "paper-recipe",
            "all-fast", "all-slow"} <= set(policy_names())
    with pytest.raises(KeyError, match="unknown memory topology"):
        get_topology("nope")
    with pytest.raises(KeyError, match="unknown placement policy"):
        get_policy("nope")
    # passthrough: a live topology resolves to itself
    topo = get_topology("uniform")
    assert get_topology(topo) is topo


def test_tpu_preset_carries_legacy_constants():
    """The default preset's tiers hold exactly the values the old
    ``core.tiered_memory`` constants hardcoded, so legacy plans are
    numerically identical."""
    from repro.core import tiered_memory as tm
    topo = get_topology("tpu-hbm-host")
    assert topo.fast.read_bw == tm.HBM_BW_READ == 819e9
    assert topo.slow.read_bw == tm.HOST_BW_READ == 16e9
    assert topo.slow.write_bw == tm.HOST_BW_WRITE == 8e9
    assert topo.fast.capacity == tm.HBM_CAPACITY == 16 * 2**30
    assert topo.names == ("hbm", "host")
    assert not topo.is_uniform and get_topology("uniform").is_uniform


def test_uniform_topology_prices_demotion_at_zero():
    topo = get_topology("uniform")
    p = AccessProfile("t", 1 << 20, reads_per_step=3.0, writes_per_step=2.0,
                      access_size=8)
    assert topo.demotion_penalty(p) == 0.0
    assert get_topology("tpu-hbm-host").demotion_penalty(p) > 0.0


def test_capacity_override_validates_and_replaces():
    topo = get_topology("tpu-hbm-host").with_capacity({"hbm": 1 << 20})
    assert topo.tier("hbm").capacity == 1 << 20
    assert topo.tier("host").capacity == 512 * 2**30    # untouched
    with pytest.raises(KeyError):
        get_topology("uniform").with_capacity({"hbm": 1})


# ------------------------------------------------------------- policies
def test_pinned_slow_tier_counts_real_penalty():
    """The satellite fix: tensors pinned to the slow tier used to
    contribute 0.0 to est_step_penalty_s in both planners; they must
    report what the pin actually costs."""
    topo = get_topology("tpu-hbm-host")
    pinned = AccessProfile("pinned_t", 1000, reads_per_step=2.0,
                           writes_per_step=1.0, pinned="host")
    free = AccessProfile("free_t", 1000, reads_per_step=1.0)
    true_pen = topo.demotion_penalty(pinned)
    assert true_pen > 0.0
    for policy in (place_greedy, place_exact):
        plan = policy([pinned, free], topo,
                      budgets={"hbm": 4000, "host": 4000})
        assert plan.tier("pinned_t") == "host"
        assert plan.tier("free_t") == "hbm"
        assert plan.placements["pinned_t"].pinned
        assert plan.est_step_penalty_s == pytest.approx(true_pen, rel=1e-12)


def test_greedy_certified_by_exact_across_all_topologies():
    """Pure greedy (no exact fallback) must stay within 5% of the exact
    DP's optimal penalty on every registered topology — not just the
    default — and both must respect per-tier budgets."""
    for name in topology_names():
        topo = get_topology(name)
        for seed in range(4):
            rng = np.random.default_rng(seed)
            profs = [AccessProfile(
                f"t{i}", int(rng.integers(1, 10**6)),
                reads_per_step=float(rng.uniform(0, 4)),
                writes_per_step=float(rng.uniform(0, 4)),
                access_size=int(rng.choice([8, 64, 512, 4096])))
                for i in range(10)]
            total = sum(p.nbytes for p in profs)
            budgets = {topo.fast.name: max(total // 3, 1),
                       topo.slow.name: total + 1}
            greedy = place_greedy(profs, topo, budgets=budgets,
                                  exact_threshold=0)
            exact = place_exact(profs, topo, budgets=budgets)
            assert set(greedy.placements) == {p.name for p in profs}
            for plan in (greedy, exact):
                for t in topo.names:
                    assert plan.used[t] <= budgets[t]
            assert exact.est_step_penalty_s <= \
                greedy.est_step_penalty_s * 1.05 + 1e-18, (name, seed)


def test_greedy_on_uniform_keeps_fitting_tensors_fast():
    """Zero-penalty topologies must not demote gratuitously: among
    equal-penalty placements the planner (greedy AND its exact-DP
    fallback) keeps as many bytes as fit on the fast tier, so a
    uniform-topology run doesn't route every tensor through the host
    store for nothing."""
    topo = get_topology("uniform")
    profs = [AccessProfile(f"t{i}", 100) for i in range(5)]
    for kwargs in ({}, {"exact_threshold": 0}):     # DP path, pure greedy
        plan = place_greedy(profs, topo, budgets={"fast": 250, "slow": 500},
                            **kwargs)
        assert plan.used["fast"] == 200             # pow-of-fit: 2 of 5 x100
        assert plan.est_step_penalty_s == 0.0
    # and with room for everything, nothing is demoted at all
    roomy = place_greedy(profs, topo)
    assert roomy.demoted() == []


def test_paper_recipe_pins_follow_section6():
    profs = gnn_recsys_profiles(1000, 800, 20_000, 64, 2)
    topo = get_topology("dram-optane-appdirect")
    plan = get_policy("paper-recipe")(profs, topo)
    assert plan.tier("graph_coo") == "optane"
    assert plan.tier("opt_state") == "optane"
    assert plan.tier("messages_l0") == "optane"   # |E|-sized, nt-written
    assert plan.tier("embeddings") == "dram"
    assert plan.write_policy()["sddmm"] == "streaming"
    assert plan.policy == "paper-recipe"
    # the pins' real cost is visible (not the old 0.0)
    assert plan.est_step_penalty_s > 0.0
    # user pins override the recipe
    plan2 = get_policy("paper-recipe")(profs, topo,
                                       pins={"opt_state": "fast"})
    assert plan2.tier("opt_state") == "dram"


def test_all_fast_all_slow_baselines():
    profs = gnn_recsys_profiles(500, 400, 5_000, 32, 1)
    topo = get_topology("dram-optane-memorymode")
    fast = get_policy("all-fast")(profs, topo)
    slow = get_policy("all-slow")(profs, topo)
    assert fast.est_step_penalty_s == 0.0
    assert slow.est_step_penalty_s > 0.0
    assert all(p.tier == "dram-cache" for p in fast.placements.values())
    assert all(p.tier == "optane-mm" for p in slow.placements.values())


def test_write_policy_emitted_from_plan():
    profs = gnn_recsys_profiles(500, 400, 5_000, 32, 1)
    # write asymmetry to route around -> SDDMM streams (nt-write)
    tpu = get_policy("greedy")(profs, "tpu-hbm-host")
    assert tpu.write_policy() == {"sddmm": "streaming",
                                  "spmm": "accumulate",
                                  "embedding_bag": "accumulate"}
    # uniform topology, nothing demoted -> nothing to stream around
    uni = get_policy("all-fast")(profs, "uniform")
    assert uni.write_policy()["sddmm"] == "accumulate"
    # ... but a message stream demoted off the fast tier streams again
    pinned = get_policy("greedy")(profs, "uniform",
                                  pins={"messages_l0": "slow"})
    assert pinned.write_policy()["sddmm"] == "streaming"
    # the deprecated kernels.ops.WRITE_POLICY shim answers with the
    # default topology's table
    with pytest.warns(DeprecationWarning, match="emitted from the placement"):
        from repro.kernels import ops
        assert ops.WRITE_POLICY["sddmm"] == "streaming"


# ------------------------------------------------------------- MemoryCfg
def test_memorycfg_roundtrip_and_defaults():
    spec = _smoke(**{
        "memory.topology": "dram-optane-appdirect",
        "memory.policy": "paper-recipe",
        "memory.capacity": {"dram": 1 << 24},
        "memory.pins": {"params['item_embed']": "slow"}})
    assert ExperimentSpec.from_dict(spec.to_dict()) == spec
    assert ExperimentSpec.from_json(spec.to_json()) == spec
    rt = ExperimentSpec.from_json(spec.to_json())
    assert rt.memory.capacity == {"dram": 1 << 24}
    assert rt.memory.pins == {"params['item_embed']": "slow"}
    # the default section is inert and equal across construction paths
    assert _smoke().memory == MemoryCfg()
    assert MemoryCfg().topology == "tpu-hbm-host"
    assert MemoryCfg().policy == "greedy"
    with pytest.raises(ValueError, match="unknown spec.memory keys"):
        ExperimentSpec.from_dict({"memory": {"topolgy": "uniform"}})


def test_memory_cli_flags_equal_spec_overrides():
    from repro.launch.train import build_arg_parser, spec_from_args
    args = build_arg_parser().parse_args([
        "--preset", "lightgcn-smoke", "--memory-topology", "uniform",
        "--placement-policy", "paper-recipe", "--pin", "item_embed=slow",
        "--pin", "graph=slow", "--ckpt-dir", "/tmp/ck"])
    spec = spec_from_args(args)
    expect = get_preset("lightgcn-smoke").override({
        "memory.topology": "uniform", "memory.policy": "paper-recipe",
        "memory.pins": {"item_embed": "slow", "graph": "slow"},
        "loop.ckpt_dir": "/tmp/ck/lightgcn"})
    assert spec == expect


def test_build_rejects_unknown_topology_and_policy():
    with pytest.raises(KeyError, match="unknown memory topology"):
        build(_smoke(**{"memory.topology": "pm-9000"}))
    with pytest.raises(KeyError, match="unknown placement policy"):
        build(_smoke(**{"memory.policy": "magic"}))


# ------------------------------------------------------------- acceptance
def test_section5_ordering_appdirect_beats_memorymode():
    """The paper's §5 qualitative result as a one-line spec change:
    the same paper-recipe plan costs less on AppDirect (explicit
    placement, nt-writes) than on Memory Mode (HW cache, normal
    writes, cacheline granularity)."""
    def penalty(topology):
        run = build(_smoke(**{"memory.topology": topology,
                              "memory.policy": "paper-recipe"}))
        plan = run.pipeline.plan.plan
        assert plan.policy == "paper-recipe"
        return plan.est_step_penalty_s

    p_ad = penalty("dram-optane-appdirect")
    p_mm = penalty("dram-optane-memorymode")
    assert 0.0 < p_ad < p_mm


def test_host_demoted_table_trains_bit_identical_on_uniform():
    """The tiered-gather parity acceptance test: pinning an embedding
    table to the slow tier routes it through the executor's host store
    (bytes live off-device, stream in per step) yet the uniform
    topology's run is bit-identical to the all-fast run."""
    n = 4
    base = build(_smoke(**{"memory.topology": "uniform"}))
    base_losses = [base.step() for _ in range(n)]

    demoted = build(_smoke(**{"memory.topology": "uniform",
                              "memory.pins": {"item_embed": "slow"}}))
    pipe = demoted.pipeline
    assert pipe.plan.plan.tier("params['item_embed']") == "slow"
    assert pipe.n_offloaded >= 1
    # the table's bytes genuinely live in the host store, not on device
    assert isinstance(demoted.state["params"]["item_embed"], np.ndarray)
    demoted_losses = [demoted.step() for _ in range(n)]

    assert demoted_losses == base_losses                 # bit-identical
    np.testing.assert_array_equal(
        np.asarray(demoted.params["item_embed"]),
        np.asarray(base.params["item_embed"]))
    np.testing.assert_array_equal(
        np.asarray(demoted.params["user_embed"]),
        np.asarray(base.params["user_embed"]))
    # ... and the default MemoryCfg() run matches too (uniform pricing
    # changes nothing on a backend whose tiers are all the same bytes)
    default = build(_smoke())
    assert [default.step() for _ in range(n)] == base_losses


def test_recommender_host_resident_serving_parity():
    """Serving through the row-granular HostResident facade (slow-tier
    tables, host bytes, per-batch gathers) returns bit-identical
    recommendations to the all-fast snapshot."""
    from repro.eval import Recommender
    rng = np.random.default_rng(0)
    ue = rng.standard_normal((37, 16)).astype(np.float32)
    ie = rng.standard_normal((23, 16)).astype(np.float32)

    fast = Recommender(ue, ie, k=5, user_batch=8, item_block=7,
                       topology="uniform")
    demoted = Recommender(ue, ie, k=5, user_batch=8, item_block=7,
                          topology="uniform",
                          pins={"serve/user_embed": "slow",
                                "serve/item_embed": "slow"})
    assert isinstance(demoted.user_e, HostResident)
    assert isinstance(demoted.item_e, HostResident)
    assert demoted.n_offloaded == 2
    ids_f, scores_f = fast.recommend(np.arange(37))
    ids_d, scores_d = demoted.recommend(np.arange(37))
    np.testing.assert_array_equal(ids_f, ids_d)
    np.testing.assert_array_equal(scores_f, scores_d)
    assert "topology=uniform" in demoted.describe()


def test_capacity_override_demotes_and_stays_bit_identical():
    """MemoryCfg.capacity drives real demotion (tight fast tier on the
    uniform topology) without changing the math."""
    spec_tight = _smoke(**{"memory.topology": "uniform",
                           "memory.capacity": {"fast": 4096}})
    tight = build(spec_tight)
    assert len(tight.pipeline.plan.plan.demoted()) > 0
    base = build(_smoke(**{"memory.topology": "uniform"}))
    n = 3
    assert [tight.step() for _ in range(n)] == \
        [base.step() for _ in range(n)]
