"""Unified Experiment API tests: spec serialization round-trips, preset
parity with the ``repro.configs`` registry, CLI-flag -> spec-override
equivalence, and Run.fit() == a hand-built ``build_pipeline`` run
step for step (bit-identical losses) on the smoke config."""
import dataclasses
import json

import numpy as np
import pytest

from repro import configs as config_registry
from repro.api import (DataCfg, EvalCfg, Experiment, ExperimentSpec,
                       LoopCfg, MeshCfg, ModelCfg, PlanCfg, build,
                       get_preset, load_data, preset_names,
                       register_data_source)
from repro.pipeline import build_pipeline


def _smoke_spec(**overrides) -> ExperimentSpec:
    return get_preset("lightgcn-smoke").override(overrides)


# ------------------------------------------------------------- round trip
def test_spec_dict_roundtrip_exact():
    spec = ExperimentSpec(
        name="rt", model=ModelCfg(arch="ngcf", embed_dim=64, n_layers=3),
        data=DataCfg(source="kronecker", dataset="gowalla", edges=1000,
                     expand_factor=4, test_frac=0.2, seed=7),
        plan=PlanCfg(hbm_budget=1 << 20, target_batch=4096, microbatch=None,
                     base_batch=128, warmup_epochs=1, lr_scaling="sqrt"),
        loop=LoopCfg(steps=17, ckpt_dir="/tmp/x", eval_every=5),
        eval=EvalCfg(k=10, user_batch=64, item_block=256),
        optimizer="sgd", base_lr=0.05, l2=0.0, seed=3)
    assert ExperimentSpec.from_dict(spec.to_dict()) == spec


def test_spec_json_file_roundtrip(tmp_path):
    spec = _smoke_spec()
    path = str(tmp_path / "spec.json")
    spec.save(path)
    assert ExperimentSpec.from_file(path) == spec
    # the file is plain JSON, editable by hand
    with open(path) as f:
        d = json.load(f)
    assert d["model"]["arch"] == "lightgcn"


def test_from_dict_rejects_unknown_keys():
    with pytest.raises(ValueError, match="unknown spec keys"):
        ExperimentSpec.from_dict({"modle": {}})
    with pytest.raises(ValueError, match="unknown spec.model keys"):
        ExperimentSpec.from_dict({"model": {"embed_dims": 64}})


def test_override_dotted_paths():
    spec = _smoke_spec()
    out = spec.override({"model.embed_dim": 64, "plan.microbatch": 16},
                        optimizer="sgd")
    assert out.model.embed_dim == 64
    assert out.plan.microbatch == 16
    assert out.optimizer == "sgd"
    assert out.model.arch == spec.model.arch        # untouched fields kept
    with pytest.raises(KeyError):
        spec.override({"model.width": 64})


def test_model_hadamard_validates_and_threads_to_pipeline():
    """ModelCfg.hadamard round-trips, rejects unknown routes, and lands
    on PipelineConfig so the engine builds the requested NGCF dataflow."""
    with pytest.raises(ValueError, match="hadamard"):
        ModelCfg(hadamard="bogus")
    spec = _smoke_spec().override({"model.arch": "ngcf",
                                   "model.hadamard": "composed"})
    assert ExperimentSpec.from_json(spec.to_json()) == spec
    assert spec.to_pipeline_config().hadamard == "composed"
    assert _smoke_spec().to_pipeline_config().hadamard == "auto"


# ------------------------------------------------------------- mesh section
def test_mesh_cfg_roundtrip_and_coercion():
    """MeshCfg survives the exact dict round-trip AND the JSON round-trip
    (JSON turns tuples into lists; __post_init__ coerces them back, so
    equality is structural, not representational)."""
    spec = _smoke_spec().override({"mesh.shape": (4,),
                                   "mesh.axes": ("data",),
                                   "mesh.spmm": "ring",
                                   "mesh.ring_steps": 2})
    assert ExperimentSpec.from_dict(spec.to_dict()) == spec
    assert ExperimentSpec.from_json(spec.to_json()) == spec
    rt = ExperimentSpec.from_json(spec.to_json())
    assert rt.mesh.shape == (4,) and isinstance(rt.mesh.shape, tuple)
    assert rt.mesh.axes == ("data",) and isinstance(rt.mesh.axes, tuple)
    # the default mesh is the inert single-device plan
    assert _smoke_spec().mesh == MeshCfg()
    assert MeshCfg().shape == (1,)


def test_mesh_cli_flags_equal_spec_overrides():
    from repro.launch.train import build_arg_parser, spec_from_args
    args = build_arg_parser().parse_args([
        "--preset", "lightgcn-smoke", "--mesh", "2x2", "--ring-steps", "2",
        "--spmm", "ring", "--ckpt-dir", "/tmp/ck"])
    spec = spec_from_args(args)
    expect = get_preset("lightgcn-smoke").override(
        {"mesh.shape": (2, 2), "mesh.ring_steps": 2, "mesh.spmm": "ring",
         "loop.ckpt_dir": "/tmp/ck/lightgcn"})
    assert spec == expect


def test_mesh_single_device_spec_is_inert():
    """MeshCfg() (the default) must not change the engine's behavior at
    all: no ShardPlan is built and the pipeline config equals the
    pre-mesh projection field for field."""
    run = build(_smoke_spec())
    assert run.pipeline.shard is None
    cfg = _smoke_spec().to_pipeline_config()
    assert cfg.mesh_shape == (1,) and cfg.spmm is None


# ------------------------------------------------------------- presets
def test_preset_registry_absorbs_configs_full_and_smoke():
    """Every gnnrecsys config-registry entry must resolve to a preset
    whose model/data shapes match the registry declaration exactly."""
    found = 0
    for arch_id in config_registry.ARCH_IDS:
        mod = config_registry.get(arch_id)
        if getattr(mod, "FAMILY", None) != "gnnrecsys":
            continue
        for variant in ("full", "smoke"):
            cfg = getattr(mod, variant.upper())
            spec = get_preset(f"{arch_id}-{variant}")
            assert spec.name == cfg.name
            assert spec.model.arch == arch_id
            assert spec.model.embed_dim == cfg.embed_dim
            assert spec.model.n_layers == cfg.n_layers
            assert spec.data.n_users == cfg.n_users
            assert spec.data.n_items == cfg.n_items
            assert spec.data.edges == cfg.n_edges
            assert spec.plan.target_batch == cfg.bpr_batch
            assert spec.optimizer == mod.OPTIMIZER
            found += 1
    assert found >= 4                       # ngcf + lightgcn, full + smoke
    assert set(preset_names()) >= {"lightgcn-smoke", "lightgcn-full",
                                   "ngcf-smoke", "ngcf-full", "quickstart"}


def test_from_preset_smoke_trains():
    run = Experiment.from_preset("lightgcn-smoke").build()
    report = run.fit(steps=3)
    assert report.steps_run == 3
    assert all(np.isfinite(l) for l in report.losses)


# ------------------------------------------------------------- CLI parity
def test_cli_flags_equal_spec_overrides():
    from repro.launch.train import (build_arg_parser, default_spec,
                                    spec_from_args)
    args = build_arg_parser().parse_args([
        "--arch", "ngcf", "--embed-dim", "64", "--layers", "3",
        "--dataset", "gowalla", "--edges", "9000",
        "--target-batch", "4096", "--microbatch", "0",
        "--steps", "7", "--eval-every", "0", "--eval-k", "10"])
    via_cli = spec_from_args(args)
    via_api = default_spec().override({
        "model.arch": "ngcf", "model.embed_dim": 64, "model.n_layers": 3,
        "data.dataset": "gowalla", "data.edges": 9000,
        "plan.target_batch": 4096, "plan.microbatch": None,  # 0 -> derived
        "loop.steps": 7, "loop.eval_every": None,            # 0 -> off
        "loop.ckpt_dir": "/tmp/repro_ckpt/ngcf", "eval.k": 10})
    assert via_cli == via_api


def test_cli_set_and_preset_compose():
    from repro.launch.train import build_arg_parser, spec_from_args
    args = build_arg_parser().parse_args([
        "--preset", "lightgcn-smoke", "--set", "plan.hbm_budget=4096",
        "--set", "name=renamed", "--ckpt-dir", "/tmp/ck"])
    spec = spec_from_args(args)
    expect = get_preset("lightgcn-smoke").override(
        {"loop.ckpt_dir": "/tmp/ck/lightgcn", "plan.hbm_budget": 4096,
         "name": "renamed"})
    assert spec == expect


# ------------------------------------------------------------- data sources
def test_data_sources_one_protocol():
    tr, te = load_data(DataCfg(source="synth", dataset="gowalla",
                               edges=1000, test_frac=0.1))
    assert te is not None and tr.n_edges + te.n_edges == 1000
    tr, te = load_data(DataCfg(source="bipartite", n_users=40, n_items=30,
                               edges=300, test_frac=0.0))
    assert te is None and tr.n_users == 40 and tr.n_items == 30
    base = load_data(DataCfg(source="synth", dataset="movielens-10m",
                             edges=500, test_frac=0.0))[0]
    kron, _ = load_data(DataCfg(source="kronecker", dataset="movielens-10m",
                                edges=500, expand_factor=4, test_frac=0.0))
    assert kron.n_edges == 4 * base.n_edges
    assert kron.n_users > base.n_users


def test_register_custom_data_source():
    from repro.data.synth import InteractionData

    def tiny(cfg):
        u = np.arange(cfg.edges, dtype=np.int32) % 8
        i = np.arange(cfg.edges, dtype=np.int32) % 6
        return InteractionData(u, i, 8, 6)

    register_data_source("tiny-test", tiny)
    spec = _smoke_spec(**{"data.source": "tiny-test", "data.edges": 48,
                          "data.test_frac": 0.0, "plan.microbatch": 16,
                          "plan.target_batch": 16, "plan.base_batch": 16})
    run = build(spec)
    assert run.train_data.n_users == 8
    assert np.isfinite(run.step())


def test_unknown_data_source_raises():
    with pytest.raises(KeyError, match="unknown data source"):
        load_data(DataCfg(source="nope"))


# ------------------------------------------------------------- fit parity
def test_run_fit_matches_hand_built_pipeline_step_for_step():
    """Run.fit() through the API == a hand-built build_pipeline driven
    by step_fn directly: bit-identical losses on the smoke config, and
    a from_dict(to_dict()) round-tripped spec reproduces them again
    (the acceptance-criterion equivalence)."""
    spec = _smoke_spec()
    n = 6

    run = build(spec)
    api_losses = run.fit(steps=n).losses

    train, holdout = load_data(spec.data)
    pipe = build_pipeline(spec.to_pipeline_config(), train, holdout=holdout)
    state = pipe.init_state()
    hand_losses = []
    for s in range(n):
        state, loss = pipe.step_fn(state, s)
        hand_losses.append(float(loss))
    assert api_losses == hand_losses                    # bit-identical

    rt = Experiment.from_dict(spec.to_dict()).build()
    assert rt.fit(steps=n).losses == api_losses         # bit-identical

    for a, b in zip(np.asarray(run.params["user_embed"]).ravel(),
                    np.asarray(state["params"]["user_embed"]).ravel()):
        assert a == b


def test_fit_continues_in_memory_after_step_and_fit():
    """fit() on an in-memory run continues from the run's current
    position — step()/fit()/fit() == one straight fit of the same total
    length (schedule, sampling, and state all advance together)."""
    spec = _smoke_spec()
    inc = build(spec)
    losses = [inc.step()]
    rep1 = inc.fit(steps=2)
    rep2 = inc.fit(steps=3)
    assert rep1.steps_run == 2 and rep2.steps_run == 3
    losses += rep1.losses + rep2.losses
    assert inc.step_count == 6

    straight = build(spec)
    assert straight.fit(steps=6).losses == losses       # bit-identical
    np.testing.assert_array_equal(
        np.asarray(inc.params["user_embed"]),
        np.asarray(straight.params["user_embed"]))


def test_fit_checkpoint_resume_matches_uninterrupted(tmp_path):
    spec = _smoke_spec()
    ck = str(tmp_path / "ck")

    interrupted = build(spec)
    interrupted.fit(steps=4, ckpt_dir=ck)
    resumed = build(spec)
    rep = resumed.fit(steps=6, ckpt_dir=ck)     # restores step 4, runs 2
    assert rep.resumed_from == 4 and rep.steps_run == 2

    straight = build(spec)
    straight.fit(steps=6)                       # in-memory, no checkpoints
    np.testing.assert_array_equal(
        np.asarray(resumed.params["user_embed"]),
        np.asarray(straight.params["user_embed"]))

    # Run.resume positions a fresh run at the committed step exactly
    fresh = build(spec).resume(ck)
    assert fresh.step_count == 6
    assert fresh.step() == straight.step()      # same next loss, bit-exact


# ------------------------------------------------------------- eval/serving
def test_run_evaluate_and_recommend():
    spec = _smoke_spec(**{"loop.eval_every": 2})
    run = build(spec)
    report = run.fit(steps=4)
    assert [s for s, _ in report.eval_history] == [2, 4]
    m = run.evaluate()
    assert set(m) == {"recall@20", "ndcg@20", "mrr"}
    ids, scores = run.recommend([0, 1, 2], k=5)
    assert ids.shape == (3, 5) and scores.shape == (3, 5)
    # seen-item exclusion rides the train CSR
    indptr, items = run.pipeline.g.seen_csr()
    seen0 = set(items[indptr[0]:indptr[1]].tolist())
    assert seen0.isdisjoint(i for i in ids[0].tolist() if i >= 0)


def test_holdoutless_run_evaluate_raises():
    spec = _smoke_spec(**{"data.test_frac": 0.0})
    run = build(spec)
    assert run.holdout is None
    with pytest.raises(RuntimeError, match="no holdout"):
        run.evaluate()


# ------------------------------------------------------------- deprecation
def test_dense_mask_shim_warns_deprecation():
    from repro.core import bpr
    ue = np.eye(3, dtype=np.float32)
    ie = np.eye(3, dtype=np.float32)
    mask = np.zeros((3, 3), dtype=bool)
    test_pos = [np.array([0]), np.array([1]), np.array([2])]
    with pytest.warns(DeprecationWarning, match="repro.eval"):
        r = bpr.recall_at_k(ue, ie, mask, test_pos, k=1)
    assert r == 1.0
    # the canonical CSR path stays silent
    import warnings as _w
    with _w.catch_warnings():
        _w.simplefilter("error", DeprecationWarning)
        bpr.recall_at_k(ue, ie, bpr.build_user_csr(
            np.array([0]), np.array([1]), 3), test_pos, k=1)
