"""repro.analysis — static lint + HLO auditor tests.

Layer 1: one true-positive AND one true-negative fixture per AST rule
(the negative pins the false-positive fixes: static_argnames, kwonly
kernel statics, ``.shape`` metadata, 'float64' outside dtype position),
registry-completeness rules against both the live repo (clean) and a
synthetic broken repo (every rule fires), and the ratchet baseline
round trip.

Layer 2: the expectation table and ``check_text`` on synthetic HLO
(fast), the recompile-hazard mirror, and — marked slow — real
lowerings: the single-device smoke audit end to end and a subprocess
f64 injection under ``JAX_ENABLE_X64=1`` that the auditor must catch.
"""
from __future__ import annotations

import json
import os
import pathlib
import subprocess
import sys
import textwrap

import pytest

from repro.analysis import (ALL_RULES, REPO_RULES, RULES, Finding, compare,
                            lint_repo, lint_source, load_baseline,
                            save_baseline)

ROOT = pathlib.Path(__file__).resolve().parents[1]


def rules_of(src: str, **kw) -> set[str]:
    return {f.rule for f in lint_source(textwrap.dedent(src), **kw)}


# ------------------------------------------------------------ rule fixtures
def test_tracer_item_inside_jit():
    assert "tracer-item" in rules_of("""
        import jax
        @jax.jit
        def f(x):
            return x.item()
    """)


def test_tracer_item_outside_jit_is_clean():
    assert "tracer-item" not in rules_of("""
        def f(x):
            return x.item()
    """)


def test_tracer_item_in_jit_wrapped_function():
    # f2 = jax.jit(f) marks f's body as a jit context too
    assert "tracer-item" in rules_of("""
        import jax
        def f(x):
            return x.item()
        f2 = jax.jit(f)
    """)


def test_tracer_host_cast_inside_jit():
    assert "tracer-host-cast" in rules_of("""
        import jax
        @jax.jit
        def f(x):
            return float(x) + 1.0
    """)


def test_host_cast_of_static_argnames_is_clean():
    # the repo's kernel-dispatch idiom: int(min(...)) over statics
    assert "tracer-host-cast" not in rules_of("""
        import functools, jax
        @functools.partial(jax.jit, static_argnames=("k", "block"))
        def f(x, k, block):
            tile = int(min(k, block))
            return x * tile
    """)


def test_host_cast_of_shape_metadata_is_clean():
    # shapes are static under jit — .shape/.ndim/len() are not tracers
    assert "tracer-host-cast" not in rules_of("""
        import jax
        @jax.jit
        def f(x):
            n = int(x.shape[0])
            return x * n * len(x.shape)
    """)


def test_tracer_np_call_inside_jit():
    assert "tracer-np-call" in rules_of("""
        import jax
        import numpy as np
        @jax.jit
        def f(x):
            return np.asarray(x)
    """)


def test_np_call_on_untraced_value_is_clean():
    assert "tracer-np-call" not in rules_of("""
        import jax
        import numpy as np
        @jax.jit
        def f(x):
            return x + np.arange(4)
    """)


def test_prng_unseeded_legacy_and_seedless():
    src = """
        import numpy as np
        a = np.random.rand(4)
        rng = np.random.default_rng()
    """
    findings = [f for f in lint_source(textwrap.dedent(src))
                if f.rule == "prng-unseeded"]
    assert len(findings) == 2


def test_prng_seeded_default_rng_is_clean():
    assert "prng-unseeded" not in rules_of("""
        import numpy as np
        rng = np.random.default_rng(0)
        x = rng.standard_normal(4)
    """)


def test_prng_key_reuse():
    assert "prng-key-reuse" in rules_of("""
        import jax
        def f():
            key = jax.random.PRNGKey(0)
            a = jax.random.normal(key, (3,))
            b = jax.random.uniform(key, (3,))
            return a + b
    """)


def test_prng_key_split_is_clean():
    assert "prng-key-reuse" not in rules_of("""
        import jax
        def f():
            key = jax.random.PRNGKey(0)
            k1, k2 = jax.random.split(key)
            a = jax.random.normal(k1, (3,))
            b = jax.random.uniform(k2, (3,))
            return a + b
    """)


def test_f64_dtypeless_constructor():
    assert "f64-dtypeless" in rules_of("""
        import jax.numpy as jnp
        x = jnp.zeros((4,))
    """)


def test_f64_dtypeless_gated_by_hot_path():
    src = """
        import jax.numpy as jnp
        x = jnp.zeros((4,))
    """
    assert "f64-dtypeless" not in rules_of(src, hot_path=False)


def test_explicit_dtype_constructor_is_clean():
    assert "f64-dtypeless" not in rules_of("""
        import jax.numpy as jnp
        x = jnp.zeros((4,), jnp.float32)
        y = jnp.ones((4,), dtype=jnp.int32)
    """)


def test_f64_explicit_dtype_and_astype():
    src = """
        import numpy as np
        a = np.zeros(3, dtype=np.float64)
        b = a.astype("float64")
        c = a.astype(float)
    """
    findings = [f for f in lint_source(textwrap.dedent(src))
                if f.rule == "f64-explicit"]
    assert len(findings) == 3


def test_f64_string_outside_dtype_position_is_clean():
    # the lint rule's own description mentions 'float64' — message
    # strings and docstrings must not trip the rule
    assert "f64-explicit" not in rules_of("""
        MSG = "hot paths must not use float64"
        def f():
            '''never emit float64 here'''
            return MSG
    """)


_KERNEL_SRC = """
    import jax
    from jax.experimental import pallas as pl
    def kern(x_ref, o_ref):
        v = x_ref[...]
        %s
        o_ref[...] = v
    @jax.jit
    def call(x, n):
        return pl.pallas_call(kern, grid=%s)(x)
"""


def test_pallas_python_branch_on_tracer():
    src = _KERNEL_SRC % ("if v.sum() > 0:\n            v = -v", "(4,)")
    assert "pallas-python-branch" in rules_of(src)


def test_pallas_branch_on_kwonly_static_is_clean():
    assert "pallas-python-branch" not in rules_of("""
        import functools
        from jax.experimental import pallas as pl
        def kern(x_ref, o_ref, *, flip):
            v = x_ref[...]
            if flip:
                v = -v
            o_ref[...] = v
        def call(x):
            return pl.pallas_call(
                functools.partial(kern, flip=True))(x)
    """)


def test_pallas_nonstatic_grid():
    # grid built from a traced (dynamic) parameter
    src = _KERNEL_SRC % ("pass", "(n,)")
    assert "pallas-nonstatic-grid" in rules_of(src)


def test_pallas_grid_from_shape_or_static_is_clean():
    assert "pallas-nonstatic-grid" not in rules_of("""
        import functools, jax
        from jax.experimental import pallas as pl
        def kern(x_ref, o_ref):
            o_ref[...] = x_ref[...]
        @functools.partial(jax.jit, static_argnames=("n",))
        def call(x, n):
            return pl.pallas_call(kern, grid=(n, x.shape[0]))(x)
    """)


def test_rule_catalogue_is_complete_and_disjoint():
    assert not set(RULES) & set(REPO_RULES)
    assert ALL_RULES == {**RULES, **REPO_RULES}
    for name, doc in ALL_RULES.items():
        assert doc, f"rule {name} has no description"


# ------------------------------------------------------------ registry rules
def test_live_repo_registries_are_complete():
    """Kernel oracles, spec sections, topology snapshot arms: the live
    repo must be clean (this is the invariant `make lint` ratchets)."""
    assert lint_repo(ROOT) == []


def _write(root: pathlib.Path, rel: str, text: str) -> None:
    p = root / rel
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(textwrap.dedent(text))


def test_registry_rules_fire_on_broken_repo(tmp_path):
    _write(tmp_path, "src/repro/kernels/ops.py", """
        def sddmm(a, b):
            return a @ b
    """)
    _write(tmp_path, "src/repro/kernels/ref.py", "")
    _write(tmp_path, "tests/test_kernel_parity.py", "")
    _write(tmp_path, "src/repro/api/spec.py", """
        class OrphanCfg:
            pass
        _SECTIONS = {}
    """)
    _write(tmp_path, "src/repro/memory/topology.py", """
        register_topology(TierTopology("ghost", fast=None, slow=None))
    """)
    got = {f.rule for f in lint_repo(tmp_path)}
    assert got == set(REPO_RULES)


def test_registry_rules_skip_missing_surfaces(tmp_path):
    assert lint_repo(tmp_path) == []


# ------------------------------------------------------------ ratchet
_BAD = """
    import jax
    @jax.jit
    def f(x):
        return x.item()
"""


def test_baseline_round_trip(tmp_path):
    findings = lint_source(textwrap.dedent(_BAD), path="src/a.py")
    assert findings
    path = tmp_path / "baseline.json"
    save_baseline(path, findings)
    new, stale = compare(findings, load_baseline(path))
    assert new == [] and stale == []


def test_new_finding_fails_against_baseline(tmp_path):
    path = tmp_path / "baseline.json"
    save_baseline(path, [])
    findings = lint_source(textwrap.dedent(_BAD), path="src/a.py")
    new, stale = compare(findings, load_baseline(path))
    assert [f.rule for f in new] == ["tracer-item"] and stale == []


def test_fixed_finding_goes_stale(tmp_path):
    """The ratchet: a baselined violation that disappears must be
    removed from the baseline (stale entries fail too)."""
    findings = lint_source(textwrap.dedent(_BAD), path="src/a.py")
    path = tmp_path / "baseline.json"
    save_baseline(path, findings)
    new, stale = compare([], load_baseline(path))
    assert new == []
    assert [(rec, rem) for _, rec, rem in stale] == [(1, 0)]


def test_fingerprint_survives_line_shifts():
    a = lint_source(textwrap.dedent(_BAD), path="src/a.py")
    shifted = "# header\n\n\n" + textwrap.dedent(_BAD)
    b = lint_source(shifted, path="src/a.py")
    assert [f.key() for f in a] == [f.key() for f in b]
    assert a[0].line != b[0].line


def test_committed_baseline_matches_current_findings():
    """tools/lint.py must exit 0 against the committed baseline — the
    same gate CI runs."""
    proc = subprocess.run(
        [sys.executable, str(ROOT / "tools" / "lint.py"),
         "--check-baseline"], capture_output=True, text=True)
    assert proc.returncode == 0, proc.stdout + proc.stderr


# ------------------------------------------------------------ HLO layer
def test_check_text_flags_f64_and_host_transfer():
    from repro.analysis.hlo_audit import check_text
    assert check_text("add.1 = f64[4,8] add(...)") != []
    assert check_text("ROOT t = c128[2] tuple(...)") != []
    assert check_text("custom-call(...), custom_call_target="
                      "\"MoveToHost\"") != []
    assert check_text("buffer: f32[4]{0:S(5)}") != []
    assert check_text("annotate_device_placement(...)") != []
    assert check_text("add.1 = f32[4,8] add(...)") == []


def test_check_text_respects_expectation_table():
    from repro.analysis.hlo_audit import check_text, expect
    int8 = "f32[4] all-reduce(...) convert s32[4] s8[4]"
    assert check_text(int8, expect("grad-combine@int8")) == []
    assert check_text("f32[4] add(...)",
                      expect("grad-combine@int8")) != []
    assert check_text("f32[4] all-reduce(...)",
                      expect("single-device")) != []
    assert check_text("f32[4] add(...)", expect("single-device")) == []


def test_expectation_merge_contains_wins_over_absent():
    from repro.analysis.hlo_audit import FRAGMENTS
    merged = FRAGMENTS["single-device"].merged(FRAGMENTS["grad-psum"])
    assert "all-reduce" in merged.contains
    assert "all-reduce" not in merged.absent
    assert "collective-permute" in merged.absent


def test_expectation_for_maps_config_to_fragments():
    from repro.analysis.hlo_audit import COLLECTIVES, expectation_for
    single = expectation_for(n_shards=1)
    assert set(single.absent) == set(COLLECTIVES)
    sharded = expectation_for(n_shards=4)
    assert {"collective-permute", "all-reduce"} <= set(sharded.contains)
    int8 = expectation_for(n_shards=4, grads="int8", ring="int8")
    assert {"s8", "s32", "all-reduce",
            "collective-permute"} <= set(int8.contains)
    topk = expectation_for(n_shards=4, grads="topk")
    assert "all-gather" in topk.contains


def test_assert_clean_raises_with_violation_text():
    from repro.analysis.hlo_audit import assert_clean, expect
    with pytest.raises(AssertionError, match="forbidden op"):
        assert_clean("f32[4] all-reduce(...)", expect("single-device"),
                     where="unit")


class _FakePlan:
    global_microbatch = 16

    def microbatches_for_epoch(self, epoch):
        return 1 + epoch          # warm-up grows the COUNT, not the shape


def test_recompile_hazard_engine_feed_is_single_shape():
    from repro.analysis.hlo_audit import recompile_hazard
    assert recompile_hazard(_FakePlan()) == [16]


def test_recompile_hazard_catches_ragged_direct_feed():
    from repro.analysis.hlo_audit import recompile_hazard
    shapes = recompile_hazard(_FakePlan(), batches=[16, 40])
    assert shapes == [8, 16]      # 40 = 2x16 + ragged 8 -> extra trace


# ------------------------------------------------------------ slow: lowerings
@pytest.mark.slow
def test_smoke_audit_single_device_is_clean():
    """The full Layer 2 pass on the single-device smoke preset: train
    halves, fused serve, recompile hazard."""
    from repro.analysis.hlo_audit import smoke_audit
    assert smoke_audit(mesh=1) == []


@pytest.mark.slow
def test_auditor_catches_seeded_f64_injection():
    """Self-test from the acceptance criteria: enable x64 in a
    subprocess, lower a train-step-shaped function that widens one
    intermediate to f64, and the auditor must flag it (without x64 JAX
    silently downcasts, which is why this runs out of process)."""
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp
        from repro.analysis.hlo_audit import check_text

        @jax.jit
        def step(x):
            acc = x.astype(jnp.float64)      # the seeded injection
            return (acc * acc).sum().astype(jnp.float32)

        txt = step.lower(jnp.ones((8,), jnp.float32)).compile().as_text()
        v = check_text(txt, where="f64-injection")
        assert v and "f64" in v[0], f"auditor missed the injection: {v}"

        clean = jax.jit(lambda x: (x * x).sum())
        txt = clean.lower(jnp.ones((8,), jnp.float32)).compile().as_text()
        assert check_text(txt) == []
        print("F64_CAUGHT")
    """)
    env = dict(os.environ, JAX_ENABLE_X64="1",
               PYTHONPATH=str(ROOT / "src"))
    proc = subprocess.run([sys.executable, "-c", code],
                          capture_output=True, text=True, env=env)
    assert proc.returncode == 0, proc.stderr
    assert "F64_CAUGHT" in proc.stdout


@pytest.mark.slow
def test_smoke_audit_forced_mesh_is_clean():
    """The mesh=4 + int8-psum arm end to end in a forced-device
    subprocess (the same arm `make audit` runs)."""
    code = ("from repro.analysis.hlo_audit import smoke_audit\n"
            "v = smoke_audit(mesh=4, grads='int8')\n"
            "assert v == [], v\n"
            "print('MESH_AUDIT_OK')\n")
    env = dict(os.environ, PYTHONPATH=str(ROOT / "src"))
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=4").strip()
    proc = subprocess.run([sys.executable, "-c", code],
                          capture_output=True, text=True, env=env)
    assert proc.returncode == 0, proc.stderr
    assert "MESH_AUDIT_OK" in proc.stdout
