"""Launch-layer tests: cell builders lower on a small mesh (subprocess),
analytic cost model sanity, roofline parsing."""
import subprocess
import sys
import textwrap

import pytest


def run_with_devices(code: str, n: int = 8):
    prog = f"import os\nos.environ['XLA_FLAGS'] = " \
           f"'--xla_force_host_platform_device_count={n}'\n" + \
           "import sys; sys.path.insert(0, 'src')\n" + textwrap.dedent(code)
    res = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, timeout=420, cwd="/root/repo")
    if res.returncode != 0:
        raise AssertionError(f"subprocess failed:\n{res.stdout[-2000:]}\n"
                             f"{res.stderr[-3000:]}")
    return res.stdout


def test_gnn_and_recsys_cells_lower_on_small_mesh():
    out = run_with_devices("""
        import jax
        from repro.launch.mesh import make_mesh
        from repro.launch import cells as cb
        mesh = make_mesh((2, 4), ("data", "model"))
        for arch, shape in [("gcn-cora", "full_graph_sm"),
                            ("deepfm", "serve_p99"),
                            ("bert4rec", "retrieval_cand")]:
            cell = cb.build_cell(arch, shape, mesh)
            with mesh:
                c = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                            out_shardings=cell.out_shardings,
                            donate_argnums=cell.donate).lower(*cell.args).compile()
            assert c.memory_analysis().temp_size_in_bytes >= 0
            print(f"{arch}/{shape} OK")
        print("CELLS_OK")
    """)
    assert "CELLS_OK" in out


def test_cell_skip_raises():
    out = run_with_devices("""
        from repro.launch.mesh import make_mesh
        from repro.launch import cells as cb
        mesh = make_mesh((2, 4), ("data", "model"))
        try:
            cb.build_cell("nemotron-4-340b", "long_500k", mesh)
            raise SystemExit("should have raised")
        except ValueError as e:
            assert "skipped" in str(e)
        print("SKIP_OK")
    """)
    assert "SKIP_OK" in out


def test_input_specs_are_abstract():
    """input_specs must be allocation-free ShapeDtypeStructs."""
    out = run_with_devices("""
        import jax
        from repro.launch.mesh import make_mesh
        from repro.launch.cells import input_specs
        mesh = make_mesh((2, 4), ("data", "model"))
        specs = input_specs("gcn-cora", "molecule", mesh)
        for leaf in jax.tree.leaves(specs):
            assert isinstance(leaf, jax.ShapeDtypeStruct), type(leaf)
        print("SPECS_OK")
    """)
    assert "SPECS_OK" in out


# --------------------------------------------------------------- analytic
def test_analytic_flops_scale_with_tokens():
    from repro import configs
    from repro.launch.analytic import lm_train_cost

    class FakeMesh:
        shape = {"data": 16, "model": 16}
        axis_names = ("data", "model")
    cfg = configs.get("granite_3_8b").FULL
    a = lm_train_cost(cfg, dict(global_batch=256, seq_len=4096,
                                microbatches=4), FakeMesh())
    b = lm_train_cost(cfg, dict(global_batch=512, seq_len=4096,
                                microbatches=4), FakeMesh())
    assert b["flops"] == pytest.approx(2 * a["flops"], rel=0.01)


def test_analytic_banded_attention_cheaper():
    from repro import configs
    from repro.launch.analytic import lm_prefill_cost

    class FakeMesh:
        shape = {"data": 16, "model": 16}
        axis_names = ("data", "model")
    full = configs.get("granite_3_8b").FULL       # full attention
    swa = configs.get("mixtral_8x7b").FULL        # SWA 4096
    sh = dict(global_batch=32, seq_len=32768)
    f = lm_prefill_cost(full, sh, FakeMesh())
    s = lm_prefill_cost(swa, sh, FakeMesh())
    # attention FLOPs per layer must be much lower for the banded arch
    from repro.launch.analytic import _attn_flops_per_layer, _s_vis
    af = _attn_flops_per_layer(full, 32, 32768, _s_vis(full, 32768))
    asw = _attn_flops_per_layer(swa, 32, 32768, _s_vis(swa, 32768))
    assert asw < af / 4


def test_analytic_moe_vs_dense_active():
    from repro import configs
    mix = configs.get("mixtral_8x7b").FULL
    assert mix.active_param_count() < 0.35 * mix.param_count()
    kimi = configs.get("kimi_k2_1t_a32b").FULL
    assert kimi.active_param_count() < 0.05 * kimi.param_count()


# --------------------------------------------------------------- roofline
def test_collective_bytes_parser():
    from repro.launch.roofline import collective_bytes
    hlo = """
      %ag = f32[128,256]{1,0} all-gather(%p0), replica_groups=[4]<=[4]
      %ar.1 = bf16[64]{0} all-reduce(%x), to_apply=%add
      %cp = f32[8,8]{1,0} collective-permute(%y), source_target_pairs={{0,1}}
      %dot = f32[128,128]{1,0} dot(%a, %b)
    """
    st = collective_bytes(hlo)
    assert st.by_kind["all-gather"] == 128 * 256 * 4
    assert st.by_kind["all-reduce"] == 64 * 2
    assert st.by_kind["collective-permute"] == 8 * 8 * 4
    assert st.total_bytes == 128 * 256 * 4 + 128 + 256


def test_roofline_bottleneck_selection():
    from repro.launch.roofline import Roofline, analyze

    class FakeCompiled:
        def cost_analysis(self):
            return {"flops": 1e15, "bytes accessed": 1e9}

        def as_text(self):
            return "%ar = f32[1024]{0} all-reduce(%x)"

    r = analyze(FakeCompiled(), n_chips=256)
    assert r.bottleneck == "compute"
    r2 = analyze(FakeCompiled(), n_chips=256,
                 analytic=dict(flops=1.0, hbm_bytes=1e15, coll_bytes=0.0))
    assert r2.bottleneck == "memory"
