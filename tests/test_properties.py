"""Property-based tests (hypothesis) on system invariants.

Skipped cleanly when hypothesis is absent (it is a dev-only dependency;
see requirements-dev.txt).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings          # noqa: E402
from hypothesis import strategies as st         # noqa: E402

from repro.core import sparse_ops
from repro.core.graph import bipartite_from_numpy
from repro.core.large_batch import LargeBatchSchedule
from repro.core.message_passing import bipartite_sym_coeff
from repro.core.tiered_memory import AccessProfile, plan_placement
from repro.data import kronecker, synth

SETTINGS = dict(max_examples=25, deadline=None)


@given(n=st.integers(2, 30), e=st.integers(1, 100), d=st.integers(1, 16),
       seed=st.integers(0, 2**16))
@settings(**SETTINGS)
def test_spmm_sum_equals_dense_matmul(n, e, d, seed):
    """SpMM(sum) == A_dense @ X for the equivalent dense adjacency."""
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, e).astype(np.int32)
    dst = rng.integers(0, n, e).astype(np.int32)
    x = rng.standard_normal((n, d)).astype(np.float32)
    mask = jnp.ones(e, bool)
    out = sparse_ops.gspmm_copy_sum(jnp.asarray(x), jnp.asarray(src),
                                    jnp.asarray(dst), n, mask)
    a = np.zeros((n, n), np.float32)
    np.add.at(a, (dst, src), 1.0)
    np.testing.assert_allclose(out, a @ x, rtol=2e-4, atol=2e-4)


@given(n=st.integers(2, 20), e=st.integers(1, 60), seed=st.integers(0, 2**16))
@settings(**SETTINGS)
def test_edge_softmax_normalizes(n, e, seed):
    rng = np.random.default_rng(seed)
    dst = rng.integers(0, n, e).astype(np.int32)
    scores = rng.standard_normal(e).astype(np.float32)
    mask = jnp.ones(e, bool)
    w = sparse_ops.edge_softmax(jnp.asarray(scores), jnp.asarray(dst), n, mask)
    sums = jax.ops.segment_sum(w, jnp.asarray(dst), num_segments=n)
    touched = np.zeros(n, bool)
    touched[dst] = True
    np.testing.assert_allclose(np.asarray(sums)[touched], 1.0, rtol=1e-5)


@given(nu=st.integers(2, 12), ni=st.integers(2, 12), e=st.integers(1, 40),
       seed=st.integers(0, 2**16))
@settings(**SETTINGS)
def test_sym_coeff_bounded(nu, ni, e, seed):
    """1/sqrt(du*di) in (0, 1] on live edges, 0 on padding."""
    rng = np.random.default_rng(seed)
    u = rng.integers(0, nu, e).astype(np.int32)
    i = rng.integers(0, ni, e).astype(np.int32)
    g = bipartite_from_numpy(u, i, nu, ni, e_pad=e + 8)
    c = np.asarray(bipartite_sym_coeff(g))
    assert (c[:e] > 0).all() and (c[:e] <= 1.0 + 1e-6).all()
    assert (c[e:] == 0).all()


@given(factor=st.integers(2, 30), seed=st.integers(0, 2**16))
@settings(max_examples=10, deadline=None)
def test_kronecker_edge_multiplication(factor, seed):
    base = synth.generate_bipartite(40, 30, 150, seed=seed % 100)
    out = kronecker.expand_by_factor(base, factor, seed=seed % 7)
    assert out.n_edges == base.n_edges * factor
    # no duplicate edges
    key = out.user.astype(np.int64) * out.n_items + out.item
    assert len(np.unique(key)) == len(key)


@given(base_batch=st.integers(1, 1000), target=st.integers(1000, 10**6),
       lr=st.floats(1e-6, 1e-2))
@settings(**SETTINGS)
def test_linear_scaling_invariant(base_batch, target, lr):
    """lr/batch ratio is invariant under linear scaling (paper §7.1)."""
    s = LargeBatchSchedule(base_lr=lr, base_batch=base_batch,
                           target_batch=target)
    assert s.linear_scaled_lr(target) / target == \
        __import__("pytest").approx(lr / base_batch)
    assert s.batch_for_epoch(0) <= s.batch_for_epoch(10)


@given(sizes=st.lists(st.integers(1, 10**9), min_size=1, max_size=12),
       budget_frac=st.floats(0.1, 1.0), seed=st.integers(0, 2**16))
@settings(**SETTINGS)
def test_planner_respects_budget_and_places_all(sizes, budget_frac, seed):
    rng = np.random.default_rng(seed)
    profiles = [AccessProfile(f"t{i}", s,
                              reads_per_step=float(rng.uniform(0, 4)),
                              writes_per_step=float(rng.uniform(0, 4)))
                for i, s in enumerate(sizes)]
    budget = max(int(sum(sizes) * budget_frac), 1)
    plan = plan_placement(profiles, hbm_budget=budget,
                          host_budget=int(sum(sizes)) + 1)
    assert plan.hbm_used <= budget
    assert set(plan.placements) == {p.name for p in profiles}


@given(b=st.integers(1, 4), sq=st.integers(1, 48), h=st.integers(1, 4),
       seed=st.integers(0, 2**16))
@settings(max_examples=15, deadline=None)
def test_flash_attention_rows_are_convex_combos(b, sq, h, seed):
    """Attention output rows lie in the convex hull of V rows ->
    max |out| <= max |V| elementwise bound."""
    from repro.models.attention import flash_attention
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(k1, (b, sq, h, 8))
    k = jax.random.normal(k2, (b, sq, h, 8))
    v = jax.random.normal(k3, (b, sq, h, 8))
    out = flash_attention(q, k, v, causal=True, q_chunk=16, k_chunk=16)
    assert float(jnp.abs(out).max()) <= float(jnp.abs(v).max()) + 1e-4
