"""Flash (chunked online-softmax) attention vs dense oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import attention_ref, flash_attention


def qkv(key, b, sq, sk, h, g, dh):
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (b, sq, h, dh))
    k = jax.random.normal(k2, (b, sk, g, dh))
    v = jax.random.normal(k3, (b, sk, g, dh))
    return q, k, v


@pytest.mark.parametrize("sq,h,g,dh", [(64, 4, 2, 16), (100, 8, 8, 8),
                                       (33, 4, 1, 32)])
def test_flash_matches_ref_causal(sq, h, g, dh):
    q, k, v = qkv(jax.random.PRNGKey(0), 2, sq, sq, h, g, dh)
    got = flash_attention(q, k, v, causal=True, q_chunk=16, k_chunk=32)
    want = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-5)


@pytest.mark.parametrize("window", [8, 16, 64])
def test_flash_banded_matches_ref(window):
    """Banded path visits a subset of k-chunks; must equal the masked oracle."""
    q, k, v = qkv(jax.random.PRNGKey(1), 2, 96, 96, 4, 2, 16)
    got = flash_attention(q, k, v, causal=True, window=window,
                          q_chunk=16, k_chunk=16)
    want = attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-5)


def test_flash_softcap():
    q, k, v = qkv(jax.random.PRNGKey(2), 1, 40, 40, 2, 2, 16)
    got = flash_attention(q, k, v, causal=True, softcap=50.0,
                          q_chunk=8, k_chunk=8)
    want = attention_ref(q, k, v, causal=True, softcap=50.0)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-5)


def test_flash_nondivisible_chunks():
    q, k, v = qkv(jax.random.PRNGKey(3), 1, 37, 37, 2, 1, 8)
    got = flash_attention(q, k, v, causal=True, q_chunk=16, k_chunk=16)
    want = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-5)


def test_flash_grad_finite():
    q, k, v = qkv(jax.random.PRNGKey(4), 1, 32, 32, 2, 1, 8)

    def f(q):
        return flash_attention(q, k, v, causal=True, q_chunk=8,
                               k_chunk=8).sum()

    g = jax.grad(f)(q)
    assert jnp.isfinite(g).all()
