"""Unified-pipeline tests: CSR forward equivalence vs the seed COO
models, gradient-accumulation == full-batch gradients, registry
round-trip, planner-placement propagation, and loop integration."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bpr, lightgcn, ngcf
from repro.core.graph import bipartite_from_numpy
from repro.data import synth
from repro.pipeline import (MODELS, BipartiteCSR, PipelineConfig,
                            build_pipeline, get_model)
from repro.runtime.loop import LoopConfig, run_pipeline


def _small():
    data = synth.generate_bipartite(60, 45, 600, seed=0)
    train, test = synth.train_test_split(data)
    return data, train, test


# ------------------------------------------------------- CSR equivalence
def test_lightgcn_csr_matches_coo():
    data, train, _ = _small()
    g_csr = BipartiteCSR(train.user, train.item, data.n_users, data.n_items)
    g_coo = bipartite_from_numpy(train.user, train.item, data.n_users,
                                 data.n_items)
    p = lightgcn.init_params(jax.random.PRNGKey(0), data.n_users,
                             data.n_items, 16)
    ue1, ie1 = get_model("lightgcn").forward(p, g_csr, 2)
    ue2, ie2 = lightgcn.forward(p, g_coo, n_layers=2)
    np.testing.assert_allclose(ue1, ue2, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(ie1, ie2, rtol=1e-5, atol=1e-6)


def test_ngcf_csr_matches_coo():
    data, train, _ = _small()
    g_csr = BipartiteCSR(train.user, train.item, data.n_users, data.n_items)
    g_coo = bipartite_from_numpy(train.user, train.item, data.n_users,
                                 data.n_items)
    p = ngcf.init_params(jax.random.PRNGKey(1), data.n_users, data.n_items,
                         16, 2)
    ue1, ie1 = get_model("ngcf").forward(p, g_csr, 2)
    ue2, ie2 = ngcf.forward(p, g_coo, opt_level=3)
    np.testing.assert_allclose(ue1, ue2, rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(ie1, ie2, rtol=2e-4, atol=2e-5)


# --------------------------------------------------- fused Hadamard (NGCF)
def _ngcf_step_loss_and_grads(g, data, train, seed=0):
    """One full NGCF BPR train-step loss + grads through the registry."""
    p = ngcf.init_params(jax.random.PRNGKey(2), data.n_users, data.n_items,
                         16, 2)
    rng = np.random.default_rng(seed)
    b = 64
    pick = rng.integers(0, len(train.user), b)
    u = jnp.asarray(train.user[pick].astype(np.int32))
    pos = jnp.asarray(train.item[pick].astype(np.int32))
    neg = jnp.asarray(rng.integers(0, data.n_items, b).astype(np.int32))

    def loss_fn(p):
        ue, ie = get_model("ngcf").forward(p, g, 2)
        return bpr.bpr_loss(ue, ie, u, pos, neg)

    return jax.value_and_grad(loss_fn)(p)


def test_ngcf_fused_matches_composed():
    """The fused hadamard_spmm route (rematerializing VJP, no [E, D]
    message matrix) must reproduce the composed path's train-step loss
    and gradients within fp32 tolerance."""
    data, train, _ = _small()
    kw = dict(n_users=data.n_users, n_items=data.n_items)
    g_f = BipartiteCSR(train.user, train.item, hadamard="fused", **kw)
    g_c = BipartiteCSR(train.user, train.item, hadamard="composed", **kw)
    assert g_f.fused_hadamard and not g_c.fused_hadamard
    loss_f, grads_f = _ngcf_step_loss_and_grads(g_f, data, train)
    loss_c, grads_c = _ngcf_step_loss_and_grads(g_c, data, train)
    np.testing.assert_allclose(loss_f, loss_c, rtol=1e-5)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        a, b, rtol=2e-4, atol=1e-5), grads_f, grads_c)


def _collect_shapes(closed_jaxpr):
    """Every aval shape in a jaxpr, including all nested sub-jaxprs."""
    shapes = set()

    def walk(jaxpr):
        for eqn in jaxpr.eqns:
            for v in list(eqn.invars) + list(eqn.outvars):
                aval = getattr(v, "aval", None)
                if aval is not None and hasattr(aval, "shape"):
                    shapes.add(tuple(aval.shape))
            for val in eqn.params.values():
                items = val if isinstance(val, (list, tuple)) else [val]
                for item in items:
                    if hasattr(item, "jaxpr"):      # ClosedJaxpr
                        walk(item.jaxpr)
                    elif hasattr(item, "eqns"):     # raw Jaxpr
                        walk(item)

    walk(closed_jaxpr.jaxpr)
    return shapes


def test_fused_ngcf_jaxpr_has_no_edge_message():
    """Regression: the fused NGCF train step (Pallas dispatch) contains
    NO [E, D]-shaped intermediate anywhere in its jaxpr — forward,
    rematerializing backward, or optimizer — while the composed path
    provably does (so the scan itself is not vacuous)."""
    data, train, _ = _small()
    d = 16
    e = len(train.user)

    def shapes_for(hadamard, impl):
        g = BipartiteCSR(train.user, train.item, data.n_users, data.n_items,
                         impl=impl, hadamard=hadamard)
        p = ngcf.init_params(jax.random.PRNGKey(0), data.n_users,
                             data.n_items, d, 2)
        u = jnp.zeros(8, jnp.int32)

        def loss_fn(p):
            ue, ie = get_model("ngcf").forward(p, g, 2)
            return bpr.bpr_loss(ue, ie, u, u % data.n_items, u)

        jaxpr = jax.make_jaxpr(jax.value_and_grad(loss_fn))(p)
        return _collect_shapes(jaxpr)

    assert (e, d) in shapes_for("composed", "pallas")
    assert (e, d) not in shapes_for("fused", "pallas")


def test_bipartite_csr_hadamard_validation_and_ring_fallback():
    data, train, _ = _small()
    with pytest.raises(ValueError, match="hadamard"):
        BipartiteCSR(train.user, train.item, data.n_users, data.n_items,
                     hadamard="bogus")
    # the ring dispatch has no fused gather-multiply-aggregate: 'auto'
    # falls back to the composed route and the planner must see that
    g_ring = BipartiteCSR(train.user, train.item, data.n_users,
                          data.n_items, impl="ring")
    assert not g_ring.fused_hadamard
    assert get_model("ngcf").messages_materialized(g_ring)
    g = BipartiteCSR(train.user, train.item, data.n_users, data.n_items)
    assert not get_model("ngcf").messages_materialized(g)
    assert get_model("ngcf").materializes_messages    # static flag stands


def test_csr_custom_vjp_matches_autodiff():
    """The kernel-routed aggregation's custom VJP (reverse-direction SpMM)
    must match plain XLA autodiff of the same contraction."""
    data, train, _ = _small()
    g = BipartiteCSR(train.user, train.item, data.n_users, data.n_items)
    x = jnp.asarray(np.random.default_rng(0).standard_normal(
        (data.n_users, 8)).astype(np.float32))

    def via_kernel(x):
        return jnp.sum(g.agg_u2i(x) ** 2)

    def via_xla(x):
        m = x[g.ui_src]
        dst = g.ui_dst
        out = jax.ops.segment_sum(m, dst, num_segments=data.n_items)
        return jnp.sum(out ** 2)

    np.testing.assert_allclose(via_kernel(x), via_xla(x), rtol=1e-5)
    np.testing.assert_allclose(jax.grad(via_kernel)(x), jax.grad(via_xla)(x),
                               rtol=1e-4, atol=1e-5)


# ------------------------------------------------------- grad accumulation
@pytest.mark.parametrize("batch", [128, 100])   # equal chunks + ragged tail
def test_grad_accumulation_matches_full_batch(batch):
    """Size-weighted accumulation of per-microbatch gradients == gradient
    of the full-batch mean loss (the acceptance-criterion equivalence),
    including when the batch is not a microbatch multiple."""
    data, train, _ = _small()
    cfg = PipelineConfig(arch="lightgcn", embed_dim=16, target_batch=128,
                         microbatch=32, base_batch=32)
    pipe = build_pipeline(cfg, train)
    params = pipe.init_state()["params"]
    rng = np.random.default_rng(0)
    u, i, n = bpr.sample_bpr_batch(rng, train.user, train.item,
                                   data.n_items, batch)

    _, acc_grads = pipe.grads_for_batch(params, u, i, n)

    def full_loss(p):
        ue, ie = pipe.spec.forward(p, pipe.g, cfg.n_layers)
        return bpr.bpr_loss(ue, ie, jnp.asarray(u), jnp.asarray(i),
                            jnp.asarray(n), l2=cfg.l2)

    full_grads = jax.grad(full_loss)(params)
    for a, b in zip(jax.tree.leaves(acc_grads), jax.tree.leaves(full_grads)):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-6)


def test_step_fn_accumulates_microbatches():
    """With target > microbatch the step must drain multiple loader
    microbatches (real accumulation), and the state must advance."""
    data, train, _ = _small()
    cfg = PipelineConfig(arch="lightgcn", embed_dim=8, target_batch=128,
                         microbatch=32, base_batch=128, warmup_epochs=0)
    pipe = build_pipeline(cfg, train)
    assert pipe.plan.microbatches_for_epoch(0) == 4
    state = pipe.init_state()
    before = pipe.loader.state.step
    state2, loss = pipe.step_fn(state, 0)
    consumed = pipe.loader.state.step - before
    assert consumed == 4 or pipe.loader.state.epoch > 0
    assert np.isfinite(loss)
    assert not np.allclose(np.asarray(state2["params"]["user_embed"]),
                           np.asarray(state["params"]["user_embed"]))


# ------------------------------------------------------- registry
@pytest.mark.parametrize("arch", sorted(MODELS))
def test_registry_roundtrip_trains(arch):
    data, train, _ = _small()
    cfg = PipelineConfig(arch=arch, embed_dim=8, target_batch=64,
                         microbatch=32, base_batch=32, warmup_epochs=0)
    pipe = build_pipeline(cfg, train)
    state = pipe.init_state()
    losses = []
    for s in range(3):
        state, loss = pipe.step_fn(state, s)
        losses.append(float(loss))
    assert all(np.isfinite(l) for l in losses)
    ue, ie = pipe.embeddings(state)
    assert ue.shape[0] == data.n_users and ie.shape[0] == data.n_items
    assert bool(jnp.isfinite(ue).all()) and bool(jnp.isfinite(ie).all())


# ------------------------------------------------------- planner threading
def test_planner_placements_cover_state_and_graph():
    data, train, _ = _small()
    cfg = PipelineConfig(arch="lightgcn", embed_dim=16, target_batch=64,
                         microbatch=32)
    pipe = build_pipeline(cfg, train)
    names = set(pipe.plan.plan.placements)
    leaf_names = {"params" + jax.tree_util.keystr(kp) for kp, _ in
                  jax.tree_util.tree_flatten_with_path(
                      pipe.init_state()["params"])[0]}
    assert leaf_names <= names
    assert "graph/csr" in names


def test_tight_budget_demotes_to_host_and_shrinks_microbatch():
    """A tight HBM budget must (a) demote some tensors to the host tier
    and (b) propagate into a smaller derived microbatch."""
    data, train, _ = _small()
    total = None
    cfg_big = PipelineConfig(arch="ngcf", embed_dim=32, target_batch=2048)
    big = build_pipeline(cfg_big, train)
    total = big.plan.plan.hbm_used
    cfg_tight = PipelineConfig(arch="ngcf", embed_dim=32, target_batch=2048,
                               hbm_budget=max(total // 3, 4096))
    tight = build_pipeline(cfg_tight, train)
    tiers = {p.tier for p in tight.plan.plan.placements.values()}
    assert "host" in tiers
    assert tight.plan.plan.est_step_penalty_s > 0
    assert tight.plan.microbatch <= big.plan.microbatch


def test_relayout_replans_over_current_state():
    data, train, _ = _small()
    cfg = PipelineConfig(arch="lightgcn", embed_dim=8, target_batch=64,
                         microbatch=32)
    pipe = build_pipeline(cfg, train)
    state = pipe.init_state()
    old_plan = pipe.plan
    state = pipe.on_relayout(state)
    assert pipe.plan is not old_plan
    assert set(pipe.plan.plan.placements) == set(old_plan.plan.placements)


# ------------------------------------------------------- loop integration
def test_run_pipeline_checkpoints_and_resumes(tmp_path):
    data, train, _ = _small()
    cfg = PipelineConfig(arch="lightgcn", embed_dim=8, target_batch=64,
                         microbatch=32, base_batch=32, warmup_epochs=0)
    pipe = build_pipeline(cfg, train)
    rep1 = run_pipeline(LoopConfig(ckpt_dir=str(tmp_path), ckpt_every=2,
                                   max_steps=4, async_ckpt=False), pipe)
    assert rep1.steps_run == 4 and rep1.resumed_from is None
    pipe2 = build_pipeline(cfg, train)
    rep2 = run_pipeline(LoopConfig(ckpt_dir=str(tmp_path), ckpt_every=2,
                                   max_steps=6, async_ckpt=False), pipe2)
    assert rep2.resumed_from == 4 and rep2.steps_run == 2
    # the resumed loader continued mid-schedule instead of restarting:
    # it must sit where 6 uninterrupted steps would leave it
    ref = build_pipeline(cfg, train)
    ref.seek(6)
    assert pipe2.loader.state == ref.loader.state


def test_seek_matches_live_progression():
    """seek(n) must land the loader exactly where n live steps leave it —
    the contract that makes checkpoint resume schedule-exact."""
    data, train, _ = _small()
    cfg = PipelineConfig(arch="lightgcn", embed_dim=8, target_batch=128,
                         microbatch=32, base_batch=32, warmup_epochs=1)
    live = build_pipeline(cfg, train)
    state = live.init_state()
    for s in range(5):
        state, _ = live.step_fn(state, s)
    seeked = build_pipeline(cfg, train)
    seeked.seek(5)
    assert seeked.loader.state == live.loader.state
    # and the next batch drawn by each is identical
    k = live.plan.microbatches_for_epoch(live.loader.state.epoch)
    u1, p1, n1 = live._next_target_batch(k, 5)
    u2, p2, n2 = seeked._next_target_batch(k, 5)
    np.testing.assert_array_equal(u1, u2)
    np.testing.assert_array_equal(p1, p2)
    np.testing.assert_array_equal(n1, n2)
