"""Multi-device tests — run in a subprocess with 8 fake CPU devices so the
main pytest process keeps its single-device jax config."""
import subprocess
import sys
import textwrap

import pytest


def run_with_devices(code: str, n: int = 8):
    prog = f"import os\nos.environ['XLA_FLAGS'] = " \
           f"'--xla_force_host_platform_device_count={n}'\n" + \
           "import sys; sys.path.insert(0, 'src')\n" + textwrap.dedent(code)
    res = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, timeout=420, cwd="/root/repo")
    if res.returncode != 0:
        raise AssertionError(f"subprocess failed:\n{res.stdout}\n{res.stderr}")
    return res.stdout


def test_ring_spmm_matches_dense():
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.dist.ring_spmm import bucket_edges, make_ring_spmm
        n_dev, n, d, e = 8, 64, 16, 400
        rng = np.random.default_rng(0)
        src = rng.integers(0, n, e).astype(np.int32)
        dst = rng.integers(0, n, e).astype(np.int32)
        x = rng.standard_normal((n, d)).astype(np.float32)
        src_l, dst_l, mask, per = bucket_edges(src, dst, n, n_dev)
        mesh = jax.make_mesh((n_dev,), ("data",))
        fn = make_ring_spmm(mesh, "data", per)
        with mesh:
            out = jax.jit(fn)(jnp.asarray(x), jnp.asarray(src_l),
                              jnp.asarray(dst_l), jnp.asarray(mask))
        a = np.zeros((n, n), np.float32)
        np.add.at(a, (dst, src), 1.0)
        np.testing.assert_allclose(np.asarray(out), a @ x, rtol=2e-4, atol=2e-4)
        print("RING_OK")
    """)
    assert "RING_OK" in out


def test_ring_spmm_uses_collective_permute():
    """The lowering must contain collective-permute (the overlap schedule),
    not all-gather of the full feature matrix."""
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.dist.ring_spmm import bucket_edges, make_ring_spmm
        n_dev, n, d, e = 8, 64, 16, 200
        rng = np.random.default_rng(1)
        src = rng.integers(0, n, e).astype(np.int32)
        dst = rng.integers(0, n, e).astype(np.int32)
        x = rng.standard_normal((n, d)).astype(np.float32)
        src_l, dst_l, mask, per = bucket_edges(src, dst, n, n_dev)
        mesh = jax.make_mesh((n_dev,), ("data",))
        fn = make_ring_spmm(mesh, "data", per)
        with mesh:
            txt = jax.jit(fn).lower(jnp.asarray(x), jnp.asarray(src_l),
                jnp.asarray(dst_l), jnp.asarray(mask)).compile().as_text()
        assert "collective-permute" in txt, "no ppermute found"
        print("PERMUTE_OK")
    """)
    assert "PERMUTE_OK" in out


def test_compressed_psum_int8():
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from functools import partial
        from repro.optim.compression import compressed_psum_int8
        n_dev = 8
        mesh = jax.make_mesh((n_dev,), ("data",))
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        g = np.random.default_rng(0).standard_normal((n_dev, 256)).astype(np.float32)
        def body(gs, key):
            return compressed_psum_int8(gs[0], key[0], "data")
        fn = shard_map(body, mesh=mesh, in_specs=(P("data", None), P("data")),
                       out_specs=P())
        keys = jax.random.split(jax.random.PRNGKey(0), n_dev)
        out = fn(jnp.asarray(g), keys)
        want = g.sum(0)
        err = np.abs(np.asarray(out) - want).max() / (np.abs(want).max() + 1e-9)
        assert err < 0.15, f"err {err}"
        print("PSUM_OK")
    """)
    assert "PSUM_OK" in out


def test_production_mesh_shapes():
    out = run_with_devices("""
        import jax
        from repro.launch.mesh import make_production_mesh, dp_axes, dp_size
        m1 = make_production_mesh()
        assert m1.axis_names == ("data", "model") and m1.devices.size == 256
        m2 = make_production_mesh(multi_pod=True)
        assert m2.axis_names == ("pod", "data", "model")
        assert m2.devices.size == 512
        assert dp_axes(m2) == ("pod", "data") and dp_size(m2) == 32
        print("MESH_OK")
    """, n=512)
    assert "MESH_OK" in out


def test_elastic_restore_to_different_mesh(tmp_path):
    """Checkpoint saved unsharded restores onto a different device count
    (elastic re-shard)."""
    out = run_with_devices(f"""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint import save_checkpoint, restore_checkpoint
        tree = {{"w": jnp.arange(64.0).reshape(8, 8)}}
        save_checkpoint("{tmp_path}", 1, tree)
        mesh = jax.make_mesh((4,), ("data",))
        sh = {{"w": NamedSharding(mesh, P("data", None))}}
        restored, step = restore_checkpoint("{tmp_path}", tree,
                                            sharding_tree=sh)
        assert restored["w"].sharding.spec == P("data", None)
        np.testing.assert_array_equal(np.asarray(restored["w"]),
                                      np.asarray(tree["w"]))
        print("ELASTIC_OK")
    """, n=4)
    assert "ELASTIC_OK" in out
