"""Multi-device tests — run in a subprocess with fake CPU devices so the
main pytest process keeps its single-device jax config.  Host-side
pieces of the shard layer (edge bucketing, node partitioning) are
tested in-process."""
import subprocess
import sys
import textwrap

import numpy as np
import pytest


def run_with_devices(code: str, n: int = 8):
    prog = f"import os\nos.environ['XLA_FLAGS'] = " \
           f"'--xla_force_host_platform_device_count={n}'\n" + \
           "import sys; sys.path.insert(0, 'src')\n" + textwrap.dedent(code)
    res = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, timeout=420, cwd="/root/repo")
    if res.returncode != 0:
        raise AssertionError(f"subprocess failed:\n{res.stdout}\n{res.stderr}")
    return res.stdout


def test_ring_spmm_matches_dense():
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.dist.ring_spmm import bucket_edges, make_ring_spmm
        n_dev, n, d, e = 8, 64, 16, 400
        rng = np.random.default_rng(0)
        src = rng.integers(0, n, e).astype(np.int32)
        dst = rng.integers(0, n, e).astype(np.int32)
        x = rng.standard_normal((n, d)).astype(np.float32)
        src_l, dst_l, mask, per = bucket_edges(src, dst, n, n_dev)
        mesh = jax.make_mesh((n_dev,), ("data",))
        fn = make_ring_spmm(mesh, "data", per)
        with mesh:
            out = jax.jit(fn)(jnp.asarray(x), jnp.asarray(src_l),
                              jnp.asarray(dst_l), jnp.asarray(mask))
        a = np.zeros((n, n), np.float32)
        np.add.at(a, (dst, src), 1.0)
        np.testing.assert_allclose(np.asarray(out), a @ x, rtol=2e-4, atol=2e-4)
        print("RING_OK")
    """)
    assert "RING_OK" in out


def test_ring_spmm_uses_collective_permute():
    """The lowering must contain collective-permute (the overlap schedule),
    not all-gather of the full feature matrix."""
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.dist.ring_spmm import bucket_edges, make_ring_spmm
        n_dev, n, d, e = 8, 64, 16, 200
        rng = np.random.default_rng(1)
        src = rng.integers(0, n, e).astype(np.int32)
        dst = rng.integers(0, n, e).astype(np.int32)
        x = rng.standard_normal((n, d)).astype(np.float32)
        src_l, dst_l, mask, per = bucket_edges(src, dst, n, n_dev)
        mesh = jax.make_mesh((n_dev,), ("data",))
        fn = make_ring_spmm(mesh, "data", per)
        with mesh:
            txt = jax.jit(fn).lower(jnp.asarray(x), jnp.asarray(src_l),
                jnp.asarray(dst_l), jnp.asarray(mask)).compile().as_text()
        from repro.analysis.hlo_audit import HloExpectation, assert_clean
        # the bare ring fn (unlike the full train step, where GSPMD
        # gathers embedding rows) must not all-gather the feature matrix
        assert_clean(txt, HloExpectation("ring-only",
                                         contains=("collective-permute",),
                                         absent=("all-gather",)),
                     where="ring-spmm")
        print("PERMUTE_OK")
    """)
    assert "PERMUTE_OK" in out


def test_compressed_psum_int8():
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from functools import partial
        from repro.optim.compression import compressed_psum_int8
        n_dev = 8
        mesh = jax.make_mesh((n_dev,), ("data",))
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        g = np.random.default_rng(0).standard_normal((n_dev, 256)).astype(np.float32)
        def body(gs, key):
            return compressed_psum_int8(gs[0], key[0], "data")
        fn = shard_map(body, mesh=mesh, in_specs=(P("data", None), P("data")),
                       out_specs=P())
        keys = jax.random.split(jax.random.PRNGKey(0), n_dev)
        out = fn(jnp.asarray(g), keys)
        want = g.sum(0)
        err = np.abs(np.asarray(out) - want).max() / (np.abs(want).max() + 1e-9)
        assert err < 0.15, f"err {err}"
        print("PSUM_OK")
    """)
    assert "PSUM_OK" in out


def test_production_mesh_shapes():
    out = run_with_devices("""
        import jax
        from repro.launch.mesh import make_production_mesh, dp_axes, dp_size
        m1 = make_production_mesh()
        assert m1.axis_names == ("data", "model") and m1.devices.size == 256
        m2 = make_production_mesh(multi_pod=True)
        assert m2.axis_names == ("pod", "data", "model")
        assert m2.devices.size == 512
        assert dp_axes(m2) == ("pod", "data") and dp_size(m2) == 32
        print("MESH_OK")
    """, n=512)
    assert "MESH_OK" in out


def test_bucket_edges_vectorized_matches_loop():
    """The single-lexsort bucketing pass must reproduce the retired
    O(P·steps) selection loop's output layout exactly — same bucket
    membership, same within-bucket order, same padding."""
    import sys as _sys
    _sys.path.insert(0, "src")
    from repro.dist.ring_spmm import _bucket_edges_loop, bucket_edges
    rng = np.random.default_rng(42)
    cases = [
        dict(n=64, p=8, e=500, steps=None, coeff=True),
        dict(n=64, p=8, e=500, steps=3, coeff=False),   # banded: drops edges
        dict(n=48, p=4, e=1, steps=None, coeff=True),
        dict(n=16, p=4, e=0, steps=2, coeff=False),     # empty edge set
        dict(n=96, p=2, e=300, steps=1, coeff=True),
    ]
    for c in cases:
        src = rng.integers(0, c["n"], c["e"]).astype(np.int32)
        dst = rng.integers(0, c["n"], c["e"]).astype(np.int32)
        coeff = rng.standard_normal(c["e"]).astype(np.float32) \
            if c["coeff"] else None
        new = bucket_edges(src, dst, c["n"], c["p"], coeff=coeff,
                           n_steps=c["steps"])
        old = _bucket_edges_loop(src, dst, c["n"], c["p"], coeff=coeff,
                                 n_steps=c["steps"])
        assert len(new) == len(old)
        for a, b in zip(new, old):
            np.testing.assert_array_equal(a, b)


def test_bucket_edges_rejects_ragged_and_shard_layer_pads():
    """bucket_edges keeps its divisibility contract; the shard layer's
    NodePartition is what absorbs ragged node counts."""
    import sys as _sys
    _sys.path.insert(0, "src")
    from repro.dist.ring_spmm import bucket_edges
    from repro.pipeline.shard import ShardPlan
    with pytest.raises(ValueError, match="not divisible"):
        bucket_edges(np.array([0]), np.array([1]), 10, 4)
    part = ShardPlan(shape=(4,)).partition(10)
    assert part.n_pad == 12 and part.n_local == 3
    # padded rows exist but own no edges
    src_l, dst_l, mask, n_local = bucket_edges(
        np.array([0, 9]), np.array([9, 0]), part.n_pad, 4)
    assert n_local == 3 and int(mask.sum()) == 2


def test_ring_dispatch_matches_csr_forward_and_grads():
    """BipartiteCSR ring dispatch vs the single-device CSR path on a
    RAGGED graph (n_users + n_items not divisible by P, so the shard
    layer pads and masks): sym_propagate and both directional
    aggregations must match in forward AND custom-VJP gradients.
    fp32 tolerance, not bit-identity: the ring sums each output row
    over P ring steps in rotation order, while the CSR kernel sums in
    one pass — a float32 reassociation."""
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.data import synth
        from repro.pipeline.shard import ShardPlan
        from repro.pipeline.sparse import BipartiteCSR
        data = synth.generate_bipartite(30, 23, 300, seed=3)   # N=53, P=4
        ref = BipartiteCSR(data.user, data.item, 30, 23)
        ring = BipartiteCSR(data.user, data.item, 30, 23,
                            shard=ShardPlan(shape=(4,), axes=("data",)))
        assert ring.spmm == "ring" and ring.shard.n_shards == 4
        rng = np.random.default_rng(0)
        xu = jnp.asarray(rng.standard_normal((30, 16)).astype(np.float32))
        xi = jnp.asarray(rng.standard_normal((23, 16)).astype(np.float32))
        for a, b in zip(ref.sym_propagate(xu, xi),
                        ring.sym_propagate(xu, xi)):
            np.testing.assert_allclose(a, b, rtol=2e-5, atol=2e-5)

        def loss(g):
            def f(xu, xi):
                hu, hi = g.sym_propagate(xu, xi)
                return (jnp.sum(hu ** 2) + jnp.sum(hi * xi)
                        + jnp.sum(g.agg_u2i(xu) ** 2)
                        + jnp.sum(g.agg_i2u(xi) ** 3))
            return f
        gr = jax.grad(loss(ref), argnums=(0, 1))(xu, xi)
        gs = jax.grad(loss(ring), argnums=(0, 1))(xu, xi)
        for a, b in zip(gr, gs):
            np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-4)
        print("RING_CSR_OK")
    """, n=4)
    assert "RING_CSR_OK" in out


def test_banded_ring_matches_dense_on_band_complete_graph():
    """n_steps < P visits only the n_steps nearest source-owner blocks;
    on a graph whose every edge source lives within that band of its
    destination, nothing is dropped and the banded ring must equal the
    dense product (fp32 tolerance: ring-step summation order)."""
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.dist.ring_spmm import bucket_edges, make_ring_spmm
        p, n, d, e, steps = 4, 32, 8, 240, 2
        per = n // p
        rng = np.random.default_rng(7)
        # band-complete: src block is dst block or its ring successor
        dst = rng.integers(0, n, e).astype(np.int32)
        off = rng.integers(0, steps, e)
        sblk = (dst // per + off) % p
        src = (sblk * per + rng.integers(0, per, e)).astype(np.int32)
        x = rng.standard_normal((n, d)).astype(np.float32)
        src_l, dst_l, mask, per_l = bucket_edges(src, dst, n, p,
                                                 n_steps=steps)
        mesh = jax.make_mesh((p,), ("data",))
        fn = make_ring_spmm(mesh, "data", per_l, n_steps=steps)
        out = jax.jit(fn)(jnp.asarray(x), jnp.asarray(src_l),
                          jnp.asarray(dst_l), jnp.asarray(mask))
        a = np.zeros((n, n), np.float32)
        np.add.at(a, (dst, src), 1.0)
        np.testing.assert_allclose(np.asarray(out), a @ x,
                                   rtol=2e-4, atol=2e-4)
        print("BANDED_OK")
    """, n=4)
    assert "BANDED_OK" in out


def test_banded_ring_gradients_match_dense_banded_operator():
    """The band-kept edge set is ASYMMETRIC (edge (s, d) is kept by the
    ring distance of s's owner ahead of d's), so the banded forward is
    not its own transpose — the custom VJP must apply the transpose of
    the KEPT edges, not an independently-banded reverse ring.  Pin both
    forward and gradients against a dense A_band built host-side with
    the same band rule, on a general (NOT band-complete) graph."""
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.data import synth
        from repro.pipeline.shard import ShardPlan
        from repro.pipeline.sparse import BipartiteCSR
        p, steps = 4, 2
        nu, ni = 40, 24                       # N=64, n_local=16
        data = synth.generate_bipartite(nu, ni, 500, seed=5)
        plan = ShardPlan(shape=(p,), ring_steps=steps)
        g = BipartiteCSR(data.user, data.item, nu, ni, shard=plan)
        # dense banded reference over the unified node space
        n = nu + ni
        n_local = n // p
        s_all = np.concatenate([data.user, data.item + nu])
        d_all = np.concatenate([data.item + nu, data.user])
        rel = (s_all // n_local - d_all // n_local) % p
        keep = rel < steps
        assert 0 < keep.sum() < len(keep)     # really drops edges
        a = np.zeros((n, n), np.float32)
        np.add.at(a, (d_all[keep], s_all[keep]), 1.0)
        a = jnp.asarray(a)
        rdu, rdi = g.rsqrt_du, g.rsqrt_di
        def ref(xu, xi):
            z = jnp.concatenate([xu * rdu[:, None], xi * rdi[:, None]])
            h = a @ z
            return h[:nu] * rdu[:, None], h[nu:] * rdi[:, None]
        rng = np.random.default_rng(1)
        xu = jnp.asarray(rng.standard_normal((nu, 8)).astype(np.float32))
        xi = jnp.asarray(rng.standard_normal((ni, 8)).astype(np.float32))
        for x, y in zip(g.sym_propagate(xu, xi), ref(xu, xi)):
            np.testing.assert_allclose(x, y, rtol=2e-4, atol=2e-4)
        def loss(f):
            return lambda xu, xi: (jnp.sum(f(xu, xi)[0] ** 2)
                                   + jnp.sum(f(xu, xi)[1] ** 3))
        gr = jax.grad(loss(ref), argnums=(0, 1))(xu, xi)
        gb = jax.grad(loss(g.sym_propagate), argnums=(0, 1))(xu, xi)
        for x, y in zip(gb, gr):
            np.testing.assert_allclose(x, y, rtol=2e-4, atol=2e-4)
        print("BANDED_GRAD_OK")
    """, n=4)
    assert "BANDED_GRAD_OK" in out


def test_sharded_fit_matches_single_device_trajectory():
    """The acceptance criterion: a MeshCfg(shape=(4,)) run through
    Run.fit() — ring-dispatched SpMM, dp-sharded batches, psum'd grads
    — must track the equivalent single-device run (same global batch:
    4 shards x microbatch 4 == microbatch 16) to fp32 tolerance, the
    lowered step must actually contain the ring collective-permute and
    the gradient all-reduce, and the sharded streaming eval must rank
    identically on identical embeddings."""
    out = run_with_devices("""
        import numpy as np
        from repro.api import build, get_preset
        from repro.eval import streaming_topk
        base = get_preset("lightgcn-smoke").override({
            "plan.microbatch": 16, "plan.target_batch": 64,
            "plan.base_batch": 64, "plan.warmup_epochs": 0})
        sharded = base.override({"mesh.shape": (4,), "mesh.axes": ("data",),
                                 "plan.microbatch": 4})
        r1 = build(base)
        l1 = r1.fit(steps=6).losses
        r2 = build(sharded)
        assert r2.pipeline.shard is not None
        assert r2.pipeline.plan.shards == 4
        assert r2.pipeline.plan.global_microbatch == 16
        # the Goyal rule must see the GLOBAL realized batch: same LR as
        # the single-device run, or the trajectories drift structurally
        assert r2.pipeline.lr_for_epoch(0) == r1.pipeline.lr_for_epoch(0)
        l2 = r2.fit(steps=6).losses
        # fp32 tolerance: ring summation + psum reassociate the fp32
        # reductions; the trajectories drift at float-noise scale
        np.testing.assert_allclose(l1, l2, rtol=5e-3, atol=1e-5)

        # lowered micro step: ring permute + psum'd grads
        pipe = r2.pipeline
        u, p, n = pipe._next_target_batch(1, 123)
        with pipe.step_context():
            db = pipe._device_batch(u[:16], p[:16], n[:16])
            txt = pipe._micro_value_and_grad.lower(
                r2.state["params"], *db).compile().as_text()
        from repro.analysis.hlo_audit import assert_clean, expectation_for
        assert_clean(txt, expectation_for(n_shards=4),
                     where="sharded-micro-step")

        # sharded streaming eval: identical embeddings -> identical
        # rankings (the dp-sharded sweep runs the same block merges)
        ue, ie = r2.embeddings()
        s0, i0 = streaming_topk(ue, ie, 10, user_batch=6)
        s1, i1 = streaming_topk(ue, ie, 10, user_batch=6,
                                shard=pipe.shard)
        np.testing.assert_array_equal(i0, i1)
        np.testing.assert_array_equal(s0, s1)
        m2 = r2.evaluate()
        assert np.isfinite(m2["recall@20"])
        print("SHARDED_FIT_OK")
    """, n=4)
    assert "SHARDED_FIT_OK" in out


def test_elastic_restore_to_different_mesh(tmp_path):
    """Checkpoint saved unsharded restores onto a different device count
    (elastic re-shard)."""
    out = run_with_devices(f"""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint import save_checkpoint, restore_checkpoint
        tree = {{"w": jnp.arange(64.0).reshape(8, 8)}}
        save_checkpoint("{tmp_path}", 1, tree)
        mesh = jax.make_mesh((4,), ("data",))
        sh = {{"w": NamedSharding(mesh, P("data", None))}}
        restored, step = restore_checkpoint("{tmp_path}", tree,
                                            sharding_tree=sh)
        assert restored["w"].sharding.spec == P("data", None)
        np.testing.assert_array_equal(np.asarray(restored["w"]),
                                      np.asarray(tree["w"]))
        print("ELASTIC_OK")
    """, n=4)
    assert "ELASTIC_OK" in out
