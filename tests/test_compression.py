"""Convergence-parity suite for the compression subsystem
(``repro.optim.compression`` wired through ``CompressionCfg``).

The pinning discipline mirrors PRs 4-5: the default ``CompressionCfg()``
is the *identity* (bit-identical training, no compressor state), every
active scheme must track the exact trajectory within a stated fp32
tolerance over >= 20 steps, int8-stored capacity-tier tables round-trip
within their quantization scale, and the planner's quantized byte
pricing stays certified by the exact DP across every registered
topology.  Multi-device arms run in subprocesses with forced host
devices (``test_distributed.run_with_devices``), including an HLO
assertion that the int8 combine really lowers to an integer all-reduce.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import CompressionCfg, ExperimentSpec, build, get_preset
from repro.memory import (AccessProfile, QuantizedHostResident, get_topology,
                          gnn_recsys_profiles, place_exact, place_greedy,
                          quantized_table_bytes, topology_names)
from repro.optim import compression as C
from repro.pipeline.compress import GradCompressor

from test_distributed import run_with_devices

_OV = {"loop.steps": 20, "plan.target_batch": 64, "plan.microbatch": 16,
       "plan.warmup_epochs": 0, "data.edges": 1200, "loop.ckpt_dir": None}


def _smoke(**overrides) -> ExperimentSpec:
    return get_preset("lightgcn-smoke").override({**_OV, **overrides})


def _losses(spec: ExperimentSpec, n: int = 20) -> list:
    run = build(spec)
    return [run.step() for _ in range(n)]


# ---------------------------------------------------------------- spec
def test_compression_cfg_roundtrip_and_validation():
    """CompressionCfg is a first-class spec section: exact JSON
    round-trip, defaults equal to the identity, unknown values raise."""
    spec = _smoke(**{"compression.grads": "topk", "compression.frac": 0.05,
                     "compression.embed_store": "int8",
                     "compression.ring": "int8"})
    again = ExperimentSpec.from_json(spec.to_json())
    assert again == spec
    assert again.compression.grads == "topk"
    assert again.compression.frac == 0.05
    # a pre-compression spec dict (no 'compression' key) loads to the
    # identity section — old saved specs keep meaning what they meant
    d = _smoke().to_dict()
    del d["compression"]
    assert ExperimentSpec.from_dict(d).compression == CompressionCfg()
    with pytest.raises(ValueError, match="compression.grads"):
        CompressionCfg(grads="fp16")
    with pytest.raises(ValueError, match="compression.frac"):
        CompressionCfg(frac=0.0)
    with pytest.raises(ValueError, match="compression.embed_store"):
        CompressionCfg(embed_store="int4")
    with pytest.raises(ValueError, match="compression.ring"):
        CompressionCfg(ring="topk")


def test_compression_cli_flags_equal_spec_overrides():
    from repro.launch.train import build_arg_parser, spec_from_args
    args = build_arg_parser().parse_args(
        ["--compress-grads", "int8", "--compress-frac", "0.05",
         "--embed-store", "int8", "--compress-ring", "int8"])
    spec = spec_from_args(args)
    assert spec.compression.grads == "int8"
    assert spec.compression.frac == 0.05
    assert spec.compression.embed_store == "int8"
    assert spec.compression.ring == "int8"


def test_grad_compressor_validation():
    with pytest.raises(ValueError, match="unknown compression scheme"):
        GradCompressor("gzip")
    with pytest.raises(ValueError, match="frac"):
        GradCompressor("topk", frac=1.5)
    gc = GradCompressor("topk", frac=0.1, error_feedback=False)
    assert "topk" in gc.describe() and "+ef" not in gc.describe()


# ---------------------------------------------------------------- primitives
def test_quantize_roundtrip_error_bounded_by_scale():
    """Stochastic int8: |dequant(quant(g)) - g| < scale per element
    (floor/ceil rounding moves at most one quantization step)."""
    for seed in range(5):
        rng = np.random.default_rng(seed)
        g = (rng.standard_normal(512) * 10.0 ** rng.integers(-3, 3)) \
            .astype(np.float32)
        q, scale = C.quantize_int8(jnp.asarray(g), jax.random.PRNGKey(seed))
        err = np.abs(np.asarray(C.dequantize_int8(q, scale)) - g)
        assert err.max() <= float(scale) * (1 + 1e-5), seed


def test_quantize_stochastic_rounding_unbiased():
    """E[dequant] == g: the mean over independent keys converges to the
    original (the property that keeps EF-free int8 psum centered)."""
    g = jnp.asarray(np.random.default_rng(0)
                    .standard_normal(64).astype(np.float32))
    outs = [np.asarray(C.dequantize_int8(*C.quantize_int8(
        g, jax.random.PRNGKey(k)))) for k in range(400)]
    _, scale = C.quantize_int8(g, jax.random.PRNGKey(0))
    bias = np.abs(np.mean(outs, 0) - np.asarray(g)).max()
    assert bias < float(scale) * 0.15, bias


def test_topk_sparsify_densify_exact_reconstruction():
    """densify(sparsify(g)) + residual == g, exactly: kept entries are
    copied (never recomputed) and the supports are disjoint."""
    for seed in range(5):
        g = np.random.default_rng(seed).standard_normal((24, 7)) \
            .astype(np.float32)
        kept, idx, residual = C.topk_sparsify(jnp.asarray(g), 13)
        dense = C.topk_densify(kept, idx, g.shape)
        np.testing.assert_array_equal(np.asarray(dense)
                                      + np.asarray(residual), g)
        # the kept entries reconstruct exactly
        flat = np.asarray(dense).reshape(-1)
        np.testing.assert_array_equal(flat[np.asarray(idx)],
                                      np.asarray(kept))


def test_error_feedback_residual_carry_invariant():
    """ErrorFeedback.apply with a top-k compressor: compressed +
    residual == grads + carried error, exactly, every leaf."""
    rng = np.random.default_rng(0)
    grads = {"a": jnp.asarray(rng.standard_normal((11, 5))
                              .astype(np.float32)),
             "b": jnp.asarray(rng.standard_normal(17).astype(np.float32))}
    errors = jax.tree.map(lambda g: jnp.asarray(
        rng.standard_normal(g.shape).astype(np.float32)), grads)
    g_hat, new_e = C.ErrorFeedback.apply(grads, errors,
                                         C.make_topk_compressor(0.25))
    for k in grads:
        np.testing.assert_array_equal(
            np.asarray(g_hat[k] + new_e[k]),
            np.asarray(grads[k] + errors[k]))


def test_wire_bytes_pricing():
    assert C.wire_bytes(1000, "none") == 4000
    assert C.wire_bytes(1000, "int8") == 1004          # 1 B/elt + 1 scale
    assert C.wire_bytes(1000, "topk", frac=0.01) == 10 * 8   # k=10 (v,i)
    with pytest.raises(ValueError, match="unknown compression scheme"):
        C.wire_bytes(10, "zstd")
    comp, exact = GradCompressor("int8").wire_bytes_per_step(
        {"w": np.zeros((100, 4), np.float32)})
    assert (comp, exact) == (404, 1600)


# ---------------------------------------------------------------- storage
def test_quantized_table_roundtrip_within_scale():
    """Acceptance (3), storage half: an int8-stored table round-trips
    through dequant-on-gather with max abs error <= its per-row
    quantization scale, at ~1/4 the resident bytes."""
    rng = np.random.default_rng(0)
    table = (rng.standard_normal((64, 16)) *
             10.0 ** rng.integers(-2, 2, (64, 1))).astype(np.float32)
    q, scale = C.quantize_rows_int8(table)
    err = np.abs(C.dequantize_rows_int8(q, scale) - table)
    assert (err <= scale * (1 + 1e-5)).all()

    host = QuantizedHostResident(table)
    ids = rng.integers(0, 64, 37)
    np.testing.assert_array_equal(host.take(ids),
                                  C.dequantize_rows_int8(q, scale)[ids])
    np.testing.assert_array_equal(host.block(ids), host.take(ids))
    assert host.shape == table.shape and host.dtype == np.float32
    assert host.nbytes == table.nbytes // 4 + 64 * 4   # q + per-row scales
    np.testing.assert_array_equal(host.dense(),
                                  C.dequantize_rows_int8(q, scale))


def test_executor_int8_store_roundtrips_and_reports_bytes():
    """A demoted params table under embed_store='int8' lives as (q,
    scale) buffers whose fetch view equals the int8 round-trip."""
    from repro.memory import TieredExecutor
    from repro.memory.policies import get_policy
    table = np.random.default_rng(1).standard_normal((32, 8)) \
        .astype(np.float32)
    profs = [AccessProfile("params['t']", table.nbytes, pinned="slow")]
    plan = get_policy("greedy")(profs, get_topology("uniform"))
    ex = TieredExecutor(plan, prefixes=("params",), embed_store="int8")
    state, moved = ex.place({"params": {"t": jnp.asarray(table)}})
    assert moved == 1
    q, scale = C.quantize_rows_int8(table)
    np.testing.assert_array_equal(state["params"]["t"],
                                  C.dequantize_rows_int8(q, scale))
    assert ex.store_nbytes("params['t']") == q.nbytes + scale.nbytes
    assert "embed_store=int8(1)" in ex.describe()
    # commit re-quantizes: the carried state is always the round-trip
    state2 = ex.commit({"params": {"t": jnp.asarray(table * 2.0)}})
    q2, s2 = C.quantize_rows_int8(table * 2.0)
    np.testing.assert_array_equal(state2["params"]["t"],
                                  C.dequantize_rows_int8(q2, s2))
    with pytest.raises(ValueError, match="unknown embed_store"):
        TieredExecutor(plan, embed_store="int4")


# ---------------------------------------------------------------- planner
def test_planner_prices_int8_tables_at_quarter_bytes():
    """Acceptance (4), pricing half: quantized profiles carry ~1/4
    resident bytes off the fast tier and the per-tier ``used``
    accounting uses them there (dense bytes stay authoritative on the
    fast tier)."""
    profs = gnn_recsys_profiles(1000, 2000, 30000, 32, 2,
                                embed_store="int8")
    emb = next(p for p in profs if p.name == "embeddings")
    assert emb.store_bytes == quantized_table_bytes(3000, 32 * 4)
    # 1 B/element + 4 B/row scale over 4 B/element dense
    assert emb.store_bytes / emb.nbytes == pytest.approx((32 + 4) / 128)
    assert emb.bytes_on(fast=True) == emb.nbytes
    assert emb.bytes_on(fast=False) == emb.store_bytes
    # fp32 profiles carry no quantized footprint (None -> dense)
    dense = gnn_recsys_profiles(1000, 2000, 30000, 32, 2)
    assert all(p.store_bytes is None for p in dense)

    topo = get_topology("dram-optane-appdirect")
    budgets = {"dram": 0, "optane": 1 << 40}         # force everything slow
    plan = place_greedy(profs, topo, budgets=budgets)
    assert plan.used["optane"] == sum(p.bytes_on(False) for p in profs)
    assert plan.used["optane"] < sum(p.nbytes for p in profs)


def test_quantized_tables_cost_less_off_fast():
    """Traffic pricing: an int8-stored table moves ~1/4 the bytes over
    the slow tier, so its demotion penalty drops accordingly — the
    byte-bandwidth argument the paper makes for every slow link."""
    topo = get_topology("dram-optane-appdirect")
    dense = AccessProfile("t", 1 << 20, reads_per_step=2.0,
                          writes_per_step=1.0, access_size=512)
    quant = dataclasses.replace(dense,
                                store_bytes=quantized_table_bytes(
                                    (1 << 20) // 512, 512))
    assert 0 < topo.demotion_penalty(quant) < \
        topo.demotion_penalty(dense) * 0.5
    # on-fast cost is storage-independent (tables compute in fp32 there)
    assert topo.step_time(quant, topo.fast) == \
        topo.step_time(dense, topo.fast)


def test_greedy_certified_by_exact_with_quantized_profiles():
    """Acceptance (4), certification half: with quantized store_bytes
    in the mix, pure greedy stays within 5% of the exact DP's optimal
    penalty on every registered topology, and per-tier budgets hold
    under quantized accounting."""
    for name in topology_names():
        topo = get_topology(name)
        for seed in range(4):
            rng = np.random.default_rng(seed)
            profs = []
            for i in range(10):
                nbytes = int(rng.integers(1, 10 ** 6))
                access = int(rng.choice([8, 64, 512, 4096]))
                store = quantized_table_bytes(max(nbytes // access, 1),
                                              access) \
                    if rng.random() < 0.5 else None
                profs.append(AccessProfile(
                    f"t{i}", nbytes,
                    reads_per_step=float(rng.uniform(0, 4)),
                    writes_per_step=float(rng.uniform(0, 4)),
                    access_size=access, store_bytes=store))
            total = sum(p.nbytes for p in profs)
            budgets = {topo.fast.name: max(total // 3, 1),
                       topo.slow.name: total + 1}
            greedy = place_greedy(profs, topo, budgets=budgets,
                                  exact_threshold=0)
            exact = place_exact(profs, topo, budgets=budgets)
            for plan in (greedy, exact):
                for t in topo.names:
                    assert plan.used[t] <= budgets[t], (name, seed, t)
            # penalties may be *negative* here: on the uniform topology
            # a quantized table is cheaper off-fast than on it, so slack
            # must scale with |penalty| to stay on the right side of 0
            g = greedy.est_step_penalty_s
            assert exact.est_step_penalty_s <= \
                g + abs(g) * 0.05 + 1e-18, (name, seed)


# ---------------------------------------------------------- trajectories
def test_default_compression_is_bit_identical():
    """Acceptance (1), single-device half: the default CompressionCfg()
    builds no compressor, adds no state, and trains bit-identically to
    an explicit exact run."""
    base = build(_smoke())
    assert base.pipeline.compressor is None
    assert set(base.state.keys()) == {"params", "opt"}
    explicit = build(_smoke(**{"compression.grads": "none"}))
    n = 5
    assert [base.step() for _ in range(n)] == \
        [explicit.step() for _ in range(n)]


def test_compressed_single_device_matches_exact_trajectory():
    """Acceptance (2), single-device half: int8 and topk+EF runs track
    the exact loss trajectory over 20 steps within fp32 tolerance, and
    the compressor state rides the training state."""
    exact = _losses(_smoke())
    int8 = build(_smoke(**{"compression.grads": "int8"}))
    assert set(int8.state.keys()) == {"params", "opt", "comp"}
    assert set(int8.state["comp"].keys()) == {"key", "ef"}
    l_int8 = [int8.step() for _ in range(20)]
    np.testing.assert_allclose(l_int8, exact, rtol=1e-3, atol=1e-4)

    l_topk = _losses(_smoke(**{"compression.grads": "topk",
                               "compression.frac": 0.1}))
    np.testing.assert_allclose(l_topk, exact, rtol=5e-3, atol=2e-3)
    # without error feedback top-k still converges but drifts more:
    # the residual carry is what keeps the trajectory centered
    l_noef = _losses(_smoke(**{"compression.grads": "topk",
                               "compression.frac": 0.1,
                               "compression.error_feedback": False}))
    np.testing.assert_allclose(l_noef, exact, rtol=2e-2, atol=5e-3)


def test_int8_embed_store_trains_to_same_tolerance():
    """Acceptance (3), training half: demoted tables stored int8
    (dequant-on-fetch, requantize-on-commit) train to the exact
    trajectory's tolerance; the identity default stays bit-identical."""
    tiered = {"memory.topology": "uniform",
              "memory.capacity": {"fast": 4096}}
    exact = _losses(_smoke(**tiered))
    q = build(_smoke(**{**tiered, "compression.embed_store": "int8"}))
    assert len(q.pipeline.plan.plan.demoted()) > 0
    l_q = [q.step() for _ in range(20)]
    # the tables really live quantized in the executor's store
    assert len(q.pipeline.executor._int8) == 2
    for name in q.pipeline.executor._int8:
        assert q.pipeline.executor.store_nbytes(name) < \
            q.state["params"][name.split("'")[1]].nbytes // 2
    np.testing.assert_allclose(l_q, exact, rtol=2e-2, atol=5e-3)
    # fp32 default on the same tight budget: still bit-identical
    fp32 = _losses(_smoke(**tiered), n=5)
    assert fp32 == exact[:5]


def test_recommender_serves_from_quantized_store():
    """Serving arm: a slow-tier table under embed_store='int8' sits
    behind the dequant-on-gather facade and scores within quantization
    tolerance of the fp32 snapshot."""
    from repro.eval import Recommender
    rng = np.random.default_rng(0)
    ue = rng.standard_normal((37, 16)).astype(np.float32)
    ie = rng.standard_normal((23, 16)).astype(np.float32)
    pins = {"serve/user_embed": "slow", "serve/item_embed": "slow"}
    fp32 = Recommender(ue, ie, k=5, user_batch=8, item_block=7,
                       topology="uniform", pins=pins)
    q = Recommender(ue, ie, k=5, user_batch=8, item_block=7,
                    topology="uniform", pins=pins, embed_store="int8")
    assert isinstance(q.user_e, QuantizedHostResident)
    assert isinstance(q.item_e, QuantizedHostResident)
    assert q.user_e.nbytes < ue.nbytes // 2
    _, scores_f = fp32.recommend(np.arange(37), exclude_seen=False)
    _, scores_q = q.recommend(np.arange(37), exclude_seen=False)
    # scores are inner products of ~unit rows: quantization moves each
    # row by <= scale ~ |row|_inf/127, so scores move by O(D * scale)
    np.testing.assert_allclose(scores_q, scores_f, atol=0.2)


# ---------------------------------------------------------- multi-device
def test_multidevice_compressed_parity_20_steps():
    """Acceptance (1) + (2), 4-device half: on the forced-4-device mesh
    the default config is bit-identical to the exact sharded run, and
    int8-psum / topk+EF / int8-ring runs track it over 20 steps."""
    out = run_with_devices("""
        import numpy as np
        from repro.api import Experiment, build
        ov = {"loop.steps": 20, "plan.target_batch": 64,
              "plan.microbatch": 4, "plan.warmup_epochs": 0,
              "data.edges": 1200, "loop.ckpt_dir": None,
              "mesh.shape": [4]}
        def run(extra):
            r = build(Experiment.from_preset(
                "lightgcn-smoke", {**ov, **extra}).spec)
            return r, [r.step() for _ in range(20)]
        r0, exact = run({})
        assert r0.pipeline.compressor is None
        _, default = run({"compression.grads": "none"})
        assert default == exact                      # bit-identical
        r8, int8 = run({"compression.grads": "int8"})
        assert r8.pipeline.compressor.shard is not None
        np.testing.assert_allclose(int8, exact, rtol=1e-3, atol=2e-4)
        _, topk = run({"compression.grads": "topk",
                       "compression.frac": 0.1})
        np.testing.assert_allclose(topk, exact, rtol=5e-3, atol=2e-3)
        _, ring = run({"compression.ring": "int8"})
        np.testing.assert_allclose(ring, exact, rtol=2e-2, atol=5e-3)
        # EF residual stacks are row-sharded over the dp axis
        import jax
        leaf = jax.tree.leaves(r8.state["comp"]["ef"])[0]
        assert leaf.shape[0] == 4
        assert "data" in str(leaf.sharding.spec)
        print("PARITY_OK")
    """, n=4)
    assert "PARITY_OK" in out


def test_multidevice_int8_combine_lowers_to_integer_allreduce():
    """The compressed combine is a *real* integer collective: the
    lowered HLO of the sharded int8 combine contains an all-reduce on
    s32 (int8 payload, int32 accumulate), which the exact fp32 combine
    does not."""
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.pipeline.shard import ShardPlan
        from repro.pipeline.compress import GradCompressor
        shard = ShardPlan((4,), ("data",))
        gc = GradCompressor("int8", shard=shard)
        grads = {"w": jnp.asarray(np.random.default_rng(0)
                 .standard_normal((64, 8)).astype(np.float32))}
        comp = gc.init_state(grads, seed=0)
        txt = jax.jit(gc).lower(grads, comp).compile().as_text()
        from repro.analysis.hlo_audit import assert_clean, expect
        assert_clean(txt, expect("grad-combine@int8"), where="int8-combine")
        # and the combine is faithful: sum of shares ~= the gradient
        combined, _ = jax.jit(gc)(grads, comp)
        np.testing.assert_allclose(np.asarray(combined["w"]),
                                   np.asarray(grads["w"]),
                                   rtol=0.2, atol=0.05)
        print("HLO_OK")
    """, n=4)
    assert "HLO_OK" in out


def test_multidevice_quantized_ring_rotates_int8():
    """The quantized ring exchange permutes an s8 payload (1/4 wire
    bytes) and stays within the quantization error bound of the exact
    ring result."""
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.dist.ring_spmm import bucket_edges, make_ring_spmm
        n_dev, n, d, e = 4, 32, 8, 200
        rng = np.random.default_rng(0)
        src = rng.integers(0, n, e).astype(np.int32)
        dst = rng.integers(0, n, e).astype(np.int32)
        x = rng.standard_normal((n, d)).astype(np.float32)
        src_l, dst_l, mask, per = bucket_edges(src, dst, n, n_dev)
        mesh = jax.make_mesh((n_dev,), ("data",))
        exact = make_ring_spmm(mesh, "data", per)
        quant = make_ring_spmm(mesh, "data", per, quantize=True)
        args = (jnp.asarray(x), jnp.asarray(src_l), jnp.asarray(dst_l),
                jnp.asarray(mask))
        with mesh:
            ref = np.asarray(jax.jit(exact)(*args))
            got = np.asarray(jax.jit(quant)(*args))
            txt = jax.jit(quant).lower(*args).compile().as_text()
        from repro.analysis.hlo_audit import assert_clean, expect
        assert_clean(txt, expect("ring-spmm@int8"), where="quantized-ring")
        # per-element bound: in-degree x scale/2 rounding error
        a = np.zeros((n, n), np.float32)
        np.add.at(a, (dst, src), 1.0)
        scale = np.abs(x).max() / 127.0
        bound = a.sum(1).max() * scale
        assert np.abs(got - ref).max() <= bound, np.abs(got - ref).max()
        print("RING_QUANT_OK")
    """, n=4)
    assert "RING_QUANT_OK" in out


# ------------------------------------------------------ property tests
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    _HYP = True
except ImportError:                                    # pragma: no cover
    _HYP = False

if _HYP:
    SETTINGS = dict(max_examples=25, deadline=None)

    @pytest.mark.slow
    @given(n=st.integers(1, 400), seed=st.integers(0, 2 ** 16),
           mag=st.integers(-4, 4))
    @settings(**SETTINGS)
    def test_prop_quantize_roundtrip_bound(n, seed, mag):
        """|dequant(quant(g)) - g| <= scale per element, any magnitude
        (stochastic rounding moves at most one quantization step; the
        expected error is <= scale/2)."""
        rng = np.random.default_rng(seed)
        g = (rng.standard_normal(n) * 10.0 ** mag).astype(np.float32)
        q, scale = C.quantize_int8(jnp.asarray(g),
                                   jax.random.PRNGKey(seed))
        err = np.abs(np.asarray(C.dequantize_int8(q, scale)) - g)
        assert err.max() <= float(scale) * (1 + 1e-5)

    @pytest.mark.slow
    @given(rows=st.integers(1, 30), cols=st.integers(1, 24),
           k=st.integers(1, 64), seed=st.integers(0, 2 ** 16))
    @settings(**SETTINGS)
    def test_prop_topk_reconstruction_exact(rows, cols, k, seed):
        """sparsify -> densify reconstructs kept entries exactly and
        densify + residual == original, bitwise."""
        k = min(k, rows * cols)
        g = np.random.default_rng(seed).standard_normal((rows, cols)) \
            .astype(np.float32)
        kept, idx, residual = C.topk_sparsify(jnp.asarray(g), k)
        dense = np.asarray(C.topk_densify(kept, idx, g.shape))
        np.testing.assert_array_equal(dense + np.asarray(residual), g)
        np.testing.assert_array_equal(dense.reshape(-1)[np.asarray(idx)],
                                      np.asarray(kept))

    @pytest.mark.slow
    @given(n=st.integers(2, 60), frac=st.floats(0.05, 1.0),
           seed=st.integers(0, 2 ** 16))
    @settings(**SETTINGS)
    def test_prop_error_feedback_conserves_mass(n, frac, seed):
        """ErrorFeedback.apply residual-carry invariant under top-k:
        compressed + residual == grads + errors, exactly."""
        rng = np.random.default_rng(seed)
        grads = {"w": jnp.asarray(rng.standard_normal(n)
                                  .astype(np.float32))}
        errors = {"w": jnp.asarray(rng.standard_normal(n)
                                   .astype(np.float32))}
        g_hat, new_e = C.ErrorFeedback.apply(
            grads, errors, C.make_topk_compressor(frac))
        np.testing.assert_array_equal(
            np.asarray(g_hat["w"] + new_e["w"]),
            np.asarray(grads["w"] + errors["w"]))
