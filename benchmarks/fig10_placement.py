"""Paper Fig 10+11: tier configuration and placement policy.

Fig 10: AppDirect (explicit placement) vs Memory Mode (HW cache) and
Optane+DRAM vs Optane-alone -> our planner vs naive policies.
Fig 11: blocked vs interleaved NUMA placement -> edge-blocked vs
round-robin edge sharding cost over a ``ShardPlan`` node partition
(blocked placement keeps SDDMM writes local; paper picks blocked
end-to-end).

The planner arm's shapes come from the paper-scale ``lightgcn-full``
preset of the Experiment API (the m-x25 configuration the config
registry declares), not hand-typed sizes — every benchmark builds its
configuration through ``repro.api``.
"""
import numpy as np

from benchmarks.common import emit
from repro.api import get_preset
from repro.core.tiered_memory import (_slow_tier_penalty,
                                      gnn_recsys_profiles, plan_placement)
from repro.pipeline.shard import ShardPlan


def run():
    # planner (AppDirect analog) vs "everything slow tier" (Optane-alone)
    # vs hardware-managed proxy (random placement), at the paper-scale
    # shapes the lightgcn-full preset declares
    spec = get_preset("lightgcn-full")
    profiles = gnn_recsys_profiles(
        spec.data.n_users, spec.data.n_items, spec.data.edges,
        spec.model.embed_dim, spec.model.n_layers)
    total = sum(p.nbytes for p in profiles)
    budget = int(total * 0.3)
    plan = plan_placement(profiles, hbm_budget=budget)
    slow_all = sum(_slow_tier_penalty(p) for p in profiles)
    emit("fig10/planner_step_penalty_s", 0.0,
         f"{plan.est_step_penalty_s:.4f} ({spec.name})")
    emit("fig10/slowtier_only_step_penalty_s", 0.0, f"{slow_all:.4f}")
    emit("fig10/planner_speedup_vs_slow_only", 0.0,
         f"{slow_all/max(plan.est_step_penalty_s, 1e-9):.2f}x "
         f"(paper: Optane+DRAM 1.3-1.5x over Optane-alone)")

    # blocked vs interleaved edge placement: fraction of edge traffic
    # that stays device-local, over the shard layer's block partition
    rng = np.random.default_rng(0)
    n, e, p = 4096, 200_000, 16
    src = rng.integers(0, n, e).astype(np.int64)
    dst = rng.integers(0, n, e).astype(np.int64)
    part = ShardPlan(shape=(p,), axes=("data",)).partition(n)
    per = part.n_local
    local_blocked = float(np.mean((src // per) == (dst // per)))
    local_interleaved = float(np.mean((src % p) == (dst % p)))
    emit("fig11/blocked_local_fraction", 0.0, f"{local_blocked:.4f}")
    emit("fig11/interleaved_local_fraction", 0.0, f"{local_interleaved:.4f}")
    # community-structured graph: blocked wins (paper's end-to-end choice)
    com = rng.integers(0, p, n)
    order = np.argsort(com, kind="stable")
    remap = np.empty(n, np.int64)
    remap[order] = np.arange(n)
    src2 = remap[src]
    dst2 = np.where(rng.random(e) < 0.8, remap[src], remap[dst])  # homophily
    local_blocked2 = float(np.mean((src2 // per) == (dst2 // per)))
    emit("fig11/blocked_local_fraction_community", 0.0,
         f"{local_blocked2:.3f} (blocked exploits community structure; "
         f"paper: blocked best for SDDMM + end-to-end)")
    return {}
