"""Paper Fig 10+11: tier configuration and placement policy.

Fig 10: AppDirect (explicit placement) vs Memory Mode (HW cache) and
Optane+DRAM vs Optane-alone -> our planner vs naive policies.
Fig 11: blocked vs interleaved NUMA placement -> edge-blocked vs
round-robin edge sharding cost over a ``ShardPlan`` node partition
(blocked placement keeps SDDMM writes local; paper picks blocked
end-to-end).

The planner arm's shapes come from the paper-scale ``lightgcn-full``
preset of the Experiment API (the m-x25 configuration the config
registry declares), not hand-typed sizes — every benchmark builds its
configuration through ``repro.api``.
"""
import numpy as np

from benchmarks.common import emit
from repro.api import get_preset
from repro.memory import get_policy, get_topology, gnn_recsys_profiles
from repro.pipeline.shard import ShardPlan


def run(topology: str = "tpu-hbm-host"):
    # placement policies by name (the repro.memory registry) at the
    # paper-scale shapes the lightgcn-full preset declares: greedy
    # planner (AppDirect analog) vs paper-recipe pins vs "everything
    # slow tier" (Optane-alone)
    spec = get_preset("lightgcn-full")
    topo = get_topology(topology)
    profiles = gnn_recsys_profiles(
        spec.data.n_users, spec.data.n_items, spec.data.edges,
        spec.model.embed_dim, spec.model.n_layers)
    total = sum(p.nbytes for p in profiles)
    budgets = {topo.fast.name: int(total * 0.3),
               topo.slow.name: topo.slow.capacity}
    plan = get_policy("greedy")(profiles, topo, budgets=budgets)
    recipe = get_policy("paper-recipe")(profiles, topo, budgets=budgets)
    slow_all = get_policy("all-slow")(profiles, topo).est_step_penalty_s
    emit("fig10/planner_step_penalty_s", 0.0,
         f"{plan.est_step_penalty_s:.4f} ({spec.name}, {topo.name})")
    emit("fig10/paper_recipe_step_penalty_s", 0.0,
         f"{recipe.est_step_penalty_s:.4f} (§6 pins, real pinned cost)")
    emit("fig10/slowtier_only_step_penalty_s", 0.0, f"{slow_all:.4f}")
    emit("fig10/planner_speedup_vs_slow_only", 0.0,
         f"{slow_all/max(plan.est_step_penalty_s, 1e-9):.2f}x "
         f"(paper: Optane+DRAM 1.3-1.5x over Optane-alone)")

    # blocked vs interleaved edge placement: fraction of edge traffic
    # that stays device-local, over the shard layer's block partition
    rng = np.random.default_rng(0)
    n, e, p = 4096, 200_000, 16
    src = rng.integers(0, n, e).astype(np.int64)
    dst = rng.integers(0, n, e).astype(np.int64)
    part = ShardPlan(shape=(p,), axes=("data",)).partition(n)
    per = part.n_local
    local_blocked = float(np.mean((src // per) == (dst // per)))
    local_interleaved = float(np.mean((src % p) == (dst % p)))
    emit("fig11/blocked_local_fraction", 0.0, f"{local_blocked:.4f}")
    emit("fig11/interleaved_local_fraction", 0.0, f"{local_interleaved:.4f}")
    # community-structured graph: blocked wins (paper's end-to-end choice)
    com = rng.integers(0, p, n)
    order = np.argsort(com, kind="stable")
    remap = np.empty(n, np.int64)
    remap[order] = np.arange(n)
    src2 = remap[src]
    dst2 = np.where(rng.random(e) < 0.8, remap[src], remap[dst])  # homophily
    local_blocked2 = float(np.mean((src2 // per) == (dst2 // per)))
    emit("fig11/blocked_local_fraction_community", 0.0,
         f"{local_blocked2:.3f} (blocked exploits community structure; "
         f"paper: blocked best for SDDMM + end-to-end)")
    return {}
