"""Shared benchmark helpers: timing, CSV emission, JSON artifacts,
standard test graphs."""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import bipartite_from_numpy
from repro.data import synth

ROWS: list[tuple] = []

BENCH_DIR = os.environ.get("BENCH_DIR", "results")
# canonical root-level artifacts: the cross-PR perf trajectory tracker
# reads BENCH_*.json from the repo root, not from results/
REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def emit(name: str, us_per_call: float, derived: str = ""):
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.1f},{derived}")


def _merge_json(path: str, section: str, payload: dict) -> None:
    data = {}
    if os.path.exists(path):
        with open(path) as f:
            data = json.load(f)
    data[section] = payload
    with open(path, "w") as f:
        json.dump(data, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"# wrote {path} [{section}]")


def write_bench_json(name: str, section: str, payload: dict) -> str:
    """Merge ``payload`` under ``section`` into ``BENCH_<name>.json`` —
    at the repo root (the canonical versioned artifact the cross-PR
    trajectory tracker reads) and mirrored under ``BENCH_DIR``
    (``results/``, kept for existing tooling/CI checks).

    Versioned perf artifacts (``BENCH_*.json``, see ROADMAP) accumulate
    sections from the modules that produce them, so two benchmarks can
    contribute to the same file without clobbering each other.
    """
    root_path = os.path.join(REPO_ROOT, f"BENCH_{name}.json")
    _merge_json(root_path, section, payload)
    mirror = os.path.join(BENCH_DIR, f"BENCH_{name}.json")
    if os.path.abspath(mirror) != root_path:
        os.makedirs(BENCH_DIR, exist_ok=True)
        _merge_json(mirror, section, payload)
    return root_path


def time_fn(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall time (us) of a jitted callable."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def bench_graph(name: str = "movielens-10m", edges: int = 20000, seed: int = 0):
    data = synth.scaled(name, edges, seed=seed)
    g = bipartite_from_numpy(data.user, data.item, data.n_users, data.n_items)
    return data, g
