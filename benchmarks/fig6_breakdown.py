"""Paper Fig 6: execution-time breakdown of optimized NGCF.

Paper: SDDMM+SpMM take 91% of inference / 75% of training time; the
elementwise `add` (weight update) ~17% of training.  We time the kernel
stages of one NGCF layer separately on the same graph.
"""
import jax
import jax.numpy as jnp

from benchmarks.common import bench_graph, emit, time_fn
from repro.core import ngcf, sparse_ops
from repro.core.message_passing import ngcf_propagate_bipartite


def run():
    data, g = bench_graph(edges=20000)
    d = 64
    params = ngcf.init_params(jax.random.PRNGKey(0), data.n_users,
                              data.n_items, d, 3)
    xu, xi = params["user_embed"], params["item_embed"]

    sddmm = jax.jit(lambda xu, xi: sparse_ops.sddmm(
        "mul", xu, xi, g.user, g.item, g.edge_mask))
    msg = sddmm(xu, xi)
    spmm = jax.jit(lambda m: sparse_ops.spmm("sum", m, g.item, g.n_items,
                                             g.edge_mask))
    matmul = jax.jit(lambda h, w: h @ w)
    h = spmm(msg)

    t_sddmm = time_fn(sddmm, xu, xi)
    t_spmm = time_fn(spmm, msg) * 2          # item + user side
    t_mm = time_fn(matmul, h, params["w1"][0]) * 4
    full = jax.jit(lambda p: ngcf_propagate_bipartite(
        g, p["user_embed"], p["item_embed"], p["w1"][0], p["w2"][0]))
    t_layer = time_fn(full, params)
    frac = (t_sddmm + t_spmm) / max(t_layer, 1e-9)
    emit("fig6/sddmm_us", t_sddmm)
    emit("fig6/spmm_us", t_spmm)
    emit("fig6/weight_matmul_us", t_mm)
    emit("fig6/full_layer_us", t_layer)
    emit("fig6/sparse_fraction", 0.0, f"{min(frac, 1.0)*100:.0f}% "
         f"(paper: 91% inference / 75% training)")
    return {"sparse_fraction": frac}
