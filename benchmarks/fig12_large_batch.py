"""Paper Fig 12 + §7.1: warm-up batch schedule for large-batch training.

Paper: batch 1K -> 150K with linear LR scaling + warm-up batch
(target/10 for 2 epochs) matches or beats small-batch recall@20; warm-up
too small (1K) hurts.  CPU-scaled: 64 -> 2048 with the same 10x/epoch
structure; we compare final recall@20 across schedules.

Every variant runs through the **unified pipeline** (repro.pipeline):
the tiered-memory plan, the LargeBatchSchedule, and real microbatched
gradient accumulation (microbatch=64, so the 2048-target variants
accumulate 32 microbatches per update) — this sweep exercises the same
engine the launcher uses, not a bespoke loop.
"""
import numpy as np

from benchmarks.common import emit
from repro.core import bpr
from repro.data import synth
from repro.pipeline import PipelineConfig, build_pipeline


def _recall(pipe, state, data, train, test):
    ue, ie = pipe.embeddings(state)
    test_pos = synth.group_by_user(test.user, test.item, data.n_users)
    # dense reference oracle, seen-mask via the O(E) user-CSR
    return bpr.recall_at_k(
        np.asarray(ue), np.asarray(ie),
        bpr.build_user_csr(train.user, train.item, data.n_users),
        test_pos, k=20)


def _train(cfg: PipelineConfig, data, train, test, epochs: int):
    pipe = build_pipeline(cfg, train)
    state = pipe.init_state()
    steps = pipe.steps_for_epochs(epochs)
    for s in range(steps):
        state, _ = pipe.step_fn(state, s)
    return _recall(pipe, state, data, train, test), pipe


def run(epochs: int = 6):
    data = synth.scaled("movielens-10m", 8000, seed=0)
    train, test = synth.train_test_split(data, 0.1)
    base = dict(arch="lightgcn", optimizer="sgd", base_lr=0.02,
                base_batch=64, microbatch=64, l2=1e-4)

    variants = {
        "small_batch64": PipelineConfig(**base, target_batch=64,
                                        warmup_epochs=0),
        "large_nowarmup": PipelineConfig(**base, target_batch=2048,
                                         warmup_epochs=0),
        "large_warmup_paper": PipelineConfig(**base, target_batch=2048,
                                             warmup_epochs=2),
        "large_sqrt_lr": PipelineConfig(**base, target_batch=2048,
                                        warmup_epochs=2, lr_scaling="sqrt"),
    }
    recalls = {}
    for name, cfg in variants.items():
        r, pipe = _train(cfg, data, train, test, epochs)
        recalls[name] = r
        # largest accumulation factor actually used across trained epochs
        accum = max(pipe.plan.microbatches_for_epoch(e)
                    for e in range(epochs))
        emit(f"fig12/recall20_{name}", 0.0, f"{r:.4f} (accum={accum}x)")
    ok = recalls["large_warmup_paper"] >= recalls["large_nowarmup"] - 0.01
    emit("fig12/warmup_matches_or_beats_nowarmup", 0.0, str(ok))
    return recalls
