"""Paper Fig 12 + §7.1: warm-up batch schedule for large-batch training.

Paper: batch 1K -> 150K with linear LR scaling + warm-up batch
(target/10 for 2 epochs) matches or beats small-batch recall@20; warm-up
too small (1K) hurts.  CPU-scaled: 64 -> 2048 with the same 10x/epoch
structure; we compare final recall@20 across schedules.

Every variant is one declarative ``ExperimentSpec`` run through the
unified Experiment API (``repro.api``): the tiered-memory plan, the
LargeBatchSchedule, and real microbatched gradient accumulation
(microbatch=64, so the 2048-target variants accumulate 32 microbatches
per update) — this sweep exercises the same engine the launcher uses,
not a bespoke loop.
"""
import numpy as np

from benchmarks.common import emit
from repro.api import DataCfg, ExperimentSpec, ModelCfg, PlanCfg, build, load_data
from repro.core import bpr
from repro.data import synth

DATA = DataCfg(source="synth", dataset="movielens-10m", edges=8000,
               test_frac=0.1, seed=0)


def _spec(name: str, **plan_kw) -> ExperimentSpec:
    plan_kw.setdefault("microbatch", 64)
    return ExperimentSpec(
        name=name, model=ModelCfg(arch="lightgcn", embed_dim=32, n_layers=2),
        data=DATA, plan=PlanCfg(base_batch=64, **plan_kw),
        optimizer="sgd", base_lr=0.02, l2=1e-4)


def _recall(run, train, test):
    ue, ie = run.embeddings()
    test_pos = synth.group_by_user(test.user, test.item, train.n_users)
    # dense reference oracle, seen-mask via the O(E) user-CSR
    return bpr.recall_at_k(
        np.asarray(ue), np.asarray(ie),
        bpr.build_user_csr(train.user, train.item, train.n_users),
        test_pos, k=20)


def run(epochs: int = 6, mesh: str | None = None):
    """``mesh`` ('4', '2x2', ...) adds a mesh-sharded replica of the
    paper-recipe variant: same global batch (per-shard microbatch =
    64/P), ring-dispatched SpMM, dp-sharded accumulation — its recall
    should match the unsharded paper variant to fp32 noise."""
    train, test = load_data(DATA)     # one graph shared across variants
    variants = {
        "small_batch64": _spec("small_batch64", target_batch=64,
                               warmup_epochs=0),
        "large_nowarmup": _spec("large_nowarmup", target_batch=2048,
                                warmup_epochs=0),
        "large_warmup_paper": _spec("large_warmup_paper", target_batch=2048,
                                    warmup_epochs=2),
        "large_sqrt_lr": _spec("large_sqrt_lr", target_batch=2048,
                               warmup_epochs=2, lr_scaling="sqrt"),
    }
    if mesh is not None:
        from repro.pipeline.shard import parse_mesh
        shape = parse_mesh(mesh)
        p = int(np.prod(shape))
        variants["large_warmup_sharded"] = _spec(
            "large_warmup_sharded", target_batch=2048, warmup_epochs=2,
            microbatch=max(64 // p, 1)).override({
                "mesh.shape": shape, "mesh.spmm": "ring"})
    recalls = {}
    for name, spec in variants.items():
        r = build(spec, train=train)
        r.fit(steps=r.steps_for_epochs(epochs))
        recalls[name] = _recall(r, train, test)
        # largest accumulation factor actually used across trained epochs
        accum = max(r.pipeline.plan.microbatches_for_epoch(e)
                    for e in range(epochs))
        emit(f"fig12/recall20_{name}", 0.0,
             f"{recalls[name]:.4f} (accum={accum}x)")
    ok = recalls["large_warmup_paper"] >= recalls["large_nowarmup"] - 0.01
    emit("fig12/warmup_matches_or_beats_nowarmup", 0.0, str(ok))
    return recalls
