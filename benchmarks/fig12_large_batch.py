"""Paper Fig 12 + §7.1: warm-up batch schedule for large-batch training.

Paper: batch 1K -> 150K with linear LR scaling + warm-up batch
(target/10 for 2 epochs) matches or beats small-batch recall@20; warm-up
too small (1K) hurts.  CPU-scaled: 64 -> 2048 with the same 10x/epoch
structure; we compare final recall@20 across schedules.
"""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_graph, emit
from repro.core import bpr, lightgcn
from repro.core.large_batch import LargeBatchSchedule
from repro.data import synth


def _train(data, g, schedule_batches, lr_for_batch, epochs, train, test,
           embed=32, layers=2, seed=0):
    params = lightgcn.init_params(jax.random.PRNGKey(seed), data.n_users,
                                  data.n_items, embed)
    rng = np.random.default_rng(seed)

    @jax.jit
    def step(params, lr, u, i, n):
        def loss_fn(p):
            ue, ie = lightgcn.forward(p, g, n_layers=layers)
            return bpr.bpr_loss(ue, ie, u, i, n)
        loss, grads = jax.value_and_grad(loss_fn)(params)
        return jax.tree.map(lambda p, gr: p - lr * gr, params, grads), loss

    for epoch in range(epochs):
        batch = schedule_batches(epoch)
        lr = lr_for_batch(batch)
        steps = max(len(train.user) // batch, 1)
        for _ in range(steps):
            u, i, n = bpr.sample_bpr_batch(rng, train.user, train.item,
                                           data.n_items, batch)
            params, loss = step(params, lr, jnp.asarray(u), jnp.asarray(i),
                                jnp.asarray(n))
    ue, ie = lightgcn.forward(params, g, n_layers=layers)
    train_mask = np.zeros((data.n_users, data.n_items), bool)
    train_mask[train.user, train.item] = True
    test_pos = [np.zeros(0, np.int64)] * data.n_users
    by_u = {}
    for u, i in zip(test.user, test.item):
        by_u.setdefault(u, []).append(i)
    for u, items in by_u.items():
        test_pos[u] = np.asarray(items)
    return bpr.recall_at_k(np.asarray(ue), np.asarray(ie), train_mask,
                           test_pos, k=20)


def run(epochs: int = 6):
    data, g = bench_graph(edges=8000)
    train, test = synth.train_test_split(data, 0.1)
    sched = LargeBatchSchedule(base_lr=0.02, base_batch=64,
                               target_batch=2048, warmup_epochs=2)

    recalls = {}
    variants = {
        "small_batch64": (lambda e: 64, lambda b: 0.02),
        "large_nowarmup": (lambda e: 2048, sched.linear_scaled_lr),
        "large_warmup_paper": (sched.batch_for_epoch, sched.linear_scaled_lr),
        "large_sqrt_lr": (sched.batch_for_epoch, sched.sqrt_scaled_lr),
    }
    for name, (bs, lr) in variants.items():
        r = _train(data, g, bs, lr, epochs, train, test)
        recalls[name] = r
        emit(f"fig12/recall20_{name}", 0.0, f"{r:.4f}")
    ok = recalls["large_warmup_paper"] >= recalls["large_nowarmup"] - 0.01
    emit("fig12/warmup_matches_or_beats_nowarmup", 0.0, str(ok))
    return recalls
