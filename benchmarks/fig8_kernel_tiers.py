"""Paper Fig 8+9: SDDMM/SpMM kernel behaviour across tiers and write
policies.

Paper findings re-expressed on TPU terms:
  (1) SDDMM is write-bound (7.7x slower on the slow tier, normal write);
      SpMM is read-bound (2.2-3.0x).  -> planner cost model per kernel.
  (2) nt-write helps SDDMM (1.4x) and destroys SpMM (>20x).  -> our
      Pallas kernels bake the policy in (streaming vs VMEM-accumulate);
      here we check the structural invariant on the kernels and report
      the modelled tier penalty per kernel.
  (3) density raises SpMM locality (m-x25 fastest).  -> measured.
"""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.core import tiered_memory as tm
from repro.core.tiered_memory import AccessProfile, _slow_tier_penalty
from repro.kernels.ops import WRITE_POLICY


def run():
    d = 64
    # (1) modelled tier penalty per kernel (per GB of working set)
    sddmm_prof = AccessProfile("sddmm_out", 1 << 30, reads_per_step=1,
                               writes_per_step=2, access_size=d * 4)
    spmm_prof = AccessProfile("spmm_in", 1 << 30, reads_per_step=3,
                              writes_per_step=0.3, access_size=d * 4)
    p_sddmm = _slow_tier_penalty(sddmm_prof)
    p_spmm = _slow_tier_penalty(spmm_prof)
    emit("fig8/sddmm_slowtier_penalty_s_perGB", 0.0, f"{p_sddmm:.3f}")
    emit("fig8/spmm_slowtier_penalty_s_perGB", 0.0, f"{p_spmm:.3f}")
    emit("fig8/sddmm_over_spmm_penalty", 0.0,
         f"{p_sddmm/p_spmm:.2f}x (paper: SDDMM 7.7x vs SpMM 2.2-3.0x slowdown)")

    # (2) write-policy table (the §6 guideline, baked into kernels/)
    for k, v in WRITE_POLICY.items():
        emit(f"fig8/write_policy_{k}", 0.0, v)

    # (3) density -> SpMM locality (same |E|, varying density; paper Fig 8
    # bottom: m-x25 densest = fastest)
    from repro.core import sparse_ops
    e = 30000
    for name, nu, ni in [("dense_m", 400, 300), ("sparse_g", 4000, 3000)]:
        rng = np.random.default_rng(0)
        src = rng.integers(0, nu, e).astype(np.int32)
        dst = rng.integers(0, ni, e).astype(np.int32)
        msg = jnp.asarray(rng.standard_normal((e, d)).astype(np.float32))
        mask = jnp.ones(e, bool)
        fn = jax.jit(lambda m, dst=jnp.asarray(dst), ni=ni:
                     sparse_ops.spmm("sum", m, dst, ni, mask))
        t = time_fn(fn, msg)
        emit(f"fig8/spmm_{name}_us", t, f"density={e/(nu*ni):.4f}")
    return {"sddmm_penalty_ratio": p_sddmm / p_spmm}
