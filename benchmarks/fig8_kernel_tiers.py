"""Paper Fig 8+9: SDDMM/SpMM kernel behaviour across tiers and write
policies.

Paper findings re-expressed through the ``repro.memory`` cost model:
  (1) SDDMM is write-bound (7.7x slower on the slow tier, normal write);
      SpMM is read-bound (2.2-3.0x).  -> per-kernel demotion penalty
      from ``TierTopology.demotion_penalty``, on any registered preset
      (``--topology``); the AppDirect-vs-MemoryMode spread is the §5
      ordering per kernel.
  (2) nt-write helps SDDMM (1.4x) and destroys SpMM (>20x).  -> our
      Pallas kernels bake the policy in (streaming vs VMEM-accumulate);
      the live table is emitted FROM the placement plan
      (``Plan.write_policy()``), not hardcoded in kernels/ops.py.
  (3) density raises SpMM locality (m-x25 fastest).  -> measured.
"""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.memory import (AccessProfile, get_policy, get_topology,
                          gnn_recsys_profiles)


def run(topology: str = "tpu-hbm-host"):
    d = 64
    # (1) modelled tier penalty per kernel (per GB of working set)
    sddmm_prof = AccessProfile("sddmm_out", 1 << 30, reads_per_step=1,
                               writes_per_step=2, access_size=d * 4)
    spmm_prof = AccessProfile("spmm_in", 1 << 30, reads_per_step=3,
                              writes_per_step=0.3, access_size=d * 4)
    topo = get_topology(topology)
    p_sddmm = topo.demotion_penalty(sddmm_prof)
    p_spmm = topo.demotion_penalty(spmm_prof)
    emit(f"fig8/{topo.name}/sddmm_slowtier_penalty_s_perGB", 0.0,
         f"{p_sddmm:.3f}")
    emit(f"fig8/{topo.name}/spmm_slowtier_penalty_s_perGB", 0.0,
         f"{p_spmm:.3f}")
    emit(f"fig8/{topo.name}/sddmm_over_spmm_penalty", 0.0,
         f"{p_sddmm/p_spmm:.2f}x (paper: SDDMM 7.7x vs SpMM 2.2-3.0x "
         "slowdown)")
    # the same kernels across the paper's two Optane configurations —
    # AppDirect must beat Memory Mode per byte, for BOTH the write-bound
    # SDDMM and the read-bound SpMM (the §5 ordering, per kernel)
    for preset in ("dram-optane-appdirect", "dram-optane-memorymode"):
        t = get_topology(preset)
        emit(f"fig8/{preset}/sddmm_penalty_s_perGB", 0.0,
             f"{t.demotion_penalty(sddmm_prof):.3f}")
        emit(f"fig8/{preset}/spmm_penalty_s_perGB", 0.0,
             f"{t.demotion_penalty(spmm_prof):.3f}")

    # (2) write-policy table, emitted from a real placement plan (§6);
    # the fused-Hadamard arm has no messages_l* rows to police — the
    # [E, D] stream the nt-write policy existed for is gone
    for arm, fused in (("", False), ("fused/", True)):
        plan = get_policy("paper-recipe")(
            gnn_recsys_profiles(349_000, 53_000, 250_000, 128, 2,
                                fused_messages=fused), topo)
        for k, v in sorted(plan.write_policy().items()):
            emit(f"fig8/write_policy_{arm}{k}", 0.0, f"{v} (plan-emitted, "
                 f"topology={topo.name})")

    # (3) density -> SpMM locality (same |E|, varying density; paper Fig 8
    # bottom: m-x25 densest = fastest)
    from repro.core import sparse_ops
    e = 30000
    for name, nu, ni in [("dense_m", 400, 300), ("sparse_g", 4000, 3000)]:
        rng = np.random.default_rng(0)
        src = rng.integers(0, nu, e).astype(np.int32)
        dst = rng.integers(0, ni, e).astype(np.int32)
        msg = jnp.asarray(rng.standard_normal((e, d)).astype(np.float32))
        mask = jnp.ones(e, bool)
        fn = jax.jit(lambda m, dst=jnp.asarray(dst), ni=ni:
                     sparse_ops.spmm("sum", m, dst, ni, mask))
        t = time_fn(fn, msg)
        emit(f"fig8/spmm_{name}_us", t, f"density={e/(nu*ni):.4f}")
    return {"sddmm_penalty_ratio": p_sddmm / p_spmm}
