"""Paper Table 5: maximum batch size allowed by memory capacity.

Paper: DistDGL max aggregate batch collapses exponentially with depth
(24K @1L-128E -> 384 @2L -> OOM @3L without sampling), while full-graph
training is depth-linear.  We evaluate the same analytic memory model for
the paper's cluster (2304 GB) and for TPU meshes, plus the full-graph
footprint from the planner profiles (paper §2.1 ~500 GB check is in
tests/test_core.py).
"""
from benchmarks.common import emit
from repro.api import get_preset
from repro.dist.subgraph import max_subgraph_batch
from repro.memory import get_topology, gnn_recsys_profiles


def run():
    mem = 2304e9  # paper cluster DRAM
    avg_degree = 566  # m-x25: 250M edges / 441K vertices
    for layers in (1, 2, 3):
        for embed in (128, 256):
            no_samp = max_subgraph_batch(1.0, embed, layers, mem, None,
                                         avg_degree)
            samp = max_subgraph_batch(1.0, embed, layers, mem, 100,
                                      avg_degree)
            emit(f"table5/subgraph_maxbatch_{layers}L_{embed}E", 0.0,
                 f"nosamp={no_samp} samp100={samp}")
    # full-graph footprint is depth-LINEAR (the paper's §2.1 model); the
    # shapes come from the paper-scale lightgcn-full preset, the depth
    # axis is swept
    full = get_preset("lightgcn-full")
    for layers in (1, 2, 3):
        prof = gnn_recsys_profiles(full.data.n_users, full.data.n_items,
                                   full.data.edges, full.model.embed_dim,
                                   layers)
        gb = sum(p.nbytes for p in prof) / 1e9
        emit(f"table5/fullgraph_footprint_{layers}L_"
             f"{full.model.embed_dim}E_GB", 0.0, f"{gb:.0f}")
    # NGCF's depth-linear term is dominated by the per-layer [E, D]
    # message stream — the fused hadamard_spmm route removes it, so the
    # fused footprint is what actually competes for capacity
    ngcf = get_preset("ngcf-full")
    for layers in (1, 2, 3):
        byts = {fused: sum(p.nbytes for p in gnn_recsys_profiles(
            ngcf.data.n_users, ngcf.data.n_items, ngcf.data.edges,
            ngcf.model.embed_dim, layers, fused_messages=fused))
            for fused in (False, True)}
        emit(f"table5/ngcf_footprint_{layers}L_{ngcf.model.embed_dim}E_GB",
             0.0, f"composed={byts[False]/1e9:.0f} fused={byts[True]/1e9:.0f} "
             f"(msg stream {100*(1-byts[True]/byts[False]):.0f}% of total)")
    # TPU pod capacity: 256 chips x the registered preset's fast tier,
    # plus its host tier
    topo = get_topology("tpu-hbm-host")
    emit("table5/tpu_pod_hbm_GB", 0.0,
         f"{256 * topo.fast.capacity // 2**30}")
    emit("table5/note", 0.0,
         "full-graph m-x25 3L fits one pod's aggregate HBM; subgraph "
         "training without sampling cannot run 3L at ANY batch (paper '/')")
    return {}
