"""§Roofline: aggregate the dry-run records into the per-cell table.

Reads results/dryrun/*.json (produced by `python -m repro.launch.dryrun
--all`) and prints the three roofline terms, dominant bottleneck, MFU at
the roofline bound, and the model-FLOPs/HLO-FLOPs useful ratio.

Each cell also gets compressed-collective arms: the collective term
rescaled by ``CompressionCfg.grads`` wire pricing (int8: ~1/4 bytes,
topk: ~2*frac bytes), with the re-derived bottleneck and bound-MFU,
recorded to ``results/BENCH_compression.json``.

NGCF cells additionally get a ``@fused-hadamard`` arm: their analytic
HBM model (launch/cells.py) carries the per-layer [E, D] message-stream
bytes as an explicit ``hadamard_msg_hbm_bytes`` meta term, and the
fused hadamard_spmm route (kernels/hadamard_spmm.py) removes exactly
that term — the arm re-derives memory_s/bottleneck with it subtracted.
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit, write_bench_json
from repro.optim.compression import wire_bytes

DRYRUN_DIR = os.environ.get("DRYRUN_DIR", "results/dryrun")


def load_records(d: str = DRYRUN_DIR):
    recs = []
    for path in sorted(glob.glob(os.path.join(d, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def run():
    recs = load_records()
    if not recs:
        emit("roofline/no_dryrun_records", 0.0,
             "run: python -m repro.launch.dryrun --all")
        return {}
    rows = {}
    for r in recs:
        roof = r["roofline"]
        cell = f"{r['arch']}/{r['shape']}/{r['mesh']}"
        bound_s = max(roof["compute_s"], roof["memory_s"],
                      roof["collective_s"])
        mfu_at_bound = (roof["model_flops"]
                        / (bound_s * r["chips"] * 197e12 + 1e-30))
        rows[cell] = (roof, mfu_at_bound, r)
        emit(f"roofline/{cell}", 0.0,
             f"c={roof['compute_s']:.4f}s m={roof['memory_s']:.4f}s "
             f"coll={roof['collective_s']:.4f}s bound={roof['bottleneck']} "
             f"mfu_bound={mfu_at_bound*100:.1f}% "
             f"fits={r['fits_hbm']} peak={r['peak_bytes_per_dev']/2**30:.2f}GiB")
    n_fit = sum(1 for _, _, r in rows.values() if r["fits_hbm"])
    emit("roofline/cells_total", 0.0, str(len(rows)))
    emit("roofline/cells_fit_hbm", 0.0, str(n_fit))

    # compressed-collective arms: the gradient exchange moves
    # wire_bytes(n)/4n of the fp32 bytes, the other two terms stand
    wire = {s: wire_bytes(10 ** 6, s) / (4 * 10 ** 6)
            for s in ("int8", "topk")}
    comp_cells = {}
    for cell, (roof, _, r) in rows.items():
        arms = {"none": {"collective_s": roof["collective_s"],
                         "bound_s": max(roof["compute_s"],
                                        roof["memory_s"],
                                        roof["collective_s"]),
                         "bottleneck": roof["bottleneck"]}}
        for scheme, ratio in wire.items():
            coll = roof["collective_s"] * ratio
            bound_s = max(roof["compute_s"], roof["memory_s"], coll)
            bottleneck = max(
                [("compute", roof["compute_s"]),
                 ("memory", roof["memory_s"]), ("collective", coll)],
                key=lambda kv: kv[1])[0]
            mfu = roof["model_flops"] / (bound_s * r["chips"] * 197e12
                                         + 1e-30)
            arms[scheme] = {"collective_s": coll, "bound_s": bound_s,
                            "bottleneck": bottleneck}
            emit(f"roofline/{cell}@{scheme}", 0.0,
                 f"coll={coll:.4f}s bound={bottleneck} "
                 f"mfu_bound={mfu*100:.1f}% (wire x{ratio:.3f})")
        comp_cells[cell] = arms

    # fused-Hadamard arms: NGCF's [E, D] message bytes drop out of the
    # memory term when the fused hadamard_spmm route is active
    for cell, (roof, _, r) in rows.items():
        msg = r.get("meta", {}).get("hadamard_msg_hbm_bytes")
        hbm = (r.get("analytic") or {}).get("hbm_bytes")
        if not msg or not hbm or roof["memory_s"] <= 0:
            continue
        mem = roof["memory_s"] * max(hbm - msg, 0.0) / hbm
        bound_s = max(roof["compute_s"], mem, roof["collective_s"])
        bottleneck = max(
            [("compute", roof["compute_s"]), ("memory", mem),
             ("collective", roof["collective_s"])], key=lambda kv: kv[1])[0]
        mfu = roof["model_flops"] / (bound_s * r["chips"] * 197e12 + 1e-30)
        emit(f"roofline/{cell}@fused-hadamard", 0.0,
             f"m={mem:.4f}s (was {roof['memory_s']:.4f}s) "
             f"bound={bottleneck} mfu_bound={mfu*100:.1f}% "
             f"(msg_bytes {msg/1e9:.1f}GB of {hbm/1e9:.1f}GB dropped)")

    write_bench_json("compression", "roofline_wire", {
        "wire_byte_ratio": wire, "cells": comp_cells})
    return rows
