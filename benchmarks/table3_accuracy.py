"""Paper Table 3: recall@20 across model variants.

Paper (amazon-book): NGCF > LightGCN at equal size; recall improves with
layers (1->3) and embedding width (128->256).  CPU-scaled: amazon-book
statistics at 8K edges, dims {16, 32}, layers {1, 2, 3}, short training;
we verify the two monotone trends + the NGCF>=LightGCN ordering.

Every cell of the table is one declarative ``ExperimentSpec`` run
through the unified Experiment API (``repro.api``), and evaluation runs
through the **streaming top-K path** (``repro.eval``): users scored in
microbatches against item blocks with the train items masked via the
O(E) user-CSR — peak eval memory is O(batch × (K + block)), never the
dense U×I matrix the old ``recall_at_k`` oracle allocates.
"""
from benchmarks.common import emit
from repro.api import (DataCfg, EvalCfg, ExperimentSpec, ModelCfg, PlanCfg,
                       build, load_data)

DATA = DataCfg(source="synth", dataset="amazon-book", edges=8000,
               test_frac=0.1, seed=1)


def _recall(train, test, model, embed, layers, epochs=5):
    spec = ExperimentSpec(
        name=f"table3-{model}-{layers}L-{embed}E",
        model=ModelCfg(arch=model, embed_dim=embed, n_layers=layers),
        data=DATA,
        plan=PlanCfg(base_batch=256, target_batch=256, microbatch=256,
                     warmup_epochs=0),
        eval=EvalCfg(k=20, user_batch=256, item_block=512),
        optimizer="sgd", base_lr=0.02)
    r = build(spec, train=train, holdout=test)
    r.fit(steps=r.steps_for_epochs(epochs))
    return r.evaluate()["recall@20"]


def run(epochs: int = 5):
    train, test = load_data(DATA)     # one graph shared across the table
    table = {}
    for model in ("ngcf", "lightgcn"):
        for embed in (16, 32):
            for layers in (1, 2, 3):
                r = _recall(train, test, model, embed, layers, epochs=epochs)
                table[(model, embed, layers)] = r
                emit(f"table3/{model}_{layers}L_{embed}E_recall20", 0.0,
                     f"{r:.4f}")
    # paper trends
    deeper = sum(table[(m, e, 3)] >= table[(m, e, 1)] - 0.005
                 for m in ("ngcf", "lightgcn") for e in (16, 32))
    wider = sum(table[(m, 32, l)] >= table[(m, 16, l)] - 0.005
                for m in ("ngcf", "lightgcn") for l in (1, 2, 3))
    emit("table3/deeper_helps (4 pairs)", 0.0, f"{deeper}/4")
    emit("table3/wider_helps (6 pairs)", 0.0, f"{wider}/6")
    return table
