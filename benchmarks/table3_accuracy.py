"""Paper Table 3: recall@20 across model variants.

Paper (amazon-book): NGCF > LightGCN at equal size; recall improves with
layers (1->3) and embedding width (128->256).  CPU-scaled: amazon-book
statistics at 8K edges, dims {16, 32}, layers {1, 2, 3}, short training;
we verify the two monotone trends + the NGCF>=LightGCN ordering.

Evaluation runs through the **streaming top-K path** (``repro.eval``):
users scored in microbatches against item blocks with the train items
masked via the O(E) user-CSR — peak eval memory is O(batch × (K +
block)), never the dense U×I matrix the old ``recall_at_k`` oracle
allocates.
"""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import bpr, lightgcn, ngcf
from repro.core.graph import bipartite_from_numpy
from repro.data import synth
from repro.eval import evaluate_embeddings


def _recall(model, data, g, train, test, embed, layers, epochs=5, lr=0.02,
            batch=256, seed=0):
    key = jax.random.PRNGKey(seed)
    if model == "ngcf":
        params = ngcf.init_params(key, data.n_users, data.n_items, embed,
                                  layers)
        fwd = lambda p: ngcf.forward(p, g)
    else:
        params = lightgcn.init_params(key, data.n_users, data.n_items, embed)
        fwd = lambda p: lightgcn.forward(p, g, n_layers=layers)
    rng = np.random.default_rng(seed)

    @jax.jit
    def step(params, u, i, n):
        loss, grads = jax.value_and_grad(
            lambda p: bpr.bpr_loss(*fwd(p), u, i, n))(params)
        return jax.tree.map(lambda p, gr: p - lr * gr, params, grads), loss

    steps = max(len(train.user) // batch, 1) * epochs
    for _ in range(steps):
        u, i, n = bpr.sample_bpr_batch(rng, train.user, train.item,
                                       data.n_items, batch)
        params, _ = step(params, jnp.asarray(u), jnp.asarray(i),
                         jnp.asarray(n))
    ue, ie = fwd(params)
    indptr, items = bpr.build_user_csr(train.user, train.item, data.n_users)
    test_pos = synth.group_by_user(test.user, test.item, data.n_users)
    m = evaluate_embeddings(ue, ie, test_pos, k=20, seen_indptr=indptr,
                            seen_items=items, user_batch=256, item_block=512)
    return m["recall@20"]


def run(epochs: int = 5):
    data = synth.scaled("amazon-book", 8000, seed=1)
    train, test = synth.train_test_split(data, 0.1)
    g = bipartite_from_numpy(train.user, train.item, data.n_users,
                             data.n_items)
    table = {}
    for model in ("ngcf", "lightgcn"):
        for embed in (16, 32):
            for layers in (1, 2, 3):
                r = _recall(model, data, g, train, test, embed, layers,
                            epochs=epochs)
                table[(model, embed, layers)] = r
                emit(f"table3/{model}_{layers}L_{embed}E_recall20", 0.0,
                     f"{r:.4f}")
    # paper trends
    deeper = sum(table[(m, e, 3)] >= table[(m, e, 1)] - 0.005
                 for m in ("ngcf", "lightgcn") for e in (16, 32))
    wider = sum(table[(m, 32, l)] >= table[(m, 16, l)] - 0.005
                for m in ("ngcf", "lightgcn") for l in (1, 2, 3))
    emit("table3/deeper_helps (4 pairs)", 0.0, f"{deeper}/4")
    emit("table3/wider_helps (6 pairs)", 0.0, f"{wider}/6")
    return table
