"""Paper Table 4 + Fig 13: recall degradation due to neighbour sampling.

Paper: sampling factor 10 costs NGCF-3L-256E -0.006 recall@20 (worse
than an unsampled 2-layer model); even factor 100 costs -0.001, because
power-law high-degree vertices lose the most information.  We train
LightGCN on a sampled graph (edges subsampled per-vertex to a fanout cap)
vs the full graph and report the same degradation trend + the degree
distribution stats of Fig 13.
"""
import numpy as np

from benchmarks.common import emit
from repro.core.graph import bipartite_from_numpy
from repro.data import synth
from benchmarks.table3_accuracy import _recall


def _sample_edges(user, item, fanout, seed=0):
    """Cap each user's degree at `fanout` (vertex-wise sampling)."""
    rng = np.random.default_rng(seed)
    keep = np.zeros(len(user), bool)
    order = rng.permutation(len(user))
    count = {}
    for idx in order:
        u = user[idx]
        if count.get(u, 0) < fanout:
            keep[idx] = True
            count[u] = count.get(u, 0) + 1
    return user[keep], item[keep]


def run(epochs: int = 5):
    data = synth.scaled("amazon-book", 8000, seed=1)
    train, test = synth.train_test_split(data, 0.1)

    # Fig 13: power-law degree stats
    deg = np.bincount(train.item, minlength=data.n_items)
    top1 = np.sort(deg)[-max(data.n_items // 100, 1):].sum() / max(deg.sum(), 1)
    emit("fig13/top1pct_items_edge_share", 0.0, f"{top1*100:.1f}%")

    g_full = bipartite_from_numpy(train.user, train.item, data.n_users,
                                  data.n_items)
    base = _recall("lightgcn", data, g_full, train, test, 32, 3,
                   epochs=epochs)
    emit("table4/recall20_full", 0.0, f"{base:.4f}")
    rows = {}
    for fanout in (2, 5, 10):
        su, si = _sample_edges(train.user, train.item, fanout)
        g_s = bipartite_from_numpy(su, si, data.n_users, data.n_items)

        class T:  # sampled training edges
            user, item = su, si
        r = _recall("lightgcn", data, g_s, T, test, 32, 3, epochs=epochs)
        rows[fanout] = base - r
        emit(f"table4/degradation_fanout{fanout}", 0.0, f"{base - r:+.4f}")
    mono = rows[2] >= rows[10] - 0.01
    emit("table4/smaller_fanout_degrades_more", 0.0, str(mono))
    return rows
