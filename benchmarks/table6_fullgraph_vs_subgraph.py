"""Paper Table 6 + Fig 14: single-machine full-graph vs distributed
subgraph training.

Paper: full-graph time grows ~linearly with depth; DistDGL grows
exponentially (1L: 0.07-1.4x of full-graph; 2L w/o sampling: 43-356x
slower; 3L even WITH sampling: 32-85x slower).  We run both paths on the
same CPU-scaled graph (LightGCN) and measure time per batch, plus the
Fig 14 breakdown (subgraph build share).

The full-graph arm is one ``ExperimentSpec`` per depth, built through
the unified Experiment API (``repro.api``) — the accumulated-microbatch
step (kernel-routed CSR aggregation + planner-derived placement) is the
engine the launcher actually runs.  A third arm runs the SAME
full-graph spec sharded over the visible device mesh (``MeshCfg`` ->
ring-dispatched SpMM, dp-sharded batch, psum'd grads): the paper's
winning side of the comparison, scaled out — vs the ``dist.subgraph``
DistDGL baseline.  With one visible device the mesh degenerates to a
1-device ring (dispatch overhead only); run under
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` for a real
mesh.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.api import DataCfg, ExperimentSpec, ModelCfg, PlanCfg, build
from repro.dist.subgraph import SubgraphTrainer

# full graph trains (no held-out split: this sweep measures step time)
DATA = DataCfg(source="synth", dataset="movielens-10m", edges=12000,
               test_frac=0.0, seed=0)


def _mesh_width() -> int:
    """Largest power-of-two device count <= min(4, visible devices)."""
    n = min(4, jax.local_device_count())
    return 1 << (n.bit_length() - 1)


def run():
    rng = np.random.default_rng(0)
    p = _mesh_width()

    results = {}
    for layers in (1, 2, 3):
        # full-graph pipeline step (512-sample batch, 256 microbatch ->
        # real 2x gradient accumulation per measured step)
        spec = ExperimentSpec(
            name=f"table6-{layers}L",
            model=ModelCfg(arch="lightgcn", n_layers=layers),
            data=DATA,
            plan=PlanCfg(base_batch=512, target_batch=512, microbatch=256,
                         warmup_epochs=0))
        r = build(spec)
        data = r.train_data
        r.step()                                   # warmup/compile
        t0 = time.perf_counter()
        r.step()
        t_full = time.perf_counter() - t0
        x_all = jnp.concatenate([r.params["user_embed"],
                                 r.params["item_embed"]])

        # sharded full-graph arm: same spec + a mesh (same global batch:
        # per-shard microbatch = 256 / P), ring SpMM + psum'd grads
        rs = build(spec.override({
            "name": f"table6-{layers}L-sharded",
            "mesh.shape": (p,), "mesh.spmm": "ring",
            "plan.microbatch": max(256 // p, 1)}))
        rs.step()                                  # warmup/compile
        t0 = time.perf_counter()
        rs.step()
        t_shard = time.perf_counter() - t0
        emit(f"table6/fullgraph_sharded_{layers}L_ms", t_shard * 1e3,
             f"mesh={p} ring")

        # subgraph step (DistDGL-like, 2 simulated workers)
        src = np.concatenate([data.user, data.item + data.n_users])
        dst = np.concatenate([data.item + data.n_users, data.user])
        tr = SubgraphTrainer(src, dst, data.n_users + data.n_items,
                             n_layers=layers, fanout=10, n_workers=2)
        seeds = rng.integers(0, data.n_users, 512).astype(np.int32)

        def loss_fn(emb, seed_ids):
            return jnp.mean(emb ** 2)

        tr.step(seeds, x_all, loss_fn, record=False)   # warmup/compile
        _, stats = tr.step(seeds, x_all, loss_fn)
        t_sub = stats.sample_s + stats.forward_s + stats.backward_s
        results[layers] = (t_full, t_sub, stats)
        emit(f"table6/fullgraph_{layers}L_ms", t_full * 1e3)
        emit(f"table6/subgraph_{layers}L_ms", t_sub * 1e3,
             f"sample={stats.sample_s*1e3:.0f}ms "
             f"expanded={stats.expanded_vertices}")
        emit(f"table6/speedup_{layers}L", 0.0, f"{t_sub/t_full:.2f}x")
        emit(f"table6/speedup_sharded_{layers}L", 0.0,
             f"{t_sub/t_shard:.2f}x (mesh={p})")

    # paper's scaling claims
    full_growth = results[3][0] / results[1][0]
    sub_growth = results[3][1] / results[1][1]
    emit("table6/fullgraph_depth_growth_1to3L", 0.0, f"{full_growth:.1f}x "
         "(paper: ~linear, ~2.9x)")
    emit("table6/subgraph_depth_growth_1to3L", 0.0, f"{sub_growth:.1f}x "
         "(paper: exponential)")
    # Fig 14: build share of subgraph step
    s = results[3][2]
    share = s.sample_s / (s.sample_s + s.forward_s + s.backward_s)
    emit("fig14/subgraph_build_share_3L", 0.0, f"{share*100:.0f}% "
         "(paper: 16-32%)")
    # redundancy across batches (paper Fig 2): a second REAL seed batch
    # on the 3L trainer, overlapping the first by sampling the same
    # user range — not the warm-up replay of the same seeds
    seeds2 = rng.integers(0, data.n_users, 512).astype(np.int32)
    tr.step(seeds2, x_all, lambda e, s: jnp.mean(e ** 2))
    emit("fig14/subgraph_redundancy", 0.0, f"{tr.redundancy():.2f}x")
    return results
