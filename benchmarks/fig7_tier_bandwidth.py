"""Paper Fig 7: tier bandwidth characterization.

The paper measures Optane vs DRAM (read 37%, write 7%, nt-write 18% of
DRAM; random-access utilization saturating at 256 B writes / >4 KB
reads).  Our tiers are declarative ``repro.memory.TierTopology``
presets — this benchmark prints the cost model any registered preset
feeds the placement policies (these numbers ARE the planner's inputs),
plus a measured CPU-cache proxy for the access-size effect.

``--topology`` selects the preset (default ``tpu-hbm-host``); run
``python -m benchmarks.run --only fig7 --topology dram-optane-appdirect``
or this module directly.

``--compression int8`` adds the quantized-storage arm: the slow-tier
byte terms rescaled by ``CompressionCfg.embed_store="int8"`` pricing
(per-row int8 + fp32 scale, ~4x capacity / ~4x effective gather
bandwidth) plus a measured exact-vs-int8-vs-topk smoke train-step
timing, all recorded to ``results/BENCH_compression.json``.
"""
import time

import numpy as np

from benchmarks.common import emit, write_bench_json
from repro.memory import get_topology


def run(topology: str = "tpu-hbm-host", compression: str = "none"):
    topo = get_topology(topology)
    fast, slow = topo.fast, topo.slow
    for t in topo.tiers:
        emit(f"fig7/{topo.name}/{t.name}_read_GBs", 0.0,
             f"{t.read_bw/1e9:.0f}")
        emit(f"fig7/{topo.name}/{t.name}_write_GBs", 0.0,
             f"{t.write_bw/1e9:.0f}")
        emit(f"fig7/{topo.name}/{t.name}_capacity_GiB", 0.0,
             f"{t.capacity/2**30:.0f}")
    emit(f"fig7/{topo.name}/slow_over_fast_read", 0.0,
         f"{slow.read_bw/fast.read_bw*100:.0f}% "
         "(paper Optane/DRAM read=37%)")
    emit(f"fig7/{topo.name}/slow_over_fast_write", 0.0,
         f"{slow.write_bw/fast.write_bw*100:.1f}% "
         "(paper Optane/DRAM write=7-18%)")

    # access-size bandwidth utilization (the preset's saturation curve,
    # paper Fig 7b)
    for access in (4, 64, 256, 512, 4096):
        emit(f"fig7/{topo.name}/access_{access}B_slow_util", 0.0,
             f"{slow.utilization(access)*100:.1f}% "
             f"(saturates at {slow.granularity}B)")

    # measured proxy on this host: sequential vs strided (embedding-row
    # sized) reads — demonstrates the same access-size cliff the paper
    # exploits (GNN recsys reads whole embedding rows, PageRank reads 4B)
    a = np.zeros(1 << 22, dtype=np.float32)
    t0 = time.perf_counter()
    for _ in range(5):
        a.sum()
    seq = 5 * a.nbytes / (time.perf_counter() - t0)
    idx = np.random.default_rng(0).permutation(len(a))[: len(a) // 8]
    t0 = time.perf_counter()
    for _ in range(5):
        a[idx].sum()
    rand = 5 * (len(idx) * 4) / (time.perf_counter() - t0)
    emit("fig7/host_seq_read_GBs_measured", 0.0, f"{seq/1e9:.2f}")
    emit("fig7/host_rand4B_read_GBs_measured", 0.0,
         f"{rand/1e9:.2f} ({rand/seq*100:.0f}% of sequential)")
    if compression != "none":
        _compression_arm(topo)
    return {}


def _compression_arm(topo):
    """Quantized-storage byte terms + measured per-scheme step times,
    recorded to ``results/BENCH_compression.json``."""
    from repro.api import build, get_preset
    from repro.memory import quantized_table_bytes
    from repro.optim.compression import wire_bytes

    slow = topo.slow
    full = get_preset("lightgcn-full")
    n_rows = full.data.n_users + full.data.n_items
    row_bytes = full.model.embed_dim * 4
    fp32_bytes = n_rows * row_bytes
    int8_bytes = quantized_table_bytes(n_rows, row_bytes)
    ratio = int8_bytes / fp32_bytes
    emit(f"fig7/{topo.name}/embed_table_fp32_GiB", 0.0,
         f"{fp32_bytes/2**30:.2f}")
    emit(f"fig7/{topo.name}/embed_table_int8_GiB", 0.0,
         f"{int8_bytes/2**30:.2f} ({1/ratio:.2f}x capacity)")
    # the gather moves store_bytes off the slow tier: same tier
    # bandwidth, ~1/4 the bytes -> ~4x effective row-fetch rate
    gather = row_bytes / slow.read_bw / slow.utilization(row_bytes)
    emit(f"fig7/{topo.name}/slow_row_gather_us_fp32", gather * 1e6,
         f"{row_bytes}B row")
    emit(f"fig7/{topo.name}/slow_row_gather_us_int8", gather * ratio * 1e6,
         f"{int(row_bytes*ratio)}B stored row")

    # measured: smoke train-step wall time per gradient scheme (the
    # single-device compressor emulates the P-share exchange, so this
    # prices the compression compute itself, not the saved wire time)
    schemes = ("none", "int8", "topk")
    n_grads = n_rows * full.model.embed_dim
    steps, times = 6, {}
    for scheme in schemes:
        run_h = build(get_preset("lightgcn-smoke").override({
            "loop.steps": steps, "plan.target_batch": 64,
            "plan.microbatch": 16, "plan.warmup_epochs": 0,
            "data.edges": 1200, "loop.ckpt_dir": None,
            "compression.grads": scheme}))
        run_h.step()                                   # compile
        t0 = time.perf_counter()
        for _ in range(steps - 1):
            run_h.step()
        times[scheme] = (time.perf_counter() - t0) / (steps - 1)
        emit(f"fig7/compression/{scheme}_step_us", times[scheme] * 1e6,
             f"wire={wire_bytes(n_grads, scheme)}B/step (full-scale grads)")
    write_bench_json("compression", "tier_storage", {
        "topology": topo.name,
        "embed_table_bytes": {"fp32": fp32_bytes, "int8": int8_bytes},
        "capacity_multiplier": 1 / ratio,
        "slow_row_gather_s": {"fp32": gather, "int8": gather * ratio},
        "grad_wire_bytes_per_step": {
            s: wire_bytes(n_grads, s) for s in schemes},
        "smoke_step_s": times,
    })


if __name__ == "__main__":
    import argparse

    from repro.memory import topology_names
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--topology", default="tpu-hbm-host",
                    choices=topology_names(),
                    help="registered TierTopology preset to print")
    ap.add_argument("--compression", default="none",
                    choices=["none", "int8"],
                    help="add the quantized-storage arm and record "
                         "results/BENCH_compression.json")
    a = ap.parse_args()
    run(a.topology, compression=a.compression)
