"""Paper Fig 7: tier bandwidth characterization.

The paper measures Optane vs DRAM (read 37%, write 7%, nt-write 18% of
DRAM; random-access utilization saturating at 256 B writes / >4 KB
reads).  Our tiers are declarative ``repro.memory.TierTopology``
presets — this benchmark prints the cost model any registered preset
feeds the placement policies (these numbers ARE the planner's inputs),
plus a measured CPU-cache proxy for the access-size effect.

``--topology`` selects the preset (default ``tpu-hbm-host``); run
``python -m benchmarks.run --only fig7 --topology dram-optane-appdirect``
or this module directly.
"""
import time

import numpy as np

from benchmarks.common import emit
from repro.memory import get_topology


def run(topology: str = "tpu-hbm-host"):
    topo = get_topology(topology)
    fast, slow = topo.fast, topo.slow
    for t in topo.tiers:
        emit(f"fig7/{topo.name}/{t.name}_read_GBs", 0.0,
             f"{t.read_bw/1e9:.0f}")
        emit(f"fig7/{topo.name}/{t.name}_write_GBs", 0.0,
             f"{t.write_bw/1e9:.0f}")
        emit(f"fig7/{topo.name}/{t.name}_capacity_GiB", 0.0,
             f"{t.capacity/2**30:.0f}")
    emit(f"fig7/{topo.name}/slow_over_fast_read", 0.0,
         f"{slow.read_bw/fast.read_bw*100:.0f}% "
         "(paper Optane/DRAM read=37%)")
    emit(f"fig7/{topo.name}/slow_over_fast_write", 0.0,
         f"{slow.write_bw/fast.write_bw*100:.1f}% "
         "(paper Optane/DRAM write=7-18%)")

    # access-size bandwidth utilization (the preset's saturation curve,
    # paper Fig 7b)
    for access in (4, 64, 256, 512, 4096):
        emit(f"fig7/{topo.name}/access_{access}B_slow_util", 0.0,
             f"{slow.utilization(access)*100:.1f}% "
             f"(saturates at {slow.granularity}B)")

    # measured proxy on this host: sequential vs strided (embedding-row
    # sized) reads — demonstrates the same access-size cliff the paper
    # exploits (GNN recsys reads whole embedding rows, PageRank reads 4B)
    a = np.zeros(1 << 22, dtype=np.float32)
    t0 = time.perf_counter()
    for _ in range(5):
        a.sum()
    seq = 5 * a.nbytes / (time.perf_counter() - t0)
    idx = np.random.default_rng(0).permutation(len(a))[: len(a) // 8]
    t0 = time.perf_counter()
    for _ in range(5):
        a[idx].sum()
    rand = 5 * (len(idx) * 4) / (time.perf_counter() - t0)
    emit("fig7/host_seq_read_GBs_measured", 0.0, f"{seq/1e9:.2f}")
    emit("fig7/host_rand4B_read_GBs_measured", 0.0,
         f"{rand/1e9:.2f} ({rand/seq*100:.0f}% of sequential)")
    return {}


if __name__ == "__main__":
    import argparse

    from repro.memory import topology_names
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--topology", default="tpu-hbm-host",
                    choices=topology_names(),
                    help="registered TierTopology preset to print")
    run(ap.parse_args().topology)
