"""Paper Fig 7: tier bandwidth characterization.

The paper measures Optane vs DRAM (read 37%, write 7%, nt-write 18% of
DRAM; random-access utilization saturating at 256 B writes / >4 KB
reads).  Our tiers are HBM (819 GB/s) vs host-DRAM-over-PCIe; the table
below reports the cost model used by the TieredMemoryPlanner (these
constants ARE the planner's inputs) plus a measured CPU-cache proxy for
the access-size effect (sequential vs strided reads).
"""
import time

import numpy as np

from benchmarks.common import emit
from repro.core import tiered_memory as tm


def run():
    emit("fig7/hbm_read_GBs", 0.0, f"{tm.HBM_BW_READ/1e9:.0f}")
    emit("fig7/hbm_write_GBs", 0.0, f"{tm.HBM_BW_WRITE/1e9:.0f}")
    emit("fig7/host_read_GBs", 0.0,
         f"{tm.HOST_BW_READ/1e9:.0f} ({tm.HOST_BW_READ/tm.HBM_BW_READ*100:.0f}% of HBM; paper Optane/DRAM read=37%)")
    emit("fig7/host_write_GBs", 0.0,
         f"{tm.HOST_BW_WRITE/1e9:.0f} ({tm.HOST_BW_WRITE/tm.HBM_BW_WRITE*100:.1f}% of HBM; paper Optane/DRAM write=7-18%)")

    # access-size bandwidth utilization (planner model, paper Fig 7b)
    for access in (4, 64, 256, 512, 4096):
        util = min(1.0, access / 256.0)
        emit(f"fig7/access_{access}B_write_util", 0.0, f"{util*100:.0f}%")

    # measured proxy on this host: sequential vs strided (embedding-row
    # sized) reads — demonstrates the same access-size cliff the paper
    # exploits (GNN recsys reads whole embedding rows, PageRank reads 4B)
    a = np.zeros(1 << 22, dtype=np.float32)
    t0 = time.perf_counter()
    for _ in range(5):
        a.sum()
    seq = 5 * a.nbytes / (time.perf_counter() - t0)
    idx = np.random.default_rng(0).permutation(len(a))[: len(a) // 8]
    t0 = time.perf_counter()
    for _ in range(5):
        a[idx].sum()
    rand = 5 * (len(idx) * 4) / (time.perf_counter() - t0)
    emit("fig7/host_seq_read_GBs_measured", 0.0, f"{seq/1e9:.2f}")
    emit("fig7/host_rand4B_read_GBs_measured", 0.0,
         f"{rand/1e9:.2f} ({rand/seq*100:.0f}% of sequential)")
    return {}
