"""Serving benchmarks: the hot scoring path, ANN block-pruned
retrieval, and queue-coalesced load — three sections of the root-level
``BENCH_serving.json`` perf-trajectory artifact (mirrored under
``results/``).

``power_law_stream`` — fused-vs-unfused scoring and cached-vs-uncached
host-demoted tables under a Zipf-ranked query stream (RecNMP's
observation in PAPERS.md: production embedding traffic is sharply
power-law).  The cached arm is measured *steady-state*: the hot-row
cache is prefilled with the stream's hot set before timing, because a
cold cache spends its first sweeps filling slots and those fill
round-trips used to land inside the measured loop and masquerade as a
p99 cliff.  The same configuration measured from a cold cache is
reported separately (``fused_cached_cold``) so the warmup transient
stays visible instead of polluting the steady numbers.

``ann_retrieval`` — exact streaming sweep vs the block-pruned
approximate path (``repro.serving.ann``) on a clustered catalogue at
``>= 65536`` items: recall@10, interleaved p50 latencies (exact / ann
alternate call-by-call so host drift cancels), and the ``keep_frac=1``
bitwise-parity flag.

``load`` — open-loop (Poisson arrivals at ~4x single-request capacity)
and closed-loop (fixed client population) request streams through
``RecommenderService`` under virtual time: per-request dispatch vs
16-way coalescing, throughput + wait/total p50/p99 per arm.  The
service advances its ``ManualClock`` by each batch's *measured*
compute, so the simulation is single-threaded but charges real costs;
arrivals that land mid-batch are enqueued when the loop regains
control, exactly as in the synchronous event loop the service is.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, write_bench_json
from repro.eval.recommender import Recommender
from repro.eval.topk import streaming_topk
from repro.serving import (AnnIndex, ManualClock, QueueFull,
                           RecommenderService, ann_topk, recall_against)

N_USERS = 2048
N_ITEMS = 4096
DIM = 32
K = 10
BATCH = 64
ITEM_BLOCK = 256
WARMUP = 3
N_BATCHES = 40
CACHE_ROWS = 512
ZIPF_A = 1.3

# ann_retrieval section: a clustered catalogue at the ISSUE's >=65536
# floor (with headroom), pruned to keep_frac of the index blocks
ANN_ITEMS = 131072
ANN_CLUSTERS = 384
ANN_BLOCK = 32
ANN_KEEP = 0.03125
ANN_USER_BATCH = 16
ANN_QUERIES = 512
ANN_REPS = 5

# load section: open loop at ~4x the single-request service capacity
LOAD_REQS = 512
LOAD_OVERLOAD = 4.0
LOAD_MAX_BATCH = 16
LOAD_CLIENTS = 32


def _zipf_stream(rng, n_batches: int):
    """Zipf-ranked user-id batches: rank r is drawn ∝ r^-a and mapped to
    a fixed random permutation of the user space (hot set ≈ low ranks).
    Returns (stream, hot_ids): the permutation's head is the hot set a
    steady-state cache would hold."""
    perm = rng.permutation(N_USERS)
    ranks = np.minimum(rng.zipf(ZIPF_A, size=(n_batches, BATCH)) - 1,
                       N_USERS - 1)
    return perm[ranks].astype(np.int32), perm[:CACHE_ROWS].astype(np.int32)


def _measure(rec: Recommender, stream: np.ndarray, warmup: int = WARMUP):
    """Per-batch wall latencies (us) over the stream; the first
    ``warmup`` batches prime jit caches and are excluded (``warmup=0``
    measures the cold transient on purpose)."""
    lat = []
    for i, batch in enumerate(stream):
        t0 = time.perf_counter()
        rec.recommend(batch)
        dt = (time.perf_counter() - t0) * 1e6
        if i >= warmup:
            lat.append(dt)
    lat = np.asarray(lat)
    return {"p50_us": float(np.percentile(lat, 50)),
            "p99_us": float(np.percentile(lat, 99)),
            "batches": int(len(lat)), "batch_size": BATCH}


def run_power_law(ue, ie, indptr, seen, stream, hot_ids):
    base = dict(seen_indptr=indptr, seen_items=seen, k=K,
                user_batch=BATCH, item_block=ITEM_BLOCK,
                topology="uniform")
    demote = {"serve/user_embed": "slow"}

    arms = {
        "unfused": Recommender(ue, ie, fused=False, **base),
        "fused": Recommender(ue, ie, fused=True, **base),
        "demoted_uncached": Recommender(ue, ie, pins=demote, **base),
        "fused_cached": Recommender(ue, ie, pins=demote,
                                    cache_rows=CACHE_ROWS, **base),
    }
    payload = {"n_users": N_USERS, "n_items": N_ITEMS, "dim": DIM, "k": K,
               "zipf_a": ZIPF_A, "cache_rows": CACHE_ROWS}
    # steady state for the cached arm: the hot set is resident *before*
    # the measured loop, as it would be minutes into real traffic
    arms["fused_cached"].prefill_cache(hot_ids)
    for name, rec in arms.items():
        res = _measure(rec, stream)
        stats = rec.cache_stats().get("serve/user_embed")
        if stats is not None:
            res.update(hit_rate=stats["hit_rate"],
                       bytes_streamed=stats["bytes_streamed"])
        payload[name] = res
        emit(f"serving/{name}_p50", res["p50_us"],
             f"p99={res['p99_us']:.0f}us")

    # the cold transient, reported separately: fresh cache, warm jit
    # (the arms above already traced every shape), warmup=0 so the fill
    # round-trips land inside the measured window
    cold = Recommender(ue, ie, pins=demote, cache_rows=CACHE_ROWS, **base)
    res = _measure(cold, stream, warmup=0)
    stats = cold.cache_stats()["serve/user_embed"]
    res.update(hit_rate=stats["hit_rate"],
               bytes_streamed=stats["bytes_streamed"])
    payload["fused_cached_cold"] = res
    emit("serving/fused_cached_cold_p50", res["p50_us"],
         f"p99={res['p99_us']:.0f}us (cold fills timed)")

    payload["fused_speedup_p50"] = (payload["unfused"]["p50_us"]
                                    / payload["fused"]["p50_us"])
    payload["fused_cached_vs_unfused_p50"] = (
        payload["unfused"]["p50_us"] / payload["fused_cached"]["p50_us"])
    payload["demoted_uncached"]["bytes_streamed"] = int(
        sum(len(b) * DIM * 4 for b in stream))   # every row re-gathered
    payload["cache_bytes_saved_frac"] = 1.0 - (
        payload["fused_cached"]["bytes_streamed"]
        / payload["demoted_uncached"]["bytes_streamed"])
    emit("serving/fused_speedup_p50", 0.0,
         f"{payload['fused_speedup_p50']:.2f}x")
    emit("serving/fused_cached_vs_unfused_p50", 0.0,
         f"{payload['fused_cached_vs_unfused_p50']:.2f}x")
    emit("serving/cache_bytes_saved", 0.0,
         f"{payload['cache_bytes_saved_frac']*100:.0f}% of slow-tier "
         f"stream (hit_rate={payload['fused_cached']['hit_rate']:.2f})")
    write_bench_json("serving", "power_law_stream", payload)
    return payload


def run_ann():
    rng = np.random.default_rng(1)
    centers = rng.normal(0, 1.0, (ANN_CLUSTERS, DIM)).astype(np.float32)
    ie = (centers[rng.integers(0, ANN_CLUSTERS, ANN_ITEMS)]
          + 0.15 * rng.normal(0, 1, (ANN_ITEMS, DIM))).astype(np.float32)
    ue = (centers[rng.integers(0, ANN_CLUSTERS, N_USERS)]
          + 0.3 * rng.normal(0, 1, (N_USERS, DIM))).astype(np.float32)
    perm = rng.permutation(N_USERS)
    z = np.minimum(rng.zipf(ZIPF_A, 4 * ANN_QUERIES) - 1, N_USERS - 1)
    stream = perm[z][:ANN_QUERIES].astype(np.int32)

    t0 = time.perf_counter()
    index = AnnIndex(ie, block=ANN_BLOCK)
    build_s = time.perf_counter() - t0

    def exact():
        return streaming_topk(ue, ie, K, user_ids=stream,
                              user_batch=ANN_USER_BATCH)

    def pruned(kf=ANN_KEEP):
        return ann_topk(index, ue, ie, K, keep_frac=kf, user_ids=stream,
                        user_batch=ANN_USER_BATCH)

    exact(); pruned()                      # trace every shape up front
    t_exact, t_ann = [], []
    for _ in range(ANN_REPS):              # interleaved: drift cancels
        t0 = time.perf_counter(); pruned(); t_ann.append(time.perf_counter() - t0)
        t0 = time.perf_counter(); exact(); t_exact.append(time.perf_counter() - t0)
    _, exact_ids = exact()
    _, ann_ids = pruned()
    recall = recall_against(exact_ids, ann_ids)

    es, ei = exact()
    ps, pi = pruned(kf=1.0)
    bitwise = bool(np.array_equal(es, ps) and np.array_equal(ei, pi))

    p50_exact = float(np.percentile(np.asarray(t_exact) * 1e6, 50))
    p50_ann = float(np.percentile(np.asarray(t_ann) * 1e6, 50))
    payload = {
        "n_items": ANN_ITEMS, "dim": DIM, "k": K, "zipf_a": ZIPF_A,
        "ann_block": ANN_BLOCK, "n_blocks": index.n_blocks,
        "keep_frac": ANN_KEEP, "n_keep": index.n_keep(ANN_KEEP),
        "user_batch": ANN_USER_BATCH, "queries": ANN_QUERIES,
        "index_bytes": index.nbytes, "index_build_s": build_s,
        "exact_p50_us": p50_exact, "ann_p50_us": p50_ann,
        "speedup_p50": p50_exact / p50_ann,
        "recall_at_10": recall,
        "keep_all_bitwise": bitwise,
    }
    emit("serving/ann_exact_p50", p50_exact, f"{ANN_ITEMS} items")
    emit("serving/ann_pruned_p50", p50_ann,
         f"keep={ANN_KEEP:g} -> {payload['speedup_p50']:.2f}x "
         f"recall@10={recall:.3f} bitwise@1.0={bitwise}")
    write_bench_json("serving", "ann_retrieval", payload)
    return payload


def _open_loop(service, users, inter_us):
    """Drive Poisson arrivals through the service under virtual time.
    Arrivals already in the past are enqueued as soon as the loop is
    back in control (mid-batch arrivals wait out the batch, as in any
    single-threaded event loop); rejected submissions are shed."""
    clock = service.clock
    arrivals = (np.cumsum(inter_us) + clock.now_us()).astype(np.int64)
    responses, rejected, i = [], 0, 0
    while i < len(arrivals) or len(service.queue):
        while i < len(arrivals) and arrivals[i] <= clock.now_us():
            try:
                service.submit(int(users[i]))
            except QueueFull:
                rejected += 1
            i += 1
        if service.queue.ready():
            responses.extend(service.poll())
            continue
        pending = [int(arrivals[i])] if i < len(arrivals) else []
        deadline = service.queue.next_deadline_us()
        if deadline is not None:
            pending.append(int(deadline))
        if not pending:
            break
        clock.advance(max(0, min(pending) - clock.now_us()))
        if service.queue.ready():
            responses.extend(service.poll())
    return responses, rejected


def _closed_loop(service, users, n_clients):
    """Fixed client population: every completed request immediately
    resubmits until the user stream is exhausted."""
    i = 0
    responses = []
    for _ in range(min(n_clients, len(users))):
        service.submit(int(users[i])); i += 1
    while len(service.queue):
        for r in service.poll(force=True):
            responses.append(r)
            if i < len(users):
                service.submit(int(users[i])); i += 1
    return responses


def _lat(responses):
    total = np.asarray([r.total_us for r in responses], np.int64)
    wait = np.asarray([r.wait_us for r in responses], np.int64)
    return {"completed": len(responses),
            "wait_p50_us": float(np.percentile(wait, 50)),
            "total_p50_us": float(np.percentile(total, 50)),
            "total_p99_us": float(np.percentile(total, 99))}


def run_load(ue, ie, indptr, seen):
    rec = Recommender(ue, ie, seen_indptr=indptr, seen_items=seen, k=K,
                      user_batch=LOAD_MAX_BATCH, item_block=ITEM_BLOCK,
                      topology="uniform", fused=True)
    rng = np.random.default_rng(2)
    perm = rng.permutation(N_USERS)
    z = np.minimum(rng.zipf(ZIPF_A, 4 * LOAD_REQS) - 1, N_USERS - 1)
    users = perm[z][:LOAD_REQS].astype(np.int32)

    # prime every bucket-ladder shape (1, 2, 4, ..., max_batch): under
    # virtual time a mid-simulation jit trace would be charged as
    # service compute and read as a massive latency spike
    b = 1
    while b <= LOAD_MAX_BATCH:
        rec.recommend(users[:b]); rec.recommend(users[:b])
        b <<= 1
    # calibrate: single-request service time sets the arrival rate
    reps = []
    for i in range(20):
        t0 = time.perf_counter()
        rec.recommend(users[i:i + 1])
        reps.append(time.perf_counter() - t0)
    t1_us = max(float(np.median(reps) * 1e6), 1.0)
    inter_us = np.maximum(
        rng.exponential(t1_us / LOAD_OVERLOAD, LOAD_REQS), 1.0)

    def arm(max_batch, max_wait_us):
        return RecommenderService(rec, max_batch=max_batch,
                                  max_wait_us=max_wait_us,
                                  max_depth=4 * LOAD_MAX_BATCH,
                                  clock=ManualClock())

    payload = {"requests": LOAD_REQS, "overload": LOAD_OVERLOAD,
               "single_service_us": t1_us, "max_batch": LOAD_MAX_BATCH,
               "zipf_a": ZIPF_A, "open_loop": {}, "closed_loop": {}}
    for name, mb, mw in (("per_request", 1, 0),
                         ("coalesced", LOAD_MAX_BATCH, int(t1_us))):
        svc = arm(mb, mw)
        start = svc.clock.now_us()
        responses, rejected = _open_loop(svc, users, inter_us)
        elapsed = max(svc.clock.now_us() - start, 1)
        res = _lat(responses)
        res.update(rejected=rejected,
                   throughput_rps=len(responses) / elapsed * 1e6,
                   mean_occupancy=svc.queue.stats()["mean_occupancy"],
                   batches=svc.queue.stats()["batches"])
        payload["open_loop"][name] = res
        emit(f"serving/load_open_{name}", res["total_p50_us"],
             f"thr={res['throughput_rps']:.0f}rps p99={res['total_p99_us']:.0f}us "
             f"shed={rejected}")

        svc = arm(mb, mw)
        start = svc.clock.now_us()
        responses = _closed_loop(svc, users, LOAD_CLIENTS)
        elapsed = max(svc.clock.now_us() - start, 1)
        res = _lat(responses)
        res.update(throughput_rps=len(responses) / elapsed * 1e6,
                   mean_occupancy=svc.queue.stats()["mean_occupancy"],
                   batches=svc.queue.stats()["batches"])
        payload["closed_loop"][name] = res
        emit(f"serving/load_closed_{name}", res["total_p50_us"],
             f"thr={res['throughput_rps']:.0f}rps p99={res['total_p99_us']:.0f}us")

    ol = payload["open_loop"]
    payload["coalescing_throughput_gain"] = (
        ol["coalesced"]["throughput_rps"] / ol["per_request"]["throughput_rps"])
    payload["coalescing_wins"] = bool(
        ol["coalesced"]["throughput_rps"] > ol["per_request"]["throughput_rps"]
        and ol["coalesced"]["total_p99_us"] <= ol["per_request"]["total_p99_us"])
    emit("serving/coalescing_throughput_gain", 0.0,
         f"{payload['coalescing_throughput_gain']:.2f}x "
         f"(wins_at_p99={payload['coalescing_wins']})")
    write_bench_json("serving", "load", payload)
    return payload


def run():
    rng = np.random.default_rng(0)
    ue = rng.standard_normal((N_USERS, DIM)).astype(np.float32)
    ie = rng.standard_normal((N_ITEMS, DIM)).astype(np.float32)
    indptr = np.arange(N_USERS + 1) * 4
    seen = rng.integers(0, N_ITEMS, indptr[-1])
    stream, hot_ids = _zipf_stream(rng, N_BATCHES)
    out = {"power_law_stream": run_power_law(ue, ie, indptr, seen,
                                             stream, hot_ids)}
    out["ann_retrieval"] = run_ann()
    out["load"] = run_load(ue, ie, indptr, seen)
    return out


if __name__ == "__main__":
    run()
