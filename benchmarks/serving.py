"""Serving hot-path benchmark: fused-vs-unfused scoring and
cached-vs-uncached host-demoted tables under a power-law query stream.

RecNMP's observation (PAPERS.md) is that production embedding traffic
is sharply Zipfian, so the serving sweep is driven by a Zipf-ranked
user stream rather than uniform ids.  Four arms, all bit-identical in
results (pinned by tests/test_serving.py):

  unfused          — both tables fast-tier resident, per-block streamed
                     merge (the pre-fused baseline dataflow);
  fused            — same placement, one fused gather+score+seen-mask+
                     top-K kernel per query batch;
  demoted_uncached — user table demoted to the capacity tier, every
                     query batch row-gathers from the host store;
  fused_cached     — demoted user table behind the LFU ``HotRowCache``
                     + fused scoring: the hot set stays device-resident
                     so steady-state traffic streams only the cold tail.

Reports p50/p99 per-batch latency, cache hit rate, and slow-tier bytes
streamed, into the root-level ``BENCH_serving.json`` perf-trajectory
artifact (mirrored under ``results/``).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, write_bench_json
from repro.eval.recommender import Recommender

N_USERS = 2048
N_ITEMS = 4096
DIM = 32
K = 10
BATCH = 64
ITEM_BLOCK = 256
WARMUP = 3
N_BATCHES = 40
CACHE_ROWS = 512
ZIPF_A = 1.3


def _zipf_stream(rng, n_batches: int):
    """Zipf-ranked user-id batches: rank r is drawn ∝ r^-a and mapped to
    a fixed random permutation of the user space (hot set ≈ low ranks)."""
    perm = rng.permutation(N_USERS)
    ranks = np.minimum(rng.zipf(ZIPF_A, size=(n_batches, BATCH)) - 1,
                       N_USERS - 1)
    return perm[ranks].astype(np.int32)


def _measure(rec: Recommender, stream: np.ndarray):
    """Per-batch wall latencies (us) over the stream; first WARMUP
    batches prime jit caches / the row cache and are excluded."""
    lat = []
    for i, batch in enumerate(stream):
        t0 = time.perf_counter()
        rec.recommend(batch)
        dt = (time.perf_counter() - t0) * 1e6
        if i >= WARMUP:
            lat.append(dt)
    lat = np.asarray(lat)
    return {"p50_us": float(np.percentile(lat, 50)),
            "p99_us": float(np.percentile(lat, 99)),
            "batches": int(len(lat)), "batch_size": BATCH}


def run():
    rng = np.random.default_rng(0)
    ue = rng.standard_normal((N_USERS, DIM)).astype(np.float32)
    ie = rng.standard_normal((N_ITEMS, DIM)).astype(np.float32)
    indptr = np.arange(N_USERS + 1) * 4
    seen = rng.integers(0, N_ITEMS, indptr[-1])
    stream = _zipf_stream(rng, N_BATCHES)
    base = dict(seen_indptr=indptr, seen_items=seen, k=K,
                user_batch=BATCH, item_block=ITEM_BLOCK,
                topology="uniform")
    demote = {"serve/user_embed": "slow"}

    arms = {
        "unfused": Recommender(ue, ie, fused=False, **base),
        "fused": Recommender(ue, ie, fused=True, **base),
        "demoted_uncached": Recommender(ue, ie, pins=demote, **base),
        "fused_cached": Recommender(ue, ie, pins=demote,
                                    cache_rows=CACHE_ROWS, **base),
    }
    payload = {"n_users": N_USERS, "n_items": N_ITEMS, "dim": DIM, "k": K,
               "zipf_a": ZIPF_A, "cache_rows": CACHE_ROWS}
    for name, rec in arms.items():
        res = _measure(rec, stream)
        stats = rec.cache_stats().get("serve/user_embed")
        if stats is not None:
            res.update(hit_rate=stats["hit_rate"],
                       bytes_streamed=stats["bytes_streamed"])
        payload[name] = res
        emit(f"serving/{name}_p50", res["p50_us"],
             f"p99={res['p99_us']:.0f}us")

    payload["fused_speedup_p50"] = (payload["unfused"]["p50_us"]
                                    / payload["fused"]["p50_us"])
    payload["fused_cached_vs_unfused_p50"] = (
        payload["unfused"]["p50_us"] / payload["fused_cached"]["p50_us"])
    payload["demoted_uncached"]["bytes_streamed"] = int(
        sum(len(b) * DIM * 4 for b in stream))   # every row re-gathered
    payload["cache_bytes_saved_frac"] = 1.0 - (
        payload["fused_cached"]["bytes_streamed"]
        / payload["demoted_uncached"]["bytes_streamed"])
    emit("serving/fused_speedup_p50", 0.0,
         f"{payload['fused_speedup_p50']:.2f}x")
    emit("serving/fused_cached_vs_unfused_p50", 0.0,
         f"{payload['fused_cached_vs_unfused_p50']:.2f}x")
    emit("serving/cache_bytes_saved", 0.0,
         f"{payload['cache_bytes_saved_frac']*100:.0f}% of slow-tier "
         f"stream (hit_rate={payload['fused_cached']['hit_rate']:.2f})")
    write_bench_json("serving", "power_law_stream", payload)
    return payload


if __name__ == "__main__":
    run()
