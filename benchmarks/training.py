"""Training-side perf trajectory: per-model train-step timings.

ROADMAP item 4's missing half: serving latency has been tracked since
PR 7 (``BENCH_serving.json``); this module starts the training-step
record.  One full BPR train step (forward + backward + Adam update)
per registry model on the same synthetic bipartite graph:

  lightgcn       — the paper's fastest model (no message stream);
  gcn            — scalar-message convolution (single fused SpMM/layer);
  ngcf_composed  — NGCF through the legacy gather-multiply dataflow:
                   the per-layer [E, D] Hadamard message matrix is
                   materialized and saved as an autodiff residual;
  ngcf_fused     — NGCF through the fused hadamard_spmm route with the
                   rematerializing VJP: the [E, D] matrix never exists.

The fused-vs-composed pair is the headline number
(``ngcf_fused_speedup``): same graph, same batch, bit-comparable loss
(pinned by tests/test_pipeline.py), different dataflow.  Results land
in the root-level ``BENCH_training.json`` perf-trajectory artifact.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn, write_bench_json
from repro.core import bpr
from repro.data import synth
from repro.optim import adam
from repro.pipeline.registry import get_model
from repro.pipeline.sparse import BipartiteCSR

EDGES = 20000
DIM = 32
LAYERS = 2
BATCH = 1024
SEED = 0


def _make_step(spec, g, opt):
    @jax.jit
    def step(params, opt_state, users, pos, neg):
        def loss_fn(p):
            ue, ie = spec.forward(p, g, LAYERS)
            return bpr.bpr_loss(ue, ie, users, pos, neg)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, loss

    return step


def _bench_arm(arch: str, data, hadamard: str = "auto"):
    spec = get_model(arch)
    g = BipartiteCSR(data.user, data.item, data.n_users, data.n_items,
                     hadamard=hadamard)
    params = spec.init(jax.random.PRNGKey(SEED), data.n_users, data.n_items,
                       DIM, LAYERS)
    opt = adam(1e-3)
    opt_state = opt.init(params)
    rng = np.random.default_rng(SEED)
    pick = rng.integers(0, len(data.user), BATCH)
    users = jnp.asarray(data.user[pick].astype(np.int32))
    pos = jnp.asarray(data.item[pick].astype(np.int32))
    neg = jnp.asarray(rng.integers(0, data.n_items, BATCH).astype(np.int32))
    step = _make_step(spec, g, opt)
    us = time_fn(step, params, opt_state, users, pos, neg,
                 warmup=2, iters=5)
    return {"step_us": us, "impl": g.impl,
            "messages_materialized": spec.messages_materialized(g)}


def run():
    data = synth.scaled("movielens-10m", EDGES, seed=SEED)
    payload = {"edges": EDGES, "dim": DIM, "layers": LAYERS,
               "batch": BATCH, "n_users": data.n_users,
               "n_items": data.n_items}
    arms = {"lightgcn": ("lightgcn", "auto"),
            "gcn": ("gcn", "auto"),
            "ngcf_composed": ("ngcf", "composed"),
            "ngcf_fused": ("ngcf", "fused")}
    for name, (arch, hadamard) in arms.items():
        res = _bench_arm(arch, data, hadamard)
        payload[name] = res
        emit(f"training/{name}_step", res["step_us"],
             f"impl={res['impl']} "
             f"messages={'yes' if res['messages_materialized'] else 'no'}")
    payload["ngcf_fused_speedup"] = (payload["ngcf_composed"]["step_us"]
                                     / payload["ngcf_fused"]["step_us"])
    emit("training/ngcf_fused_speedup", 0.0,
         f"{payload['ngcf_fused_speedup']:.2f}x (composed "
         f"{payload['ngcf_composed']['step_us']:.0f}us -> fused "
         f"{payload['ngcf_fused']['step_us']:.0f}us)")
    write_bench_json("training", "train_step", payload)
    return payload


if __name__ == "__main__":
    run()
