"""Paper Fig 5: NGCF dataflow-optimization ablation.

Paper: O1+O2+O3 give 8.3x inference / 8.0x training on NGCF-3L-128E
(movielens-10m, DGL).  Our O-levels: 0=naive per-edge matmuls,
1=+reorder, 3=+SDDMM reuse (O2 kernelization maps to the Pallas path,
benchmarked separately in fig8).  CPU-scaled graph; the claim is a ratio.

Configuration and state come from one ``ExperimentSpec`` through the
Experiment API: the spec names the data and model shapes, ``build``
materializes the graph and the NGCF parameters (the registry init is
the seed ``core.ngcf.init_params``); the O-level ablation then runs the
seed COO forwards over that same data, since O0/O1 only exist there.
"""
import jax

from benchmarks.common import emit, time_fn
from repro.api import DataCfg, ExperimentSpec, ModelCfg, PlanCfg, build
from repro.core import bpr, ngcf
from repro.core.graph import bipartite_from_numpy

SPEC = ExperimentSpec(
    name="fig5-ngcf3L",
    model=ModelCfg(arch="ngcf", embed_dim=64, n_layers=3),
    data=DataCfg(source="synth", dataset="movielens-10m", edges=20000,
                 test_frac=0.0, seed=0),
    plan=PlanCfg(base_batch=512, target_batch=512, microbatch=512,
                 warmup_epochs=0))


def run():
    r = build(SPEC)
    data = r.train_data
    g = bipartite_from_numpy(data.user, data.item, data.n_users,
                             data.n_items)
    params = r.params                 # registry init == core.ngcf's

    times = {}
    for lvl in (0, 1, 3):
        fwd = jax.jit(lambda p, lvl=lvl: ngcf.forward(p, g, opt_level=lvl))
        times[f"inf_O{lvl}"] = time_fn(fwd, params)
        emit(f"fig5/ngcf3L_inference_opt{lvl}", times[f"inf_O{lvl}"])

    import jax.numpy as jnp
    import numpy as np
    rng = np.random.default_rng(0)
    u, i, n = bpr.sample_bpr_batch(rng, data.user, data.item, data.n_items,
                                   512)
    u, i, n = jnp.asarray(u), jnp.asarray(i), jnp.asarray(n)
    for lvl in (0, 1, 3):
        grad = jax.jit(jax.grad(
            lambda p, lvl=lvl: bpr.bpr_loss(*ngcf.forward(p, g, opt_level=lvl),
                                            u, i, n)))
        times[f"train_O{lvl}"] = time_fn(grad, params)
        emit(f"fig5/ngcf3L_train_opt{lvl}", times[f"train_O{lvl}"])

    inf_speedup = times["inf_O0"] / times["inf_O3"]
    train_speedup = times["train_O0"] / times["train_O3"]
    emit("fig5/inference_speedup_O0_to_O3", 0.0, f"{inf_speedup:.2f}x")
    emit("fig5/train_speedup_O0_to_O3", 0.0, f"{train_speedup:.2f}x")
    return {"inference_speedup": inf_speedup, "train_speedup": train_speedup}
