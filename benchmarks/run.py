"""Benchmark harness — one module per paper table/figure.

``python -m benchmarks.run [--only fig5,table6] [--fast]``
prints ``name,us_per_call,derived`` CSV rows (benchmarks/common.emit).
"""
from __future__ import annotations

import argparse
import inspect
import sys
import time
import traceback

ALL = [
    "fig5_dataflow_opts",
    "fig6_breakdown",
    "fig7_tier_bandwidth",
    "fig8_kernel_tiers",
    "fig10_placement",
    "fig12_large_batch",
    "table3_accuracy",
    "table4_sampling",
    "table5_memory_model",
    "table6_fullgraph_vs_subgraph",
    "roofline",
    "serving",
    "training",
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", type=str, default=None)
    ap.add_argument("--mesh", type=str, default=None,
                    help="mesh shape ('4', '2x2') forwarded to benchmarks "
                         "that take one (fig12); pair with XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N on CPU")
    ap.add_argument("--topology", type=str, default=None,
                    help="registered TierTopology preset forwarded to "
                         "benchmarks that take one (fig7, fig8, fig10), "
                         "e.g. dram-optane-appdirect")
    ap.add_argument("--compression", type=str, default=None,
                    help="compression scheme ('int8') forwarded to "
                         "benchmarks that take one (fig7's quantized-"
                         "storage arm; records BENCH_compression.json)")
    args = ap.parse_args()
    names = args.only.split(",") if args.only else ALL
    failures = []
    for name in names:
        mod_name = next((m for m in ALL if m.startswith(name)), name)
        print(f"# === {mod_name} ===", flush=True)
        t0 = time.perf_counter()
        try:
            mod = __import__(f"benchmarks.{mod_name}", fromlist=["run"])
            kw = {}
            params = inspect.signature(mod.run).parameters
            if args.mesh is not None and "mesh" in params:
                kw["mesh"] = args.mesh
            if args.topology is not None and "topology" in params:
                kw["topology"] = args.topology
            if args.compression is not None and "compression" in params:
                kw["compression"] = args.compression
            mod.run(**kw)
        except Exception:  # noqa: BLE001
            traceback.print_exc()
            failures.append(mod_name)
        print(f"# {mod_name} done in {time.perf_counter()-t0:.1f}s",
              flush=True)
    if failures:
        print(f"# FAILED: {failures}")
        sys.exit(1)
    print("# all benchmarks OK")


if __name__ == "__main__":
    main()
