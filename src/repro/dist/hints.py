"""Ambient sharding hints.

Model code (transformer/moe/gcn cells) needs to pin a handful of
intermediates whose sharding GSPMD cannot infer (reshapes that merge a
dp axis with a tp axis, one-hot dispatch tables, ...).  Threading the
mesh + axis names through every forward call would contaminate every
signature, so the launch layer instead installs *hints* around the step:

    with sharding_hints(dp=("pod", "data"), tp="model"):
        loss = train_step(...)

and model code calls ``constrain(x, "dp", None, "tp")`` at the few
places that need a pin.  Outside a hints context (single-device tests,
CPU smoke runs) every call is a no-op, so the same model code runs
unmodified everywhere.

Labels: ``None`` (unconstrained dim), ``"dp"``, ``"tp"``, or ``"dp+tp"``
(the flattened data x model axis — used for token-major reshapes).
"""
from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import PartitionSpec as P

_STATE = threading.local()


def get_hints() -> dict | None:
    """The active hint dict ({'dp': ..., 'tp': ...}) or None."""
    return getattr(_STATE, "hints", None)


@contextlib.contextmanager
def sharding_hints(dp=None, tp=None, mesh=None):
    """Install dp/tp axis-name hints for the enclosed region.  ``dp`` may
    be one axis name or a tuple (multi-pod data axes); ``mesh`` is
    optional — when omitted, ``constrain`` emits bare PartitionSpecs and
    relies on the surrounding jit/shard context to bind them."""
    prev = get_hints()
    _STATE.hints = {"dp": dp, "tp": tp, "mesh": mesh}
    try:
        yield
    finally:
        _STATE.hints = prev


def _axes(label: str | None, h: dict):
    if label is None:
        return None
    out: list[str] = []
    for part in label.split("+"):
        ax = h.get(part)
        if ax is None:
            continue
        if isinstance(ax, (tuple, list)):
            out.extend(ax)
        else:
            out.append(ax)
    if not out:
        return None
    return tuple(out) if len(out) > 1 else out[0]


def constrain(x: jax.Array, *labels):
    """``with_sharding_constraint`` resolved through the active hints;
    identity when no hints are installed (or the constraint cannot be
    bound, e.g. no mesh context on a single-device backend)."""
    h = get_hints()
    if h is None:
        return x
    spec = P(*[_axes(l, h) for l in labels])
    try:
        if h.get("mesh") is not None:
            from jax.sharding import NamedSharding
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(h["mesh"], spec))
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError):
        return x
