"""Ring SpMM — node-sharded sparse aggregation over a device ring.

The feature matrix is row-sharded across the device ring; edges are
bucketed by (destination device, ring distance to the source device).
Each ring step k, every device holds the feature block of device
(i+k) mod P (rotated by collective-permute) and applies exactly the
edge bucket whose sources live on that device.  Compute on bucket k
overlaps the permute that fetches block k+1 — the same schedule the
paper's NUMA-blocked edge placement (Fig 11) exploits, and the
distributed analogue of keeping SpMM's accumulator tier-resident (§6):
the [n_local, D] output block never leaves the device.

``n_steps < P`` gives a banded ring: only the n_steps nearest source
owners are visited, which is the locality-aware partitioning knob used
by the launch cells (REPRO_RING_STEPS); edges outside the band are
dropped by ``bucket_edges`` (acceptable when the node ordering is
community-clustered, paper Fig 11).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P


def bucket_edges(src: np.ndarray, dst: np.ndarray, n_nodes: int, p: int,
                 coeff: np.ndarray | None = None, n_steps: int | None = None,
                 pad_multiple: int = 8):
    """Bucket edges by (dst device, relative ring step).

    Nodes are block-partitioned: device d owns rows [d*n_local,
    (d+1)*n_local).  Bucket [d, k] holds the edges whose dst lives on d
    and whose src lives on (d+k) mod p, with *local* row indices, padded
    to a uniform size.

    ``n_nodes`` must be a multiple of ``p``; ragged graphs are padded to
    the next multiple by the shard layer (``pipeline.shard``) before
    reaching here — padded rows simply own no edges.

    One lexsort pass groups every edge into its (d, k) bucket; within a
    bucket edges keep their original order (lexsort is stable), matching
    the per-bucket ``np.nonzero`` selection of the O(P·steps) loop this
    replaced (parity pinned by tests/test_distributed.py).

    Returns (src_l, dst_l, mask, n_local) — each array [p, n_steps, E_b]
    — or (src_l, dst_l, mask, coeff_l, n_local) when ``coeff`` is given.
    """
    if n_nodes % p:
        raise ValueError(f"n_nodes {n_nodes} not divisible by {p} devices; "
                         "pad via pipeline.shard.NodePartition")
    n_local = n_nodes // p
    steps = p if n_steps is None else n_steps
    src = np.asarray(src)
    dst = np.asarray(dst)
    sdev = src // n_local
    ddev = dst // n_local
    rel = (sdev - ddev) % p
    keep = np.nonzero(rel < steps)[0]          # banded ring drops the rest
    d_k = ddev[keep]
    k_k = rel[keep]
    order = np.lexsort((k_k, d_k))             # stable: (d, k), orig order
    sel = keep[order]
    flat_bucket = d_k[order] * steps + k_k[order]
    counts = np.bincount(flat_bucket, minlength=p * steps)
    emax = max(int(counts.max()) if counts.size else 1, 1)
    emax = int(np.ceil(emax / pad_multiple)) * pad_multiple
    starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
    within = np.arange(len(sel)) - np.repeat(starts, counts)
    slot = flat_bucket * emax + within         # position in the padded cube
    shape = (p, steps, emax)
    src_l = np.zeros(shape, np.int32)
    dst_l = np.zeros(shape, np.int32)
    mask = np.zeros(shape, bool)
    src_l.reshape(-1)[slot] = src[sel] % n_local
    dst_l.reshape(-1)[slot] = dst[sel] % n_local
    mask.reshape(-1)[slot] = True
    if coeff is not None:
        coeff_l = np.zeros(shape, np.float32)
        coeff_l.reshape(-1)[slot] = np.asarray(coeff)[sel]
        return src_l, dst_l, mask, coeff_l, n_local
    return src_l, dst_l, mask, n_local


def _bucket_edges_loop(src, dst, n_nodes: int, p: int, coeff=None,
                       n_steps: int | None = None, pad_multiple: int = 8):
    """The original O(P·steps) per-bucket selection loop, kept as the
    layout oracle for the vectorized ``bucket_edges`` (parity test)."""
    if n_nodes % p:
        raise ValueError(f"n_nodes {n_nodes} not divisible by {p} devices")
    n_local = n_nodes // p
    steps = p if n_steps is None else n_steps
    src = np.asarray(src)
    dst = np.asarray(dst)
    sdev = src // n_local
    ddev = dst // n_local
    rel = (sdev - ddev) % p
    keep = rel < steps
    buckets: dict[tuple[int, int], np.ndarray] = {}
    emax = 1
    for d in range(p):
        for k in range(steps):
            sel = np.nonzero((ddev == d) & (rel == k) & keep)[0]
            buckets[(d, k)] = sel
            emax = max(emax, len(sel))
    emax = int(np.ceil(emax / pad_multiple)) * pad_multiple
    shape = (p, steps, emax)
    src_l = np.zeros(shape, np.int32)
    dst_l = np.zeros(shape, np.int32)
    mask = np.zeros(shape, bool)
    coeff_l = np.zeros(shape, np.float32) if coeff is not None else None
    for (d, k), sel in buckets.items():
        e = len(sel)
        src_l[d, k, :e] = src[sel] % n_local
        dst_l[d, k, :e] = dst[sel] % n_local
        mask[d, k, :e] = True
        if coeff_l is not None:
            coeff_l[d, k, :e] = np.asarray(coeff)[sel]
    if coeff_l is not None:
        return src_l, dst_l, mask, coeff_l, n_local
    return src_l, dst_l, mask, n_local


def make_ring_spmm(mesh, axis, n_local: int, with_coeff: bool = False,
                   n_steps: int | None = None, relative_buckets: bool = True,
                   quantize: bool = False):
    """Build ring_spmm(x, src_l, dst_l, mask[, coeff]) -> A @ x over the
    flattened device ring of ``axis`` (one name or a tuple of names).

    x: [N, D] row-sharded on ``axis``; bucket arrays [P, S, E_b] sharded
    on their leading (dst-device) dim, as produced by ``bucket_edges``
    (which emits relative buckets — ``relative_buckets`` is accepted for
    signature stability and must stay True).

    ``quantize=True`` rotates an int8 payload instead of the fp32 block
    (``repro.api.CompressionCfg.ring``): each device quantizes its local
    block ONCE (symmetric per-block int8, deterministic round-to-nearest
    so forward and transpose rings see identical payloads) and the ring
    permutes (q int8, scale fp32 scalar) — 1/4 the bytes per rotation.
    The k=0 bucket still gathers from the exact local block, so local
    edges (the majority under community-clustered node orderings, paper
    Fig 11) see zero quantization error; only remote contributions pay
    the bounded <= scale/2 per-element rounding.  Per-step error
    feedback does not apply here — the payload is an *activation*
    rotated once per call, with no next step to carry a residual into;
    the gradient path's residuals live in ``pipeline.compress``.
    """
    if not relative_buckets:
        raise NotImplementedError("absolute bucket indexing was retired; "
                                  "bucket_edges emits relative buckets")
    axes = axis if isinstance(axis, (tuple, list)) else (axis,)
    axes = tuple(axes)
    p = int(np.prod([mesh.shape[a] for a in axes]))
    steps = p if n_steps is None else n_steps

    def local_fn(x, src_l, dst_l, mask, coeff=None):
        # shard_map blocks: x [n_local, D]; buckets [1, S, E_b]
        src_l = src_l[0]
        dst_l = dst_l[0]
        mask = mask[0]
        if coeff is not None:
            coeff = coeff[0]
        perm = [(j, (j - 1) % p) for j in range(p)]
        ax = axes if len(axes) > 1 else axes[0]

        def gather(k, x_rot, bs, bm):
            if quantize:
                q_rot, s_rot = x_rot
                deq = q_rot.astype(jnp.float32) * s_rot
                # the local (k=0) bucket reads the exact resident block
                xk = jnp.where(k == 0, x, deq)
            else:
                xk = x_rot
            return jnp.where(bm[:, None], xk[bs], 0.0)

        def rotate(x_rot):
            if quantize:
                q_rot, s_rot = x_rot
                return (jax.lax.ppermute(q_rot, ax, perm),
                        jax.lax.ppermute(s_rot, ax, perm))
            return jax.lax.ppermute(x_rot, ax, perm)

        def body(k, carry):
            acc, x_rot = carry
            bs = jax.lax.dynamic_index_in_dim(src_l, k, 0, keepdims=False)
            bd = jax.lax.dynamic_index_in_dim(dst_l, k, 0, keepdims=False)
            bm = jax.lax.dynamic_index_in_dim(mask, k, 0, keepdims=False)
            m = gather(k, x_rot, bs, bm)
            if coeff is not None:
                bc = jax.lax.dynamic_index_in_dim(coeff, k, 0, keepdims=False)
                m = m * bc[:, None]
            acc = acc + jax.ops.segment_sum(m, bd, num_segments=n_local)
            # rotate: after this permute device i holds block (i+k+1)%p
            return acc, rotate(x_rot)

        if quantize:
            scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
            q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
            payload = (q, scale)
        else:
            payload = x
        acc = jnp.zeros((n_local, x.shape[-1]), x.dtype)
        acc, _ = jax.lax.fori_loop(0, steps, body, (acc, payload))
        return acc

    xspec = P(axes if len(axes) > 1 else axes[0], None)
    bspec = P(axes if len(axes) > 1 else axes[0], None, None)
    in_specs = (xspec, bspec, bspec, bspec) + ((bspec,) if with_coeff else ())
    fn = shard_map(local_fn, mesh=mesh, in_specs=in_specs, out_specs=xspec)

    if with_coeff:
        def ring(x, src_l, dst_l, mask, coeff):
            return fn(x, src_l, dst_l, mask, coeff)
    else:
        def ring(x, src_l, dst_l, mask):
            return fn(x, src_l, dst_l, mask)
    return ring
