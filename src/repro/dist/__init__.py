"""Distributed-training utilities.

  hints      — ambient sharding hints (dp/tp axis names) that model code
               reads to pin intermediates without threading a mesh
               through every call;
  ring_spmm  — node-sharded SpMM over a device ring (overlapped
               collective-permute instead of GSPMD all-gather);
  subgraph   — the DistDGL-style subgraph-training baseline the paper
               compares single-machine full-graph training against.
"""
