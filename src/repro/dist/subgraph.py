"""DistDGL-style subgraph training baseline (paper §2.2 / §7.2).

Single-machine full-graph training is the paper's headline winner; this
module is the thing it wins against.  Each step dynamically builds k-hop
sampled message-flow blocks for the seed batch (the cost the paper's
Fig 14 breaks down), runs a mean-aggregation GNN over the blocks, and
backprops to the global embedding table.  Per-batch block construction
and the cross-batch vertex redundancy (paper Fig 2) are both accounted.

``max_subgraph_batch`` is the paper's Table 5 analytic memory model: the
expanded-vertex count per seed grows ~f^L with depth, so the maximum
batch that fits a fixed memory budget collapses exponentially — the
reason 3-layer DistDGL cannot run without sampling at any batch size.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.sampler import build_csr, sample_blocks, subgraph_redundancy


@dataclasses.dataclass
class StepStats:
    sample_s: float            # subgraph (block) construction time
    forward_s: float
    backward_s: float
    expanded_vertices: int     # unique vertices pulled in by sampling


def _block_forward(blocks_dev, x_all):
    """Mean-aggregation over the sampled blocks, deepest hop first.
    Returns seed-node embeddings [n_seeds, D]."""
    h = x_all[blocks_dev[0]["src_nodes"]]
    for b in blocks_dev:
        src, dst, mask = b["edge_src"], b["edge_dst"], b["edge_mask"]
        n_dst = b["dst_nodes"].shape[0]
        m = jnp.where(mask[:, None], h[src], 0.0)
        agg = jax.ops.segment_sum(m, dst, num_segments=n_dst)
        deg = jax.ops.segment_sum(mask.astype(h.dtype), dst,
                                  num_segments=n_dst)
        # self + mean-of-neighbours keeps the update well-defined on
        # zero-degree frontier nodes (dst_pos maps dst rows into the
        # sorted-unique src_nodes row order of h)
        h = 0.5 * h[b["dst_pos"]] + 0.5 * agg / jnp.maximum(deg, 1.0)[:, None]
    return h


class SubgraphTrainer:
    """Simulated n-worker DistDGL trainer on one host.

    The seed batch is split across ``n_workers``; each worker samples its
    own blocks (the paper's per-trainer subgraph construction) and the
    per-step stats aggregate across workers.  ``redundancy()`` reports
    the paper's Fig 2 metric over every batch stepped so far.
    """

    def __init__(self, src: np.ndarray, dst: np.ndarray, n_nodes: int,
                 n_layers: int = 2, fanout: int | None = 10,
                 n_workers: int = 1, seed: int = 0):
        self.g = build_csr(np.asarray(src), np.asarray(dst), n_nodes)
        self.n_nodes = n_nodes
        self.n_layers = n_layers
        self.fanout = fanout
        self.n_workers = max(1, n_workers)
        self.rng = np.random.default_rng(seed)
        self._batches: list = []   # per-batch block lists, for redundancy

    def step(self, seeds: np.ndarray, x_all: jax.Array, loss_fn,
             record: bool = True):
        """One training step: sample blocks, forward, backward.

        loss_fn(seed_embeddings, seed_ids) -> scalar.  Returns
        (grads w.r.t. x_all, StepStats).  ``record=False`` keeps the
        batch out of the redundancy accounting (warm-up/compile calls
        would otherwise double-count their vertices).
        """
        seeds = np.asarray(seeds, np.int32)
        fanouts = [self.fanout] * self.n_layers

        t0 = time.perf_counter()
        worker_blocks = []
        for w in range(self.n_workers):
            part = seeds[w::self.n_workers]
            if len(part) == 0:
                continue
            worker_blocks.append(
                sample_blocks(self.g, part, fanouts, self.rng))
        sample_s = time.perf_counter() - t0
        if record:
            self._batches.extend(worker_blocks)
        expanded = int(sum(
            len(np.unique(np.concatenate(
                [b.src_nodes[:b.n_src] for b in blocks])))
            for blocks in worker_blocks))

        # device-tensor conversion is part of subgraph construction
        # (DistDGL builds block tensors per batch), so it counts toward
        # sample_s
        t1 = time.perf_counter()
        bd_all = [[{"src_nodes": jnp.asarray(b.src_nodes),
                    "dst_nodes": jnp.asarray(b.dst_nodes),
                    "dst_pos": jnp.asarray(np.searchsorted(
                        b.src_nodes[:b.n_src], b.dst_nodes).astype(np.int32)),
                    "edge_src": jnp.asarray(b.edge_src),
                    "edge_dst": jnp.asarray(b.edge_dst),
                    "edge_mask": jnp.asarray(b.edge_mask)}
                   for b in blocks]
                  for blocks in worker_blocks]
        sample_s += time.perf_counter() - t1

        def total_loss(x):
            losses = [loss_fn(_block_forward(bd, x), bd[-1]["dst_nodes"])
                      for bd in bd_all]
            return sum(losses) / len(losses)

        t2 = time.perf_counter()
        jax.block_until_ready(total_loss(x_all))
        forward_s = time.perf_counter() - t2

        # one value_and_grad is what a real step runs; subtract the
        # measured forward so (forward_s + backward_s) ~= its wall time
        # instead of double-counting the forward recompute
        t3 = time.perf_counter()
        _, grads = jax.value_and_grad(total_loss)(x_all)
        jax.block_until_ready(grads)
        backward_s = max(time.perf_counter() - t3 - forward_s, 1e-9)
        return grads, StepStats(sample_s, forward_s, backward_s, expanded)

    def redundancy(self) -> float:
        """Paper Fig 2: total expanded vertices / unique vertices."""
        return subgraph_redundancy(self._batches)


def max_subgraph_batch(n_nodes_est_per_seed: float, embed_dim: int,
                       n_layers: int, mem_bytes: float,
                       fanout: int | None, avg_degree: float,
                       bytes_per_value: int = 4,
                       train_multiplier: float = 4.0) -> int:
    """Paper Table 5 analytic model: the largest seed batch whose expanded
    subgraph (activations + grads across layers) fits ``mem_bytes``.

    The frontier grows by min(fanout, avg_degree) per hop, so vertices
    per seed ~ sum_{l<=L} f^l — exponential in depth.  fanout=None is the
    'DistDGL w/o sampling' configuration (full neighbourhood, f=degree).
    """
    f = float(avg_degree if fanout is None else min(fanout, avg_degree))
    verts_per_seed = n_nodes_est_per_seed * sum(
        f ** l for l in range(n_layers + 1))
    # per expanded vertex: one activation row per layer boundary, doubled
    # for grads (train_multiplier folds grads + optimizer temps in)
    bytes_per_seed = (verts_per_seed * embed_dim * bytes_per_value *
                      train_multiplier * (n_layers + 1))
    return max(int(mem_bytes // max(bytes_per_seed, 1.0)), 0)
