"""Jit'd dispatch wrappers: impl='xla' (jnp gather/segment ops — used for
multi-pod lowering) vs impl='pallas' (TPU kernels; interpret=True on CPU).

The per-kernel write policy table is the productized form of the paper's
§6 guideline (nt-write for SDDMM, normal write for SpMM): the Pallas
kernels bake the policy into their memory structure, and the table is
what the TieredMemoryPlanner reads when costing kernel traffic.
"""
from __future__ import annotations

import jax

from repro.core import sparse_ops
from repro.kernels import embedding_bag as _eb
from repro.kernels import ref as _ref
from repro.kernels import sddmm as _sddmm
from repro.kernels import spmm as _spmm

# paper §6 guideline, per kernel
WRITE_POLICY = {
    "sddmm": "streaming",      # nt-write analogue: no VMEM accumulator
    "spmm": "accumulate",      # normal write: VMEM-resident accumulator
    "embedding_bag": "accumulate",
}


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def sddmm(op, x, y, src, dst, edge_mask, coeff=None, impl="xla", **kw):
    if impl == "xla":
        if op == "copy":
            return _ref.sddmm_ref(op, x, y, src, dst, edge_mask, coeff)
        return sparse_ops.sddmm(op, x, y, src, dst, edge_mask)
    return _sddmm.sddmm_pallas(op, x, y, src, dst, edge_mask, coeff,
                               interpret=not _on_tpu(), **kw)


def spmm_csr(reduce, values, indptr, src_sorted, n_nodes, gather=False,
             impl="xla", **kw):
    if impl == "xla":
        return _ref.spmm_csr_ref(reduce, values, indptr, src_sorted, n_nodes,
                                 gather=gather)
    return _spmm.spmm_csr_pallas(reduce, values, indptr, src_sorted, n_nodes,
                                 gather=gather, interpret=not _on_tpu(), **kw)


def embedding_bag(table, ids, mask, combiner="sum", impl="xla", **kw):
    if impl == "xla":
        return _ref.embedding_bag_ref(table, ids, mask, combiner)
    return _eb.embedding_bag_pallas(table, ids, mask, combiner,
                                    interpret=not _on_tpu(), **kw)
