"""Jit'd dispatch wrappers: impl='xla' (jnp gather/segment ops — used for
multi-pod lowering) vs impl='pallas' (TPU kernels; interpret=True on CPU).

The per-kernel write-policy table (the paper's §6 guideline: nt-write
for SDDMM, normal write for SpMM) is no longer hardcoded here — it is
*emitted from the placement plan* (``repro.memory.Plan.write_policy()``
/ ``TrainPlan.write_policy``), which knows the run's topology and where
each kernel's output stream actually lands.  The Pallas kernels bake
the structural side in (SDDMM streams with no VMEM accumulator, SpMM
accumulates).  The module-level ``WRITE_POLICY`` name survives as a
deprecated shim that answers with the default topology's table.
"""
from __future__ import annotations

import warnings

import jax

from repro.core import sparse_ops
from repro.kernels import ann as _ann
from repro.kernels import embedding_bag as _eb
from repro.kernels import hadamard_spmm as _hspmm
from repro.kernels import ref as _ref
from repro.kernels import sddmm as _sddmm
from repro.kernels import spmm as _spmm
from repro.kernels import topk_score as _topk


def __getattr__(name):
    if name == "WRITE_POLICY":
        warnings.warn(
            "repro.kernels.ops.WRITE_POLICY is deprecated; the per-kernel "
            "write-policy table is emitted from the placement plan "
            "(repro.memory.Plan.write_policy / TrainPlan.write_policy)",
            DeprecationWarning, stacklevel=2)
        from repro.memory import get_policy, get_topology
        plan = get_policy("all-fast")([], get_topology("tpu-hbm-host"))
        return plan.write_policy()
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def sddmm(op, x, y, src, dst, edge_mask, coeff=None, impl="xla", **kw):
    if impl == "xla":
        if op == "copy":
            return _ref.sddmm_ref(op, x, y, src, dst, edge_mask, coeff)
        return sparse_ops.sddmm(op, x, y, src, dst, edge_mask)
    return _sddmm.sddmm_pallas(op, x, y, src, dst, edge_mask, coeff,
                               interpret=not _on_tpu(), **kw)


def spmm_csr(reduce, values, indptr, src_sorted, n_nodes, gather=False,
             impl="xla", **kw):
    if impl == "xla":
        return _ref.spmm_csr_ref(reduce, values, indptr, src_sorted, n_nodes,
                                 gather=gather)
    return _spmm.spmm_csr_pallas(reduce, values, indptr, src_sorted, n_nodes,
                                 gather=gather, interpret=not _on_tpu(), **kw)


def hadamard_spmm(x, y, indptr, x_idx, y_idx, n_nodes, scale=None,
                  slope=None, structure="general", impl="xla", **kw):
    """Fused gather-Hadamard-aggregate: out[v] = sum_{e: dst_e = v}
    x[x_idx_e] * y[y_idx_e] with an optional (scale, leaky-relu)
    epilogue — NGCF's per-layer message without the [E, D] matrix.
    ``structure`` is the caller-asserted index invariant that lets the
    XLA route factor the Hadamard out of the aggregation (the Pallas
    kernel needs no structure: the product only ever exists in VMEM)."""
    if impl == "xla":
        return _hspmm.hadamard_spmm_xla(x, y, indptr, x_idx, y_idx,
                                        n_nodes, scale=scale, slope=slope,
                                        structure=structure)
    return _hspmm.hadamard_spmm_pallas(x, y, indptr, x_idx, y_idx, n_nodes,
                                       scale=scale, slope=slope,
                                       interpret=not _on_tpu(), **kw)


def embedding_bag(table, ids, mask, combiner="sum", impl="xla", **kw):
    if impl == "xla":
        return _ref.embedding_bag_ref(table, ids, mask, combiner)
    return _eb.embedding_bag_pallas(table, ids, mask, combiner,
                                    interpret=not _on_tpu(), **kw)


def ann_block_scores(ue, centroids_q, scale, radius, impl="xla", **kw):
    """ANN coarse stage: per-block score *upper bounds* over int8 block
    centroids — ``(u·ĉ_b)·scale_b + ‖u‖·radius_b``, f32[B, n_blocks].
    The serving ANN index prunes item blocks on this bound before the
    exact gather + ``fused_topk_score`` merge (``repro.serving.ann``)."""
    if impl == "xla":
        return _ref.ann_block_scores_ref(ue, centroids_q, scale, radius)
    return _ann.ann_block_scores_pallas(ue, centroids_q, scale, radius,
                                        interpret=not _on_tpu(), **kw)


def fused_topk_score(ue, table, seen, seen_mask, *, k, n_items,
                     item_block=1024, impl="xla", **kw):
    """Serving hot path: gather + score + seen-mask + top-K in one call.
    Returns (scores f32[B, k], ids i32[B, k]), (score desc, id asc)."""
    if impl == "xla":
        return _ref.fused_topk_score_ref(ue, table, seen, seen_mask, k=k,
                                         item_block=item_block,
                                         n_items=n_items)
    return _topk.fused_topk_score_pallas(ue, table, seen, seen_mask, k=k,
                                         item_block=item_block,
                                         n_items=n_items,
                                         interpret=not _on_tpu(), **kw)
