"""Pallas TPU embedding-bag — gather + in-VMEM segment reduce.

JAX has no native EmbeddingBag; this kernel IS the substrate for the
recsys architectures (DeepFM/xDeepFM/DLRM/BERT4Rec) and mirrors the
paper's capacity-tier residency: the table [V, D] stays in HBM (on a real
deployment, possibly host memory via the TieredMemoryPlanner) and only
the rows named by the batch are DMA'd into VMEM.

Bags are fixed-length padded (ids[B, L] + mask[B, L]) — the standard TPU
formulation of ragged multi-hot lookups.  Combiner: sum or mean.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import MEM_HBM, CompilerParams

DEFAULT_BAG_BLOCK = 8


def _kernel(ids, idmask, table_hbm, out_ref, row_buf, sem,
            *, bb: int, bag_len: int, combiner: str):
    blk = pl.program_id(0)
    out_ref[...] = jnp.zeros_like(out_ref)

    def bag_body(b, _):
        bag = blk * bb + b

        def item_body(l, cnt):
            pos = bag * bag_len + l
            live = idmask[pos] > 0
            idx = ids[pos]
            cp = pltpu.make_async_copy(table_hbm.at[pl.ds(idx, 1), :], row_buf, sem)
            cp.start()
            cp.wait()
            v = jnp.where(live, row_buf[0], 0.0)
            out_ref[b, :] = out_ref[b, :] + v
            return cnt + jnp.where(live, 1, 0)

        cnt = jax.lax.fori_loop(0, bag_len, item_body, 0, unroll=False)
        if combiner == "mean":
            denom = jnp.maximum(cnt, 1).astype(jnp.float32)
            out_ref[b, :] = out_ref[b, :] / denom
        return 0

    jax.lax.fori_loop(0, bb, bag_body, 0, unroll=False)


@functools.partial(jax.jit, static_argnames=("combiner", "bag_block", "interpret"))
def embedding_bag_pallas(table: jax.Array, ids: jax.Array, mask: jax.Array,
                         combiner: str = "sum",
                         bag_block: int = DEFAULT_BAG_BLOCK,
                         interpret: bool | None = None) -> jax.Array:
    """table: f32[V, D]; ids/mask: int32/bool[B, L] -> f32[B, D].
    interpret=None resolves from the backend (compiled on TPU,
    interpreter elsewhere)."""
    if combiner not in ("sum", "mean"):
        raise ValueError(combiner)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b_in, bag_len = ids.shape
    bb = min(bag_block, max(1, b_in))
    b_pad = ((b_in + bb - 1) // bb) * bb
    pad = b_pad - b_in
    ids_p = jnp.concatenate([ids, jnp.zeros((pad, bag_len), ids.dtype)]) if pad else ids
    mask_p = jnp.concatenate([mask, jnp.zeros((pad, bag_len), mask.dtype)]) if pad else mask
    d = table.shape[-1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b_pad // bb,),
        in_specs=[pl.BlockSpec(memory_space=MEM_HBM)],
        out_specs=pl.BlockSpec((bb, d), lambda i, *_: (i, 0)),
        scratch_shapes=[pltpu.VMEM((1, d), jnp.float32),
                        pltpu.SemaphoreType.DMA],
    )
    fn = pl.pallas_call(
        functools.partial(_kernel, bb=bb, bag_len=bag_len, combiner=combiner),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b_pad, d), jnp.float32),
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
        name=f"embedding_bag_{combiner}",
    )
    out = fn(ids_p.reshape(-1).astype(jnp.int32),
             mask_p.reshape(-1).astype(jnp.int32),
             table.astype(jnp.float32))
    return out[:b_in]
