"""Pallas TPU SDDMM — per-edge sampled dense-dense op, streaming store.

TPU adaptation of the paper's nt-write guidance (§6): SDDMM output (the
per-edge message matrix) has *no temporal locality* — each edge row is
produced once and never re-read by this kernel — so the kernel streams
each output block straight back to HBM and keeps **no VMEM-resident
accumulator**.  This is the TPU-native analogue of a non-temporal store
bypassing the cache hierarchy.

Structure:
  grid = (E_pad / EDGE_BLOCK,)  with ``dimension_semantics=arbitrary``
  src/dst/edge-mask (+ optional per-edge coeff) are scalar-prefetched to
  SMEM; the node-feature matrix stays in HBM and rows are DMA'd on demand
  into a double-buffered VMEM scratch pair.

Supported ops (mirrors core.sparse_ops.sddmm):
  'mul'  : m_e = x[src_e] * y[dst_e]            out [E, D]
  'add'  : m_e = x[src_e] + y[dst_e]            out [E, D]
  'dot'  : m_e = <x[src_e], y[dst_e]>           out [E, 1]
  'copy' : m_e = coeff_e * x[src_e]             out [E, D]  (coeff=1 if None)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import MEM_HBM, CompilerParams

DEFAULT_EDGE_BLOCK = 128


def _kernel(src_idx, dst_idx, emask, coeff, x_hbm, y_hbm, out_ref,
            a_buf, b_buf, sem_a, sem_b, *, op: str, eb: int):
    blk = pl.program_id(0)

    def body(i, _):
        e = blk * eb + i
        s = src_idx[e]
        ca = pltpu.make_async_copy(x_hbm.at[pl.ds(s, 1), :], a_buf, sem_a)
        ca.start()
        if op in ("mul", "add", "dot"):
            d = dst_idx[e]
            cb = pltpu.make_async_copy(y_hbm.at[pl.ds(d, 1), :], b_buf, sem_b)
            cb.start()
            ca.wait()
            cb.wait()
            a, b = a_buf[0], b_buf[0]
            if op == "mul":
                m = a * b
            elif op == "add":
                m = a + b
            else:  # dot
                m = jnp.sum(a * b)
        else:  # copy (optionally scaled)
            ca.wait()
            m = a_buf[0] * coeff[e]
        live = emask[e] > 0
        if op == "dot":
            out_ref[i, 0] = jnp.where(live, m, 0.0)
        else:
            out_ref[i, :] = jnp.where(live, m, 0.0)
        return 0

    jax.lax.fori_loop(0, eb, body, 0, unroll=False)


def _pad_to(arr, n, fill=0):
    pad = n - arr.shape[0]
    if pad == 0:
        return arr
    return jnp.concatenate([arr, jnp.full((pad,) + arr.shape[1:], fill, arr.dtype)])


@functools.partial(jax.jit, static_argnames=("op", "edge_block", "interpret"))
def sddmm_pallas(op: str, x: jax.Array, y: jax.Array, src: jax.Array,
                 dst: jax.Array, edge_mask: jax.Array,
                 coeff: jax.Array | None = None,
                 edge_block: int = DEFAULT_EDGE_BLOCK,
                 interpret: bool | None = None) -> jax.Array:
    """Pallas SDDMM.  x, y: f32[N, D]; src/dst: int32[E]; returns
    f32[E, D] (or f32[E] for op='dot').  interpret=None resolves from
    the backend (compiled on TPU, interpreter elsewhere)."""
    if op not in ("mul", "add", "dot", "copy"):
        raise ValueError(op)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    e_in = src.shape[0]
    eb = min(edge_block, max(8, e_in))
    e_pad = ((e_in + eb - 1) // eb) * eb
    src_p = _pad_to(src.astype(jnp.int32), e_pad)
    dst_p = _pad_to(dst.astype(jnp.int32), e_pad)
    mask_p = _pad_to(edge_mask.astype(jnp.int32), e_pad)
    if coeff is None:
        coeff_p = jnp.ones((e_pad,), jnp.float32)
    else:
        coeff_p = _pad_to(coeff.astype(jnp.float32), e_pad)

    d = x.shape[-1]
    out_d = 1 if op == "dot" else d
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(e_pad // eb,),
        in_specs=[pl.BlockSpec(memory_space=MEM_HBM),
                  pl.BlockSpec(memory_space=MEM_HBM)],
        # streaming store: each out block written exactly once (nt-write analog)
        out_specs=pl.BlockSpec((eb, out_d), lambda i, *_: (i, 0)),
        scratch_shapes=[pltpu.VMEM((1, d), jnp.float32),
                        pltpu.VMEM((1, d), jnp.float32),
                        pltpu.SemaphoreType.DMA, pltpu.SemaphoreType.DMA],
    )
    fn = pl.pallas_call(
        functools.partial(_kernel, op=op, eb=eb),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((e_pad, out_d), jnp.float32),
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
        name=f"sddmm_{op}",
    )
    out = fn(src_p, dst_p, mask_p, coeff_p, x.astype(jnp.float32),
             y.astype(jnp.float32))
    out = out[:e_in]
    return out[:, 0] if op == "dot" else out
