"""Pallas API compatibility across jax versions.

jax renamed the TPU-Pallas surface between 0.4.x and newer releases:
``pltpu.TPUMemorySpace`` -> ``pltpu.MemorySpace`` (and grew an ``HBM``
member; older versions spell HBM-resident refs as ``ANY``), and
``pltpu.TPUCompilerParams`` -> ``pltpu.CompilerParams``.  The kernels
import the canonical names from here so they run on either API.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

_MEMSPACE = getattr(pltpu, "MemorySpace", None) or pltpu.TPUMemorySpace

# HBM-resident ref (manually DMA'd inside the kernel): newer jax has an
# explicit HBM member; on older jax ``ANY`` leaves the buffer unpinned
# (in practice HBM) which is the same contract.
MEM_HBM = getattr(_MEMSPACE, "HBM", _MEMSPACE.ANY)

CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
