"""Pallas TPU fused serving kernel — gather + score + seen-mask + top-K.

The serving hot loop used to run as four separate XLA ops per item
block (row gather -> dense matmul -> seen-mask scatter -> ``lax.top_k``
merge), each a separate dispatch with its own HBM round-trip for the
score tile.  This kernel is the paper's §4 dataflow rewrite applied to
serving: the item table stays in HBM (on a real deployment, the
capacity tier) and each program

  * DMAs one item *block* of rows into VMEM (the row gather — only the
    block's bytes ever leave HBM),
  * scores its user tile against the block on the MXU,
  * masks already-seen items in place (no dense U×I boolean mask),
  * folds the block into a running per-user top-K carry,

so the score tile never leaves VMEM and the only HBM writes are the
final ``[B, K]`` results.  The grid tiles the *user batch* (tiles are
independent — no cross-program carry); the block loop runs inside each
program with the carry as a ``fori_loop`` value.

Tie-breaking contract (identical to ``eval/topk.py``'s streamed merge,
pinned by tests/test_kernel_parity.py): results are ordered by
(score desc, item id asc) because the carry precedes the block in the
top-k concatenation, block ids ascend, and earlier blocks hold lower
ids.  Scores equal to zero are canonicalized to +0.0 first —
``lax.top_k`` sorts by IEEE total order (-0.0 < +0.0) while
comparison-based dense sorts treat them as a tie.  Slots with fewer
than K scoreable candidates return id -1 with score -inf.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import MEM_HBM, CompilerParams

NEG_INF = float("-inf")
DEFAULT_USER_TILE = 64


def _kernel(table_hbm, ue_ref, seen_ref, smask_ref, out_s_ref, out_i_ref,
            blk_buf, sem, *, blk: int, n_blocks: int, n_items: int, k: int,
            seen_len: int):
    tile = ue_ref.shape[0]
    ue = ue_ref[...]

    def block_body(j, carry):
        carry_s, carry_i = carry
        start = j * blk
        cp = pltpu.make_async_copy(table_hbm.at[pl.ds(start, blk), :],
                                   blk_buf, sem)
        cp.start()
        cp.wait()
        scores = jnp.dot(ue, blk_buf[...].T,
                         preferred_element_type=jnp.float32)
        # -0.0 -> +0.0 before any masking: one total order for ties
        scores = jnp.where(scores == 0.0, 0.0, scores)
        ids = start + jax.lax.broadcasted_iota(jnp.int32, (tile, blk), 1)
        scores = jnp.where(ids < n_items, scores, NEG_INF)

        def seen_body(l, s):
            pos = seen_ref[:, l] - start                       # [tile]
            live = (smask_ref[:, l] > 0) & (pos >= 0) & (pos < blk)
            col = jax.lax.broadcasted_iota(jnp.int32, (tile, blk), 1)
            return jnp.where(live[:, None] & (col == pos[:, None]),
                             NEG_INF, s)

        scores = jax.lax.fori_loop(0, seen_len, seen_body, scores,
                                   unroll=False)
        cat_s = jnp.concatenate([carry_s, scores], axis=1)
        cat_i = jnp.concatenate([carry_i, ids], axis=1)
        top_s, idx = jax.lax.top_k(cat_s, k)
        return top_s, jnp.take_along_axis(cat_i, idx, axis=1)

    init = (jnp.full((tile, k), NEG_INF, jnp.float32),
            jnp.full((tile, k), -1, jnp.int32))
    carry_s, carry_i = jax.lax.fori_loop(0, n_blocks, block_body, init,
                                         unroll=False)
    out_s_ref[...] = carry_s
    out_i_ref[...] = carry_i


@functools.partial(jax.jit, static_argnames=("k", "item_block", "n_items",
                                             "user_tile", "interpret"))
def fused_topk_score_pallas(ue: jax.Array, table: jax.Array,
                            seen: jax.Array, seen_mask: jax.Array, *,
                            k: int, item_block: int, n_items: int,
                            user_tile: int = DEFAULT_USER_TILE,
                            interpret: bool = True):
    """ue: f32[B, D]; table: f32[I, D] (HBM-resident, block-DMA'd);
    seen/seen_mask: i32/bool[B, L] padded per-user seen-item ids ->
    (scores f32[B, k], ids i32[B, k])."""
    b_in, d = ue.shape
    blk = int(min(item_block, max(n_items, 1)))
    n_blocks = math.ceil(n_items / blk)
    tile = int(min(user_tile, max(b_in, 1)))
    b_pad = math.ceil(b_in / tile) * tile
    pad = b_pad - b_in
    ue = jnp.pad(ue, ((0, pad), (0, 0))) if pad else ue
    # the block DMA reads n_blocks*blk rows: pad the table tail once
    tpad = n_blocks * blk - table.shape[0]
    table = jnp.pad(table, ((0, tpad), (0, 0))) if tpad else table
    seen = jnp.asarray(seen, jnp.int32)
    seen_mask = jnp.asarray(seen_mask, jnp.int32)
    if seen.shape[1] == 0:                  # Pallas dislikes 0-wide blocks
        seen = jnp.zeros((b_in, 1), jnp.int32)
        seen_mask = jnp.zeros((b_in, 1), jnp.int32)
    if pad:
        seen = jnp.pad(seen, ((0, pad), (0, 0)))
        seen_mask = jnp.pad(seen_mask, ((0, pad), (0, 0)))
    seen_len = seen.shape[1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=0,
        grid=(b_pad // tile,),
        in_specs=[pl.BlockSpec(memory_space=MEM_HBM),
                  pl.BlockSpec((tile, d), lambda i: (i, 0)),
                  pl.BlockSpec((tile, seen_len), lambda i: (i, 0)),
                  pl.BlockSpec((tile, seen_len), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((tile, k), lambda i: (i, 0)),
                   pl.BlockSpec((tile, k), lambda i: (i, 0))],
        scratch_shapes=[pltpu.VMEM((blk, d), jnp.float32),
                        pltpu.SemaphoreType.DMA],
    )
    fn = pl.pallas_call(
        functools.partial(_kernel, blk=blk, n_blocks=n_blocks,
                          n_items=n_items, k=k, seen_len=seen_len),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((b_pad, k), jnp.float32),
                   jax.ShapeDtypeStruct((b_pad, k), jnp.int32)],
        compiler_params=CompilerParams(dimension_semantics=("arbitrary",)),
        interpret=interpret,
        name="fused_topk_score",
    )
    out_s, out_i = fn(table.astype(jnp.float32), ue.astype(jnp.float32),
                      seen, seen_mask)
    return out_s[:b_in], out_i[:b_in]
