"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def sddmm_ref(op: str, x: jax.Array, y: jax.Array, src: jax.Array,
              dst: jax.Array, edge_mask: jax.Array,
              coeff: jax.Array | None = None) -> jax.Array:
    a = x[src]
    if op == "copy":
        m = a if coeff is None else a * coeff[:, None]
    else:
        b = y[dst]
        if op == "mul":
            m = a * b
        elif op == "add":
            m = a + b
        elif op == "dot":
            m = jnp.sum(a * b, axis=-1)
        else:
            raise ValueError(op)
    mask = edge_mask if m.ndim == 1 else edge_mask[:, None]
    return jnp.where(mask, m, 0.0)


def spmm_csr_ref(reduce: str, values: jax.Array, indptr: jax.Array,
                 src_sorted: jax.Array, n_nodes: int,
                 gather: bool = False) -> jax.Array:
    e = src_sorted.shape[0] if gather else values.shape[0]
    # dst id per sorted edge from indptr
    dst = jnp.searchsorted(indptr, jnp.arange(e), side="right") - 1
    rows = values[src_sorted] if gather else values
    if reduce == "sum":
        return jax.ops.segment_sum(rows, dst, num_segments=n_nodes)
    if reduce == "max":
        out = jax.ops.segment_max(rows, dst, num_segments=n_nodes)
        return jnp.where(jnp.isfinite(out), out, 0.0)
    raise ValueError(reduce)


def hadamard_spmm_ref(x: jax.Array, y: jax.Array, indptr: jax.Array,
                      x_idx: jax.Array, y_idx: jax.Array, n_nodes: int,
                      scale: jax.Array | None = None,
                      slope: float | None = None) -> jax.Array:
    """Naive gather -> Hadamard -> segment-sum composition (the [E, D]
    message matrix the fused kernel avoids IS formed here — this is the
    parity ground truth, never a production route)."""
    e = x_idx.shape[0]
    dst = jnp.searchsorted(indptr, jnp.arange(e), side="right") - 1
    msgs = x.astype(jnp.float32)[x_idx] * y.astype(jnp.float32)[y_idx]
    out = jax.ops.segment_sum(msgs, dst, num_segments=n_nodes)
    if scale is not None:
        out = out * scale[:, None]
    if slope is not None:
        out = jnp.where(out >= 0, out, out * slope)
    return out


def embedding_bag_ref(table: jax.Array, ids: jax.Array, mask: jax.Array,
                      combiner: str = "sum") -> jax.Array:
    rows = table[ids]                                  # [B, L, D]
    rows = jnp.where(mask[..., None], rows, 0.0)
    out = rows.sum(axis=1)
    if combiner == "mean":
        cnt = jnp.maximum(mask.sum(axis=1), 1)
        out = out / cnt[:, None]
    return out


@jax.jit
def ann_block_scores_ref(ue: jax.Array, centroids_q: jax.Array,
                         scale: jax.Array, radius: jax.Array) -> jax.Array:
    """XLA oracle for the ANN coarse stage: per-block score upper bounds
    ``(u · ĉ_b)·scale_b + ‖u‖₂·radius_b`` over int8 block centroids.
    ue: f32[B, D]; centroids_q: i8[nb, D]; scale/radius: f32[nb] ->
    f32[B, nb].  The bound dominates every block member's exact score
    (see ``repro.serving.ann``), so pruning on it never drops a
    candidate whose bound clears the shortlist cut."""
    ue = ue.astype(jnp.float32)
    cent = centroids_q.astype(jnp.float32)
    dots = jnp.dot(ue, cent.T, preferred_element_type=jnp.float32)
    dots = dots * scale[None, :].astype(jnp.float32)
    unorm = jnp.sqrt(jnp.sum(ue * ue, axis=1, keepdims=True))
    return dots + unorm * radius[None, :].astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("k", "item_block", "n_items"))
def fused_topk_score_ref(ue: jax.Array, table: jax.Array, seen: jax.Array,
                         seen_mask: jax.Array, *, k: int, item_block: int,
                         n_items: int):
    """XLA oracle for the fused serving kernel: one jitted sweep over
    item blocks doing score -> -0.0 canonicalization -> seen-mask ->
    running top-K merge, with the exact per-block ops (and therefore the
    exact bit patterns and tie order) of ``eval.topk``'s streamed merge.
    Returns (scores f32[B, k], ids i32[B, k]); short slots are
    (-inf, -1)."""
    neg_inf = float("-inf")
    b = ue.shape[0]
    blk = int(min(item_block, max(n_items, 1)))
    n_blocks = -(-n_items // blk)
    tpad = n_blocks * blk - table.shape[0]
    table = jnp.pad(table, ((0, tpad), (0, 0))) if tpad else table
    table = table.astype(jnp.float32)
    ue = ue.astype(jnp.float32)
    seen = jnp.asarray(seen, jnp.int32)
    seen_mask = jnp.asarray(seen_mask, bool)
    rows_b = jnp.arange(b)[:, None]

    def body(j, carry):
        carry_s, carry_i = carry
        start = j * blk
        ie_blk = jax.lax.dynamic_slice_in_dim(table, start, blk, axis=0)
        scores = ue @ ie_blk.T
        scores = jnp.where(scores == 0.0, 0.0, scores)
        ids = start + jax.lax.broadcasted_iota(jnp.int32, (b, blk), 1)
        scores = jnp.where(ids < n_items, scores, neg_inf)
        pos = seen - start
        in_block = seen_mask & (pos >= 0) & (pos < blk)
        cols = jnp.where(in_block, pos, blk)           # overflow column
        hit = jnp.zeros((b, blk + 1), bool).at[rows_b, cols].set(True)[:, :blk]
        scores = jnp.where(hit, neg_inf, scores)
        cat_s = jnp.concatenate([carry_s, scores], axis=1)
        cat_i = jnp.concatenate([carry_i, ids], axis=1)
        top_s, idx = jax.lax.top_k(cat_s, k)
        return top_s, jnp.take_along_axis(cat_i, idx, axis=1)

    init = (jnp.full((b, k), neg_inf, jnp.float32),
            jnp.full((b, k), -1, jnp.int32))
    return jax.lax.fori_loop(0, n_blocks, body, init)
