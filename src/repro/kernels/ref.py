"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sddmm_ref(op: str, x: jax.Array, y: jax.Array, src: jax.Array,
              dst: jax.Array, edge_mask: jax.Array,
              coeff: jax.Array | None = None) -> jax.Array:
    a = x[src]
    if op == "copy":
        m = a if coeff is None else a * coeff[:, None]
    else:
        b = y[dst]
        if op == "mul":
            m = a * b
        elif op == "add":
            m = a + b
        elif op == "dot":
            m = jnp.sum(a * b, axis=-1)
        else:
            raise ValueError(op)
    mask = edge_mask if m.ndim == 1 else edge_mask[:, None]
    return jnp.where(mask, m, 0.0)


def spmm_csr_ref(reduce: str, values: jax.Array, indptr: jax.Array,
                 src_sorted: jax.Array, n_nodes: int,
                 gather: bool = False) -> jax.Array:
    e = src_sorted.shape[0] if gather else values.shape[0]
    # dst id per sorted edge from indptr
    dst = jnp.searchsorted(indptr, jnp.arange(e), side="right") - 1
    rows = values[src_sorted] if gather else values
    if reduce == "sum":
        return jax.ops.segment_sum(rows, dst, num_segments=n_nodes)
    if reduce == "max":
        out = jax.ops.segment_max(rows, dst, num_segments=n_nodes)
        return jnp.where(jnp.isfinite(out), out, 0.0)
    raise ValueError(reduce)


def embedding_bag_ref(table: jax.Array, ids: jax.Array, mask: jax.Array,
                      combiner: str = "sum") -> jax.Array:
    rows = table[ids]                                  # [B, L, D]
    rows = jnp.where(mask[..., None], rows, 0.0)
    out = rows.sum(axis=1)
    if combiner == "mean":
        cnt = jnp.maximum(mask.sum(axis=1), 1)
        out = out / cnt[:, None]
    return out
