"""Pallas TPU SpMM — CSR row-block aggregation with a VMEM accumulator.

TPU adaptation of the paper's write-policy finding (§6): SpMM *does* have
temporal locality — the destination row is touched once per incoming edge
— so unlike SDDMM the kernel keeps the output row block resident in VMEM
for the whole contraction and writes it back to HBM exactly once
("normal write" behaviour; nt-write would destroy the accumulator reuse,
the paper measured >20x slowdown).

Structure:
  edges are pre-sorted by destination (CSR); ``indptr`` and the sorted
  source indices are scalar-prefetched to SMEM; the message matrix (or,
  with gather=True, the node-feature matrix) stays in HBM and rows are
  DMA'd per edge into a small VMEM buffer; the out row-block [RB, D] is
  the VMEM accumulator.

Reduces: 'sum' (used by NGCF/LightGCN/GCN) and 'max' (generalized SpMM).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import MEM_HBM, CompilerParams

DEFAULT_ROW_BLOCK = 8


def _kernel(indptr, rows_src, x_hbm, out_ref, row_buf, sem,
            *, reduce: str, rb: int, gather: bool):
    blk = pl.program_id(0)
    init = 0.0 if reduce == "sum" else -jnp.inf
    out_ref[...] = jnp.full_like(out_ref, init)

    def row_body(r, _):
        row = blk * rb + r
        lo = indptr[row]
        hi = indptr[row + 1]

        # double-buffered row DMA: two VMEM row buffers + two
        # semaphores ping-pong over the edge loop, so edge e+1's fetch
        # overlaps edge e's accumulate instead of serializing on one
        # start();wait() pair
        def dma(e, slot):
            idx = rows_src[e] if gather else e
            return pltpu.make_async_copy(x_hbm.at[pl.ds(idx, 1), :],
                                         row_buf.at[slot], sem.at[slot])

        @pl.when(lo < hi)
        def _warmup():
            dma(lo, lo % 2).start()

        def edge_body(e, _):
            slot = e % 2

            @pl.when(e + 1 < hi)
            def _prefetch():
                dma(e + 1, (e + 1) % 2).start()

            dma(e, slot).wait()
            v = row_buf[slot, 0]
            if reduce == "sum":
                out_ref[r, :] = out_ref[r, :] + v
            else:
                out_ref[r, :] = jnp.maximum(out_ref[r, :], v)
            return 0

        # dynamic bounds (indptr in SMEM): older jax forbids `unroll` here
        jax.lax.fori_loop(lo, hi, edge_body, 0)
        return 0

    jax.lax.fori_loop(0, rb, row_body, 0, unroll=False)
    if reduce == "max":  # empty rows: -inf -> 0 (matches XLA oracle)
        out_ref[...] = jnp.where(jnp.isfinite(out_ref[...]), out_ref[...], 0.0)


@functools.partial(jax.jit, static_argnames=("reduce", "n_nodes", "row_block",
                                             "gather", "interpret"))
def spmm_csr_pallas(reduce: str, values: jax.Array, indptr: jax.Array,
                    src_sorted: jax.Array, n_nodes: int,
                    row_block: int = DEFAULT_ROW_BLOCK,
                    gather: bool = False,
                    interpret: bool | None = None) -> jax.Array:
    """CSR SpMM.

    values: f32[E, D] per-edge messages (gather=False) or f32[N_src, D]
      node features gathered through ``src_sorted`` (gather=True).
    indptr: int32[n_nodes+1] destination row pointers over dst-sorted edges.
    src_sorted: int32[E] source index per dst-sorted edge (used iff gather).
    interpret: None resolves from the backend (compiled on TPU,
      interpreter elsewhere), so direct callers bypassing ``kernels.ops``
      don't silently run interpreter-mode Pallas on TPU.
    """
    if reduce not in ("sum", "max"):
        raise ValueError(reduce)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    rb = row_block
    n_pad = ((n_nodes + rb - 1) // rb) * rb
    pad = n_pad - n_nodes
    indptr = indptr.astype(jnp.int32)
    if pad:
        indptr = jnp.concatenate([indptr, jnp.full((pad,), indptr[-1], jnp.int32)])
    d = values.shape[-1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n_pad // rb,),
        in_specs=[pl.BlockSpec(memory_space=MEM_HBM)],
        out_specs=pl.BlockSpec((rb, d), lambda i, *_: (i, 0)),
        scratch_shapes=[pltpu.VMEM((2, 1, d), jnp.float32),
                        pltpu.SemaphoreType.DMA((2,))],
    )
    fn = pl.pallas_call(
        functools.partial(_kernel, reduce=reduce, rb=rb, gather=gather),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_pad, d), jnp.float32),
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
        name=f"spmm_{reduce}",
    )
    out = fn(indptr, src_sorted.astype(jnp.int32), values.astype(jnp.float32))
    return out[:n_nodes]


def build_csr_by_dst(dst: np.ndarray, src: np.ndarray, n_nodes: int,
                     edge_mask: np.ndarray | None = None):
    """Host-side helper: sort edges by dst, build indptr.  Masked (padded)
    edges are dropped.  Returns (indptr, src_sorted, perm)."""
    dst = np.asarray(dst)
    src = np.asarray(src)
    if edge_mask is not None:
        keep = np.asarray(edge_mask).astype(bool)
        dst, src = dst[keep], src[keep]
        perm_base = np.nonzero(keep)[0]
    else:
        perm_base = np.arange(len(dst))
    order = np.argsort(dst, kind="stable")
    indptr = np.zeros(n_nodes + 1, dtype=np.int32)
    np.add.at(indptr, dst[order] + 1, 1)
    np.cumsum(indptr, out=indptr)
    return indptr, src[order].astype(np.int32), perm_base[order]
