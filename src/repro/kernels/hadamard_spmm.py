"""Pallas TPU fused Hadamard-SpMM — gather x multiply x aggregate in one pass.

The training-side half of the kernel-fusion work (serving shipped the
fused gather+score+top-K path): NGCF's per-layer message

    out[v] = sum_{e : dst_e = v}  x[x_idx_e] * y[y_idx_e]

is a gather-SDDMM ('mul') followed by an edge-aggregation SpMM, and the
intermediate [E, D] Hadamard matrix is exactly the |E|-sized stream the
paper's §4 rewrites try to keep off the capacity tier.  This kernel
fuses the three steps: per destination row block, the two source rows
of each edge are DMA'd HBM->VMEM (double-buffered so the next edge's
fetch overlaps the current multiply-accumulate), the Hadamard product
is formed *in VMEM*, and the row-block accumulator is written back to
HBM exactly once — the [E, D] message matrix never exists in HBM.

Write policy: the output keeps the SpMM side's temporal locality
(destination rows accumulate in VMEM, normal write), while the SDDMM
side's streaming store disappears entirely — its [E, D] output no
longer exists to be written.

Optional fused epilogue for NGCF's nonlinear layers: a per-node scale
(degree norm) and a leaky-relu, applied to the finished accumulator row
while it is still VMEM-resident.

``hadamard_spmm_xla`` is the production XLA route (CPU/GPU backends):
when the caller can assert structure on the index vectors — NGCF's four
call sites all can — the Hadamard factors out of the aggregation and
the XLA lowering also avoids the [E, D] intermediate:

  * ``y_is_dst``  (y_idx_e == dst_e):      out = y * spmm(gather x)
  * ``x_eq_y``    (x_idx_e == y_idx_e):    out = spmm(gather (x * y))
  * ``general``:  no structure — falls back to the naive gather/segment
                  composition (the parity oracle in ``kernels.ref``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import MEM_HBM, CompilerParams
from repro.kernels.spmm import DEFAULT_ROW_BLOCK

STRUCTURES = ("general", "y_is_dst", "x_eq_y")


def _kernel(indptr, x_idx, y_idx, scale, x_hbm, y_hbm, out_ref,
            x_buf, y_buf, sem_x, sem_y, *, rb: int, slope):
    blk = pl.program_id(0)
    out_ref[...] = jnp.zeros_like(out_ref)

    def row_body(r, _):
        row = blk * rb + r
        lo = indptr[row]
        hi = indptr[row + 1]

        def dma_pair(e, slot):
            cx = pltpu.make_async_copy(
                x_hbm.at[pl.ds(x_idx[e], 1), :], x_buf.at[slot],
                sem_x.at[slot])
            cy = pltpu.make_async_copy(
                y_hbm.at[pl.ds(y_idx[e], 1), :], y_buf.at[slot],
                sem_y.at[slot])
            return cx, cy

        @pl.when(lo < hi)
        def _warmup():
            cx, cy = dma_pair(lo, lo % 2)
            cx.start()
            cy.start()

        def edge_body(e, _):
            slot = e % 2

            # next edge's fetch overlaps this edge's multiply-accumulate
            @pl.when(e + 1 < hi)
            def _prefetch():
                cx, cy = dma_pair(e + 1, (e + 1) % 2)
                cx.start()
                cy.start()

            cx, cy = dma_pair(e, slot)
            cx.wait()
            cy.wait()
            # the Hadamard product lives only in VMEM, never in HBM
            out_ref[r, :] = out_ref[r, :] + x_buf[slot, 0] * y_buf[slot, 0]
            return 0

        jax.lax.fori_loop(lo, hi, edge_body, 0)
        # epilogue on the still-VMEM-resident accumulator row
        v = out_ref[r, :] * scale[row]
        if slope is not None:
            v = jnp.where(v >= 0, v, v * slope)
        out_ref[r, :] = v
        return 0

    jax.lax.fori_loop(0, rb, row_body, 0, unroll=False)


@functools.partial(jax.jit, static_argnames=("n_nodes", "row_block",
                                             "slope", "interpret"))
def hadamard_spmm_pallas(x: jax.Array, y: jax.Array, indptr: jax.Array,
                         x_idx: jax.Array, y_idx: jax.Array, n_nodes: int,
                         scale: jax.Array | None = None,
                         slope: float | None = None,
                         row_block: int = DEFAULT_ROW_BLOCK,
                         interpret: bool | None = None) -> jax.Array:
    """Fused gather-Hadamard-aggregate over a dst-sorted CSR.

    x: f32[N_x, D], y: f32[N_y, D] node features.
    indptr: int32[n_nodes+1] destination row pointers (dst-sorted edges).
    x_idx / y_idx: int32[E] per-edge row index into x / y.
    scale: optional f32[n_nodes] per-destination epilogue factor.
    slope: optional leaky-relu negative slope applied after ``scale``.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if x_idx.shape[0] == 0:
        # no edges: every row aggregates to zero, and the epilogue maps
        # zero to zero (scale and leaky-relu both fix the origin)
        return jnp.zeros((n_nodes, x.shape[-1]), jnp.float32)
    rb = row_block
    n_pad = ((n_nodes + rb - 1) // rb) * rb
    pad = n_pad - n_nodes
    indptr = indptr.astype(jnp.int32)
    if scale is None:
        scale = jnp.ones((n_nodes,), jnp.float32)
    scale = scale.astype(jnp.float32)
    if pad:
        indptr = jnp.concatenate(
            [indptr, jnp.full((pad,), indptr[-1], jnp.int32)])
        scale = jnp.concatenate([scale, jnp.zeros((pad,), jnp.float32)])
    d = x.shape[-1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(n_pad // rb,),
        in_specs=[pl.BlockSpec(memory_space=MEM_HBM),
                  pl.BlockSpec(memory_space=MEM_HBM)],
        out_specs=pl.BlockSpec((rb, d), lambda i, *_: (i, 0)),
        scratch_shapes=[pltpu.VMEM((2, 1, d), jnp.float32),
                        pltpu.VMEM((2, 1, d), jnp.float32),
                        pltpu.SemaphoreType.DMA((2,)),
                        pltpu.SemaphoreType.DMA((2,))],
    )
    fn = pl.pallas_call(
        functools.partial(_kernel, rb=rb, slope=slope),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_pad, d), jnp.float32),
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
        name="hadamard_spmm",
    )
    out = fn(indptr, x_idx.astype(jnp.int32), y_idx.astype(jnp.int32),
             scale, x.astype(jnp.float32), y.astype(jnp.float32))
    return out[:n_nodes]


def _epilogue(out, scale, slope):
    if scale is not None:
        out = out * scale[:, None]
    if slope is not None:
        out = jnp.where(out >= 0, out, out * slope)
    return out


def hadamard_spmm_xla(x: jax.Array, y: jax.Array, indptr: jax.Array,
                      x_idx: jax.Array, y_idx: jax.Array, n_nodes: int,
                      scale: jax.Array | None = None,
                      slope: float | None = None,
                      structure: str = "general") -> jax.Array:
    """XLA production route.  ``structure`` is a caller-asserted
    invariant on the index vectors that lets the Hadamard factor out of
    the aggregation — with it, no [E, D] intermediate is formed here
    either (the fused-NGCF jaxpr test pins that)."""
    if structure not in STRUCTURES:
        raise ValueError(f"structure must be one of {STRUCTURES}, "
                         f"got {structure!r}")
    from repro.kernels.ref import hadamard_spmm_ref, spmm_csr_ref
    if structure == "y_is_dst":
        # y rides the destination: out[v] = y[v] * sum_e x[x_idx_e]
        agg = spmm_csr_ref("sum", x.astype(jnp.float32), indptr,
                           x_idx.astype(jnp.int32), n_nodes, gather=True)
        return _epilogue(y.astype(jnp.float32) * agg, scale, slope)
    if structure == "x_eq_y":
        # both gathers share an index: the product forms at NODE level
        prod = x.astype(jnp.float32) * y.astype(jnp.float32)
        agg = spmm_csr_ref("sum", prod, indptr, x_idx.astype(jnp.int32),
                           n_nodes, gather=True)
        return _epilogue(agg, scale, slope)
    return hadamard_spmm_ref(x, y, indptr, x_idx, y_idx, n_nodes,
                             scale=scale, slope=slope)
