"""Pallas TPU coarse-scoring kernel for the block-pruned ANN retrieval.

The serving ANN index (``repro.serving.ann.AnnIndex``) summarizes each
item *block* by an int8-quantized centroid plus a fp32 radius (the max
member distance to the centroid, inflated by the centroid's own
quantization error).  For a query ``u`` the per-block score **upper
bound**

    ub[u, b] = (u · ĉ_b) · scale_b + ‖u‖₂ · radius_b

dominates every member's exact score (Cauchy-Schwarz:
``u·x = u·c + u·(x−c) ≤ u·ĉ·s + ‖u‖(‖c−ĉ·s‖ + max‖x−c‖)``), so blocks
whose bound falls below the shortlist cut can be skipped without ever
touching their rows.  This kernel computes the whole ``[B, n_blocks]``
bound matrix in one launch: the int8 centroid table dequantizes in
VMEM, the dot rides the MXU, and the norm·radius rank-1 term is fused
into the same tile — the bound matrix is tiny (n_blocks ≈ items/1024),
which is the entire point of the coarse stage.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(ue_ref, cq_ref, scale_ref, radius_ref, out_ref):
    ue = ue_ref[...]                                       # [B, D] f32
    cent = cq_ref[...].astype(jnp.float32)                 # [nb, D]
    dots = jnp.dot(ue, cent.T, preferred_element_type=jnp.float32)
    dots = dots * scale_ref[...]                           # [1, nb] bcast
    unorm = jnp.sqrt(jnp.sum(ue * ue, axis=1, keepdims=True))
    out_ref[...] = dots + unorm * radius_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def ann_block_scores_pallas(ue: jax.Array, centroids_q: jax.Array,
                            scale: jax.Array, radius: jax.Array,
                            interpret: bool = True) -> jax.Array:
    """ue: f32[B, D]; centroids_q: i8[nb, D]; scale/radius: f32[nb] ->
    per-block score upper bounds f32[B, nb]."""
    b, d = ue.shape
    nb = centroids_q.shape[0]
    # lane-align the block axis (f32 tiles are 8x128); the user axis
    # only needs sublane alignment
    nb_pad = math.ceil(nb / 128) * 128
    b_pad = math.ceil(b / 8) * 8
    ue = jnp.pad(ue.astype(jnp.float32), ((0, b_pad - b), (0, 0)))
    cq = jnp.pad(jnp.asarray(centroids_q, jnp.int8),
                 ((0, nb_pad - nb), (0, 0)))
    sc = jnp.pad(jnp.asarray(scale, jnp.float32),
                 (0, nb_pad - nb)).reshape(1, nb_pad)
    rad = jnp.pad(jnp.asarray(radius, jnp.float32),
                  (0, nb_pad - nb)).reshape(1, nb_pad)
    out = pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct((b_pad, nb_pad), jnp.float32),
        in_specs=[pl.BlockSpec(memory_space=pltpu.VMEM),
                  pl.BlockSpec(memory_space=pltpu.VMEM),
                  pl.BlockSpec(memory_space=pltpu.VMEM),
                  pl.BlockSpec(memory_space=pltpu.VMEM)],
        out_specs=pl.BlockSpec(memory_space=pltpu.VMEM),
        interpret=interpret,
        name="ann_block_scores",
    )(ue, cq, sc, rad)
    return out[:b, :nb]
