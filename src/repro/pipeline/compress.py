"""GradCompressor — the compressed gradient combine of one pipeline.

The paper's bandwidth argument (§2, §7) applies to every slow link a
gradient crosses: the cross-device all-reduce of the sharded step and
the capacity-tier round-trip of the tiered one.  This module replaces
the exact fp32 combine with the compressed collectives of
``repro.optim.compression`` when ``repro.api.CompressionCfg.grads``
selects a scheme:

  ``int8``  each participant stochastically quantizes its share and the
            exchange is an integer psum (int8 payload, int32
            accumulate) — a real integer all-reduce in the lowered HLO,
            1/4 the bytes on the wire;
  ``topk``  each participant keeps the k = frac x size largest-|.|
            entries of its share and the exchange all-gathers (values,
            indices) — 2k entries per device instead of the dense
            tensor; colliding indices accumulate exactly.

Error feedback (``error_feedback=True``, the default) carries each
participant's compression residual into its next share, which is what
makes both schemes converge to the exact trajectory instead of to a
biased neighborhood — pinned by tests/test_compression.py.

Sharded runs emulate the per-device decomposition explicitly: the
GSPMD-combined gradient ``g`` is split into P equal shares ``g/P`` (the
shares sum to the exact gradient, so the compressed sum is a faithful
stand-in for compressing P per-device local gradients), each share adds
its device's residual slice and quantizes under its own PRNG key inside
a ``shard_map``, and the exchange runs on the mesh for real.  The
residuals live in the training state as one ``[P, *leaf.shape]`` stack
per parameter, row-sharded over the data-parallel axes like every other
large table (``state["comp"]``).  Single-device runs use the same
primitives without the mesh (one share, no collective).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.optim.compression import (dequantize_int8,
                                     psum_int8_with_residual, quantize_int8,
                                     topk_allgather_sum, topk_densify,
                                     topk_sparsify, wire_bytes)

SCHEMES = ("int8", "topk")


class GradCompressor:
    """Compressed combine: ``(grads, comp) -> (combined, comp')``.

    Pure and jit-safe — the engine calls it inside the jitted update,
    so the integer psum / top-k all-gather lowers into the same
    program as the optimizer step.  ``comp`` is the compressor's slice
    of the training state: ``{"key": PRNGKey}`` plus, under error
    feedback, ``{"ef": stacked residual tree}``.
    """

    def __init__(self, scheme: str, frac: float = 0.01,
                 error_feedback: bool = True, shard=None):
        if scheme not in SCHEMES:
            raise ValueError(f"unknown compression scheme {scheme!r}; "
                             f"known: {SCHEMES} (or 'none' = no compressor)")
        if not 0.0 < frac <= 1.0:
            raise ValueError(f"compression frac must be in (0, 1], "
                             f"got {frac}")
        self.scheme = scheme
        self.frac = float(frac)
        self.error_feedback = bool(error_feedback)
        self.shard = shard if shard is not None and shard.is_sharded else None

    # ------------------------------------------------------------ state
    @property
    def n_shares(self) -> int:
        return self.shard.n_shards if self.shard is not None else 1

    def init_state(self, params, seed: int):
        """The ``state["comp"]`` slice: a PRNG key decorrelated from the
        model-init key, and zero residual stacks under error feedback
        ([P, *shape] per leaf — row-sharded over the mesh by the same
        shard_state rule as every other table)."""
        comp = {"key": jax.random.PRNGKey((int(seed) ^ 0x5EEDC0DE)
                                          & 0x7FFFFFFF)}
        if self.error_feedback:
            p = self.n_shares
            comp["ef"] = jax.tree.map(
                lambda g: jnp.zeros((p,) + tuple(g.shape), g.dtype), params)
        return comp

    def _zeros_ef(self, grads):
        p = self.n_shares
        return jax.tree.map(
            lambda g: jnp.zeros((p,) + tuple(g.shape), g.dtype), grads)

    # ------------------------------------------------------------ combine
    def __call__(self, grads, comp):
        key, sub = jax.random.split(comp["key"])
        ef = comp["ef"] if self.error_feedback else self._zeros_ef(grads)
        if self.shard is not None:
            combined, new_ef = self._combine_sharded(grads, ef, sub)
        else:
            combined, new_ef = self._combine_single(grads, ef, sub)
        out = {"key": key}
        if self.error_feedback:
            out["ef"] = new_ef
        return combined, out

    # ------------------------------------------------------- single-device
    def _compress_share(self, share, key):
        """One participant's (combined_contrib, residual) under the
        scheme — collective-free (the single-device path, where the
        'exchange' is the identity)."""
        if self.scheme == "int8":
            q, scale = quantize_int8(share, key)
            g_hat = dequantize_int8(q, scale)
            return g_hat, share - g_hat
        k = max(1, int(share.size * self.frac))
        vals, idx, residual = topk_sparsify(share, k)
        return topk_densify(vals, idx, share.shape), residual

    def _combine_single(self, grads, ef, key):
        leaves, treedef = jax.tree.flatten(grads)
        ef_leaves = jax.tree.flatten(ef)[0]
        outs, resids = [], []
        for i, (g, e) in enumerate(zip(leaves, ef_leaves)):
            g_hat, r = self._compress_share(g + e[0],
                                            jax.random.fold_in(key, i))
            outs.append(g_hat)
            resids.append(r[None])
        return (jax.tree.unflatten(treedef, outs),
                jax.tree.unflatten(treedef, resids))

    # ------------------------------------------------------------ sharded
    def _combine_sharded(self, grads, ef, key):
        """Per-leaf shard_map: every device compresses its share
        ``g/P + ef[d]`` under its own key and the exchange is the real
        collective on the mesh (integer psum / top-k all-gather) — the
        compressed all-reduce the lowered HLO can be asserted on."""
        mesh = self.shard.build_mesh()
        axes = self.shard.axes
        ax = axes if len(axes) > 1 else axes[0]
        p = self.shard.n_shards
        scheme, frac = self.scheme, self.frac

        leaves, treedef = jax.tree.flatten(grads)
        ef_leaves = jax.tree.flatten(ef)[0]
        outs, resids = [], []
        for i, (g, e) in enumerate(zip(leaves, ef_leaves)):
            keys = jax.random.split(jax.random.fold_in(key, i), p)

            def local(gf, ed, kd, _shape=g.shape):
                # blocks: gf full leaf (replicated), ed [1, *shape],
                # kd [1, 2] — this device's residual slice and key
                share = gf / p + ed[0]
                if scheme == "int8":
                    combined, r = psum_int8_with_residual(share, kd[0], ax)
                else:
                    k = max(1, int(share.size * frac))
                    vals, idx, r_flat = topk_sparsify(share, k)
                    combined = topk_allgather_sum(vals, idx, _shape, ax)
                    r = r_flat
                return combined, r[None]

            spec_full = P(*([None] * g.ndim))
            spec_stack = P(ax, *([None] * g.ndim))
            fn = shard_map(local, mesh=mesh,
                           in_specs=(spec_full, spec_stack, P(ax, None)),
                           out_specs=(spec_full, spec_stack),
                           check_rep=False)
            combined, r = fn(g, e, keys)
            outs.append(combined)
            resids.append(r)
        return (jax.tree.unflatten(treedef, outs),
                jax.tree.unflatten(treedef, resids))

    # ------------------------------------------------------------ pricing
    def wire_bytes_per_step(self, params) -> tuple[int, int]:
        """(compressed, exact) bytes ONE participant puts on the wire
        per combine — the analytic term benchmarks scale by
        (``BENCH_compression.json``)."""
        comp = exact = 0
        for g in jax.tree.leaves(params):
            comp += wire_bytes(g.size, self.scheme, self.frac)
            exact += wire_bytes(g.size, "none")
        return comp, exact

    def describe(self) -> str:
        ef = "+ef" if self.error_feedback else ""
        tk = f" frac={self.frac}" if self.scheme == "topk" else ""
        where = f"mesh P={self.n_shares}" if self.shard is not None \
            else "single"
        return f"GradCompressor[{self.scheme}{ef}{tk}] ({where})"
