"""Unified training engine (paper §7 end-to-end).

Composes the previously-disconnected subsystems into one pipeline:

  TieredMemoryPlanner  — placement over the run's actual tensor set,
                         re-run on the loop's re-layout requests;
  LargeBatchSchedule   — per-epoch batch + LR (warm-up batch = target/10
                         for the first epochs, linear LR scaling);
  microbatch gradient accumulation — the target batch B runs as
                         ceil(B/microbatch) accumulated microbatches so
                         the paper's 150K-sample batches fit a fixed
                         HBM budget;
  kernel-routed models — registry forwards aggregate through the
                         Pallas/XLA SpMM dispatch (pipeline.sparse);
  EdgeLoader           — deterministic resumable microbatch stream;
  ShardPlan            — mesh-parallel execution (pipeline.shard): ring
                         SpMM aggregation, dp-sharded batch chunks with
                         GSPMD-psum'd grads, per-device planner budgets,
                         the whole step under dist.hints sharding hints
                         (``step_context``);
  runtime.loop         — the fault-tolerant outer loop consumes
                         ``step_fn``/``on_relayout``/``step_context``
                         produced here (see runtime.loop.run_pipeline).

The loader iterates at *microbatch* granularity; one engine step drains
``microbatches_for_epoch(epoch)`` consecutive microbatches, so the
warm-up epochs automatically accumulate fewer microbatches per update.
"""
from __future__ import annotations

import contextlib
import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bpr
from repro.core.large_batch import LargeBatchSchedule
from repro.data.loader import EdgeLoader
from repro.data.synth import InteractionData
from repro.dist.hints import sharding_hints
from repro.memory import TieredExecutor, get_topology
from repro.optim import adam, sgd
from repro.pipeline.plan import TrainPlan, build_train_plan
from repro.pipeline.registry import get_model
from repro.pipeline.shard import ShardPlan
from repro.pipeline.sparse import BipartiteCSR, default_impl


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    arch: str = "lightgcn"
    embed_dim: int = 32
    n_layers: int = 2
    optimizer: str = "adam"            # 'adam' | 'sgd'
    base_lr: float = 1e-3
    base_batch: int = 256
    target_batch: int = 2048
    microbatch: int | None = None      # None -> derived from HBM headroom;
    #                                    per-SHARD when the mesh has P > 1
    warmup_epochs: int = 2
    lr_scaling: str = "linear"         # 'linear' | 'sqrt' (paper ablation)
    l2: float = 1e-4
    hbm_budget: int | None = None      # fast-tier budget override (bytes/device)
    impl: str | None = None            # kernel dispatch override; 'ring'
    #                                    forces the sharded aggregation route
    hadamard: str = "auto"             # NGCF Hadamard route: 'auto' |
    #                                    'fused' (no [E, D]) | 'composed'
    seed: int = 0
    # memory-tier subsystem (repro.memory): which registered topology
    # the run models, which placement policy assigns tensors to tiers,
    # per-tier capacity overrides, and name->tier pins.  The defaults
    # reproduce the pre-redesign planner bit for bit.
    memory_topology: str = "tpu-hbm-host"
    memory_policy: str = "greedy"
    memory_capacity: dict | None = None   # tier name -> bytes
    memory_pins: dict | None = None       # tensor (sub)name -> tier name
    # sharded execution (pipeline.shard.ShardPlan); the defaults are the
    # inert single-device plan — bit-identical to the unsharded pipeline
    mesh_shape: tuple[int, ...] = (1,)
    mesh_axes: tuple[str, ...] | None = None   # None -> auto axis names
    spmm: str | None = None            # None (auto) | 'ring'
    ring_steps: int | None = None      # banded ring band (n_steps < P)
    # byte compression on the slow links (repro.api.CompressionCfg ->
    # optim.compression): the gradient combine, capacity-tier embedding
    # storage, and the ring payload.  The 'none'/'fp32' defaults build
    # no compressor and stay bit-identical to the exact pipeline.
    grad_compression: str = "none"     # 'none' | 'int8' | 'topk'
    compression_frac: float = 0.01     # top-k kept fraction
    compression_ef: bool = True        # carry compression residuals
    embed_store: str = "fp32"          # 'fp32' | 'int8' slow-tier tables
    ring_compression: str = "none"     # 'none' | 'int8' ring payload
    # held-out streaming evaluation (repro.eval); cadence lives in the
    # loop's LoopConfig.eval_every — these shape one eval sweep
    eval_k: int = 20
    eval_user_batch: int | None = None  # None -> derived from HBM headroom
    eval_item_block: int = 1024


class Pipeline:
    """One training run: state, plan, and the step the loop executes."""

    def __init__(self, cfg: PipelineConfig, train: InteractionData,
                 holdout: InteractionData | None = None):
        self.cfg = cfg
        self.spec = get_model(cfg.arch)
        # one ShardPlan flows through every layer below; None = the
        # inert single-device path, bit-identical to the pre-shard
        # pipeline.  impl='ring' forces the ring route (BipartiteCSR
        # builds a degenerate 1-device plan when no mesh is configured).
        self.shard = ShardPlan.from_config(
            cfg.mesh_shape, cfg.mesh_axes, cfg.spmm, cfg.ring_steps,
            ring_quant=(cfg.ring_compression == "int8"))
        self.g = BipartiteCSR(train.user, train.item, train.n_users,
                              train.n_items, impl=cfg.impl, shard=self.shard,
                              hadamard=cfg.hadamard)
        self.shard = self.g.shard
        impl = self.g.impl                     # kernel impl: pallas | xla
        self.n_items = train.n_items

        params = self.spec.init(jax.random.PRNGKey(cfg.seed), train.n_users,
                                train.n_items, cfg.embed_dim, cfg.n_layers)
        self.opt = {"adam": adam, "sgd": sgd}[cfg.optimizer](cfg.base_lr)
        opt_state = self.opt.init(params)

        sched = LargeBatchSchedule(base_lr=cfg.base_lr,
                                   base_batch=cfg.base_batch,
                                   target_batch=cfg.target_batch,
                                   warmup_epochs=cfg.warmup_epochs,
                                   scaling=cfg.lr_scaling)
        self.topology = get_topology(cfg.memory_topology) \
            .with_capacity(cfg.memory_capacity or {})
        self.plan = build_train_plan(cfg.arch, self.spec, params, opt_state,
                                     self.g, cfg.n_layers, cfg.embed_dim,
                                     sched, impl, hbm_budget=cfg.hbm_budget,
                                     microbatch=cfg.microbatch,
                                     shard=self.shard,
                                     topology=self.topology,
                                     policy=cfg.memory_policy,
                                     pins=cfg.memory_pins,
                                     embed_store=cfg.embed_store)
        self.executor = TieredExecutor(self.plan.plan,
                                       embed_store=cfg.embed_store)
        # compressed gradient combine (None = exact fp32, bit-identical
        # to the pre-compression step).  Its residual/key state rides
        # the training state under "comp": the executor's fetch/commit
        # only walk params/opt, and shard_state row-shards the stacked
        # [P, ...] residuals over the mesh like any other large table.
        self.compressor = None
        if cfg.grad_compression != "none":
            from repro.pipeline.compress import GradCompressor
            self.compressor = GradCompressor(
                cfg.grad_compression, cfg.compression_frac,
                cfg.compression_ef, shard=self.shard)
        state0 = {"params": params, "opt": opt_state}
        if self.compressor is not None:
            state0["comp"] = self.compressor.init_state(params, cfg.seed)
        self._state0 = self.apply_plan(state0)

        # the loader iterates at GLOBAL microbatch granularity: one
        # loader batch feeds all P shards (microbatch rows each)
        self.loader = EdgeLoader(train.user, train.item,
                                 batch=self.plan.global_microbatch,
                                 seed=cfg.seed)
        self._next_step = 0

        n_layers = cfg.n_layers
        l2 = cfg.l2
        spec = self.spec
        g = self.g

        @jax.jit
        def micro_value_and_grad(params, users, pos, neg):
            def loss_fn(p):
                ue, ie = spec.forward(p, g, n_layers)
                return bpr.bpr_loss(ue, ie, users, pos, neg, l2=l2)
            return jax.value_and_grad(loss_fn)(params)

        compressor = self.compressor

        @jax.jit
        def apply_update(state, grads, lr):
            out = {}
            if compressor is not None:
                grads, out["comp"] = compressor(grads, state["comp"])
            p, o = self.opt.update(grads, state["opt"], state["params"],
                                   lr=lr)
            out["params"], out["opt"] = p, o
            return out

        self._micro_value_and_grad = micro_value_and_grad
        self._apply_update = apply_update

        self.eval_fn = None                # (state, step) -> metrics dict
        self._test_pos = None
        if holdout is not None:
            self.attach_holdout(holdout)

    # ---------------------------------------------------------------- state
    def init_state(self):
        return self._state0

    def apply_plan(self, state):
        """Place every state leaf onto its planned memory tier (used on
        fresh state, after re-layout, and on checkpoint restore — raw
        restored leaves otherwise land back in the fast tier).

        The ``TieredExecutor`` makes the demotion real on every
        backend: leaves go to their tier's JAX memory kind when the
        backend has one (TPU), and into the executor's host byte store
        otherwise — ``step_fn`` then streams them device-ward per step
        (``fetch``) and writes updates back (``commit``).

        Sharded runs place onto the MESH instead: large tables
        row-sharded (the per-device capacity relief), small leaves
        replicated.  Host-tier demotions are not applied there — a
        mesh NamedSharding and a host-memory-kind placement are
        mutually exclusive device_puts, and silently doing one after
        the other would just undo the first — so ``n_offloaded`` stays
        0 and the tier plan remains documented intent that drives the
        per-device microbatch derivation."""
        if self.shard is not None and self.shard.is_sharded:
            self.n_offloaded = 0
            return self.shard.shard_state(state)
        state, self.n_offloaded = self.executor.place(state)
        return state

    def step_context(self):
        """The ambient context one engine step runs under: dp/mesh
        sharding hints on a sharded run (``dist.hints``), nothing on a
        single-device run.  The fault-tolerant loop enters this around
        the steps it drives (``runtime.loop.run_training``)."""
        if self.shard is None:
            return contextlib.nullcontext()
        return sharding_hints(dp=self.shard.dp, mesh=self.shard.build_mesh())

    def _device_batch(self, users, pos, neg):
        """Host arrays -> device arrays, leading dim sharded over the
        mesh's data-parallel axes when the run is sharded."""
        u, p, n = jnp.asarray(users), jnp.asarray(pos), jnp.asarray(neg)
        if self.shard is not None and self.shard.is_sharded:
            u, p, n = self.shard.shard_batch(u, p, n)
        return u, p, n

    @property
    def sched(self) -> LargeBatchSchedule:
        return self.plan.sched

    def out_dim(self) -> int:
        """Final embedding width, per the model's own contract."""
        return self.spec.out_dim(self.cfg.embed_dim, self.cfg.n_layers)

    def lr_for_epoch(self, epoch: int) -> float:
        """LR scaled to the batch *actually run* this epoch — the
        schedule batch rounded up to a whole number of GLOBAL
        microbatches (all P shards' samples count toward the realized
        batch) — so the Goyal scaling rule tracks the realized batch
        size and a sharded run scales exactly like the single-device
        run with the same global batch."""
        actual = self.plan.microbatches_for_epoch(epoch) \
            * self.plan.global_microbatch
        return self.sched.scaled_lr(actual)

    def steps_per_epoch(self, epoch: int) -> int:
        spe_micro = self.loader.steps_per_epoch()
        return max(1, spe_micro // self.plan.microbatches_for_epoch(epoch))

    def steps_for_epochs(self, n_epochs: int) -> int:
        return sum(self.steps_per_epoch(e) for e in range(n_epochs))

    # ---------------------------------------------------------------- step
    def grads_for_batch(self, params, users, pos, neg):
        """Microbatched gradient accumulation over one target batch.

        Per-chunk mean-loss gradients are combined weighted by chunk
        size, so the result equals the full-batch gradient even when the
        batch is not a microbatch multiple (pinned by
        tests/test_pipeline.py).  Returns (mean_loss, grads).  A ragged
        final chunk costs one extra jit trace; loader-fed batches are
        always full microbatches.

        Sharded runs chunk at the GLOBAL microbatch (P x per-shard
        microbatch) and shard each chunk's rows over the mesh, so every
        device computes its per-shard slice and GSPMD all-reduces
        (psums) the gradients of the replicated-or-row-sharded params.
        """
        mu = self.plan.global_microbatch
        n = len(users)
        k = max(1, math.ceil(n / mu))
        loss_sum = None      # device scalar: no host sync inside the loop
        acc = None
        for c in range(k):
            sl = slice(c * mu, min((c + 1) * mu, n))
            w = (sl.stop - sl.start) / n
            loss, grads = self._micro_value_and_grad(
                params, *self._device_batch(users[sl], pos[sl], neg[sl]))
            wl = loss * w
            wg = jax.tree.map(lambda t: t * w, grads)
            loss_sum = wl if loss_sum is None else loss_sum + wl
            acc = wg if acc is None else jax.tree.map(jnp.add, acc, wg)
        return float(loss_sum), acc

    def _next_target_batch(self, k: int, step: int):
        """Drain k loader microbatches into one (u, i+, i-) target batch.
        Negatives are seeded per (run seed, step) so a resumed run draws
        the same samples as an uninterrupted one."""
        us, ps = [], []
        for _ in range(k):
            u, i = next(self.loader)
            us.append(u)
            ps.append(i)
        users = np.concatenate(us)
        pos = np.concatenate(ps)
        rng = np.random.default_rng((self.cfg.seed, step))
        neg = rng.integers(0, self.n_items, len(users)).astype(np.int32)
        return users, pos, neg

    def _micro_pos(self) -> int:
        """Loader position as a linear microbatch counter.  EdgeLoader
        rolls epochs lazily (state (e, spe) before the roll), and
        ``g = e*spe + s`` makes consumption exactly ``g += 1``."""
        st = self.loader.state
        return st.epoch * self.loader.steps_per_epoch() + st.step

    def current_epoch(self) -> int:
        """The epoch the NEXT microbatch will come from (post-roll), so
        the first step of an epoch uses that epoch's batch and LR."""
        return self._micro_pos() // self.loader.steps_per_epoch()

    def seek(self, step: int) -> None:
        """Position the loader as if ``step`` pipeline steps had already
        run, so a checkpoint-resumed loop continues mid-schedule (same
        epoch, same accumulation factor, same sample order).  Closed
        form over epoch segments (each step consumes k(epoch)
        microbatches), so a deep resume costs O(epochs), not O(steps)."""
        from repro.data.loader import LoaderState
        spe = self.loader.steps_per_epoch()
        g = 0
        done = 0
        while done < step:
            e = g // spe
            k = self.plan.microbatches_for_epoch(e)
            # steps until the next epoch boundary can change k (the step
            # crossing the boundary still uses this epoch's k)
            t = min(step - done, max(1, math.ceil(((e + 1) * spe - g) / k)))
            g += t * k
            done += t
        if g == 0:
            self.loader.state = LoaderState(0, 0)
        else:
            e = (g - 1) // spe
            self.loader.state = LoaderState(e, g - e * spe)
        self._next_step = step

    def step_fn(self, state, step: int):
        """(state, step) -> (state, loss): the loop-consumable step.
        The CALLER enters ``step_context()`` around it — the
        fault-tolerant loop does so for every step it drives
        (``run_training(step_context=...)``), and ``repro.api.Run.step``
        for direct single steps — so the sharded accumulation step sees
        the dp/mesh sharding hints exactly once."""
        if step != self._next_step:
            self.seek(step)
        epoch = self.current_epoch()
        k = self.plan.microbatches_for_epoch(epoch)
        users, pos, neg = self._next_target_batch(k, step)
        # slow-tier leaves stream device-ward once per step (the tables
        # don't change inside one accumulated batch) through the
        # executor's double buffer, and the updated bytes stream back
        # afterwards — identity when nothing is demoted off-device.
        state = self.executor.fetch(state)
        loss, grads = self.grads_for_batch(state["params"], users, pos, neg)
        lr = jnp.float32(self.lr_for_epoch(epoch))
        self._next_step = step + 1
        return self.executor.commit(self._apply_update(state, grads, lr)), loss

    def on_relayout(self, state):
        """Loop straggler escalation: re-run the planner over the current
        tensor set and re-place the state (paper §8.1 automation).  On a
        sharded run the re-plan stays per shard: per-device profiles
        against the per-device budget, and the re-placed state goes back
        onto the mesh (``apply_plan``'s shard step)."""
        cfg = self.cfg
        self.plan = build_train_plan(
            cfg.arch, self.spec, state["params"], state["opt"], self.g,
            cfg.n_layers, cfg.embed_dim, self.sched, self.plan.impl,
            hbm_budget=cfg.hbm_budget, microbatch=self.plan.microbatch,
            shard=self.shard, topology=self.topology,
            policy=cfg.memory_policy, pins=cfg.memory_pins,
            embed_store=cfg.embed_store)
        self.executor = TieredExecutor(self.plan.plan,
                                       embed_store=cfg.embed_store)
        return self.apply_plan(state)

    # ---------------------------------------------------------------- eval
    def embeddings(self, state):
        """Final (user, item) embeddings for evaluation."""
        return self.spec.forward(state["params"], self.g, self.cfg.n_layers)

    def attach_holdout(self, holdout: InteractionData) -> None:
        """Enable periodic held-out evaluation: sets ``eval_fn`` (which
        the fault-tolerant loop calls every ``LoopConfig.eval_every``
        steps, appending to the report's metric history).  Evaluation
        rides the streaming top-K path — train items masked via the CSR
        structure, never a dense U×I matrix."""
        from repro.data.synth import group_by_user
        self._test_pos = group_by_user(holdout.user, holdout.item,
                                       self.g.n_users)

        def eval_fn(state, step):
            return self.evaluate(state)

        self.eval_fn = eval_fn

    def eval_user_batch(self) -> int:
        """User microbatch for one eval sweep: configured, or derived
        from the HBM left after the training plan's placements."""
        if self.cfg.eval_user_batch is not None:
            return int(self.cfg.eval_user_batch)
        from repro.pipeline.plan import derive_eval_batch
        free = self.plan.hbm_budget - self.plan.plan.hbm_used
        return derive_eval_batch(free, self.out_dim(), self.cfg.eval_k,
                                 self.cfg.eval_item_block)

    def evaluate(self, state) -> dict:
        """One held-out eval sweep (recall/NDCG@eval_k + MRR) through
        ``repro.eval`` on the current state."""
        if self._test_pos is None:
            raise RuntimeError("no holdout attached; call attach_holdout")
        from repro.eval import evaluate_embeddings   # lazy: engine<->eval
        with self.step_context():
            ue, ie = self.embeddings(state)
        indptr, items = self.g.seen_csr()
        return evaluate_embeddings(
            ue, ie, self._test_pos, k=self.cfg.eval_k,
            seen_indptr=indptr, seen_items=items,
            user_batch=self.eval_user_batch(),
            item_block=self.cfg.eval_item_block, impl=self.plan.impl,
            shard=self.shard)


def build_pipeline(cfg: PipelineConfig, train: InteractionData,
                   holdout: InteractionData | None = None) -> Pipeline:
    return Pipeline(cfg, train, holdout=holdout)
