"""ShardPlan — the mesh-parallel execution contract of one training run.

The paper's headline comparison (§7, Table 6) pits single-machine
full-graph training against distributed subgraph training; this module
is what lets the full-graph side *scale out* without changing its math.
One ``ShardPlan`` describes the whole sharded execution and flows
through every layer:

  * ``pipeline.sparse``   — routes model aggregation through the ring
    SpMM (``dist.ring_spmm``) when ``wants_ring``: features row-sharded
    over the device ring, edges bucketed by (dst device, ring distance),
    the NUMA-blocked Fig 11 schedule as collective-permutes;
  * ``pipeline.plan``     — profiles *per-device* tensor shards and runs
    the tiered-memory knapsack against the per-device HBM budget; the
    derived microbatch is the per-shard microbatch (global batch =
    ``n_shards x microbatch x accum``);
  * ``pipeline.engine`` / ``runtime.loop`` — the accumulation step runs
    under ``dist.hints.sharding_hints`` with the batch sharded over the
    data-parallel axes and gradients combined by GSPMD all-reduce
    (psum);
  * ``repro.api``         — ``MeshCfg`` on the ExperimentSpec is the
    declarative surface that builds one of these;
  * ``eval.topk``         — streaming top-K shards its user batches over
    the same axes.

Node partitioning follows GNNear's partition-the-aggregation design:
each device owns a contiguous block of the *unified* node space (users
then items), with the node count padded up to the next multiple of the
shard count — padded rows have no edges, so they aggregate to zero and
are sliced off (see ``NodePartition``).  The single-device plan
(``shape=(1,)``, no explicit spmm) is inert: every helper degenerates
to the identity and the unsharded pipeline path is taken bit-for-bit.
"""
from __future__ import annotations

import dataclasses
import functools
import math

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P


def auto_axes(shape) -> tuple[str, ...]:
    """Default axis names for a mesh shape: the shard layer treats every
    axis as data-parallel (model parallelism is out of scope here), so
    the names only need to be unique and recognizable."""
    n = len(tuple(shape))
    if n == 1:
        return ("data",)
    if n == 2:
        return ("pod", "data")
    return tuple(f"data{i}" for i in range(n))


@functools.lru_cache(maxsize=None)
def _mesh_for(shape: tuple[int, ...], axes: tuple[str, ...]):
    """One mesh per (shape, axes) per process — meshes are cheap but
    building them repeatedly defeats jit caching of shard_mapped fns."""
    n_dev = len(jax.devices())
    need = int(np.prod(shape))
    if need > n_dev:
        raise ValueError(
            f"mesh shape {shape} needs {need} devices but only {n_dev} "
            f"are visible; on CPU CI export "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={need}")
    return jax.make_mesh(tuple(shape), tuple(axes))


@dataclasses.dataclass(frozen=True)
class NodePartition:
    """Block partition of ``n_nodes`` rows over ``n_shards`` devices,
    padded so every device owns the same number of rows.  Padded rows
    carry no edges, so sharded aggregation leaves them zero and callers
    slice them off (``trim``)."""
    n_nodes: int
    n_shards: int

    @property
    def n_pad(self) -> int:
        """Node count rounded up to the next multiple of the shard
        count (the satellite fix for ``bucket_edges``'s hard
        divisibility requirement)."""
        return math.ceil(self.n_nodes / self.n_shards) * self.n_shards

    @property
    def n_local(self) -> int:
        return self.n_pad // self.n_shards

    def pad_rows(self, x):
        """[n_nodes, D] -> [n_pad, D], zero rows appended."""
        import jax.numpy as jnp
        extra = self.n_pad - self.n_nodes
        if extra == 0:
            return x
        return jnp.pad(x, ((0, extra), (0, 0)))

    def trim(self, x):
        """[n_pad, D] -> [n_nodes, D]: mask the padded rows back out of
        the aggregation result."""
        return x if self.n_pad == self.n_nodes else x[:self.n_nodes]


@dataclasses.dataclass(frozen=True)
class ShardPlan:
    """Mesh shape/axes + SpMM dispatch + ring band for one run.

    ``spmm``: ``None`` = auto (ring when the mesh has >1 device, the
    plain kernel dispatch otherwise); ``"ring"`` forces the ring path
    even on a 1-device mesh (degenerate ring — useful for testing the
    dispatch without multiple devices).
    """
    shape: tuple[int, ...] = (1,)
    axes: tuple[str, ...] = ("data",)
    spmm: str | None = None          # None (auto) | 'ring'
    ring_steps: int | None = None    # banded ring: visit only n_steps owners
    ring_quant: bool = False         # int8 ring payload rotation
    #                                  (CompressionCfg.ring = 'int8')

    def __post_init__(self):
        object.__setattr__(self, "shape", tuple(int(s) for s in self.shape))
        object.__setattr__(self, "axes", tuple(str(a) for a in self.axes))
        object.__setattr__(self, "ring_quant", bool(self.ring_quant))
        if len(self.shape) != len(self.axes):
            raise ValueError(f"mesh shape {self.shape} has "
                             f"{len(self.shape)} dims but axes {self.axes} "
                             f"name {len(self.axes)}")
        if self.spmm not in (None, "ring"):
            raise ValueError(f"unknown spmm dispatch {self.spmm!r}; "
                             "known: None (auto), 'ring'")
        if self.ring_steps is not None and self.ring_steps < 1:
            raise ValueError(f"ring_steps must be >= 1 (or None for the "
                             f"full ring), got {self.ring_steps}")

    # ------------------------------------------------------------ shape
    @property
    def n_shards(self) -> int:
        return int(np.prod(self.shape))

    @property
    def is_sharded(self) -> bool:
        return self.n_shards > 1

    @property
    def wants_ring(self) -> bool:
        """Route aggregation through ``dist.ring_spmm``?"""
        return self.spmm == "ring" or (self.spmm is None and self.is_sharded)

    @property
    def dp(self):
        """The data-parallel axis argument (one name or a tuple) for
        ``make_ring_spmm`` / ``sharding_hints``."""
        return self.axes if len(self.axes) > 1 else self.axes[0]

    # ------------------------------------------------------------ mesh
    def build_mesh(self):
        return _mesh_for(self.shape, self.axes)

    def partition(self, n_nodes: int) -> NodePartition:
        return NodePartition(int(n_nodes), self.n_shards)

    # ------------------------------------------------------------ placement
    def batch_sharding(self, ndim: int = 1) -> NamedSharding:
        """Leading dim sharded over every mesh axis, the rest replicated
        — the per-shard view of a (users, pos, neg) batch chunk."""
        spec = P(self.dp, *([None] * (ndim - 1)))
        return NamedSharding(self.build_mesh(), spec)

    def shard_batch(self, *arrays):
        """device_put each array with its leading dim sharded over the
        mesh.  Arrays whose leading dim does not divide the shard count
        are left unsharded (replicated by jit) — the engine only feeds
        divisible chunks on the hot path."""
        out = []
        p = self.n_shards
        for a in arrays:
            if a.shape[0] % p == 0:
                a = jax.device_put(a, self.batch_sharding(a.ndim))
            out.append(a)
        return tuple(out) if len(out) > 1 else out[0]

    def _leaf_sharding(self, leaf) -> NamedSharding:
        """Row-shard embedding-table-like leaves (>=2 dims, leading dim
        divisible by the shard count); replicate everything else.  This
        is the storage analogue of the per-worker memory budget framing
        (MTrainS): each shard holds 1/P of every large table."""
        mesh = self.build_mesh()
        if getattr(leaf, "ndim", 0) >= 2 and leaf.shape[0] % self.n_shards == 0:
            return NamedSharding(mesh, P(self.dp, *([None] * (leaf.ndim - 1))))
        return NamedSharding(mesh, P())

    def shard_state(self, tree):
        """Place every state leaf onto the mesh: large tables row-sharded,
        small leaves replicated.  Identity on a 1-device mesh."""
        if not self.is_sharded:
            return tree
        return jax.tree.map(
            lambda leaf: jax.device_put(leaf, self._leaf_sharding(leaf)),
            tree)

    def shard_divisor(self, leaf_shape) -> int:
        """How many ways a tensor of this shape is split per device —
        the planner divides its nbytes by this (per-device profiling)."""
        if not self.is_sharded:
            return 1
        if len(leaf_shape) >= 2 and leaf_shape[0] % self.n_shards == 0:
            return self.n_shards
        return 1

    def describe(self) -> str:
        band = f" ring_steps={self.ring_steps}" if self.ring_steps else ""
        quant = " ring_quant=int8" if self.ring_quant else ""
        return (f"mesh={'x'.join(map(str, self.shape))} "
                f"axes={','.join(self.axes)} "
                f"spmm={'ring' if self.wants_ring else 'kernel'}{band}{quant}")

    # ------------------------------------------------------------ builders
    @classmethod
    def from_config(cls, mesh_shape=(1,), mesh_axes=None, spmm=None,
                    ring_steps=None, ring_quant=False) -> "ShardPlan | None":
        """The engine-facing constructor: returns ``None`` for the inert
        single-device default (no mesh, bit-identical legacy path), a
        live plan otherwise."""
        shape = tuple(int(s) for s in mesh_shape)
        axes = tuple(mesh_axes) if mesh_axes else auto_axes(shape)
        plan = cls(shape, axes, spmm, ring_steps, ring_quant)
        if not plan.is_sharded and not plan.wants_ring:
            return None
        return plan


def parse_mesh(text: str) -> tuple[int, ...]:
    """'4' -> (4,); '2x2' -> (2, 2) — the --mesh CLI syntax."""
    try:
        return tuple(int(t) for t in text.lower().split("x"))
    except ValueError:
        raise ValueError(f"bad mesh spec {text!r}; expected e.g. '4' or "
                         "'2x2'") from None
