"""CSR-routed sparse aggregation for the training pipeline.

The seed models aggregated through jnp segment ops directly; the
pipeline instead pre-sorts the bipartite graph into the two CSR
directions once (host side) and routes every aggregation through
``repro.kernels.ops.spmm_csr`` — the Pallas TPU kernel on TPU backends,
the XLA reference oracle elsewhere (``default_impl``).

Autodiff: ``pallas_call`` has no registered VJP, so each aggregation op
carries a custom VJP that expresses its gradient as the *reverse
direction's* SpMM — the paper's observation (§4) that GNN gradients map
onto the same SDDMM/SpMM kernels, made explicit:

  * adjacency matmul (gather=True SpMM):  d/dx (A x) = A^T ct — the
    opposite-direction gather-SpMM;
  * edge aggregation (gather=False SpMM): d/dvalues = ct[dst_e] — an
    SDDMM-copy gather.

LightGCN's symmetric normalization 1/sqrt(d_u d_i) is separable, so the
kernels run unweighted and the degree scalings apply at node level —
no [E, D] message matrix is ever materialized for LightGCN/GCN (the
planner's tensor set reflects this; NGCF's Hadamard messages still
materialize one edge matrix per layer).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as kops
from repro.kernels.spmm import build_csr_by_dst


def default_impl() -> str:
    """Kernel dispatch per backend: Pallas on TPU, XLA oracle elsewhere
    (interpret-mode Pallas is correct but far too slow for training)."""
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def _make_adj_matmul(indptr_f, src_f, n_dst, indptr_b, src_b, n_src, impl):
    """out = A x via gather-SpMM; VJP = A^T ct via the reverse CSR."""

    def _run(x):
        return kops.spmm_csr("sum", x, indptr_f, src_f, n_dst,
                             gather=True, impl=impl)

    @jax.custom_vjp
    def matmul(x):
        return _run(x)

    def fwd(x):
        return _run(x), None

    def bwd(_, ct):
        return (kops.spmm_csr("sum", ct, indptr_b, src_b, n_src,
                              gather=True, impl=impl),)

    matmul.defvjp(fwd, bwd)
    return matmul


def _make_edge_agg(indptr, dst_sorted, n_dst, impl):
    """out[v] = sum of edge values into v (values already dst-sorted);
    VJP = ct[dst_e], the SDDMM-copy gather."""

    def _run(values):
        # src_sorted operand unused when gather=False; pass dst_sorted
        return kops.spmm_csr("sum", values, indptr, dst_sorted, n_dst,
                             gather=False, impl=impl)

    @jax.custom_vjp
    def agg(values):
        return _run(values)

    def fwd(values):
        return _run(values), None

    def bwd(_, ct):
        return (ct[dst_sorted],)

    agg.defvjp(fwd, bwd)
    return agg


class BipartiteCSR:
    """Both CSR directions of a user-item graph + kernel-routed ops.

    Built once per training run (host-side sort); the jnp index arrays
    are captured as trace-time constants by the jitted train step.

      agg_u2i(x_user)  -> [n_items, D]   unweighted A^T x
      agg_i2u(x_item)  -> [n_users, D]   unweighted A x
      edge_agg_item(m) -> [n_items, D]   m in ui (item-sorted) edge order
      edge_agg_user(m) -> [n_users, D]   m in iu (user-sorted) edge order
      perm_ui_to_iu    reorders ui-order edge values into iu order (the
                       O3 SDDMM-reuse path: one Hadamard per layer)
    """

    def __init__(self, user: np.ndarray, item: np.ndarray, n_users: int,
                 n_items: int, edge_mask: np.ndarray | None = None,
                 impl: str | None = None):
        self.impl = impl or default_impl()
        user = np.asarray(user, np.int32)
        item = np.asarray(item, np.int32)
        if edge_mask is not None:
            keep = np.asarray(edge_mask).astype(bool)
            user, item = user[keep], item[keep]
        self.n_users = int(n_users)
        self.n_items = int(n_items)
        self.n_edges = len(user)

        ui_indptr, ui_src, perm_ui = build_csr_by_dst(item, user, n_items)
        iu_indptr, iu_src, perm_iu = build_csr_by_dst(user, item, n_users)
        # host copies of the user-CSR: the eval/serving seen-item mask is
        # built from these (O(E) structure, never a dense U×I mask)
        self._seen_indptr = np.asarray(iu_indptr, np.int64)
        self._seen_items = np.asarray(iu_src, np.int64)
        inv_ui = np.empty(self.n_edges, np.int64)
        inv_ui[perm_ui] = np.arange(self.n_edges)
        self.perm_ui_to_iu = jnp.asarray(inv_ui[perm_iu].astype(np.int32))

        self.ui_indptr = jnp.asarray(ui_indptr)
        self.ui_src = jnp.asarray(ui_src)                  # user per edge
        self.ui_dst = jnp.asarray(item[perm_ui])           # item per edge
        self.iu_indptr = jnp.asarray(iu_indptr)
        self.iu_src = jnp.asarray(iu_src)                  # item per edge
        self.iu_dst = jnp.asarray(user[perm_iu])           # user per edge

        du = np.bincount(user, minlength=n_users).astype(np.float32)
        di = np.bincount(item, minlength=n_items).astype(np.float32)
        self.rsqrt_du = jnp.asarray(1.0 / np.sqrt(np.maximum(du, 1.0)))
        self.rsqrt_di = jnp.asarray(1.0 / np.sqrt(np.maximum(di, 1.0)))

        self.agg_u2i = _make_adj_matmul(self.ui_indptr, self.ui_src, n_items,
                                        self.iu_indptr, self.iu_src, n_users,
                                        self.impl)
        self.agg_i2u = _make_adj_matmul(self.iu_indptr, self.iu_src, n_users,
                                        self.ui_indptr, self.ui_src, n_items,
                                        self.impl)
        self.edge_agg_item = _make_edge_agg(self.ui_indptr, self.ui_dst,
                                            n_items, self.impl)
        self.edge_agg_user = _make_edge_agg(self.iu_indptr, self.iu_dst,
                                            n_users, self.impl)

    def seen_csr(self) -> tuple[np.ndarray, np.ndarray]:
        """(indptr, items) numpy user-CSR over the train interactions —
        the exclusion structure for streaming eval and serving
        (``repro.eval``): items[indptr[u]:indptr[u+1]] are user u's
        already-seen item ids."""
        return self._seen_indptr, self._seen_items

    def graph_nbytes(self) -> int:
        """Bytes of the adjacency structure (both CSR directions)."""
        arrs = (self.ui_indptr, self.ui_src, self.ui_dst, self.iu_indptr,
                self.iu_src, self.iu_dst, self.perm_ui_to_iu)
        return int(sum(a.size * a.dtype.itemsize for a in arrs))

    def sym_propagate(self, x_user, x_item):
        """One symmetric-normalized propagation (LightGCN/GCN layer):
        h_i = sum_e x_u / sqrt(d_u d_i), both directions.  The separable
        coefficient lets both directions run as unweighted gather-SpMM."""
        h_item = self.agg_u2i(x_user * self.rsqrt_du[:, None]) \
            * self.rsqrt_di[:, None]
        h_user = self.agg_i2u(x_item * self.rsqrt_di[:, None]) \
            * self.rsqrt_du[:, None]
        return h_user, h_item
