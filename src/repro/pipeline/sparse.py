"""CSR-routed sparse aggregation for the training pipeline.

The seed models aggregated through jnp segment ops directly; the
pipeline instead pre-sorts the bipartite graph into the two CSR
directions once (host side) and routes every aggregation through
``repro.kernels.ops.spmm_csr`` — the Pallas TPU kernel on TPU backends,
the XLA reference oracle elsewhere (``default_impl``).

Autodiff: ``pallas_call`` has no registered VJP, so each aggregation op
carries a custom VJP that expresses its gradient as the *reverse
direction's* SpMM — the paper's observation (§4) that GNN gradients map
onto the same SDDMM/SpMM kernels, made explicit:

  * adjacency matmul (gather=True SpMM):  d/dx (A x) = A^T ct — the
    opposite-direction gather-SpMM;
  * edge aggregation (gather=False SpMM): d/dvalues = ct[dst_e] — an
    SDDMM-copy gather.

LightGCN's symmetric normalization 1/sqrt(d_u d_i) is separable, so the
kernels run unweighted and the degree scalings apply at node level —
no [E, D] message matrix is ever materialized for LightGCN/GCN (the
planner's tensor set reflects this; NGCF's Hadamard messages still
materialize one edge matrix per layer).

Sharded dispatch: alongside ``pallas``/``xla`` there is a ``ring``
route (``ShardPlan.wants_ring``) that runs node aggregation through
``dist.ring_spmm`` over the *unified* node space (users then items,
padded to a multiple of the shard count): features row-sharded over the
device ring, edges bucketed by (dst device, ring distance), compute on
bucket k overlapping the collective-permute fetching block k+1 — the
paper's NUMA-blocked Fig 11 schedule as a device ring.  The symmetric
propagation becomes ONE ring SpMM per layer (both directions at once,
since the unified adjacency is symmetric), and every ring op carries a
custom VJP that is the transpose-direction ring — the same
gradients-map-onto-the-same-kernels structure (§4) as the CSR path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as kops
from repro.kernels.spmm import build_csr_by_dst
from repro.pipeline.shard import ShardPlan


def default_impl() -> str:
    """Kernel dispatch per backend: Pallas on TPU, XLA oracle elsewhere
    (interpret-mode Pallas is correct but far too slow for training)."""
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def _make_adj_matmul(indptr_f, src_f, n_dst, indptr_b, src_b, n_src, impl):
    """out = A x via gather-SpMM; VJP = A^T ct via the reverse CSR."""

    def _run(x):
        return kops.spmm_csr("sum", x, indptr_f, src_f, n_dst,
                             gather=True, impl=impl)

    @jax.custom_vjp
    def matmul(x):
        return _run(x)

    def fwd(x):
        return _run(x), None

    def bwd(_, ct):
        return (kops.spmm_csr("sum", ct, indptr_b, src_b, n_src,
                              gather=True, impl=impl),)

    matmul.defvjp(fwd, bwd)
    return matmul


def _make_edge_agg(indptr, dst_sorted, n_dst, impl):
    """out[v] = sum of edge values into v (values already dst-sorted);
    VJP = ct[dst_e], the SDDMM-copy gather."""

    def _run(values):
        # src_sorted operand unused when gather=False; pass dst_sorted
        return kops.spmm_csr("sum", values, indptr, dst_sorted, n_dst,
                             gather=False, impl=impl)

    @jax.custom_vjp
    def agg(values):
        return _run(values)

    def fwd(values):
        return _run(values), None

    def bwd(_, ct):
        return (ct[dst_sorted],)

    agg.defvjp(fwd, bwd)
    return agg


def _make_hadamard_agg(indptr_f, src_f, dst_f, n_dst, indptr_b, src_b,
                       n_src, impl):
    """Fused Hadamard aggregation with a REMATERIALIZING custom VJP.

    Forward: out[v] = sum_{e: dst_e = v} x[src_e] * y[v] — one
    ``ops.hadamard_spmm`` call (structure ``y_is_dst``: the second
    factor rides the destination), no [E, D] message matrix.

    Backward saves only the NODE embeddings (x, y) as residuals and
    recomputes the edge products inside the cotangent kernels instead
    of storing [E, D] residuals; both cotangent paths are themselves
    fused gather-multiply-aggregate calls over the same CSR pair:

      d_x[s] = sum_{e: src_e = s} ct[dst_e] * y[dst_e]
               — the transpose CSR with BOTH gathers through its source
                 index (structure ``x_eq_y``: the product forms at node
                 level, gathered once);
      d_y[v] = ct[v] * sum_{e: dst_e = v} x[src_e]
               — the forward CSR with ct riding the destination
                 (structure ``y_is_dst`` again).
    """

    def _run(x, y):
        return kops.hadamard_spmm(x, y, indptr_f, src_f, dst_f, n_dst,
                                  structure="y_is_dst", impl=impl)

    @jax.custom_vjp
    def agg(x, y):
        return _run(x, y)

    def fwd(x, y):
        return _run(x, y), (x, y)

    def bwd(res, ct):
        x, y = res
        d_x = kops.hadamard_spmm(ct, y, indptr_b, src_b, src_b, n_src,
                                 structure="x_eq_y", impl=impl)
        d_y = kops.hadamard_spmm(x, ct, indptr_f, src_f, dst_f, n_dst,
                                 structure="y_is_dst", impl=impl)
        return d_x, d_y

    agg.defvjp(fwd, bwd)
    return agg


# ---------------------------------------------------------------- ring
class _RingGraph:
    """Ring-SpMM aggregations over the unified node space of one
    bipartite graph (user u -> row u, item i -> row n_users + i, rows
    padded to a multiple of the shard count).  Padded rows own no
    edges, so they aggregate to zero and are sliced back off.

    ``sym`` applies the symmetric adjacency (both edge directions in
    one ring pass); ``ui``/``iu`` apply only the user->item /
    item->user direction.  Every op resolves its bucket cubes lazily at
    first trace (LightGCN/GCN only ever build ``sym``; the directional
    cubes exist only for models that call them, i.e. NGCF).
    """

    def __init__(self, shard: ShardPlan, user: np.ndarray, item: np.ndarray,
                 n_users: int, n_items: int):
        from repro.dist.ring_spmm import bucket_edges, make_ring_spmm
        self._bucket_edges = bucket_edges
        self._make_ring_spmm = make_ring_spmm
        self.shard = shard
        self.n_users = int(n_users)
        self.n_items = int(n_items)
        self.part = shard.partition(n_users + n_items)
        self._src_ui = np.asarray(user, np.int64)
        self._dst_ui = np.asarray(item, np.int64) + n_users
        self._fns: dict[str, object] = {}

    def _banded(self) -> bool:
        s = self.shard.ring_steps
        return s is not None and s < self.shard.n_shards

    def _build(self, src: np.ndarray, dst: np.ndarray,
               n_steps: int | None):
        """One direction's ring closure: x_pad [n_pad, D] -> A x_pad.
        The bucket cubes stay host numpy: ``_build`` may first run
        inside a jit trace (ops resolve lazily), and memoizing arrays
        device-put during a trace would leak tracers into later
        traces — numpy closures bake in as constants per compile."""
        src_l, dst_l, mask, n_local = self._bucket_edges(
            src, dst, self.part.n_pad, self.shard.n_shards,
            n_steps=n_steps)
        fn = self._make_ring_spmm(self.shard.build_mesh(), self.shard.dp,
                                  n_local, n_steps=n_steps,
                                  quantize=self.shard.ring_quant)
        return lambda x: fn(x, src_l, dst_l, mask)

    def _band_kept(self, src: np.ndarray, dst: np.ndarray):
        """The subset of edges the banded forward actually applies."""
        p = self.shard.n_shards
        n_local = self.part.n_local
        rel = (src // n_local - dst // n_local) % p
        keep = rel < self.shard.ring_steps
        return src[keep], dst[keep]

    def _fn(self, which: str):
        """Memoized ring closures.  ``*_T`` keys are the exact transposes
        of the banded forwards: the band keeps edge (s, d) by the ring
        distance of s's owner AHEAD of d's — an asymmetric criterion —
        so the VJP cannot reuse a banded reverse ring (it would apply a
        different edge set than A^T).  Instead the transpose buckets the
        reversed KEPT edges over the full ring.  Unbanded, transposes
        alias the plain reverses (sym is self-adjoint, ui/iu are mutual
        transposes)."""
        if which not in self._fns:
            s, d = self._src_ui, self._dst_ui
            sym_s = np.concatenate([s, d])
            sym_d = np.concatenate([d, s])
            steps = self.shard.ring_steps
            if which == "sym":
                self._fns[which] = self._build(sym_s, sym_d, steps)
            elif which == "ui":
                self._fns[which] = self._build(s, d, steps)
            elif which == "iu":
                self._fns[which] = self._build(d, s, steps)
            elif not self._banded():
                alias = {"sym_T": "sym", "ui_T": "iu", "iu_T": "ui"}
                self._fns[which] = self._fn(alias[which])
            else:
                base = {"sym_T": (sym_s, sym_d), "ui_T": (s, d),
                        "iu_T": (d, s)}[which]
                ks, kd = self._band_kept(*base)
                self._fns[which] = self._build(kd, ks, None)
        return self._fns[which]

    def est_nbytes(self) -> int:
        """Exact bytes the sym bucket cubes WILL occupy, computed from
        bucket counts without building them — the planner profiles the
        graph before any op has traced (cubes resolve lazily), so it
        needs this analytic size, not the built-so-far total.  The sym
        set (2E edges) is also a fair proxy for NGCF's ui+iu pair."""
        p = self.shard.n_shards
        steps = self.shard.ring_steps if self.shard.ring_steps is not None \
            else p
        n_local = self.part.n_local
        s = np.concatenate([self._src_ui, self._dst_ui])
        d = np.concatenate([self._dst_ui, self._src_ui])
        rel = (s // n_local - d // n_local) % p
        keep = rel < steps
        dk = (d[keep] // n_local) * steps + rel[keep]
        counts = np.bincount(dk, minlength=p * steps)
        emax = max(int(counts.max()) if counts.size else 1, 1)
        emax = int(np.ceil(emax / 8)) * 8          # bucket_edges pad_multiple
        return p * steps * emax * (4 + 4 + 1)      # src_l + dst_l + mask

    def nbytes(self) -> int:
        """Planner-facing bucket bytes: the built cubes once any exist
        (unbanded transpose keys alias their base closure — each counted
        once), the analytic sym estimate before first trace."""
        total = 0
        seen: set[int] = set()
        for fn in self._fns.values():
            if id(fn) in seen:
                continue
            seen.add(id(fn))
            for cell in getattr(fn, "__closure__", None) or ():
                v = cell.cell_contents
                if hasattr(v, "nbytes"):
                    total += int(v.nbytes)
        return max(total, self.est_nbytes())

    # ------------------------------------------------------- lifted ops
    def _lift(self, x, offset: int):
        """[n, D] rows -> unified padded [n_pad, D] at row ``offset``."""
        z = jnp.zeros((self.part.n_pad, x.shape[-1]), x.dtype)
        return jax.lax.dynamic_update_slice(z, x, (offset, 0))

    def make_sym(self):
        """x_pad -> A_sym x_pad; VJP = the exact transpose ring (A_sym
        itself unbanded; the kept-edge transpose when banded).  The
        closures resolve ``_fn`` lazily at first trace, so bucket cubes
        only materialize for the ops a model actually uses."""

        @jax.custom_vjp
        def sym(x):
            return self._fn("sym")(x)

        sym.defvjp(lambda x: (self._fn("sym")(x), None),
                   lambda _, ct: (self._fn("sym_T")(ct),))
        return sym

    def _apply(self, which, x, in_off, out_off, n_out):
        h = self._fn(which)(self._lift(x, in_off))
        return jax.lax.dynamic_slice(h, (out_off, 0), (n_out, x.shape[-1]))

    def make_u2i(self):
        """x_user [n_users, D] -> [n_items, D]; VJP rides the transpose
        ring (item->user direction), mirroring the CSR custom VJPs."""
        nu, ni = self.n_users, self.n_items

        @jax.custom_vjp
        def u2i(x):
            return self._apply("ui", x, 0, nu, ni)

        u2i.defvjp(lambda x: (self._apply("ui", x, 0, nu, ni), None),
                   lambda _, ct: (self._apply("ui_T", ct, nu, 0, nu),))
        return u2i

    def make_i2u(self):
        nu, ni = self.n_users, self.n_items

        @jax.custom_vjp
        def i2u(x):
            return self._apply("iu", x, nu, 0, nu)

        i2u.defvjp(lambda x: (self._apply("iu", x, nu, 0, nu), None),
                   lambda _, ct: (self._apply("iu_T", ct, 0, nu, ni),))
        return i2u


class BipartiteCSR:
    """Both CSR directions of a user-item graph + kernel-routed ops.

    Built once per training run (host-side sort); the jnp index arrays
    are captured as trace-time constants by the jitted train step.

      agg_u2i(x_user)  -> [n_items, D]   unweighted A^T x
      agg_i2u(x_item)  -> [n_users, D]   unweighted A x
      edge_agg_item(m) -> [n_items, D]   m in ui (item-sorted) edge order
      edge_agg_user(m) -> [n_users, D]   m in iu (user-sorted) edge order
      perm_ui_to_iu    reorders ui-order edge values into iu order (the
                       O3 SDDMM-reuse path: one Hadamard per layer)
      hadamard_agg_item(xu, xi) -> [n_items, D]   fused sum_e xu[u_e]*xi[i]
      hadamard_agg_user(xi, xu) -> [n_users, D]   fused sum_e xi[i_e]*xu[u]
                       (rematerializing VJP, no [E, D] message matrix)

    ``hadamard`` selects NGCF's Hadamard-message route: 'fused' (the
    no-[E, D] ops above), 'composed' (the edge_agg path), or 'auto' —
    fused everywhere except under the ring dispatch, whose rotation
    schedule has no fused gather-multiply-aggregate yet
    (``fused_hadamard`` exposes the resolved choice to the registry
    forward and the planner).
    """

    def __init__(self, user: np.ndarray, item: np.ndarray, n_users: int,
                 n_items: int, edge_mask: np.ndarray | None = None,
                 impl: str | None = None, shard: ShardPlan | None = None,
                 hadamard: str = "auto"):
        # 'ring' is a first-class dispatch value: it forces the sharded
        # aggregation route (degenerate 1-device ring when no mesh is
        # given); node-level kernels still need a pallas/xla backend.
        if impl == "ring" and shard is None:
            shard = ShardPlan(spmm="ring")
        self.impl = default_impl() if impl in (None, "ring") else impl
        self.shard = shard
        self.spmm = "ring" if (shard is not None and shard.wants_ring) \
            else self.impl
        user = np.asarray(user, np.int32)
        item = np.asarray(item, np.int32)
        if edge_mask is not None:
            keep = np.asarray(edge_mask).astype(bool)
            user, item = user[keep], item[keep]
        self.n_users = int(n_users)
        self.n_items = int(n_items)
        self.n_edges = len(user)

        ui_indptr, ui_src, perm_ui = build_csr_by_dst(item, user, n_items)
        iu_indptr, iu_src, perm_iu = build_csr_by_dst(user, item, n_users)
        # host copies of the user-CSR: the eval/serving seen-item mask is
        # built from these (O(E) structure, never a dense U×I mask)
        self._seen_indptr = np.asarray(iu_indptr, np.int64)
        self._seen_items = np.asarray(iu_src, np.int64)
        inv_ui = np.empty(self.n_edges, np.int64)
        inv_ui[perm_ui] = np.arange(self.n_edges)
        self.perm_ui_to_iu = jnp.asarray(inv_ui[perm_iu].astype(np.int32))

        self.ui_indptr = jnp.asarray(ui_indptr)
        self.ui_src = jnp.asarray(ui_src)                  # user per edge
        self.ui_dst = jnp.asarray(item[perm_ui])           # item per edge
        self.iu_indptr = jnp.asarray(iu_indptr)
        self.iu_src = jnp.asarray(iu_src)                  # item per edge
        self.iu_dst = jnp.asarray(user[perm_iu])           # user per edge

        du = np.bincount(user, minlength=n_users).astype(np.float32)
        di = np.bincount(item, minlength=n_items).astype(np.float32)
        self.rsqrt_du = jnp.asarray(1.0 / np.sqrt(np.maximum(du, 1.0)))
        self.rsqrt_di = jnp.asarray(1.0 / np.sqrt(np.maximum(di, 1.0)))

        self._ring = None
        self._ring_sym = None
        if self.spmm == "ring":
            self._ring = _RingGraph(self.shard, user, item, n_users, n_items)
            self._ring_sym = self._ring.make_sym()
            self.agg_u2i = self._ring.make_u2i()
            self.agg_i2u = self._ring.make_i2u()
        else:
            self.agg_u2i = _make_adj_matmul(self.ui_indptr, self.ui_src,
                                            n_items, self.iu_indptr,
                                            self.iu_src, n_users, self.impl)
            self.agg_i2u = _make_adj_matmul(self.iu_indptr, self.iu_src,
                                            n_users, self.ui_indptr,
                                            self.ui_src, n_items, self.impl)
        # edge-level aggregation ([E, D] values, dst-sorted) stays on the
        # node-local kernel path under every dispatch: the values are
        # already per-edge, so there is no feature block to rotate
        self.edge_agg_item = _make_edge_agg(self.ui_indptr, self.ui_dst,
                                            n_items, self.impl)
        self.edge_agg_user = _make_edge_agg(self.iu_indptr, self.iu_dst,
                                            n_users, self.impl)
        # fused Hadamard aggregation (NGCF): ring runs fall back to the
        # composed edge_agg route — the rotation schedule owns those
        if hadamard not in ("auto", "fused", "composed"):
            raise ValueError(f"hadamard must be 'auto', 'fused' or "
                             f"'composed', got {hadamard!r}")
        self.fused_hadamard = hadamard != "composed" \
            and self.spmm != "ring"
        self.hadamard_agg_item = _make_hadamard_agg(
            self.ui_indptr, self.ui_src, self.ui_dst, n_items,
            self.iu_indptr, self.iu_src, n_users, self.impl)
        self.hadamard_agg_user = _make_hadamard_agg(
            self.iu_indptr, self.iu_src, self.iu_dst, n_users,
            self.ui_indptr, self.ui_src, n_items, self.impl)

    def seen_csr(self) -> tuple[np.ndarray, np.ndarray]:
        """(indptr, items) numpy user-CSR over the train interactions —
        the exclusion structure for streaming eval and serving
        (``repro.eval``): items[indptr[u]:indptr[u+1]] are user u's
        already-seen item ids."""
        return self._seen_indptr, self._seen_items

    def csr_nbytes(self) -> int:
        """Bytes of the CSR adjacency alone (both directions) — stays
        fully REPLICATED per device under every dispatch (edge aggs and
        the eval seen-structure still read it)."""
        arrs = (self.ui_indptr, self.ui_src, self.ui_dst, self.iu_indptr,
                self.iu_src, self.iu_dst, self.perm_ui_to_iu)
        return int(sum(a.size * a.dtype.itemsize for a in arrs))

    def ring_nbytes(self) -> int:
        """Bytes of the ring bucket cubes (built or analytically
        estimated); 0 off the ring dispatch.  The cubes are dst-sharded
        over the mesh — each device holds 1/P of them."""
        return self._ring.nbytes() if self._ring is not None else 0

    def graph_nbytes(self) -> int:
        """Bytes of the whole adjacency structure (CSR + ring cubes)."""
        return self.csr_nbytes() + self.ring_nbytes()

    def sym_propagate(self, x_user, x_item):
        """One symmetric-normalized propagation (LightGCN/GCN layer):
        h_i = sum_e x_u / sqrt(d_u d_i), both directions.  The separable
        coefficient lets both directions run as unweighted gather-SpMM —
        and, under the ring dispatch, as ONE ring SpMM over the unified
        (symmetric) adjacency: both directions ride a single rotation
        schedule, the distributed analogue of the paper's fused
        NUMA-blocked pass."""
        if self._ring_sym is not None:
            part = self._ring.part
            z = jnp.concatenate([x_user * self.rsqrt_du[:, None],
                                 x_item * self.rsqrt_di[:, None]], axis=0)
            h = part.trim(self._ring_sym(part.pad_rows(z)))
            return (h[:self.n_users] * self.rsqrt_du[:, None],
                    h[self.n_users:] * self.rsqrt_di[:, None])
        h_item = self.agg_u2i(x_user * self.rsqrt_du[:, None]) \
            * self.rsqrt_di[:, None]
        h_user = self.agg_i2u(x_item * self.rsqrt_di[:, None]) \
            * self.rsqrt_du[:, None]
        return h_user, h_item
