"""TrainPlan — the placement + batching contract of one training run.

This is where the paper's two headline knobs stop being independent:
the placement policy decides which tensors keep fast-tier residency,
and whatever fast-tier capacity is left over bounds the *microbatch*;
the 150K-sample target batches of §7.1 then run as
``ceil(B/microbatch)`` accumulated microbatches.  ``build_train_plan``
profiles the **actual** tensor set of the model (every params/optimizer
leaf by its real nbytes, the CSR adjacency, and — only for models that
materialize them — the per-layer edge-message matrices), runs the
selected ``repro.memory`` placement policy over the selected
``TierTopology``, and derives the microbatch.  Budgets are per-tier and
— under a ``ShardPlan`` — per-shard: profiles describe per-device
tensor shards and every mesh device gets its own tier plan.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import numpy as np

from repro.core.large_batch import LargeBatchSchedule
from repro.memory import (AccessProfile, Plan, TieredExecutor, get_policy,
                          get_topology, memory_kind_sharding,
                          quantized_table_bytes)
from repro.pipeline.registry import ModelSpec
from repro.pipeline.shard import ShardPlan
from repro.pipeline.sparse import BipartiteCSR


def _leaf_profiles(tree, prefix: str, reads: float, writes: float,
                   shard: ShardPlan | None = None,
                   embed_store: str = "fp32"):
    out = []
    for kp, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        name = prefix + jax.tree_util.keystr(kp)
        nbytes = int(np.prod(leaf.shape) * leaf.dtype.itemsize) \
            if hasattr(leaf, "shape") else 0
        if nbytes == 0:
            continue
        if shard is not None:
            # per-device profiling: a row-sharded table occupies 1/P of
            # each shard's budget (its placement decision is per shard)
            nbytes //= shard.shard_divisor(leaf.shape)
        row = (leaf.shape[-1] if getattr(leaf, "ndim", 0) else 1) * \
            leaf.dtype.itemsize
        # int8 capacity-tier storage: 2-D fp32 params tables carry their
        # quantized footprint (1B/element + one fp32 scale per row) —
        # the same leaves TieredExecutor._wants_int8 quantizes
        store = quantized_table_bytes(max(nbytes // row, 1), row) \
            if (embed_store == "int8" and prefix == "params"
                and getattr(leaf, "ndim", 0) == 2
                and leaf.dtype == np.float32) else None
        out.append(AccessProfile(name, nbytes, reads_per_step=reads,
                                 writes_per_step=writes, access_size=row,
                                 store_bytes=store))
    return out


def profiles_from_state(params, opt_state, g: BipartiteCSR, n_layers: int,
                        spec: ModelSpec, embed_dim: int,
                        shard: ShardPlan | None = None,
                        embed_store: str = "fp32") -> list[AccessProfile]:
    """AccessProfiles over the run's actual tensor set (paper §2.1 memory
    model, measured from the live pytrees instead of assumed shapes).

    With a live ``ShardPlan`` every profile describes the *per-device*
    shard: row-sharded tables and the edge-bucketed adjacency each
    occupy 1/P of a device, and the knapsack then runs against the
    per-device HBM budget — each mesh shard gets its own budget and
    tier plan (GNNear / MTrainS framing)."""
    p = shard.n_shards if shard is not None else 1
    profs = []
    # embedding tables + weights: read every layer fwd+bwd, written once
    profs += _leaf_profiles(params, "params", reads=2.0 * n_layers,
                            writes=1.0, shard=shard,
                            embed_store=embed_store)
    # optimizer state: one read + one write per update
    profs += _leaf_profiles(opt_state, "opt", reads=1.0, writes=1.0,
                            shard=shard)
    # adjacency: read-only, tiny access granularity.  Per device: the
    # CSR stays fully replicated (edge aggs + eval read it everywhere)
    # while the ring bucket cubes are dst-sharded at 1/P each
    if shard is None:
        gbytes = g.graph_nbytes()
    else:
        gbytes = g.csr_nbytes() + max(g.ring_nbytes() // p, 1)
    profs.append(AccessProfile("graph/csr", gbytes,
                               reads_per_step=2.0 * n_layers,
                               writes_per_step=0.0, access_size=8))
    if spec.messages_materialized(g):
        # per-layer messages are layer-input wide ([E, embed_dim]) even
        # when the model concatenates layer outputs; sharded runs
        # materialize only the local edge partition's share.  The fused
        # Hadamard route never forms them: no profile, no placement,
        # and the microbatch derives against the reclaimed budget
        row = embed_dim * 4
        for l in range(n_layers):
            profs.append(AccessProfile(
                f"messages_l{l}", max(g.n_edges * row // p, row),
                reads_per_step=2.0, writes_per_step=2.0, access_size=row))
    return profs


def derive_microbatch(free_hbm: int, out_dim: int, target_batch: int,
                      floor: int = 32) -> int:
    """Largest power-of-two microbatch whose per-sample working set fits
    the HBM left after placement.  Per BPR sample: 3 embedding rows
    (u, i+, i-) x fwd/bwd activations + temps (~8 row-equivalents)."""
    bytes_per_sample = 3 * out_dim * 4 * 8
    mu = max(int(free_hbm) // bytes_per_sample, floor)
    mu = 1 << (mu.bit_length() - 1)          # pow2 floor
    return int(min(mu, target_batch))


def derive_eval_batch(free_hbm: int, out_dim: int, k: int, item_block: int,
                      floor: int = 32, cap: int = 4096) -> int:
    """Largest power-of-two user microbatch for streaming eval/serving:
    per user the carry, one score block, and the concat double-buffer —
    ``(K + 2·block + D) · 4B`` — must fit the HBM left after placement."""
    per_user = (k + 2 * item_block + out_dim) * 4
    b = max(int(free_hbm) // max(per_user, 1), floor)
    b = 1 << (b.bit_length() - 1)            # pow2 floor
    return int(min(b, cap))


def serving_profiles(user_nbytes: int, item_nbytes: int, row: int,
                     user_fraction: float = 0.05,
                     cache_rows: int = 0,
                     ann_index_bytes: int = 0) -> list[AccessProfile]:
    """AccessProfiles for the serving snapshot: every query batch streams
    the full item table block-by-block (read 1.0×/step), but gathers only
    the batch's rows of the user table (``user_fraction``×/step) — so
    under a tight budget the planner demotes the user table first,
    mirroring RecNMP's observation that item-side traffic dominates.

    ``cache_rows`` prices the hot-row cache's device slots against the
    fast tier (a pinned-fast reservation: slot store + per-slot
    bookkeeping, priced at 2 rows/slot) — the knapsack sees the cache
    budget as spent and may legitimately demote a table the cache then
    serves.

    ``ann_index_bytes`` prices the ANN index's coarse summaries
    (``serving.ann.ann_index_nbytes``: int8 block centroids + bound
    terms + the item permutation) the same way: pinned fast, because the
    coarse stage runs on *every* query batch and exists precisely to
    avoid touching the slow tier — a demoted index would re-add the
    traffic it prunes."""
    profs = [
        AccessProfile("serve/user_embed", int(user_nbytes),
                      reads_per_step=user_fraction, writes_per_step=0.0,
                      access_size=row),
        AccessProfile("serve/item_embed", int(item_nbytes),
                      reads_per_step=1.0, writes_per_step=0.0,
                      access_size=row),
    ]
    if cache_rows > 0:
        profs.append(AccessProfile("serve/hot_cache",
                                   int(2 * cache_rows * row),
                                   reads_per_step=0.0, writes_per_step=0.0,
                                   access_size=row, pinned="fast"))
    if ann_index_bytes > 0:
        profs.append(AccessProfile("serve/ann_index", int(ann_index_bytes),
                                   reads_per_step=1.0, writes_per_step=0.0,
                                   access_size=row, pinned="fast"))
    return profs


@dataclasses.dataclass
class TrainPlan:
    """Everything the engine needs to run one training configuration.

    ``microbatch`` is the *per-shard* microbatch: each of the
    ``shards`` mesh devices runs that many samples per accumulation
    chunk, so the global batch is ``shards x microbatch x accum``
    (``global_microbatch`` per chunk).  Single-device runs have
    ``shards == 1`` and the two coincide.  ``hbm_budget`` is the
    fast-tier budget (per device); the full per-tier budgets live on
    ``plan.budgets``."""
    arch: str
    plan: Plan                     # tier placement over the tensor set
    sched: LargeBatchSchedule
    microbatch: int                # per-shard
    impl: str                      # kernel dispatch ('pallas' | 'xla')
    hbm_budget: int                # fast-tier budget, per-device
    shards: int = 1                # mesh size P

    @property
    def global_microbatch(self) -> int:
        return self.microbatch * self.shards

    @property
    def topology(self):
        return self.plan.topology

    @property
    def write_policy(self) -> dict[str, str]:
        """Per-kernel §6 write-policy table, emitted from the plan."""
        return self.plan.write_policy()

    def microbatches_for_epoch(self, epoch: int) -> int:
        return max(1, math.ceil(self.sched.batch_for_epoch(epoch)
                                / self.global_microbatch))

    def describe(self) -> str:
        tiers = {}
        for name, p in self.plan.placements.items():
            tiers.setdefault(p.tier, []).append(name)
        shard_txt = f" shards={self.shards}" if self.shards > 1 else ""
        fast = self.topology.fast.name
        lines = [f"TrainPlan[{self.arch}] impl={self.impl}{shard_txt} "
                 f"microbatch={self.microbatch} "
                 f"target_batch={self.sched.target_batch} "
                 f"topology={self.topology.name} policy={self.plan.policy} "
                 f"{fast}={self.plan.hbm_used/2**20:.1f}/"
                 f"{self.hbm_budget/2**20:.1f} MiB "
                 f"est_penalty={self.plan.est_step_penalty_s*1e3:.2f} ms/step"]
        for tier in self.topology.names:
            names = tiers.get(tier, [])
            if names:
                lines.append(f"  {tier}: {', '.join(sorted(names))}")
        wp = self.write_policy
        lines.append("  write_policy: "
                     + " ".join(f"{k}={wp[k]}" for k in sorted(wp)))
        return "\n".join(lines)


def build_train_plan(arch: str, spec: ModelSpec, params, opt_state,
                     g: BipartiteCSR, n_layers: int, embed_dim: int,
                     sched: LargeBatchSchedule, impl: str,
                     hbm_budget: int | None = None,
                     microbatch: int | None = None,
                     shard: ShardPlan | None = None,
                     topology: "str | object" = "tpu-hbm-host",
                     policy: str = "greedy",
                     pins: dict | None = None,
                     embed_store: str = "fp32") -> TrainPlan:
    """Profile -> place -> derive the microbatch.  ``topology`` names a
    registered ``TierTopology`` (or is one); ``policy`` names a
    registered placement policy; ``pins`` force tensors onto tiers by
    (sub)name.  ``hbm_budget`` overrides the fast tier's capacity and
    all budgets are *per device*: with a ``ShardPlan`` the profiles
    describe per-device shards and the derived microbatch is the
    per-shard one."""
    topo = get_topology(topology)
    budgets = topo.capacities()
    if hbm_budget is not None:
        budgets[topo.fast.name] = int(hbm_budget)
    budget = budgets[topo.fast.name]
    profs = profiles_from_state(params, opt_state, g, n_layers, spec,
                                embed_dim, shard=shard,
                                embed_store=embed_store)
    plan = get_policy(policy)(profs, topo, budgets=budgets, pins=pins)
    shards = shard.n_shards if shard is not None else 1
    if microbatch is None:
        microbatch = derive_microbatch(budget - plan.hbm_used,
                                       spec.out_dim(embed_dim, n_layers),
                                       max(1, sched.target_batch // shards))
    return TrainPlan(arch, plan, sched, int(microbatch), impl, budget,
                     shards=shards)


# ---------------------------------------------------------------- placement
def host_offload_sharding():
    """A sharding that pins to the host memory tier, when the backend has
    one (TPU); None on backends without memory kinds (CPU tests).
    Legacy wrapper over ``repro.memory.memory_kind_sharding``."""
    return memory_kind_sharding("pinned_host")


def apply_placements(state, plan: Plan) -> tuple[object, int]:
    """Place every state leaf onto its planned tier.  Returns
    (state, n_offloaded).  Legacy wrapper: the engine now drives a
    ``repro.memory.TieredExecutor`` directly, which also gives
    backends without memory kinds a real (host-store) slow tier."""
    return TieredExecutor(plan).place(state)
