"""Unified large-batch training pipeline (paper §7 end-to-end).

Public surface:

  build_pipeline / Pipeline / PipelineConfig — the engine;
  TrainPlan / build_train_plan               — placement + microbatching;
  MODELS / get_model                         — the model registry;
  BipartiteCSR / default_impl                — kernel-routed graph ops;
  ShardPlan                                  — mesh-parallel execution
                                               (ring SpMM, dp batches,
                                               per-device budgets).
"""
from repro.pipeline.engine import Pipeline, PipelineConfig, build_pipeline
from repro.pipeline.plan import TrainPlan, build_train_plan
from repro.pipeline.registry import MODELS, get_model
from repro.pipeline.shard import ShardPlan
from repro.pipeline.sparse import BipartiteCSR, default_impl

__all__ = [
    "Pipeline", "PipelineConfig", "build_pipeline", "TrainPlan",
    "build_train_plan", "MODELS", "get_model", "BipartiteCSR",
    "default_impl", "ShardPlan",
]
