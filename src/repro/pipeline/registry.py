"""Model registry for the unified training pipeline.

One contract for every GNNRecSys architecture:

    init(key, n_users, n_items, embed_dim, n_layers) -> params
    forward(params, g: BipartiteCSR, n_layers) -> (user_emb, item_emb)

All three forwards route aggregation through the kernel-dispatched CSR
ops in ``pipeline.sparse`` (Pallas SpMM on TPU, XLA oracle elsewhere)
and are numerically equivalent to the seed COO implementations in
``repro.core`` — tests/test_pipeline.py pins that equivalence.

  lightgcn — He et al. SIGIR'20; the paper's fastest model.
  ngcf     — Wang et al. SIGIR'19 with the §4 O1-O3 dataflow rewrites
             (single Hadamard SDDMM per layer, reused for both
             directions via the edge permutation).
  gcn      — Kipf-Welling convolution applied to the user-item graph
             (sym-normalized propagate + per-layer weight + ReLU),
             BPR-trained like the others; paper §9 notes GCN's scalar
             message fuses into a single SpMM, which is exactly the
             ``sym_propagate`` path.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core import lightgcn as _lightgcn
from repro.core import ngcf as _ngcf
from repro.pipeline.sparse import BipartiteCSR


@dataclasses.dataclass(frozen=True)
class ModelSpec:
    name: str
    init: Callable          # (key, n_users, n_items, embed_dim, n_layers)
    forward: Callable       # (params, g, n_layers) -> (user_emb, item_emb)
    materializes_messages: bool   # [E, embed_dim] edge matrix per layer
    concat_layers: bool = False   # output concatenates all layer embeddings

    def out_dim(self, embed_dim: int, n_layers: int) -> int:
        """Final embedding width (drives the planner's per-sample cost)."""
        return embed_dim * (n_layers + 1) if self.concat_layers else embed_dim

    def messages_materialized(self, g: "BipartiteCSR | None" = None) -> bool:
        """Whether THIS run's forward actually forms the per-layer
        [E, embed_dim] message matrix: the graph's fused Hadamard route
        keeps it out of memory entirely, so the planner must not
        profile the stream it no longer carries."""
        return self.materializes_messages \
            and not getattr(g, "fused_hadamard", False)


# ---------------------------------------------------------------- lightgcn
def _lightgcn_init(key, n_users, n_items, embed_dim, n_layers):
    return _lightgcn.init_params(key, n_users, n_items, embed_dim)


def _lightgcn_forward(params, g: BipartiteCSR, n_layers: int):
    xu, xi = params["user_embed"], params["item_embed"]
    acc_u, acc_i = xu, xi
    for _ in range(n_layers):
        xu, xi = g.sym_propagate(xu, xi)
        acc_u = acc_u + xu
        acc_i = acc_i + xi
    denom = n_layers + 1
    return acc_u / denom, acc_i / denom


# ---------------------------------------------------------------- ngcf
def _ngcf_init(key, n_users, n_items, embed_dim, n_layers):
    return _ngcf.init_params(key, n_users, n_items, embed_dim, n_layers)


def _ngcf_forward(params, g: BipartiteCSR, n_layers: int):
    xu, xi = params["user_embed"], params["item_embed"]
    outs_u, outs_i = [xu], [xi]
    fused = getattr(g, "fused_hadamard", False)
    for w1, w2 in zip(params["w1"], params["w2"]):
        if fused:
            # fused gather-Hadamard-aggregate (rematerializing VJP):
            # the [E, D] message matrix never exists in memory
            agg_mul_item = g.hadamard_agg_item(xu, xi)
            agg_mul_user = g.hadamard_agg_user(xi, xu)
        else:
            # O3: one Hadamard SDDMM per layer, reused for both directions
            mul_ui = xu[g.ui_src] * xi[g.ui_dst]         # [E, D], ui order
            agg_mul_item = g.edge_agg_item(mul_ui)
            agg_mul_user = g.edge_agg_user(mul_ui[g.perm_ui_to_iu])
        # O1: aggregate raw src features first, matmul at node level
        h_item = agg_mul_item @ w1 + g.agg_u2i(xu) @ w2
        h_user = agg_mul_user @ w1 + g.agg_i2u(xi) @ w2
        xu = jax.nn.leaky_relu(h_user, 0.2)
        xi = jax.nn.leaky_relu(h_item, 0.2)
        outs_u.append(xu)
        outs_i.append(xi)
    return jnp.concatenate(outs_u, -1), jnp.concatenate(outs_i, -1)


# ---------------------------------------------------------------- gcn
def _gcn_init(key, n_users, n_items, embed_dim, n_layers):
    keys = jax.random.split(key, 2 + n_layers)
    scale = 1.0 / jnp.sqrt(embed_dim)
    params = {
        "user_embed": jax.random.normal(
            keys[0], (n_users, embed_dim), jnp.float32) * scale,
        "item_embed": jax.random.normal(
            keys[1], (n_items, embed_dim), jnp.float32) * scale,
        "layers": [],
    }
    for l in range(n_layers):
        w = jax.random.normal(keys[2 + l], (embed_dim, embed_dim),
                              jnp.float32) * jnp.sqrt(2.0 / embed_dim)
        params["layers"].append({"w": w, "b": jnp.zeros((embed_dim,), jnp.float32)})
    return params


def _gcn_forward(params, g: BipartiteCSR, n_layers: int):
    xu, xi = params["user_embed"], params["item_embed"]
    for l, lyr in enumerate(params["layers"]):
        hu, hi = g.sym_propagate(xu, xi)
        xu = hu @ lyr["w"] + lyr["b"]
        xi = hi @ lyr["w"] + lyr["b"]
        if l + 1 < len(params["layers"]):
            xu = jax.nn.relu(xu)
            xi = jax.nn.relu(xi)
    return xu, xi


MODELS = {
    "lightgcn": ModelSpec("lightgcn", _lightgcn_init, _lightgcn_forward,
                          materializes_messages=False),
    "ngcf": ModelSpec("ngcf", _ngcf_init, _ngcf_forward,
                      materializes_messages=True, concat_layers=True),
    "gcn": ModelSpec("gcn", _gcn_init, _gcn_forward,
                     materializes_messages=False),
}


def get_model(name: str) -> ModelSpec:
    if name not in MODELS:
        raise KeyError(f"unknown pipeline model {name!r}; "
                       f"known: {sorted(MODELS)}")
    return MODELS[name]
