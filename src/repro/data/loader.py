"""Deterministic, resumable, shard-aware minibatch iterator.

Fault-tolerance contract: the iterator state is (epoch, step, seed);
``state_dict``/``load_state_dict`` round-trips exactly, so a restarted
job resumes mid-epoch on the same sample order.  Sharding: each data-
parallel worker takes a strided slice of the per-epoch permutation.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class LoaderState:
    epoch: int = 0
    step: int = 0


class EdgeLoader:
    """Iterates (user, pos_item) interaction minibatches."""

    def __init__(self, user: np.ndarray, item: np.ndarray, batch: int,
                 seed: int = 0, shard_id: int = 0, num_shards: int = 1,
                 drop_last: bool = True):
        assert len(user) == len(item)
        self.user, self.item = user, item
        self.batch = batch
        self.seed = seed
        self.shard_id, self.num_shards = shard_id, num_shards
        self.drop_last = drop_last
        self.state = LoaderState()

    def _epoch_perm(self, epoch: int) -> np.ndarray:
        rng = np.random.default_rng((self.seed, epoch))
        perm = rng.permutation(len(self.user))
        return perm[self.shard_id::self.num_shards]

    def steps_per_epoch(self) -> int:
        # arithmetic count of this shard's strided slice — callers (the
        # pipeline engine) hit this several times per step, so don't
        # materialize an O(N) permutation just to measure it
        n = len(range(self.shard_id, len(self.user), self.num_shards))
        return n // self.batch if self.drop_last else -(-n // self.batch)

    def __iter__(self):
        return self

    def __next__(self):
        perm = self._epoch_perm(self.state.epoch)
        spe = self.steps_per_epoch()
        if self.state.step >= spe:
            self.state = LoaderState(self.state.epoch + 1, 0)
            perm = self._epoch_perm(self.state.epoch)
        lo = self.state.step * self.batch
        idx = perm[lo:lo + self.batch]
        self.state = LoaderState(self.state.epoch, self.state.step + 1)
        return self.user[idx], self.item[idx]

    def state_dict(self) -> dict:
        return dataclasses.asdict(self.state)

    def load_state_dict(self, d: dict) -> None:
        self.state = LoaderState(**d)
