"""Kronecker expansion of interaction graphs (Belletti et al. 2019,
arXiv:1901.08910) — the paper's method for growing movielens/gowalla/
amazon-book into the 250M-1.2B edge benchmark graphs (m-x25, g-x256, ...).

A' = K (x) A for a small binary expander K [ku, ki]:
  edge (u, i) of A and edge (a, b) of K produce
  (a * n_users + u, b * n_items + i).
Edge count multiplies by nnz(K); the per-block degree distribution (and
hence the power law, community structure, item popularity) is preserved.
"""
from __future__ import annotations

import numpy as np

from repro.data.synth import InteractionData


def expander_matrix(ku: int, ki: int, nnz: int, seed: int = 0) -> np.ndarray:
    """Random binary expander with exactly nnz ones, diagonal-ish bias so
    the expansion keeps community structure (blocks mostly map to
    themselves)."""
    rng = np.random.default_rng(seed)
    k = np.zeros((ku, ki), dtype=bool)
    d = min(ku, ki)
    k[np.arange(d) % ku, np.arange(d) % ki] = True  # diagonal backbone
    need = nnz - k.sum()
    if need < 0:
        raise ValueError("nnz smaller than diagonal backbone")
    flat = np.flatnonzero(~k.reshape(-1))
    extra = rng.choice(flat, int(need), replace=False)
    k.reshape(-1)[extra] = True
    return k


def kronecker_expand(data: InteractionData, k: np.ndarray) -> InteractionData:
    """A' = K (x) A on edge lists."""
    ka, kb = np.nonzero(k)
    nu, ni = data.n_users, data.n_items
    # broadcast: every K-edge replicates every A-edge into a shifted block
    user = (ka[:, None].astype(np.int64) * nu + data.user[None, :]).reshape(-1)
    item = (kb[:, None].astype(np.int64) * ni + data.item[None, :]).reshape(-1)
    return InteractionData(user.astype(np.int64), item.astype(np.int64),
                           k.shape[0] * nu, k.shape[1] * ni)


def expand_by_factor(data: InteractionData, factor: int,
                     seed: int = 0) -> InteractionData:
    """Expand edge count by ~``factor`` (paper: m-x25 = movielens x25).
    Uses a ceil(sqrt(factor))-square expander with ``factor`` nonzeros."""
    side = int(np.ceil(np.sqrt(factor)))
    k = expander_matrix(side, side, factor, seed=seed)
    return kronecker_expand(data, k)
