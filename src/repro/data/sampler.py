"""K-hop neighbour sampling (host side, numpy).

Two consumers:
  * the DistDGL-style subgraph-training baseline (paper §2.2/§7.2) —
    builds per-batch message-flow blocks, including the *redundancy
    accounting* the paper measures (same vertex appearing in many
    subgraphs);
  * the gcn-cora ``minibatch_lg`` shape (fanout 15-10 sampled training).

Blocks are padded to static shapes so the jitted step traces once.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class CSRGraph:
    indptr: np.ndarray   # int64[N+1]
    indices: np.ndarray  # int32[E]

    @property
    def n_nodes(self) -> int:
        return len(self.indptr) - 1

    def degree(self, v) -> np.ndarray:
        return self.indptr[np.asarray(v) + 1] - self.indptr[np.asarray(v)]


def build_csr(src: np.ndarray, dst: np.ndarray, n_nodes: int) -> CSRGraph:
    """CSR over incoming edges: row v lists the *sources* of edges into v."""
    order = np.argsort(dst, kind="stable")
    indptr = np.zeros(n_nodes + 1, dtype=np.int64)
    np.add.at(indptr, dst[order] + 1, 1)
    np.cumsum(indptr, out=indptr)
    return CSRGraph(indptr, src[order].astype(np.int32))


@dataclasses.dataclass
class Block:
    """One sampled message-passing hop: edges from src_nodes -> dst_nodes,
    with indices local to the respective node lists."""
    src_nodes: np.ndarray   # global ids, int32[S_pad]
    dst_nodes: np.ndarray   # global ids, int32[D_pad]
    edge_src: np.ndarray    # local into src_nodes, int32[E_pad]
    edge_dst: np.ndarray    # local into dst_nodes, int32[E_pad]
    edge_mask: np.ndarray   # bool[E_pad]
    n_src: int
    n_dst: int


def sample_blocks(g: CSRGraph, seeds: np.ndarray, fanouts: list[int | None],
                  rng: np.random.Generator,
                  pad_multiple: int = 64) -> list[Block]:
    """Layered sampling (GraphSAGE-style), deepest hop first in the
    returned list.  fanout=None means full neighbourhood (no sampling),
    which is the paper's 'DistDGL w/o sampling' configuration."""
    blocks: list[Block] = []
    frontier = np.unique(seeds.astype(np.int32))
    for fanout in fanouts:
        src_lists = []
        edge_src_g = []
        edge_dst_l = []
        for li, v in enumerate(frontier):
            lo, hi = g.indptr[v], g.indptr[v + 1]
            neigh = g.indices[lo:hi]
            if fanout is not None and len(neigh) > fanout:
                neigh = rng.choice(neigh, fanout, replace=False)
            src_lists.append(neigh)
            edge_src_g.append(neigh)
            edge_dst_l.append(np.full(len(neigh), li, dtype=np.int32))
        edge_src_g = np.concatenate(edge_src_g) if edge_src_g else np.zeros(0, np.int32)
        edge_dst_l = np.concatenate(edge_dst_l) if edge_dst_l else np.zeros(0, np.int32)
        # src node list = frontier ∪ sampled neighbours (self rows keep
        # the residual/update path simple)
        src_nodes, inverse = np.unique(
            np.concatenate([frontier, edge_src_g]), return_inverse=True)
        edge_src_l = inverse[len(frontier):].astype(np.int32)
        e = len(edge_src_l)
        e_pad = max(pad_multiple, int(np.ceil(e / pad_multiple)) * pad_multiple)
        blocks.append(Block(
            src_nodes=src_nodes.astype(np.int32),
            dst_nodes=frontier.copy(),
            edge_src=_pad(edge_src_l, e_pad),
            edge_dst=_pad(edge_dst_l, e_pad),
            edge_mask=_pad(np.ones(e, bool), e_pad),
            n_src=len(src_nodes), n_dst=len(frontier)))
        frontier = src_nodes.astype(np.int32)
    blocks.reverse()  # deepest hop first: apply layer L on block[0]
    return blocks


def _pad(a: np.ndarray, n: int) -> np.ndarray:
    out = np.zeros(n, dtype=a.dtype)
    out[: len(a)] = a
    return out


def subgraph_redundancy(all_blocks: list[list[Block]]) -> float:
    """Paper Fig 2 metric: (sum of per-batch expanded vertex counts) /
    (count of unique vertices touched) — 1.0 means no redundancy."""
    total = 0
    seen: set[int] = set()
    for blocks in all_blocks:
        verts = np.unique(np.concatenate([b.src_nodes[:b.n_src] for b in blocks]))
        total += len(verts)
        seen.update(verts.tolist())
    return total / max(len(seen), 1)
