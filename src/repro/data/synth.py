"""Synthetic user-item bipartite graphs with power-law degree structure.

The paper's real datasets (movielens-10m / gowalla / amazon-book) are
external downloads; we reproduce their published shape statistics
(Table 2: #users, #items, density) with a Zipf-popularity generator so
accuracy/perf experiments run hermetically.  ``DATASET_STATS`` carries
the paper's exact numbers; ``scaled(name, factor)`` gives the same
density at reduced size for CPU-runnable accuracy tests.
"""
from __future__ import annotations

import dataclasses

import numpy as np

# Paper Table 2 (users, items, interactions).
DATASET_STATS = {
    "movielens-10m": (70_000, 11_000, 10_000_000),
    "gowalla": (30_000, 41_000, 1_000_000),
    "amazon-book": (53_000, 92_000, 3_000_000),
    "m-x25": (349_000, 53_000, 250_000_000),
    "g-x256": (478_000, 656_000, 263_000_000),
    "a-x100": (526_000, 916_000, 298_000_000),
    "m-x100": (699_000, 107_000, 1_000_000_000),
    "g-x1024": (955_000, 1_311_000, 1_052_000_000),
    "a-x400": (1_053_000, 1_832_000, 1_194_000_000),
}


@dataclasses.dataclass
class InteractionData:
    user: np.ndarray   # int32[E]
    item: np.ndarray   # int32[E]
    n_users: int
    n_items: int

    @property
    def n_edges(self) -> int:
        return len(self.user)

    @property
    def density(self) -> float:
        return self.n_edges / (self.n_users * self.n_items)


def zipf_probs(n: int, alpha: float = 1.05) -> np.ndarray:
    p = 1.0 / np.arange(1, n + 1) ** alpha
    return p / p.sum()


def generate_bipartite(n_users: int, n_items: int, n_edges: int,
                       seed: int = 0, alpha: float = 1.05) -> InteractionData:
    """Power-law bipartite generator: user activity and item popularity
    both Zipf-distributed (matches the paper's Fig 13 degree shape).
    Deduplicates; may return slightly fewer than n_edges."""
    rng = np.random.default_rng(seed)
    pu = zipf_probs(n_users, alpha)
    pi = zipf_probs(n_items, alpha)
    # sample-dedup-resample until filled (Zipf heads collide heavily)
    keys: np.ndarray = np.zeros(0, np.int64)
    for _ in range(12):
        need = n_edges - len(keys)
        if need <= 0:
            break
        m = int(need * 1.5) + 16
        u = rng.choice(n_users, m, p=pu)
        i = rng.choice(n_items, m, p=pi)
        keys = np.unique(np.concatenate([keys, u.astype(np.int64) * n_items + i]))
    if len(keys) > n_edges:
        keys = rng.choice(keys, n_edges, replace=False)
    u = (keys // n_items).astype(np.int32)
    i = (keys % n_items).astype(np.int32)
    # shuffle user/item id space so ids are not popularity-ordered
    uperm = rng.permutation(n_users).astype(np.int32)
    iperm = rng.permutation(n_items).astype(np.int32)
    return InteractionData(uperm[u], iperm[i], n_users, n_items)


def scaled(name: str, target_edges: int, seed: int = 0) -> InteractionData:
    """Same density/aspect as the named paper dataset, shrunk so that it
    has ~target_edges interactions."""
    nu, ni, ne = DATASET_STATS[name]
    f = (target_edges / ne) ** 0.5
    return generate_bipartite(max(int(nu * f), 16), max(int(ni * f), 16),
                              target_edges, seed=seed)


def group_by_user(user: np.ndarray, item: np.ndarray,
                  n_users: int) -> list[np.ndarray]:
    """Per-user item lists: out[u] = item ids of user u's interactions
    (empty array when none).  The held-out ``test_pos`` structure the
    eval metrics consume — the user-CSR sliced into views, no U×I
    anything."""
    from repro.core.bpr import build_user_csr
    indptr, items = build_user_csr(user, item, n_users)
    return [items[indptr[u]:indptr[u + 1]] for u in range(n_users)]


def train_test_split(data: InteractionData, test_frac: float = 0.1,
                     seed: int = 0):
    """Paper protocol: 90/10 edge split."""
    rng = np.random.default_rng(seed)
    e = data.n_edges
    perm = rng.permutation(e)
    cut = int(e * (1 - test_frac))
    tr, te = perm[:cut], perm[cut:]
    train = InteractionData(data.user[tr], data.item[tr], data.n_users, data.n_items)
    test = InteractionData(data.user[te], data.item[te], data.n_users, data.n_items)
    return train, test
