"""PlacementPolicy — pluggable tensor→tier assignment over a topology.

The paper solved placement by hand per kernel (AppDirect + numactl,
§6); §8.1 points at AutoTM's ILP as the automated future.  This module
ships both, behind one registry so the planner, benchmarks, and tests
select policies *by name*:

  ``greedy``        density-ordered knapsack (penalty-per-byte), the
                    production default; auto-certifies itself through
                    the exact DP when the free-tensor count is small.
  ``exact``         0/1-knapsack DP (AutoTM-style) — optimal for small
                    tensor counts, used to certify the greedy plan.
  ``paper-recipe``  the paper's §6 hand recipe as pins: the |E|-sized
                    graph structure and SDDMM message streams take the
                    capacity tier (nt-written, per the emitted write
                    policy) along with the once-per-step optimizer
                    state, while the embedding tables keep fast-tier
                    residency; the rest falls back to greedy.
  ``all-fast`` / ``all-slow``   what-if baselines (Fig 10's
                    Optane-alone arm; capacity is reported, not
                    enforced).

A policy is ``(profiles, topology, *, budgets=None, pins=None) ->
Plan``.  ``pins`` maps a profile name (exact, or substring — e.g. the
dotted-path ``params['item_embed']`` or just ``item_embed``) to a tier
name or the ``fast``/``slow`` aliases; pins override the profiles' own
``pinned`` fields.

Unlike the pre-redesign planner, tensors pinned to a slow tier
contribute their *real* step penalty to ``est_step_penalty_s`` — a
paper-recipe plan reports what its pins actually cost.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, Iterable, Mapping, Protocol

from repro.memory.profiles import AccessProfile
from repro.memory.topology import TierTopology, get_topology, resolve_tier


@dataclasses.dataclass(frozen=True)
class Placement:
    """One tensor's assignment: the tier it lives on and the step-time
    penalty actually incurred there (0.0 on the fast tier — including
    for pinned tensors, whose slow-tier penalties are real and counted)."""
    tier: str
    penalty_s: float
    pinned: bool = False


@dataclasses.dataclass
class Plan:
    """A complete placement over one topology."""
    placements: dict[str, Placement]
    used: dict[str, int]             # bytes resident per tier
    budgets: dict[str, int]          # capacity per tier
    est_step_penalty_s: float        # total slow-tier penalty incurred
    topology: TierTopology
    policy: str = "greedy"

    # ------------------------------------------------------------ queries
    def tier(self, name: str) -> str:
        return self.placements[name].tier

    def is_fast(self, name: str) -> bool:
        return self.placements[name].tier == self.topology.fast.name

    def memory_kind(self, name: str) -> str | None:
        return self.topology.tier(self.tier(name)).memory_kind

    def demoted(self) -> list[str]:
        """Names placed off the fast tier, sorted."""
        return sorted(n for n in self.placements if not self.is_fast(n))

    # ------------------------------------------------------------ legacy view
    @property
    def hbm_used(self) -> int:
        """Fast-tier bytes (legacy name from the two-tier TPU planner)."""
        return self.used[self.topology.fast.name]

    @property
    def hbm_budget(self) -> int:
        return self.budgets[self.topology.fast.name]

    # ------------------------------------------------------------ §6 table
    def write_policy(self) -> dict[str, str]:
        """The per-kernel write-policy table, emitted from the plan
        (paper §6): SDDMM streams its edge-message output (nt-write
        analogue — no accumulator) whenever the topology has write
        asymmetry to route around or a message tensor actually lands
        off the fast tier; SpMM and embedding_bag always accumulate in
        fast memory (nt-write destroys them, paper Fig 9)."""
        msgs_demoted = any("messages" in n and not self.is_fast(n)
                           for n in self.placements)
        sddmm = "streaming" if (msgs_demoted or not self.topology.is_uniform) \
            else "accumulate"
        return {"sddmm": sddmm, "spmm": "accumulate",
                "embedding_bag": "accumulate"}

    # ------------------------------------------------------------ snapshot
    def to_dict(self) -> dict:
        """Deterministic JSON form (tools/check_plan_snapshot.py)."""
        return {
            "topology": self.topology.name,
            "policy": self.policy,
            "placements": {n: self.placements[n].tier
                           for n in sorted(self.placements)},
            "used": {k: int(v) for k, v in sorted(self.used.items())},
            "budgets": {k: int(v) for k, v in sorted(self.budgets.items())},
            "est_step_penalty_s": round(float(self.est_step_penalty_s), 9),
            "write_policy": self.write_policy(),
        }


class PlacementPolicy(Protocol):
    def __call__(self, profiles: Iterable[AccessProfile],
                 topology: TierTopology | str, *,
                 budgets: Mapping[str, int] | None = None,
                 pins: Mapping[str, str] | None = None) -> Plan: ...


# ---------------------------------------------------------------- helpers
def _bytes_on(p: AccessProfile, topology: TierTopology, tier: str) -> int:
    """Resident bytes of ``p`` on ``tier``: quantized ``store_bytes``
    off the fast tier (int8 capacity-tier tables at ~1/4 bytes), dense
    ``nbytes`` on it — the quantity every budget/usage account uses."""
    return p.bytes_on(tier == topology.fast.name)


def _budgets(topology: TierTopology,
             overrides: Mapping[str, int] | None) -> dict[str, int]:
    out = topology.capacities()
    for name, cap in (overrides or {}).items():
        if name not in out:
            raise KeyError(f"no tier {name!r} in topology "
                           f"{topology.name!r} to budget")
        out[name] = int(cap)
    return out


def _effective_pin(p: AccessProfile, topology: TierTopology,
                   pins: Mapping[str, str] | None) -> str | None:
    """The tier this profile is pinned to, if any: an entry in ``pins``
    (exact name match wins over substring matches, which are resolved
    in sorted-pattern order) overrides the profile's own ``pinned``."""
    label = None
    if pins:
        if p.name in pins:
            label = pins[p.name]
        else:
            for pat in sorted(pins):
                if pat in p.name:
                    label = pins[pat]
                    break
    if label is None:
        label = p.pinned
    return resolve_tier(topology, label) if label is not None else None


def _place_pinned(profiles, topology, budgets, pins):
    """Shared pinned-first pass: returns (placements, used, free,
    pinned_penalty).  Pinned slow-tier tensors carry their real penalty
    (the pre-redesign planner under-counted them as 0.0)."""
    placements: dict[str, Placement] = {}
    used = {t.name: 0 for t in topology.tiers}
    free: list[AccessProfile] = []
    pinned_penalty = 0.0
    for p in profiles:
        tier = _effective_pin(p, topology, pins)
        if tier is None:
            free.append(p)
            continue
        pen = topology.demotion_penalty(p, tier)
        placements[p.name] = Placement(tier, pen, pinned=True)
        used[tier] += _bytes_on(p, topology, tier)
        pinned_penalty += pen
    fast = topology.fast.name
    if used[fast] > budgets[fast]:
        raise MemoryError(
            f"pinned tensors ({used[fast]/2**30:.1f} GiB) exceed "
            f"{fast} budget ({budgets[fast]/2**30:.1f} GiB)")
    return placements, used, free, pinned_penalty


# ---------------------------------------------------------------- policies
def place_greedy(profiles, topology, *, budgets=None, pins=None,
                 exact_threshold: int = 16) -> Plan:
    """Density-ordered knapsack: keep the highest penalty-per-byte
    tensors on the fast tier until its budget runs out, waterfall the
    rest down the tier order.  Optimal here because cost is additive
    and the only constraint is capacity (a fractional knapsack rounded
    down); when the free-tensor count is small and the topology has two
    tiers, the exact DP answers instead (self-certifying)."""
    topology = get_topology(topology)
    budgets = _budgets(topology, budgets)
    profiles = list(profiles)
    n_free = sum(1 for p in profiles
                 if _effective_pin(p, topology, pins) is None)
    if 0 < n_free <= exact_threshold and len(topology.tiers) == 2:
        plan = place_exact(profiles, topology, budgets=budgets, pins=pins)
        for t in topology.tiers[1:]:
            if plan.used[t.name] > budgets[t.name]:
                raise MemoryError(f"{t.name} tier over budget")
        return dataclasses.replace(plan, policy="greedy")
    placements, used, free, penalty = _place_pinned(
        profiles, topology, budgets, pins)
    for t in topology.tiers[1:]:
        if used[t.name] > budgets[t.name]:
            raise MemoryError(f"pinned tensors over {t.name} budget")
    ranked = sorted(
        free, key=lambda p: -topology.demotion_penalty(p) / max(p.nbytes, 1))
    for p in ranked:
        for t in topology.tiers:
            size = _bytes_on(p, topology, t.name)
            if used[t.name] + size <= budgets[t.name]:
                pen = topology.demotion_penalty(p, t)
                placements[p.name] = Placement(t.name, pen)
                used[t.name] += size
                penalty += pen
                break
        else:
            raise MemoryError(f"tensor {p.name} fits no tier")
    return Plan(placements, used, budgets, penalty, topology,
                policy="greedy")


def place_exact(profiles, topology, *, budgets=None, pins=None) -> Plan:
    """Exact 0/1-knapsack DP (small tensor counts, two-tier topologies
    only) — the AutoTM-style ILP answer, used to certify greedy plans.
    The pinned fast-tier size is computed once, outside the 2^n subset
    loop (the pre-redesign DP recomputed it per subset)."""
    topology = get_topology(topology)
    if len(topology.tiers) != 2:
        raise ValueError("exact planner supports two-tier topologies; "
                         f"{topology.name!r} has {len(topology.tiers)}")
    budgets = _budgets(topology, budgets)
    profiles = list(profiles)
    placements, used, free, penalty = _place_pinned(
        profiles, topology, budgets, pins)
    if len(free) > 24:
        raise ValueError("exact planner is for small tensor counts")
    fast, slow = topology.fast.name, topology.slow.name
    pinned_fast = used[fast]                # hoisted: loop-invariant
    budget = budgets[fast]
    # best = (value, kept_bytes, keep): penalty-value first, then —
    # among equal-value subsets — the one keeping MORE bytes fast, so
    # zero-penalty topologies (uniform) never demote gratuitously and
    # the DP agrees with greedy's fill-fast-first behaviour on ties.
    best_keep: tuple[float, int, tuple[int, ...]] = (-1.0, -1, ())
    for keep in itertools.product([0, 1], repeat=len(free)):
        size = sum(p.nbytes for p, k in zip(free, keep) if k)
        if size + pinned_fast > budget:
            continue
        value = sum(topology.demotion_penalty(p)
                    for p, k in zip(free, keep) if k)
        if (value, size) > (best_keep[0], best_keep[1]):
            best_keep = (value, size, keep)
    if not free:
        best_keep = (0.0, 0, ())
    elif best_keep[0] < 0.0:
        raise MemoryError("pinned tensors leave no room on the fast tier")
    for p, k in zip(free, best_keep[2]):
        if k:
            placements[p.name] = Placement(fast, 0.0)
            used[fast] += p.nbytes
        else:
            pen = topology.demotion_penalty(p)
            placements[p.name] = Placement(slow, pen)
            used[slow] += _bytes_on(p, topology, slow)
            penalty += pen
    return Plan(placements, used, budgets, penalty, topology,
                policy="exact")


# Paper §6 recipe, as name-pattern pins over the live tensor names
# (both the planner's params[...]/opt[...]/graph/messages_l* names and
# the analytic gnn_recsys_profiles names): everything |E|-sized lives
# on the capacity tier — the graph structure because it is read-only,
# the SDDMM message streams because only that tier can hold them (the
# nt-write/streaming policy the plan emits is what makes those writes
# survivable, §6) — while the node-sized embedding tables keep
# fast-tier residency; optimizer state is touched once per step.
_PAPER_RECIPE_PINS = (
    ("graph", "slow"),       # read-only structure: Optane holds it
    ("messages", "slow"),    # |E|-sized SDDMM streams: nt-written to PM
    ("opt", "slow"),         # optimizer state: one touch per step
    ("embed", "fast"),       # embedding tables: row-granular hot reads
)


def place_paper_recipe(profiles, topology, *, budgets=None,
                       pins=None) -> Plan:
    """The paper's §5-§6 hand-tuned placement as pins, greedy for any
    tensor the recipe doesn't name.  Explicit user pins win over the
    recipe."""
    profiles = list(profiles)
    user = dict(pins or {})
    recipe: dict[str, str] = {}
    for p in profiles:
        # a profile the user pins (by name or substring) is theirs —
        # the recipe must not shadow it with an exact-name pin
        if any(pat == p.name or pat in p.name for pat in user):
            continue
        for pat, tier in _PAPER_RECIPE_PINS:
            if pat in p.name:
                recipe[p.name] = tier
                break
    recipe.update(user)
    plan = place_greedy(profiles, topology, budgets=budgets, pins=recipe,
                        exact_threshold=0)
    return dataclasses.replace(plan, policy="paper-recipe")


def _place_everything(tier_index: int, policy: str):
    def place_all(profiles, topology, *, budgets=None, pins=None) -> Plan:
        topology = get_topology(topology)
        budgets = _budgets(topology, budgets)
        t = topology.tiers[tier_index]
        placements = {}
        used = {x.name: 0 for x in topology.tiers}
        penalty = 0.0
        for p in profiles:
            pen = topology.demotion_penalty(p, t)
            placements[p.name] = Placement(t.name, pen)
            used[t.name] += _bytes_on(p, topology, t.name)
            penalty += pen
        return Plan(placements, used, budgets, penalty, topology,
                    policy=policy)
    place_all.__doc__ = (
        f"What-if baseline: every tensor on the {'fastest' if tier_index == 0 else 'slowest'} "
        "tier (capacity reported, not enforced — Fig 10's comparison arms).")
    return place_all


# ---------------------------------------------------------------- registry
_POLICIES: dict[str, Callable] = {}


def register_policy(name: str, policy: Callable) -> None:
    _POLICIES[name] = policy


def policy_names() -> list[str]:
    return sorted(_POLICIES)


def get_policy(name: str) -> Callable:
    if name not in _POLICIES:
        raise KeyError(f"unknown placement policy {name!r}; "
                       f"known: {policy_names()}")
    return _POLICIES[name]


register_policy("greedy", place_greedy)
register_policy("exact", place_exact)
register_policy("paper-recipe", place_paper_recipe)
register_policy("all-fast", _place_everything(0, "all-fast"))
register_policy("all-slow", _place_everything(-1, "all-slow"))
