"""AccessProfile — the per-tensor traffic descriptor every placement
policy consumes (paper §2.1's memory model, per tensor).

Moved here from ``core.tiered_memory`` (which now re-exports these as a
deprecation shim): the profile is topology-independent — bytes, reads
and writes per step, and the access granularity of one touch — and the
topology's cost model turns it into a per-tier step time.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class AccessProfile:
    """Static per-step traffic descriptor for one tensor."""
    name: str
    nbytes: int
    reads_per_step: float = 1.0     # full-tensor read equivalents
    writes_per_step: float = 0.0    # full-tensor write equivalents
    access_size: int = 512          # bytes per touch (embedding row, tile, ...)
    pinned: str | None = None       # force a tier by name, or the
    #                                 'fast'/'slow' ('hbm'/'host') aliases

    def step_traffic(self) -> tuple[float, float]:
        return (self.nbytes * self.reads_per_step,
                self.nbytes * self.writes_per_step)


# ---------------------------------------------------------------------------
# Workload profile builders (used by configs and benchmarks)

def gnn_recsys_profiles(n_users: int, n_items: int, n_edges: int,
                        embed_dim: int, n_layers: int,
                        dtype_bytes: int = 4) -> list[AccessProfile]:
    """Paper §2.1 memory model: len(m)*|E| per layer for messages,
    len(x)*|V| for embeddings, doubled for training (grads)."""
    v = n_users + n_items
    row = embed_dim * dtype_bytes
    out = [
        AccessProfile("embeddings", v * row, reads_per_step=2 * n_layers,
                      writes_per_step=2.0, access_size=row),
        AccessProfile("embed_grads", v * row, reads_per_step=1.0,
                      writes_per_step=2 * n_layers, access_size=row),
        AccessProfile("opt_state", 2 * v * row, reads_per_step=1.0,
                      writes_per_step=1.0, access_size=row),
        AccessProfile("graph_coo", 2 * n_edges * 8, reads_per_step=2 * n_layers,
                      writes_per_step=0.0, access_size=8),
    ]
    for l in range(n_layers):
        # SDDMM output: written once (streaming), read once by SpMM; and
        # re-read/re-written in backward.
        out.append(AccessProfile(f"messages_l{l}", n_edges * row,
                                 reads_per_step=2.0, writes_per_step=2.0,
                                 access_size=row))
        out.append(AccessProfile(f"activations_l{l}", v * row,
                                 reads_per_step=2.0, writes_per_step=2.0,
                                 access_size=row))
    return out
