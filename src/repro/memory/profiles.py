"""AccessProfile — the per-tensor traffic descriptor every placement
policy consumes (paper §2.1's memory model, per tensor).

Moved here from ``core.tiered_memory`` (which now re-exports these as a
deprecation shim): the profile is topology-independent — bytes, reads
and writes per step, and the access granularity of one touch — and the
topology's cost model turns it into a per-tier step time.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class AccessProfile:
    """Static per-step traffic descriptor for one tensor."""
    name: str
    nbytes: int
    reads_per_step: float = 1.0     # full-tensor read equivalents
    writes_per_step: float = 0.0    # full-tensor write equivalents
    access_size: int = 512          # bytes per touch (embedding row, tile, ...)
    pinned: str | None = None       # force a tier by name, or the
    #                                 'fast'/'slow' ('hbm'/'host') aliases
    store_bytes: int | None = None  # bytes this tensor occupies when it
    #                                 lives OFF the fast tier (quantized
    #                                 capacity-tier storage, e.g. int8
    #                                 embedding tables at ~1/4 bytes);
    #                                 None -> stored dense (nbytes)

    def step_traffic(self) -> tuple[float, float]:
        return (self.nbytes * self.reads_per_step,
                self.nbytes * self.writes_per_step)

    def bytes_on(self, fast: bool) -> int:
        """Resident bytes on a tier: the dense ``nbytes`` on the fast
        tier (tensors are always computed on in fp32 there), the
        quantized ``store_bytes`` on any slower tier when set."""
        return self.nbytes if fast or self.store_bytes is None \
            else self.store_bytes


# ---------------------------------------------------------------------------
# Workload profile builders (used by configs and benchmarks)

def quantized_table_bytes(n_rows: int, row_bytes: int,
                          dtype_bytes: int = 4) -> int:
    """Capacity-tier footprint of an int8-stored embedding table: one
    byte per element plus a per-row fp32 scale — the ~4x capacity
    multiplier the planner prices (``AccessProfile.store_bytes``)."""
    return n_rows * (row_bytes // dtype_bytes) + n_rows * 4


def gnn_recsys_profiles(n_users: int, n_items: int, n_edges: int,
                        embed_dim: int, n_layers: int,
                        dtype_bytes: int = 4,
                        embed_store: str = "fp32",
                        fused_messages: bool = False) -> list[AccessProfile]:
    """Paper §2.1 memory model: len(m)*|E| per layer for messages,
    len(x)*|V| for embeddings, doubled for training (grads).  With
    ``embed_store='int8'`` the embedding table carries a quantized
    capacity-tier footprint (``store_bytes`` at ~1/4 bytes), the
    storage arm of ``repro.api.CompressionCfg``.  ``fused_messages``
    models the fused Hadamard-SpMM route: the per-layer [E, D] message
    stream never exists, so its profiles are dropped entirely."""
    v = n_users + n_items
    row = embed_dim * dtype_bytes
    embed_sb = quantized_table_bytes(v, row, dtype_bytes) \
        if embed_store == "int8" else None
    out = [
        AccessProfile("embeddings", v * row, reads_per_step=2 * n_layers,
                      writes_per_step=2.0, access_size=row,
                      store_bytes=embed_sb),
        AccessProfile("embed_grads", v * row, reads_per_step=1.0,
                      writes_per_step=2 * n_layers, access_size=row),
        AccessProfile("opt_state", 2 * v * row, reads_per_step=1.0,
                      writes_per_step=1.0, access_size=row),
        AccessProfile("graph_coo", 2 * n_edges * 8, reads_per_step=2 * n_layers,
                      writes_per_step=0.0, access_size=8),
    ]
    for l in range(n_layers):
        # SDDMM output: written once (streaming), read once by SpMM; and
        # re-read/re-written in backward.  The fused Hadamard-SpMM
        # route forms the product in VMEM only — no stream to profile.
        if not fused_messages:
            out.append(AccessProfile(f"messages_l{l}", n_edges * row,
                                     reads_per_step=2.0,
                                     writes_per_step=2.0,
                                     access_size=row))
        out.append(AccessProfile(f"activations_l{l}", v * row,
                                 reads_per_step=2.0, writes_per_step=2.0,
                                 access_size=row))
    return out
