"""repro.memory — the declarative memory-tier subsystem.

Three layers, replacing the hardcoded constants + advisory placement of
``core.tiered_memory`` (now a deprecation shim):

  ``TierTopology`` / ``Tier``  — a declarative, registered description
      of the memory system (``tpu-hbm-host``, the paper's
      ``dram-optane-appdirect`` / ``dram-optane-memorymode``,
      ``uniform`` for CPU CI);
  ``PlacementPolicy`` registry — greedy knapsack, exact DP certifier,
      the paper's §6 ``paper-recipe`` pins, all-fast/all-slow
      baselines, selected by name;
  ``TieredExecutor``           — makes the plan real on every backend
      (JAX memory kinds on TPU, a host byte store + streaming
      fetch/commit elsewhere), with the ``HostResident`` row-granular
      gather facade for serving.

The Experiment API surface is ``repro.api.MemoryCfg``; the planner
entry is ``repro.pipeline.plan.build_train_plan``.
"""
from repro.memory.cache import CacheStats, HotRowCache
from repro.memory.executor import (HostResident, QuantizedHostResident,
                                   TieredExecutor, memory_kind_sharding)
from repro.memory.policies import (Placement, PlacementPolicy, Plan,
                                   get_policy, place_exact, place_greedy,
                                   policy_names, register_policy)
from repro.memory.profiles import (AccessProfile, gnn_recsys_profiles,
                                   quantized_table_bytes)
from repro.memory.topology import (Tier, TierTopology, get_topology,
                                   register_topology, resolve_tier,
                                   topology_names)

__all__ = [
    "Tier", "TierTopology", "get_topology", "register_topology",
    "topology_names", "resolve_tier",
    "AccessProfile", "gnn_recsys_profiles", "quantized_table_bytes",
    "Placement", "Plan", "PlacementPolicy", "get_policy",
    "register_policy", "policy_names", "place_greedy", "place_exact",
    "TieredExecutor", "HostResident", "QuantizedHostResident",
    "memory_kind_sharding", "HotRowCache", "CacheStats",
]
