"""TierTopology — the declarative description of a heterogeneous memory
system.

The paper's headline contribution is *configuring* a two-tier memory
system (DRAM + Optane, AppDirect vs Memory Mode, §5-§6) for GNNRecSys;
this module makes that configuration a first-class, swappable input
instead of module-level constants.  A topology is an ordered list of
named ``Tier``s, fastest first, each carrying:

  * read/write bandwidth (bytes/s at full utilization) — the slow
    tier's write asymmetry is what makes SDDMM outputs the worst
    tensors to demote (paper Fig 8: 7.7x);
  * capacity (bytes per device) — the knapsack budget per tier;
  * access granularity — the transfer size at which the tier reaches
    peak bandwidth.  Smaller accesses get ``access/granularity``
    utilization (paper Fig 7b: Optane needs >=256 B writes; Memory
    Mode's cacheline management needs multi-KB reads);
  * an optional JAX ``memory_kind`` so the executor can place bytes for
    real on backends that expose one (TPU ``pinned_host``).

Registered presets:

  ``tpu-hbm-host``           HBM (819 GB/s, 16 GiB) + host DRAM over
                             PCIe (16/8 GB/s, Optane-like asymmetry) —
                             the values the old ``core.tiered_memory``
                             constants hardcoded.
  ``dram-optane-appdirect``  the paper's §5 AppDirect recipe: DRAM +
                             Optane with nt-writes (read 37%, nt-write
                             18% of DRAM; 256 B saturation).
  ``dram-optane-memorymode`` the paper's Memory Mode baseline: the HW
                             cache manages placement at cacheline
                             granularity, so the slow tier sees normal
                             writes (7%), a cache-miss read discount,
                             and a 4 KiB saturation point — strictly
                             worse per byte than AppDirect, which is
                             the §5 qualitative ordering.
  ``uniform``                both tiers identical — every demotion
                             penalty is exactly 0.0, so CPU CI can
                             exercise the tiered executor while staying
                             bit-identical to the all-fast run.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class Tier:
    """One memory tier: bandwidths, capacity, and access behaviour."""
    name: str
    read_bw: float               # bytes/s at full utilization
    write_bw: float              # bytes/s at full utilization
    capacity: int                # bytes per device
    granularity: int = 1         # access size (bytes) that saturates bw
    memory_kind: str | None = None   # JAX memory kind, when the backend
    #                                  has one ('device', 'pinned_host')

    def utilization(self, access_size: int) -> float:
        """Fraction of peak bandwidth an ``access_size``-byte touch
        achieves (paper Fig 7b's saturation curve, linear below the
        granularity point)."""
        return min(1.0, access_size / self.granularity)

    def step_time(self, read_bytes: float, write_bytes: float,
                  access_size: int) -> float:
        """Seconds/step to move this traffic through this tier."""
        util = self.utilization(access_size)
        return (read_bytes / (self.read_bw * util)
                + write_bytes / (self.write_bw * util))


@dataclasses.dataclass(frozen=True)
class TierTopology:
    """An ordered set of tiers, fastest first."""
    name: str
    tiers: tuple[Tier, ...]

    def __post_init__(self):
        object.__setattr__(self, "tiers", tuple(self.tiers))
        if not self.tiers:
            raise ValueError("a topology needs at least one tier")
        names = [t.name for t in self.tiers]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate tier names in {self.name!r}: "
                             f"{names}")

    # ------------------------------------------------------------ lookup
    @property
    def fast(self) -> Tier:
        return self.tiers[0]

    @property
    def slow(self) -> Tier:
        return self.tiers[-1]

    @property
    def names(self) -> tuple[str, ...]:
        return tuple(t.name for t in self.tiers)

    def tier(self, name: str) -> Tier:
        for t in self.tiers:
            if t.name == name:
                return t
        raise KeyError(f"no tier {name!r} in topology {self.name!r}; "
                       f"tiers: {list(self.names)}")

    def capacities(self) -> dict[str, int]:
        return {t.name: t.capacity for t in self.tiers}

    @property
    def is_uniform(self) -> bool:
        """True when every tier moves bytes at the same speed — then no
        placement can change step time and every penalty is 0.0 (the
        CPU-CI topology)."""
        f = self.fast
        return all(t.read_bw == f.read_bw and t.write_bw == f.write_bw
                   and t.granularity == f.granularity for t in self.tiers)

    # ------------------------------------------------------------ cost model
    def step_time(self, profile, tier: Tier | str) -> float:
        """Seconds/step this tensor's traffic costs when resident on
        ``tier`` (profile: ``repro.memory.profiles.AccessProfile``).

        A profile with quantized off-fast storage (``store_bytes``)
        moves proportionally fewer bytes per touch over a slow tier —
        dequant-on-gather streams the int8 rows, not the fp32 ones — so
        both the traffic and the per-touch access size scale by
        ``store_bytes/nbytes`` there."""
        t = tier if isinstance(tier, Tier) else self.tier(tier)
        rd, wr = profile.step_traffic()
        access = profile.access_size
        sb = getattr(profile, "store_bytes", None)
        if sb is not None and t.name != self.fast.name and profile.nbytes:
            f = sb / profile.nbytes
            rd, wr = rd * f, wr * f
            access = max(1, int(access * f))
        return t.step_time(rd, wr, access)

    def demotion_penalty(self, profile, tier: Tier | str | None = None
                         ) -> float:
        """Extra seconds/step if this tensor lives on ``tier`` (default:
        the slowest tier) instead of the fast tier — the quantity the
        paper's Fig 8 measures per kernel."""
        t = self.slow if tier is None else (
            tier if isinstance(tier, Tier) else self.tier(tier))
        return self.step_time(profile, t) - self.step_time(profile, self.fast)

    # ------------------------------------------------------------ derivation
    def with_capacity(self, overrides: dict[str, int]) -> "TierTopology":
        """New topology with some tiers' capacities replaced (the
        ``MemoryCfg.capacity`` override path).  Unknown tier names
        raise."""
        if not overrides:
            return self
        for k in overrides:
            self.tier(k)                      # raise on unknown names
        return TierTopology(self.name, tuple(
            dataclasses.replace(t, capacity=int(overrides[t.name]))
            if t.name in overrides else t for t in self.tiers))

    def describe(self) -> str:
        lines = [f"TierTopology[{self.name}]"]
        for t in self.tiers:
            kind = f" memory_kind={t.memory_kind}" if t.memory_kind else ""
            lines.append(
                f"  {t.name:12s} read={t.read_bw/1e9:7.1f} GB/s "
                f"write={t.write_bw/1e9:7.1f} GB/s "
                f"cap={t.capacity/2**30:8.1f} GiB "
                f"granularity={t.granularity}B{kind}")
        return "\n".join(lines)


# ---------------------------------------------------------------- registry
_TOPOLOGIES: dict[str, TierTopology] = {}


def register_topology(topo: TierTopology) -> TierTopology:
    _TOPOLOGIES[topo.name] = topo
    return topo


def topology_names() -> list[str]:
    return sorted(_TOPOLOGIES)


def get_topology(name: "str | TierTopology") -> TierTopology:
    """Resolve a topology by name (or pass one through)."""
    if isinstance(name, TierTopology):
        return name
    if name not in _TOPOLOGIES:
        raise KeyError(f"unknown memory topology {name!r}; "
                       f"known: {topology_names()}")
    return _TOPOLOGIES[name]


def resolve_tier(topology: TierTopology, label: str) -> str:
    """Tier-name aliasing for pins and legacy profiles: exact tier names
    pass through; 'fast'/'slow' (and the legacy 'hbm'/'host') map to the
    topology's first/last tier."""
    if label in topology.names:
        return label
    alias = {"fast": topology.fast.name, "hbm": topology.fast.name,
             "slow": topology.slow.name, "host": topology.slow.name}
    if label in alias:
        return alias[label]
    raise ValueError(f"unknown tier {label!r} for topology "
                     f"{topology.name!r}; tiers: {list(topology.names)} "
                     f"(aliases: fast, slow, hbm, host)")


# ---------------------------------------------------------------- presets
# TPU: HBM per v5e chip; host link = PCIe gen3 x16-ish effective with
# Optane-like R/W asymmetry.  These are exactly the values the old
# core.tiered_memory module-level constants hardcoded, so plans built on
# this preset are numerically identical to the pre-redesign planner.
register_topology(TierTopology("tpu-hbm-host", (
    Tier("hbm", read_bw=819e9, write_bw=819e9, capacity=16 * 2**30,
         granularity=1, memory_kind="device"),
    Tier("host", read_bw=16e9, write_bw=8e9, capacity=512 * 2**30,
         granularity=256, memory_kind="pinned_host"),
)))

# Paper §5, AppDirect: explicit placement, nt-writes on the slow tier
# (read 37% / nt-write 18% of DRAM; 256 B write saturation — Fig 7).
register_topology(TierTopology("dram-optane-appdirect", (
    Tier("dram", read_bw=100e9, write_bw=80e9, capacity=192 * 2**30,
         granularity=1),
    Tier("optane", read_bw=37e9, write_bw=18e9, capacity=1536 * 2**30,
         granularity=256),
)))

# Paper §5, Memory Mode: the DRAM acts as a hardware-managed cacheline
# cache in front of the same Optane pool — normal writes (7% of DRAM),
# a cache-miss read discount, and a multi-KiB saturation point because
# 64 B cacheline management wastes row-granular traffic.  Per byte this
# is strictly worse than AppDirect: the §5 qualitative ordering.
register_topology(TierTopology("dram-optane-memorymode", (
    Tier("dram-cache", read_bw=100e9, write_bw=80e9, capacity=192 * 2**30,
         granularity=1),
    Tier("optane-mm", read_bw=30e9, write_bw=7e9, capacity=1536 * 2**30,
         granularity=4096),
)))

# CPU CI: two tiers, same speed — demotion penalties are exactly 0.0 and
# the tiered executor's gather/commit path round-trips bytes, so a
# demoted run is bit-identical to the all-fast run (pinned by
# tests/test_memory.py).
register_topology(TierTopology("uniform", (
    Tier("fast", read_bw=16e9, write_bw=16e9, capacity=1 << 62,
         granularity=1),
    Tier("slow", read_bw=16e9, write_bw=16e9, capacity=1 << 62,
         granularity=1),
)))
