"""HotRowCache — software-managed device-resident cache for slow-tier
embedding rows.

RecNMP's central serving observation (PAPERS.md) is that embedding
access under production traffic is sharply Zipfian: a small hot set of
rows serves most requests.  MTrainS exploits the same skew with
byte-addressable hot/cold tiering.  This module applies both to the
demoted serving tables: a ``HostResident``/``QuantizedHostResident``
table keeps its bytes in the capacity tier, and a fixed budget of
device-resident row slots absorbs the hot set, so steady-state Zipfian
traffic streams only the cold tail over the slow link.

Policy: LFU residency with an admission filter (TinyLFU-style).  Every
requested row bumps a frequency counter whether or not it is resident;
a miss is admitted into a free slot unconditionally, but once the cache
is full it only displaces the coldest resident when the newcomer's
frequency is strictly higher — one-shot scans cannot flush the hot set.
Eviction is deterministic (first minimum-frequency slot), so a serving
sweep is reproducible.

Bit-identity: a cached row is byte-for-byte the row ``backing.take``
returns (the dequantized fp32 view for the int8 arm — dequantization is
deterministic), and a query's output rows are assembled *before* any
admission/eviction from this query mutates the store, so cache-enabled
serving returns exactly the cache-off results (pinned by
tests/test_serving.py and the slow sweep in tests/test_kernel_parity.py).

The planner prices the slot budget against the fast tier
(``pipeline.plan.serving_profiles(cache_rows=...)`` adds a pinned-fast
``serve/hot_cache`` profile), and ``TieredExecutor``/`
``Recommender.describe()`` surface the hit/miss/bytes-streamed
counters.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.memory.executor import HostResident


@dataclasses.dataclass
class CacheStats:
    """Hit/miss accounting in *distinct rows per query* (a row requested
    twice in one batch costs one lookup, like one gather)."""
    hits: int = 0
    misses: int = 0
    bytes_streamed: int = 0
    fills: int = 0
    evictions: int = 0
    queries: int = 0

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def to_dict(self) -> dict:
        return {**dataclasses.asdict(self), "hit_rate": self.hit_rate}


class HotRowCache(HostResident):
    """LFU device-resident row cache over a slow-tier table facade.

    Subclasses ``HostResident`` so serving code that type-routes on the
    facade (``eval.topk.streaming_topk``) streams through the cache
    transparently; ``take``/``block`` return rows bit-identical to the
    uncached gather, with only the misses counted as slow-tier traffic.
    """

    def __init__(self, backing: HostResident, rows: int):
        if not isinstance(backing, HostResident):
            backing = HostResident(backing)
        self.backing = backing
        n, d = backing.shape
        self.rows = int(min(max(rows, 0), n))
        self.stats = CacheStats()
        self._freq = np.zeros(n, np.int64)
        self._slot_of = np.full(n, -1, np.int64)       # row -> slot
        self._slot_ids = np.full(self.rows, -1, np.int64)  # slot -> row
        self._free = list(range(self.rows - 1, -1, -1))
        # the fast-tier slot pool.  Kept as a contiguous buffer the same
        # way TieredExecutor keeps tier residency on backends without
        # discrete device memories (CPU CI): what matters for the model
        # is which bytes cross the slow link (stats.bytes_streamed), and
        # slot reads never do.
        self._store = np.zeros((self.rows, d), np.float32)

    shape = property(lambda self: self.backing.shape)
    dtype = property(lambda self: np.dtype(np.float32))
    nbytes = property(lambda self: self.backing.nbytes)

    @property
    def resident_rows(self) -> int:
        return self.rows - len(self._free)

    def _admit(self, rows: np.ndarray, data: np.ndarray) -> None:
        """Fill free slots; once full, displace the coldest resident only
        when the newcomer is strictly hotter (deterministic first-min
        eviction)."""
        for j, r in enumerate(rows):
            if self._free:
                s = self._free.pop()
            else:
                resident_freq = self._freq[self._slot_ids]
                v = int(np.argmin(resident_freq))
                if self._freq[r] <= resident_freq[v]:
                    continue                     # admission filter
                self._slot_of[self._slot_ids[v]] = -1
                self.stats.evictions += 1
                s = v
            self._slot_ids[s] = r
            self._slot_of[r] = s
            self._store[s] = data[j]
            self.stats.fills += 1

    def take(self, ids):
        ids = np.asarray(ids)
        uniq, inv = np.unique(ids, return_inverse=True)
        self.stats.queries += 1
        self._freq[uniq] += 1
        slots = self._slot_of[uniq]
        resident = slots >= 0
        self.stats.hits += int(resident.sum())
        # assemble the output from the *pre-admission* store: this
        # query's evictions must not corrupt this query's rows
        out = np.empty((len(uniq), self.shape[1]), np.float32)
        out[resident] = self._store[slots[resident]]
        missed = uniq[~resident]
        self.stats.misses += len(missed)
        if len(missed):
            streamed = np.asarray(self.backing.take(missed), np.float32)
            self.stats.bytes_streamed += streamed.nbytes
            out[~resident] = streamed
            self._admit(missed, streamed)
        return out[inv]

    def block(self, ids):
        return self.take(ids)

    def prefill(self, ids) -> None:
        """Warm the cache (the executor's prefetch/fill path): stream the
        given rows up front, without counting them as serving traffic
        hits/misses."""
        ids = np.unique(np.asarray(ids))
        self._freq[ids] += 1
        missed = ids[self._slot_of[ids] < 0]
        if len(missed):
            data = np.asarray(self.backing.take(missed), np.float32)
            self.stats.bytes_streamed += data.nbytes
            self._admit(missed, data)
