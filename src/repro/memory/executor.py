"""TieredExecutor — makes a placement Plan *functional* on every backend.

The pre-redesign ``apply_placements`` was advisory: on backends without
a ``pinned_host`` memory kind (CPU CI) a host demotion changed nothing
but a ``describe()`` string.  The executor gives every backend a real
slow tier:

  * **memory-kind path** (TPU): a demoted leaf is ``device_put`` onto
    its tier's JAX ``memory_kind`` — XLA then streams it over the host
    link on access, which is exactly the traffic the cost model prices.
    ``fetch``/``commit`` are no-ops here.
  * **host-store path** (everything else): a demoted leaf's bytes are
    committed to host memory as a numpy buffer — it genuinely leaves
    the device buffer pool.  Each step the executor *fetches* demoted
    leaves back onto the device (``jax.device_put`` dispatches the H2D
    copy asynchronously, overlapping the previous step's tail),
    computes, then *commits* the updated bytes back to the host store.
    The executor retains no reference to the device copies — once a
    step's state is committed the only live device buffers are the ones
    the next fetch creates, so demoted bytes genuinely leave the device
    pool between steps.  One fetch serves every microbatch of the step
    — the tables don't change inside one accumulated batch — so the
    stream runs at step granularity upward and microbatch granularity
    during serving gathers.

Both paths round-trip bytes exactly (device↔host copies of the same
float32 buffers), so a demoted run computes *bit-identical* results to
the all-fast run — on the ``uniform`` topology the cost model prices
that demotion at exactly 0.0 and CPU CI pins the bit-identity
(tests/test_memory.py).

``HostResident`` is the row-granular serving facade: a slow-tier
embedding table whose bytes live in the host store and whose rows are
gathered/streamed on demand (``take``/``block``), so a query batch
moves O(batch × D) bytes instead of the whole table —
``eval.topk.streaming_topk`` consumes it directly.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.memory.policies import Plan


def memory_kind_sharding(kind: str | None):
    """A single-device sharding onto the given memory kind, when the
    backend exposes one; None otherwise (then the host-store path takes
    over)."""
    if kind is None:
        return None
    try:
        dev = jax.devices()[0]
        kinds = {m.kind for m in dev.addressable_memories()}
        if kind not in kinds:
            return None
        return jax.sharding.SingleDeviceSharding(dev, memory_kind=kind)
    except Exception:  # noqa: BLE001 — backends without memories API
        return None


class HostResident:
    """A slow-tier table: bytes live in host memory, rows stream to the
    device on demand.  Shape/dtype/nbytes mirror the array so facades
    (Recommender) can treat it like one."""

    def __init__(self, arr):
        self.arr = np.asarray(arr)

    shape = property(lambda self: self.arr.shape)
    dtype = property(lambda self: self.arr.dtype)
    nbytes = property(lambda self: self.arr.nbytes)

    def take(self, ids) -> np.ndarray:
        """Row-granular gather: only the requested rows leave the host
        store (O(len(ids) × D) bytes)."""
        return self.arr[np.asarray(ids)]

    def block(self, ids) -> np.ndarray:
        """Contiguous-ish block stream for the scorer's item blocks
        (same gather semantics as ``take``; kept separate for intent)."""
        return self.arr[np.asarray(ids)]


class TieredExecutor:
    """Drives one Plan's placements on the current backend."""

    def __init__(self, plan: Plan, prefixes: tuple[str, ...] = ("params",
                                                                "opt")):
        self.plan = plan
        self.topology = plan.topology
        self.prefixes = prefixes
        # host-store leaves currently demoted (by profile name)
        self._host_names: set[str] = set()

    # ------------------------------------------------------------ queries
    def _demoted_tier(self, name: str):
        pl = self.plan.placements.get(name)
        if pl is None or pl.tier == self.topology.fast.name:
            return None
        return self.topology.tier(pl.tier)

    @property
    def has_demotions(self) -> bool:
        return any(not self.plan.is_fast(n) for n in self.plan.placements)

    def _walk(self, state, leaf_fn):
        out = {}
        for prefix in self.prefixes:
            tree = state[prefix]
            flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
            leaves = [leaf_fn(prefix + jax.tree_util.keystr(kp), leaf)
                      for kp, leaf in flat]
            out[prefix] = jax.tree_util.tree_unflatten(treedef, leaves)
        for k in state:
            if k not in out:
                out[k] = state[k]
        return out

    # ------------------------------------------------------------ placement
    def place(self, state) -> tuple[object, int]:
        """Move every demoted state leaf onto its planned tier: the
        tier's memory kind when the backend has it, the host store
        otherwise.  Returns (state, n_offloaded)."""
        self._host_names.clear()
        moved = 0

        def place_leaf(name, leaf):
            nonlocal moved
            tier = self._demoted_tier(name)
            if tier is None:
                return leaf
            sh = memory_kind_sharding(tier.memory_kind)
            moved += 1
            if sh is not None:
                return jax.device_put(leaf, sh)
            self._host_names.add(name)
            return np.asarray(leaf)

        out = self._walk(state, place_leaf)
        return out, moved

    # ------------------------------------------------------------ streaming
    def fetch(self, state):
        """Demoted host-store leaves -> device (async H2D dispatch; the
        returned state is the only reference holder, so the previous
        step's copies free as soon as its state is dropped).  Identity
        when nothing is in the host store (memory-kind path, or no
        demotions)."""
        if not self._host_names:
            return state
        return self._walk(
            state, lambda name, leaf:
            jax.device_put(leaf) if name in self._host_names else leaf)

    def commit(self, state):
        """Write demoted leaves' updated bytes back to the host store
        (the slow tier owns them between steps).  Identity when nothing
        is host-resident."""
        if not self._host_names:
            return state
        return self._walk(
            state, lambda name, leaf:
            np.asarray(leaf) if name in self._host_names else leaf)

    # ------------------------------------------------------------ serving
    def host_table(self, name: str, table):
        """Wrap a demoted table in the row-granular serving facade when
        it belongs to the host store; device_put it when its tier has a
        real memory kind; pass through otherwise."""
        tier = self._demoted_tier(name)
        if tier is None:
            return table
        sh = memory_kind_sharding(tier.memory_kind)
        if sh is not None:
            return jax.device_put(table, sh)
        return HostResident(table)

    def describe(self) -> str:
        demoted = self.plan.demoted()
        mode = "memory-kind" if not self._host_names and demoted \
            else "host-store"
        return (f"TieredExecutor[{self.topology.name}] "
                f"demoted={len(demoted)} ({mode})")
