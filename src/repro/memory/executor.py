"""TieredExecutor — makes a placement Plan *functional* on every backend.

The pre-redesign ``apply_placements`` was advisory: on backends without
a ``pinned_host`` memory kind (CPU CI) a host demotion changed nothing
but a ``describe()`` string.  The executor gives every backend a real
slow tier:

  * **memory-kind path** (TPU): a demoted leaf is ``device_put`` onto
    its tier's JAX ``memory_kind`` — XLA then streams it over the host
    link on access, which is exactly the traffic the cost model prices.
    ``fetch``/``commit`` are no-ops here.
  * **host-store path** (everything else): a demoted leaf's bytes are
    committed to host memory as a numpy buffer — it genuinely leaves
    the device buffer pool.  Each step the executor *fetches* demoted
    leaves back onto the device (``jax.device_put`` dispatches the H2D
    copy asynchronously, overlapping the previous step's tail),
    computes, then *commits* the updated bytes back to the host store.
    The executor retains no reference to the device copies — once a
    step's state is committed the only live device buffers are the ones
    the next fetch creates, so demoted bytes genuinely leave the device
    pool between steps.  One fetch serves every microbatch of the step
    — the tables don't change inside one accumulated batch — so the
    stream runs at step granularity upward and microbatch granularity
    during serving gathers.

Both paths round-trip bytes exactly (device↔host copies of the same
float32 buffers), so a demoted run computes *bit-identical* results to
the all-fast run — on the ``uniform`` topology the cost model prices
that demotion at exactly 0.0 and CPU CI pins the bit-identity
(tests/test_memory.py).

With ``embed_store='int8'`` (``repro.api.CompressionCfg``) demoted
host-store embedding tables are held *quantized*: the store keeps
per-row symmetric int8 values plus one fp32 scale per row (~1/4 the
bytes — the capacity multiplier the planner prices via
``AccessProfile.store_bytes``), and every fetch dequantizes on the way
up.  The state's own leaf is the dequantized fp32 view, so checkpoints,
eval snapshots and the jitted step see ordinary float32 arrays whose
values have round-tripped through int8 (max abs error <= the row's
quantization scale — pinned by tests/test_compression.py).

``HostResident`` is the row-granular serving facade: a slow-tier
embedding table whose bytes live in the host store and whose rows are
gathered/streamed on demand (``take``/``block``), so a query batch
moves O(batch × D) bytes instead of the whole table —
``eval.topk.streaming_topk`` consumes it directly.
``QuantizedHostResident`` is its int8 arm: rows live as (q, scale) and
dequantize on gather.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.memory.policies import Plan
from repro.optim.compression import dequantize_rows_int8, quantize_rows_int8


def memory_kind_sharding(kind: str | None):
    """A single-device sharding onto the given memory kind, when the
    backend exposes one; None otherwise (then the host-store path takes
    over)."""
    if kind is None:
        return None
    try:
        dev = jax.devices()[0]
        kinds = {m.kind for m in dev.addressable_memories()}
        if kind not in kinds:
            return None
        return jax.sharding.SingleDeviceSharding(dev, memory_kind=kind)
    except Exception:  # noqa: BLE001 — backends without memories API
        return None


class HostResident:
    """A slow-tier table: bytes live in host memory, rows stream to the
    device on demand.  Shape/dtype/nbytes mirror the array so facades
    (Recommender) can treat it like one."""

    def __init__(self, arr):
        self.arr = np.asarray(arr)

    shape = property(lambda self: self.arr.shape)
    dtype = property(lambda self: self.arr.dtype)
    nbytes = property(lambda self: self.arr.nbytes)

    def take(self, ids) -> np.ndarray:
        """Row-granular gather: only the requested rows leave the host
        store (O(len(ids) × D) bytes)."""
        return self.arr[np.asarray(ids)]

    def block(self, ids) -> np.ndarray:
        """Contiguous-ish block stream for the scorer's item blocks
        (same gather semantics as ``take``; kept separate for intent)."""
        return self.arr[np.asarray(ids)]


class QuantizedHostResident(HostResident):
    """An int8-stored slow-tier table: the host store holds per-row
    symmetric int8 values plus one fp32 scale per row (~1/4 the dense
    bytes) and every gather dequantizes on the way to the device."""

    def __init__(self, arr):
        arr = np.asarray(arr, np.float32)
        self.q, self.scale = quantize_rows_int8(arr)
        self._shape = arr.shape

    shape = property(lambda self: self._shape)
    dtype = property(lambda self: np.dtype(np.float32))
    nbytes = property(lambda self: self.q.nbytes + self.scale.nbytes)

    def dense(self) -> np.ndarray:
        """The full dequantized fp32 view (checkpoint/debug path)."""
        return dequantize_rows_int8(self.q, self.scale)

    def take(self, ids) -> np.ndarray:
        ids = np.asarray(ids)
        return dequantize_rows_int8(self.q[ids], self.scale[ids])

    def block(self, ids) -> np.ndarray:
        return self.take(ids)


class TieredExecutor:
    """Drives one Plan's placements on the current backend."""

    def __init__(self, plan: Plan, prefixes: tuple[str, ...] = ("params",
                                                                "opt"),
                 embed_store: str = "fp32", cache_rows: int = 0):
        if embed_store not in ("fp32", "int8"):
            raise ValueError(f"unknown embed_store {embed_store!r}; "
                             "known: fp32, int8")
        if cache_rows < 0:
            raise ValueError(f"cache_rows must be >= 0, got {cache_rows}")
        self.plan = plan
        self.topology = plan.topology
        self.prefixes = prefixes
        self.embed_store = embed_store
        self.cache_rows = int(cache_rows)
        # hot-row caches wrapped around host-store serving tables
        self.caches: dict[str, object] = {}
        # host-store leaves currently demoted (by profile name)
        self._host_names: set[str] = set()
        # int8 buffers for quantized host-store tables: name -> (q, scale)
        self._int8: dict[str, tuple[np.ndarray, np.ndarray]] = {}

    # ------------------------------------------------------------ queries
    def _demoted_tier(self, name: str):
        pl = self.plan.placements.get(name)
        if pl is None or pl.tier == self.topology.fast.name:
            return None
        return self.topology.tier(pl.tier)

    @property
    def has_demotions(self) -> bool:
        return any(not self.plan.is_fast(n) for n in self.plan.placements)

    def _walk(self, state, leaf_fn):
        out = {}
        for prefix in self.prefixes:
            tree = state[prefix]
            flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
            leaves = [leaf_fn(prefix + jax.tree_util.keystr(kp), leaf)
                      for kp, leaf in flat]
            out[prefix] = jax.tree_util.tree_unflatten(treedef, leaves)
        for k in state:
            if k not in out:
                out[k] = state[k]
        return out

    def _wants_int8(self, name: str, leaf) -> bool:
        """Embedding tables demoted to the host store are the quantized
        arm: 2-D float32 ``params`` leaves (tables), when the executor
        runs with ``embed_store='int8'``."""
        return (self.embed_store == "int8"
                and name.startswith("params")
                and getattr(leaf, "ndim", 0) == 2
                and getattr(leaf, "dtype", None) == np.float32)

    def _store(self, name: str, leaf):
        """Commit one host-store leaf: quantized tables keep (int8,
        scale) buffers and the state carries the dequantized fp32 view;
        everything else stores dense fp32 bytes."""
        if self._wants_int8(name, leaf):
            q, scale = quantize_rows_int8(np.asarray(leaf))
            self._int8[name] = (q, scale)
            return dequantize_rows_int8(q, scale)
        self._int8.pop(name, None)
        return np.asarray(leaf)

    # ------------------------------------------------------------ placement
    def place(self, state) -> tuple[object, int]:
        """Move every demoted state leaf onto its planned tier: the
        tier's memory kind when the backend has it, the host store
        otherwise.  Returns (state, n_offloaded)."""
        self._host_names.clear()
        self._int8.clear()
        moved = 0

        def place_leaf(name, leaf):
            nonlocal moved
            tier = self._demoted_tier(name)
            if tier is None:
                return leaf
            sh = memory_kind_sharding(tier.memory_kind)
            moved += 1
            if sh is not None:
                return jax.device_put(leaf, sh)
            self._host_names.add(name)
            return self._store(name, leaf)

        out = self._walk(state, place_leaf)
        return out, moved

    # ------------------------------------------------------------ streaming
    def fetch(self, state):
        """Demoted host-store leaves -> device (async H2D dispatch; the
        returned state is the only reference holder, so the previous
        step's copies free as soon as its state is dropped).  Identity
        when nothing is in the host store (memory-kind path, or no
        demotions)."""
        if not self._host_names:
            return state
        return self._walk(
            state, lambda name, leaf:
            jax.device_put(leaf) if name in self._host_names else leaf)

    def commit(self, state):
        """Write demoted leaves' updated bytes back to the host store
        (the slow tier owns them between steps; quantized tables
        re-quantize here, so the carried state is always the int8
        round-trip).  Identity when nothing is host-resident."""
        if not self._host_names:
            return state
        return self._walk(
            state, lambda name, leaf:
            self._store(name, leaf) if name in self._host_names else leaf)

    # ------------------------------------------------------------ serving
    def host_table(self, name: str, table):
        """Wrap a demoted table in the row-granular serving facade when
        it belongs to the host store (the int8 dequant-on-gather facade
        under ``embed_store='int8'``); device_put it when its tier has a
        real memory kind; pass through otherwise.  With ``cache_rows``
        set, the host-store facade gains a device-resident
        ``HotRowCache`` front (LFU hot set; fills ride the same async
        H2D dispatch as ``fetch``)."""
        tier = self._demoted_tier(name)
        if tier is None:
            return table
        sh = memory_kind_sharding(tier.memory_kind)
        if sh is not None:
            return jax.device_put(table, sh)
        if self.embed_store == "int8" and getattr(table, "ndim", 0) == 2:
            facade = QuantizedHostResident(table)
        else:
            facade = HostResident(table)
        if self.cache_rows > 0:
            from repro.memory.cache import HotRowCache
            facade = HotRowCache(facade, self.cache_rows)
            self.caches[name] = facade
        return facade

    def prefetch_rows(self, name: str, ids) -> None:
        """Warm a serving table's hot-row cache with the given row ids
        (no-op for uncached tables)."""
        cache = self.caches.get(name)
        if cache is not None:
            cache.prefill(ids)

    def cache_stats(self) -> dict[str, dict]:
        """Per-table hit/miss/bytes-streamed counters for the serving
        caches this executor handed out."""
        return {name: c.stats.to_dict() for name, c in self.caches.items()}

    def store_nbytes(self, name: str) -> int | None:
        """Actual host-store bytes of a quantized table (q + scales), or
        None when the leaf isn't int8-resident — what the planner's
        ``store_bytes`` pricing should match."""
        if name not in self._int8:
            return None
        q, scale = self._int8[name]
        return q.nbytes + scale.nbytes

    def describe(self) -> str:
        demoted = self.plan.demoted()
        mode = "memory-kind" if not self._host_names and demoted \
            else "host-store"
        store = f" embed_store=int8({len(self._int8)})" \
            if self.embed_store == "int8" else ""
        cache = ""
        if self.caches:
            parts = []
            for name, c in self.caches.items():
                s = c.stats
                parts.append(f"{name}: rows={c.rows} "
                             f"hit_rate={s.hit_rate:.2f} "
                             f"streamed={s.bytes_streamed}B")
            cache = f" cache[{'; '.join(parts)}]"
        return (f"TieredExecutor[{self.topology.name}] "
                f"demoted={len(demoted)} ({mode}){store}{cache}")
