"""bert4rec [arXiv:1904.06690]: embed 64, 2 blocks, 2 heads, seq 200,
bidirectional cloze training.  Catalogue 2^20 items; training uses
sampled softmax (1024 negatives) — full-vocab logits at batch 65536 x 200
positions would be ~50 TB (noted in DESIGN.md).  Encoder-only: no decode
shapes exist; serve = next-item scoring."""
from repro.models.recsys_models import BERT4RecConfig

FAMILY = "recsys_seq"
OPTIMIZER = "adam"
N_NEGATIVES = 1024
N_MASKED = 20          # masked (cloze) positions per sequence

FULL = BERT4RecConfig(name="bert4rec", embed_dim=64, n_blocks=2, n_heads=2,
                      seq_len=200, n_items=1_048_576, d_ff=256)
SMOKE = BERT4RecConfig(name="bert4rec-smoke", embed_dim=16, n_blocks=2,
                       n_heads=2, seq_len=12, n_items=128, d_ff=32)

SHAPES = {
    "train_batch": dict(kind="seq_train", batch=65_536),
    "serve_p99": dict(kind="seq_serve", batch=512),
    "serve_bulk": dict(kind="seq_serve", batch=262_144),
    "retrieval_cand": dict(kind="seq_retrieval", batch=1,
                           n_candidates=1_048_576),
}
SKIP = {}
