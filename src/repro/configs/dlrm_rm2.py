"""dlrm-rm2 [arXiv:1906.00091]: 13 dense + 26 sparse (embed 64),
bot MLP 13-512-256-64, top MLP 512-512-256-1, dot interaction.
Tables: 26 x 10*2^20 rows x 64 — ~69 GB of embeddings, the capacity-tier
resident of the recsys family (row-sharded over the full mesh)."""
from repro.models.recsys_models import DLRMConfig

FAMILY = "recsys_dlrm"
OPTIMIZER = "adam"

FULL = DLRMConfig(name="dlrm-rm2", n_dense=13, n_sparse=26, embed_dim=64,
                  vocab=10 * 1_048_576, bot_mlp=(512, 256, 64),
                  top_mlp=(512, 512, 256, 1))
SMOKE = DLRMConfig(name="dlrm-rm2-smoke", n_dense=13, n_sparse=4,
                   embed_dim=8, vocab=64, bot_mlp=(16, 8),
                   top_mlp=(16, 1))

SHAPES = {
    "train_batch": dict(kind="recsys_train", batch=65_536),
    "serve_p99": dict(kind="recsys_serve", batch=512),
    "serve_bulk": dict(kind="recsys_serve", batch=262_144),
    "retrieval_cand": dict(kind="recsys_retrieval", batch=1,
                           n_candidates=1_048_576),
}
SKIP = {}
