"""gemma2-2b [arXiv:2408.00118]: 26L d2304 8H GQA(kv4, d_head 256) ff9216
vocab 256000 — alternating local(4096)/global attention, logit softcaps."""
from repro.models.transformer import TransformerConfig

FAMILY = "lm"
OPTIMIZER = "adam"

FULL = TransformerConfig(
    name="gemma2-2b", n_layers=26, d_model=2304, n_heads=8, n_kv_heads=4,
    d_head=256, d_ff=9216, vocab=256000, activation="gelu",
    attn_type="local_global", window=4096, attn_softcap=50.0,
    final_softcap=30.0)

SMOKE = TransformerConfig(
    name="gemma2-2b-smoke", n_layers=4, d_model=64, n_heads=4, n_kv_heads=2,
    d_head=16, d_ff=256, vocab=128, activation="gelu",
    attn_type="local_global", window=8, attn_softcap=50.0,
    final_softcap=30.0, dtype="float32")

SHAPES = {
    "train_4k": dict(kind="train", seq_len=4096, global_batch=256,
                     microbatches=4),
    "prefill_32k": dict(kind="prefill", seq_len=32768, global_batch=32),
    "decode_32k": dict(kind="decode", seq_len=32768, global_batch=128),
    # local layers attend over a 4096 window (compute-skipped banded
    # kernel) -> sub-quadratic share; global layers stream the cache.
    "long_500k": dict(kind="decode", seq_len=524288, global_batch=1),
}
SKIP = {}
