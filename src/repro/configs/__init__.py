"""Architecture registry: one module per assigned arch (+ the paper's own
NGCF/LightGCN).  ``get(arch_id)`` returns the module; every module
exposes FULL, SMOKE, FAMILY, SHAPES (and family-specific extras)."""
from __future__ import annotations

import importlib

ARCH_IDS = [
    "nemotron_4_340b", "gemma2_2b", "granite_3_8b", "mixtral_8x7b",
    "kimi_k2_1t_a32b", "gcn_cora", "deepfm", "xdeepfm", "bert4rec",
    "dlrm_rm2", "ngcf", "lightgcn",
]

ASSIGNED = ARCH_IDS[:10]          # graded pool
PAPER_OWN = ARCH_IDS[10:]         # the paper's own models


def canon(arch_id: str) -> str:
    return arch_id.replace("-", "_")


def get(arch_id: str):
    name = canon(arch_id)
    if name not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch_id}; known: {ARCH_IDS}")
    return importlib.import_module(f"repro.configs.{name}")
