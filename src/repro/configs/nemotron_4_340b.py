"""nemotron-4-340b [arXiv:2402.16819]: 96L d18432 96H GQA(kv8) ff73728
vocab 256000 — squared-ReLU FFN, pure full attention."""
from repro.models.transformer import TransformerConfig

FAMILY = "lm"
OPTIMIZER = "adafactor"          # 340B: Adam state would not fit 16 GiB chips

FULL = TransformerConfig(
    name="nemotron-4-340b", n_layers=96, d_model=18432, n_heads=96,
    n_kv_heads=8, d_ff=73728, vocab=256000, activation="squared_relu",
    attn_type="full")

SMOKE = TransformerConfig(
    name="nemotron-4-340b-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=256, vocab=128, activation="squared_relu",
    attn_type="full", dtype="float32")

SHAPES = {
    "train_4k": dict(kind="train", seq_len=4096, global_batch=256,
                     microbatches=16),
    "prefill_32k": dict(kind="prefill", seq_len=32768, global_batch=32),
    "decode_32k": dict(kind="decode", seq_len=32768, global_batch=128),
}
SKIP = {"long_500k": "pure full attention — no sub-quadratic path "
                     "(DESIGN.md §5)"}
