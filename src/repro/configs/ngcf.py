"""NGCF (paper's own model, Wang et al. SIGIR'19) at m-x25 scale:
full-graph BPR training, 3 layers, embed 128, batch 150K (paper §7)."""
import dataclasses

FAMILY = "gnnrecsys"
OPTIMIZER = "adam"


@dataclasses.dataclass(frozen=True)
class NGCFConfig:
    name: str
    n_users: int
    n_items: int
    n_edges: int
    embed_dim: int
    n_layers: int
    bpr_batch: int


# m-x25 scale (paper Table 2), edges padded to mesh-divisible size
FULL = NGCFConfig(name="ngcf-3l-128e", n_users=349_184, n_items=53_248,
                  n_edges=250_085_376, embed_dim=128, n_layers=3,
                  bpr_batch=150_528)
SMOKE = NGCFConfig(name="ngcf-smoke", n_users=64, n_items=48, n_edges=512,
                   embed_dim=16, n_layers=2, bpr_batch=64)

SHAPES = {
    "fullgraph_train": dict(kind="gnnrecsys_train"),
}
SKIP = {}
