"""LightGCN (paper's own model, He et al. SIGIR'20) at m-x25 scale."""
from repro.configs.ngcf import NGCFConfig

FAMILY = "gnnrecsys"
OPTIMIZER = "adam"

FULL = NGCFConfig(name="lightgcn-3l-128e", n_users=349_184, n_items=53_248,
                  n_edges=250_085_376, embed_dim=128, n_layers=3,
                  bpr_batch=150_528)
SMOKE = NGCFConfig(name="lightgcn-smoke", n_users=64, n_items=48,
                   n_edges=512, embed_dim=16, n_layers=2, bpr_batch=64)

SHAPES = {
    "fullgraph_train": dict(kind="gnnrecsys_train"),
}
SKIP = {}
