"""xdeepfm [arXiv:1803.05170]: 39 sparse fields, embed 10, CIN 200-200-200,
MLP 400-400."""
from repro.models.recsys_models import XDeepFMConfig

FAMILY = "recsys"
OPTIMIZER = "adam"

FULL = XDeepFMConfig(name="xdeepfm", n_sparse=39, embed_dim=10,
                     vocab=1_048_576, cin_layers=(200, 200, 200),
                     mlp_dims=(400, 400))
SMOKE = XDeepFMConfig(name="xdeepfm-smoke", n_sparse=5, embed_dim=4,
                      vocab=64, cin_layers=(8, 8), mlp_dims=(16,))

SHAPES = {
    "train_batch": dict(kind="recsys_train", batch=65_536),
    "serve_p99": dict(kind="recsys_serve", batch=512),
    "serve_bulk": dict(kind="recsys_serve", batch=262_144),
    "retrieval_cand": dict(kind="recsys_retrieval", batch=1,
                           n_candidates=1_048_576),
}
SKIP = {}
