"""granite-3-8b [hf:ibm-granite]: 40L d4096 32H GQA(kv8) ff12800 — SwiGLU,
full attention.  Vocab 49155 padded to 49664 (multiple of 512) for mesh
divisibility; the pad rows are dead weights (noted in DESIGN.md)."""
from repro.models.transformer import TransformerConfig

FAMILY = "lm"
OPTIMIZER = "adam"
VOCAB_REAL = 49155

FULL = TransformerConfig(
    name="granite-3-8b", n_layers=40, d_model=4096, n_heads=32,
    n_kv_heads=8, d_ff=12800, vocab=49664, activation="swiglu",
    attn_type="full")

SMOKE = TransformerConfig(
    name="granite-3-8b-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=192, vocab=128, activation="swiglu",
    attn_type="full", dtype="float32")

SHAPES = {
    "train_4k": dict(kind="train", seq_len=4096, global_batch=256,
                     microbatches=4),
    "prefill_32k": dict(kind="prefill", seq_len=32768, global_batch=32),
    "decode_32k": dict(kind="decode", seq_len=32768, global_batch=128),
}
SKIP = {"long_500k": "pure full attention — no sub-quadratic path "
                     "(DESIGN.md §5)"}
