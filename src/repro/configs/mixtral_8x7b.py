"""mixtral-8x7b [arXiv:2401.04088]: 32L d4096 32H GQA(kv8) vocab 32000,
MoE 8 experts top-2 (d_ff 14336/expert), SWA window 4096.

8 experts < 16-way model axis, so expert weights use tensor-parallelism
*within* experts (d_ff sharded) instead of expert-parallelism — see
launch/shardings.py; kimi-k2 (384e) takes the EP path."""
from repro.models.transformer import TransformerConfig

FAMILY = "lm"
OPTIMIZER = "adam"

FULL = TransformerConfig(
    name="mixtral-8x7b", n_layers=32, d_model=4096, n_heads=32,
    n_kv_heads=8, d_ff=14336, vocab=32000, activation="swiglu",
    attn_type="swa", window=4096, n_experts=8, top_k=2, moe_d_ff=14336)

SMOKE = TransformerConfig(
    name="mixtral-8x7b-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab=128, activation="swiglu",
    attn_type="swa", window=8, n_experts=4, top_k=2, moe_d_ff=128,
    dtype="float32")

SHAPES = {
    "train_4k": dict(kind="train", seq_len=4096, global_batch=256,
                     microbatches=8),
    "prefill_32k": dict(kind="prefill", seq_len=32768, global_batch=32),
    "decode_32k": dict(kind="decode", seq_len=32768, global_batch=128),
    # SWA caps every layer's attention window -> O(S*W) decode reads
    "long_500k": dict(kind="decode", seq_len=524288, global_batch=1),
}
SKIP = {}
