"""kimi-k2-1t-a32b [arXiv:2501.kimi2, paper-table]: 61L d7168 64H GQA(kv8)
vocab 163840, MoE 384 experts top-8 (d_ff 2048/expert) + 1 shared expert.
~1T total / ~32B active params.  Adafactor (factored second moments) —
Adam state for 1T params cannot fit 16 GiB/chip x 512."""
from repro.models.transformer import TransformerConfig

FAMILY = "lm"
OPTIMIZER = "adafactor"

FULL = TransformerConfig(
    name="kimi-k2-1t-a32b", n_layers=61, d_model=7168, n_heads=64,
    n_kv_heads=8, d_ff=2048, vocab=163840, activation="swiglu",
    attn_type="full", n_experts=384, top_k=8, moe_d_ff=2048,
    shared_experts=1)

SMOKE = TransformerConfig(
    name="kimi-k2-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=64, vocab=128, activation="swiglu", attn_type="full",
    n_experts=8, top_k=2, moe_d_ff=64, shared_experts=1, dtype="float32")

SHAPES = {
    "train_4k": dict(kind="train", seq_len=4096, global_batch=256,
                     microbatches=8),
    "prefill_32k": dict(kind="prefill", seq_len=32768, global_batch=32),
    "decode_32k": dict(kind="decode", seq_len=32768, global_batch=128),
}
SKIP = {"long_500k": "full attention per the assigned config — no "
                     "sub-quadratic path (DESIGN.md §5)"}
