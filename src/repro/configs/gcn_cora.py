"""gcn-cora [arXiv:1609.02907]: 2 layers, d_hidden 16, sym-norm mean agg.

The model is fixed; each shape supplies its own graph (d_feat/classes):
  full_graph_sm : cora      (2708 n / 10556 e / 1433 f / 7 c) full-batch
  minibatch_lg  : reddit    (233k n / 114.6M e / 602 f / 41 c) fanout 15-10
  ogb_products  : products  (2.45M n / 61.9M e / 100 f / 47 c) full-batch
  molecule      : batched 30-node graphs (64 e, binary class), batch 128
"""
from repro.models.gcn import GCNConfig

FAMILY = "gnn"
OPTIMIZER = "adam"

FULL = GCNConfig(name="gcn-cora", n_layers=2, d_hidden=16, n_classes=7,
                 d_feat=1433)
SMOKE = GCNConfig(name="gcn-cora-smoke", n_layers=2, d_hidden=8, n_classes=3,
                  d_feat=32)

SHAPES = {
    "full_graph_sm": dict(kind="gnn_full", n_nodes=2708, n_edges=10556,
                          d_feat=1433, n_classes=7),
    "minibatch_lg": dict(kind="gnn_sampled", n_nodes=232_965,
                         n_edges=114_615_892, batch_nodes=1024,
                         fanouts=(15, 10), d_feat=602, n_classes=41),
    "ogb_products": dict(kind="gnn_full", n_nodes=2_449_029,
                         n_edges=61_859_140, d_feat=100, n_classes=47),
    "molecule": dict(kind="gnn_batched", n_nodes=30, n_edges=64, batch=128,
                     d_feat=64, n_classes=2),
}
SKIP = {}
