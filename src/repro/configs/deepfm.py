"""deepfm [arXiv:1703.04247]: 39 sparse fields, embed 10, MLP 400-400-400,
FM interaction.  Vocab 2^20 rows/field (criteo-hashed scale, mesh-divisible)."""
from repro.models.recsys_models import DeepFMConfig

FAMILY = "recsys"
OPTIMIZER = "adam"

FULL = DeepFMConfig(name="deepfm", n_sparse=39, embed_dim=10,
                    vocab=1_048_576, mlp_dims=(400, 400, 400))
SMOKE = DeepFMConfig(name="deepfm-smoke", n_sparse=5, embed_dim=4,
                     vocab=64, mlp_dims=(16, 16))

SHAPES = {
    "train_batch": dict(kind="recsys_train", batch=65_536),
    "serve_p99": dict(kind="recsys_serve", batch=512),
    "serve_bulk": dict(kind="recsys_serve", batch=262_144),
    "retrieval_cand": dict(kind="recsys_retrieval", batch=1,
                           n_candidates=1_048_576),
}
SKIP = {}
