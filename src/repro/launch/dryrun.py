import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape)
cell on the production meshes, record memory/cost/roofline terms.

MUST be the first importer of jax in the process (the XLA_FLAGS line
above precedes every other import, including repro.*).

Usage:
  python -m repro.launch.dryrun --arch gemma2-2b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod-only|--single-pod-only]
  python -m repro.launch.dryrun --all --out results/dryrun
"""
import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402

from repro import configs as config_registry             # noqa: E402
from repro.launch import cells as cell_builder           # noqa: E402
from repro.launch import roofline as rl                  # noqa: E402
from repro.launch.mesh import make_production_mesh       # noqa: E402

HBM_PER_CHIP = 16 * 2**30


def run_cell(arch: str, shape: str, multi_pod: bool, verbose: bool = True):
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    cell = cell_builder.build_cell(arch, shape, mesh)
    t0 = time.perf_counter()
    with mesh:
        jitted = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                         out_shardings=cell.out_shardings,
                         donate_argnums=cell.donate)
        lowered = jitted.lower(*cell.args)
        compiled = lowered.compile()
    compile_s = time.perf_counter() - t0
    mem = compiled.memory_analysis()
    analytic_cost = None
    if "ring_coll_bytes" in cell.meta:
        # ring variant: the ppermute/segment_sum sit inside fori_loop
        # bodies (counted once by HloCostAnalysis) -> analytic terms
        analytic_cost = dict(flops=cell.meta["model_flops"],
                             hbm_bytes=cell.meta["ring_hbm_bytes"],
                             coll_bytes=cell.meta["ring_coll_bytes"])
    elif "analytic_hbm" in cell.meta:
        # recsys trains: XLA 'bytes accessed' badly under-counts dense
        # optimizer table streaming; use the documented analytic model
        analytic_cost = dict(flops=cell.meta["model_flops"],
                             hbm_bytes=cell.meta["analytic_hbm"],
                             coll_bytes=cell.meta["analytic_coll"])
    elif cell.kind in ("train", "prefill", "decode"):
        # scan-based programs: HloCostAnalysis counts while bodies once;
        # use the analytic model (launch/analytic.py)
        from repro.launch.analytic import lm_cost
        cfg = config_registry.get(arch).FULL
        analytic_cost = lm_cost(cell.kind, cfg,
                                config_registry.get(arch).SHAPES[shape], mesh)
    roof = rl.analyze(compiled, n_chips,
                      model_flops=cell.meta.get("model_flops", 0.0),
                      analytic=analytic_cost)
    peak = (mem.temp_size_in_bytes + mem.argument_size_in_bytes
            + mem.output_size_in_bytes - getattr(mem, "alias_size_in_bytes", 0))
    rec = {
        "arch": arch, "shape": shape,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": n_chips,
        "compile_s": round(compile_s, 2),
        "arg_bytes_per_dev": mem.argument_size_in_bytes,
        "temp_bytes_per_dev": mem.temp_size_in_bytes,
        "out_bytes_per_dev": mem.output_size_in_bytes,
        "peak_bytes_per_dev": peak,
        "fits_hbm": bool(peak <= HBM_PER_CHIP),
        "roofline": roof.as_dict(),
        "analytic": analytic_cost,
        "raw_hlo": {
            "flops": float((compiled.cost_analysis()[0]
                            if isinstance(compiled.cost_analysis(), list)
                            else compiled.cost_analysis()).get("flops", 0)),
            "coll_bytes_hlo_text":
                rl.collective_bytes(compiled.as_text()).total_bytes,
        },
        "meta": cell.meta,
    }
    if verbose:
        print(compiled.memory_analysis())
        ca = compiled.cost_analysis()
        ca = ca[0] if isinstance(ca, list) else ca
        print({k: v for k, v in ca.items()
               if k in ("flops", "bytes accessed")})
        print(f"[{arch}/{shape}/{rec['mesh']}] peak/dev="
              f"{peak/2**30:.2f} GiB fits={rec['fits_hbm']} "
              f"bottleneck={roof.bottleneck} "
              f"terms(c/m/coll)={roof.compute_s:.4f}/{roof.memory_s:.4f}/"
              f"{roof.collective_s:.4f}s compile={compile_s:.1f}s")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str)
    ap.add_argument("--shape", type=str)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--multi-pod-only", action="store_true")
    ap.add_argument("--out", type=str, default="results/dryrun")
    ap.add_argument("--archs", type=str, default=None,
                    help="comma-separated subset for --all")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)

    if not args.all:
        assert args.arch and args.shape, "--arch/--shape or --all"
        rec = run_cell(args.arch, args.shape, args.multi_pod)
        _save(args.out, rec)
        return

    arch_list = (args.archs.split(",") if args.archs
                 else config_registry.ARCH_IDS)
    pods = []
    if not args.multi_pod_only:
        pods.append(False)
    if not args.single_pod_only:
        pods.append(True)
    failures = []
    for arch in arch_list:
        mod = config_registry.get(arch)
        for shape in mod.SHAPES:
            for multi in pods:
                tag = f"{config_registry.canon(arch)}__{shape}__" \
                      f"{'multi' if multi else 'single'}"
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path):
                    print(f"skip cached {tag}")
                    continue
                try:
                    rec = run_cell(arch, shape, multi)
                    _save(args.out, rec)
                except Exception as e:  # noqa: BLE001
                    traceback.print_exc()
                    failures.append((tag, str(e)))
        for shape, reason in mod.SKIP.items():
            print(f"SKIP {arch}/{shape}: {reason}")
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for tag, err in failures:
            print(f"  {tag}: {err[:200]}")
        raise SystemExit(1)
    print("\nall dry-run cells compiled OK")


def _save(out_dir, rec):
    tag = f"{config_registry.canon(rec['arch'])}__{rec['shape']}__" \
          f"{'multi' if rec['mesh'] == '2x16x16' else 'single'}"
    with open(os.path.join(out_dir, tag + ".json"), "w") as f:
        json.dump(rec, f, indent=1)


if __name__ == "__main__":
    main()
