"""Production mesh builders.  Functions (not module constants) so that
importing this module never touches jax device state."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    return jax.make_mesh(tuple(shape), tuple(axes))


def dp_axes(mesh) -> tuple[str, ...]:
    """Data-parallel axes: ('pod','data') on the multi-pod mesh."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def all_axes(mesh) -> tuple[str, ...]:
    return tuple(mesh.axis_names)


def dp_size(mesh) -> int:
    out = 1
    for a in dp_axes(mesh):
        out *= mesh.shape[a]
    return out
