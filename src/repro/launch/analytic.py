"""Analytic FLOPs / HBM-traffic / collective-traffic model for LM cells.

Why this exists: XLA's HloCostAnalysis counts a while-loop *body once*,
not multiplied by trip count (verified empirically: a lax.scan of 10
matmuls reports the FLOPs of 1 — see EXPERIMENTS.md §Dry-run).  Every LM
cell is scan-over-layers (+ scan-over-microbatches + fori-loop flash
attention), so raw cost_analysis() under-reports by ~L*mb and the HLO
text shows loop collectives once.  Non-LM cells (GNN / recsys / NGCF)
contain no loops — their HLO numbers are used directly.

Conventions:
  * FLOPs: 2*M*N*K per matmul (matches XLA).  Train = fwd(2NT) +
    bwd(4NT) + remat re-forward(2NT) = 8NT on scan layers; lm_head is
    outside the remat scope -> 6NT.
  * Attention: our flash kernel computes full causal tiles (no
    above-diagonal skip) but *does* skip outside banded windows:
    S_vis = min(S, window + 2*k_chunk) for local/SWA layers.
  * Collective link-bytes (ring algorithms, logical buffer Z over axis k):
    all-gather (k-1)*Z, reduce-scatter (k-1)*Z, all-reduce 2(k-1)*Z.
  * HBM traffic: explicit per-term list, documented inline.  This is a
    ±20% model — good enough to rank roofline terms.
"""
from __future__ import annotations

from repro.launch.mesh import dp_size


def _lm_dims(cfg):
    d, dh = cfg.d_model, cfg.head_dim
    h, kv = cfg.n_heads, cfg.n_kv_heads
    attn_p = d * h * dh + 2 * d * kv * dh + h * dh * d
    n_mats = 3 if cfg.activation == "swiglu" else 2
    if cfg.is_moe:
        ffn_act = (cfg.top_k + cfg.shared_experts) * n_mats * d * cfg.moe_d_ff
        ffn_stored = (cfg.n_experts + cfg.shared_experts) * n_mats * d * cfg.moe_d_ff \
            + d * cfg.n_experts
    else:
        ffn_act = ffn_stored = n_mats * d * cfg.d_ff
    return attn_p, ffn_act, ffn_stored


def _s_vis(cfg, s, k_chunk=1024):
    """Average visited kv positions per query across layers."""
    full = s
    banded = min(s, cfg.window + 2 * k_chunk)
    if cfg.attn_type == "swa":
        return banded
    if cfg.attn_type == "local_global":
        return (banded + full) / 2
    return full


def _attn_flops_per_layer(cfg, b, s, s_vis):
    # QK^T + PV, grouped GQA: 2 matmuls x 2*B*H*dh*S*S_vis
    return 2 * 2 * b * cfg.n_heads * cfg.head_dim * s * s_vis


def lm_train_cost(cfg, shape, mesh):
    b, s = shape["global_batch"], shape["seq_len"]
    mb = shape.get("microbatches", 1)
    t = b * s
    t_mb = t // mb
    dp = dp_size(mesh)
    tp = mesh.shape["model"]
    attn_p, ffn_act, ffn_stored = _lm_dims(cfg)
    l = cfg.n_layers
    d, v = cfg.d_model, cfg.vocab
    p_layer_act = attn_p + ffn_act
    p_stored = l * (attn_p + ffn_stored) + 2 * v * d
    s_vis = _s_vis(cfg, s)

    # ---- FLOPs
    matmul = 8 * l * p_layer_act * t + 6 * (d * v) * t
    attn = 4 * l * _attn_flops_per_layer(cfg, 1, s, s_vis) * b
    flops = matmul + attn

    # ---- HBM bytes (global, per step)
    pb = 2  # bf16 params
    param_traffic = 3 * mb * (l * (attn_p + ffn_stored) * pb)  # fwd+bwd+remat weight reads
    grad_traffic = 4 * p_stored * 4          # f32 grads: acc read+write, opt read
    opt_traffic = 2 * p_stored * 4           # optimizer state r/w (adam ~4x this; adafactor ~0)
    act_bytes_layer = t_mb * (6 * d + (cfg.n_heads + 2 * cfg.n_kv_heads)
                              * cfg.head_dim + 2 * (ffn_act // d)) * 2
    act_traffic = 3 * mb * l * act_bytes_layer     # fwd + remat + bwd
    logits_traffic = 3 * t * v * 4                 # fwd write, bwd read/write (f32)
    hbm = param_traffic + grad_traffic + opt_traffic + act_traffic + logits_traffic

    # ---- collective link-bytes (global, per step)
    fsdp_ag = 2 * mb * (dp - 1) * (l * (attn_p + ffn_stored) * pb)
    grad_ar = 2 * (dp - 1) * p_stored * 4
    act_z = t_mb * d * 2
    tp_ar = 3 * mb * l * 2 * 2 * (tp - 1) * act_z // max(tp, 1)  # 2 AR/layer, fwd+bwd+remat
    moe_a2a = 0
    if cfg.is_moe:
        # dispatch+combine x (fwd+bwd+remat): ~top_k*T*D crossing EP axis
        moe_a2a = 3 * 2 * l * cfg.top_k * t * d * 2
    coll = fsdp_ag + grad_ar + tp_ar + moe_a2a
    return dict(flops=float(flops), hbm_bytes=float(hbm),
                coll_bytes=float(coll))


def lm_prefill_cost(cfg, shape, mesh):
    b, s = shape["global_batch"], shape["seq_len"]
    t = b * s
    dp = dp_size(mesh)
    tp = mesh.shape["model"]
    attn_p, ffn_act, ffn_stored = _lm_dims(cfg)
    l, d, v = cfg.n_layers, cfg.d_model, cfg.vocab
    s_vis = _s_vis(cfg, s)

    flops = 2 * (l * (attn_p + ffn_act)) * t + 2 * (d * v) * b \
        + l * _attn_flops_per_layer(cfg, 1, s, s_vis) * b
    param_traffic = l * (attn_p + ffn_stored) * 2
    act_traffic = l * t * (6 * d + (cfg.n_heads + 2 * cfg.n_kv_heads)
                           * cfg.head_dim + 2 * (ffn_act // d)) * 2
    cache_traffic = l * b * 2 * cfg.n_kv_heads * s * cfg.head_dim * 2
    hbm = param_traffic + act_traffic + cache_traffic

    fsdp_ag = (dp - 1) * param_traffic
    act_z = t * d * 2
    tp_ar = l * 2 * 2 * (tp - 1) * act_z // max(tp, 1)
    moe_a2a = 2 * l * cfg.top_k * t * d * 2 if cfg.is_moe else 0
    coll = fsdp_ag + tp_ar + moe_a2a
    return dict(flops=float(flops), hbm_bytes=float(hbm),
                coll_bytes=float(coll))


def lm_decode_cost(cfg, shape, mesh):
    b, s = shape["global_batch"], shape["seq_len"]
    dp = dp_size(mesh)
    tp = mesh.shape["model"]
    attn_p, ffn_act, ffn_stored = _lm_dims(cfg)
    l, d, v = cfg.n_layers, cfg.d_model, cfg.vocab
    s_vis = _s_vis(cfg, s, k_chunk=0) if cfg.attn_type != "full" else s

    flops = 2 * (l * (attn_p + ffn_act)) * b + 2 * (d * v) * b \
        + l * 2 * 2 * b * cfg.n_heads * cfg.head_dim * s_vis
    param_traffic = l * (attn_p + ffn_stored) * 2 + d * v * 2
    cache_read = l * b * 2 * cfg.n_kv_heads * s_vis * cfg.head_dim * 2
    cache_write = l * b * 2 * cfg.n_kv_heads * cfg.head_dim * 2
    hbm = param_traffic + cache_read + cache_write + b * v * 4

    fsdp_ag = (dp - 1) * param_traffic  # weight gather dominates decode comms
    act_z = b * d * 2
    tp_ar = l * 2 * 2 * (tp - 1) * act_z // max(tp, 1)
    moe_a2a = 2 * l * cfg.top_k * b * d * 2 if cfg.is_moe else 0
    coll = fsdp_ag + tp_ar + moe_a2a
    return dict(flops=float(flops), hbm_bytes=float(hbm),
                coll_bytes=float(coll))


def lm_cost(kind: str, cfg, shape, mesh):
    return {"train": lm_train_cost, "prefill": lm_prefill_cost,
            "decode": lm_decode_cost}[kind](cfg, shape, mesh)
