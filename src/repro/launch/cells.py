"""Cell builder: (architecture x input-shape x mesh) -> lowering-ready
step function + abstract input specs + shardings.

A "cell" is one entry of the dry-run/roofline matrix.  Everything here is
allocation-free: parameters and optimizer state are jax.eval_shape'd
ShapeDtypeStructs; the dry-run lowers with them directly.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro import configs as config_registry
from repro.launch import shardings as shd
from repro.launch.mesh import all_axes, dp_axes, dp_size
from repro.optim import adafactor, adam

F32, BF16, I32 = jnp.float32, jnp.bfloat16, jnp.int32


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _round_up(n: int, mult: int) -> int:
    return -(-n // mult) * mult


def _make_opt(name: str):
    return adam(1e-4) if name == "adam" else adafactor(1e-2)


@dataclasses.dataclass
class Cell:
    arch: str
    shape: str
    kind: str
    fn: Callable                      # positional-args step function
    args: tuple                       # ShapeDtypeStructs
    in_shardings: tuple
    out_shardings: Any
    donate: tuple[int, ...]
    meta: dict


def list_cells(arch_id: str) -> list[str]:
    mod = config_registry.get(arch_id)
    return list(mod.SHAPES.keys())


def skipped_cells(arch_id: str) -> dict[str, str]:
    return dict(config_registry.get(arch_id).SKIP)


def build_cell(arch_id: str, shape_name: str, mesh) -> Cell:
    arch_id = config_registry.canon(arch_id)
    mod = config_registry.get(arch_id)
    if shape_name in mod.SKIP:
        raise ValueError(f"{arch_id}/{shape_name} skipped: {mod.SKIP[shape_name]}")
    shape = mod.SHAPES[shape_name]
    kind = shape["kind"]
    builder = _BUILDERS[kind]
    return builder(arch_id, mod, shape_name, shape, mesh)


# =========================================================== LM family

def _lm_param_struct(cfg):
    from repro.models.transformer import init_params
    return jax.eval_shape(lambda k: init_params(cfg, k),
                          jax.random.PRNGKey(0))


def _lm_train(arch, mod, shape_name, shape, mesh):
    from repro.models import transformer as tfm
    cfg = mod.FULL
    opt = _make_opt(mod.OPTIMIZER)
    b, s = shape["global_batch"], shape["seq_len"]
    dp = dp_axes(mesh)
    # each microbatch must still shard its batch dim over dp
    mb = min(shape.get("microbatches", 1), b // dp_size(mesh))
    p_struct = _lm_param_struct(cfg)
    o_struct = jax.eval_shape(opt.init, p_struct)
    p_spec = shd.lm_param_specs(cfg, mesh)
    o_spec = shd.opt_state_specs(mod.OPTIMIZER, p_spec, p_struct)

    mb_c = lambda t: jax.lax.with_sharding_constraint(
        t, NamedSharding(mesh, P(dp, None)))
    lg_c = lambda t: jax.lax.with_sharding_constraint(
        t, NamedSharding(mesh, P(dp, None, "model")))
    # sequence-parallel residual stream (Megatron-SP): the remat carry
    # stack [L, B, S, D] shards S over 'model' as well — without this the
    # per-device stack is L*S_mb*D bytes (13.5 GiB bf16 on the 340B) and
    # XLA additionally hoists an f32 copy of it out of the backward loop
    act_c = lambda t: jax.lax.with_sharding_constraint(
        t, NamedSharding(mesh, P(dp, "model", None)))
    final_c = lambda t: jax.lax.with_sharding_constraint(
        t, NamedSharding(mesh, P(dp, None, None)))

    # bf16 grad accumulation for adafactor giants (340B/1T): the f32
    # accumulator alone is 4 TB on kimi-k2 (16 GiB/chip on a pod)
    gdt = jnp.bfloat16 if mod.OPTIMIZER == "adafactor" else jnp.float32
    grad_c = lambda g: jax.tree.map(
        lambda t, s: jax.lax.with_sharding_constraint(t, NamedSharding(mesh, s)),
        g, p_spec, is_leaf=lambda x: isinstance(x, jnp.ndarray))

    def step(params, opt_state, tokens, labels):
        from repro.dist.hints import sharding_hints
        with sharding_hints(dp=dp, tp="model"):
            return tfm.train_step(cfg, opt, params, opt_state, tokens, labels,
                                  n_microbatches=mb, mb_constraint=mb_c,
                                  logits_constraint=lg_c, act_constraint=act_c,
                                  grad_dtype=gdt, grad_constraint=grad_c,
                                  final_constraint=final_c)

    args = (p_struct, o_struct, _sds((b, s), I32), _sds((b, s), I32))
    in_sh = (shd.named(mesh, p_spec), shd.named(mesh, o_spec),
             NamedSharding(mesh, P(dp, None)), NamedSharding(mesh, P(dp, None)))
    out_sh = (shd.named(mesh, p_spec), shd.named(mesh, o_spec),
              NamedSharding(mesh, P()))
    n_par = cfg.param_count()
    n_act = cfg.active_param_count()
    return Cell(arch, shape_name, "train", step, args, in_sh, out_sh,
                donate=(0, 1),
                meta=dict(model_flops=6 * n_act * b * s, params=n_par,
                          active_params=n_act, tokens=b * s))


def _lm_prefill(arch, mod, shape_name, shape, mesh):
    from repro.models import transformer as tfm
    cfg = mod.FULL
    b, s = shape["global_batch"], shape["seq_len"]
    dp = dp_axes(mesh)
    p_struct = _lm_param_struct(cfg)
    p_spec = shd.lm_param_specs(cfg, mesh)
    cache_spec = shd.lm_cache_specs(cfg, mesh, b)

    def step(params, tokens):
        from repro.dist.hints import sharding_hints
        with sharding_hints(dp=dp, tp="model"):
            return tfm.prefill(cfg, params, tokens)

    args = (p_struct, _sds((b, s), I32))
    in_sh = (shd.named(mesh, p_spec), NamedSharding(mesh, P(dp, None)))
    out_sh = (NamedSharding(mesh, P(dp, "model")),
              shd.named(mesh, cache_spec))
    n_act = cfg.active_param_count()
    return Cell(arch, shape_name, "prefill", step, args, in_sh, out_sh,
                donate=(),
                meta=dict(model_flops=2 * n_act * b * s, params=cfg.param_count(),
                          active_params=n_act, tokens=b * s))


def _lm_decode(arch, mod, shape_name, shape, mesh):
    from repro.models import transformer as tfm
    cfg = mod.FULL
    b, s = shape["global_batch"], shape["seq_len"]
    dp = dp_axes(mesh)
    p_struct = _lm_param_struct(cfg)
    p_spec = shd.lm_param_specs(cfg, mesh)
    cache_spec = shd.lm_cache_specs(cfg, mesh, b)
    dt = jnp.dtype(cfg.dtype)
    cache_struct = {
        "k": _sds((cfg.n_layers, b, cfg.n_kv_heads, s, cfg.head_dim), dt),
        "v": _sds((cfg.n_layers, b, cfg.n_kv_heads, s, cfg.head_dim), dt),
    }
    tok_spec = P(dp, None) if b % dp_size(mesh) == 0 else P(None, None)
    logit_spec = P(dp, "model") if b % dp_size(mesh) == 0 else P(None, "model")

    def step(params, token, cache, pos):
        from repro.dist.hints import sharding_hints
        with sharding_hints(dp=dp, tp="model"):
            return tfm.decode_step(cfg, params, token, cache, pos)

    args = (p_struct, _sds((b, 1), I32), cache_struct, _sds((), I32))
    in_sh = (shd.named(mesh, p_spec), NamedSharding(mesh, tok_spec),
             shd.named(mesh, cache_spec), NamedSharding(mesh, P()))
    out_sh = (NamedSharding(mesh, logit_spec), shd.named(mesh, cache_spec))
    n_act = cfg.active_param_count()
    return Cell(arch, shape_name, "decode", step, args, in_sh, out_sh,
                donate=(2,),
                meta=dict(model_flops=2 * n_act * b, params=cfg.param_count(),
                          active_params=n_act, tokens=b, kv_len=s))


# =========================================================== GNN family

def _gcn_cfg_for_shape(mod, shape):
    from repro.models.gcn import GCNConfig
    base = mod.FULL
    return GCNConfig(name=base.name, n_layers=base.n_layers,
                     d_hidden=base.d_hidden, n_classes=shape["n_classes"],
                     d_feat=shape["d_feat"])


def _gcn_param_struct(cfg):
    from repro.models.gcn import init_params
    return jax.eval_shape(lambda k: init_params(cfg, k),
                          jax.random.PRNGKey(0))


def _gnn_full(arch, mod, shape_name, shape, mesh):
    import os
    # REPRO_GNN_IMPL=ring selects the ring-SpMM variant (the §Perf
    # hillclimb); REPRO_RING_STEPS=R bounds the ring radius (locality-
    # partitioned graph, paper §6 blocked placement + §8.1 reordering)
    if os.environ.get("REPRO_GNN_IMPL") == "ring":
        return _gnn_full_ring(arch, mod, shape_name, shape, mesh)
    from repro.core.graph import Graph
    from repro.models import gcn
    cfg = _gcn_cfg_for_shape(mod, shape)
    opt = _make_opt(mod.OPTIMIZER)
    nd = mesh.devices.size
    n_pad = _round_up(shape["n_nodes"], nd)
    e_pad = _round_up(shape["n_edges"], nd)
    ax = all_axes(mesh)

    p_struct = _gcn_param_struct(cfg)
    o_struct = jax.eval_shape(opt.init, p_struct)
    p_spec = shd.gcn_param_specs(cfg, mesh)
    o_spec = shd.opt_state_specs(mod.OPTIMIZER, p_spec, p_struct)

    def step(params, opt_state, x, src, dst, emask, labels, lmask):
        g = Graph(src, dst, emask, n_pad, shape["n_edges"])
        loss, grads = jax.value_and_grad(
            lambda p: gcn.loss_fn(cfg, p, g, x, labels, lmask))(params)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, loss

    args = (p_struct, o_struct,
            _sds((n_pad, shape["d_feat"]), F32),
            _sds((e_pad,), I32), _sds((e_pad,), I32), _sds((e_pad,), jnp.bool_),
            _sds((n_pad,), I32), _sds((n_pad,), F32))
    in_sh = (shd.named(mesh, p_spec), shd.named(mesh, o_spec),
             NamedSharding(mesh, P(ax, None)),
             NamedSharding(mesh, P(ax)), NamedSharding(mesh, P(ax)),
             NamedSharding(mesh, P(ax)),
             NamedSharding(mesh, P(ax)), NamedSharding(mesh, P(ax)))
    out_sh = (shd.named(mesh, p_spec), shd.named(mesh, o_spec),
              NamedSharding(mesh, P()))
    dims = [shape["d_feat"]] + [cfg.d_hidden] * (cfg.n_layers - 1) + [cfg.n_classes]
    spmm_flops = sum(2 * shape["n_edges"] * d for d in dims[:-1])
    mm_flops = sum(2 * shape["n_nodes"] * dims[i] * dims[i + 1]
                   for i in range(len(dims) - 1))
    return Cell(arch, shape_name, "gnn_full", step, args, in_sh, out_sh,
                donate=(0, 1),
                meta=dict(model_flops=3 * (spmm_flops + mm_flops),
                          n_nodes=shape["n_nodes"], n_edges=shape["n_edges"]))


def _gnn_full_ring(arch, mod, shape_name, shape, mesh):
    """Ring-SpMM variant of full-graph GCN training: node-sharded
    features rotate around the flattened device ring (overlapped
    ppermute) instead of GSPMD gather/all-reduce.  Edge buckets are
    relative-banded: REPRO_RING_STEPS (default: full ring) owners per
    device, from locality-aware partitioning."""
    import os
    from repro.dist.ring_spmm import make_ring_spmm
    from repro.models import gcn
    cfg = _gcn_cfg_for_shape(mod, shape)
    opt = _make_opt(mod.OPTIMIZER)
    nd = mesh.devices.size
    ax = all_axes(mesh)
    n_pad = _round_up(shape["n_nodes"], nd)
    n_local = n_pad // nd
    r = int(os.environ.get("REPRO_RING_STEPS", nd))
    e_max = _round_up(int(shape["n_edges"] / (nd * r) * 1.3) + 8, 8)

    p_struct = _gcn_param_struct(cfg)
    o_struct = jax.eval_shape(opt.init, p_struct)
    p_spec = shd.gcn_param_specs(cfg, mesh)
    o_spec = shd.opt_state_specs(mod.OPTIMIZER, p_spec, p_struct)
    ring = make_ring_spmm(mesh, ax, n_local, with_coeff=True, n_steps=r,
                          relative_buckets=True)

    def step(params, opt_state, x, src_l, dst_l, emask, coeff, labels, lmask):
        def loss_fn(p):
            h = x
            for li, w in enumerate(p["layers"]):
                h = ring(h, src_l, dst_l, emask, coeff)
                h = h @ w["w"] + w["b"]
                if li + 1 < cfg.n_layers:
                    h = jax.nn.relu(h)
            logp = jax.nn.log_softmax(h, -1)
            ll = jnp.take_along_axis(logp, labels[:, None], -1)[:, 0]
            return -jnp.sum(ll * lmask) / jnp.maximum(lmask.sum(), 1.0)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, loss

    bspec = NamedSharding(mesh, P(ax, None, None))
    args = (p_struct, o_struct,
            _sds((n_pad, shape["d_feat"]), F32),
            _sds((nd, r, e_max), I32), _sds((nd, r, e_max), I32),
            _sds((nd, r, e_max), jnp.bool_), _sds((nd, r, e_max), F32),
            _sds((n_pad,), I32), _sds((n_pad,), F32))
    in_sh = (shd.named(mesh, p_spec), shd.named(mesh, o_spec),
             NamedSharding(mesh, P(ax, None)),
             bspec, bspec, bspec, bspec,
             NamedSharding(mesh, P(ax)), NamedSharding(mesh, P(ax)))
    out_sh = (shd.named(mesh, p_spec), shd.named(mesh, o_spec),
              NamedSharding(mesh, P()))
    dims = [shape["d_feat"]] + [cfg.d_hidden] * (cfg.n_layers - 1) + [cfg.n_classes]
    spmm_flops = sum(2 * shape["n_edges"] * d for d in dims[:-1])
    mm_flops = sum(2 * shape["n_nodes"] * dims[i] * dims[i + 1]
                   for i in range(len(dims) - 1))
    # ring collective bytes (analytic; ppermute sits in a fori_loop so the
    # HLO text counts it once): fwd+bwd per layer, R steps moving the
    # whole feature matrix once per full rotation fraction
    ring_bytes = 2 * r / nd * sum(n_pad * d * 4 for d in dims[:-1])
    hbm_bytes = 3 * sum(2 * shape["n_edges"] * d * 4 + 3 * n_pad * d * 4
                        for d in dims[:-1])
    return Cell(arch, shape_name, "gnn_full", step, args, in_sh, out_sh,
                donate=(0, 1),
                meta=dict(model_flops=3 * (spmm_flops + mm_flops),
                          n_nodes=shape["n_nodes"], n_edges=shape["n_edges"],
                          ring_steps=r, ring_coll_bytes=ring_bytes,
                          ring_hbm_bytes=hbm_bytes))


def _gnn_sampled(arch, mod, shape_name, shape, mesh):
    from repro.models import gcn
    cfg = _gcn_cfg_for_shape(mod, shape)
    opt = _make_opt(mod.OPTIMIZER)
    nd = mesh.devices.size
    ax = all_axes(mesh)
    seeds = shape["batch_nodes"]
    f1, f2 = shape["fanouts"]
    # static block sizes (upper bounds, mesh-divisible)
    n1_dst = seeds
    e1 = _round_up(seeds * f1, nd)
    n1_src = _round_up(seeds * (f1 + 1), nd)
    e2 = _round_up(n1_src * f2, nd)
    n2_src = _round_up(n1_src * (f2 + 1), nd)

    p_struct = _gcn_param_struct(cfg)
    o_struct = jax.eval_shape(opt.init, p_struct)
    p_spec = shd.gcn_param_specs(cfg, mesh)
    o_spec = shd.opt_state_specs(mod.OPTIMIZER, p_spec, p_struct)

    def step(params, opt_state, x, e2s, e2d, m2, e1s, e1d, m1, labels):
        blocks = [
            dict(edge_src=e2s, edge_dst=e2d, edge_mask=m2, n_dst=n1_src),
            dict(edge_src=e1s, edge_dst=e1d, edge_mask=m1, n_dst=n1_dst),
        ]

        def loss_fn(p):
            logits = gcn.forward_blocks(cfg, p, blocks, x)
            logp = jax.nn.log_softmax(logits, -1)
            ll = jnp.take_along_axis(logp, labels[:, None], -1)[:, 0]
            return -jnp.mean(ll)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, loss

    args = (p_struct, o_struct,
            _sds((n2_src, shape["d_feat"]), F32),
            _sds((e2,), I32), _sds((e2,), I32), _sds((e2,), jnp.bool_),
            _sds((e1,), I32), _sds((e1,), I32), _sds((e1,), jnp.bool_),
            _sds((seeds,), I32))
    in_sh = (shd.named(mesh, p_spec), shd.named(mesh, o_spec),
             NamedSharding(mesh, P(ax, None)),
             NamedSharding(mesh, P(ax)), NamedSharding(mesh, P(ax)),
             NamedSharding(mesh, P(ax)),
             NamedSharding(mesh, P(ax)), NamedSharding(mesh, P(ax)),
             NamedSharding(mesh, P(ax)),
             NamedSharding(mesh, P(ax)))
    out_sh = (shd.named(mesh, p_spec), shd.named(mesh, o_spec),
              NamedSharding(mesh, P()))
    flops = 2 * e2 * shape["d_feat"] + 2 * n1_src * shape["d_feat"] * cfg.d_hidden \
        + 2 * e1 * cfg.d_hidden + 2 * seeds * cfg.d_hidden * shape["n_classes"]
    return Cell(arch, shape_name, "gnn_sampled", step, args, in_sh, out_sh,
                donate=(0, 1), meta=dict(model_flops=3 * flops,
                                         sampled_src=n2_src, sampled_edges=e2))


def _gnn_batched(arch, mod, shape_name, shape, mesh):
    from repro.models import gcn
    cfg = _gcn_cfg_for_shape(mod, shape)
    opt = _make_opt(mod.OPTIMIZER)
    nd = mesh.devices.size
    ax = all_axes(mesh)
    bsz = shape["batch"]
    n_flat = _round_up(bsz * shape["n_nodes"], nd)
    e_flat = _round_up(bsz * shape["n_edges"], nd)

    p_struct = _gcn_param_struct(cfg)
    o_struct = jax.eval_shape(opt.init, p_struct)
    p_spec = shd.gcn_param_specs(cfg, mesh)
    o_spec = shd.opt_state_specs(mod.OPTIMIZER, p_spec, p_struct)

    def step(params, opt_state, x, src, dst, emask, gids, labels):
        def loss_fn(p):
            logits = gcn.forward_batched(cfg, p, src, dst, emask, x, gids, bsz)
            logp = jax.nn.log_softmax(logits, -1)
            ll = jnp.take_along_axis(logp, labels[:, None], -1)[:, 0]
            return -jnp.mean(ll)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, loss

    args = (p_struct, o_struct,
            _sds((n_flat, shape["d_feat"]), F32),
            _sds((e_flat,), I32), _sds((e_flat,), I32), _sds((e_flat,), jnp.bool_),
            _sds((n_flat,), I32), _sds((bsz,), I32))
    in_sh = (shd.named(mesh, p_spec), shd.named(mesh, o_spec),
             NamedSharding(mesh, P(ax, None)),
             NamedSharding(mesh, P(ax)), NamedSharding(mesh, P(ax)),
             NamedSharding(mesh, P(ax)),
             NamedSharding(mesh, P(ax)), NamedSharding(mesh, P()))
    out_sh = (shd.named(mesh, p_spec), shd.named(mesh, o_spec),
              NamedSharding(mesh, P()))
    return Cell(arch, shape_name, "gnn_batched", step, args, in_sh, out_sh,
                donate=(0, 1),
                meta=dict(model_flops=3 * 2 * e_flat * shape["d_feat"]))


# =========================================================== recsys family

def _recsys_init_struct(arch, cfg):
    from repro.models import recsys_models as rm
    init = {"deepfm": rm.deepfm_init, "xdeepfm": rm.xdeepfm_init,
            "dlrm_rm2": rm.dlrm_init, "bert4rec": rm.bert4rec_init}[arch]
    return jax.eval_shape(lambda k: init(cfg, k), jax.random.PRNGKey(0))


def _recsys_forward(arch, cfg):
    from repro.models import recsys_models as rm
    return {"deepfm": partial(rm.deepfm_forward, cfg),
            "xdeepfm": partial(rm.xdeepfm_forward, cfg),
            "dlrm_rm2": partial(rm.dlrm_forward, cfg)}[arch]


def _recsys_embedding_flops(arch, cfg, batch):
    # lookups dominate bytes, interaction+MLP dominates FLOPs
    if arch == "dlrm_rm2":
        f = cfg.n_sparse + 1
        mlp = sum(a * b for a, b in zip((cfg.n_dense,) + cfg.bot_mlp[:-1],
                                        cfg.bot_mlp))
        top_in = cfg.bot_mlp[-1] + f * (f - 1) // 2
        mlp += sum(a * b for a, b in zip((top_in,) + cfg.top_mlp[:-1],
                                         cfg.top_mlp))
        inter = f * f * cfg.embed_dim
        return 2 * batch * (mlp + inter)
    d_in = cfg.n_sparse * cfg.embed_dim
    mlp = sum(a * b for a, b in zip((d_in,) + cfg.mlp_dims[:-1],
                                    cfg.mlp_dims)) + cfg.mlp_dims[-1]
    extra = 0
    if hasattr(cfg, "cin_layers"):
        h_prev = cfg.n_sparse
        for h in cfg.cin_layers:
            extra += h * h_prev * cfg.n_sparse * cfg.embed_dim
            h_prev = h
    else:
        extra = cfg.n_sparse * cfg.embed_dim  # FM
    return 2 * batch * (mlp + extra)


def _recsys_io(arch, cfg, batch, mesh, with_labels):
    """(arg structs, shardings) for dense/ids(/labels) inputs."""
    dpall = all_axes(mesh)
    nd = mesh.devices.size
    bspec = P(dpall) if batch % nd == 0 else P()
    bspec2 = P(dpall, None) if batch % nd == 0 else P(None, None)
    args, shs = [], []
    if arch == "dlrm_rm2":
        args.append(_sds((batch, cfg.n_dense), F32))
        shs.append(NamedSharding(mesh, bspec2))
    args.append(_sds((batch, cfg.n_sparse), I32))
    shs.append(NamedSharding(mesh, bspec2))
    if with_labels:
        args.append(_sds((batch,), F32))
        shs.append(NamedSharding(mesh, bspec))
    return args, shs, bspec


def _recsys_train_rowwise(arch, mod, shape_name, shape, mesh):
    """dlrm-rm2 variant: lazy row-wise AdaGrad on the embedding tables
    (REPRO_RECSYS_OPT=rowwise).  Dense towers keep Adam; tables touch only
    the B*F gathered rows per step instead of the full [F, V, D] tensor
    (+m,v) that dense Adam streams."""
    from repro.models.recsys_models import (bce_loss, dlrm_forward_from_emb,
                                            lookup_fields,
                                            rowwise_adagrad_update)
    cfg = mod.FULL
    opt = _make_opt(mod.OPTIMIZER)
    batch = shape["batch"]
    dpall = all_axes(mesh)
    p_struct = _recsys_init_struct(arch, cfg)
    p_spec = shd.recsys_param_specs(arch, p_struct, mesh)
    dense_keys = ("bot", "top")
    dense_struct = {k: p_struct[k] for k in dense_keys}
    o_struct = {
        "acc": _sds((cfg.n_sparse, cfg.vocab), F32),
        "mlp": jax.eval_shape(opt.init, dense_struct),
    }
    o_spec = {
        "acc": P(None, dpall),
        "mlp": shd.opt_state_specs(mod.OPTIMIZER,
                                   {k: p_spec[k] for k in dense_keys},
                                   dense_struct),
    }
    data_args, data_sh, _ = _recsys_io(arch, cfg, batch, mesh, with_labels=True)

    def step(params, opt_state, dense, ids, labels):
        emb = lookup_fields(params["tables"], ids)

        def loss_fn(emb, mlps):
            p2 = dict(params, **mlps)
            return bce_loss(dlrm_forward_from_emb(cfg, p2, dense, emb), labels)

        mlps = {k: params[k] for k in dense_keys}
        loss, (g_emb, g_mlp) = jax.value_and_grad(loss_fn, argnums=(0, 1))(
            emb, mlps)
        tables, acc = rowwise_adagrad_update(params["tables"],
                                             opt_state["acc"], ids, g_emb)
        new_mlps, mlp_state = opt.update(g_mlp, opt_state["mlp"], mlps)
        new_params = dict(params, tables=tables, **new_mlps)
        return new_params, {"acc": acc, "mlp": mlp_state}, loss

    args = (p_struct, o_struct, *data_args)
    in_sh = (shd.named(mesh, p_spec), shd.named(mesh, o_spec), *data_sh)
    out_sh = (shd.named(mesh, p_spec), shd.named(mesh, o_spec),
              NamedSharding(mesh, P()))
    d = cfg.embed_dim
    touched = batch * cfg.n_sparse
    analytic_hbm = (6 * touched * d * 4          # gather + scatter + scale rows
                    + 4 * touched * 4            # accumulator rows
                    + 3 * 8 * batch * 1024 * 4)  # mlp fwd/bwd approx
    analytic_coll = 6 * touched * d * 4          # a2a-ish lookup + grad return
    return Cell(arch, shape_name, "recsys_train", step, args, in_sh, out_sh,
                donate=(0, 1),
                meta=dict(model_flops=3 * _recsys_embedding_flops(arch, cfg, batch),
                          batch=batch, analytic_hbm=float(analytic_hbm),
                          analytic_coll=float(analytic_coll),
                          variant="rowwise_adagrad"))


def _recsys_train(arch, mod, shape_name, shape, mesh):
    import os
    if os.environ.get("REPRO_RECSYS_OPT") == "rowwise" and arch == "dlrm_rm2":
        return _recsys_train_rowwise(arch, mod, shape_name, shape, mesh)
    from repro.models.recsys_models import bce_loss
    cfg = mod.FULL
    opt = _make_opt(mod.OPTIMIZER)
    batch = shape["batch"]
    fwd = _recsys_forward(arch, cfg)
    p_struct = _recsys_init_struct(arch, cfg)
    o_struct = jax.eval_shape(opt.init, p_struct)
    p_spec = shd.recsys_param_specs(arch, p_struct, mesh)
    o_spec = shd.opt_state_specs(mod.OPTIMIZER, p_spec, p_struct)

    data_args, data_sh, _ = _recsys_io(arch, cfg, batch, mesh, with_labels=True)

    def step(params, opt_state, *data):
        *feats, labels = data

        def loss_fn(p):
            return bce_loss(fwd(p, *feats), labels)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, loss

    args = (p_struct, o_struct, *data_args)
    in_sh = (shd.named(mesh, p_spec), shd.named(mesh, o_spec), *data_sh)
    out_sh = (shd.named(mesh, p_spec), shd.named(mesh, o_spec),
              NamedSharding(mesh, P()))
    d = cfg.embed_dim
    table_bytes = cfg.n_sparse * cfg.vocab * d * 4
    touched = batch * cfg.n_sparse
    # dense Adam streams the whole table + m + v (read+write each)
    analytic_hbm = 6 * table_bytes + 4 * touched * d * 4
    analytic_coll = 6 * touched * d * 4
    return Cell(arch, shape_name, "recsys_train", step, args, in_sh, out_sh,
                donate=(0, 1),
                meta=dict(model_flops=3 * _recsys_embedding_flops(arch, cfg, batch),
                          batch=batch, analytic_hbm=float(analytic_hbm),
                          analytic_coll=float(analytic_coll),
                          variant="dense_adam"))


def _recsys_serve(arch, mod, shape_name, shape, mesh):
    cfg = mod.FULL
    batch = shape["batch"]
    fwd = _recsys_forward(arch, cfg)
    p_struct = _recsys_init_struct(arch, cfg)
    p_spec = shd.recsys_param_specs(arch, p_struct, mesh)
    data_args, data_sh, bspec = _recsys_io(arch, cfg, batch, mesh,
                                           with_labels=False)

    def step(params, *feats):
        return fwd(params, *feats)

    args = (p_struct, *data_args)
    in_sh = (shd.named(mesh, p_spec), *data_sh)
    out_sh = NamedSharding(mesh, bspec)
    return Cell(arch, shape_name, "recsys_serve", step, args, in_sh, out_sh,
                donate=(),
                meta=dict(model_flops=_recsys_embedding_flops(arch, cfg, batch),
                          batch=batch))


def _recsys_retrieval(arch, mod, shape_name, shape, mesh):
    from repro.models.recsys_models import dlrm_retrieve
    cfg = mod.FULL
    c = shape["n_candidates"]
    dpall = all_axes(mesh)
    p_struct = _recsys_init_struct(arch, cfg)
    p_spec = shd.recsys_param_specs(arch, p_struct, mesh)

    if arch == "dlrm_rm2":
        def step(params, dense, ids, cand):
            return dlrm_retrieve(cfg, params, dense, ids, cand)
        args = (p_struct, _sds((1, cfg.n_dense), F32),
                _sds((1, cfg.n_sparse), I32), _sds((c,), I32))
        in_sh = (shd.named(mesh, p_spec), NamedSharding(mesh, P(None, None)),
                 NamedSharding(mesh, P(None, None)),
                 NamedSharding(mesh, P(dpall)))
    else:
        fwd = _recsys_forward(arch, cfg)

        def step(params, ids, cand):
            # broadcast user fields, swap field 0 with the candidates
            ids_b = jnp.broadcast_to(ids, (c, cfg.n_sparse))
            ids_b = ids_b.at[:, 0].set(cand)
            return fwd(params, ids_b)
        args = (p_struct, _sds((1, cfg.n_sparse), I32), _sds((c,), I32))
        in_sh = (shd.named(mesh, p_spec), NamedSharding(mesh, P(None, None)),
                 NamedSharding(mesh, P(dpall)))
    out_sh = NamedSharding(mesh, P(dpall))
    return Cell(arch, shape_name, "recsys_retrieval", step, args, in_sh, out_sh,
                donate=(),
                meta=dict(model_flops=_recsys_embedding_flops(arch, cfg, c),
                          candidates=c))


# =========================================================== bert4rec (seq)

def _seq_train(arch, mod, shape_name, shape, mesh):
    from repro.models.recsys_models import bert4rec_sampled_loss
    cfg = mod.FULL
    opt = _make_opt(mod.OPTIMIZER)
    b = shape["batch"]
    m, n_neg = mod.N_MASKED, mod.N_NEGATIVES
    dpall = all_axes(mesh)
    p_struct = _recsys_init_struct(arch, cfg)
    o_struct = jax.eval_shape(opt.init, p_struct)
    p_spec = shd.recsys_param_specs(arch, p_struct, mesh)
    o_spec = shd.opt_state_specs(mod.OPTIMIZER, p_spec, p_struct)

    def step(params, opt_state, seq, smask, mpos, labels, negs):
        def loss_fn(p):
            return bert4rec_sampled_loss(cfg, p, seq, smask, mpos, labels, negs)
        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, loss

    s = cfg.seq_len
    args = (p_struct, o_struct, _sds((b, s), I32), _sds((b, s), jnp.bool_),
            _sds((b, m), I32), _sds((b, m), I32), _sds((b, m, n_neg), I32))
    dsh = lambda *sp: NamedSharding(mesh, P(*sp))
    in_sh = (shd.named(mesh, p_spec), shd.named(mesh, o_spec),
             dsh(dpall, None), dsh(dpall, None), dsh(dpall, None),
             dsh(dpall, None), dsh(dpall, None, None))
    out_sh = (shd.named(mesh, p_spec), shd.named(mesh, o_spec),
              NamedSharding(mesh, P()))
    d = cfg.embed_dim
    enc = cfg.n_blocks * (4 * d * d + 2 * d * cfg.d_ff) * s * b * 2 \
        + cfg.n_blocks * 2 * b * s * s * d * 2
    return Cell(arch, shape_name, "seq_train", step, args, in_sh, out_sh,
                donate=(0, 1),
                meta=dict(model_flops=3 * (enc + 2 * b * m * (n_neg + 1) * d),
                          batch=b))


def _seq_serve(arch, mod, shape_name, shape, mesh):
    from repro.models.recsys_models import bert4rec_serve
    cfg = mod.FULL
    b = shape["batch"]
    slate = 1024
    dpall = all_axes(mesh)
    nd = mesh.devices.size
    p_struct = _recsys_init_struct(arch, cfg)
    p_spec = shd.recsys_param_specs(arch, p_struct, mesh)
    bspec = dpall if b % nd == 0 else None

    def step(params, seq, smask, slate_ids):
        return bert4rec_serve(cfg, params, seq, smask, slate_ids)

    s = cfg.seq_len
    args = (p_struct, _sds((b, s), I32), _sds((b, s), jnp.bool_),
            _sds((b, slate), I32))
    in_sh = (shd.named(mesh, p_spec), NamedSharding(mesh, P(bspec, None)),
             NamedSharding(mesh, P(bspec, None)),
             NamedSharding(mesh, P(bspec, None)))
    out_sh = (NamedSharding(mesh, P(bspec, None)),
              NamedSharding(mesh, P(bspec, None)))
    d = cfg.embed_dim
    enc = cfg.n_blocks * (4 * d * d + 2 * d * cfg.d_ff) * s * b * 2 \
        + cfg.n_blocks * 2 * b * s * s * d * 2
    return Cell(arch, shape_name, "seq_serve", step, args, in_sh, out_sh,
                donate=(), meta=dict(model_flops=enc, batch=b))


def _seq_retrieval(arch, mod, shape_name, shape, mesh):
    from repro.models.recsys_models import bert4rec_retrieve
    cfg = mod.FULL
    b, c = shape["batch"], shape["n_candidates"]
    dpall = all_axes(mesh)
    p_struct = _recsys_init_struct(arch, cfg)
    p_spec = shd.recsys_param_specs(arch, p_struct, mesh)

    def step(params, seq, smask, cand):
        return bert4rec_retrieve(cfg, params, seq, smask, cand)

    s = cfg.seq_len
    args = (p_struct, _sds((b, s), I32), _sds((b, s), jnp.bool_),
            _sds((c,), I32))
    in_sh = (shd.named(mesh, p_spec), NamedSharding(mesh, P(None, None)),
             NamedSharding(mesh, P(None, None)), NamedSharding(mesh, P(dpall)))
    out_sh = NamedSharding(mesh, P(None, dpall))
    return Cell(arch, shape_name, "seq_retrieval", step, args, in_sh, out_sh,
                donate=(),
                meta=dict(model_flops=2 * b * c * cfg.embed_dim, candidates=c))


# =========================================================== NGCF/LightGCN

def _gnnrecsys_train(arch, mod, shape_name, shape, mesh):
    from repro.core import bpr, lightgcn, ngcf
    from repro.core.graph import BipartiteGraph
    cfg = mod.FULL
    opt = _make_opt(mod.OPTIMIZER)
    dpall = all_axes(mesh)
    nd = mesh.devices.size
    e_pad = _round_up(cfg.n_edges, nd)
    is_ngcf = arch == "ngcf"

    if is_ngcf:
        p_struct = jax.eval_shape(
            lambda k: ngcf.init_params(k, cfg.n_users, cfg.n_items,
                                       cfg.embed_dim, cfg.n_layers),
            jax.random.PRNGKey(0))
    else:
        p_struct = jax.eval_shape(
            lambda k: lightgcn.init_params(k, cfg.n_users, cfg.n_items,
                                           cfg.embed_dim),
            jax.random.PRNGKey(0))
    o_struct = jax.eval_shape(opt.init, p_struct)
    p_spec = shd.gnnrecsys_param_specs(cfg, mesh, "ngcf" if is_ngcf else "lightgcn")
    o_spec = shd.opt_state_specs(mod.OPTIMIZER, p_spec, p_struct)

    def step(params, opt_state, user, item, emask, bu, bi, bn):
        g = BipartiteGraph(user, item, emask, cfg.n_users, cfg.n_items,
                           cfg.n_edges)

        def loss_fn(p):
            if is_ngcf:
                ue, ie = ngcf.forward(p, g, opt_level=3)
            else:
                ue, ie = lightgcn.forward(p, g, n_layers=cfg.n_layers)
            return bpr.bpr_loss(ue, ie, bu, bi, bn)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, loss

    bb = cfg.bpr_batch
    args = (p_struct, o_struct,
            _sds((e_pad,), I32), _sds((e_pad,), I32), _sds((e_pad,), jnp.bool_),
            _sds((bb,), I32), _sds((bb,), I32), _sds((bb,), I32))
    esh = NamedSharding(mesh, P(dpall))
    in_sh = (shd.named(mesh, p_spec), shd.named(mesh, o_spec),
             esh, esh, esh, esh, esh, esh)
    out_sh = (shd.named(mesh, p_spec), shd.named(mesh, o_spec),
              NamedSharding(mesh, P()))
    d = cfg.embed_dim
    # per layer: SDDMM mul (E*D) + 2 SpMM (E*D each); NGCF adds O(V*D^2) matmuls
    per_layer = 3 * 2 * cfg.n_edges * d
    if is_ngcf:
        per_layer += 2 * (cfg.n_users + cfg.n_items) * d * d * 2
    flops = 3 * cfg.n_layers * per_layer
    meta = dict(model_flops=flops, n_edges=cfg.n_edges, bpr_batch=bb)
    if is_ngcf:
        # NGCF byte model (paper §2.1): the dominant HBM term is the
        # per-layer [E, D] Hadamard message stream — written by the
        # SDDMM-mul, read by both SpMMs, and saved/re-read as an
        # autodiff residual (~4 touches/layer, both directions).  The
        # fused hadamard_spmm route forms the product in VMEM and
        # rematerializes it in backward, so that term vanishes; the
        # node-level gather/scatter and optimizer traffic stand.
        row = d * 4
        v = cfg.n_users + cfg.n_items
        msg_bytes = cfg.n_layers * 2 * 4 * cfg.n_edges * row
        node_bytes = cfg.n_layers * 3 * 2 * (2 * cfg.n_edges + 2 * v) * row
        opt_bytes = 6 * (cfg.n_layers + 1) * v * row   # adam: p+m+v r/w
        coll_bytes = 2 * (cfg.n_layers + 1) * v * row  # grad all-reduce
        meta.update(analytic_hbm=float(msg_bytes + node_bytes + opt_bytes),
                    analytic_coll=float(coll_bytes),
                    hadamard_msg_hbm_bytes=float(msg_bytes))
    return Cell(arch, shape_name, "gnnrecsys_train", step, args, in_sh, out_sh,
                donate=(0, 1), meta=meta)


_BUILDERS = {
    "train": _lm_train,
    "prefill": _lm_prefill,
    "decode": _lm_decode,
    "gnn_full": _gnn_full,
    "gnn_sampled": _gnn_sampled,
    "gnn_batched": _gnn_batched,
    "recsys_train": _recsys_train,
    "recsys_serve": _recsys_serve,
    "recsys_retrieval": _recsys_retrieval,
    "seq_train": _seq_train,
    "seq_serve": _seq_serve,
    "seq_retrieval": _seq_retrieval,
    "gnnrecsys_train": _gnnrecsys_train,
}


def input_specs(arch_id: str, shape_name: str, mesh):
    """Paper-required entry point: ShapeDtypeStruct stand-ins for every
    model input of the given cell."""
    return build_cell(arch_id, shape_name, mesh).args
