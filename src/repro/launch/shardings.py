"""Parameter / optimizer-state / data sharding rules per model family.

LM transformers: FSDP x TP — every weight matrix shards its d_model-like
dim over the data-parallel axes (ZeRO-3 storage) and its heads/ffn dim
over 'model' (tensor parallelism).  MoE experts shard over 'model'
(expert parallelism) when n_experts divides the axis, else TP-in-expert
(mixtral: 8 experts < 16).

Optimizer state: adam m/v inherit the param spec; adafactor's factored
moments drop the corresponding dim from the spec.
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import all_axes, dp_axes


def named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


# ----------------------------------------------------------------- LM

def lm_param_specs(cfg, mesh):
    dp = dp_axes(mesh)
    tp = "model"
    layers = {
        "attn_norm": P(None, None),
        "ffn_norm": P(None, None),
        "wq": P(None, dp, tp),
        "wk": P(None, dp, tp),
        "wv": P(None, dp, tp),
        "wo": P(None, tp, dp),
    }
    if cfg.is_moe:
        layers["router"] = P(None, dp, None)
        if cfg.n_experts % mesh.shape[tp] == 0:
            # expert parallelism
            layers["w_up"] = P(None, tp, dp, None)
            layers["w_down"] = P(None, tp, None, dp)
            if cfg.activation == "swiglu":
                layers["w_gate"] = P(None, tp, dp, None)
        else:
            # TP within each expert
            layers["w_up"] = P(None, None, dp, tp)
            layers["w_down"] = P(None, None, tp, dp)
            if cfg.activation == "swiglu":
                layers["w_gate"] = P(None, None, dp, tp)
        if cfg.shared_experts:
            layers["ws_up"] = P(None, dp, tp)
            layers["ws_down"] = P(None, tp, dp)
    else:
        layers["w_up"] = P(None, dp, tp)
        layers["w_down"] = P(None, tp, dp)
        if cfg.activation == "swiglu":
            layers["w_gate"] = P(None, dp, tp)
    return {
        # vocab-parallel only (no FSDP dim): with the one-hot-matmul
        # lookup, fwd/bwd of both vocab matrices are clean tp-sharded
        # matmuls + dp all-reduce.  Sharding D over dp as well makes the
        # head-grad dot unshardable and GSPMD replicates a [D, V] f32
        # buffer per device (17.6 GiB on the 340B).  Storage cost of
        # dp-replication: <=590 MB/device on the largest config.
        "embed": P(tp, None),
        "layers": layers,
        "final_norm": P(None),
        "lm_head": P(None, tp),
    }


def lm_cache_specs(cfg, mesh, batch: int):
    """KV cache [L, B, Hkv, S, dh]: batch over dp when divisible, else
    sequence over every axis (long-context single-stream)."""
    dp = dp_axes(mesh)
    dp_sz = 1
    for a in dp:
        dp_sz *= mesh.shape[a]
    if batch % dp_sz == 0 and batch >= dp_sz:
        spec = P(None, dp, None, "model", None)
    else:
        spec = P(None, None, None, all_axes(mesh), None)
    return {"k": spec, "v": spec}


# ----------------------------------------------------------------- opt state

def adam_state_specs(param_specs):
    return {
        "m": param_specs,
        "v": param_specs,
        "t": P(),
    }


def adafactor_state_specs(param_specs, param_shapes):
    def leaf(spec, shape):
        if len(shape.shape) >= 2:
            return {"vr": P(*spec[:len(shape.shape) - 1]),
                    "vc": P(*(tuple(spec[:len(shape.shape) - 2])
                              + (spec[len(shape.shape) - 1],)))}
        return {"v": spec}

    s = jax.tree.map(leaf, param_specs, param_shapes,
                     is_leaf=lambda x: isinstance(x, P))
    return {"s": s, "t": P()}


def opt_state_specs(optimizer_name: str, param_specs, param_shapes):
    if optimizer_name == "adam":
        return adam_state_specs(param_specs)
    if optimizer_name == "adafactor":
        return adafactor_state_specs(param_specs, param_shapes)
    if optimizer_name == "sgd":
        return ()
    raise ValueError(optimizer_name)


# ----------------------------------------------------------------- others

def gcn_param_specs(cfg, mesh):
    # GCN weights are tiny (1433x16, 16x7): replicate
    return {"layers": [{"w": P(None, None), "b": P(None)}
                       for _ in range(cfg.n_layers)]}


def recsys_param_specs(model_name: str, params_shapes, mesh):
    """Tables row-sharded over the whole mesh (capacity-tier residency);
    dense towers replicated."""
    ax = all_axes(mesh)

    def leaf_spec(path, shape):
        name = jax.tree_util.keystr(path)
        if "tables" in name:
            return P(None, ax, None)
        if "linear" in name:
            return P(None, ax)
        if "item_embed" in name:
            return P(ax, None)
        if "out_bias" in name:
            return P(ax)
        return P(*([None] * len(shape.shape)))

    return jax.tree_util.tree_map_with_path(leaf_spec, params_shapes)


def gnnrecsys_param_specs(cfg, mesh, model: str):
    ax = all_axes(mesh)
    specs = {"user_embed": P(ax, None), "item_embed": P(ax, None)}
    if model == "ngcf":
        specs["w1"] = [P(None, None)] * cfg.n_layers
        specs["w2"] = [P(None, None)] * cfg.n_layers
    return specs
