"""Roofline-term extraction from compiled dry-run artifacts.

  compute term    = HLO_FLOPs / (chips * 197e12 bf16 FLOP/s)
  memory term     = HLO_bytes / (chips * 819e9 B/s HBM)
  collective term = collective_bytes / (chips * n_links * 50e9 B/s ICI)

HLO_FLOPs / HLO_bytes come from compiled.cost_analysis() (whole-program,
all devices).  collective_bytes is parsed out of the optimized HLO text:
for every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute instruction we take the largest shape named in the
instruction (the full buffer that crosses links) — an upper-bound proxy;
loop-carried collectives count once per appearance (documented
limitation; ring schedules multiply analytically in benchmarks).
"""
from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12          # bf16 / chip (TPU v5e)
HBM_BW = 819e9               # B/s / chip
ICI_BW = 50e9                # B/s / link
ICI_LINKS = 4                # torus links usable per chip (2D)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")


def shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


@dataclasses.dataclass
class CollectiveStats:
    total_bytes: int
    by_kind: dict

    def __str__(self):
        parts = ", ".join(f"{k}: {v/1e9:.3f} GB" for k, v in
                          sorted(self.by_kind.items()))
        return f"collectives {self.total_bytes/1e9:.3f} GB ({parts})"


def collective_bytes(hlo_text: str) -> CollectiveStats:
    total = 0
    by_kind: dict[str, int] = {}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        for kind in _COLLECTIVES:
            # match the op name as the instruction, not inside metadata
            if f"= {kind}(" in stripped or re.search(
                    rf"\)\s*{kind}\(", stripped) or re.search(
                    rf"\]\S*\s{kind}\(", stripped):
                sizes = [shape_bytes(m.group(1), m.group(2))
                         for m in _SHAPE_RE.finditer(stripped)]
                if sizes:
                    b = max(sizes)
                    total += b
                    by_kind[kind] = by_kind.get(kind, 0) + b
                break
    return CollectiveStats(total, by_kind)


@dataclasses.dataclass
class Roofline:
    flops: float
    hbm_bytes: float
    coll_bytes: float
    chips: int
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: float
    useful_ratio: float

    def as_dict(self):
        return dataclasses.asdict(self)


def analyze(compiled, n_chips: int, model_flops: float = 0.0,
            analytic: dict | None = None) -> Roofline:
    """analytic: optional {'flops','hbm_bytes','coll_bytes'} override for
    loop-heavy (scan) programs where HloCostAnalysis counts while bodies
    once (see launch/analytic.py docstring)."""
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # older API returns [dict]
        cost = cost[0]
    if analytic is not None:
        flops = float(analytic["flops"])
        byts = float(analytic["hbm_bytes"])
        coll_total = float(analytic["coll_bytes"])
    else:
        flops = float(cost.get("flops", 0.0))
        byts = float(cost.get("bytes accessed", 0.0))
        coll_total = float(collective_bytes(compiled.as_text()).total_bytes)
    compute_s = flops / (n_chips * PEAK_FLOPS)
    memory_s = byts / (n_chips * HBM_BW)
    collective_s = coll_total / (n_chips * ICI_LINKS * ICI_BW)
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    return Roofline(flops, byts, coll_total, n_chips, compute_s,
                    memory_s, collective_s, bottleneck, model_flops,
                    (model_flops / flops) if flops else 0.0)
