"""End-to-end training launcher.

CPU-sized by default (smoke-scale synthetic data); the same entry point
drives the production mesh on real hardware via --mesh.

GNNRecSys archs (lightgcn / ngcf / gcn) run through the unified
Experiment API (``repro.api``): every flag is a declarative-spec
override, so the CLI, a preset, and a JSON spec file all build the same
``ExperimentSpec`` — tiered-memory placement, the §7.1 large-batch
schedule, microbatched gradient accumulation, and streaming held-out
eval all ride along.

  python -m repro.launch.train --arch lightgcn --steps 100
  python -m repro.launch.train --arch ngcf --target-batch 4096 --microbatch 512
  python -m repro.launch.train --preset lightgcn-smoke
  python -m repro.launch.train --arch lightgcn --dataset gowalla --edges 8000
  python -m repro.launch.train --spec my_experiment.json --set plan.microbatch=128
  python -m repro.launch.train --arch gcn-cora --steps 50      # legacy archs

Sharded execution (mesh-parallel full-graph training; CPU CI uses
XLA_FLAGS=--xla_force_host_platform_device_count=4):

  python -m repro.launch.train --arch lightgcn --mesh 4 --ring-steps 2
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs as config_registry
from repro.api import (DataCfg, Experiment, ExperimentSpec, LoopCfg,
                       ModelCfg, PlanCfg, get_preset)
from repro.optim import adam
from repro.runtime.loop import LoopConfig, run_training

PIPELINE_ARCHS = ("lightgcn", "ngcf", "gcn")

DEFAULT_CKPT_ROOT = "/tmp/repro_ckpt"


def default_spec() -> ExperimentSpec:
    """The launcher's base spec — the values the flags override."""
    return ExperimentSpec(
        name="train",
        model=ModelCfg(arch="lightgcn", embed_dim=32, n_layers=2),
        data=DataCfg(source="synth", dataset="movielens-10m", edges=4000),
        plan=PlanCfg(base_batch=512, target_batch=2048, microbatch=512),
        loop=LoopCfg(steps=100),
    )


def build_arg_parser() -> argparse.ArgumentParser:
    """Flags default to None so only explicitly-passed ones override the
    base spec (preset / spec file / ``default_spec``)."""
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", help="model architecture "
                    f"(pipeline: {', '.join(PIPELINE_ARCHS)}; plus the "
                    "legacy CPU trainers)")
    ap.add_argument("--preset", help="start from a named spec "
                    "(repro.api.preset_names())")
    ap.add_argument("--spec", help="start from a JSON ExperimentSpec file")
    ap.add_argument("--set", action="append", default=[], metavar="PATH=V",
                    help="dotted spec override, e.g. plan.hbm_budget=2048 "
                         "(repeatable; values parsed as JSON)")
    ap.add_argument("--steps", type=int)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--dataset", help="paper dataset statistics to "
                    "synthesize (repro.data.synth.DATASET_STATS)")
    ap.add_argument("--edges", type=int)
    ap.add_argument("--target-batch", type=int,
                    help="large-batch target (accumulated microbatches)")
    ap.add_argument("--microbatch", type=int,
                    help="microbatch size; 0 = derive from HBM headroom")
    ap.add_argument("--embed-dim", type=int)
    ap.add_argument("--layers", type=int)
    ap.add_argument("--eval-every", type=int,
                    help="held-out streaming-eval cadence in steps; "
                         "0 = final eval only")
    ap.add_argument("--eval-k", type=int)
    ap.add_argument("--mesh", help="mesh shape for sharded execution, "
                    "e.g. '4' or '2x2' (spec override mesh.shape); on CPU "
                    "pair with XLA_FLAGS=--xla_force_host_platform_"
                    "device_count=N")
    ap.add_argument("--ring-steps", type=int,
                    help="banded ring: visit only this many source owners "
                         "per SpMM (mesh.ring_steps; 0 = full ring)")
    ap.add_argument("--spmm", choices=["auto", "ring"],
                    help="aggregation dispatch (mesh.spmm); 'ring' forces "
                         "the ring route even on one device")
    ap.add_argument("--memory-topology",
                    help="registered TierTopology to model "
                         "(repro.memory.topology_names(), e.g. "
                         "dram-optane-appdirect; spec override "
                         "memory.topology)")
    ap.add_argument("--placement-policy",
                    help="registered placement policy "
                         "(repro.memory.policy_names(), e.g. paper-recipe; "
                         "spec override memory.policy)")
    ap.add_argument("--pin", action="append", default=[],
                    metavar="TENSOR=TIER",
                    help="pin a tensor (by profile name or substring) to a "
                         "tier, e.g. --pin item_embed=slow (repeatable; "
                         "merges into memory.pins)")
    ap.add_argument("--compress-grads", choices=["none", "int8", "topk"],
                    help="compressed gradient combine (compression.grads): "
                         "int8 stochastic psum or top-k all-gather, with "
                         "error feedback")
    ap.add_argument("--compress-frac", type=float,
                    help="top-k kept fraction (compression.frac)")
    ap.add_argument("--embed-store", choices=["fp32", "int8"],
                    help="capacity-tier embedding-table storage "
                         "(compression.embed_store): int8 = ~1/4 bytes, "
                         "fp32 dequant-on-gather")
    ap.add_argument("--compress-ring", choices=["none", "int8"],
                    help="ring-SpMM payload rotation (compression.ring)")
    return ap


def _parse_set(entry: str) -> tuple[str, object]:
    path, sep, raw = entry.partition("=")
    if not sep:
        raise SystemExit(f"--set expects PATH=VALUE, got {entry!r}")
    try:
        value = json.loads(raw)
    except json.JSONDecodeError:
        value = raw                       # bare strings pass through
    return path.strip(), value


def spec_from_args(args: argparse.Namespace) -> ExperimentSpec:
    """argparse namespace -> ExperimentSpec: base (spec file > preset >
    defaults), then flag overrides, then --set dotted overrides."""
    if args.spec:
        spec = ExperimentSpec.from_file(args.spec)
    elif args.preset:
        spec = get_preset(args.preset)
    else:
        spec = default_spec()
    ov: dict[str, object] = {}
    if args.arch is not None:
        ov["model.arch"] = args.arch
    if args.embed_dim is not None:
        ov["model.embed_dim"] = args.embed_dim
    if args.layers is not None:
        ov["model.n_layers"] = args.layers
    if args.dataset is not None:
        ov["data.dataset"] = args.dataset
    if args.edges is not None:
        ov["data.edges"] = args.edges
    if args.target_batch is not None:
        ov["plan.target_batch"] = args.target_batch
    if args.microbatch is not None:
        ov["plan.microbatch"] = args.microbatch or None
    if args.steps is not None:
        ov["loop.steps"] = args.steps
    if args.eval_every is not None:
        ov["loop.eval_every"] = args.eval_every or None
    if args.eval_k is not None:
        ov["eval.k"] = args.eval_k
    if args.mesh is not None:
        from repro.pipeline.shard import parse_mesh
        ov["mesh.shape"] = parse_mesh(args.mesh)
    if args.ring_steps is not None:
        ov["mesh.ring_steps"] = args.ring_steps or None
    if args.spmm is not None:
        ov["mesh.spmm"] = None if args.spmm == "auto" else args.spmm
    if args.memory_topology is not None:
        ov["memory.topology"] = args.memory_topology
    if args.placement_policy is not None:
        ov["memory.policy"] = args.placement_policy
    if args.compress_grads is not None:
        ov["compression.grads"] = args.compress_grads
    if args.compress_frac is not None:
        ov["compression.frac"] = args.compress_frac
    if args.embed_store is not None:
        ov["compression.embed_store"] = args.embed_store
    if args.compress_ring is not None:
        ov["compression.ring"] = args.compress_ring
    if args.pin:
        pins = dict(spec.memory.pins or {})
        for entry in args.pin:
            name, sep, tier = entry.partition("=")
            if not sep:
                raise SystemExit(f"--pin expects TENSOR=TIER, got {entry!r}")
            pins[name.strip()] = tier.strip()
        ov["memory.pins"] = pins
    spec = spec.override(ov)
    spec = spec.override(dict(_parse_set(s) for s in args.set))
    # ckpt-dir default last, so it names the arch the run actually uses
    # (a --set model.arch=... override included)
    if spec.loop.ckpt_dir is None:
        ckpt_root = args.ckpt_dir if args.ckpt_dir is not None \
            else DEFAULT_CKPT_ROOT
        spec = spec.override({"loop.ckpt_dir": f"{ckpt_root}/{spec.model.arch}"})
    return spec


def run_experiment(spec: ExperimentSpec):
    """One spec, end to end: build -> fit (fault-tolerant loop, resumes
    from the spec's checkpoint dir) -> final held-out streaming eval."""
    run = Experiment(spec).build()
    print(run.describe())
    t0 = time.perf_counter()
    report = run.fit()
    dt = time.perf_counter() - t0
    pipe = run.pipeline
    print(f"[{spec.model.arch}] {report.steps_run} steps in {dt:.1f}s "
          f"loss {_loss_span(report)} "
          f"(microbatch={pipe.plan.microbatch}, "
          f"accum={pipe.plan.microbatches_for_epoch(pipe.loader.state.epoch)}x, "
          f"resumed_from={report.resumed_from})")
    for step, m in report.eval_history:
        print(f"  eval@{step}: {_fmt_metrics(m)}")
    if run.holdout is not None:
        print(f"[{spec.model.arch}] final held-out: "
              f"{_fmt_metrics(run.evaluate())}")
    return report


def _fmt_metrics(m: dict) -> str:
    return " ".join(f"{k}={v:.4f}" for k, v in sorted(m.items()))


def _loss_span(report) -> str:
    """'first -> last' loss, robust to a resume at max_steps (no new
    steps run -> losses is empty)."""
    if not report.losses:
        return "n/a (already at max_steps)"
    return f"{report.losses[0]:.4f} -> {report.losses[-1]:.4f}"


def train_gcn(steps: int, ckpt_dir: str):
    from repro.core.graph import from_numpy
    from repro.models import gcn
    cfg = config_registry.get("gcn_cora").SMOKE
    rng = np.random.default_rng(0)
    n = 200
    src = rng.integers(0, n, 1600).astype(np.int32)
    dst = rng.integers(0, n, 1600).astype(np.int32)
    g = from_numpy(src, dst, n)
    x = jnp.asarray(rng.standard_normal((n, cfg.d_feat)).astype(np.float32))
    labels = jnp.asarray(rng.integers(0, cfg.n_classes, n).astype(np.int32))
    lmask = jnp.ones((n,), jnp.float32)
    params = gcn.init_params(cfg, jax.random.PRNGKey(0))
    opt = adam(1e-2)

    @jax.jit
    def train_step(state):
        loss, grads = jax.value_and_grad(
            lambda p: gcn.loss_fn(cfg, p, g, x, labels, lmask))(state["params"])
        p, o = opt.update(grads, state["opt"], state["params"])
        return {"params": p, "opt": o}, loss

    state0 = {"params": params, "opt": opt.init(params)}
    report = run_training(
        LoopConfig(ckpt_dir=ckpt_dir, ckpt_every=max(steps // 2, 1),
                   max_steps=steps, async_ckpt=False),
        state0, lambda s, _: train_step(s))
    print(f"[gcn] loss {_loss_span(report)}")
    return report


def train_recsys(arch: str, steps: int, ckpt_dir: str, batch: int = 256):
    from repro.models import recsys_models as rm
    mod = config_registry.get(arch)
    cfg = mod.SMOKE
    rng = np.random.default_rng(0)
    init = {"deepfm": rm.deepfm_init, "xdeepfm": rm.xdeepfm_init,
            "dlrm_rm2": rm.dlrm_init}[config_registry.canon(arch)]
    fwd = {"deepfm": rm.deepfm_forward, "xdeepfm": rm.xdeepfm_forward,
           "dlrm_rm2": rm.dlrm_forward}[config_registry.canon(arch)]
    params = init(cfg, jax.random.PRNGKey(0))
    opt = adam(1e-3)
    is_dlrm = config_registry.canon(arch) == "dlrm_rm2"

    @jax.jit
    def train_step(state, *args):
        *feats, labels = args

        def loss_fn(p):
            return rm.bce_loss(fwd(cfg, p, *feats), labels)

        loss, grads = jax.value_and_grad(loss_fn)(state["params"])
        p, o = opt.update(grads, state["opt"], state["params"])
        return {"params": p, "opt": o}, loss

    def step_fn(state, step):
        ids = jnp.asarray(rng.integers(0, cfg.vocab, (batch, cfg.n_sparse))
                          .astype(np.int32))
        labels = jnp.asarray(rng.integers(0, 2, batch).astype(np.float32))
        if is_dlrm:
            dense = jnp.asarray(rng.standard_normal((batch, cfg.n_dense))
                                .astype(np.float32))
            return train_step(state, dense, ids, labels)
        return train_step(state, ids, labels)

    state0 = {"params": params, "opt": opt.init(params)}
    report = run_training(
        LoopConfig(ckpt_dir=ckpt_dir, ckpt_every=max(steps // 2, 1),
                   max_steps=steps, async_ckpt=False), state0, step_fn)
    print(f"[{arch}] loss {_loss_span(report)}")
    return report


def main():
    args = build_arg_parser().parse_args()
    if args.preset or args.spec or args.arch in PIPELINE_ARCHS:
        run_experiment(spec_from_args(args))
        return
    if args.arch is None:
        raise SystemExit("need --arch, --preset, or --spec")
    arch = config_registry.canon(args.arch)
    steps = args.steps if args.steps is not None else 100
    ckpt_root = args.ckpt_dir if args.ckpt_dir is not None \
        else DEFAULT_CKPT_ROOT
    if arch == "gcn_cora":
        train_gcn(steps, f"{ckpt_root}/{arch}")
    elif arch in ("deepfm", "xdeepfm", "dlrm_rm2"):
        train_recsys(arch, steps, f"{ckpt_root}/{arch}")
    else:
        raise SystemExit(
            f"CPU trainer for {arch!r} not wired; pipeline archs: "
            f"{', '.join(PIPELINE_ARCHS)}; also gcn-cora, deepfm, xdeepfm, "
            f"dlrm_rm2 (LM archs run via the dry-run)")


if __name__ == "__main__":
    main()
