"""End-to-end training launcher.

CPU-sized by default (smoke config, synthetic data); the same entry point
drives the production mesh on real hardware via --mesh.

  python -m repro.launch.train --arch lightgcn --steps 100
  python -m repro.launch.train --arch gcn-cora --steps 50
  python -m repro.launch.train --arch deepfm --steps 50
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs as config_registry
from repro.checkpoint import latest_step
from repro.core import bpr, lightgcn, ngcf
from repro.core.graph import bipartite_from_numpy
from repro.core.large_batch import LargeBatchSchedule
from repro.data import synth
from repro.data.loader import EdgeLoader
from repro.optim import adam
from repro.runtime.loop import LoopConfig, run_training


def train_gnnrecsys(arch: str, steps: int, ckpt_dir: str, batch: int = 512,
                    edges: int = 4000, embed_dim: int = 32, layers: int = 2,
                    log_every: int = 20):
    """Full-graph BPR training of NGCF/LightGCN on a synthetic graph that
    matches the paper's dataset statistics."""
    data = synth.scaled("movielens-10m", edges, seed=0)
    train, test = synth.train_test_split(data)
    g = bipartite_from_numpy(train.user, train.item, data.n_users,
                             data.n_items)
    sched = LargeBatchSchedule(base_lr=1e-3, base_batch=batch,
                               target_batch=batch)
    opt = adam(sched.linear_scaled_lr(batch))
    is_ngcf = arch == "ngcf"
    key = jax.random.PRNGKey(0)
    if is_ngcf:
        params = ngcf.init_params(key, data.n_users, data.n_items, embed_dim,
                                  layers)
    else:
        params = lightgcn.init_params(key, data.n_users, data.n_items,
                                      embed_dim)
    loader = EdgeLoader(train.user, train.item, batch)
    rng = np.random.default_rng(0)

    @jax.jit
    def train_step(state, users, pos, neg):
        def loss_fn(p):
            if is_ngcf:
                ue, ie = ngcf.forward(p, g)
            else:
                ue, ie = lightgcn.forward(p, g, n_layers=layers)
            return bpr.bpr_loss(ue, ie, users, pos, neg)

        loss, grads = jax.value_and_grad(loss_fn)(state["params"])
        p, o = opt.update(grads, state["opt"], state["params"])
        return {"params": p, "opt": o}, loss

    def step_fn(state, step):
        u, i = next(loader)
        neg = rng.integers(0, data.n_items, len(u)).astype(np.int32)
        return train_step(state, jnp.asarray(u), jnp.asarray(i),
                          jnp.asarray(neg))

    state0 = {"params": params, "opt": opt.init(params)}
    cfg = LoopConfig(ckpt_dir=ckpt_dir, ckpt_every=max(steps // 2, 1),
                     max_steps=steps, async_ckpt=False)
    t0 = time.perf_counter()
    report = run_training(cfg, state0, step_fn)
    dt = time.perf_counter() - t0
    print(f"[{arch}] {report.steps_run} steps in {dt:.1f}s "
          f"loss {report.losses[0]:.4f} -> {report.losses[-1]:.4f} "
          f"(resumed_from={report.resumed_from})")
    return report


def train_gcn(steps: int, ckpt_dir: str):
    from repro.core.graph import from_numpy
    from repro.models import gcn
    cfg = config_registry.get("gcn_cora").SMOKE
    rng = np.random.default_rng(0)
    n = 200
    src = rng.integers(0, n, 1600).astype(np.int32)
    dst = rng.integers(0, n, 1600).astype(np.int32)
    g = from_numpy(src, dst, n)
    x = jnp.asarray(rng.standard_normal((n, cfg.d_feat)).astype(np.float32))
    labels = jnp.asarray(rng.integers(0, cfg.n_classes, n).astype(np.int32))
    lmask = jnp.ones((n,), jnp.float32)
    params = gcn.init_params(cfg, jax.random.PRNGKey(0))
    opt = adam(1e-2)

    @jax.jit
    def train_step(state):
        loss, grads = jax.value_and_grad(
            lambda p: gcn.loss_fn(cfg, p, g, x, labels, lmask))(state["params"])
        p, o = opt.update(grads, state["opt"], state["params"])
        return {"params": p, "opt": o}, loss

    state0 = {"params": params, "opt": opt.init(params)}
    report = run_training(
        LoopConfig(ckpt_dir=ckpt_dir, ckpt_every=max(steps // 2, 1),
                   max_steps=steps, async_ckpt=False),
        state0, lambda s, _: train_step(s))
    print(f"[gcn] loss {report.losses[0]:.4f} -> {report.losses[-1]:.4f}")
    return report


def train_recsys(arch: str, steps: int, ckpt_dir: str, batch: int = 256):
    from repro.models import recsys_models as rm
    mod = config_registry.get(arch)
    cfg = mod.SMOKE
    rng = np.random.default_rng(0)
    init = {"deepfm": rm.deepfm_init, "xdeepfm": rm.xdeepfm_init,
            "dlrm_rm2": rm.dlrm_init}[config_registry.canon(arch)]
    fwd = {"deepfm": rm.deepfm_forward, "xdeepfm": rm.xdeepfm_forward,
           "dlrm_rm2": rm.dlrm_forward}[config_registry.canon(arch)]
    params = init(cfg, jax.random.PRNGKey(0))
    opt = adam(1e-3)
    is_dlrm = config_registry.canon(arch) == "dlrm_rm2"

    @jax.jit
    def train_step(state, *args):
        *feats, labels = args

        def loss_fn(p):
            return rm.bce_loss(fwd(cfg, p, *feats), labels)

        loss, grads = jax.value_and_grad(loss_fn)(state["params"])
        p, o = opt.update(grads, state["opt"], state["params"])
        return {"params": p, "opt": o}, loss

    def step_fn(state, step):
        ids = jnp.asarray(rng.integers(0, cfg.vocab, (batch, cfg.n_sparse))
                          .astype(np.int32))
        labels = jnp.asarray(rng.integers(0, 2, batch).astype(np.float32))
        if is_dlrm:
            dense = jnp.asarray(rng.standard_normal((batch, cfg.n_dense))
                                .astype(np.float32))
            return train_step(state, dense, ids, labels)
        return train_step(state, ids, labels)

    state0 = {"params": params, "opt": opt.init(params)}
    report = run_training(
        LoopConfig(ckpt_dir=ckpt_dir, ckpt_every=max(steps // 2, 1),
                   max_steps=steps, async_ckpt=False), state0, step_fn)
    print(f"[{arch}] loss {report.losses[0]:.4f} -> {report.losses[-1]:.4f}")
    return report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    args = ap.parse_args()
    arch = config_registry.canon(args.arch)
    if arch in ("ngcf", "lightgcn"):
        train_gnnrecsys(arch, args.steps, f"{args.ckpt_dir}/{arch}")
    elif arch == "gcn_cora":
        train_gcn(args.steps, f"{args.ckpt_dir}/{arch}")
    elif arch in ("deepfm", "xdeepfm", "dlrm_rm2"):
        train_recsys(arch, args.steps, f"{args.ckpt_dir}/{arch}")
    else:
        raise SystemExit(f"CPU trainer for {arch} not wired; use the "
                         f"dry-run for LM archs")


if __name__ == "__main__":
    main()
