"""End-to-end training launcher.

CPU-sized by default (smoke config, synthetic data); the same entry point
drives the production mesh on real hardware via --mesh.

  python -m repro.launch.train --arch lightgcn --steps 100
  python -m repro.launch.train --arch ngcf --target-batch 4096 --microbatch 512
  python -m repro.launch.train --arch gcn-cora --steps 50
  python -m repro.launch.train --arch deepfm --steps 50

GNNRecSys archs (lightgcn / ngcf / gcn) run through the unified
pipeline: tiered-memory placement over the run's tensor set, the §7.1
large-batch schedule, and microbatched gradient accumulation so the
target batch can exceed the per-step memory budget.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs as config_registry
from repro.checkpoint import latest_step, restore_checkpoint
from repro.data import synth
from repro.optim import adam
from repro.pipeline import PipelineConfig, build_pipeline
from repro.runtime.loop import LoopConfig, run_pipeline, run_training

PIPELINE_ARCHS = ("lightgcn", "ngcf", "gcn")


def train_gnnrecsys(arch: str, steps: int, ckpt_dir: str,
                    target_batch: int = 2048, microbatch: int | None = 512,
                    base_batch: int = 512, edges: int = 4000,
                    embed_dim: int = 32, layers: int = 2,
                    hbm_budget: int | None = None,
                    eval_every: int | None = None, eval_k: int = 20):
    """Full-graph BPR training through the unified pipeline on a synthetic
    graph matching the paper's dataset statistics.  The held-out split is
    evaluated through the streaming top-K path (``repro.eval``) every
    ``eval_every`` steps and once at the end."""
    data = synth.scaled("movielens-10m", edges, seed=0)
    train, test = synth.train_test_split(data)
    cfg = PipelineConfig(arch=arch, embed_dim=embed_dim, n_layers=layers,
                         base_batch=base_batch, target_batch=target_batch,
                         microbatch=microbatch, hbm_budget=hbm_budget,
                         eval_k=eval_k)
    pipe = build_pipeline(cfg, train, holdout=test)
    print(pipe.plan.describe())
    loop_cfg = LoopConfig(ckpt_dir=ckpt_dir, ckpt_every=max(steps // 2, 1),
                          max_steps=steps, async_ckpt=False,
                          eval_every=eval_every)
    t0 = time.perf_counter()
    report = run_pipeline(loop_cfg, pipe)
    dt = time.perf_counter() - t0
    print(f"[{arch}] {report.steps_run} steps in {dt:.1f}s "
          f"loss {_loss_span(report)} "
          f"(microbatch={pipe.plan.microbatch}, "
          f"accum={pipe.plan.microbatches_for_epoch(pipe.loader.state.epoch)}x, "
          f"resumed_from={report.resumed_from})")
    for step, m in report.eval_history:
        print(f"  eval@{step}: {_fmt_metrics(m)}")
    state, _ = restore_checkpoint(ckpt_dir, pipe.init_state())
    final = pipe.evaluate(pipe.apply_plan(state))
    print(f"[{arch}] final held-out: {_fmt_metrics(final)}")
    return report


def _fmt_metrics(m: dict) -> str:
    return " ".join(f"{k}={v:.4f}" for k, v in sorted(m.items()))


def _loss_span(report) -> str:
    """'first -> last' loss, robust to a resume at max_steps (no new
    steps run -> losses is empty)."""
    if not report.losses:
        return "n/a (already at max_steps)"
    return f"{report.losses[0]:.4f} -> {report.losses[-1]:.4f}"


def train_gcn(steps: int, ckpt_dir: str):
    from repro.core.graph import from_numpy
    from repro.models import gcn
    cfg = config_registry.get("gcn_cora").SMOKE
    rng = np.random.default_rng(0)
    n = 200
    src = rng.integers(0, n, 1600).astype(np.int32)
    dst = rng.integers(0, n, 1600).astype(np.int32)
    g = from_numpy(src, dst, n)
    x = jnp.asarray(rng.standard_normal((n, cfg.d_feat)).astype(np.float32))
    labels = jnp.asarray(rng.integers(0, cfg.n_classes, n).astype(np.int32))
    lmask = jnp.ones((n,), jnp.float32)
    params = gcn.init_params(cfg, jax.random.PRNGKey(0))
    opt = adam(1e-2)

    @jax.jit
    def train_step(state):
        loss, grads = jax.value_and_grad(
            lambda p: gcn.loss_fn(cfg, p, g, x, labels, lmask))(state["params"])
        p, o = opt.update(grads, state["opt"], state["params"])
        return {"params": p, "opt": o}, loss

    state0 = {"params": params, "opt": opt.init(params)}
    report = run_training(
        LoopConfig(ckpt_dir=ckpt_dir, ckpt_every=max(steps // 2, 1),
                   max_steps=steps, async_ckpt=False),
        state0, lambda s, _: train_step(s))
    print(f"[gcn] loss {_loss_span(report)}")
    return report


def train_recsys(arch: str, steps: int, ckpt_dir: str, batch: int = 256):
    from repro.models import recsys_models as rm
    mod = config_registry.get(arch)
    cfg = mod.SMOKE
    rng = np.random.default_rng(0)
    init = {"deepfm": rm.deepfm_init, "xdeepfm": rm.xdeepfm_init,
            "dlrm_rm2": rm.dlrm_init}[config_registry.canon(arch)]
    fwd = {"deepfm": rm.deepfm_forward, "xdeepfm": rm.xdeepfm_forward,
           "dlrm_rm2": rm.dlrm_forward}[config_registry.canon(arch)]
    params = init(cfg, jax.random.PRNGKey(0))
    opt = adam(1e-3)
    is_dlrm = config_registry.canon(arch) == "dlrm_rm2"

    @jax.jit
    def train_step(state, *args):
        *feats, labels = args

        def loss_fn(p):
            return rm.bce_loss(fwd(cfg, p, *feats), labels)

        loss, grads = jax.value_and_grad(loss_fn)(state["params"])
        p, o = opt.update(grads, state["opt"], state["params"])
        return {"params": p, "opt": o}, loss

    def step_fn(state, step):
        ids = jnp.asarray(rng.integers(0, cfg.vocab, (batch, cfg.n_sparse))
                          .astype(np.int32))
        labels = jnp.asarray(rng.integers(0, 2, batch).astype(np.float32))
        if is_dlrm:
            dense = jnp.asarray(rng.standard_normal((batch, cfg.n_dense))
                                .astype(np.float32))
            return train_step(state, dense, ids, labels)
        return train_step(state, ids, labels)

    state0 = {"params": params, "opt": opt.init(params)}
    report = run_training(
        LoopConfig(ckpt_dir=ckpt_dir, ckpt_every=max(steps // 2, 1),
                   max_steps=steps, async_ckpt=False), state0, step_fn)
    print(f"[{arch}] loss {_loss_span(report)}")
    return report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--target-batch", type=int, default=2048,
                    help="large-batch target (accumulated microbatches)")
    ap.add_argument("--microbatch", type=int, default=512,
                    help="microbatch size; 0 = derive from HBM headroom")
    ap.add_argument("--edges", type=int, default=4000)
    ap.add_argument("--embed-dim", type=int, default=32)
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--eval-every", type=int, default=0,
                    help="held-out streaming-eval cadence in steps; "
                         "0 = final eval only")
    ap.add_argument("--eval-k", type=int, default=20)
    args = ap.parse_args()
    if args.arch in PIPELINE_ARCHS:
        train_gnnrecsys(args.arch, args.steps, f"{args.ckpt_dir}/{args.arch}",
                        target_batch=args.target_batch,
                        microbatch=args.microbatch or None,
                        edges=args.edges, embed_dim=args.embed_dim,
                        layers=args.layers,
                        eval_every=args.eval_every or None,
                        eval_k=args.eval_k)
        return
    arch = config_registry.canon(args.arch)
    if arch == "gcn_cora":
        train_gcn(args.steps, f"{args.ckpt_dir}/{arch}")
    elif arch in ("deepfm", "xdeepfm", "dlrm_rm2"):
        train_recsys(arch, args.steps, f"{args.ckpt_dir}/{arch}")
    else:
        raise SystemExit(
            f"CPU trainer for {arch!r} not wired; pipeline archs: "
            f"{', '.join(PIPELINE_ARCHS)}; also gcn-cora, deepfm, xdeepfm, "
            f"dlrm_rm2 (LM archs run via the dry-run)")


if __name__ == "__main__":
    main()
