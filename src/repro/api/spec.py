"""ExperimentSpec — the one declarative description of a run.

The paper's thesis (§5-§7) is that batch size, tensor placement, and
model depth must be co-tuned; before this module those knobs lived on
three disconnected surfaces (``repro.configs`` registry entries,
``PipelineConfig``/``LoopConfig`` dataclasses, ad-hoc argparse flags).
``ExperimentSpec`` is the single source of truth: nine typed sections
(model / data / plan / mesh / memory / compression / loop / eval /
serve) plus the training hyperparameters,
with an exact ``to_dict``/``from_dict``/JSON round-trip and dotted-path
overrides so a CLI flag, a preset, and a spec file all converge on the
same object.  ``repro.api.build(spec)`` turns it into a ``Run``.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Mapping


@dataclasses.dataclass(frozen=True)
class ModelCfg:
    """Which architecture, how wide, how deep (paper Table 3 axes).
    ``hadamard`` picks NGCF's Hadamard-message route: 'auto' (fused
    everywhere except the ring dispatch), 'fused' (the no-[E, D]
    gather-multiply-aggregate kernel), 'composed' (the legacy edge
    SDDMM + edge-aggregation pair).  Non-NGCF models ignore it."""
    arch: str = "lightgcn"           # repro.pipeline.registry key
    embed_dim: int = 32
    n_layers: int = 2
    hadamard: str = "auto"           # 'auto' | 'fused' | 'composed'

    def __post_init__(self):
        if self.hadamard not in ("auto", "fused", "composed"):
            raise ValueError(f"model.hadamard must be 'auto', 'fused' or "
                             f"'composed', got {self.hadamard!r}")


@dataclasses.dataclass(frozen=True)
class DataCfg:
    """Where interactions come from — one protocol over every source
    (``repro.api.data.DATA_SOURCES``): 'synth' scales a named paper
    dataset's statistics, 'bipartite' generates explicit sizes,
    'kronecker' expands a scaled base graph (paper's m-x25 method)."""
    source: str = "synth"            # registered data-source name
    dataset: str = "movielens-10m"   # stats name (synth / kronecker)
    edges: int = 4000                # target edge count (pre-expansion)
    n_users: int | None = None       # explicit sizes ('bipartite')
    n_items: int | None = None
    expand_factor: int = 1           # kronecker edge multiplier
    test_frac: float = 0.1           # held-out split; 0 -> no holdout
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class PlanCfg:
    """Placement + batching knobs consumed by ``pipeline.plan``."""
    hbm_budget: int | None = None    # planner budget override (bytes)
    target_batch: int = 2048         # §7.1 large-batch target
    microbatch: int | None = None    # None -> derived from HBM headroom
    base_batch: int = 256            # LR-scaling reference batch
    warmup_epochs: int = 2           # warm-up batch = target/10 epochs
    lr_scaling: str = "linear"       # 'linear' | 'sqrt'
    impl: str | None = None          # kernel dispatch override


@dataclasses.dataclass(frozen=True)
class MeshCfg:
    """Sharded execution (``pipeline.shard.ShardPlan``): mesh shape and
    axis names, SpMM dispatch, and the banded-ring knob.  The default
    ``shape=(1,)`` is the inert single-device plan — bit-identical to
    the unsharded pipeline (pinned by tests/test_api.py)."""
    shape: tuple[int, ...] = (1,)
    axes: tuple[str, ...] | None = None  # None -> auto axis names
    spmm: str | None = None          # None (auto: ring when P>1) | 'ring'
    ring_steps: int | None = None    # banded ring: visit n_steps < P owners

    def __post_init__(self):
        object.__setattr__(self, "shape",
                           tuple(int(s) for s in self.shape))
        if self.axes is not None:
            object.__setattr__(self, "axes",
                               tuple(str(a) for a in self.axes))
        if self.ring_steps is not None and self.ring_steps < 1:
            raise ValueError(f"mesh.ring_steps must be >= 1 (or null for "
                             f"the full ring), got {self.ring_steps}")


@dataclasses.dataclass(frozen=True)
class MemoryCfg:
    """Memory-tier subsystem (``repro.memory``): which registered
    ``TierTopology`` the run models, which placement policy assigns
    tensors to tiers, per-tier capacity overrides, and name->tier pins.
    The default reproduces the pre-redesign TPU planner bit for bit;
    the paper's §5 Memory-Mode-vs-AppDirect comparison is a one-line
    change of ``topology``.  Exact JSON round-trip like ``MeshCfg``."""
    topology: str = "tpu-hbm-host"   # repro.memory.topology_names()
    policy: str = "greedy"           # repro.memory.policy_names()
    capacity: dict | None = None     # tier name -> bytes override
    pins: dict | None = None         # tensor (sub)name -> tier name
    #                                  (e.g. {"params['item_embed']": "slow"})

    def __post_init__(self):
        if self.capacity is not None:
            object.__setattr__(self, "capacity",
                               {str(k): int(v)
                                for k, v in self.capacity.items()})
        if self.pins is not None:
            object.__setattr__(self, "pins",
                               {str(k): str(v)
                                for k, v in self.pins.items()})


@dataclasses.dataclass(frozen=True)
class CompressionCfg:
    """Byte compression on the slow links (``repro.optim.compression``):
    the gradient combine (``grads``: int8 stochastic psum or top-k
    all-gather, with per-participant error feedback carried in
    ``state["comp"]``), capacity-tier embedding-table storage
    (``embed_store='int8'``: ~1/4 bytes, fp32 dequant-on-gather, and
    the planner prices the quantized footprint), and the ring-SpMM
    payload rotation (``ring='int8'``).  The default is the identity:
    no compressor is built and training stays bit-identical to the
    pre-compression pipeline (pinned by tests/test_compression.py)."""
    grads: str = "none"              # 'none' | 'int8' | 'topk'
    frac: float = 0.01               # top-k kept fraction of each tensor
    error_feedback: bool = True      # carry compression residuals
    embed_store: str = "fp32"        # 'fp32' | 'int8' slow-tier tables
    ring: str = "none"               # 'none' | 'int8' ring payload

    def __post_init__(self):
        if self.grads not in ("none", "int8", "topk"):
            raise ValueError(f"compression.grads must be 'none', 'int8' "
                             f"or 'topk', got {self.grads!r}")
        if not 0.0 < float(self.frac) <= 1.0:
            raise ValueError(f"compression.frac must be in (0, 1], "
                             f"got {self.frac}")
        if self.embed_store not in ("fp32", "int8"):
            raise ValueError(f"compression.embed_store must be 'fp32' or "
                             f"'int8', got {self.embed_store!r}")
        if self.ring not in ("none", "int8"):
            raise ValueError(f"compression.ring must be 'none' or 'int8', "
                             f"got {self.ring!r}")
        object.__setattr__(self, "frac", float(self.frac))
        object.__setattr__(self, "error_feedback", bool(self.error_feedback))


@dataclasses.dataclass(frozen=True)
class LoopCfg:
    """Fault-tolerant-loop knobs consumed by ``runtime.loop``."""
    steps: int = 100
    ckpt_dir: str | None = None      # None -> in-memory run (no resume)
    ckpt_every: int | None = None    # None -> max(steps // 2, 1)
    eval_every: int | None = None    # held-out eval cadence; None = off
    step_deadline_s: float | None = None
    max_strays: int = 3
    async_ckpt: bool = False


@dataclasses.dataclass(frozen=True)
class EvalCfg:
    """Streaming top-K evaluation/serving shape (``repro.eval``)."""
    k: int = 20
    user_batch: int | None = None    # None -> derived from HBM headroom
    item_block: int = 1024


@dataclasses.dataclass(frozen=True)
class ServeCfg:
    """Serving hot-path knobs (``eval.Recommender`` /
    ``serving.RecommenderService``): the hot-row cache budget in front
    of host-demoted embedding tables (device-resident LFU slots, priced
    against the fast tier by ``pipeline.plan.serving_profiles``), the
    fused gather+score+top-K kernel routing, the block-pruned ANN index
    (``serving.ann.AnnIndex``; ``keep_frac`` is the surviving-block
    fraction — 1.0 scans everything and is bit-identical to the exact
    streamed sweep), and the request-coalescing queue's two dispatch
    triggers.  Defaults are the identity: no cache, auto-fused, no ANN
    pruning — bit-identical results either way (pinned by
    tests/test_serving.py)."""
    cache_rows: int = 0              # device-resident hot rows; 0 = off
    fused: bool | None = None        # None = auto (device-resident items)
    ann: bool = False                # block-pruned approximate retrieval
    keep_frac: float = 1.0           # surviving block fraction, (0, 1]
    queue_max_batch: int = 64        # coalescing bound (pow2 bucket cap)
    queue_max_wait_us: int = 1_000   # oldest-request dispatch deadline

    def __post_init__(self):
        if int(self.cache_rows) < 0:
            raise ValueError(f"serve.cache_rows must be >= 0, "
                             f"got {self.cache_rows}")
        object.__setattr__(self, "cache_rows", int(self.cache_rows))
        if self.fused is not None:
            object.__setattr__(self, "fused", bool(self.fused))
        object.__setattr__(self, "ann", bool(self.ann))
        kf = float(self.keep_frac)
        if not 0.0 < kf <= 1.0:
            raise ValueError(f"serve.keep_frac must be in (0, 1], "
                             f"got {self.keep_frac}")
        object.__setattr__(self, "keep_frac", kf)
        if int(self.queue_max_batch) < 1:
            raise ValueError(f"serve.queue_max_batch must be >= 1, "
                             f"got {self.queue_max_batch}")
        object.__setattr__(self, "queue_max_batch",
                           int(self.queue_max_batch))
        if int(self.queue_max_wait_us) < 0:
            raise ValueError(f"serve.queue_max_wait_us must be >= 0, "
                             f"got {self.queue_max_wait_us}")
        object.__setattr__(self, "queue_max_wait_us",
                           int(self.queue_max_wait_us))


@dataclasses.dataclass(frozen=True)
class ExperimentSpec:
    """The whole experiment, declaratively."""
    name: str = "experiment"
    model: ModelCfg = dataclasses.field(default_factory=ModelCfg)
    data: DataCfg = dataclasses.field(default_factory=DataCfg)
    plan: PlanCfg = dataclasses.field(default_factory=PlanCfg)
    mesh: MeshCfg = dataclasses.field(default_factory=MeshCfg)
    memory: MemoryCfg = dataclasses.field(default_factory=MemoryCfg)
    compression: CompressionCfg = dataclasses.field(
        default_factory=CompressionCfg)
    loop: LoopCfg = dataclasses.field(default_factory=LoopCfg)
    eval: EvalCfg = dataclasses.field(default_factory=EvalCfg)
    serve: ServeCfg = dataclasses.field(default_factory=ServeCfg)
    optimizer: str = "adam"          # 'adam' | 'sgd'
    base_lr: float = 1e-3
    l2: float = 1e-4
    seed: int = 0

    # ------------------------------------------------------- serialization
    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    def to_json(self, indent: int = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent)

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json() + "\n")

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "ExperimentSpec":
        return _spec_from_dict(cls, d, where="spec")

    @classmethod
    def from_json(cls, s: str) -> "ExperimentSpec":
        return cls.from_dict(json.loads(s))

    @classmethod
    def from_file(cls, path: str) -> "ExperimentSpec":
        with open(path) as f:
            return cls.from_dict(json.load(f))

    # ------------------------------------------------------- overrides
    def override(self, overrides: Mapping[str, Any] | None = None,
                 **kw: Any) -> "ExperimentSpec":
        """New spec with dotted-path fields replaced:
        ``spec.override({"model.embed_dim": 64, "plan.microbatch": 128})``.
        Top-level fields work too (``optimizer="sgd"`` or
        ``{"optimizer": "sgd"}``).  Unknown paths raise KeyError."""
        merged = {**(overrides or {}), **kw}
        spec = self
        for path, value in merged.items():
            spec = _replace_path(spec, path.split("."), value)
        return spec

    # ------------------------------------------------------- pipeline view
    def to_pipeline_config(self):
        """The engine-facing projection of this spec (the legacy
        ``PipelineConfig`` the pipeline layer still consumes)."""
        from repro.pipeline import PipelineConfig
        return PipelineConfig(
            arch=self.model.arch, embed_dim=self.model.embed_dim,
            n_layers=self.model.n_layers, hadamard=self.model.hadamard,
            optimizer=self.optimizer,
            base_lr=self.base_lr, base_batch=self.plan.base_batch,
            target_batch=self.plan.target_batch,
            microbatch=self.plan.microbatch,
            warmup_epochs=self.plan.warmup_epochs,
            lr_scaling=self.plan.lr_scaling, l2=self.l2,
            hbm_budget=self.plan.hbm_budget, impl=self.plan.impl,
            seed=self.seed, mesh_shape=self.mesh.shape,
            mesh_axes=self.mesh.axes, spmm=self.mesh.spmm,
            ring_steps=self.mesh.ring_steps,
            memory_topology=self.memory.topology,
            memory_policy=self.memory.policy,
            memory_capacity=self.memory.capacity,
            memory_pins=self.memory.pins,
            grad_compression=self.compression.grads,
            compression_frac=self.compression.frac,
            compression_ef=self.compression.error_feedback,
            embed_store=self.compression.embed_store,
            ring_compression=self.compression.ring,
            eval_k=self.eval.k,
            eval_user_batch=self.eval.user_batch,
            eval_item_block=self.eval.item_block)


_SECTIONS = {"model": ModelCfg, "data": DataCfg, "plan": PlanCfg,
             "mesh": MeshCfg, "memory": MemoryCfg,
             "compression": CompressionCfg, "loop": LoopCfg,
             "eval": EvalCfg, "serve": ServeCfg}


def _fields(cls) -> dict:
    return {f.name: f for f in dataclasses.fields(cls)}


def _spec_from_dict(cls, d: Mapping[str, Any], where: str) -> ExperimentSpec:
    known = _fields(cls)
    unknown = set(d) - set(known)
    if unknown:
        raise ValueError(f"unknown {where} keys {sorted(unknown)}; "
                         f"known: {sorted(known)}")
    kw: dict[str, Any] = {}
    for name, value in d.items():
        section = _SECTIONS.get(name)
        if section is not None:
            if not isinstance(value, Mapping):
                raise ValueError(f"{where}.{name} must be a mapping")
            sub_known = _fields(section)
            sub_unknown = set(value) - set(sub_known)
            if sub_unknown:
                raise ValueError(
                    f"unknown {where}.{name} keys {sorted(sub_unknown)}; "
                    f"known: {sorted(sub_known)}")
            kw[name] = section(**value)
        else:
            kw[name] = value
    return cls(**kw)


def _replace_path(obj, path: list[str], value):
    head = path[0]
    if not any(f.name == head for f in dataclasses.fields(obj)):
        raise KeyError(f"unknown spec field {'.'.join(path)!r}")
    if len(path) == 1:
        return dataclasses.replace(obj, **{head: value})
    return dataclasses.replace(
        obj, **{head: _replace_path(getattr(obj, head), path[1:], value)})
