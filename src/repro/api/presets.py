"""Preset registry — named ExperimentSpecs.

Absorbs the ``repro.configs`` module-per-arch FULL/SMOKE entries for
the GNNRecSys family, so ``Experiment.from_preset("lightgcn-smoke")``
resolves to the same shapes ``repro.configs.get("lightgcn").SMOKE``
declares (tests/test_api.py pins that parity — the registry reads the
config modules at import, it cannot drift).  ``register_preset`` adds
project-local presets; a preset is stored as a zero-arg factory so
registration order never freezes a stale spec.
"""
from __future__ import annotations

from typing import Callable

from repro import configs as _configs
from repro.api.spec import (DataCfg, EvalCfg, ExperimentSpec, LoopCfg,
                            ModelCfg, PlanCfg)

_PRESETS: dict[str, Callable[[], ExperimentSpec]] = {}


def register_preset(name: str,
                    factory: Callable[[], ExperimentSpec]) -> None:
    _PRESETS[name] = factory


def preset_names() -> list[str]:
    return sorted(_PRESETS)


def get_preset(name: str) -> ExperimentSpec:
    if name not in _PRESETS:
        raise KeyError(f"unknown preset {name!r}; known: {preset_names()}")
    return _PRESETS[name]()


# ------------------------------------------------- repro.configs absorption
def _spec_from_config(arch: str, cfg, optimizer: str,
                      smoke: bool) -> ExperimentSpec:
    """One config-registry entry -> a runnable spec.  FULL keeps the
    paper's §7.1 schedule (1K warm-up toward the 150K target); SMOKE is
    a no-warm-up micro run sized for CPU tests."""
    return ExperimentSpec(
        name=cfg.name,
        model=ModelCfg(arch=arch, embed_dim=cfg.embed_dim,
                       n_layers=cfg.n_layers),
        data=DataCfg(source="bipartite", n_users=cfg.n_users,
                     n_items=cfg.n_items, edges=cfg.n_edges),
        plan=PlanCfg(target_batch=cfg.bpr_batch,
                     base_batch=cfg.bpr_batch if smoke else 1024,
                     microbatch=cfg.bpr_batch if smoke else None,
                     warmup_epochs=0 if smoke else 2),
        loop=LoopCfg(steps=20 if smoke else 1000),
        eval=EvalCfg(k=20),
        optimizer=optimizer,
    )


def _register_config_presets() -> None:
    for arch_id in _configs.ARCH_IDS:
        mod = _configs.get(arch_id)
        if getattr(mod, "FAMILY", None) != "gnnrecsys":
            continue
        for variant, smoke in (("full", False), ("smoke", True)):
            cfg = getattr(mod, variant.upper())
            register_preset(
                f"{arch_id}-{variant}",
                lambda a=arch_id, c=cfg, o=mod.OPTIMIZER, s=smoke:
                    _spec_from_config(a, c, o, s))


_register_config_presets()


# ------------------------------------------------- project presets
def _quickstart() -> ExperimentSpec:
    """The README/examples run: paper recipe (warm-up batch + linear LR
    scaling, plain SGD) on a movielens-statistics graph, CPU-sized."""
    return ExperimentSpec(
        name="quickstart",
        model=ModelCfg(arch="lightgcn", embed_dim=32, n_layers=2),
        data=DataCfg(source="synth", dataset="movielens-10m", edges=8000),
        plan=PlanCfg(target_batch=1024, base_batch=64, microbatch=256,
                     warmup_epochs=2, lr_scaling="linear"),
        loop=LoopCfg(steps=120),
        eval=EvalCfg(k=20),
        optimizer="sgd", base_lr=0.02,
    )


register_preset("quickstart", _quickstart)
