"""Experiment — the user-facing entry point of ``repro.api``.

    from repro.api import Experiment

    run = Experiment.from_preset("lightgcn-smoke").build()
    run.fit()
    print(run.evaluate())
    ids, scores = run.recommend([0, 1, 2])

An Experiment is an immutable wrapper around one ``ExperimentSpec``
with the constructors (preset / dict / JSON file) and the dotted-path
``override`` hook; ``build()`` materializes it into a live ``Run``.
"""
from __future__ import annotations

from typing import Any, Mapping

from repro.api.presets import get_preset
from repro.api.run import Run, build
from repro.api.spec import ExperimentSpec


class Experiment:
    def __init__(self, spec: ExperimentSpec):
        self.spec = spec

    # ------------------------------------------------------- constructors
    @classmethod
    def from_preset(cls, name: str,
                    overrides: Mapping[str, Any] | None = None,
                    **kw: Any) -> "Experiment":
        return cls(get_preset(name).override(overrides, **kw))

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "Experiment":
        return cls(ExperimentSpec.from_dict(d))

    @classmethod
    def from_file(cls, path: str) -> "Experiment":
        return cls(ExperimentSpec.from_file(path))

    # ------------------------------------------------------- spec surface
    def override(self, overrides: Mapping[str, Any] | None = None,
                 **kw: Any) -> "Experiment":
        return Experiment(self.spec.override(overrides, **kw))

    def to_dict(self) -> dict:
        return self.spec.to_dict()

    def save(self, path: str) -> None:
        self.spec.save(path)

    # ------------------------------------------------------- execution
    def build(self, train=None, holdout=None) -> Run:
        return build(self.spec, train=train, holdout=holdout)

    def run(self, steps: int | None = None) -> Run:
        """build + fit in one call."""
        r = self.build()
        r.fit(steps=steps)
        return r

    def __repr__(self) -> str:
        s = self.spec
        return (f"Experiment({s.name!r}, arch={s.model.arch!r}, "
                f"data={s.data.source!r}:{s.data.dataset!r}, "
                f"target_batch={s.plan.target_batch})")
