"""repro.api — the unified Experiment API.

One declarative ``ExperimentSpec`` (model / data / plan / loop / eval)
drives the whole stack: ``build(spec)`` returns a ``Run`` exposing
``fit()`` (fault-tolerant loop), ``evaluate()`` (streaming top-K),
``recommend()`` (planner-placed serving facade), and ``resume()``.

  Experiment  — preset / dict / JSON-file constructors + overrides;
  ExperimentSpec, ModelCfg, DataCfg, PlanCfg, MeshCfg, MemoryCfg,
      CompressionCfg, LoopCfg, EvalCfg, ServeCfg — the typed,
      serializable sections;
  build / Run — spec -> live handle;
  get_preset / register_preset / preset_names — the preset registry
      (absorbs repro.configs FULL/SMOKE for the GNNRecSys family);
  load_data / register_data_source — data sources behind one protocol.
"""
from repro.api.data import (DATA_SOURCES, load_data, register_data_source)
from repro.api.experiment import Experiment
from repro.api.presets import get_preset, preset_names, register_preset
from repro.api.run import Run, build
from repro.api.spec import (CompressionCfg, DataCfg, EvalCfg,
                            ExperimentSpec, LoopCfg, MemoryCfg, MeshCfg,
                            ModelCfg, PlanCfg, ServeCfg)

__all__ = [
    "Experiment", "ExperimentSpec", "ModelCfg", "DataCfg", "PlanCfg",
    "MeshCfg", "MemoryCfg", "CompressionCfg", "LoopCfg", "EvalCfg",
    "ServeCfg", "Run", "build",
    "get_preset", "register_preset", "preset_names", "load_data",
    "register_data_source", "DATA_SOURCES",
]
