"""Data sources behind one protocol.

A data source is ``fn(cfg: DataCfg) -> InteractionData``; ``load_data``
applies the spec's held-out split on top.  The built-ins cover the
repo's three generators (synthetic paper-statistics graphs, explicit
bipartite sizes, Kronecker expansion); ``register_data_source`` lets a
new scenario plug in a loader without touching the engine — the spec
just names it.
"""
from __future__ import annotations

from typing import Callable

from repro.api.spec import DataCfg
from repro.data import synth
from repro.data.synth import InteractionData

DataSource = Callable[[DataCfg], InteractionData]

DATA_SOURCES: dict[str, DataSource] = {}


def register_data_source(name: str, fn: DataSource) -> None:
    DATA_SOURCES[name] = fn


def _synth(cfg: DataCfg) -> InteractionData:
    return synth.scaled(cfg.dataset, cfg.edges, seed=cfg.seed)


def _bipartite(cfg: DataCfg) -> InteractionData:
    if cfg.n_users is None or cfg.n_items is None:
        raise ValueError("source='bipartite' needs DataCfg.n_users and "
                         "DataCfg.n_items")
    return synth.generate_bipartite(cfg.n_users, cfg.n_items, cfg.edges,
                                    seed=cfg.seed)


def _kronecker(cfg: DataCfg) -> InteractionData:
    from repro.data.kronecker import expand_by_factor
    base = synth.scaled(cfg.dataset, cfg.edges, seed=cfg.seed)
    if cfg.expand_factor <= 1:
        return base
    return expand_by_factor(base, cfg.expand_factor, seed=cfg.seed)


register_data_source("synth", _synth)
register_data_source("bipartite", _bipartite)
register_data_source("kronecker", _kronecker)


def load_data(cfg: DataCfg) -> tuple[InteractionData, InteractionData | None]:
    """(train, holdout) for a DataCfg.  ``test_frac=0`` means the whole
    graph trains and there is no holdout (e.g. timing-only runs)."""
    if cfg.source not in DATA_SOURCES:
        raise KeyError(f"unknown data source {cfg.source!r}; known: "
                       f"{sorted(DATA_SOURCES)}")
    data = DATA_SOURCES[cfg.source](cfg)
    if cfg.test_frac <= 0.0:
        return data, None
    return synth.train_test_split(data, cfg.test_frac, seed=cfg.seed)
