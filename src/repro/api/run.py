"""Run — the live handle ``build(spec)`` returns.

One object that drives the whole existing stack from a spec: data
loading (``repro.api.data``), planner + engine (``repro.pipeline``),
the fault-tolerant loop (``repro.runtime``), streaming evaluation and
the serving facade (``repro.eval``).  The spec stays the single source
of truth; the Run only adds position (current state + step).
"""
from __future__ import annotations

import numpy as np

from repro.api.data import load_data
from repro.api.spec import ExperimentSpec
from repro.checkpoint import restore_checkpoint
from repro.data.synth import InteractionData
from repro.pipeline import build_pipeline
from repro.runtime.loop import LoopConfig, LoopReport, run_training


class Run:
    """One experiment's live state: pipeline + current (state, step)."""

    def __init__(self, spec: ExperimentSpec, train: InteractionData,
                 holdout: InteractionData | None = None):
        self.spec = spec
        self.train_data = train
        self.holdout = holdout
        self.pipeline = build_pipeline(spec.to_pipeline_config(), train,
                                       holdout=holdout)
        self.state = self.pipeline.init_state()
        self.step_count = 0
        self.report: LoopReport | None = None
        self._recommender = None

    # ------------------------------------------------------------ training
    def step(self) -> float:
        """Advance one pipeline step (one accumulated target batch),
        under the pipeline's sharding context (the loop does the same
        for ``fit()``-driven steps)."""
        with self.pipeline.step_context():
            self.state, loss = self.pipeline.step_fn(self.state,
                                                     self.step_count)
        self.step_count += 1
        self._recommender = None
        return float(loss)

    def fit(self, steps: int | None = None,
            ckpt_dir: str | None = None) -> LoopReport:
        """Run ``steps`` more steps (default ``spec.loop.steps``) under
        the fault-tolerant loop.  With a checkpoint directory (argument
        or ``spec.loop.ckpt_dir``) the loop checkpoints periodically and
        resumes from the latest committed step; without one it runs
        in-memory.  Periodic held-out eval fires every
        ``spec.loop.eval_every`` steps when the run has a holdout."""
        lc = self.spec.loop
        steps = lc.steps if steps is None else int(steps)
        ckpt_dir = ckpt_dir if ckpt_dir is not None else lc.ckpt_dir
        max_steps = self.step_count + steps
        cfg = LoopConfig(
            ckpt_dir=ckpt_dir,
            ckpt_every=lc.ckpt_every or max(steps // 2, 1),
            max_steps=max_steps, step_deadline_s=lc.step_deadline_s,
            max_strays=lc.max_strays, async_ckpt=lc.async_ckpt,
            eval_every=lc.eval_every)
        self.report = run_training(
            cfg, self.state, self.pipeline.step_fn,
            on_relayout=self.pipeline.on_relayout,
            on_restore=self.pipeline.apply_plan,
            eval_fn=self.pipeline.eval_fn,
            start_step=self.step_count,
            step_context=self.pipeline.step_context)
        self.state = self.report.final_state
        self.step_count = max_steps
        self._recommender = None
        return self.report

    def resume(self, ckpt_dir: str) -> "Run":
        """Position this run at the latest committed checkpoint: state
        restored onto its planned tiers, loader seeked so the next batch
        matches an uninterrupted run's (schedule-exact resume)."""
        state, step = restore_checkpoint(ckpt_dir, self.pipeline.init_state())
        self.state = self.pipeline.apply_plan(state)
        self.pipeline.seek(step)
        self.step_count = step
        self._recommender = None
        return self

    # ------------------------------------------------------------ schedule
    def steps_for_epochs(self, n_epochs: int) -> int:
        return self.pipeline.steps_for_epochs(n_epochs)

    @property
    def params(self):
        return self.state["params"]

    # ------------------------------------------------------------ eval
    def embeddings(self):
        """Final (user, item) embeddings at the current state."""
        return self.pipeline.embeddings(self.state)

    def evaluate(self) -> dict:
        """One held-out streaming-eval sweep (recall/NDCG@k + MRR)."""
        return self.pipeline.evaluate(self.state)

    # ------------------------------------------------------------ serving
    def recommender(self, **kw):
        """Serving facade over the current state's embeddings (planner-
        placed snapshot, train items as the seen-exclusion set)."""
        from repro.eval import Recommender
        kw.setdefault("k", self.spec.eval.k)
        kw.setdefault("item_block", self.spec.eval.item_block)
        kw.setdefault("cache_rows", self.spec.serve.cache_rows)
        kw.setdefault("fused", self.spec.serve.fused)
        kw.setdefault("ann", self.spec.serve.ann)
        kw.setdefault("keep_frac", self.spec.serve.keep_frac)
        return Recommender.from_pipeline(self.pipeline, self.state, **kw)

    def service(self, *, clock=None, **kw):
        """Queue-fronted serving: a ``RecommenderService`` wiring the
        coalescing queue (``spec.serve.queue_*`` knobs) → the ANN index
        (when ``spec.serve.ann``) → the placed ``Recommender``."""
        from repro.serving import RecommenderService
        return RecommenderService(
            self.recommender(**kw),
            max_batch=self.spec.serve.queue_max_batch,
            max_wait_us=self.spec.serve.queue_max_wait_us,
            clock=clock)

    def recommend(self, user_ids, k: int | None = None,
                  exclude_seen: bool = True):
        """Batched top-K (ids, scores); snapshot cached until the next
        training step invalidates it."""
        if self._recommender is None:
            self._recommender = self.recommender()
        return self._recommender.recommend(np.asarray(user_ids), k=k,
                                           exclude_seen=exclude_seen)

    def describe(self) -> str:
        d = self.train_data
        lines = [f"Run[{self.spec.name}] arch={self.spec.model.arch} "
                 f"data={self.spec.data.source}:{self.spec.data.dataset} "
                 f"({d.n_users}U x {d.n_items}I, {d.n_edges} train edges)"]
        if self.pipeline.shard is not None:
            lines.append("  " + self.pipeline.shard.describe())
        lines.append(self.pipeline.plan.describe())
        return "\n".join(lines)


def build(spec: ExperimentSpec, train: InteractionData | None = None,
          holdout: InteractionData | None = None) -> Run:
    """spec -> Run.  Data comes from ``spec.data`` unless an explicit
    train (and optional holdout) InteractionData is passed in."""
    if train is None:
        train, holdout = load_data(spec.data)
    return Run(spec, train, holdout=holdout)
