"""Ratchet baseline for lint findings (``tools/lint_baseline.json``).

Pre-existing violations are *recorded*, not grandfathered forever: the
baseline stores each finding's line-number-free fingerprint
(``Finding.key()`` = rule :: path :: stripped source line) with a count,
so

  * a NEW violation (key absent, or count above baseline) fails CI;
  * FIXING a violation leaves a stale baseline entry, which also fails
    — with instructions to shrink the baseline (``--update``) — so the
    recorded debt only ever ratchets downward;
  * unrelated edits (line shifts, renames elsewhere) change nothing.

The file is committed JSON: sorted keys, counts, and a header noting
the ratchet contract, regenerated only via ``tools/lint.py --update``.
"""
from __future__ import annotations

import collections
import json
import pathlib

__all__ = ["load_baseline", "save_baseline", "compare"]

_HEADER = ("ratcheted lint baseline: new findings fail CI; fixed "
           "findings must be removed via `python tools/lint.py "
           "--update`")


def _counts(findings) -> dict[str, int]:
    return dict(collections.Counter(f.key() for f in findings))


def load_baseline(path: "pathlib.Path | str") -> dict[str, int]:
    path = pathlib.Path(path)
    if not path.exists():
        return {}
    data = json.loads(path.read_text())
    return {str(k): int(v) for k, v in data.get("findings", {}).items()}


def save_baseline(path: "pathlib.Path | str", findings) -> int:
    """Write the baseline for ``findings``; returns the entry count."""
    counts = _counts(findings)
    payload = {"_comment": _HEADER,
               "findings": dict(sorted(counts.items()))}
    pathlib.Path(path).write_text(json.dumps(payload, indent=2) + "\n")
    return sum(counts.values())


def compare(findings, baseline: dict[str, int]):
    """(new, stale): ``new`` are current findings beyond the baselined
    count for their key (the ones that must be fixed); ``stale`` are
    baselined keys whose violations have (partly) disappeared, listed as
    ``(key, recorded, remaining)`` (the ratchet to shrink)."""
    current = _counts(findings)
    remaining = dict(baseline)
    new = []
    for f in sorted(findings, key=lambda x: (x.path, x.line, x.rule)):
        k = f.key()
        if remaining.get(k, 0) > 0:
            remaining[k] -= 1
        else:
            new.append(f)
    stale = [(k, baseline[k], current.get(k, 0))
             for k in sorted(baseline)
             if current.get(k, 0) < baseline[k]]
    return new, stale
