"""repro.analysis — static analysis for the repro codebase.

Two layers (paper motivation: the §5-§6 placement and write-policy wins
evaporate from a single accidental host sync or dtype widening, so we
catch those bug classes *before* a benchmark regresses):

  Layer 1 — AST lint, no JAX import needed.
    ``rules``       per-module JAX-aware rules (tracer safety, PRNG
                    hygiene, f64 hazards, Pallas kernel rules);
    ``repo_rules``  cross-file registry-completeness rules (kernel
                    oracles, spec sections, topology snapshots);
    ``baseline``    the ratchet (tools/lint_baseline.json).

  Layer 2 — HLO invariant auditor (imports JAX; import it explicitly):
    ``repro.analysis.hlo_audit`` lowers the jitted train step and the
    serve path for representative presets and asserts on the lowered
    text — no f64, no host transfers, collectives present/absent
    exactly per MeshCfg/CompressionCfg, and a recompile-hazard count.

Driven by ``tools/lint.py`` (``make lint`` / ``make audit``); rule docs
in ``docs/ARCHITECTURE.md`` ("Static analysis").

This package intentionally does NOT import ``hlo_audit`` here: Layer 1
must stay importable (and fast) in environments and CI steps that never
touch JAX.
"""
from repro.analysis.baseline import compare, load_baseline, save_baseline
from repro.analysis.repo_rules import REPO_RULES, lint_repo
from repro.analysis.rules import RULES, Finding, lint_paths, lint_source

ALL_RULES = {**RULES, **REPO_RULES}

__all__ = ["Finding", "RULES", "REPO_RULES", "ALL_RULES", "lint_source",
           "lint_paths", "lint_repo", "load_baseline", "save_baseline",
           "compare"]
