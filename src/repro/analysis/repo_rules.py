"""Layer 1b — registry-completeness rules (cross-file, AST + JSON only).

PRs 1-7 grew several registries whose invariants were only enforced by
hand-written tests that new entries can silently bypass:

  * every ``kernels/ops.py`` dispatch entry needs a ``kernels/ref.py``
    oracle (the allclose ground truth) and coverage in
    ``tests/test_kernel_parity.py``;
  * every ``*Cfg`` dataclass in ``api/spec.py`` must be registered in
    ``_SECTIONS`` (or ``from_dict`` silently drops it) and exercised by
    a round-trip test somewhere under ``tests/``;
  * every registered ``TierTopology`` preset needs its golden arms
    (``<name>`` and ``<name>@int8``) in ``tools/plan_snapshots.json`` or
    ``tools/check_plan_snapshot.py`` has nothing to ratchet against.

These rules cross-check the *files* — no JAX import, no registry
execution — so adding a kernel without an oracle fails ``make lint``
before any benchmark can regress.

Rule catalogue:

  reg-kernel-oracle       ops.py dispatch def without a ``<name>_ref``
                          oracle in ref.py.
  reg-kernel-parity-test  ops.py dispatch def never referenced in
                          tests/test_kernel_parity.py.
  reg-spec-section        a ``*Cfg`` dataclass in api/spec.py missing
                          from the ``_SECTIONS`` table.
  reg-spec-roundtrip      a ``*Cfg`` dataclass never referenced by name
                          under tests/ (no round-trip coverage).
  reg-topology-snapshot   a registered topology preset without its
                          fp32 or @int8 golden arm in plan_snapshots.
"""
from __future__ import annotations

import ast
import json
import pathlib

from repro.analysis.rules import Finding

__all__ = ["REPO_RULES", "lint_repo"]

REPO_RULES = {
    "reg-kernel-oracle": "kernels/ops.py dispatch entry without a "
                         "kernels/ref.py oracle",
    "reg-kernel-parity-test": "kernels/ops.py dispatch entry not covered "
                              "by tests/test_kernel_parity.py",
    "reg-spec-section": "*Cfg dataclass in api/spec.py missing from "
                        "_SECTIONS",
    "reg-spec-roundtrip": "*Cfg dataclass with no test referencing it "
                          "under tests/",
    "reg-topology-snapshot": "registered TierTopology preset without its "
                             "golden plan-snapshot arm",
}

# registry surfaces, relative to the repo root
_OPS = "src/repro/kernels/ops.py"
_REF = "src/repro/kernels/ref.py"
_PARITY = "tests/test_kernel_parity.py"
_SPEC = "src/repro/api/spec.py"
_TOPOLOGY = "src/repro/memory/topology.py"
_SNAPSHOTS = "tools/plan_snapshots.json"
_TESTS_DIR = "tests"


def _parse(root: pathlib.Path, rel: str) -> ast.Module:
    return ast.parse((root / rel).read_text(), filename=rel)


def _top_level_defs(tree: ast.Module) -> dict[str, int]:
    return {n.name: n.lineno for n in tree.body
            if isinstance(n, ast.FunctionDef)}


def _finding(rule: str, rel: str, line: int, message: str) -> Finding:
    # registry findings fingerprint on the message, not a source line:
    # they describe a missing thing, so there is no offending line text
    return Finding(rule, rel, line, 0, message, message)


def _kernel_rules(root: pathlib.Path) -> list[Finding]:
    out: list[Finding] = []
    ops = _top_level_defs(_parse(root, _OPS))
    dispatch = {name: line for name, line in ops.items()
                if not name.startswith("_")}
    oracles = set(_top_level_defs(_parse(root, _REF)))
    parity_src = (root / _PARITY).read_text()
    for name, line in sorted(dispatch.items()):
        if f"{name}_ref" not in oracles:
            out.append(_finding(
                "reg-kernel-oracle", _OPS, line,
                f"dispatch `{name}` has no `{name}_ref` oracle in "
                f"{_REF}"))
        if name not in parity_src:
            out.append(_finding(
                "reg-kernel-parity-test", _OPS, line,
                f"dispatch `{name}` is never referenced in {_PARITY}"))
    return out


def _spec_rules(root: pathlib.Path) -> list[Finding]:
    out: list[Finding] = []
    tree = _parse(root, _SPEC)
    cfgs = {n.name: n.lineno for n in tree.body
            if isinstance(n, ast.ClassDef) and n.name.endswith("Cfg")}
    section_values: set[str] = set()
    for node in tree.body:
        if isinstance(node, ast.Assign) \
                and any(isinstance(t, ast.Name) and t.id == "_SECTIONS"
                        for t in node.targets) \
                and isinstance(node.value, ast.Dict):
            section_values = {v.id for v in node.value.values
                              if isinstance(v, ast.Name)}
    tests_src = "\n".join(p.read_text() for p in
                          sorted((root / _TESTS_DIR).glob("*.py")))
    for name, line in sorted(cfgs.items()):
        if name not in section_values:
            out.append(_finding(
                "reg-spec-section", _SPEC, line,
                f"`{name}` is not registered in _SECTIONS — from_dict "
                "will silently drop the section"))
        if name not in tests_src:
            out.append(_finding(
                "reg-spec-roundtrip", _SPEC, line,
                f"`{name}` is never referenced under {_TESTS_DIR}/ — "
                "no round-trip coverage"))
    return out


def _registered_topologies(tree: ast.Module) -> dict[str, int]:
    """Preset names from ``register_topology(TierTopology("<name>", ...)``
    call sites (string-literal first arguments only)."""
    names: dict[str, int] = {}
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        head = node.func
        if not (isinstance(head, ast.Name)
                and head.id == "register_topology") and not (
                isinstance(head, ast.Attribute)
                and head.attr == "register_topology"):
            continue
        for arg in node.args:
            if isinstance(arg, ast.Call) and arg.args \
                    and isinstance(arg.args[0], ast.Constant) \
                    and isinstance(arg.args[0].value, str):
                names[arg.args[0].value] = node.lineno
    return names


def _topology_rules(root: pathlib.Path) -> list[Finding]:
    out: list[Finding] = []
    topos = _registered_topologies(_parse(root, _TOPOLOGY))
    snap_path = root / _SNAPSHOTS
    keys = set(json.loads(snap_path.read_text())) if snap_path.exists() \
        else set()
    for name, line in sorted(topos.items()):
        for arm in (name, f"{name}@int8"):
            if arm not in keys:
                out.append(_finding(
                    "reg-topology-snapshot", _TOPOLOGY, line,
                    f"topology `{name}` has no `{arm}` golden arm in "
                    f"{_SNAPSHOTS} (run check_plan_snapshot.py "
                    "--update)"))
    return out


def lint_repo(root: "pathlib.Path | str") -> list[Finding]:
    """Run every registry-completeness rule against the repo at
    ``root``.  Surfaces that don't exist are skipped (the rules are
    repo-shape-specific by design)."""
    root = pathlib.Path(root)
    out: list[Finding] = []
    if (root / _OPS).exists() and (root / _REF).exists() \
            and (root / _PARITY).exists():
        out.extend(_kernel_rules(root))
    if (root / _SPEC).exists() and (root / _TESTS_DIR).is_dir():
        out.extend(_spec_rules(root))
    if (root / _TOPOLOGY).exists():
        out.extend(_topology_rules(root))
    return sorted(out, key=lambda x: (x.path, x.line, x.rule))
