"""Layer 2 — HLO invariant auditor (imports JAX; import explicitly).

The AST layer can't see what XLA actually emits, and the paper's whole
§5-§6 argument is about where bytes live and move — so this layer
lowers the jitted train step and the serve path for representative
presets and asserts on the lowered text itself:

  * **no f64 ops** anywhere in a hot-path lowering (a silent dtype
    widening doubles every byte the paper counts);
  * **no host transfers inside the step** (device→host custom calls /
    host memory spaces — MTrainS/RecNMP-style wins evaporate from one
    accidental sync);
  * **collectives present/absent exactly per MeshCfg/CompressionCfg**
    via the declarative ``FRAGMENTS`` table below — the one source of
    truth the former one-off string asserts in ``test_compression.py``
    and ``test_distributed.py`` now share;
  * a **recompile-hazard count**: the microbatch schedule must trace to
    ONE chunk shape (warm-up epochs change the accumulation factor,
    never the chunk shape), or every epoch boundary recompiles.

Pure functions over lowered text plus small drivers that build a run
from an ``ExperimentSpec`` — used by ``tools/lint.py --hlo``
(``make audit``) and by the test suite.
"""
from __future__ import annotations

import dataclasses
import math
import re

__all__ = ["HloExpectation", "COLLECTIVES", "FRAGMENTS", "expect",
           "expectation_for", "check_text", "assert_clean",
           "lower_train_step", "lower_serve", "recompile_hazard",
           "message_shape", "ngcf_message_fragment", "fusion_audit",
           "audit_spec", "smoke_audit"]

# every collective op name XLA can lower for this repo's programs; the
# single-device expectation is their total absence
COLLECTIVES = ("collective-permute", "all-reduce", "all-gather",
               "all-to-all", "reduce-scatter")

_F64_RE = re.compile(r"\b(f64|c128)\[")
# device→host movement markers: host-offload custom calls, placement
# annotations, and the host memory-space color in buffer annotations
_HOST_RES = (re.compile(r"MoveToHost|MoveToDevice"),
             re.compile(r"annotate_device_placement"),
             re.compile(r"S\(5\)"))


@dataclasses.dataclass(frozen=True)
class HloExpectation:
    """What a lowered program must (not) contain, by substring."""
    name: str
    contains: tuple[str, ...] = ()
    absent: tuple[str, ...] = ()

    def merged(self, other: "HloExpectation") -> "HloExpectation":
        contains = self.contains + tuple(
            c for c in other.contains if c not in self.contains)
        # a substring any fragment requires can't simultaneously be
        # forbidden: contains wins (int8 psum adds all-reduce to a
        # config whose base fragment forbids nothing it needs)
        absent = tuple(a for a in self.absent + other.absent
                       if a not in contains)
        absent = tuple(dict.fromkeys(absent))
        return HloExpectation(f"{self.name}+{other.name}",
                              tuple(c for c in contains
                                    if c not in absent), absent)


# ------------------------------------------------------------------ table
# The declarative expectation table: one named fragment per collective
# contract in the codebase.  Tests and the auditor compose these with
# ``expect(...)`` / ``expectation_for(...)`` instead of hand-rolling
# string asserts.
FRAGMENTS = {
    # no mesh -> no collectives of any kind in the lowering
    "single-device": HloExpectation("single-device", absent=COLLECTIVES),
    # ring SpMM rotates blocks with collective-permute (GSPMD may still
    # emit all-gathers elsewhere in the step, e.g. for the BPR row
    # gather out of the row-sharded tables — the ring contract is only
    # that the permute is present)
    "ring-spmm": HloExpectation("ring-spmm",
                                contains=("collective-permute",)),
    # quantized ring: the rotated payload really is s8 (1/4 wire bytes)
    "ring-spmm@int8": HloExpectation("ring-spmm@int8",
                                     contains=("collective-permute", "s8")),
    # sharded training psums grads with a plain all-reduce
    "grad-psum": HloExpectation("grad-psum", contains=("all-reduce",)),
    # int8 gradient combine: a REAL integer all-reduce (int8 payload,
    # int32 accumulate) — test_compression's former one-off assert
    "grad-combine@int8": HloExpectation("grad-combine@int8",
                                        contains=("all-reduce", "s32")),
    # top-k combine exchanges sparse shares via all-gather, no psum of
    # the dense gradient
    "grad-combine@topk": HloExpectation("grad-combine@topk",
                                        contains=("all-gather",)),
}


def message_shape(n_edges: int, embed_dim: int) -> str:
    """The [E, D] message buffer's shape string as XLA prints it — the
    needle the fused-NGCF fragments look for."""
    return f"f32[{n_edges},{embed_dim}]"


def ngcf_message_fragment(n_edges: int, embed_dim: int, *,
                          fused: bool) -> HloExpectation:
    """The graph-shaped half of the fused-NGCF contract, built per run
    (FRAGMENTS entries are static; the message shape is not).  The
    COMPOSED lowering must contain the [E, D] message buffer (it
    materializes one per layer); the FUSED Pallas lowering must not
    contain it at all.  The fused XLA fallback still gathers operand
    rows at that shape, so its invariant is the relative count in
    ``fusion_audit``, not this absolute fragment."""
    shape = message_shape(n_edges, embed_dim)
    if fused:
        return HloExpectation("ngcf-fused-messages", absent=(shape,))
    return HloExpectation("ngcf-composed-messages", contains=(shape,))


def expect(*names: str) -> HloExpectation:
    """Merge named ``FRAGMENTS`` into one expectation."""
    exp = FRAGMENTS[names[0]]
    for n in names[1:]:
        exp = exp.merged(FRAGMENTS[n])
    return exp


def expectation_for(*, n_shards: int = 1, grads: str = "none",
                    ring: str = "none") -> HloExpectation:
    """The full train-step expectation for a (MeshCfg, CompressionCfg)
    point: which fragments apply is a pure function of the config."""
    if n_shards <= 1:
        return expect("single-device")
    names = ["ring-spmm@int8" if ring == "int8" else "ring-spmm"]
    if grads == "topk":
        names.append("grad-combine@topk")
    elif grads == "int8":
        names.append("grad-combine@int8")
    else:
        names.append("grad-psum")
    return expect(*names)


# ------------------------------------------------------------------ checks
def check_text(txt: str, expectation: HloExpectation | None = None, *,
               forbid_f64: bool = True, forbid_host_transfer: bool = True,
               where: str = "") -> list[str]:
    """Audit one lowered (compiled) HLO text; returns violations."""
    out = []
    tag = f"[{where}] " if where else ""
    if forbid_f64:
        m = _F64_RE.search(txt)
        if m:
            out.append(f"{tag}f64 op in lowering ({m.group(0)}...): a "
                       "hot path widened past fp32")
    if forbid_host_transfer:
        for pat in _HOST_RES:
            m = pat.search(txt)
            if m:
                out.append(f"{tag}host-transfer marker "
                           f"{m.group(0)!r} inside the step lowering")
    if expectation is not None:
        for s in expectation.contains:
            if s not in txt:
                out.append(f"{tag}expected collective {s!r} missing "
                           f"(expectation {expectation.name})")
        for s in expectation.absent:
            if s in txt:
                out.append(f"{tag}forbidden op {s!r} present "
                           f"(expectation {expectation.name})")
    return out


def assert_clean(txt: str, expectation: HloExpectation | None = None,
                 **kw) -> None:
    """``check_text`` raising AssertionError with every violation — the
    one-call form the test suite uses."""
    violations = check_text(txt, expectation, **kw)
    assert not violations, "; ".join(violations)


# ----------------------------------------------------------------- drivers
def lower_train_step(run) -> dict[str, str]:
    """Compiled HLO texts of the two jitted halves of one engine step
    (the microbatch value-and-grad and the optimizer update) for a
    ``repro.api.Run``, lowered exactly as ``step_fn`` would execute
    them (under the run's sharding hints)."""
    import jax.numpy as jnp
    pipe = run.pipeline
    u, p, n = pipe._next_target_batch(1, 0)
    state = run.state
    with pipe.step_context():
        db = pipe._device_batch(u, p, n)
        micro = pipe._micro_value_and_grad.lower(
            state["params"], *db).compile().as_text()
        # params stand in for grads: same pytree, shapes, dtypes
        update = pipe._apply_update.lower(
            state, state["params"], jnp.float32(1e-3)).compile().as_text()
    return {"micro_step": micro, "apply_update": update}


def lower_serve(run, *, k: int = 10, item_block: int = 256,
                users: int = 8) -> dict[str, str]:
    """Compiled HLO of the fused serve oracle (the serving hot path's
    jitted score → mask → top-K sweep) on a host snapshot of the run's
    embeddings — serving scores a placed snapshot, not the (possibly
    mesh-sharded) live training arrays."""
    import jax.numpy as jnp
    import numpy as np
    from repro.kernels import ref
    ue, ie = run.embeddings()
    ue, ie = np.asarray(ue), np.asarray(ie)
    ue = jnp.asarray(ue)[:users]
    seen = jnp.zeros((ue.shape[0], 1), jnp.int32)
    mask = jnp.zeros((ue.shape[0], 1), bool)
    n_items = int(ie.shape[0])
    txt = ref.fused_topk_score_ref.lower(
        ue, jnp.asarray(ie), seen, mask, k=min(k, n_items),
        item_block=min(item_block, n_items),
        n_items=n_items).compile().as_text()
    return {"fused_serve": txt}


def recompile_hazard(plan, n_epochs: int = 8,
                     batches: list[int] | None = None) -> list[int]:
    """Distinct microbatch chunk shapes ``Pipeline.grads_for_batch``
    would trace across the schedule.  More than one distinct shape
    means an extra XLA compile per shape — the warm-up schedule must
    vary the accumulation COUNT, never the chunk shape.

    By default audits the engine's own feed (the loader-fed target
    batch, ``microbatches_for_epoch * global_microbatch`` per epoch —
    the round-up to whole microbatches IS the mitigation this check
    pins).  Pass ``batches`` to audit a direct ``grads_for_batch``
    caller's batch sizes instead: any size that is not a microbatch
    multiple shows up here as the ragged trailing chunk it would
    trace."""
    mu = plan.global_microbatch
    if batches is None:
        batches = [plan.microbatches_for_epoch(e) * mu
                   for e in range(n_epochs)]
    shapes = set()
    for n in batches:
        for c in range(max(1, math.ceil(n / mu))):
            shapes.add(min((c + 1) * mu, n) - c * mu)
    return sorted(shapes)


def audit_spec(spec, *, serve: bool = True, n_epochs: int = 8
               ) -> list[str]:
    """Build ``spec`` and audit every hot-path lowering: train halves
    (f64 / host-transfer / collectives per the spec's own MeshCfg +
    CompressionCfg), the fused serve path, and the recompile hazard.
    Returns all violations (empty = clean)."""
    from repro.api import build
    run = build(spec)
    n_shards = 1
    for d in spec.mesh.shape:
        n_shards *= int(d)
    exp = expectation_for(n_shards=n_shards,
                          grads=spec.compression.grads,
                          ring=spec.compression.ring)
    violations = []
    for name, txt in lower_train_step(run).items():
        # the collective contract binds the aggregation step; the
        # optimizer update only shares the f64/host invariants and, when
        # sharded, must not itself gather or widen anything
        e = exp if name == "micro_step" else (
            expect("single-device") if n_shards <= 1 else None)
        violations += check_text(txt, e, where=f"{spec.name}:{name}")
    if serve:
        for name, txt in lower_serve(run).items():
            violations += check_text(txt, expect("single-device"),
                                     where=f"{spec.name}:{name}")
    shapes = recompile_hazard(run.pipeline.plan, n_epochs=n_epochs)
    if len(shapes) != 1:
        violations.append(
            f"[{spec.name}:schedule] recompile hazard: {len(shapes)} "
            f"distinct microbatch chunk shapes {shapes} across the "
            "schedule (expected exactly 1)")
    return violations


def fusion_audit(spec, *, where: str = "") -> list[str]:
    """The fused-NGCF train-step contract, checked on the LOWERED text:
    build the same spec at ``model.hadamard`` 'fused' and 'composed',
    lower both micro steps, and require

      * the composed lowering CONTAINS the [E, D] message buffer (the
        absolute fragment — it materializes one per layer);
      * the fused lowering references that shape STRICTLY less often —
        on TPU the Pallas kernel drops it entirely, while the XLA
        fallback still gathers operand rows at [E, D] inside the
        aggregation, so the cross-arm count is the invariant that
        holds on every backend.
    """
    from repro.api import build
    txts, runs = {}, {}
    for had in ("fused", "composed"):
        s = spec.override({"model.hadamard": had,
                           "name": f"{spec.name}@{had}"})
        runs[had] = build(s)
        txts[had] = lower_train_step(runs[had])["micro_step"]
    g = runs["fused"].pipeline.g
    tag = f"[{where}] " if where else ""
    if not getattr(g, "fused_hadamard", False):
        return [f"{tag}model.hadamard='fused' did not resolve to the "
                "fused route"]
    out = check_text(txts["composed"],
                     ngcf_message_fragment(g.n_edges, spec.model.embed_dim,
                                           fused=False),
                     where=f"{where}:composed")
    shape = message_shape(g.n_edges, spec.model.embed_dim)
    n_fused = txts["fused"].count(shape)
    n_composed = txts["composed"].count(shape)
    if n_fused >= n_composed:
        out.append(f"{tag}fused NGCF micro step references the message "
                   f"shape {shape} {n_fused}x vs composed "
                   f"{n_composed}x — the fusion bought nothing")
    return out


# ------------------------------------------------------------------ smoke
_SMOKE_OV = {"loop.steps": 5, "plan.target_batch": 64,
             "plan.microbatch": 16, "plan.warmup_epochs": 2,
             "data.edges": 1200, "loop.ckpt_dir": None}


def smoke_audit(mesh: int = 1, grads: str = "none", ring: str = "none",
                embed_store: str = "fp32", fused_serve: bool = True,
                arch: str = "lightgcn") -> list[str]:
    """The representative-preset audit ``make audit`` runs: the
    ``{arch}-smoke`` preset at a (mesh, compression) point.  ``mesh > 1``
    requires the caller to have forced that many devices (the CLI
    spawns a subprocess with ``XLA_FLAGS``).  The ngcf arch adds the
    fused-Hadamard contract: ``fusion_audit`` at mesh=1; at mesh>1 the
    ring dispatch owns aggregation, so the audit asserts the fused
    route correctly fell back (plus the standard ring collectives)."""
    from repro.api import get_preset
    ov = dict(_SMOKE_OV)
    if mesh > 1:
        ov.update({"mesh.shape": (mesh,), "plan.microbatch": 4})
    ov.update({"compression.grads": grads, "compression.ring": ring,
               "compression.embed_store": embed_store})
    spec = get_preset(f"{arch}-smoke").override(ov)
    name = f"{arch}-smoke[mesh={mesh},grads={grads},ring={ring}" \
           f",store={embed_store}]"
    spec = spec.override({"name": name})
    violations = audit_spec(spec, serve=fused_serve)
    if arch == "ngcf":
        if mesh <= 1:
            violations += fusion_audit(spec, where=name)
        else:
            from repro.api import build
            run = build(spec.override(
                {"name": f"{name}@ring-fallback"}))
            if getattr(run.pipeline.g, "fused_hadamard", False):
                violations.append(
                    f"[{name}] ring dispatch did not fall back to the "
                    "composed Hadamard route")
    return violations
