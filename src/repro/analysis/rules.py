"""Layer 1 — JAX-aware AST lint (no JAX import required).

The paper's placement/write-policy wins (§5-§6) die from bug classes
that type checkers don't see: a stray ``.item()`` inside a jitted step
is a hidden device→host sync, a dtype-less ``jnp.zeros`` silently
widens to f64 under x64, an unseeded ``np.random`` call makes a
benchmark unreproducible, and a Python branch on a tracer inside a
Pallas kernel either fails late or bakes one side in.  These rules
catch all of that *statically*, from the AST alone, so ``tools/lint.py``
can run in CI without building a single array.

Rule catalogue (each finding is reported as ``path:line:col: rule:
message``; fingerprints — ``Finding.key`` — are line-number-free so the
ratchet baseline survives unrelated edits):

  tracer-item           ``x.item()`` inside a jit/Pallas function —
                        a forced device→host sync per call.
  tracer-host-cast      ``float(x)``/``int(x)``/``bool(x)`` on a
                        traced value inside a jit/Pallas function.
  tracer-np-call        ``np.*(traced value)`` inside a jit/Pallas
                        function — numpy concretizes the tracer (sync
                        or ConcretizationTypeError).
  prng-unseeded         legacy global-state ``np.random.*`` calls or
                        ``np.random.default_rng()`` with no seed.
  prng-key-reuse        the same PRNGKey fed to two or more samplers
                        without an intervening ``split`` — correlated
                        streams.
  f64-dtypeless         dtype-less ``jnp.zeros/ones/empty/full`` (or a
                        ``jnp.array`` of float literals) in hot-path
                        code — f64 under x64, weak-type surprises
                        otherwise.
  f64-explicit          explicit float64: ``np.float64``,
                        ``jnp.float64``, ``"float64"`` dtype strings,
                        ``astype(float)``.
  pallas-python-branch  Python ``if``/``while`` on a traced (non-static)
                        value inside a Pallas kernel body.
  pallas-nonstatic-grid ``grid=`` built from traced (non-static) values.

Static-argument awareness: names listed in ``static_argnames`` of a
``functools.partial(jax.jit, ...)`` decorator, keyword-only kernel
parameters, and locals derived only from static names are NOT treated
as tracers, so ``int(min(item_block, n))`` under
``static_argnames=("item_block", "n")`` is clean.
"""
from __future__ import annotations

import ast
import builtins
import dataclasses
import pathlib

__all__ = ["Finding", "RULES", "lint_source", "lint_paths"]


@dataclasses.dataclass(frozen=True)
class Finding:
    """One lint violation, with a line-number-free baseline fingerprint."""
    rule: str
    path: str                 # repo-relative posix path
    line: int
    col: int
    message: str
    snippet: str              # stripped source line (ratchet fingerprint)

    def key(self) -> str:
        return f"{self.rule}::{self.path}::{self.snippet}"

    def __str__(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule}: " \
               f"{self.message}"


# rule name -> one-line description (the catalogue `tools/lint.py --rules`
# prints; tests assert every implemented rule is documented here)
RULES = {
    "tracer-item": "`.item()` inside a jit/Pallas function is a forced "
                   "device->host sync",
    "tracer-host-cast": "float()/int()/bool() of a traced value inside a "
                        "jit/Pallas function",
    "tracer-np-call": "numpy call on a traced value inside a jit/Pallas "
                      "function (hidden sync / concretization)",
    "prng-unseeded": "global-state np.random.* call or seedless "
                     "default_rng() — unreproducible",
    "prng-key-reuse": "same PRNGKey consumed by >=2 samplers without "
                      "split() — correlated streams",
    "f64-dtypeless": "dtype-less jnp array constructor in hot-path code "
                     "(f64 under x64)",
    "f64-explicit": "explicit float64 dtype in repo code (fp32-only hot "
                    "paths)",
    "pallas-python-branch": "Python if/while on a traced value inside a "
                            "Pallas kernel",
    "pallas-nonstatic-grid": "pallas grid= built from traced values "
                             "(must be static)",
}

_LEGACY_NP_RANDOM = {
    "rand", "randn", "randint", "random", "random_sample", "ranf",
    "sample", "choice", "shuffle", "permutation", "uniform", "normal",
    "standard_normal", "beta", "binomial", "exponential", "gamma",
    "poisson", "seed",
}
_KEY_CONSUMERS_EXEMPT = {"split", "fold_in", "PRNGKey", "key", "key_data",
                         "wrap_key_data", "clone"}
_DTYPE_REQUIRED = {"zeros", "ones", "empty", "full"}
_BUILTINS = set(dir(builtins))


def _attr_chain(node: ast.AST) -> str:
    """Dotted name of an attribute/name chain ('' when not a chain)."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


_STATIC_ATTRS = {"shape", "ndim", "dtype", "size", "sharding"}


def _names_in(node: ast.AST) -> set[str]:
    return {n.id for n in ast.walk(node) if isinstance(n, ast.Name)}


def _traced_names_in(node: ast.AST) -> set[str]:
    """Names referenced by ``node`` in tracer *value* position — uses
    under ``x.shape``/``x.ndim``/``x.dtype``/``len(x)`` are static
    metadata, not traced values, and don't count."""
    if isinstance(node, ast.Attribute) and node.attr in _STATIC_ATTRS:
        return set()
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
            and node.func.id == "len" and len(node.args) == 1:
        return set()
    if isinstance(node, ast.Name):
        return {node.id}
    out: set[str] = set()
    for child in ast.iter_child_nodes(node):
        out |= _traced_names_in(child)
    return out


def _const_str_seq(node: ast.AST) -> list[str]:
    """String constants in a str/tuple/list constant expression."""
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return [node.value]
    if isinstance(node, (ast.Tuple, ast.List)):
        return [e.value for e in node.elts
                if isinstance(e, ast.Constant) and isinstance(e.value, str)]
    return []


def _jit_decoration(fn: ast.FunctionDef) -> tuple[bool, set[str]]:
    """(is_jitted, static_argnames) from the decorator list."""
    for dec in fn.decorator_list:
        chain = _attr_chain(dec)
        if chain in ("jax.jit", "jit"):
            return True, set()
        if isinstance(dec, ast.Call):
            head = _attr_chain(dec.func)
            if head in ("jax.jit", "jit"):
                static = set()
                for kw in dec.keywords:
                    if kw.arg in ("static_argnames", "static_argnums") \
                            and kw.arg == "static_argnames":
                        static |= set(_const_str_seq(kw.value))
                return True, static
            if head in ("functools.partial", "partial") and dec.args:
                inner = _attr_chain(dec.args[0])
                if inner in ("jax.jit", "jit"):
                    static = set()
                    for kw in dec.keywords:
                        if kw.arg == "static_argnames":
                            static |= set(_const_str_seq(kw.value))
                    return True, static
    return False, set()


def _kernel_names(tree: ast.Module) -> set[str]:
    """Function names passed (directly or through functools.partial) as
    the kernel argument of a ``pl.pallas_call``/``pallas_call``."""
    kernels: set[str] = set()
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        head = _attr_chain(node.func)
        if not head.endswith("pallas_call"):
            continue
        if not node.args:
            continue
        target = node.args[0]
        if isinstance(target, ast.Call):         # functools.partial(k, ...)
            phead = _attr_chain(target.func)
            if phead in ("functools.partial", "partial") and target.args:
                target = target.args[0]
        name = _attr_chain(target)
        if name:
            kernels.add(name.split(".")[-1])
    return kernels


class _FunctionLinter:
    """Taint-tracks one function body: which locals derive from traced
    parameters, then reports tracer-unsafe operations."""

    def __init__(self, fn: ast.FunctionDef, *, jit_ctx: bool,
                 kernel_ctx: bool, static: set[str], emit):
        self.fn = fn
        self.jit_ctx = jit_ctx
        self.kernel_ctx = kernel_ctx
        self.emit = emit
        a = fn.args
        params = [p.arg for p in
                  (a.posonlyargs + a.args + ([a.vararg] if a.vararg else []))]
        # keyword-only params are the closure-bound statics of the
        # functools.partial kernel idiom (reduce=, rb=, gather=)
        kwonly = {p.arg for p in a.kwonlyargs}
        self.dynamic = {p for p in params if p not in static} - kwonly
        self.static = static | kwonly | _BUILTINS

    def tainted(self, node: ast.AST) -> bool:
        return bool(_traced_names_in(node) & self.dynamic)

    def run(self) -> None:
        for stmt in self.fn.body:
            self._walk(stmt)

    # --------------------------------------------------------------- walk
    def _walk(self, node: ast.AST) -> None:
        if isinstance(node, ast.FunctionDef):
            # nested defs inherit this function's taint context
            sub = _FunctionLinter(node, jit_ctx=self.jit_ctx,
                                  kernel_ctx=self.kernel_ctx,
                                  static=set(), emit=self.emit)
            sub.dynamic |= self.dynamic
            sub.run()
            return
        if isinstance(node, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
            self._track_assign(node)
        if isinstance(node, ast.For) and self.tainted(node.iter):
            for n in ast.walk(node.target):
                if isinstance(n, ast.Name):
                    self.dynamic.add(n.id)
        if isinstance(node, (ast.If, ast.While)) and self.kernel_ctx \
                and self.tainted(node.test):
            self.emit("pallas-python-branch", node.test,
                      "Python branch on a traced value inside a Pallas "
                      "kernel — use lax.cond/jnp.where")
        if isinstance(node, ast.Call):
            self._check_call(node)
        for child in ast.iter_child_nodes(node):
            self._walk(child)

    def _track_assign(self, node) -> None:
        value = getattr(node, "value", None)
        if value is None:
            return
        taint = self.tainted(value)
        targets = node.targets if isinstance(node, ast.Assign) \
            else [node.target]
        for t in targets:
            for n in ast.walk(t):
                if isinstance(n, ast.Name):
                    if taint:
                        self.dynamic.add(n.id)
                    else:
                        self.dynamic.discard(n.id)

    # -------------------------------------------------------------- calls
    def _check_call(self, call: ast.Call) -> None:
        if not (self.jit_ctx or self.kernel_ctx):
            return
        func = call.func
        if isinstance(func, ast.Attribute) and func.attr == "item" \
                and not call.args and not call.keywords:
            self.emit("tracer-item", call,
                      "`.item()` inside a jitted function forces a "
                      "device->host sync per call")
            return
        if isinstance(func, ast.Name) and func.id in ("float", "int",
                                                      "bool") \
                and len(call.args) == 1 and self.tainted(call.args[0]):
            self.emit("tracer-host-cast", call,
                      f"`{func.id}()` of a traced value inside a jitted "
                      "function concretizes it (device->host sync)")
            return
        chain = _attr_chain(func)
        if (chain.startswith("np.") or chain.startswith("numpy.")) \
                and any(self.tainted(a) for a in call.args):
            self.emit("tracer-np-call", call,
                      f"`{chain}()` on a traced value inside a jitted "
                      "function (hidden sync / concretization)")


class _ModuleLinter:
    def __init__(self, tree: ast.Module, src: str, path: str,
                 hot_path: bool):
        self.tree = tree
        self.lines = src.splitlines()
        self.path = path
        self.hot_path = hot_path
        self.findings: list[Finding] = []
        self.kernels = _kernel_names(tree)
        # fns jitted at a call/assignment site: f2 = jax.jit(f2_impl)
        self.jit_wrapped: set[str] = set()
        for node in ast.walk(tree):
            if isinstance(node, ast.Call) \
                    and _attr_chain(node.func) in ("jax.jit", "jit") \
                    and node.args:
                name = _attr_chain(node.args[0])
                if name:
                    self.jit_wrapped.add(name.split(".")[-1])

    def emit(self, rule: str, node: ast.AST, message: str) -> None:
        line = getattr(node, "lineno", 1)
        snippet = self.lines[line - 1].strip() if \
            0 < line <= len(self.lines) else ""
        self.findings.append(Finding(rule, self.path, line,
                                     getattr(node, "col_offset", 0),
                                     message, snippet))

    def run(self) -> list[Finding]:
        self._module_wide()
        self._functions(self.tree, outer_jit=False, outer_dynamic=set())
        return self.findings

    # ----------------------------------------------------- module rules
    def _module_wide(self) -> None:
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Call):
                self._check_prng_unseeded(node)
                self._check_dtypeless(node)
                self._check_astype_float(node)
            if isinstance(node, ast.Attribute) \
                    and node.attr == "float64" \
                    and _attr_chain(node) in ("np.float64", "numpy.float64",
                                              "jnp.float64",
                                              "jax.numpy.float64"):
                self.emit("f64-explicit", node,
                          f"explicit {_attr_chain(node)} (hot paths are "
                          "fp32-only)")
            if isinstance(node, ast.Call):
                self._check_f64_string(node)
        for fn in [n for n in ast.walk(self.tree)
                   if isinstance(n, ast.FunctionDef)]:
            self._check_key_reuse(fn)

    def _check_prng_unseeded(self, call: ast.Call) -> None:
        chain = _attr_chain(call.func)
        if chain in {f"np.random.{f}" for f in _LEGACY_NP_RANDOM} \
                | {f"numpy.random.{f}" for f in _LEGACY_NP_RANDOM}:
            self.emit("prng-unseeded", call,
                      f"legacy global-state `{chain}()` — seed a "
                      "`np.random.default_rng(seed)` instead")
        elif chain.endswith("default_rng") and not call.args \
                and not call.keywords:
            self.emit("prng-unseeded", call,
                      "`default_rng()` without a seed is "
                      "unreproducible")

    def _check_dtypeless(self, call: ast.Call) -> None:
        if not self.hot_path:
            return
        chain = _attr_chain(call.func)
        if chain.split(".")[0] not in ("jnp", "jax"):
            return
        name = chain.split(".")[-1]
        if chain.startswith("jax.") and ".numpy." not in f".{chain}.":
            return
        has_dtype = any(kw.arg == "dtype" for kw in call.keywords)
        if name in _DTYPE_REQUIRED:
            need = 3 if name == "full" else 2
            if not has_dtype and len(call.args) < need:
                self.emit("f64-dtypeless", call,
                          f"`{chain}()` without an explicit dtype "
                          "(f64 under x64; pass jnp.float32/int32)")
        elif name == "array" and not has_dtype and len(call.args) < 2 \
                and call.args:
            lits = [n for n in ast.walk(call.args[0])
                    if isinstance(n, ast.Constant)
                    and isinstance(n.value, float)]
            if lits:
                self.emit("f64-dtypeless", call,
                          "`jnp.array()` of float literals without a "
                          "dtype (weak-type / f64 hazard)")

    def _check_f64_string(self, call: ast.Call) -> None:
        """'float64' only counts in dtype position (dtype= kwarg or an
        astype()/view() argument) — not in arbitrary strings."""
        def is_f64(node):
            return isinstance(node, ast.Constant) and node.value == "float64"
        hits = [kw.value for kw in call.keywords
                if kw.arg == "dtype" and is_f64(kw.value)]
        if isinstance(call.func, ast.Attribute) \
                and call.func.attr in ("astype", "view"):
            hits += [a for a in call.args if is_f64(a)]
        for node in hits:
            self.emit("f64-explicit", node,
                      "'float64' dtype (hot paths are fp32-only)")

    def _check_astype_float(self, call: ast.Call) -> None:
        if isinstance(call.func, ast.Attribute) \
                and call.func.attr == "astype" and len(call.args) == 1 \
                and isinstance(call.args[0], ast.Name) \
                and call.args[0].id == "float":
            self.emit("f64-explicit", call,
                      "`astype(float)` is float64 — use an explicit "
                      "32-bit dtype")

    def _check_key_reuse(self, fn: ast.FunctionDef) -> None:
        key_vars: set[str] = set()
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign) \
                    and isinstance(node.value, ast.Call):
                chain = _attr_chain(node.value.func)
                if chain.endswith("random.PRNGKey") or chain == "PRNGKey":
                    for t in node.targets:
                        if isinstance(t, ast.Name):
                            key_vars.add(t.id)
        if not key_vars:
            return
        uses: dict[str, list[ast.Call]] = {k: [] for k in key_vars}
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            chain = _attr_chain(node.func)
            parts = chain.split(".")
            if len(parts) >= 2 and parts[-2] == "random" \
                    and parts[-1] not in _KEY_CONSUMERS_EXEMPT:
                for arg in node.args:
                    if isinstance(arg, ast.Name) and arg.id in key_vars:
                        uses[arg.id].append(node)
        for var, calls in uses.items():
            for call in calls[1:]:
                self.emit("prng-key-reuse", call,
                          f"PRNGKey `{var}` already consumed by another "
                          "sampler — jax.random.split it first")

    # --------------------------------------------------- function rules
    def _functions(self, scope, *, outer_jit: bool,
                   outer_dynamic: set[str]) -> None:
        for node in ast.iter_child_nodes(scope):
            if isinstance(node, ast.FunctionDef):
                jit, static = _jit_decoration(node)
                jit = jit or node.name in self.jit_wrapped or outer_jit
                kernel = node.name in self.kernels
                if jit or kernel:
                    fl = _FunctionLinter(node, jit_ctx=jit,
                                         kernel_ctx=kernel, static=static,
                                         emit=self.emit)
                    fl.dynamic |= outer_dynamic
                    self._check_grid(node, fl)
                    # fl.run() walks nested defs itself (they inherit
                    # the taint context) — do not recurse again here
                    fl.run()
                else:
                    self._functions(node, outer_jit=False,
                                    outer_dynamic=set())
            elif isinstance(node, (ast.ClassDef, ast.If, ast.Try,
                                   ast.With)):
                self._functions(node, outer_jit=outer_jit,
                                outer_dynamic=outer_dynamic)

    def _check_grid(self, fn: ast.FunctionDef, fl) -> None:
        """grid= inside this function must not reference traced names."""
        if fl is None:
            return
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            head = _attr_chain(node.func)
            if not (head.endswith("pallas_call")
                    or head.endswith("GridSpec")):
                continue
            for kw in node.keywords:
                if kw.arg == "grid" and fl.tainted(kw.value):
                    self.emit("pallas-nonstatic-grid", kw.value,
                              "pallas grid derives from a traced value "
                              "— grids must be static ints")


def lint_source(src: str, path: str = "<memory>",
                hot_path: bool = True) -> list[Finding]:
    """Lint one module's source text.  ``hot_path`` gates the
    f64-dtypeless constructor rule (applied to src/ + benchmarks/)."""
    tree = ast.parse(src, filename=path)
    return _ModuleLinter(tree, src, path, hot_path).run()


def lint_paths(paths, root: "pathlib.Path | str | None" = None
               ) -> list[Finding]:
    """Lint every ``*.py`` under ``paths`` (files or directories).
    Paths in findings are reported relative to ``root`` when given."""
    root = pathlib.Path(root) if root is not None else None
    files: list[pathlib.Path] = []
    for p in map(pathlib.Path, paths):
        files.extend(sorted(p.rglob("*.py")) if p.is_dir() else [p])
    findings: list[Finding] = []
    for f in files:
        rel = f
        if root is not None:
            try:
                rel = f.resolve().relative_to(pathlib.Path(root).resolve())
            except ValueError:
                rel = f
        findings.extend(lint_source(f.read_text(), rel.as_posix()))
    return sorted(findings, key=lambda x: (x.path, x.line, x.rule))
