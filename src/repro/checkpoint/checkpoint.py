"""Sharded, atomic, elastic checkpointing (no orbax dependency).

Layout:  <dir>/step_<N>/
            manifest.json        {step, leaf paths, treedef, shapes, dtypes}
            leaf_<i>.npy         one file per pytree leaf
            COMMITTED            written last -> crash-safe atomic commit

Elasticity: leaves are saved *unsharded* (fully-addressable host copy) so
a restore can re-shard onto any mesh — restore() takes an optional
``sharding_tree`` and device_puts each leaf accordingly.  An async mode
runs the serialization on a worker thread so the step loop isn't gated.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any

import jax
import numpy as np


def _leaf_paths(tree) -> list[str]:
    paths = []
    for kp, _ in jax.tree_util.tree_flatten_with_path(tree)[0]:
        paths.append(jax.tree_util.keystr(kp))
    return paths


def save_checkpoint(ckpt_dir: str, step: int, tree: Any,
                    async_: bool = False) -> threading.Thread | None:
    """Atomically save ``tree`` under step ``step``."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    host_leaves = [np.asarray(l) for l in leaves]

    def _write():
        final = os.path.join(ckpt_dir, f"step_{step}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp, exist_ok=True)
        manifest = {
            "step": step,
            "n_leaves": len(host_leaves),
            "paths": _leaf_paths(tree),
            "shapes": [list(l.shape) for l in host_leaves],
            "dtypes": [str(l.dtype) for l in host_leaves],
        }
        for i, l in enumerate(host_leaves):
            np.save(os.path.join(tmp, f"leaf_{i}.npy"), l)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        with open(os.path.join(tmp, "COMMITTED"), "w") as f:
            f.write("ok")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)

    if async_:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        return t
    _write()
    return None


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(ckpt_dir, name, "COMMITTED")):
                steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, example_tree: Any, step: int | None = None,
                       sharding_tree: Any | None = None) -> tuple[Any, int]:
    """Restore into the structure of ``example_tree``; optionally place
    each leaf with the matching sharding (elastic re-shard)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    leaves = [np.load(os.path.join(d, f"leaf_{i}.npy"))
              for i in range(manifest["n_leaves"])]
    treedef = jax.tree_util.tree_structure(example_tree)
    if treedef.num_leaves != len(leaves):
        raise ValueError(f"checkpoint has {len(leaves)} leaves; "
                         f"expected {treedef.num_leaves}")
    if sharding_tree is not None:
        shardings = jax.tree_util.tree_leaves(sharding_tree)
        leaves = [jax.device_put(l, s) for l, s in zip(leaves, shardings)]
    return jax.tree_util.tree_unflatten(treedef, leaves), step
