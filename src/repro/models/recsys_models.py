"""Assigned recsys architectures on the shared embedding substrate.

  deepfm    (arXiv:1703.04247): FM interaction ∥ deep MLP 400-400-400
  xdeepfm   (arXiv:1803.05170): CIN 200-200-200 ∥ MLP 400-400
  bert4rec  (arXiv:1904.06690): bidirectional transformer over item seqs
  dlrm-rm2  (arXiv:1906.00091): bottom MLP + 26 tables + dot interaction

All sparse lookups go through the embedding-bag substrate (single-hot
fields = bag length 1); tables are stacked [F, V, D] so the row axis can
be sharded over the whole mesh (the paper's capacity-tier residents).
``serve_retrieval`` scores 1M candidates by swapping the item field and
reusing the fixed user-side compute — a batched dot, not a loop.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp


# ----------------------------------------------------------------- common

def _mlp_params(key, dims, dtype=jnp.float32):
    ks = jax.random.split(key, len(dims) - 1)
    out = []
    for i in range(len(dims) - 1):
        scale = jnp.sqrt(2.0 / dims[i])
        out.append({"w": jax.random.normal(ks[i], (dims[i], dims[i + 1]),
                                           dtype) * scale,
                    "b": jnp.zeros((dims[i + 1],), dtype)})
    return out


def _mlp(params, x, final_act=False):
    for i, l in enumerate(params):
        x = x @ l["w"] + l["b"]
        if i + 1 < len(params) or final_act:
            x = jax.nn.relu(x)
    return x


def lookup_fields(tables: jax.Array, ids: jax.Array) -> jax.Array:
    """tables [F, V, D]; ids [B, F] single-hot -> [B, F, D]."""
    return jax.vmap(lambda tab, col: tab[col], in_axes=(0, 1),
                    out_axes=1)(tables, ids)


def bce_loss(logits, labels):
    return jnp.mean(jnp.maximum(logits, 0) - logits * labels
                    + jnp.log1p(jnp.exp(-jnp.abs(logits))))


# ----------------------------------------------------------------- deepfm

@dataclasses.dataclass(frozen=True)
class DeepFMConfig:
    name: str = "deepfm"
    n_sparse: int = 39
    embed_dim: int = 10
    vocab: int = 1_000_000
    mlp_dims: tuple = (400, 400, 400)


def deepfm_init(cfg: DeepFMConfig, key):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "tables": jax.random.normal(
            k1, (cfg.n_sparse, cfg.vocab, cfg.embed_dim)) * 0.01,
        "linear": jax.random.normal(k2, (cfg.n_sparse, cfg.vocab)) * 0.01,
        "mlp": _mlp_params(k3, (cfg.n_sparse * cfg.embed_dim,) + cfg.mlp_dims
                           + (1,)),
        "bias": jnp.zeros((), jnp.float32),
    }


def fm_interaction(emb: jax.Array) -> jax.Array:
    """emb [B, F, D] -> [B] second-order FM term:
    0.5 * ((Σ_f v_f)^2 − Σ_f v_f^2) summed over D."""
    s = emb.sum(1)
    s2 = (emb * emb).sum(1)
    return 0.5 * (s * s - s2).sum(-1)


def deepfm_forward(cfg: DeepFMConfig, params, ids):
    emb = lookup_fields(params["tables"], ids)                       # [B,F,D]
    first = jax.vmap(lambda tab, col: tab[col], in_axes=(0, 1),
                     out_axes=1)(params["linear"], ids).sum(-1)      # [B]
    fm = fm_interaction(emb)
    deep = _mlp(params["mlp"], emb.reshape(emb.shape[0], -1))[:, 0]
    return first + fm + deep + params["bias"]


# ----------------------------------------------------------------- xdeepfm

@dataclasses.dataclass(frozen=True)
class XDeepFMConfig:
    name: str = "xdeepfm"
    n_sparse: int = 39
    embed_dim: int = 10
    vocab: int = 1_000_000
    cin_layers: tuple = (200, 200, 200)
    mlp_dims: tuple = (400, 400)


def xdeepfm_init(cfg: XDeepFMConfig, key):
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    cin = []
    h_prev = cfg.n_sparse
    kcs = jax.random.split(k4, len(cfg.cin_layers))
    for h, kc in zip(cfg.cin_layers, kcs):
        cin.append(jax.random.normal(kc, (h, h_prev, cfg.n_sparse)) *
                   jnp.sqrt(2.0 / (h_prev * cfg.n_sparse)))
        h_prev = h
    return {
        "tables": jax.random.normal(
            k1, (cfg.n_sparse, cfg.vocab, cfg.embed_dim)) * 0.01,
        "linear": jax.random.normal(k2, (cfg.n_sparse, cfg.vocab)) * 0.01,
        "mlp": _mlp_params(k3, (cfg.n_sparse * cfg.embed_dim,) + cfg.mlp_dims
                           + (1,)),
        "cin": cin,
        "cin_out": jax.random.normal(k5, (sum(cfg.cin_layers), 1)) * 0.1,
        "bias": jnp.zeros((), jnp.float32),
    }


def cin(params_cin, x0):
    """Compressed Interaction Network.  x0 [B, F, D]."""
    xk = x0
    outs = []
    for w in params_cin:
        # z [B, Hk, F, D] = outer product along field axes
        z = jnp.einsum("bhd,bmd->bhmd", xk, x0)
        xk = jnp.einsum("bhmd,nhm->bnd", z, w)                      # [B, H, D]
        outs.append(xk.sum(-1))                                     # [B, H]
    return jnp.concatenate(outs, -1)


def xdeepfm_forward(cfg: XDeepFMConfig, params, ids):
    emb = lookup_fields(params["tables"], ids)
    first = jax.vmap(lambda tab, col: tab[col], in_axes=(0, 1),
                     out_axes=1)(params["linear"], ids).sum(-1)
    p = cin(params["cin"], emb) @ params["cin_out"]
    deep = _mlp(params["mlp"], emb.reshape(emb.shape[0], -1))[:, 0]
    return first + p[:, 0] + deep + params["bias"]


# ----------------------------------------------------------------- bert4rec

@dataclasses.dataclass(frozen=True)
class BERT4RecConfig:
    name: str = "bert4rec"
    embed_dim: int = 64
    n_blocks: int = 2
    n_heads: int = 2
    seq_len: int = 200
    n_items: int = 1_000_000
    d_ff: int = 256


def bert4rec_init(cfg: BERT4RecConfig, key):
    ks = jax.random.split(key, 3 + cfg.n_blocks)
    d = cfg.embed_dim
    blocks = []
    for i in range(cfg.n_blocks):
        kb = jax.random.split(ks[3 + i], 6)
        s = 1.0 / jnp.sqrt(d)
        blocks.append({
            "wq": jax.random.normal(kb[0], (d, d)) * s,
            "wk": jax.random.normal(kb[1], (d, d)) * s,
            "wv": jax.random.normal(kb[2], (d, d)) * s,
            "wo": jax.random.normal(kb[3], (d, d)) * s,
            "w1": jax.random.normal(kb[4], (d, cfg.d_ff)) * s,
            "w2": jax.random.normal(kb[5], (cfg.d_ff, d)) *
                  (1.0 / jnp.sqrt(cfg.d_ff)),
            "ln1": jnp.ones((d,), jnp.float32),
            "ln2": jnp.ones((d,), jnp.float32),
        })
    return {
        "item_embed": jax.random.normal(ks[0], (cfg.n_items, d)) * 0.02,
        "pos_embed": jax.random.normal(ks[1], (cfg.seq_len, d)) * 0.02,
        "blocks": blocks,
        "out_bias": jnp.zeros((cfg.n_items,), jnp.float32),
    }


def _ln(x, w, eps=1e-6):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * w


def bert4rec_encode(cfg: BERT4RecConfig, params, item_seq, seq_mask):
    """item_seq [B, S] -> hidden [B, S, D] (bidirectional: no causal mask)."""
    b, s = item_seq.shape
    d, h = cfg.embed_dim, cfg.n_heads
    x = params["item_embed"][item_seq] + params["pos_embed"][None, :s]
    attn_mask = seq_mask[:, None, None, :]  # key-side padding mask
    for blk in params["blocks"]:
        y = _ln(x, blk["ln1"])
        q = (y @ blk["wq"]).reshape(b, s, h, d // h)
        k = (y @ blk["wk"]).reshape(b, s, h, d // h)
        v = (y @ blk["wv"]).reshape(b, s, h, d // h)
        logits = jnp.einsum("bqhd,bkhd->bhqk", q, k) / jnp.sqrt(d / h)
        logits = jnp.where(attn_mask, logits, -1e30)
        p = jax.nn.softmax(logits, -1)
        a = jnp.einsum("bhqk,bkhd->bqhd", p, v).reshape(b, s, d)
        x = x + a @ blk["wo"]
        y = _ln(x, blk["ln2"])
        x = x + jax.nn.gelu(y @ blk["w1"]) @ blk["w2"]
    return x


def bert4rec_loss(cfg: BERT4RecConfig, params, item_seq, seq_mask, labels,
                  label_mask):
    """Cloze objective: predict masked items (tied output embedding)."""
    hid = bert4rec_encode(cfg, params, item_seq, seq_mask)
    logits = hid @ params["item_embed"].T + params["out_bias"]
    logp = jax.nn.log_softmax(logits, -1)
    ll = jnp.take_along_axis(logp, labels[..., None], -1)[..., 0]
    return -jnp.sum(ll * label_mask) / jnp.maximum(label_mask.sum(), 1.0)


def bert4rec_sampled_loss(cfg: BERT4RecConfig, params, item_seq, seq_mask,
                          mask_pos, labels, neg_ids):
    """Cloze objective with sampled softmax (full-vocab logits at
    65536x200 positions x 1M items would be ~50 TB — see configs).
    mask_pos [B, M]: masked positions; labels [B, M]: true items;
    neg_ids [B, M, N]: sampled negatives."""
    hid = bert4rec_encode(cfg, params, item_seq, seq_mask)
    h = jnp.take_along_axis(hid, mask_pos[..., None], axis=1)       # [B,M,D]
    cand = jnp.concatenate([labels[..., None], neg_ids], -1)        # [B,M,1+N]
    ce = params["item_embed"][cand]                                 # [B,M,1+N,D]
    logits = jnp.einsum("bmd,bmnd->bmn", h, ce) + params["out_bias"][cand]
    logp = jax.nn.log_softmax(logits, -1)
    return -jnp.mean(logp[..., 0])


def bert4rec_serve(cfg: BERT4RecConfig, params, item_seq, seq_mask,
                   slate_ids):
    """Online/bulk serving: user representation + re-ranking slate scores
    (catalogue-wide scoring is the retrieval cell's job)."""
    hid = bert4rec_encode(cfg, params, item_seq, seq_mask)
    u = hid[:, -1]                                                  # [B, D]
    cand = params["item_embed"][slate_ids]                          # [B,K,D]
    scores = jnp.einsum("bd,bkd->bk", u, cand)
    return u, scores


def bert4rec_retrieve(cfg: BERT4RecConfig, params, item_seq, seq_mask,
                      cand_ids):
    """Score the last position against candidate items (batched dot)."""
    hid = bert4rec_encode(cfg, params, item_seq, seq_mask)
    u = hid[:, -1]                                     # [B, D]
    cand = params["item_embed"][cand_ids]              # [C, D]
    return u @ cand.T                                  # [B, C]


# ----------------------------------------------------------------- dlrm

@dataclasses.dataclass(frozen=True)
class DLRMConfig:
    name: str = "dlrm-rm2"
    n_dense: int = 13
    n_sparse: int = 26
    embed_dim: int = 64
    vocab: int = 10_000_000
    bot_mlp: tuple = (512, 256, 64)
    top_mlp: tuple = (512, 512, 256, 1)


def dlrm_init(cfg: DLRMConfig, key):
    k1, k2, k3 = jax.random.split(key, 3)
    f = cfg.n_sparse + 1
    n_inter = f * (f - 1) // 2
    top_in = cfg.bot_mlp[-1] + n_inter
    return {
        "tables": jax.random.normal(
            k1, (cfg.n_sparse, cfg.vocab, cfg.embed_dim)) * 0.01,
        "bot": _mlp_params(k2, (cfg.n_dense,) + cfg.bot_mlp),
        "top": _mlp_params(k3, (top_in,) + cfg.top_mlp),
    }


def dot_interaction(vecs: jax.Array) -> jax.Array:
    """vecs [B, F, D] -> strictly-lower-triangular pairwise dots [B, F(F-1)/2]."""
    f = vecs.shape[1]
    z = jnp.einsum("bfd,bgd->bfg", vecs, vecs)
    iu, ju = jnp.tril_indices(f, -1)
    return z[:, iu, ju]


def dlrm_forward_from_emb(cfg: DLRMConfig, params, dense, emb):
    """Forward given pre-gathered embeddings [B, F, D] — the grad entry
    point for lazy/row-wise table optimizers."""
    bot = _mlp(params["bot"], dense, final_act=True)                 # [B, 64]
    vecs = jnp.concatenate([bot[:, None], emb], 1)                   # [B,27,64]
    inter = dot_interaction(vecs)
    top_in = jnp.concatenate([bot, inter], -1)
    return _mlp(params["top"], top_in)[:, 0]


def dlrm_forward(cfg: DLRMConfig, params, dense, ids):
    emb = lookup_fields(params["tables"], ids)                       # [B,26,64]
    return dlrm_forward_from_emb(cfg, params, dense, emb)


def rowwise_adagrad_update(tables, acc, ids, g_emb, lr=0.01, eps=1e-8):
    """Lazy row-wise AdaGrad (the production DLRM optimizer): only the
    B*F touched rows are read/updated and the accumulator is one scalar
    per ROW ([F, V] instead of [F, V, D]) — vs dense Adam which streams
    the entire [F, V, D] table plus two moments every step.

    tables [F, V, D]; acc [F, V]; ids [B, F]; g_emb [B, F, D].
    """
    g2 = (g_emb.astype(jnp.float32) ** 2).mean(-1)                   # [B, F]

    def per_field(tab, a, col, g, gsq):
        a = a.at[col].add(gsq)                                       # [V]
        scale = jax.lax.rsqrt(a[col] + eps)                          # [B]
        tab = tab.at[col].add((-lr * scale[:, None] * g).astype(tab.dtype))
        return tab, a

    return jax.vmap(per_field, in_axes=(0, 0, 1, 1, 1))(
        tables, acc, ids, g_emb, g2)


def dlrm_retrieve(cfg: DLRMConfig, params, dense, ids, cand_ids):
    """1 user x C candidates: user-side compute once, swap field 0.
    dense [1, 13]; ids [1, 26]; cand_ids [C]."""
    bot = _mlp(params["bot"], dense, final_act=True)                 # [1, 64]
    emb = lookup_fields(params["tables"], ids)                       # [1,26,64]
    cand = params["tables"][0][cand_ids]                             # [C, 64]
    c = cand_ids.shape[0]
    vecs = jnp.concatenate([bot[:, None], emb], 1)                   # [1,27,64]
    vecs = jnp.broadcast_to(vecs, (c,) + vecs.shape[1:])
    vecs = vecs.at[:, 1].set(cand)                                   # swap item field
    inter = dot_interaction(vecs)
    top_in = jnp.concatenate([jnp.broadcast_to(bot, (c, bot.shape[1])),
                              inter], -1)
    return _mlp(params["top"], top_in)[:, 0]
