"""Sort-based top-k MoE dispatch (MegaBlocks-style, dense-capacity form).

The GShard one-hot dispatch tensor [T, E, C] is infeasible at kimi scale
(1M tokens x 384 experts); instead tokens are argsorted by expert id, a
rank-within-expert gives each (token, slot) a capacity position, and
overflow tokens are dropped into a scratch row (position C) that is
sliced off — the standard static-shape JAX formulation.  Expert compute
is a batched einsum over the expert axis, which GSPMD shards over the
mesh 'model' axis (expert parallelism with all_to_all at the
scatter/gather boundaries).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _axis_size(axis_name: str) -> int:
    """Size of a mesh axis from the ambient physical mesh (0 if absent)."""
    try:
        import jax._src.mesh as mesh_lib
        env_mesh = mesh_lib.thread_resources.env.physical_mesh
        if env_mesh.empty:
            return 0
        return env_mesh.shape.get(axis_name, 0)
    except Exception:  # noqa: BLE001
        return 0


def moe_ffn(cfg, x, w):
    """x [B, S, D] -> [B, S, D] through top-k routed experts."""
    b, s, d = x.shape
    t = b * s
    e, k, f = cfg.n_experts, cfg.top_k, cfg.moe_d_ff
    xt = x.reshape(t, d)
    # the [B(dp), S(tp), D] -> [T, D] reshape is inexpressible in GSPMD
    # shardings; without an explicit pin the whole dispatch chain
    # replicates (~10 live [T, D] f32 buffers per device at 131k tokens)
    from repro.dist.hints import constrain as _constrain
    xt = _constrain(xt, "dp+tp", None)

    gate_logits = (xt.astype(jnp.float32) @ w["router"])          # [T, E]
    topw, topi = jax.lax.top_k(gate_logits, k)                     # [T, K]
    topw = jax.nn.softmax(topw, axis=-1).astype(x.dtype)

    cap = int((t * k) / e * cfg.capacity_factor) + 1

    flat_e = topi.reshape(-1)                                      # [T*K]
    order = jnp.argsort(flat_e)                                    # stable
    sorted_e = flat_e[order]
    # rank within expert = index - first occurrence of this expert id
    first = jnp.searchsorted(sorted_e, sorted_e, side="left")
    pos = jnp.arange(t * k) - first
    keep = pos < cap
    pos_c = jnp.where(keep, pos, cap)                              # overflow row
    tok_of = order // k

    # GSPMD-friendly dispatch: scatters touch only small int32 tables;
    # all D-wide data movement is gathers (a row-wise scatter of [E,C,D]
    # makes the SPMD partitioner replicate operand-sized index tensors).
    tok_table = jnp.full((e, cap + 1), t, jnp.int32)
    tok_table = tok_table.at[sorted_e, pos_c].set(tok_of)          # [E, C+1]
    xt_pad = jnp.concatenate([xt, jnp.zeros((1, d), x.dtype)])     # row t = 0
    x_disp = xt_pad[tok_table[:, :cap]]                            # [E, C, D]
    # EP when experts divide 'model' (kimi), else capacity over dp (mixtral)
    from repro.dist.hints import constrain, get_hints
    h = get_hints()
    tp_sz = _axis_size(h["tp"]) if h is not None else 0
    ep = tp_sz > 0 and e % tp_sz == 0
    # EP: experts over 'model' AND capacity over dp (2D) so the dispatch
    # gather never replicates a [E, C, D] copy per device
    x_disp = constrain(x_disp, "tp" if ep else None, "dp", None)

    # expert compute (batched over E -> GSPMD shards this axis)
    if cfg.activation == "swiglu":
        up = jnp.einsum("ecd,edf->ecf", x_disp, w["w_up"])
        gate = jax.nn.silu(
            jnp.einsum("ecd,edf->ecf", x_disp, w["w_gate"]).astype(jnp.float32)
        ).astype(x.dtype)
        h = up * gate
    else:
        h = jax.nn.gelu(
            jnp.einsum("ecd,edf->ecf", x_disp, w["w_up"]).astype(jnp.float32)
        ).astype(x.dtype)
    y_exp = jnp.einsum("ecf,efd->ecd", h, w["w_down"])             # [E, C, D]
    y_exp = constrain(y_exp, "tp" if ep else None, "dp", None)

    # combine: map each (token, slot) to its capacity position via a
    # small int32 scatter, then gather its expert output row
    pos_flat = jnp.full((t * k,), cap, jnp.int32).at[order].set(pos_c)
    y_pad = jnp.concatenate([y_exp, jnp.zeros((e, 1, d), x.dtype)], axis=1)
    y_sorted = y_pad[flat_e, pos_flat]                             # [T*K, D]
    y_sorted = _constrain(y_sorted, "dp+tp", None)
    y_flat = y_sorted.reshape(t, k, d)                             # [T, K, D]
    y = jnp.sum(y_flat * topw[..., None], axis=1)                  # [T, D]
    y = _constrain(y, "dp+tp", None)

    if cfg.shared_experts:
        # shared expert: always-on FFN branch (no separate gate matrix)
        up = xt @ w["ws_up"]
        act = jax.nn.silu(up.astype(jnp.float32)).astype(x.dtype)
        y = y + act @ w["ws_down"]
    return y.reshape(b, s, d)


def load_balance_loss(gate_logits: jax.Array, topi: jax.Array, e: int):
    """Switch-style aux loss: E * sum_e (frac_tokens_e * mean_prob_e)."""
    probs = jax.nn.softmax(gate_logits, -1)
    counts = jnp.zeros((e,), jnp.float32).at[topi.reshape(-1)].add(1.0)
    frac = counts / counts.sum()
    return e * jnp.sum(frac * probs.mean(0))
