"""Decoder-only transformer backbone covering the five assigned LM archs:

  nemotron-4-340b  GQA kv8, squared-ReLU FFN
  gemma2-2b        GQA kv4, alternating local(4096)/global attn, softcaps
  granite-3-8b     GQA kv8, SwiGLU
  mixtral-8x7b     GQA kv8, SWA(4096), MoE 8e top-2
  kimi-k2-1t-a32b  GQA kv8, MoE 384e top-8 (+1 shared), SwiGLU

Layers are stacked [L, ...] and applied with lax.scan (+remat), so HLO
size and compile time are depth-independent — required for the 96-layer
340B dry-run.  Train steps use gradient (micro-batch) accumulation.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.models.moe import moe_ffn


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int | None = None
    activation: str = "swiglu"            # swiglu | squared_relu | gelu
    attn_type: str = "full"               # full | swa | local_global
    window: int = 4096
    attn_softcap: float | None = None     # gemma2: 50.0
    final_softcap: float | None = None    # gemma2: 30.0
    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    shared_experts: int = 0
    capacity_factor: float = 1.25
    # numerics / training
    dtype: str = "bfloat16"
    rope_theta: float = 10000.0

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def param_count(self) -> int:
        d, f, v = self.d_model, self.d_ff, self.vocab
        h, kv, dh = self.n_heads, self.n_kv_heads, self.head_dim
        attn = d * h * dh + 2 * d * kv * dh + h * dh * d
        if self.is_moe:
            fmoe = self.moe_d_ff
            n_mats = 3 if self.activation == "swiglu" else 2
            ffn = (self.n_experts + self.shared_experts) * n_mats * d * fmoe \
                + d * self.n_experts
        else:
            n_mats = 3 if self.activation == "swiglu" else 2
            ffn = n_mats * d * f
        return self.n_layers * (attn + ffn + 2 * d) + 2 * v * d + d

    def active_param_count(self) -> int:
        if not self.is_moe:
            return self.param_count()
        d = self.d_model
        h, kv, dh = self.n_heads, self.n_kv_heads, self.head_dim
        attn = d * h * dh + 2 * d * kv * dh + h * dh * d
        n_mats = 3 if self.activation == "swiglu" else 2
        ffn = (self.top_k + self.shared_experts) * n_mats * d * self.moe_d_ff \
            + d * self.n_experts
        return self.n_layers * (attn + ffn + 2 * d) + 2 * self.vocab * d + d


# ----------------------------------------------------------------- params

def init_params(cfg: TransformerConfig, key) -> dict:
    dt = jnp.dtype(cfg.dtype)
    d, dh = cfg.d_model, cfg.head_dim
    h, kv = cfg.n_heads, cfg.n_kv_heads
    l = cfg.n_layers
    ks = jax.random.split(key, 12)
    s = lambda *shape: 1.0 / jnp.sqrt(shape[-2] if len(shape) > 1 else shape[-1])

    def norm(k, *shape):
        return (jax.random.normal(k, shape, jnp.float32) * 0.02).astype(dt)

    layers = {
        "attn_norm": jnp.ones((l, d), dt),
        "ffn_norm": jnp.ones((l, d), dt),
        "wq": norm(ks[0], l, d, h * dh),
        "wk": norm(ks[1], l, d, kv * dh),
        "wv": norm(ks[2], l, d, kv * dh),
        "wo": norm(ks[3], l, h * dh, d),
    }
    if cfg.is_moe:
        e, f = cfg.n_experts, cfg.moe_d_ff
        layers["router"] = norm(ks[4], l, d, e).astype(jnp.float32)
        layers["w_up"] = norm(ks[5], l, e, d, f)
        layers["w_down"] = norm(ks[6], l, e, f, d)
        if cfg.activation == "swiglu":
            layers["w_gate"] = norm(ks[7], l, e, d, f)
        if cfg.shared_experts:
            layers["ws_up"] = norm(ks[8], l, d, cfg.shared_experts * f)
            layers["ws_down"] = norm(ks[9], l, cfg.shared_experts * f, d)
    else:
        f = cfg.d_ff
        layers["w_up"] = norm(ks[5], l, d, f)
        layers["w_down"] = norm(ks[6], l, f, d)
        if cfg.activation == "swiglu":
            layers["w_gate"] = norm(ks[7], l, d, f)
    return {
        "embed": norm(ks[10], cfg.vocab, d),
        "layers": layers,
        "final_norm": jnp.ones((d,), dt),
        "lm_head": norm(ks[11], d, cfg.vocab),
    }


# ----------------------------------------------------------------- pieces

def rmsnorm(x, w, eps=1e-6):
    x32 = x.astype(jnp.float32)
    y = x32 * jax.lax.rsqrt(jnp.mean(x32 * x32, -1, keepdims=True) + eps)
    return (y * w.astype(jnp.float32)).astype(x.dtype)


def rope(x, positions, theta):
    """x [..., S, H, dh]; positions [..., S]."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # [..., S, half]
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos],
                           -1).astype(x.dtype)


def _ffn_act(cfg, x, w):
    if cfg.activation == "swiglu":
        up = x @ w["w_up"]
        gate = jax.nn.silu((x @ w["w_gate"]).astype(jnp.float32)).astype(x.dtype)
        return (up * gate) @ w["w_down"]
    if cfg.activation == "squared_relu":
        h = jax.nn.relu(x @ w["w_up"])
        return (h * h) @ w["w_down"]
    h = jax.nn.gelu((x @ w["w_up"]).astype(jnp.float32)).astype(x.dtype)
    return h @ w["w_down"]


def _softcap(x, cap):
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


def _embed_lookup(params, tokens, s_chunk: int = 4096):
    """Embedding lookup.  Under a mesh (sharding hints active) this is a
    one-hot matmul: GSPMD partitions the contraction over the
    vocab-sharded table cleanly, whereas a gather from a sharded operand
    lowers to full-activation all-reduces (and the VJP to a scatter-add
    with operand-sized index tensors).  Long sequences are scanned in
    chunks so the one-hot buffer stays bounded (unchunked, a 32k-token
    prefill materializes T*V*2 bytes — 343 TB on kimi-k2).  Plain gather
    on a single device."""
    from repro.dist.hints import constrain, get_hints
    if get_hints() is None:
        return params["embed"][tokens]
    v, d = params["embed"].shape

    def chunk_lookup(tok):
        oh = jax.nn.one_hot(tok, v, dtype=params["embed"].dtype)
        oh = constrain(oh, "dp", None, "tp")
        return oh @ params["embed"]

    b, s = tokens.shape
    if s <= s_chunk or s % s_chunk != 0:
        return chunk_lookup(tokens)
    tk = tokens.reshape(b, s // s_chunk, s_chunk).transpose(1, 0, 2)
    out = jax.lax.map(chunk_lookup, tk)           # [n_chunk, B, s_chunk, D]
    return out.transpose(1, 0, 2, 3).reshape(b, s, d)


def attention(cfg: TransformerConfig, x, w, positions, *, is_local,
              kv_cache=None, cache_pos=None):
    """x [B, S, D].  Training/prefill when kv_cache is None; decode
    (S==1) when kv_cache=(k [B,Hkv,Sc,dh], v) and cache_pos is a scalar.
    Returns (out, new_cache)."""
    b, s, d = x.shape
    h, kvh, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ w["wq"]).reshape(b, s, h, dh)
    k = (x @ w["wk"]).reshape(b, s, kvh, dh)
    v = (x @ w["wv"]).reshape(b, s, kvh, dh)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    scale = dh ** -0.5
    rep = h // kvh

    if kv_cache is None:
        # causal (optionally banded, compute-skipped) flash attention
        from repro.models.attention import flash_attention
        out = flash_attention(q, k, v, causal=True,
                              window=cfg.window if is_local else None,
                              softcap=cfg.attn_softcap,
                              q_chunk=min(512, s), k_chunk=min(1024, s))
        return out.reshape(b, s, h * dh) @ w["wo"], (k, v)

    # decode: append to cache, attend over (windowed) cache
    ck, cv = kv_cache
    sc = ck.shape[2]
    ck = jax.lax.dynamic_update_slice(ck, k.transpose(0, 2, 1, 3),
                                      (0, 0, cache_pos, 0))
    cv = jax.lax.dynamic_update_slice(cv, v.transpose(0, 2, 1, 3),
                                      (0, 0, cache_pos, 0))
    if is_local and cfg.window < sc:
        wdw = cfg.window
        start = jnp.clip(cache_pos - wdw + 1, 0, sc - wdw)
        ks_ = jax.lax.dynamic_slice(ck, (0, 0, start, 0),
                                    (b, kvh, wdw, dh))
        vs_ = jax.lax.dynamic_slice(cv, (0, 0, start, 0),
                                    (b, kvh, wdw, dh))
        kidx = start + jnp.arange(wdw)
    else:
        ks_, vs_ = ck, cv
        kidx = jnp.arange(sc)
    kf = jnp.repeat(ks_, rep, axis=1)
    vf = jnp.repeat(vs_, rep, axis=1)
    logits = jnp.einsum("bqhd,bhkd->bhqk", q, kf).astype(jnp.float32) * scale
    logits = _softcap(logits, cfg.attn_softcap)
    valid = kidx <= cache_pos
    logits = jnp.where(valid[None, None, None, :], logits, -1e30)
    p = jax.nn.softmax(logits, -1).astype(x.dtype)
    out = jnp.einsum("bhqk,bhkd->bqhd", p, vf)
    return out.reshape(b, s, h * dh) @ w["wo"], (ck, cv)


def _layer_is_local(cfg: TransformerConfig) -> jnp.ndarray:
    if cfg.attn_type == "swa":
        return jnp.ones((cfg.n_layers,), bool)
    if cfg.attn_type == "local_global":
        return jnp.arange(cfg.n_layers) % 2 == 0
    return jnp.zeros((cfg.n_layers,), bool)


def _block(cfg, x, w, positions, is_local, kv_cache=None, cache_pos=None):
    a, new_cache = attention(cfg, rmsnorm(x, w["attn_norm"]), w, positions,
                             is_local=is_local, kv_cache=kv_cache,
                             cache_pos=cache_pos)
    x = x + a
    hnorm = rmsnorm(x, w["ffn_norm"])
    if cfg.is_moe:
        f = moe_ffn(cfg, hnorm, w)
    else:
        f = _ffn_act(cfg, hnorm, w)
    return x + f, new_cache


# ----------------------------------------------------------------- forward

def forward(cfg: TransformerConfig, params, tokens, act_constraint=None,
            final_constraint=None):
    """tokens [B, S] -> logits [B, S, V] (bf16 matmul, fp32 softcap).

    act_constraint pins the [B, S, D] activations (batch over dp) — the
    scan carry otherwise inherits the embedding's D-sharding and GSPMD
    replicates the batch dim."""
    x = _embed_lookup(params, tokens)
    if act_constraint is not None:
        x = act_constraint(x)
    positions = jnp.arange(tokens.shape[1])[None, :]
    locals_ = _layer_is_local(cfg)

    def body(x, layer):
        w, is_local = layer
        # both branches traced; mask selects (scan needs uniform body)
        if cfg.attn_type == "full":
            y, _ = _block(cfg, x, w, positions, is_local=False)
        elif cfg.attn_type == "swa":
            y, _ = _block(cfg, x, w, positions, is_local=True)
        else:
            y_loc, _ = _block(cfg, x, w, positions, is_local=True)
            y_glob, _ = _block(cfg, x, w, positions, is_local=False)
            y = jnp.where(is_local, y_loc, y_glob)
        if act_constraint is not None:
            y = act_constraint(y)
        return y, None

    body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, (params["layers"], locals_))
    x = rmsnorm(x, params["final_norm"])
    if final_constraint is not None:
        # leave sequence-parallel layout before the vocab-parallel head:
        # S(tp) x V(tp) on the same axis forces GSPMD to unshard V in the
        # head gradient otherwise
        x = final_constraint(x)
    logits = x @ params["lm_head"]
    return _softcap(logits.astype(jnp.float32), cfg.final_softcap)


def lm_loss(cfg: TransformerConfig, params, tokens, labels,
            logits_constraint=None, act_constraint=None,
            final_constraint=None):
    logits = forward(cfg, params, tokens, act_constraint=act_constraint,
                     final_constraint=final_constraint)
    if logits_constraint is not None:
        logits = logits_constraint(logits)
    # logsumexp-form CE: avoids materializing a second [.., V] logp buffer
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], -1)[..., 0] - lse
    return -jnp.mean(ll)


def train_step(cfg: TransformerConfig, opt, params, opt_state, tokens, labels,
               n_microbatches: int = 1, mb_constraint=None,
               logits_constraint=None, act_constraint=None,
               grad_dtype=jnp.float32, grad_constraint=None,
               final_constraint=None):
    """Gradient-accumulated train step (tokens [B, S]).

    mb_constraint / logits_constraint / act_constraint: optional sharding
    constraints re-pinning the microbatch slice (batch over dp), the
    logits (batch over dp, vocab over tp) and the layer activations —
    GSPMD loses the batch sharding through the reshape+scan otherwise
    and replicates the [T, V] logits.
    """
    loss_fn = partial(lm_loss, cfg, logits_constraint=logits_constraint,
                      act_constraint=act_constraint,
                      final_constraint=final_constraint)
    if n_microbatches == 1:
        loss, grads = jax.value_and_grad(loss_fn)(params, tokens, labels)
    else:
        b = tokens.shape[0]
        mb = b // n_microbatches
        tk = tokens.reshape(n_microbatches, mb, -1)
        lb = labels.reshape(n_microbatches, mb, -1)

        def acc_body(carry, xs):
            g_acc, l_acc = carry
            t, l = xs
            if mb_constraint is not None:
                t, l = mb_constraint(t), mb_constraint(l)
            loss, g = jax.value_and_grad(loss_fn)(params, t, l)
            if grad_constraint is not None:
                g = grad_constraint(g)
            g_acc = jax.tree.map(lambda a, b: a + b.astype(grad_dtype),
                                 g_acc, g)
            if grad_constraint is not None:
                # the accumulator is a scan carry: re-pin it to the param
                # shardings or GSPMD replicates it (17.6 GiB/device for
                # the 340B lm_head grad alone)
                g_acc = grad_constraint(g_acc)
            return (g_acc, l_acc + loss), None

        # grad_dtype=bf16 (with the optimizer's clipping) halves the
        # accumulator footprint — required to fit the 1T config on a pod
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, grad_dtype), params)
        if grad_constraint is not None:
            zeros = grad_constraint(zeros)
        (grads, loss), _ = jax.lax.scan(acc_body, (zeros, 0.0), (tk, lb))
        grads = jax.tree.map(lambda g: g / n_microbatches, grads)
        loss = loss / n_microbatches
    new_params, new_opt = opt.update(grads, opt_state, params)
    return new_params, new_opt, loss


# ----------------------------------------------------------------- serving

def init_kv_cache(cfg: TransformerConfig, batch: int, max_seq: int):
    dt = jnp.dtype(cfg.dtype)
    shape = (cfg.n_layers, batch, cfg.n_kv_heads, max_seq, cfg.head_dim)
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}


def prefill(cfg: TransformerConfig, params, tokens):
    """tokens [B, S] -> (logits [B, V] for last position, kv cache)."""
    x = _embed_lookup(params, tokens)
    positions = jnp.arange(tokens.shape[1])[None, :]
    locals_ = _layer_is_local(cfg)

    def body(x, layer):
        w, is_local = layer
        if cfg.attn_type == "full":
            y, kvc = _block(cfg, x, w, positions, is_local=False)
        elif cfg.attn_type == "swa":
            y, kvc = _block(cfg, x, w, positions, is_local=True)
        else:
            y_loc, kvc = _block(cfg, x, w, positions, is_local=True)
            y_glob, _ = _block(cfg, x, w, positions, is_local=False)
            y = jnp.where(is_local, y_loc, y_glob)
        k, v = kvc
        return y, (k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3))

    body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    x, (ks, vs) = jax.lax.scan(body, x, (params["layers"], locals_))
    x = rmsnorm(x[:, -1], params["final_norm"])
    logits = _softcap((x @ params["lm_head"]).astype(jnp.float32),
                      cfg.final_softcap)
    return logits, {"k": ks, "v": vs}


def decode_step(cfg: TransformerConfig, params, token, cache, cache_pos):
    """token [B, 1]; cache {'k','v'} [L, B, Hkv, S, dh]; cache_pos scalar
    int32 (same position across batch).  Returns (logits [B, V], cache)."""
    x = params["embed"][token]
    positions = jnp.full((1, 1), cache_pos, jnp.int32)
    locals_ = _layer_is_local(cfg)

    def body(x, layer):
        w, is_local, ck, cv = layer
        if cfg.attn_type == "full":
            y, (nk, nv) = _block(cfg, x, w, positions, is_local=False,
                                 kv_cache=(ck, cv), cache_pos=cache_pos)
        elif cfg.attn_type == "swa":
            y, (nk, nv) = _block(cfg, x, w, positions, is_local=True,
                                 kv_cache=(ck, cv), cache_pos=cache_pos)
        else:
            y_loc, (nk, nv) = _block(cfg, x, w, positions, is_local=True,
                                     kv_cache=(ck, cv), cache_pos=cache_pos)
            y_glob, _ = _block(cfg, x, w, positions, is_local=False,
                               kv_cache=(ck, cv), cache_pos=cache_pos)
            y = jnp.where(is_local, y_loc, y_glob)
        return y, (nk, nv)

    x, (nks, nvs) = jax.lax.scan(
        body, x, (params["layers"], locals_, cache["k"], cache["v"]))
    x = rmsnorm(x[:, -1], params["final_norm"])
    logits = _softcap((x @ params["lm_head"]).astype(jnp.float32),
                      cfg.final_softcap)
    return logits, {"k": nks, "v": nvs}
