"""Memory-bounded attention: online-softmax over K/V chunks (flash-style,
pure JAX — XLA fuses the tile loop; the Pallas fusion lives in the same
algebra).

Why it exists: a 32k prefill with materialized [B, H, S, S] scores is
~50 GiB/device on the 340B config — the tile loop caps the transient at
[B, H, q_chunk, k_chunk].

Banded windows are *compute-skipped*, not just masked: for a layer with
window W, each query chunk only visits ceil(W/k_chunk)+1 key chunks via
dynamic_slice, so SWA/local-global prefill FLOPs scale O(S*W) instead of
O(S^2) — this is what makes gemma2/mixtral `long_500k`-eligible.

GQA is computed grouped ([B, G, rep, ...]) so K/V are never materialized
repeated across query heads.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _tile(q, kt, vt, qpos, kpos, scale, softcap, causal, window, carry):
    """One (q_chunk x k_chunk) online-softmax update.
    q [B,G,R,Qc,dh]; kt/vt [B,G,Kc,dh]; carry = (m, l, acc)."""
    m, l, acc = carry
    s = jnp.einsum("bgrqd,bgkd->bgrqk", q, kt).astype(jnp.float32) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    mask = jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        mask &= kpos[None, :] > qpos[:, None] - window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    m_new = jnp.maximum(m, s.max(-1))
    p = jnp.exp(s - m_new[..., None])
    corr = jnp.exp(m - m_new)
    l_new = l * corr + p.sum(-1)
    pv = jnp.einsum("bgrqk,bgkd->bgrqd", p.astype(vt.dtype), vt)
    acc_new = acc * corr[..., None].astype(acc.dtype) + pv
    return m_new, l_new, acc_new


def flash_attention(q, k, v, *, causal=True, window=None, softcap=None,
                    q_chunk=512, k_chunk=1024, kv_offset=0):
    """q [B, Sq, H, dh]; k/v [B, Sk, G, dh] with H = G*rep.
    kv_offset: global position of k[0] (for windowed caches).
    Returns [B, Sq, H, dh]."""
    b, sq, h, dh = q.shape
    sk, g = k.shape[1], k.shape[2]
    rep = h // g
    q_chunk = min(q_chunk, sq)
    k_chunk = min(k_chunk, sk)
    nq = -(-sq // q_chunk)
    nk = -(-sk // k_chunk)
    sq_pad, sk_pad = nq * q_chunk, nk * k_chunk
    scale = dh ** -0.5

    qg = jnp.pad(q, ((0, 0), (0, sq_pad - sq), (0, 0), (0, 0)))
    qg = qg.reshape(b, nq, q_chunk, g, rep, dh).transpose(1, 0, 3, 4, 2, 5)
    kp = jnp.pad(k, ((0, 0), (0, sk_pad - sk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, sk_pad - sk), (0, 0), (0, 0)))
    kp = kp.transpose(0, 2, 1, 3)   # [B, G, Sk, dh]
    vp = vp.transpose(0, 2, 1, 3)
    kpos_all = kv_offset + jnp.arange(sk_pad)
    kvalid = jnp.arange(sk_pad) < sk

    banded = window is not None and window < sk_pad

    def q_body(qi, qc):
        qpos = kv_offset + (sk - sq) + qi * q_chunk + jnp.arange(q_chunk)
        m0 = jnp.full((b, g, rep, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, g, rep, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, g, rep, q_chunk, dh), v.dtype)

        if banded:
            # visit only the k-chunks intersecting [qpos0-window, qpos_end]
            n_vis = min(nk, window // k_chunk + 2)
            q_hi_chunk = (qi * q_chunk + (sk - sq) + q_chunk - 1) // k_chunk
            start = jnp.clip(q_hi_chunk - (n_vis - 1), 0, nk - n_vis)

            def k_body(j, carry):
                kj = start + j
                kt = jax.lax.dynamic_slice(
                    kp, (0, 0, kj * k_chunk, 0), (b, g, k_chunk, dh))
                vt = jax.lax.dynamic_slice(
                    vp, (0, 0, kj * k_chunk, 0), (b, g, k_chunk, dh))
                kpos = kv_offset + kj * k_chunk + jnp.arange(k_chunk)
                kpos = jnp.where(
                    jax.lax.dynamic_slice(kvalid, (kj * k_chunk,), (k_chunk,)),
                    kpos, jnp.iinfo(jnp.int32).max)  # mask pad keys
                return _tile(qc, kt, vt, qpos, kpos, scale, softcap,
                             causal, window, carry)

            m, l, acc = jax.lax.fori_loop(0, n_vis, k_body, (m0, l0, a0))
        else:
            def k_body(j, carry):
                kt = jax.lax.dynamic_slice(
                    kp, (0, 0, j * k_chunk, 0), (b, g, k_chunk, dh))
                vt = jax.lax.dynamic_slice(
                    vp, (0, 0, j * k_chunk, 0), (b, g, k_chunk, dh))
                kpos = kv_offset + j * k_chunk + jnp.arange(k_chunk)
                kpos = jnp.where(
                    jax.lax.dynamic_slice(kvalid, (j * k_chunk,), (k_chunk,)),
                    kpos, jnp.iinfo(jnp.int32).max)
                return _tile(qc, kt, vt, qpos, kpos, scale, softcap,
                             causal, window, carry)

            m, l, acc = jax.lax.fori_loop(0, nk, k_body, (m0, l0, a0))

        out = acc / jnp.maximum(l, 1e-20)[..., None].astype(acc.dtype)
        return out  # [B, G, R, Qc, dh]

    outs = jax.vmap(q_body, in_axes=(0, 0))(jnp.arange(nq), qg)
    # [nq, B, G, R, Qc, dh] -> [B, Sq, H, dh]
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(b, sq_pad, h, dh)
    return out[:, :sq]


def attention_ref(q, k, v, *, causal=True, window=None, softcap=None,
                  kv_offset=0):
    """Dense oracle for tests."""
    b, sq, h, dh = q.shape
    sk, g = k.shape[1], k.shape[2]
    rep = h // g
    kf = jnp.repeat(k, rep, axis=2)
    vf = jnp.repeat(v, rep, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kf).astype(jnp.float32) * dh ** -0.5
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    qpos = kv_offset + (sk - sq) + jnp.arange(sq)
    kpos = kv_offset + jnp.arange(sk)
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        mask &= kpos[None, :] > qpos[:, None] - window
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, -1).astype(v.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", p, vf)
