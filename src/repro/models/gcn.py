"""GCN (Kipf & Welling, arXiv:1609.02907) — the assigned gnn arch.

Four operating shapes:
  full_graph_sm / ogb_products : full-batch training (one SpMM per layer
    over the whole graph — the paper's single-machine full-graph regime);
  minibatch_lg : sampled-block training (fanout 15-10) — the DistDGL-style
    regime the paper compares against;
  molecule     : batched small graphs + mean readout.

GCN's message fn is a scalar-weighted copy, so message+aggregate fuse
into ONE SpMM (paper §9) — ``gspmm_copy_sum`` with the symmetric-norm
coefficient.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.graph import Graph, sym_norm_coeff
from repro.core.sparse_ops import gspmm_copy_sum


@dataclasses.dataclass(frozen=True)
class GCNConfig:
    name: str = "gcn-cora"
    n_layers: int = 2
    d_hidden: int = 16
    n_classes: int = 7
    d_feat: int = 1433
    dropout: float = 0.0   # eval-mode default; training uses rng arg


def init_params(cfg: GCNConfig, key) -> dict:
    dims = [cfg.d_feat] + [cfg.d_hidden] * (cfg.n_layers - 1) + [cfg.n_classes]
    ks = jax.random.split(key, cfg.n_layers)
    ws = []
    for l in range(cfg.n_layers):
        scale = jnp.sqrt(2.0 / dims[l])
        ws.append({"w": jax.random.normal(ks[l], (dims[l], dims[l + 1]),
                                          jnp.float32) * scale,
                   "b": jnp.zeros((dims[l + 1],), jnp.float32)})
    return {"layers": ws}


def forward(cfg: GCNConfig, params, g: Graph, x):
    """Full-graph forward: x [N, F] -> logits [N, C]."""
    coeff = sym_norm_coeff(g)
    for l, w in enumerate(params["layers"]):
        # aggregate-then-transform keeps the matmul at O(|V|) (paper O1)
        x = gspmm_copy_sum(x, g.src, g.dst, g.n_nodes, g.edge_mask, coeff)
        x = x @ w["w"] + w["b"]
        if l + 1 < cfg.n_layers:
            x = jax.nn.relu(x)
    return x


def forward_blocks(cfg: GCNConfig, params, blocks, x):
    """Sampled-block forward (deepest block first); x aligns with
    blocks[0].src_nodes rows."""
    for l, (w, b) in enumerate(zip(params["layers"], blocks)):
        src, dst, mask = b["edge_src"], b["edge_dst"], b["edge_mask"]
        n_dst = b["n_dst"]
        m = jnp.where(mask[:, None], x[src], 0)
        h = jax.ops.segment_sum(m, dst, num_segments=n_dst)
        deg = jax.ops.segment_sum(mask.astype(x.dtype), dst, num_segments=n_dst)
        x = h / jnp.maximum(deg, 1.0)[:, None]
        x = x @ w["w"] + w["b"]
        if l + 1 < cfg.n_layers:
            x = jax.nn.relu(x)
    return x


def forward_batched(cfg: GCNConfig, params, src, dst, edge_mask, x, graph_ids,
                    n_graphs: int):
    """molecule shape: node-batched small graphs.
    x [B*n, F]; src/dst index into the flat node axis; graph_ids [B*n]."""
    n = x.shape[0]
    ones = edge_mask.astype(jnp.float32)
    deg_o = jax.ops.segment_sum(ones, src, num_segments=n)
    deg_i = jax.ops.segment_sum(ones, dst, num_segments=n)
    coeff = jax.lax.rsqrt(jnp.maximum(deg_o, 1.0))[src] * \
        jax.lax.rsqrt(jnp.maximum(deg_i, 1.0))[dst]
    coeff = jnp.where(edge_mask, coeff, 0.0)
    for l, w in enumerate(params["layers"]):
        m = x[src] * coeff[:, None]
        m = jnp.where(edge_mask[:, None], m, 0)
        x = jax.ops.segment_sum(m, dst, num_segments=n)
        x = x @ w["w"] + w["b"]
        if l + 1 < cfg.n_layers:
            x = jax.nn.relu(x)
    # mean readout per graph
    pooled = jax.ops.segment_sum(x, graph_ids, num_segments=n_graphs)
    cnt = jax.ops.segment_sum(jnp.ones((n,), jnp.float32), graph_ids, num_segments=n_graphs)
    return pooled / jnp.maximum(cnt, 1.0)[:, None]


def loss_fn(cfg: GCNConfig, params, g: Graph, x, labels, label_mask):
    logits = forward(cfg, params, g, x)
    logp = jax.nn.log_softmax(logits, -1)
    ll = jnp.take_along_axis(logp, labels[:, None], -1)[:, 0]
    return -jnp.sum(ll * label_mask) / jnp.maximum(label_mask.sum(), 1.0)
