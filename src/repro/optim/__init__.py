from repro.optim.optimizers import adafactor, adam, sgd  # noqa: F401
