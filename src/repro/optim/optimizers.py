"""Functional optimizers (init/update pairs), optax-free.

adafactor exists because the 340B/1T configs cannot afford 8 bytes/param
of Adam state on 16 GiB chips — factored second moments cut optimizer
state to ~2 bytes/param + O(rows+cols), which is what makes the kimi-k2
dry-run fit (see DESIGN §6)."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    # (grads, state, params[, lr=...]) — sgd/adam accept an optional
    # per-call lr override (the large-batch schedule's epoch LR, passed
    # as a traced scalar so changing it does not retrace the step)
    update: Callable[..., tuple[Any, Any]]


def sgd(lr: float, momentum: float = 0.0) -> Optimizer:
    def init(params):
        if momentum == 0.0:
            return ()
        return jax.tree.map(jnp.zeros_like, params)

    def update(grads, state, params, lr=lr):
        if momentum == 0.0:
            new_p = jax.tree.map(lambda p, g: p - lr * g, params, grads)
            return new_p, state
        new_m = jax.tree.map(lambda m, g: momentum * m + g, state, grads)
        new_p = jax.tree.map(lambda p, m: p - lr * m, params, new_m)
        return new_p, new_m

    return Optimizer(init, update)


def adam(lr: float, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
         lr_fn: Callable[[jax.Array], jax.Array] | None = None) -> Optimizer:
    def init(params):
        return {
            "m": jax.tree.map(jnp.zeros_like, params),
            "v": jax.tree.map(jnp.zeros_like, params),
            "t": jnp.zeros((), jnp.int32),
        }

    base_lr = lr

    def update(grads, state, params, lr=None):
        t = state["t"] + 1
        if lr is not None:
            step_lr = lr
        else:
            step_lr = lr_fn(t) if lr_fn is not None else base_lr
        m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
        v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
        bc1 = 1 - b1 ** t.astype(jnp.float32)
        bc2 = 1 - b2 ** t.astype(jnp.float32)
        new_p = jax.tree.map(
            lambda p, m_, v_: p - step_lr * (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps),
            params, m, v)
        return new_p, {"m": m, "v": v, "t": t}

    return Optimizer(init, update)


def adafactor(lr: float = 1e-2, eps: float = 1e-30,
              clip_threshold: float = 1.0) -> Optimizer:
    """Factored second moments for matrices; full for vectors/scalars."""

    def _factored(shape) -> bool:
        return len(shape) >= 2

    def init(params):
        def leaf(p):
            if _factored(p.shape):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return {"v": jnp.zeros_like(p, dtype=jnp.float32)}
        return {"s": jax.tree.map(leaf, params),
                "t": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        t = state["t"] + 1
        beta2 = 1.0 - t.astype(jnp.float32) ** -0.8

        def leaf(g, s, p):
            # row/col second moments via f32-accumulated reductions — no
            # materialized f32 copy of g (a [L, D, F] f32 temp per leaf is
            # ~2 GiB/device on the 340B/1T configs)
            n_last = g.shape[-1] if g.ndim else 1
            if _factored(p.shape):
                g2r = jnp.einsum("...rc,...rc->...r", g, g,
                                 preferred_element_type=jnp.float32) / n_last
                g2c = jnp.einsum("...rc,...rc->...c", g, g,
                                 preferred_element_type=jnp.float32) / g.shape[-2]
                vr = beta2 * s["vr"] + (1 - beta2) * (g2r + eps)
                vc = beta2 * s["vc"] + (1 - beta2) * (g2c + eps)
                # rank-1 reconstruction of the second moment
                denom = vr[..., :, None] * vc[..., None, :] / jnp.maximum(
                    vr.mean(-1)[..., None, None], eps)
                scale = jax.lax.rsqrt(jnp.maximum(denom, eps))
                rms2 = jnp.einsum("...rc,...rc->", g, g * scale.astype(g.dtype) ** 2,
                                  preferred_element_type=jnp.float32) / float(g.size)
                new_s = {"vr": vr, "vc": vc}
            else:
                g32 = g.astype(jnp.float32)
                v = beta2 * s["v"] + (1 - beta2) * (g32 * g32 + eps)
                scale = jax.lax.rsqrt(jnp.maximum(v, eps))
                rms2 = jnp.mean((g32 * scale) ** 2)
                new_s = {"v": v}
            clip = jnp.maximum(1.0, jnp.sqrt(rms2 + eps) / clip_threshold)
            upd = (g * scale.astype(g.dtype)) / clip.astype(g.dtype)
            return (p - lr * upd).astype(p.dtype), new_s

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_s = tdef.flatten_up_to(state["s"])
        out = [leaf(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
        new_p = tdef.unflatten([o[0] for o in out])
        new_s = tdef.unflatten([o[1] for o in out])
        return new_p, {"s": new_s, "t": t}

    return Optimizer(init, update)


@dataclasses.dataclass(frozen=True)
class WarmupLinearLR:
    """LR ramp used with the large-batch schedule (paper §7.1 pairs the
    warm-up batch with linearly-scaled LR)."""
    peak_lr: float
    warmup_steps: int

    def __call__(self, t: jax.Array) -> jax.Array:
        tf = t.astype(jnp.float32)
        return self.peak_lr * jnp.minimum(1.0, tf / max(self.warmup_steps, 1))


def global_norm_clip(grads, max_norm: float):
    norm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                        for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: g * scale, grads), norm
