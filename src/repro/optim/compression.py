"""Gradient compression for the slow (cross-pod / DCN) axis.

Two schemes, both with error feedback so compression error is carried,
not lost:

  * int8 stochastic-rounding quantization (8x byte reduction on the
    wire): q = round_s(g/scale), all-reduce int32-accumulated, dequant.
  * top-k magnitude sparsification (send k values + indices).

Used by the runtime when ``config.grad_compression`` is set; the roofline
collective term scales down accordingly (§Perf logs the before/after).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


def quantize_int8(g: jax.Array, key: jax.Array):
    """Symmetric per-tensor int8 with stochastic rounding."""
    scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-12
    x = g / scale
    lo = jnp.floor(x)
    p = x - lo
    r = jax.random.uniform(key, g.shape)
    q = (lo + (r < p)).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum_int8(g: jax.Array, key: jax.Array, axis: str):
    """Quantize -> psum (int32 accumulate) -> dequant.  Scales are
    max-reduced so every participant dequantizes consistently."""
    scale = jax.lax.pmax(jnp.max(jnp.abs(g)) / 127.0 + 1e-12, axis)
    x = g / scale
    lo = jnp.floor(x)
    p = x - lo
    r = jax.random.uniform(key, g.shape)
    q = (lo + (r < p)).astype(jnp.int8)
    total = jax.lax.psum(q.astype(jnp.int32), axis)
    return total.astype(jnp.float32) * scale


def topk_sparsify(g: jax.Array, k: int):
    """Flatten, keep k largest-|.|, return (values, indices, residual)."""
    flat = g.reshape(-1)
    vals, idx = jax.lax.top_k(jnp.abs(flat), k)
    kept = flat[idx]
    residual = flat.at[idx].set(0.0).reshape(g.shape)
    return kept, idx, residual


def topk_densify(vals: jax.Array, idx: jax.Array, shape) -> jax.Array:
    size = 1
    for s in shape:
        size *= s
    return jnp.zeros((size,), vals.dtype).at[idx].add(vals).reshape(shape)


class ErrorFeedback:
    """Carry compression residuals across steps: g_eff = g + e_{t-1};
    e_t = g_eff - decompress(compress(g_eff))."""

    @staticmethod
    def init(params):
        return jax.tree.map(jnp.zeros_like, params)

    @staticmethod
    def apply(grads, errors, compress_fn):
        """compress_fn(g) -> (g_hat, new_error); returns (g_hats, errors)."""
        out = jax.tree.map(lambda g, e: compress_fn(g + e), grads, errors,
                           is_leaf=lambda x: isinstance(x, jnp.ndarray))
        g_hat = jax.tree.map(lambda t: t[0], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_e = jax.tree.map(lambda t: t[1], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        return g_hat, new_e


def make_topk_compressor(frac: float):
    def compress(g):
        k = max(1, int(g.size * frac))
        vals, idx, residual = topk_sparsify(g, k)
        g_hat = topk_densify(vals, idx, g.shape)
        return g_hat, residual
    return compress


def make_int8_compressor(key: jax.Array):
    holder = {"key": key}

    def compress(g):
        holder["key"], sub = jax.random.split(holder["key"])
        q, scale = quantize_int8(g, sub)
        g_hat = dequantize_int8(q, scale)
        return g_hat, g - g_hat
    return compress
