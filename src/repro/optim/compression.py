"""Compression primitives for the slow links: collectives and storage.

Every byte moved over a capacity-tier link (Optane, PCIe host link, the
cross-device ring) is on the paper's critical path, so this module
shrinks them two ways, both with error feedback so compression error is
carried, not lost:

  * int8 stochastic-rounding quantization (4-8x byte reduction on the
    wire): q = round_s(g/scale), all-reduce int32-accumulated, dequant;
  * top-k magnitude sparsification (exchange k values + indices).

Consumers (wired by ``repro.api.CompressionCfg`` — the spec section the
engine threads through ``PipelineConfig``):

  ``pipeline.compress.GradCompressor``  — the per-step gradient
      exchange (``compression.grads``: int8 psum / top-k all-gather,
      ``ErrorFeedback`` residuals carried in the training state);
  ``memory.executor.TieredExecutor``    — int8 storage for
      capacity-tier embedding tables (``compression.embed_store``),
      fp32 dequant-on-gather via ``quantize_rows_int8``;
  ``dist.ring_spmm``                    — int8 ring payload rotation
      (``compression.ring``).

The roofline/fig7 collective and capacity-tier byte terms scale down by
the active scheme (``benchmarks`` emits the before/after as
``BENCH_compression.json``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "quantize_int8", "dequantize_int8", "compressed_psum_int8",
    "psum_int8_with_residual", "topk_sparsify", "topk_densify",
    "topk_allgather_sum", "quantize_rows_int8", "dequantize_rows_int8",
    "ErrorFeedback", "make_topk_compressor", "make_int8_compressor",
    "wire_bytes",
]


def quantize_int8(g: jax.Array, key: jax.Array):
    """Symmetric per-tensor int8 with stochastic rounding."""
    scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-12
    x = g / scale
    lo = jnp.floor(x)
    p = x - lo
    r = jax.random.uniform(key, g.shape)
    q = (lo + (r < p)).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum_int8(g: jax.Array, key: jax.Array, axis: str):
    """Quantize -> psum (int32 accumulate) -> dequant.  Scales are
    max-reduced so every participant dequantizes consistently."""
    scale = jax.lax.pmax(jnp.max(jnp.abs(g)) / 127.0 + 1e-12, axis)
    x = g / scale
    lo = jnp.floor(x)
    p = x - lo
    r = jax.random.uniform(key, g.shape)
    q = (lo + (r < p)).astype(jnp.int8)
    total = jax.lax.psum(q.astype(jnp.int32), axis)
    return total.astype(jnp.float32) * scale


def psum_int8_with_residual(g: jax.Array, key: jax.Array, axis):
    """``compressed_psum_int8`` that also returns the *local* residual
    ``g - dequant(q)`` — the error-feedback carry for the next step.
    Same shared pmax scale, so every participant dequantizes (and
    accounts its residual) consistently."""
    scale = jax.lax.pmax(jnp.max(jnp.abs(g)) / 127.0 + 1e-12, axis)
    x = g / scale
    lo = jnp.floor(x)
    p = x - lo
    r = jax.random.uniform(key, g.shape)
    q = lo + (r < p)
    total = jax.lax.psum(q.astype(jnp.int8).astype(jnp.int32), axis)
    return total.astype(jnp.float32) * scale, g - q * scale


def topk_allgather_sum(vals: jax.Array, idx: jax.Array, shape, axis):
    """The top-k exchange: all-gather every participant's (values,
    indices) — 2k entries per device on the wire instead of the dense
    tensor — and densify-sum them into the combined gradient.  Colliding
    indices accumulate, matching an exact sum of the sparsified
    tensors."""
    vals_all = jax.lax.all_gather(vals, axis)
    idx_all = jax.lax.all_gather(idx, axis)
    return topk_densify(vals_all.reshape(-1), idx_all.reshape(-1), shape)


def topk_sparsify(g: jax.Array, k: int):
    """Flatten, keep k largest-|.|, return (values, indices, residual)."""
    flat = g.reshape(-1)
    vals, idx = jax.lax.top_k(jnp.abs(flat), k)
    kept = flat[idx]
    residual = flat.at[idx].set(0.0).reshape(g.shape)
    return kept, idx, residual


def topk_densify(vals: jax.Array, idx: jax.Array, shape) -> jax.Array:
    size = 1
    for s in shape:
        size *= s
    return jnp.zeros((size,), vals.dtype).at[idx].add(vals).reshape(shape)


class ErrorFeedback:
    """Carry compression residuals across steps: g_eff = g + e_{t-1};
    e_t = g_eff - decompress(compress(g_eff))."""

    @staticmethod
    def init(params):
        return jax.tree.map(jnp.zeros_like, params)

    @staticmethod
    def apply(grads, errors, compress_fn):
        """compress_fn(g) -> (g_hat, new_error); returns (g_hats, errors)."""
        out = jax.tree.map(lambda g, e: compress_fn(g + e), grads, errors,
                           is_leaf=lambda x: isinstance(x, jnp.ndarray))
        g_hat = jax.tree.map(lambda t: t[0], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_e = jax.tree.map(lambda t: t[1], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        return g_hat, new_e


def make_topk_compressor(frac: float):
    def compress(g):
        k = max(1, int(g.size * frac))
        vals, idx, residual = topk_sparsify(g, k)
        g_hat = topk_densify(vals, idx, g.shape)
        return g_hat, residual
    return compress


def make_int8_compressor(key: jax.Array):
    holder = {"key": key}

    def compress(g):
        holder["key"], sub = jax.random.split(holder["key"])
        q, scale = quantize_int8(g, sub)
        g_hat = dequantize_int8(q, scale)
        return g_hat, g - g_hat
    return compress


# ---------------------------------------------------------------- storage
def quantize_rows_int8(table: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Symmetric per-row int8 for capacity-tier table *storage* (host
    side, deterministic round-to-nearest — storage must round-trip
    reproducibly, unlike the stochastic collective path).  Returns
    (q [N, D] int8, scale [N, 1] float32); max abs reconstruction error
    is scale/2 per element, so always <= the row's quantization scale."""
    table = np.asarray(table, np.float32)
    scale = (np.abs(table).max(axis=-1, keepdims=True) / 127.0
             + 1e-12).astype(np.float32)
    q = np.clip(np.rint(table / scale), -127, 127).astype(np.int8)
    return q, scale


def dequantize_rows_int8(q: np.ndarray, scale: np.ndarray) -> np.ndarray:
    return q.astype(np.float32) * scale


# ---------------------------------------------------------------- pricing
def wire_bytes(n_elements: int, scheme: str, frac: float = 0.01,
               dtype_bytes: int = 4) -> int:
    """Bytes one participant puts on the wire (or on the capacity tier)
    for an ``n_elements`` tensor under a compression scheme — the term
    the planner, roofline, and fig7 scale by.  'int8' pays 1 byte per
    element plus one fp32 scale; 'topk' pays (value + int32 index) per
    kept entry; 'none'/'fp32' pay the dense dtype."""
    if scheme in ("none", "fp32"):
        return int(n_elements) * dtype_bytes
    if scheme == "int8":
        return int(n_elements) + 4
    if scheme == "topk":
        k = max(1, int(n_elements * frac))
        return k * (dtype_bytes + 4)
    raise ValueError(f"unknown compression scheme {scheme!r}; "
                     "known: none, int8, topk")
