"""TieredMemoryPlanner — the paper's Optane guidance productized for TPU.

The paper's §5-§6 problem: a fast small tier (DRAM; here HBM, 819 GB/s,
16 GiB/chip) and a slow big tier (Optane; here host DRAM over PCIe,
~16 GB/s effective, asymmetric R/W like Optane's 40%/20%), and a set of
tensors whose traffic profile decides where each should live.  The paper
solved it by hand per kernel (AppDirect + numactl); §8.1 points at
AutoTM's ILP as the automated future.  We ship that automation:

  * every tensor registers an AccessProfile (bytes, reads/step,
    writes/step, access granularity);
  * the planner scores each tensor by the *step-time penalty per byte* of
    demoting it to the slow tier, exactly the quantity the paper's Fig 8
    measures (write-heavy tensors are penalized by the write-bandwidth
    asymmetry — SDDMM outputs hurt most, mirroring its 7.7x slowdown);
  * greedy knapsack: keep the highest-penalty tensors in HBM until the
    budget runs out (optimal here because cost is additive and the only
    constraint is capacity — a classic density-ordered fractional
    knapsack rounded down, plus an exact DP for small tensor counts);
  * emits per-tensor JAX sharding/memory_kind assignments plus the
    per-kernel write-policy table (streaming vs accumulate).

Placement granularity is whole tensors (pages in the paper; per-tensor is
the JAX-addressable unit — the paper's page-granular AppDirect beats
cacheline-granular Memory Mode for the same reason: GNNRecSys access size
is an embedding row, hundreds of bytes).
"""
from __future__ import annotations

import dataclasses

# Tier bandwidths (bytes/s).  HBM per TPU v5e chip; host link = PCIe gen3
# x16-ish effective, with Optane-like R/W asymmetry on the slow tier.
HBM_BW_READ = 819e9
HBM_BW_WRITE = 819e9
HOST_BW_READ = 16e9
HOST_BW_WRITE = 8e9          # slow tier writes are ~half of reads (Optane-like)
HBM_CAPACITY = 16 * 2**30    # per chip
DEFAULT_HOST_CAPACITY = 512 * 2**30


@dataclasses.dataclass(frozen=True)
class AccessProfile:
    """Static per-step traffic descriptor for one tensor."""
    name: str
    nbytes: int
    reads_per_step: float = 1.0     # full-tensor read equivalents
    writes_per_step: float = 0.0    # full-tensor write equivalents
    access_size: int = 512          # bytes per touch (embedding row, tile, ...)
    pinned: str | None = None       # force 'hbm' or 'host'

    def step_traffic(self) -> tuple[float, float]:
        return (self.nbytes * self.reads_per_step,
                self.nbytes * self.writes_per_step)


def _slow_tier_penalty(p: AccessProfile) -> float:
    """Extra seconds/step if this tensor is demoted to the slow tier.

    Small-access-size tensors are additionally penalized: like Optane,
    the host link only reaches peak bandwidth at >=256B transfers
    (paper Fig 7b); we model utilization = min(1, access/256)."""
    rd, wr = p.step_traffic()
    util = min(1.0, p.access_size / 256.0)
    t_fast = rd / HBM_BW_READ + wr / HBM_BW_WRITE
    t_slow = rd / (HOST_BW_READ * util) + wr / (HOST_BW_WRITE * util)
    return t_slow - t_fast


@dataclasses.dataclass
class Placement:
    tier: str                 # 'hbm' | 'host'
    penalty_s: float          # step-time cost if demoted (0 when pinned)


@dataclasses.dataclass
class Plan:
    placements: dict[str, Placement]
    hbm_used: int
    hbm_budget: int
    est_step_penalty_s: float  # total slow-tier penalty actually incurred

    def tier(self, name: str) -> str:
        return self.placements[name].tier

    def memory_kind(self, name: str) -> str:
        return {"hbm": "device", "host": "pinned_host"}[self.tier(name)]


def plan_placement(profiles: list[AccessProfile], hbm_budget: int = HBM_CAPACITY,
                   host_budget: int = DEFAULT_HOST_CAPACITY,
                   exact_threshold: int = 16) -> Plan:
    """Per-tensor tier placement.  Exact knapsack (AutoTM-style) when the
    free-tensor count is small (the realistic case: tens of named
    tensors per model); greedy density-ordered beyond that."""
    n_free = sum(1 for p in profiles if p.pinned is None)
    if 0 < n_free <= exact_threshold:
        plan = plan_placement_exact(profiles, hbm_budget=hbm_budget)
        host_used = sum(p.nbytes for p in profiles
                        if plan.placements[p.name].tier == "host")
        if host_used > host_budget:
            raise MemoryError("host tier over budget")
        return plan
    placements: dict[str, Placement] = {}
    hbm_used = 0
    host_used = 0
    # pinned first
    free: list[tuple[float, AccessProfile]] = []
    for p in profiles:
        if p.pinned == "hbm":
            placements[p.name] = Placement("hbm", 0.0)
            hbm_used += p.nbytes
        elif p.pinned == "host":
            placements[p.name] = Placement("host", 0.0)
            host_used += p.nbytes
        else:
            free.append((_slow_tier_penalty(p) / max(p.nbytes, 1), p))
    if hbm_used > hbm_budget:
        raise MemoryError(f"pinned tensors ({hbm_used/2**30:.1f} GiB) exceed "
                          f"HBM budget ({hbm_budget/2**30:.1f} GiB)")
    # highest penalty-density first into HBM
    free.sort(key=lambda t: -t[0])
    total_penalty = 0.0
    for _, p in free:
        pen = _slow_tier_penalty(p)
        if hbm_used + p.nbytes <= hbm_budget:
            placements[p.name] = Placement("hbm", pen)
            hbm_used += p.nbytes
        else:
            if host_used + p.nbytes > host_budget:
                raise MemoryError(f"tensor {p.name} fits neither tier")
            placements[p.name] = Placement("host", pen)
            host_used += p.nbytes
            total_penalty += pen
    return Plan(placements, hbm_used, hbm_budget, total_penalty)


def plan_placement_exact(profiles: list[AccessProfile],
                         hbm_budget: int = HBM_CAPACITY) -> Plan:
    """Exact 0/1-knapsack DP (small tensor counts only) — the AutoTM-style
    ILP answer, used in tests to certify the greedy plan."""
    free = [p for p in profiles if p.pinned is None]
    if len(free) > 24:
        raise ValueError("exact planner is for small tensor counts")
    pinned_hbm = sum(p.nbytes for p in profiles if p.pinned == "hbm")
    if pinned_hbm > hbm_budget:
        raise MemoryError("pinned tensors exceed HBM budget")
    best_keep: tuple[float, tuple[int, ...]] = (-1.0, ())
    import itertools
    for keep in itertools.product([0, 1], repeat=len(free)):
        size = sum(p.nbytes for p, k in zip(free, keep) if k)
        pinned_size = sum(p.nbytes for p in profiles if p.pinned == "hbm")
        if size + pinned_size > hbm_budget:
            continue
        value = sum(_slow_tier_penalty(p) for p, k in zip(free, keep) if k)
        if value > best_keep[0]:
            best_keep = (value, keep)
    placements = {}
    hbm_used = 0
    penalty = 0.0
    for p in profiles:
        if p.pinned:
            placements[p.name] = Placement(p.pinned, 0.0)
            if p.pinned == "hbm":
                hbm_used += p.nbytes
    for p, k in zip(free, best_keep[1]):
        pen = _slow_tier_penalty(p)
        if k:
            placements[p.name] = Placement("hbm", pen)
            hbm_used += p.nbytes
        else:
            placements[p.name] = Placement("host", pen)
            penalty += pen
    return Plan(placements, hbm_used, hbm_budget, penalty)


# ---------------------------------------------------------------------------
# Workload profile builders (used by configs and benchmarks)

def gnn_recsys_profiles(n_users: int, n_items: int, n_edges: int,
                        embed_dim: int, n_layers: int,
                        dtype_bytes: int = 4) -> list[AccessProfile]:
    """Paper §2.1 memory model: len(m)*|E| per layer for messages,
    len(x)*|V| for embeddings, doubled for training (grads)."""
    v = n_users + n_items
    row = embed_dim * dtype_bytes
    out = [
        AccessProfile("embeddings", v * row, reads_per_step=2 * n_layers,
                      writes_per_step=2.0, access_size=row),
        AccessProfile("embed_grads", v * row, reads_per_step=1.0,
                      writes_per_step=2 * n_layers, access_size=row),
        AccessProfile("opt_state", 2 * v * row, reads_per_step=1.0,
                      writes_per_step=1.0, access_size=row),
        AccessProfile("graph_coo", 2 * n_edges * 8, reads_per_step=2 * n_layers,
                      writes_per_step=0.0, access_size=8),
    ]
    for l in range(n_layers):
        # SDDMM output: written once (streaming), read once by SpMM; and
        # re-read/re-written in backward.
        out.append(AccessProfile(f"messages_l{l}", n_edges * row,
                                 reads_per_step=2.0, writes_per_step=2.0,
                                 access_size=row))
        out.append(AccessProfile(f"activations_l{l}", v * row,
                                 reads_per_step=2.0, writes_per_step=2.0,
                                 access_size=row))
    return out
