"""DEPRECATED shim — the tiered-memory planner moved to ``repro.memory``.

The redesigned subsystem replaces this module's hardcoded two-tier
constants with a declarative, registered ``TierTopology``
(``repro.memory.get_topology``), its single greedy/exact planner pair
with a named ``PlacementPolicy`` registry, and its advisory placement
with a functional ``TieredExecutor``.  Everything below delegates to
the new package on the ``tpu-hbm-host`` preset (whose tiers carry
exactly the bandwidth/capacity values these constants hardcoded), so
legacy callers keep identical numbers:

  * ``AccessProfile`` / ``gnn_recsys_profiles`` — re-exported from
    ``repro.memory.profiles``;
  * ``plan_placement`` / ``plan_placement_exact`` — the ``greedy`` /
    ``exact`` policies on the default topology.  One behavioural fix
    rides the delegation: tensors pinned to the slow tier now
    contribute their *real* step penalty to ``est_step_penalty_s``
    (they used to count 0.0);
  * the ``HBM_*`` / ``HOST_*`` constants — read off the preset's tiers.

New code should use ``repro.memory`` directly.
"""
from __future__ import annotations

import warnings

from repro.memory.policies import (Placement, Plan, place_exact,  # noqa: F401
                                   place_greedy)
from repro.memory.profiles import (AccessProfile,  # noqa: F401 — re-export
                                   gnn_recsys_profiles)
from repro.memory.topology import get_topology

_DEFAULT = get_topology("tpu-hbm-host")

# Tier bandwidths (bytes/s), read off the tpu-hbm-host preset tiers —
# kept for legacy importers (benchmarks predating the redesign).
HBM_BW_READ = _DEFAULT.fast.read_bw
HBM_BW_WRITE = _DEFAULT.fast.write_bw
HOST_BW_READ = _DEFAULT.slow.read_bw
HOST_BW_WRITE = _DEFAULT.slow.write_bw
HBM_CAPACITY = _DEFAULT.fast.capacity
DEFAULT_HOST_CAPACITY = _DEFAULT.slow.capacity


def _warn(name: str, repl: str) -> None:
    warnings.warn(f"repro.core.tiered_memory.{name} is deprecated; use "
                  f"{repl}", DeprecationWarning, stacklevel=3)


def _slow_tier_penalty(p: AccessProfile) -> float:
    """Deprecated: use ``TierTopology.demotion_penalty``."""
    return _DEFAULT.demotion_penalty(p)


def plan_placement(profiles: list[AccessProfile],
                   hbm_budget: int = HBM_CAPACITY,
                   host_budget: int = DEFAULT_HOST_CAPACITY,
                   exact_threshold: int = 16) -> Plan:
    """Deprecated: ``repro.memory.get_policy('greedy')`` on a registered
    topology."""
    _warn("plan_placement", "repro.memory.place_greedy / get_policy")
    return place_greedy(
        profiles, _DEFAULT,
        budgets={_DEFAULT.fast.name: int(hbm_budget),
                 _DEFAULT.slow.name: int(host_budget)},
        exact_threshold=exact_threshold)


def plan_placement_exact(profiles: list[AccessProfile],
                         hbm_budget: int = HBM_CAPACITY) -> Plan:
    """Deprecated: ``repro.memory.get_policy('exact')`` on a registered
    topology."""
    _warn("plan_placement_exact", "repro.memory.place_exact / get_policy")
    return place_exact(profiles, _DEFAULT,
                       budgets={_DEFAULT.fast.name: int(hbm_budget)})
