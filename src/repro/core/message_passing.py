"""Message-passing layer (paper Eq 1-3) with the §4 dataflow optimizations
as explicit, toggleable rewrite levels.

opt_level:
  0  naive        — per-edge dense matmuls then scatter-add, the original
                    DGL-style dataflow of Fig 4a: O(|E|) weight matmuls.
  1  +reorder     — Fig 4b: aggregate first, multiply weights at node
                    level: O(|V|) matmuls (optimization O1).
  2  +kernelize   — Fig 4c: message gen/agg expressed as generalized
                    SDDMM/SpMM kernel calls (optimization O2; dispatches
                    to Pallas kernels with impl='pallas').
  3  +sddmm reuse — compute x_u⊙x_i once per layer and reuse it for both
                    propagation directions (optimization O3).

Levels 1-3 are numerically identical; level 0 differs only by float
reassociation.  Tests assert allclose across levels.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import sparse_ops
from repro.core.graph import BipartiteGraph
from repro.kernels import ops as kops


def _sddmm(op, x, y, src, dst, mask, impl):
    if impl == "xla":
        return sparse_ops.sddmm(op, x, y, src, dst, mask)
    return kops.sddmm(op, x, y, src, dst, mask, impl=impl)


def ngcf_propagate_bipartite(g: BipartiteGraph, x_user, x_item, w1, w2,
                             opt_level: int = 3, impl: str = "xla"):
    """One NGCF message-passing layer on the bipartite graph; returns
    (h_user, h_item).

    m_e = (x_src ⊙ x_dst) W1 + x_src W2 ;  h_dst = Σ_e m_e
    """
    u, i, mask = g.user, g.item, g.edge_mask
    nu, ni = g.n_users, g.n_items

    if opt_level == 0:
        # Fig 4a: weight matmuls at edge level (O(|E|) dense FLOPs).
        mul_ui = jnp.where(mask[:, None], x_user[u] * x_item[i], 0)
        m_to_item = mul_ui @ w1 + jnp.where(mask[:, None], x_user[u], 0) @ w2
        m_to_user = mul_ui @ w1 + jnp.where(mask[:, None], x_item[i], 0) @ w2
        h_item = jax.ops.segment_sum(m_to_item, i, num_segments=ni)
        h_user = jax.ops.segment_sum(m_to_user, u, num_segments=nu)
        return h_user, h_item

    if opt_level >= 3:
        # O3: one SDDMM serves both directions (x_u⊙x_i == x_i⊙x_u).
        mul_e = _sddmm("mul", x_user, x_item, u, i, mask, impl)
        agg_mul_item = sparse_ops.spmm("sum", mul_e, i, ni, mask)
        agg_mul_user = sparse_ops.spmm("sum", mul_e, u, nu, mask)
    else:
        mul_e_item = _sddmm("mul", x_user, x_item, u, i, mask, impl)
        mul_e_user = _sddmm("mul", x_item, x_user, i, u, mask, impl)
        agg_mul_item = sparse_ops.spmm("sum", mul_e_item, i, ni, mask)
        agg_mul_user = sparse_ops.spmm("sum", mul_e_user, u, nu, mask)

    # O1: aggregate raw src features first, then one node-level matmul.
    agg_src_item = sparse_ops.gspmm_copy_sum(x_user, u, i, ni, mask)
    agg_src_user = sparse_ops.gspmm_copy_sum(x_item, i, u, nu, mask)
    h_item = agg_mul_item @ w1 + agg_src_item @ w2
    h_user = agg_mul_user @ w1 + agg_src_user @ w2
    return h_user, h_item


def lightgcn_propagate_bipartite(g: BipartiteGraph, x_user, x_item,
                                 coeff_ui=None, impl: str = "xla"):
    """One LightGCN layer: h_dst = Σ_e coeff_e · x_src (no weights)."""
    u, i, mask = g.user, g.item, g.edge_mask
    h_item = sparse_ops.gspmm_copy_sum(x_user, u, i, g.n_items, mask, coeff_ui)
    h_user = sparse_ops.gspmm_copy_sum(x_item, i, u, g.n_users, mask, coeff_ui)
    return h_user, h_item


def bipartite_sym_coeff(g: BipartiteGraph) -> jax.Array:
    """1/sqrt(d_u d_i) per interaction (LightGCN normalization)."""
    ones = g.edge_mask.astype(jnp.float32)
    du = jax.ops.segment_sum(ones, g.user, num_segments=g.n_users)
    di = jax.ops.segment_sum(ones, g.item, num_segments=g.n_items)
    du = jnp.maximum(du, 1.0)
    di = jnp.maximum(di, 1.0)
    c = jax.lax.rsqrt(du[g.user]) * jax.lax.rsqrt(di[g.item])
    return jnp.where(g.edge_mask, c, 0.0)
