"""Generalized SDDMM / SpMM on COO graphs — XLA path.

This is the paper's §4 kernelization (optimization O2) expressed in
jax-native gather + segment-reduce.  The Pallas TPU kernels in
``repro.kernels`` implement the same contracts; ``repro.kernels.ops``
dispatches between this module (impl='xla') and Pallas (impl='pallas').

Contracts (all edge-level ops respect `edge_mask`):

  sddmm(op, x_src, x_dst, src, dst, mask)        -> m[E_pad, D] or [E_pad]
      op='mul'  : m_e = x[src_e] * x[dst_e]          (NGCF/LightGCN messages)
      op='dot'  : m_e = <x[src_e], x[dst_e]>         (attention-style scores)
      op='add'  : m_e = x[src_e] + x[dst_e]
      op='copy' : m_e = x[src_e]                      (GCN-style)

  spmm(reduce, msg, dst, n_nodes, mask)          -> h[n_nodes, D]
      reduce in {'sum', 'mean', 'max'}

Both are linear (for 'mul'/'copy'/'add' and 'sum'/'mean') so their VJPs
are themselves SDDMM/SpMM calls — the paper's observation that gradients
map onto the same two kernels falls out of JAX autodiff for free.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

SDDMM_OPS = ("mul", "dot", "add", "copy")
SPMM_REDUCE = ("sum", "mean", "max")


@partial(jax.jit, static_argnames=("op",))
def sddmm(op: str, x_src: jax.Array, x_dst: jax.Array, src: jax.Array,
          dst: jax.Array, edge_mask: jax.Array) -> jax.Array:
    """Sampled dense-dense op at edge positions."""
    if op not in SDDMM_OPS:
        raise ValueError(f"unknown sddmm op {op}")
    a = x_src[src]
    if op == "copy":
        m = a
    else:
        b = x_dst[dst]
        if op == "mul":
            m = a * b
        elif op == "add":
            m = a + b
        else:  # dot
            m = jnp.sum(a * b, axis=-1)
    mask = edge_mask if m.ndim == 1 else edge_mask[:, None]
    return jnp.where(mask, m, 0)


@partial(jax.jit, static_argnames=("reduce", "n_nodes"))
def spmm(reduce: str, msg: jax.Array, dst: jax.Array, n_nodes: int,
         edge_mask: jax.Array) -> jax.Array:
    """Segment-reduce messages onto destination nodes."""
    if reduce not in SPMM_REDUCE:
        raise ValueError(f"unknown spmm reduce {reduce}")
    mask = edge_mask if msg.ndim == 1 else edge_mask[:, None]
    if reduce == "max":
        neg = jnp.full_like(msg, -jnp.inf)
        m = jnp.where(mask, msg, neg)
        out = jax.ops.segment_max(m, dst, num_segments=n_nodes)
        return jnp.where(jnp.isfinite(out), out, 0)
    m = jnp.where(mask, msg, 0)
    out = jax.ops.segment_sum(m, dst, num_segments=n_nodes)
    if reduce == "mean":
        cnt = jax.ops.segment_sum(edge_mask.astype(msg.dtype), dst,
                                  num_segments=n_nodes)
        out = out / jnp.maximum(cnt, 1)[..., None] if msg.ndim > 1 else out / jnp.maximum(cnt, 1)
    return out


@partial(jax.jit, static_argnames=("n_nodes",))
def edge_softmax(scores: jax.Array, dst: jax.Array, n_nodes: int,
                 edge_mask: jax.Array) -> jax.Array:
    """Softmax over incoming edges per destination (GAT-style)."""
    neg = jnp.full_like(scores, -jnp.inf)
    s = jnp.where(edge_mask, scores, neg)
    mx = jax.ops.segment_max(s, dst, num_segments=n_nodes)
    mx = jnp.where(jnp.isfinite(mx), mx, 0)
    e = jnp.where(edge_mask, jnp.exp(s - mx[dst]), 0)
    z = jax.ops.segment_sum(e, dst, num_segments=n_nodes)
    return e / jnp.maximum(z, 1e-20)[dst]


def gspmm_copy_sum(x: jax.Array, src: jax.Array, dst: jax.Array,
                   n_nodes: int, edge_mask: jax.Array,
                   coeff: jax.Array | None = None) -> jax.Array:
    """Fused gather-scale-scatter: sum_e coeff_e * x[src_e] -> dst.

    This is the single-SpMM fusion available to GCN (paper §9: GCN's
    message fn is a scalar multiply, so message+aggregate fuse into one
    SpMM).  coeff=None means unweighted copy.
    """
    m = x[src]
    if coeff is not None:
        m = m * coeff[:, None]
    m = jnp.where(edge_mask[:, None], m, 0)
    return jax.ops.segment_sum(m, dst, num_segments=n_nodes)
