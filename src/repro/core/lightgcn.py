"""LightGCN (He et al., SIGIR'20): NGCF minus W1/W2/nonlinearity; final
embedding = mean over layer outputs."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.graph import BipartiteGraph
from repro.core.message_passing import (bipartite_sym_coeff,
                                        lightgcn_propagate_bipartite)


def init_params(key, n_users, n_items, embed_dim, n_layers=None, dtype=jnp.float32):
    del n_layers  # static: passed to forward, not stored (keeps params grad-able)
    k1, k2 = jax.random.split(key)
    scale = 1.0 / jnp.sqrt(embed_dim)
    return {
        "user_embed": jax.random.normal(k1, (n_users, embed_dim), dtype) * scale,
        "item_embed": jax.random.normal(k2, (n_items, embed_dim), dtype) * scale,
    }


def forward(params, g: BipartiteGraph, n_layers: int = 2, impl: str = "xla"):
    """Returns (user_final, item_final) = mean over {x^(0)..x^(L)}."""
    coeff = bipartite_sym_coeff(g)
    xu, xi = params["user_embed"], params["item_embed"]
    acc_u, acc_i = xu, xi
    for _ in range(n_layers):
        xu, xi = lightgcn_propagate_bipartite(g, xu, xi, coeff, impl=impl)
        acc_u = acc_u + xu
        acc_i = acc_i + xi
    denom = n_layers + 1
    return acc_u / denom, acc_i / denom
