"""Bayesian Personalized Ranking loss, negative sampling, recall@K."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


def bpr_loss(user_e, item_e, users, pos_items, neg_items, l2: float = 1e-4):
    """-log sigma(s(u,i+) - s(u,i-)) + L2 on the touched embeddings."""
    eu = user_e[users]
    ep = item_e[pos_items]
    en = item_e[neg_items]
    pos = jnp.sum(eu * ep, -1)
    neg = jnp.sum(eu * en, -1)
    loss = -jnp.mean(jax.nn.log_sigmoid(pos - neg))
    reg = l2 * (jnp.mean(jnp.sum(eu ** 2, -1)) + jnp.mean(jnp.sum(ep ** 2, -1))
                + jnp.mean(jnp.sum(en ** 2, -1)))
    return loss + reg


def sample_bpr_batch(rng: np.random.Generator, train_user: np.ndarray,
                     train_item: np.ndarray, n_items: int, batch: int):
    """Uniform (u, i+, i-) tuples from observed interactions.  i- is
    uniform over the catalogue (classic BPR; collision prob is tiny on
    sparse graphs and does not bias the estimator materially)."""
    idx = rng.integers(0, len(train_user), batch)
    users = train_user[idx]
    pos = train_item[idx]
    neg = rng.integers(0, n_items, batch)
    return users.astype(np.int32), pos.astype(np.int32), neg.astype(np.int32)


def recall_at_k(user_e, item_e, train_mask, test_pos: list[np.ndarray],
                k: int = 20) -> float:
    """Dense-score recall@k (small graphs).  train_mask[u, i]=True masks
    seen items; test_pos[u] = array of held-out item ids."""
    scores = np.asarray(user_e @ item_e.T)
    scores[train_mask] = -np.inf
    topk = np.argpartition(-scores, min(k, scores.shape[1] - 1), axis=1)[:, :k]
    recalls = []
    for u, pos in enumerate(test_pos):
        if len(pos) == 0:
            continue
        hits = np.intersect1d(topk[u], pos).size
        recalls.append(hits / len(pos))
    return float(np.mean(recalls)) if recalls else 0.0
