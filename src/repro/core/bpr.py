"""Bayesian Personalized Ranking loss, negative sampling, recall@K."""
from __future__ import annotations

import warnings
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


def bpr_loss(user_e, item_e, users, pos_items, neg_items, l2: float = 1e-4):
    """-log sigma(s(u,i+) - s(u,i-)) + L2 on the touched embeddings."""
    eu = user_e[users]
    ep = item_e[pos_items]
    en = item_e[neg_items]
    pos = jnp.sum(eu * ep, -1)
    neg = jnp.sum(eu * en, -1)
    loss = -jnp.mean(jax.nn.log_sigmoid(pos - neg))
    reg = l2 * (jnp.mean(jnp.sum(eu ** 2, -1)) + jnp.mean(jnp.sum(ep ** 2, -1))
                + jnp.mean(jnp.sum(en ** 2, -1)))
    return loss + reg


def sample_bpr_batch(rng: np.random.Generator, train_user: np.ndarray,
                     train_item: np.ndarray, n_items: int, batch: int):
    """Uniform (u, i+, i-) tuples from observed interactions.  i- is
    uniform over the catalogue (classic BPR; collision prob is tiny on
    sparse graphs and does not bias the estimator materially)."""
    idx = rng.integers(0, len(train_user), batch)
    users = train_user[idx]
    pos = train_item[idx]
    neg = rng.integers(0, n_items, batch)
    return users.astype(np.int32), pos.astype(np.int32), neg.astype(np.int32)


def build_user_csr(user: np.ndarray, item: np.ndarray,
                   n_users: int) -> tuple[np.ndarray, np.ndarray]:
    """(indptr, items) user-CSR over interaction edges: items[indptr[u]:
    indptr[u+1]] are user u's item ids.  O(E) — the mask structure for
    evaluation/serving (``repro.eval``) and for ``recall_at_k`` below."""
    user = np.asarray(user)
    item = np.asarray(item)
    order = np.argsort(user, kind="stable")
    indptr = np.zeros(n_users + 1, np.int64)
    np.add.at(indptr, user + 1, 1)
    np.cumsum(indptr, out=indptr)
    return indptr, item[order].astype(np.int64)


def recall_at_k(user_e, item_e, train, test_pos: list[np.ndarray],
                k: int = 20) -> float:
    """Dense-score recall@k — the small-graph reference oracle (it still
    materializes the U×I score matrix; production eval is the streaming
    path in ``repro.eval``).

    ``train`` masks already-seen items, either as the (indptr, items)
    user-CSR from ``build_user_csr`` (canonical — O(E)), or as the
    legacy dense boolean mask [U, I] (back-compat shim; itself O(U×I)).
    test_pos[u] = array of held-out item ids."""
    scores = np.asarray(user_e @ item_e.T)
    if isinstance(train, np.ndarray):
        if train.ndim != 2 or train.dtype != bool:
            raise TypeError("dense train mask must be a 2-D boolean array; "
                            "pass build_user_csr(...) otherwise")
        warnings.warn(
            "passing a dense [U, I] boolean train mask to recall_at_k is "
            "deprecated (it materializes the U×I matrix twice); pass the "
            "(indptr, items) user-CSR from build_user_csr, or use the "
            "streaming evaluation in repro.eval (evaluate_embeddings)",
            DeprecationWarning, stacklevel=2)
        scores[train] = -np.inf            # legacy dense-mask shim
    else:
        indptr, items = train
        indptr = np.asarray(indptr)
        items = np.asarray(items)
        rows = np.repeat(np.arange(len(indptr) - 1), np.diff(indptr))
        scores[rows, items] = -np.inf
    topk = np.argpartition(-scores, min(k, scores.shape[1] - 1), axis=1)[:, :k]
    recalls = []
    for u, pos in enumerate(test_pos):
        if len(pos) == 0:
            continue
        hits = np.intersect1d(topk[u], pos).size
        recalls.append(hits / len(pos))
    return float(np.mean(recalls)) if recalls else 0.0
