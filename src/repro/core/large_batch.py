"""Large-batch training techniques (paper §7.1).

The paper increases BPR batch size 1K -> 150K without recall loss via:
  1. linear learning-rate scaling (Goyal et al.): lr = base_lr * B/B_base
     (square-root scaling was tried and found worse);
  2. warm-up *batch-size* schedule: train the first ``warmup_epochs``
     epochs with batch = target/10, then switch to the target batch
     (a too-small warm-up batch, e.g. the original 1K, hurts accuracy).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class LargeBatchSchedule:
    base_lr: float
    base_batch: int
    target_batch: int
    warmup_epochs: int = 2
    warmup_divisor: int = 10      # paper: warm-up batch = target/10
    scaling: str = "linear"       # 'linear' (paper) | 'sqrt' (ablation)

    def batch_for_epoch(self, epoch: int) -> int:
        if epoch < self.warmup_epochs:
            return max(self.base_batch, self.target_batch // self.warmup_divisor)
        return self.target_batch

    def lr_for_epoch(self, epoch: int) -> float:
        return self.scaled_lr(self.batch_for_epoch(epoch))

    def scaled_lr(self, batch: int) -> float:
        """LR for the batch actually run, under the configured rule."""
        if self.scaling == "sqrt":
            return self.sqrt_scaled_lr(batch)
        return self.linear_scaled_lr(batch)

    def linear_scaled_lr(self, batch: int) -> float:
        return self.base_lr * (batch / self.base_batch)

    def sqrt_scaled_lr(self, batch: int) -> float:
        """Kept for the paper's ablation (found inferior)."""
        return self.base_lr * (batch / self.base_batch) ** 0.5
