"""Static-shape graph containers.

Everything is COO-first (edge lists) because JAX sparse support is
BCOO-only and message passing maps onto gather + segment-reduce.  All
arrays carry *static* shapes: graphs are padded to a fixed edge budget so
jit traces once per (|V|, |E|) bucket.

A bipartite user-item graph is stored with users and items in disjoint id
ranges ([0, n_users) and [n_users, n_users + n_items)) so the same kernels
serve bipartite recsys graphs and general graphs (GCN).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Graph:
    """COO graph with per-edge validity mask (for padding).

    src/dst: int32[E_pad] edge endpoints.
    edge_mask: bool[E_pad], False on padded edges.
    n_nodes / n_edges: static python ints (aux data, not traced).
    """

    src: jax.Array
    dst: jax.Array
    edge_mask: jax.Array
    n_nodes: int = dataclasses.field(metadata=dict(static=True))
    n_edges: int = dataclasses.field(metadata=dict(static=True))

    @property
    def e_pad(self) -> int:
        return self.src.shape[0]

    def reverse(self) -> "Graph":
        return Graph(self.dst, self.src, self.edge_mask, self.n_nodes, self.n_edges)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class BipartiteGraph:
    """User-item interaction graph.

    Edges are stored once, (user, item) with item ids in [0, n_items).
    ``as_homogeneous`` re-bases items to [n_users, n_users+n_items) and
    emits both edge directions, which is what NGCF/LightGCN propagate on.
    """

    user: jax.Array  # int32[E_pad]
    item: jax.Array  # int32[E_pad]
    edge_mask: jax.Array
    n_users: int = dataclasses.field(metadata=dict(static=True))
    n_items: int = dataclasses.field(metadata=dict(static=True))
    n_edges: int = dataclasses.field(metadata=dict(static=True))

    @property
    def e_pad(self) -> int:
        return self.user.shape[0]

    @property
    def n_nodes(self) -> int:
        return self.n_users + self.n_items

    def as_homogeneous(self) -> Graph:
        src = jnp.concatenate([self.user, self.item + self.n_users])
        dst = jnp.concatenate([self.item + self.n_users, self.user])
        mask = jnp.concatenate([self.edge_mask, self.edge_mask])
        return Graph(src, dst, mask, self.n_nodes, 2 * self.n_edges)


def pad_edges(src: np.ndarray, dst: np.ndarray, e_pad: int):
    """Pad (src, dst) to e_pad entries; padded edges point at node 0 and
    are masked out."""
    e = src.shape[0]
    if e > e_pad:
        raise ValueError(f"{e} edges exceed pad budget {e_pad}")
    mask = np.zeros(e_pad, dtype=bool)
    mask[:e] = True
    out_src = np.zeros(e_pad, dtype=np.int32)
    out_dst = np.zeros(e_pad, dtype=np.int32)
    out_src[:e] = src
    out_dst[:e] = dst
    return out_src, out_dst, mask


def from_numpy(src: np.ndarray, dst: np.ndarray, n_nodes: int,
               e_pad: int | None = None) -> Graph:
    e_pad = e_pad or len(src)
    s, d, m = pad_edges(np.asarray(src), np.asarray(dst), e_pad)
    return Graph(jnp.asarray(s), jnp.asarray(d), jnp.asarray(m), int(n_nodes), int(len(src)))


def bipartite_from_numpy(user: np.ndarray, item: np.ndarray, n_users: int,
                         n_items: int, e_pad: int | None = None) -> BipartiteGraph:
    e_pad = e_pad or len(user)
    u, i, m = pad_edges(np.asarray(user), np.asarray(item), e_pad)
    return BipartiteGraph(jnp.asarray(u), jnp.asarray(i), jnp.asarray(m),
                          int(n_users), int(n_items), int(len(user)))


@partial(jax.jit, static_argnames=("n_nodes",))
def degrees(src: jax.Array, edge_mask: jax.Array, n_nodes: int) -> jax.Array:
    """Out-degree per node (or in-degree if called with dst)."""
    ones = edge_mask.astype(jnp.float32)
    return jax.ops.segment_sum(ones, src, num_segments=n_nodes)


def sym_norm_coeff(g: Graph) -> jax.Array:
    """GCN symmetric normalization 1/sqrt(d_src * d_dst) per edge."""
    d_out = degrees(g.src, g.edge_mask, g.n_nodes)
    d_in = degrees(g.dst, g.edge_mask, g.n_nodes)
    d_out = jnp.maximum(d_out, 1.0)
    d_in = jnp.maximum(d_in, 1.0)
    coeff = jax.lax.rsqrt(d_out[g.src]) * jax.lax.rsqrt(d_in[g.dst])
    return jnp.where(g.edge_mask, coeff, 0.0)


def to_csr(src: np.ndarray, dst: np.ndarray, n_nodes: int):
    """Host-side CSR build (row = src).  Returns (indptr, indices, perm)
    where perm maps sorted-edge order back to input order."""
    perm = np.argsort(src, kind="stable")
    s = src[perm]
    indptr = np.zeros(n_nodes + 1, dtype=np.int64)
    np.add.at(indptr, s + 1, 1)
    np.cumsum(indptr, out=indptr)
    return indptr, dst[perm].astype(np.int32), perm
