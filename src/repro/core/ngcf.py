"""NGCF (Wang et al., SIGIR'19) as used by the paper: Eq (4)-(6) with the
three §4 dataflow optimizations.  Final embedding = concat over layers
(NGCF convention); BPR-trained."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.graph import BipartiteGraph
from repro.core.message_passing import ngcf_propagate_bipartite


def init_params(key, n_users, n_items, embed_dim, n_layers, dtype=jnp.float32):
    keys = jax.random.split(key, 2 + 2 * n_layers)
    scale = 1.0 / jnp.sqrt(embed_dim)
    params = {
        "user_embed": jax.random.normal(keys[0], (n_users, embed_dim), dtype) * scale,
        "item_embed": jax.random.normal(keys[1], (n_items, embed_dim), dtype) * scale,
        "w1": [], "w2": [],
    }
    for l in range(n_layers):
        params["w1"].append(jax.random.normal(keys[2 + 2 * l], (embed_dim, embed_dim), dtype) * scale)
        params["w2"].append(jax.random.normal(keys[3 + 2 * l], (embed_dim, embed_dim), dtype) * scale)
    return params


def forward(params, g: BipartiteGraph, opt_level: int = 3, impl: str = "xla"):
    """Returns (user_final, item_final): concat of all layer embeddings,
    shape [n, (L+1)*D]."""
    xu, xi = params["user_embed"], params["item_embed"]
    outs_u, outs_i = [xu], [xi]
    for w1, w2 in zip(params["w1"], params["w2"]):
        xu, xi = ngcf_propagate_bipartite(g, xu, xi, w1, w2,
                                          opt_level=opt_level, impl=impl)
        xu = jax.nn.leaky_relu(xu, 0.2)
        xi = jax.nn.leaky_relu(xi, 0.2)
        outs_u.append(xu)
        outs_i.append(xi)
    return jnp.concatenate(outs_u, -1), jnp.concatenate(outs_i, -1)


def n_layers(params) -> int:
    return len(params["w1"])
