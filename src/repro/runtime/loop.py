"""Fault-tolerant training loop.

Design: the entire training state (params, opt state, loader state, rng)
is one pytree; the step is a pure function of it.  Fault tolerance is
therefore exactly (a) periodic atomic checkpoints, (b) on start, resume
from the latest committed step, (c) on failure, the supervisor re-launches
the same binary and (b) takes over — the loop below is that logic.

Straggler mitigation: SPMD training has no per-worker skew knob inside a
step, so mitigation lives at the step boundary — a per-step deadline; a
step exceeding it is recorded, and after ``max_strays`` consecutive slow
steps the loop requests re-layout (in production: evict the slow host /
re-shard; here: callback + log, and the elastic restore path covers the
re-shard).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint


@dataclasses.dataclass
class LoopConfig:
    ckpt_dir: str | None = None     # None -> in-memory run (no resume)
    ckpt_every: int = 50
    max_steps: int = 200
    step_deadline_s: float | None = None
    max_strays: int = 3
    async_ckpt: bool = True
    eval_every: int | None = None   # held-out eval cadence (steps); None=off


@dataclasses.dataclass
class LoopReport:
    steps_run: int
    resumed_from: int | None
    stray_steps: int
    relayout_requests: int
    losses: list
    # (step, metrics dict) per eval_every firing — the train-time metric
    # history (paper Table 3's recall@20 tracked during training)
    eval_history: list = dataclasses.field(default_factory=list)
    # the state after the last step — callers (repro.api.Run) continue
    # from here without a checkpoint round-trip
    final_state: Any = None


def run_training(cfg: LoopConfig, init_state: Any,
                 step_fn: Callable[[Any, int], tuple[Any, float]],
                 on_relayout: Callable[[Any], Any] | None = None,
                 on_restore: Callable[[Any], Any] | None = None,
                 eval_fn: Callable[[Any, int], dict] | None = None,
                 start_step: int = 0,
                 step_context: Callable[[], Any] | None = None) -> LoopReport:
    """step_fn(state, step) -> (state, loss).  Resumes if a checkpoint
    exists (``on_restore`` post-processes the restored state — e.g.
    re-applying memory-tier placements that raw checkpoint leaves lose);
    checkpoints every ``ckpt_every``; final state saved at end.
    ``eval_fn(state, step) -> metrics`` fires every ``cfg.eval_every``
    steps and its results accumulate in ``LoopReport.eval_history``.
    ``cfg.ckpt_dir=None`` runs in memory: no restore, no saves.
    ``start_step`` positions the loop when ``init_state`` has already
    trained that far (repro.api.Run continuing in memory); a restored
    checkpoint overrides it.  ``step_context`` (zero-arg, returns a
    context manager) is entered around every step the loop drives — a
    sharded pipeline passes its mesh/dp sharding-hints context here
    (``Pipeline.step_context``), so the accumulation step runs under
    ``dist.hints.sharding_hints`` without the loop knowing about
    meshes."""
    start = start_step
    state = init_state
    resumed = None
    if cfg.ckpt_dir is not None and latest_step(cfg.ckpt_dir) is not None:
        state, start = restore_checkpoint(cfg.ckpt_dir, init_state)
        resumed = start
        if on_restore is not None:
            state = on_restore(state)
    strays = 0
    relayouts = 0
    losses = []
    evals = []
    pending = None
    for step in range(start, cfg.max_steps):
        t0 = time.perf_counter()
        if step_context is not None:
            with step_context():
                state, loss = step_fn(state, step)
        else:
            state, loss = step_fn(state, step)
        dt = time.perf_counter() - t0
        losses.append(float(loss))
        if (eval_fn is not None and cfg.eval_every
                and (step + 1) % cfg.eval_every == 0):
            evals.append((step + 1, eval_fn(state, step + 1)))
        if cfg.step_deadline_s is not None and dt > cfg.step_deadline_s:
            strays += 1
            if strays >= cfg.max_strays:
                relayouts += 1
                strays = 0
                if on_relayout is not None:
                    state = on_relayout(state)
        else:
            strays = 0
        if cfg.ckpt_dir is not None and (step + 1) % cfg.ckpt_every == 0:
            if pending is not None:
                pending.join()
            pending = save_checkpoint(cfg.ckpt_dir, step + 1, state,
                                      async_=cfg.async_ckpt)
    if pending is not None:
        pending.join()
    if cfg.ckpt_dir is not None:
        save_checkpoint(cfg.ckpt_dir, cfg.max_steps, state)
    return LoopReport(cfg.max_steps - start, resumed, strays, relayouts,
                      losses, evals, final_state=state)


def run_pipeline(cfg: LoopConfig, pipeline) -> LoopReport:
    """Drive a ``repro.pipeline.Pipeline`` under the fault-tolerant loop:
    the pipeline supplies the initial state, the accumulated-microbatch
    ``step_fn``, ``on_relayout`` (re-runs the tiered-memory planner when
    the straggler escalation fires), and ``apply_plan`` (restored
    checkpoint leaves land back on their planned tiers)."""
    return run_training(cfg, pipeline.init_state(), pipeline.step_fn,
                        on_relayout=pipeline.on_relayout,
                        on_restore=pipeline.apply_plan,
                        eval_fn=getattr(pipeline, "eval_fn", None),
                        step_context=getattr(pipeline, "step_context", None))
