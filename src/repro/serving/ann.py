"""Block-pruned approximate-MIPS retrieval over the item table.

The streaming scorer (``eval.topk.streaming_topk``) is *exact*: every
query batch scores every item block.  At the paper's serving scale
(millions of users against a capacity-tier catalogue) that is the
dominant cost, and almost all of it is wasted — a user's top-K items
live in a handful of embedding-space neighbourhoods.  ``AnnIndex`` is
the classic IVF/block-max answer, shaped to this repo's invariants:

  build   items are reordered so embedding-space neighbours share
          fixed-size *index blocks* (``reorder='bisect'``: recursive
          PCA median splits — deterministic, exactly balanced, no
          Lloyd convergence hazard; ``'none'`` keeps catalogue order);
          each block keeps an int8-quantized centroid and a radius =
          max member distance to the centroid **plus** the centroid's
          own quantization error.

  query   1. coarse: ``kernels.ops.ann_block_scores`` scans every
             block's summary in one tiny ``[B, n_blocks]`` launch —
             ``(u·ĉ_b)·scale_b + ‖u‖·radius_b``.  With the radius term
             this is a *valid score upper bound* (Cauchy-Schwarz:
             ``u·x ≤ ub`` for every member ``x`` — pinned by
             tests/test_serving.py); with the radius zeroed it is the
             IVF probing affinity ``u·ĉ_b·scale_b``.
          2. prune: blocks are ranked per user by affinity (the bound's
             radius term scales with worst-case block impurity, which
             would let one loose block outrank genuinely close ones —
             affinity ranking is what IVF systems probe with), then the
             ``ceil(keep_frac · n_blocks)`` best survive by rank-voting
             across the microbatch (a block's priority is the best rank
             any user gave it; ties toward lower id — deterministic).
          3. exact: the survivors' rows are gathered **in ascending
             global-id order** (through whatever facade the placement
             produced — ``HostResident``, ``QuantizedHostResident`` or
             the ``HotRowCache``, so pruning directly cuts slow-tier
             bytes) and merged through the existing
             ``kernels.ops.fused_topk_score`` dispatch at the caller's
             ``item_block`` (decoupled from the index's finer blocks).

Because the candidate matrix is id-sorted and the exact stage runs the
very ops of the streamed merge at the same merge block size,
``keep_frac=1.0`` keeps every block and is **bit-identical** to
``streaming_topk`` — same scores, same (score desc, id asc) tie
contract, for device-resident, int8-stored and cached tables alike
(pinned by tests/test_serving.py).  Candidate-count shapes are static
per ``(index, keep_frac)``, so the exact stage traces once and
``hlo_audit.recompile_hazard``-style shape churn cannot occur.

Pruning quality scales with microbatch coherence: every user's top-j
affinity blocks are kept whenever ``n_keep >= j * batch``, so the
request queue's small skew-coherent microbatches are the natural
pruning unit (the load bench measures exactly this composition).

The planner prices the index footprint (centroids + bounds + the item
permutation) as a pinned-fast ``serve/ann_index`` profile
(``pipeline.plan.serving_profiles(ann_index_bytes=...)``).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.eval.topk import (DEFAULT_ITEM_BLOCK, DEFAULT_USER_BATCH, NEG_INF,
                             _gather_rows, _padded_seen, validate_user_ids)
from repro.kernels import ops as kops
from repro.memory.executor import HostResident
from repro.pipeline.sparse import default_impl

_ID_SENTINEL = np.iinfo(np.int32).max
DEFAULT_ANN_BLOCK = 64      # index granularity: fine blocks select well


def ann_index_nbytes(n_items: int, dim: int, block: int) -> int:
    """Static index footprint for planner pricing (before the index is
    built): int8 centroids + fp32 scale/radius per block + the int32
    item permutation."""
    n_blocks = max(1, math.ceil(n_items / max(block, 1)))
    return n_blocks * dim + 8 * n_blocks + 4 * n_items


def _bisect_order(items: np.ndarray, n_blocks: int) -> np.ndarray:
    """Pack embedding-space neighbours into contiguous slots by
    recursive PCA median splits (a balanced kd-cut): each subset is
    halved at the median of its principal-direction projection until
    ``ceil(log2(n_blocks))`` levels deep.  Exactly balanced (leaf sizes
    differ by at most 1), deterministic (power iteration from a fixed
    vector, stable sorts), and free of the empty/duplicate-centroid
    hazards of Lloyd iterations.  Chunks of ``block`` consecutive slots
    become the index blocks; the per-block centroid/radius are computed
    from the *actual* chunk members afterwards, so bounds stay valid
    even where a chunk straddles a leaf boundary."""
    levels = max(math.ceil(math.log2(max(n_blocks, 1))), 0)

    def split(ids: np.ndarray, depth: int) -> list[np.ndarray]:
        if depth == 0 or len(ids) <= 1:
            return [ids]
        x = items[ids]
        xc = x - x.mean(axis=0)
        v = np.ones(x.shape[1], np.float32)
        for _ in range(8):            # power iteration on the covariance
            v = xc.T @ (xc @ v)
            v /= max(np.linalg.norm(v), np.finfo(np.float32).tiny)
        srt = ids[np.argsort(xc @ v, kind="stable")]
        half = len(ids) // 2
        return split(srt[:half], depth - 1) + split(srt[half:], depth - 1)

    parts = split(np.arange(len(items), dtype=np.int64), levels)
    return np.concatenate(parts)


class AnnIndex:
    """Per-block coarse summaries over a (reordered) item table.

    Holds no item rows itself — only the permutation, the int8 centroid
    table and the per-block bound terms; the exact stage gathers rows
    from whatever table object serving placed (device array or a
    ``HostResident``-family facade)."""

    def __init__(self, item_e, *, block: int = DEFAULT_ANN_BLOCK,
                 reorder: str = "bisect"):
        if reorder not in ("bisect", "none"):
            raise ValueError(f"ann reorder must be 'bisect' or 'none', "
                             f"got {reorder!r}")
        items = np.asarray(item_e, np.float32)
        self.n_items, self.dim = int(items.shape[0]), int(items.shape[1])
        self.blk = int(min(max(block, 1), max(self.n_items, 1)))
        self.n_blocks = max(1, math.ceil(self.n_items / self.blk))
        self.reorder = reorder
        if reorder == "bisect" and self.n_blocks > 1:
            self.order = _bisect_order(items, self.n_blocks)
        else:
            self.order = np.arange(self.n_items, dtype=np.int64)
        # per-block summaries from the actual chunk members
        nb, blk = self.n_blocks, self.blk
        cent = np.zeros((nb, self.dim), np.float32)
        radius = np.zeros(nb, np.float32)
        for b in range(nb):
            members = items[self.order[b * blk:(b + 1) * blk]]
            c = members.mean(axis=0)
            cent[b] = c
            radius[b] = np.linalg.norm(members - c, axis=1).max()
        # int8 symmetric centroid quantization; the dequantization error
        # is folded into the radius so the bound survives quantization
        self.scale = np.maximum(np.abs(cent).max(axis=1) / 127.0,
                                np.finfo(np.float32).tiny).astype(np.float32)
        self.centroids_q = np.clip(
            np.rint(cent / self.scale[:, None]), -127, 127).astype(np.int8)
        dequant = self.centroids_q.astype(np.float32) * self.scale[:, None]
        self.radius = (radius + np.linalg.norm(cent - dequant, axis=1)
                       ).astype(np.float32)
        # device-side copies for the coarse kernel (tiny, pinned fast by
        # the serve/ann_index profile)
        self._cq_dev = jnp.asarray(self.centroids_q)
        self._scale_dev = jnp.asarray(self.scale)
        self._radius_dev = jnp.asarray(self.radius)
        self._zero_dev = jnp.zeros_like(self._radius_dev)

    @property
    def nbytes(self) -> int:
        return (self.centroids_q.nbytes + self.scale.nbytes
                + self.radius.nbytes + 4 * self.n_items)

    def n_keep(self, keep_frac: float) -> int:
        if not 0.0 < float(keep_frac) <= 1.0:
            raise ValueError(f"keep_frac must be in (0, 1], got {keep_frac}")
        return int(min(self.n_blocks,
                       max(1, math.ceil(float(keep_frac) * self.n_blocks))))

    def block_bounds(self, ue, n_valid: int, impl: str) -> np.ndarray:
        """Per-block score **upper bounds** for the first ``n_valid``
        rows of a (possibly padded) staged user batch —
        f32[n_valid, n_blocks].  Valid: every member's exact score is
        ``<=`` its block's bound (the Cauchy-Schwarz radius term)."""
        ub = kops.ann_block_scores(ue, self._cq_dev, self._scale_dev,
                                   self._radius_dev, impl=impl)
        return np.asarray(ub)[:n_valid]

    def block_affinity(self, ue, n_valid: int, impl: str) -> np.ndarray:
        """Per-block probing affinities ``(u·ĉ_b)·scale_b`` — the same
        coarse kernel with the radius term zeroed.  This is what blocks
        are *ranked* by: the bound's radius scales with worst-case block
        impurity, so ranking on it would let one loose block outrank
        genuinely close ones (the IVF argument)."""
        aff = kops.ann_block_scores(ue, self._cq_dev, self._scale_dev,
                                    self._zero_dev, impl=impl)
        return np.asarray(aff)[:n_valid]

    def select_blocks(self, affinity: np.ndarray, keep_frac: float
                      ) -> np.ndarray:
        """The shared shortlist: each user ranks every block by its own
        affinity (descending, ties toward lower block id); a block's
        priority is the best rank any user gave it, and the ``n_keep``
        best-priority blocks survive (priority ties toward lower id).

        Rank-voting rather than batch-max-affinity: affinities scale
        with the querying user's norm, so a max across users would let
        one large-norm user's blocks crowd out everyone else's.  Ranks
        are norm-invariant — every user's argmax block is kept whenever
        ``n_keep >= batch``, and each user's top-``j`` blocks whenever
        ``n_keep >= j * batch``.  Returned sorted ascending;
        deterministic for a given (batch, index).

        Only ranks below ``n_keep`` can influence the outcome (user 0
        alone gives ``n_keep`` blocks a better priority than any
        truncated block), so each user's ranking is an O(n_blocks)
        partition of unique (affinity, id) sort keys — float bits
        made order-preserving under integer compare, block id packed
        into the low half so ties are broken by lower id and keys never
        collide — not a full argsort."""
        n_keep = self.n_keep(keep_frac)
        nb = self.n_blocks
        ids32 = np.arange(nb, dtype=np.uint64)
        # unique uint64 keys ordering by (affinity desc, id asc):
        # negate (canonicalizing -0.0 so +/-0.0 still tie), map float
        # bits monotonically onto uint32, append the id as low bits
        neg = np.ascontiguousarray(-np.asarray(affinity, np.float32)) \
            + np.float32(0.0)
        fb = neg.view(np.int32)
        mono = (fb ^ ((fb >> 31) | np.int32(-2**31))).view(np.uint32)
        keys = (mono.astype(np.uint64) << np.uint64(32)) | ids32[None, :]
        top = np.partition(keys, n_keep - 1, axis=1)[:, :n_keep] \
            if n_keep < nb else keys.copy()
        top.sort(axis=1)                 # column index == per-user rank
        top_ids = (top & np.uint64(0xFFFFFFFF)).astype(np.int64)
        priority = np.full(nb, nb, np.int64)
        np.minimum.at(priority, top_ids.ravel(),
                      np.broadcast_to(np.arange(n_keep),
                                      top_ids.shape).ravel())
        # n_keep best (priority, id) pairs via the same packed-key trick
        keys2 = (priority.astype(np.uint64) << np.uint64(32)) | ids32
        best = np.partition(keys2, n_keep - 1)[:n_keep] \
            if n_keep < nb else keys2
        return np.sort((best & np.uint64(0xFFFFFFFF)).astype(np.int64))

    def candidate_ids(self, kept: np.ndarray) -> tuple[np.ndarray, int]:
        """Global item ids of the kept blocks, sorted ascending and
        padded with ``_ID_SENTINEL`` to the static ``n_keep·blk`` width.
        Returns (ids i64[C], n_valid).  Ascending order is what makes
        the exact stage's positional tie-break equal the global
        (score desc, id asc) contract."""
        slots = (kept[:, None] * self.blk + np.arange(self.blk)[None, :]
                 ).ravel()
        valid = slots < self.n_items
        ids = np.full(len(slots), _ID_SENTINEL, np.int64)
        ids[valid] = self.order[slots[valid]]
        ids.sort()                       # sentinels land at the tail
        return ids, int(valid.sum())

    def describe(self) -> str:
        return (f"AnnIndex[{self.n_items}I x {self.dim}D] "
                f"blocks={self.n_blocks}x{self.blk} reorder={self.reorder} "
                f"index={self.nbytes}B")


@jax.jit
def _take_rows(table, ids):
    """Jitted device row gather for the candidate matrix (a plain take —
    bit-exact row copies, one dispatch per batch)."""
    return jnp.take(table, ids, axis=0)


def _gather_candidates(item_e, ids: np.ndarray, n_valid: int, dim: int):
    """Candidate rows for the exact stage, through the placed table:
    HostResident-family facades stream (and cache-count) only the valid
    rows; device tables gather in place.  Pad slots carry row 0 — they
    are position-masked by ``n_items=n_valid`` in the fused merge."""
    if isinstance(item_e, HostResident):
        rows = np.zeros((len(ids), dim), np.float32)
        rows[:n_valid] = np.asarray(item_e.block(ids[:n_valid]), np.float32)
        return jnp.asarray(rows)
    safe = np.where(ids < _ID_SENTINEL, ids, 0).astype(np.int32)
    return _take_rows(item_e, jnp.asarray(safe))


def ann_topk(index: AnnIndex, user_e, item_e, k: int, *,
             keep_frac: float = 1.0, user_ids=None,
             seen_indptr=None, seen_items=None,
             user_batch: int = DEFAULT_USER_BATCH,
             item_block: int = DEFAULT_ITEM_BLOCK,
             impl: str | None = None):
    """Approximate top-K through the block-pruned index — the drop-in
    counterpart of ``eval.topk.streaming_topk`` (same signature shape,
    same (scores, ids) return contract, same -1/-inf invalid slots).
    ``item_block`` is the *exact-merge* block size (the index's own
    finer blocks only drive selection); with the same ``item_block``
    the exact sweep uses, ``keep_frac=1.0`` scans every block and is
    bit-identical to the streamed result."""
    impl = impl or default_impl()
    user_host = user_e if isinstance(user_e, HostResident) else None
    if user_host is None:
        user_e = jnp.asarray(user_e)
    if not isinstance(item_e, HostResident):
        item_e = jnp.asarray(item_e)     # device-resident once per sweep,
                                         # not re-uploaded per batch gather
    n_users = int(user_e.shape[0])
    if user_ids is None:
        user_ids = np.arange(n_users, dtype=np.int32)
    user_ids = np.asarray(user_ids, np.int32)
    validate_user_ids(user_ids, n_users)
    n_q = len(user_ids)
    k = int(k)
    index.n_keep(keep_frac)              # validate before any work
    if n_q == 0 or index.n_items == 0:
        return (np.full((n_q, k), NEG_INF, np.float32),
                np.full((n_q, k), -1, np.int32))
    ub = int(min(user_batch, n_q))
    max_deg = 0
    if seen_indptr is not None:
        seen_indptr = np.asarray(seen_indptr, np.int64)
        seen_items = np.asarray(seen_items, np.int64)
        max_deg = int(np.diff(seen_indptr)[user_ids].max())
    out_s = np.full((n_q, k), NEG_INF, np.float32)
    out_i = np.full((n_q, k), -1, np.int32)

    # stage ALL query user rows + coarse affinities up front: one gather
    # and one coarse-kernel launch for the whole sweep (the per-batch
    # python loop below then only sorts, gathers candidates and merges —
    # dispatch overhead must not eat the pruned compute)
    n_pad = math.ceil(n_q / ub) * ub
    ids_p = np.pad(user_ids, (0, n_pad - n_q))
    ue_all = jnp.asarray(user_host.take(ids_p)) if user_host is not None \
        else _gather_rows(user_e, ids_p, impl)
    aff_all = index.block_affinity(ue_all, n_q, impl)

    for lo in range(0, n_q, ub):
        sel = user_ids[lo:lo + ub]
        b = len(sel)
        sel_p = ids_p[lo:lo + ub]            # padded batch: static shape
        ue = jax.lax.dynamic_slice_in_dim(ue_all, lo, ub, axis=0)
        # 1. coarse affinities (real rows only: padded rows must not vote)
        affinity = aff_all[lo:lo + b]
        # 2. prune to the shortlist
        kept = index.select_blocks(affinity, keep_frac)
        cand_ids, n_valid = index.candidate_ids(kept)
        # 3. exact merge over the id-sorted candidates
        cand = _gather_candidates(item_e, cand_ids, n_valid, index.dim)
        if seen_indptr is not None:
            seen, smask = _padded_seen(sel_p, seen_indptr, seen_items,
                                       max_deg)
        else:
            seen = np.zeros((ub, 0), np.int64)
            smask = np.zeros((ub, 0), bool)
        # seen ids -> candidate positions (id-sorted, so searchsorted);
        # out-of-shortlist seen items simply aren't candidates
        pos = np.searchsorted(cand_ids, seen)
        pos_c = np.minimum(pos, len(cand_ids) - 1)
        smask = smask & (cand_ids[pos_c] == seen)
        top_s, top_p = kops.fused_topk_score(
            ue, cand, jnp.asarray(pos_c.astype(np.int32)),
            jnp.asarray(smask), k=k, n_items=n_valid,
            item_block=int(min(item_block, max(n_valid, 1))), impl=impl)
        # candidate positions -> global ids (invalid slots stay -1)
        top_p = np.asarray(top_p)
        ids_g = np.where(top_p >= 0,
                         cand_ids[np.maximum(top_p, 0)], -1).astype(np.int32)
        out_s[lo:lo + b] = np.asarray(top_s)[:b]
        out_i[lo:lo + b] = ids_g[:b]
    return out_s, out_i


def recall_against(exact_ids: np.ndarray, approx_ids: np.ndarray) -> float:
    """Mean per-user recall of ``approx_ids`` against ``exact_ids``
    (both [n, k]; -1 slots ignored) — the ANN quality metric the bench
    and tests floor at 0.95."""
    hits, total = 0, 0
    for ex, ap in zip(np.asarray(exact_ids), np.asarray(approx_ids)):
        truth = set(int(i) for i in ex if i >= 0)
        if not truth:
            continue
        got = set(int(i) for i in ap if i >= 0)
        hits += len(truth & got)
        total += len(truth)
    return hits / total if total else 1.0
