"""Production serving subsystem: request coalescing + block-pruned ANN
retrieval + the service facade (ROADMAP item 1).

  ``queue``    microbatcher — max-batch/max-wait coalescing under an
               injectable clock, pow2 pad-to-bucket shapes, bounded-
               depth backpressure.
  ``ann``      approximate-MIPS index — int8 block centroids + score
               upper bounds prune item blocks before the exact fused
               top-K merge; ``keep_frac=1.0`` is bit-identical to
               ``eval.topk.streaming_topk``.
  ``service``  ``RecommenderService`` — queue → ANN → ``Recommender``
               with queue-depth / occupancy / hit-rate / p50 / p99
               stats.
"""
from repro.serving.ann import (DEFAULT_ANN_BLOCK, AnnIndex,
                               ann_index_nbytes, ann_topk, recall_against)
from repro.serving.queue import (Batch, Clock, ManualClock, QueueFull,
                                 Request, RequestQueue, WallClock,
                                 bucket_for)
from repro.serving.service import RecommenderService, Response

__all__ = [
    "DEFAULT_ANN_BLOCK", "AnnIndex", "ann_index_nbytes", "ann_topk",
    "recall_against",
    "Batch", "Clock", "ManualClock", "QueueFull", "Request",
    "RequestQueue", "WallClock", "bucket_for",
    "RecommenderService", "Response",
]
