"""RecommenderService — the serving facade: queue → ANN → Recommender.

One object owns the whole request path: single-user queries enter the
``RequestQueue`` (coalescing + backpressure), dispatched microbatches
run through the ``Recommender`` — which itself routes through the
block-pruned ``AnnIndex`` when configured — and per-request responses
come back with full latency decomposition (wait in queue, batch
service, total).  The service is synchronous-event-loop shaped rather
than threaded: callers ``submit`` then ``poll``; under a ``ManualClock``
the service advances virtual time by each batch's *measured* compute,
so the load benchmark simulates open-loop arrival processes
deterministically while still charging real compute cost per batch.

Stats surface every quantity the ISSUE's serving section asks for:
queue depth / shed count, batch occupancy, cache hit-rate (from the
``HotRowCache`` behind the Recommender, when placed), and wait /
service / total p50 + p99 in microseconds.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.serving.queue import Batch, ManualClock, RequestQueue

# re-exported for callers that catch backpressure at the service level
from repro.serving.queue import QueueFull  # noqa: F401


@dataclasses.dataclass(frozen=True)
class Response:
    """One completed request with its latency decomposition."""
    req_id: int
    user_id: int
    ids: np.ndarray            # i32[k] recommended item ids (-1 invalid)
    scores: np.ndarray         # f32[k] their scores (-inf invalid)
    wait_us: int               # time spent coalescing in the queue
    service_us: int            # the batch's compute, charged to each rider
    total_us: int              # wait + service


def _pct(vals, q: float) -> float:
    if not len(vals):
        return 0.0
    return float(np.percentile(np.asarray(vals), q))


class RecommenderService:
    """Queue-fronted serving over a ``Recommender`` snapshot."""

    def __init__(self, recommender, *, max_batch: int = 64,
                 max_wait_us: int = 1_000, max_depth: int | None = None,
                 clock=None, k: int | None = None):
        self.rec = recommender
        self.k = int(k) if k is not None else recommender.k
        self.clock = clock if clock is not None else ManualClock()
        self.queue = RequestQueue(max_batch=max_batch,
                                  max_wait_us=max_wait_us,
                                  max_depth=max_depth, clock=self.clock)
        self._wait_us: list[int] = []
        self._service_us: list[int] = []
        self._total_us: list[int] = []
        self.n_completed = 0

    # ------------------------------------------------------------ intake
    def submit(self, user_id: int) -> int:
        """Enqueue one user's query (raises ``QueueFull`` under
        backpressure); the answer arrives from a later ``poll``."""
        return self.queue.submit(user_id)

    # ------------------------------------------------------------ serving
    def _run_batch(self, batch: Batch) -> list[Response]:
        t0 = time.monotonic_ns()
        ids, scores = self.rec.recommend(
            np.asarray(batch.user_ids, np.int32), k=self.k)
        service_us = max((time.monotonic_ns() - t0) // 1_000, 1)
        # under virtual time the batch's measured compute *is* the time
        # that passes — arrivals during it see a busy server
        if isinstance(self.clock, ManualClock):
            self.clock.advance(service_us)
        out = []
        for row, req in enumerate(batch.requests):
            wait = batch.t_dispatch_us - req.t_submit_us
            total = wait + service_us
            self._wait_us.append(wait)
            self._service_us.append(service_us)
            self._total_us.append(total)
            self.n_completed += 1
            out.append(Response(req.req_id, req.user_id,
                                np.asarray(ids[row]),
                                np.asarray(scores[row]),
                                wait, service_us, total))
        return out

    def poll(self, force: bool = False) -> list[Response]:
        """Dispatch at most one microbatch if the queue says it's time
        (or ``force`` and anything is pending); returns its responses
        (empty list when nothing dispatched)."""
        batch = self.queue.next_batch(force=force)
        return self._run_batch(batch) if batch is not None else []

    def drain(self) -> list[Response]:
        """Flush everything pending regardless of deadlines."""
        out = []
        while len(self.queue):
            out.extend(self.poll(force=True))
        return out

    # ------------------------------------------------------------ stats
    def stats(self) -> dict:
        """Queue + latency + cache counters for the whole service."""
        cache = self.rec.cache_stats() if hasattr(self.rec, "cache_stats") \
            else {}
        hit = {n: s["hit_rate"] for n, s in cache.items()}
        return {
            **self.queue.stats(),
            "completed": self.n_completed,
            "wait_p50_us": _pct(self._wait_us, 50),
            "wait_p99_us": _pct(self._wait_us, 99),
            "service_p50_us": _pct(self._service_us, 50),
            "service_p99_us": _pct(self._service_us, 99),
            "total_p50_us": _pct(self._total_us, 50),
            "total_p99_us": _pct(self._total_us, 99),
            "cache_hit_rate": hit,
        }

    def describe(self) -> str:
        q = self.queue
        return (f"RecommenderService[k={self.k} max_batch={q.max_batch} "
                f"max_wait={q.max_wait_us}us max_depth={q.max_depth}] "
                f"over {self.rec.describe()}")
