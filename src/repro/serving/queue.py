"""Request microbatcher — coalesces single-user queries into device-sized
batches.

Serving traffic arrives one user at a time, but every layer below is
batch-shaped: the fused top-K kernel amortizes its catalogue sweep over
the user batch, the ANN coarse stage is one small matmul per batch, and
a slow-tier gather costs the same link round-trip for 1 row or 64.  The
queue closes that gap with the classic two-trigger microbatch policy:

  dispatch when ``max_batch`` requests are waiting (occupancy bound)
  OR the oldest waiting request has aged ``max_wait_us`` (latency bound)

Time is injected (``Clock``): production uses ``WallClock``; tests and
the load bench use ``ManualClock``, which makes batch composition a
pure function of the (trace, clock) pair — the determinism contract
pinned by tests/test_serving.py.

Dispatched batches are padded up a power-of-two *bucket ladder*
(1, 2, 4, …, max_batch), never to arbitrary occupancy: the jitted
scorer then sees at most ``log2(max_batch)+1`` distinct batch shapes
over any trace — the same bounded-retrace discipline
``analysis.hlo_audit.recompile_hazard`` enforces on training chunk
shapes.  Pad slots repeat user id 0 and are dropped before responses
are built, so padding changes shapes only, never results.

Backpressure is bounded-depth: ``submit`` raises ``QueueFull`` beyond
``max_depth`` waiting requests instead of queueing unboundedly — the
caller sheds load where it can still answer cheaply.  Every request
carries its enqueue timestamp; the queue stamps wait time at dispatch
so the service layer can report steady-state wait/service/total
latency percentiles per request.
"""
from __future__ import annotations

import dataclasses
import time


class Clock:
    """Injectable microsecond clock (duck-typed: ``now_us() -> int``)."""

    def now_us(self) -> int:  # pragma: no cover - interface
        raise NotImplementedError


class WallClock(Clock):
    """Monotonic wall time in microseconds."""

    def now_us(self) -> int:
        return time.monotonic_ns() // 1_000


class ManualClock(Clock):
    """Deterministic virtual time: advances only when told.  Makes queue
    behaviour (and the load bench's arrival process) a pure function of
    the request trace."""

    def __init__(self, start_us: int = 0):
        self._now = int(start_us)

    def now_us(self) -> int:
        return self._now

    def advance(self, dt_us: int) -> int:
        if dt_us < 0:
            raise ValueError(f"cannot advance time backwards ({dt_us}us)")
        self._now += int(dt_us)
        return self._now


class QueueFull(RuntimeError):
    """Bounded-depth backpressure: the queue sheds load instead of
    growing an unbounded backlog."""


@dataclasses.dataclass(frozen=True)
class Request:
    """One pending single-user query."""
    req_id: int
    user_id: int
    t_submit_us: int


@dataclasses.dataclass(frozen=True)
class Batch:
    """One dispatched microbatch: ``user_ids`` is padded to ``bucket``
    slots (pad slots repeat user id 0); only the first
    ``len(requests)`` rows correspond to real requests."""
    requests: tuple[Request, ...]
    user_ids: tuple[int, ...]
    bucket: int
    t_dispatch_us: int

    @property
    def occupancy(self) -> float:
        return len(self.requests) / self.bucket

    @property
    def wait_us(self) -> tuple[int, ...]:
        return tuple(self.t_dispatch_us - r.t_submit_us for r in self.requests)


def bucket_for(n: int, max_batch: int) -> int:
    """Smallest power-of-two >= n, capped at max_batch — the pad-to-
    bucket ladder that bounds distinct jit shapes."""
    if n < 1:
        raise ValueError(f"bucket_for needs n >= 1, got {n}")
    b = 1
    while b < n:
        b <<= 1
    return min(b, max_batch)


class RequestQueue:
    """FIFO microbatcher with max-batch/max-wait dispatch, pad-to-bucket
    shaping and bounded-depth backpressure."""

    def __init__(self, *, max_batch: int = 64, max_wait_us: int = 1_000,
                 max_depth: int | None = None, clock: Clock | None = None):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if max_wait_us < 0:
            raise ValueError(f"max_wait_us must be >= 0, got {max_wait_us}")
        self.max_batch = int(max_batch)
        self.max_wait_us = int(max_wait_us)
        self.max_depth = int(max_depth) if max_depth is not None \
            else 16 * self.max_batch
        if self.max_depth < self.max_batch:
            raise ValueError(
                f"max_depth ({self.max_depth}) must be >= max_batch "
                f"({self.max_batch}) or full batches could never form")
        self.clock = clock or WallClock()
        self._pending: list[Request] = []
        self._next_id = 0
        self.n_submitted = 0
        self.n_rejected = 0
        self.n_dispatched = 0
        self.n_batches = 0
        self._occupancy_sum = 0.0

    # ------------------------------------------------------------ intake
    def __len__(self) -> int:
        return len(self._pending)

    def submit(self, user_id: int) -> int:
        """Enqueue one single-user query; returns its request id.
        Raises ``QueueFull`` past ``max_depth`` pending requests."""
        if len(self._pending) >= self.max_depth:
            self.n_rejected += 1
            raise QueueFull(
                f"queue depth {len(self._pending)} at max_depth "
                f"{self.max_depth}; shed load or drain faster")
        req = Request(self._next_id, int(user_id), self.clock.now_us())
        self._next_id += 1
        self.n_submitted += 1
        self._pending.append(req)
        return req.req_id

    # ------------------------------------------------------------ dispatch
    def ready(self) -> bool:
        """True when the two-trigger policy says dispatch now: a full
        batch is waiting, or the oldest request has hit its deadline."""
        if len(self._pending) >= self.max_batch:
            return True
        if not self._pending:
            return False
        age = self.clock.now_us() - self._pending[0].t_submit_us
        return age >= self.max_wait_us

    def next_deadline_us(self) -> int | None:
        """When the oldest pending request's wait bound expires (None if
        empty) — what an event loop would sleep until."""
        if not self._pending:
            return None
        return self._pending[0].t_submit_us + self.max_wait_us

    def next_batch(self, force: bool = False) -> Batch | None:
        """Pop one microbatch if ``ready()`` (or ``force`` and anything
        is pending): the oldest ``<= max_batch`` requests, FIFO, padded
        to their bucket."""
        if not self._pending or not (force or self.ready()):
            return None
        take = self._pending[:self.max_batch]
        self._pending = self._pending[len(take):]
        bucket = bucket_for(len(take), self.max_batch)
        ids = tuple(r.user_id for r in take) + (0,) * (bucket - len(take))
        batch = Batch(tuple(take), ids, bucket, self.clock.now_us())
        self.n_dispatched += len(take)
        self.n_batches += 1
        self._occupancy_sum += batch.occupancy
        return batch

    # ------------------------------------------------------------ stats
    def stats(self) -> dict:
        return {
            "depth": len(self._pending),
            "submitted": self.n_submitted,
            "rejected": self.n_rejected,
            "dispatched": self.n_dispatched,
            "batches": self.n_batches,
            "mean_occupancy": (self._occupancy_sum / self.n_batches
                               if self.n_batches else 0.0),
        }
