"""Ranking metrics over streamed top-K lists — recall@K, NDCG@K, MRR.

All three share one ranked-hits core: ``ranked_hits`` turns a top-K id
matrix plus per-user held-out item lists into a boolean hit matrix, and
each metric is a different reduction of it.  Everything runs host-side
in float64 numpy — metric math is trivially cheap next to scoring, and
float64 keeps the streamed and dense-oracle paths bit-for-bit equal
(pinned by tests/test_eval.py).

Users with zero held-out items are excluded from every average (they
have no defined recall); invalid top-K slots (id -1, from catalogues
smaller than K or fully-masked users) never count as hits.
"""
from __future__ import annotations

import numpy as np

from repro.eval.topk import streaming_topk


def ranked_hits(topk_ids: np.ndarray, test_pos: list[np.ndarray]) -> np.ndarray:
    """hits[u, j] = (topk_ids[u, j] in test_pos[u]).  topk_ids: i32[n, K]
    with -1 for invalid slots (never a hit — item ids are >= 0)."""
    topk_ids = np.asarray(topk_ids)
    n, _ = topk_ids.shape
    if n != len(test_pos):
        raise ValueError(f"{n} ranked rows vs {len(test_pos)} test lists")
    hits = np.zeros(topk_ids.shape, bool)
    for u, pos in enumerate(test_pos):
        if len(pos):
            hits[u] = np.isin(topk_ids[u], pos)
    return hits


def ranking_metrics(topk_ids: np.ndarray, test_pos: list[np.ndarray],
                    ks: tuple[int, ...] = (20,)) -> dict[str, float]:
    """recall@K / NDCG@K for each K in ``ks`` (capped at the ranked list
    width) plus MRR over the full ranked list, averaged over users with
    at least one held-out item."""
    hits = ranked_hits(topk_ids, test_pos)
    n_test = np.array([len(p) for p in test_pos], np.int64)
    evalable = n_test > 0
    out: dict[str, float] = {}
    width = hits.shape[1]
    discount = 1.0 / np.log2(np.arange(2, width + 2, dtype=np.float64))
    ideal = np.cumsum(discount)
    for k in ks:
        k = min(int(k), width)
        h = hits[:, :k]
        recall = h.sum(axis=1) / np.maximum(n_test, 1)
        dcg = (h * discount[:k]).sum(axis=1)
        idcg = ideal[np.minimum(np.maximum(n_test, 1), k) - 1]
        ndcg = dcg / idcg
        out[f"recall@{k}"] = float(recall[evalable].mean()) \
            if evalable.any() else 0.0
        out[f"ndcg@{k}"] = float(ndcg[evalable].mean()) \
            if evalable.any() else 0.0
    any_hit = hits.any(axis=1)
    first = hits.argmax(axis=1)
    rr = np.where(any_hit, 1.0 / (first + 1.0), 0.0)
    out["mrr"] = float(rr[evalable].mean()) if evalable.any() else 0.0
    return out


def evaluate_embeddings(user_e, item_e, test_pos: list[np.ndarray], *,
                        k: int = 20, ks: tuple[int, ...] | None = None,
                        seen_indptr=None, seen_items=None,
                        user_batch: int = 256, item_block: int = 1024,
                        impl: str | None = None,
                        shard=None) -> dict[str, float]:
    """Held-out ranking evaluation through the streaming top-K path.

    Only users with at least one held-out item are scored (the others
    cannot affect any average), so eval cost scales with the test set,
    not the user catalogue.  ``seen_indptr``/``seen_items`` is the
    user-CSR of training interactions to exclude from the ranking.
    ``shard`` (a ``pipeline.shard.ShardPlan``) distributes each user
    batch over the mesh's data-parallel axes.
    """
    ks = tuple(ks) if ks is not None else (int(k),)
    width = max(ks)
    eval_users = np.array([u for u, p in enumerate(test_pos) if len(p)],
                          np.int32)
    if len(eval_users) == 0:
        return ranking_metrics(np.zeros((0, width), np.int32), [], ks=ks)
    _, ids = streaming_topk(user_e, item_e, width, user_ids=eval_users,
                            seen_indptr=seen_indptr, seen_items=seen_items,
                            user_batch=user_batch, item_block=item_block,
                            impl=impl, shard=shard)
    return ranking_metrics(ids, [test_pos[u] for u in eval_users], ks=ks)
