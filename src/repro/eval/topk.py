"""Streaming top-K scorer — recommendation eval/serving without the dense
score matrix.

``bpr.recall_at_k`` materializes the full ``U×I`` score matrix, which is
exactly the memory blow-up the paper's tiered design exists to avoid
(and a dead end at the "millions of users" serving scale).  This module
scores users in fixed-size microbatches against *item blocks*:

  * each (user-batch × item-block) score tile is a small dense matmul
    whose row gathers ride the same kernel dispatch as training
    (``kernels.ops.embedding_bag`` → Pallas on TPU, XLA oracle
    elsewhere), so serving traffic hits the capacity tier through the
    same DMA path the planner already costs;
  * already-seen train items are masked per block through the user-CSR
    structure — a scatter of each user's in-block item ids, never a
    dense ``U×I`` boolean mask;
  * a running per-user top-K carry merges each block via
    ``jax.lax.top_k`` over the concatenated ``[carry ‖ block]`` scores.

The sweep is *block-major*: every query batch's user rows, seen ids and
top-K carries are staged once up front (``O(n_q × (D + K + deg))``),
then each item block is gathered/uploaded exactly **once** and merged
into every batch's carry before the next block streams.  The earlier
user-major ordering re-streamed the whole catalogue per user batch —
Q× the catalogue bytes over a sweep, the exact redundant-traffic
pathology the paper's tiering analysis flags.  Peak device memory is
``O(n_q × (D + K + deg) + block × D)`` — still never the dense ``U×I``
score matrix.

When the item table is device-resident (and the sweep is unsharded) the
whole per-block pipeline instead runs as one fused gather+score+mask+
top-K kernel per user batch (``kernels.ops.fused_topk_score`` — Pallas
on TPU, a single jitted XLA loop elsewhere), keeping the same dispatch
``impl`` routing as training and bit-identical results.

Tie-breaking contract (pinned by tests/test_eval.py): results are
ordered by (score desc, item id asc) — identical to a stable dense
argsort — because ``lax.top_k`` breaks ties in favour of lower indices,
the carry precedes the block in the concatenation, block item ids are
ascending, and earlier blocks hold lower ids.  Slots with fewer than K
scoreable candidates (catalogue smaller than K, or everything masked)
return id -1 with score -inf.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as kops
from repro.memory.executor import HostResident
from repro.pipeline.sparse import default_impl

NEG_INF = float("-inf")
DEFAULT_USER_BATCH = 256
DEFAULT_ITEM_BLOCK = 1024


def _gather_rows(table, ids, impl: str):
    """Row gather through the kernel dispatch (bag of length 1)."""
    ids = jnp.asarray(ids, jnp.int32)[:, None]
    mask = jnp.ones_like(ids, dtype=bool)
    return kops.embedding_bag(table, ids, mask, "sum", impl=impl)


@functools.partial(jax.jit, static_argnames=("k",))
def _merge_block(ue, ie_blk, block_ids, seen, seen_mask, start,
                 carry_s, carry_i, *, k: int):
    """One streamed block: score, mask seen via scatter, top-k merge."""
    b = ue.shape[0]
    blk = ie_blk.shape[0]
    scores = ue @ ie_blk.T                                  # [B, blk]
    # canonicalize -0.0 -> +0.0: lax.top_k sorts by IEEE total order
    # (-0.0 < +0.0) while comparison-based dense sorts treat them as a
    # tie — the (score desc, id asc) contract needs one behaviour
    scores = jnp.where(scores == 0.0, 0.0, scores)
    scores = jnp.where(block_ids[None, :] >= 0, scores, NEG_INF)
    # seen-item mask: scatter each user's in-block train items; the
    # extra column absorbs out-of-block ids (always in-bounds scatter)
    pos = seen - start                                      # [B, L]
    in_block = seen_mask & (pos >= 0) & (pos < blk)
    cols = jnp.where(in_block, pos, blk)
    rows = jnp.broadcast_to(jnp.arange(b)[:, None], cols.shape)
    hit = jnp.zeros((b, blk + 1), bool).at[rows, cols].set(True)[:, :blk]
    scores = jnp.where(hit, NEG_INF, scores)
    cat_s = jnp.concatenate([carry_s, scores], axis=1)
    cat_i = jnp.concatenate(
        [carry_i, jnp.broadcast_to(block_ids[None, :], scores.shape)], axis=1)
    top_s, idx = jax.lax.top_k(cat_s, k)
    return top_s, jnp.take_along_axis(cat_i, idx, axis=1)


def _padded_seen(user_ids: np.ndarray, indptr: np.ndarray, items: np.ndarray,
                 pad_to: int):
    """Ragged CSR rows -> padded [n, pad_to] ids + validity mask.
    ``pad_to`` is fixed per eval sweep so the jitted merge traces once."""
    deg = np.diff(indptr)[user_ids]
    if pad_to == 0 or len(items) == 0:
        n = len(user_ids)
        return (np.zeros((n, 0), np.int32), np.zeros((n, 0), bool))
    col = np.arange(pad_to)[None, :]
    mask = col < deg[:, None]
    idx = np.minimum(indptr[user_ids][:, None] + col, len(items) - 1)
    padded = np.where(mask, items[idx], 0).astype(np.int32)
    return padded, mask


def validate_user_ids(user_ids: np.ndarray, n_users: int) -> None:
    """Uniform out-of-range policy across placements.

    Raw numpy indexing (``HostResident.take``) wraps negative ids and
    raises on large ones, while the device gather clamps — so an
    adversarial id would silently return *different users* depending on
    where the planner happened to put the table.  Reject at the serving
    boundary instead.
    """
    if len(user_ids) == 0:
        return
    lo, hi = int(user_ids.min()), int(user_ids.max())
    if lo < 0 or hi >= n_users:
        bad = hi if hi >= n_users else lo
        raise ValueError(
            f"user_ids out of range: id {bad} not in [0, {n_users}); "
            "out-of-range ids are rejected uniformly regardless of "
            "embedding-table placement")


def streaming_topk(user_e, item_e, k: int, *, user_ids=None,
                   seen_indptr=None, seen_items=None,
                   user_batch: int = DEFAULT_USER_BATCH,
                   item_block: int = DEFAULT_ITEM_BLOCK,
                   impl: str | None = None, shard=None,
                   fused: bool | None = None):
    """Top-K items per user without materializing the U×I score matrix.

    user_e, item_e: [U, D] / [I, D] embedding tables (any tier).  A
      table demoted to a slow tier without a JAX memory kind arrives as
      a ``repro.memory.HostResident`` facade: its bytes stay in the
      host store and only each query batch's user rows / each item
      block stream to the device (row-granular gathers — bit-identical
      to the resident path, which copies the same bytes).
    user_ids: which users to score (default: all rows of user_e).
    seen_indptr/seen_items: user-CSR of already-seen (train) items to
      exclude, by global user id (``BipartiteCSR.seen_csr()`` or
      ``bpr.build_user_csr``).  None -> nothing excluded.
    shard: optional ``pipeline.shard.ShardPlan`` — user batches are
      padded to a multiple of the mesh size and their rows sharded over
      the data-parallel axes, so each device scores its slice of the
      batch against the (replicated) item blocks.  Results are
      identical to the unsharded sweep (same block schedule, same
      merges — only the batch rows are distributed).
    fused: route through the fused gather+score+top-K kernel.  None
      (default) auto-selects: fused whenever the item table is
      device-resident and the sweep is unsharded.  ``fused=True`` with a
      host-resident item table or a sharded sweep raises — the fused
      kernel needs the table addressable from device.
    Returns (scores f32[n, k], ids i32[n, k]) numpy arrays, ordered by
    (score desc, id asc); invalid slots are (-inf, -1).
    """
    impl = impl or default_impl()
    user_host = user_e if isinstance(user_e, HostResident) else None
    item_host = item_e if isinstance(item_e, HostResident) else None
    if user_host is None:
        user_e = jnp.asarray(user_e)
    if item_host is None:
        item_e = jnp.asarray(item_e)
    n_items = int(item_e.shape[0])
    n_users = int(user_e.shape[0])
    if user_ids is None:
        user_ids = np.arange(n_users, dtype=np.int32)
    user_ids = np.asarray(user_ids, np.int32)
    validate_user_ids(user_ids, n_users)
    n_q = len(user_ids)
    k = int(k)
    fused_ok = item_host is None and (shard is None or not shard.is_sharded)
    if fused and not fused_ok:
        raise ValueError(
            "fused=True needs a device-resident item table and an "
            "unsharded sweep (host-demoted tables stream block-major; "
            "sharded sweeps merge per-slice)")
    use_fused = fused_ok if fused is None else bool(fused)
    if n_q == 0 or n_items == 0:
        return (np.full((n_q, k), NEG_INF, np.float32),
                np.full((n_q, k), -1, np.int32))
    ub = int(min(user_batch, n_q))
    if shard is not None and shard.is_sharded:
        ub = math.ceil(ub / shard.n_shards) * shard.n_shards
    blk = int(min(item_block, n_items))
    n_blocks = math.ceil(n_items / blk)

    max_deg = 0
    if seen_indptr is not None:
        seen_indptr = np.asarray(seen_indptr, np.int64)
        seen_items = np.asarray(seen_items, np.int64)
        max_deg = int(np.diff(seen_indptr)[user_ids].max())
    out_s = np.full((n_q, k), NEG_INF, np.float32)
    out_i = np.full((n_q, k), -1, np.int32)

    def stage_batch(lo):
        sel = user_ids[lo:lo + ub]
        b = len(sel)
        sel_p = np.pad(sel, (0, ub - b))        # pad batch: static jit shape
        ue = jnp.asarray(user_host.take(sel_p)) if user_host is not None \
            else _gather_rows(user_e, sel_p, impl)
        if seen_indptr is not None:
            seen, smask = _padded_seen(sel_p, seen_indptr, seen_items, max_deg)
        else:
            seen = np.zeros((ub, 0), np.int32)
            smask = np.zeros((ub, 0), bool)
        return lo, b, ue, jnp.asarray(seen), jnp.asarray(smask)

    if use_fused:
        # one kernel launch per user batch — the item table never
        # leaves device memory, so there is nothing to re-stream
        for lo in range(0, n_q, ub):
            lo, b, ue, seen_d, smask_d = stage_batch(lo)
            top_s, top_i = kops.fused_topk_score(
                ue, item_e, seen_d, smask_d, k=k, n_items=n_items,
                item_block=blk, impl=impl)
            out_s[lo:lo + b] = np.asarray(top_s)[:b]
            out_i[lo:lo + b] = np.asarray(top_i)[:b]
        return out_s, out_i

    # block-major sweep: stage every user batch once, then stream each
    # item block exactly once and fold it into every batch's carry
    batches = []
    for lo in range(0, n_q, ub):
        lo, b, ue, seen_d, smask_d = stage_batch(lo)
        carry_s = jnp.full((ub, k), NEG_INF, jnp.float32)
        carry_i = jnp.full((ub, k), -1, jnp.int32)
        if shard is not None and shard.is_sharded:
            # distribute the batch rows over the dp axes; the jitted
            # merge then runs one user-slice per device (GSPMD)
            ue, seen_d, smask_d, carry_s, carry_i = shard.shard_batch(
                ue, seen_d, smask_d, carry_s, carry_i)
        batches.append([lo, b, ue, seen_d, smask_d, carry_s, carry_i])
    for b0 in range(0, n_blocks * blk, blk):
        ids_np = np.arange(b0, b0 + blk)
        valid = ids_np < n_items
        block_ids = jnp.asarray(np.where(valid, ids_np, -1).astype(np.int32))
        safe_ids = np.where(valid, ids_np, 0)
        ie_blk = jnp.asarray(item_host.block(safe_ids)) \
            if item_host is not None else _gather_rows(item_e, safe_ids, impl)
        for bt in batches:
            bt[5], bt[6] = _merge_block(
                bt[2], ie_blk, block_ids, bt[3], bt[4], jnp.int32(b0),
                bt[5], bt[6], k=k)
    for lo, b, _, _, _, carry_s, carry_i in batches:
        out_s[lo:lo + b] = np.asarray(carry_s)[:b]
        out_i[lo:lo + b] = np.asarray(carry_i)[:b]
    return out_s, out_i
