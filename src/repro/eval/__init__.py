"""Streaming top-K evaluation & serving (paper Table 3 / recall@20).

Public surface:

  streaming_topk       — block-merged top-K, never materializes U×I;
  ranked_hits / ranking_metrics — recall@K, NDCG@K, MRR on one core;
  evaluate_embeddings  — held-out eval through the streaming path;
  Recommender          — serving facade: planner-placed embedding
                         snapshot answering batched top-K queries.
"""
from repro.eval.metrics import (evaluate_embeddings, ranked_hits,
                                ranking_metrics)
from repro.eval.recommender import Recommender
from repro.eval.topk import streaming_topk

__all__ = [
    "streaming_topk", "ranked_hits", "ranking_metrics",
    "evaluate_embeddings", "Recommender",
]
