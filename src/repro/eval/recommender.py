"""Recommender — the serving facade over trained embeddings.

Snapshots a trained model's final (user, item) embeddings, places them
across the memory tiers with the same policy registry that places
training tensors (serving traffic profile: the item table is streamed
block-by-block for every query batch, the user table is only
row-gathered for the users in the batch), and answers batched top-K
queries through the streaming scorer — peak memory per query batch is
``O(batch × (K + block))`` however large the catalogue.

A demoted table is placed *functionally*: onto its tier's JAX memory
kind when the backend has one, and behind the row-granular
``HostResident`` gather facade otherwise — the scorer then streams only
each query batch's user rows / each item block out of the host store,
so demotion changes where bytes live and stream from, not just the
``describe()`` string.

Serving knobs (``repro.api.ServeCfg``): ``cache_rows`` puts a
device-resident LFU ``HotRowCache`` in front of every host-demoted
table (its slot budget priced against the fast tier by
``serving_profiles``), so Zipfian traffic streams only the cold tail;
``fused`` routes scoring through the fused gather+score+top-K kernel
(auto on for device-resident item tables).  Both are bit-identical to
the plain streamed path.  ``ann`` builds a block-pruned approximate-
MIPS index (``repro.serving.ann.AnnIndex``) over the *served* item
bytes — the index is constructed after placement, from exactly the
(possibly int8-round-tripped) rows the exact stage will score, so its
upper bounds stay valid for every storage arm — and routes
``recommend()`` through the coarse-prune-then-exact path; its
footprint is priced pinned-fast as ``serve/ann_index``.
``keep_frac=1.0`` keeps every block and is bit-identical to the exact
sweep (pinned by tests/test_serving.py).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.eval.topk import (DEFAULT_ITEM_BLOCK, DEFAULT_USER_BATCH,
                             streaming_topk, validate_user_ids)
from repro.memory import HostResident, TieredExecutor, get_policy, \
    get_topology, quantized_table_bytes
from repro.pipeline.plan import serving_profiles
from repro.pipeline.sparse import default_impl

# NOTE: repro.serving.ann is imported lazily inside Recommender — it
# consumes repro.eval.topk, so a module-level import here would cycle
# through the package __init__.


def _served_rows(table) -> np.ndarray:
    """The dense fp32 view of whatever placement produced — the bytes a
    gather will actually return (cache → its backing store; int8 →
    the dequantized round-trip; device array → itself)."""
    from repro.memory.cache import HotRowCache
    from repro.memory.executor import QuantizedHostResident
    if isinstance(table, HotRowCache):
        return _served_rows(table.backing)
    if isinstance(table, QuantizedHostResident):
        return table.dense()
    if isinstance(table, HostResident):
        return np.asarray(table.arr, np.float32)
    return np.asarray(table, np.float32)


class Recommender:
    """Batched top-K retrieval over a snapshot of trained embeddings."""

    def __init__(self, user_e, item_e, *, seen_indptr=None, seen_items=None,
                 k: int = 20, user_batch: int = DEFAULT_USER_BATCH,
                 item_block: int = DEFAULT_ITEM_BLOCK,
                 impl: str | None = None, hbm_budget: int | None = None,
                 topology: str = "tpu-hbm-host", policy: str = "greedy",
                 pins: dict | None = None, embed_store: str = "fp32",
                 cache_rows: int = 0, fused: bool | None = None,
                 ann: bool = False, keep_frac: float = 1.0,
                 ann_block: int | None = None, ann_reorder: str = "bisect"):
        self.k = int(k)
        self.user_batch = int(user_batch)
        self.item_block = int(item_block)
        self.impl = impl or default_impl()
        self.cache_rows = int(cache_rows)
        self.fused = fused
        self.ann = bool(ann)
        self.keep_frac = float(keep_frac)
        self.seen_indptr = None if seen_indptr is None \
            else np.asarray(seen_indptr, np.int64)
        self.seen_items = None if seen_items is None \
            else np.asarray(seen_items, np.int64)

        user_e = np.asarray(user_e)
        item_e = np.asarray(item_e)
        topo = get_topology(topology)
        budgets = topo.capacities()
        if hbm_budget is not None:
            budgets[topo.fast.name] = int(hbm_budget)
        from repro.serving.ann import (DEFAULT_ANN_BLOCK, AnnIndex,
                                       ann_index_nbytes)
        self.ann_block = int(ann_block) if ann_block is not None \
            else DEFAULT_ANN_BLOCK
        row = int(item_e.shape[-1]) * item_e.dtype.itemsize
        ann_bytes = ann_index_nbytes(int(item_e.shape[0]),
                                     int(item_e.shape[-1]),
                                     self.ann_block) if self.ann else 0
        profs = serving_profiles(user_e.nbytes, item_e.nbytes, row,
                                 cache_rows=self.cache_rows,
                                 ann_index_bytes=ann_bytes)
        if embed_store == "int8":
            # demoted tables live quantized (~1/4 bytes): price the
            # placement on their stored footprint, serve via the
            # dequant-on-gather facade below
            profs = [p if p.name == "serve/hot_cache" else
                     dataclasses.replace(
                         p, store_bytes=quantized_table_bytes(
                             int(p.nbytes // row), row)) for p in profs]
        self.plan = get_policy(policy)(profs, topo, budgets=budgets,
                                       pins=pins)
        self._executor = TieredExecutor(self.plan, prefixes=(),
                                        embed_store=embed_store,
                                        cache_rows=self.cache_rows)
        executor = self._executor

        def place_table(name, table):
            placed = executor.host_table(name, table)
            # fast-tier tables become resident device arrays once, so
            # every recommend() reuses them instead of re-uploading
            return placed if isinstance(placed, HostResident) or \
                not self.plan.is_fast(name) else jnp.asarray(placed)

        self.user_e = place_table("serve/user_embed", user_e)
        self.item_e = place_table("serve/item_embed", item_e)
        self.n_offloaded = sum(
            1 for n in ("serve/user_embed", "serve/item_embed")
            if not self.plan.is_fast(n))
        self.n_users = int(self.user_e.shape[0])
        self.n_items = int(self.item_e.shape[0])
        # the ANN index summarizes the *served* bytes — built after
        # placement so the bounds hold for the rows the exact stage will
        # actually score (int8 dequant round-trip included)
        self.ann_index = AnnIndex(_served_rows(self.item_e),
                                  block=self.ann_block,
                                  reorder=ann_reorder) if self.ann else None
        if self.ann_index is not None:
            self.ann_index.n_keep(self.keep_frac)   # fail fast on bad knob

    @classmethod
    def from_pipeline(cls, pipeline, state, **kw) -> "Recommender":
        """Snapshot a trained ``repro.pipeline.Pipeline``: final forward
        embeddings + the train CSR as the seen-item exclusion set,
        placed on the pipeline's own topology/policy."""
        user_e, item_e = pipeline.embeddings(state)
        indptr, items = pipeline.g.seen_csr()
        kw.setdefault("impl", pipeline.plan.impl)
        kw.setdefault("topology", pipeline.topology)
        kw.setdefault("policy", pipeline.cfg.memory_policy)
        kw.setdefault("hbm_budget", pipeline.cfg.hbm_budget)
        kw.setdefault("pins", pipeline.cfg.memory_pins)
        kw.setdefault("embed_store",
                      getattr(pipeline.cfg, "embed_store", "fp32"))
        return cls(user_e, item_e, seen_indptr=indptr, seen_items=items, **kw)

    def recommend(self, user_ids, k: int | None = None,
                  exclude_seen: bool = True):
        """Top-K (ids, scores) for a batch of user ids.  Invalid slots
        (fewer than K unseen candidates) are (-1, -inf)."""
        k = self.k if k is None else int(k)
        si, sv = (self.seen_indptr, self.seen_items) if exclude_seen \
            else (None, None)
        user_ids = np.asarray(user_ids)
        validate_user_ids(user_ids, self.n_users)
        if self.ann_index is not None:
            from repro.serving.ann import ann_topk
            scores, ids = ann_topk(
                self.ann_index, self.user_e, self.item_e, k,
                keep_frac=self.keep_frac, user_ids=user_ids,
                seen_indptr=si, seen_items=sv,
                user_batch=self.user_batch, item_block=self.item_block,
                impl=self.impl)
        else:
            scores, ids = streaming_topk(
                self.user_e, self.item_e, k, user_ids=user_ids,
                seen_indptr=si, seen_items=sv, user_batch=self.user_batch,
                item_block=self.item_block, impl=self.impl,
                fused=self.fused)
        return ids, scores

    def cache_stats(self) -> dict[str, dict]:
        """Per-table hot-row cache counters (hits, misses, bytes
        streamed, hit_rate); empty when ``cache_rows == 0`` or nothing
        is host-demoted."""
        return self._executor.cache_stats()

    def prefill_cache(self, user_ids=None) -> None:
        """Warm the hot-row caches: stream the given user rows (all the
        cache fits by default) into the device-resident slots up front."""
        for name, cache in self._executor.caches.items():
            if name == "serve/user_embed":
                ids = np.arange(cache.rows) if user_ids is None \
                    else np.asarray(user_ids)
                self._executor.prefetch_rows(name, ids)

    def describe(self) -> str:
        tiers = {n: p.tier for n, p in self.plan.placements.items()}
        cache = ""
        stats = self.cache_stats()
        if stats:
            parts = [f"{n.split('/')[-1]}: rows={self._executor.caches[n].rows} "
                     f"hit_rate={s['hit_rate']:.2f} "
                     f"streamed={s['bytes_streamed']}B"
                     for n, s in stats.items()]
            cache = f" cache[{'; '.join(parts)}]"
        ann = ""
        if self.ann_index is not None:
            ann = (f" ann[{self.ann_index.describe()} "
                   f"keep_frac={self.keep_frac:g}]")
        return (f"Recommender[{self.n_users}U x {self.n_items}I] "
                f"impl={self.impl} k={self.k} block={self.item_block} "
                f"topology={self.plan.topology.name} "
                f"policy={self.plan.policy} "
                f"user_embed->{tiers['serve/user_embed']} "
                f"item_embed->{tiers['serve/item_embed']} "
                f"(offloaded={self.n_offloaded}){cache}{ann}")
