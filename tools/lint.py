#!/usr/bin/env python
"""JAX-aware static lint + HLO invariant audit (``repro.analysis``).

Layer 1 (default; no JAX import): AST rules over ``src/`` and
``benchmarks/`` — tracer-unsafe Python inside jitted/Pallas functions,
PRNG hygiene, f64-promotion hazards, Pallas kernel rules — plus
cross-file registry-completeness rules (kernel oracles, spec sections,
topology snapshot arms).  Findings are compared against the committed
ratchet baseline (``tools/lint_baseline.json``): NEW findings fail,
FIXED findings must be removed from the baseline (``--update``), so the
recorded debt only ever shrinks.

Layer 2 (``--hlo``): lowers the jitted train step and the fused serve
path for representative lightgcn-smoke presets — single device and a
forced-4-device mesh with int8 psum / int8 ring arms — and asserts on
the lowered text: no f64 ops, no host transfers inside the step,
collectives present/absent exactly per MeshCfg/CompressionCfg, one
microbatch chunk shape across the schedule.  Each arm runs in a
subprocess so ``XLA_FLAGS`` device forcing works.

    python tools/lint.py                   # lint vs baseline
    python tools/lint.py --check-baseline  # same, explicit (CI)
    python tools/lint.py --update          # rewrite the ratchet baseline
    python tools/lint.py --hlo             # Layer 2 HLO audit
    python tools/lint.py --rules           # rule catalogue
    python tools/lint.py src/repro/eval    # restrict lint paths
"""
from __future__ import annotations

import os
import subprocess
import sys

import _cli

_cli.ensure_src()

BASELINE_PATH = _cli.tool_file("lint_baseline.json")
LINT_ROOTS = ("src", "benchmarks")

# (arch, mesh, grads, ring): the representative preset points ``make
# audit`` lowers — lightgcn single device, plain 4-way mesh, int8
# gradient psum, int8 quantized ring; ngcf single device (the fused
# Hadamard contract: fusion_audit's cross-arm message-shape check) and
# 4-way mesh (the fused route must fall back to the composed path
# under the ring dispatch)
HLO_ARMS = (("lightgcn", 1, "none", "none"), ("lightgcn", 4, "none", "none"),
            ("lightgcn", 4, "int8", "none"), ("lightgcn", 4, "none", "int8"),
            ("ngcf", 1, "none", "none"), ("ngcf", 4, "none", "none"))


def run_lint(paths: list[str]) -> list:
    from repro.analysis import lint_paths, lint_repo
    root = _cli.repo_root()
    targets = [root / p for p in (paths or LINT_ROOTS)]
    findings = lint_paths([p for p in targets if p.exists()], root=root)
    if not paths:  # registry rules are repo-wide, skip when restricted
        findings += lint_repo(root)
    return findings


def lint_main(args) -> int:
    from repro.analysis import compare, load_baseline, save_baseline
    findings = run_lint(args.paths)
    if args.update:
        n = save_baseline(BASELINE_PATH, findings)
        print(f"wrote {BASELINE_PATH} ({n} baselined finding(s))")
        return 0
    new, stale = compare(findings, load_baseline(BASELINE_PATH))
    failures = [str(f) for f in new]
    failures += [f"stale baseline entry {k!r}: recorded {rec}, "
                 f"now {rem} — shrink the baseline"
                 for k, rec, rem in stale]
    return _cli.report(
        "lint (repro.analysis layer 1)", failures,
        ok=f"lint OK ({len(findings)} finding(s), all baselined; "
           f"baseline {BASELINE_PATH.name})",
        hint="new findings: fix them; fixed findings: rerun with "
             "--update and commit the shrunk baseline")


def hlo_main(args) -> int:
    failures: list[str] = []
    for arch, mesh, grads, ring in HLO_ARMS:
        env = dict(os.environ)
        env["PYTHONPATH"] = os.pathsep.join(
            [str(_cli.repo_root() / "src"),
             env.get("PYTHONPATH", "")]).rstrip(os.pathsep)
        if mesh > 1:
            env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                                f" --xla_force_host_platform_device_count"
                                f"={mesh}").strip()
        code = ("import json, sys\n"
                "from repro.analysis import hlo_audit\n"
                f"v = hlo_audit.smoke_audit(mesh={mesh}, "
                f"grads={grads!r}, ring={ring!r}, arch={arch!r})\n"
                "print(json.dumps(v))\n")
        proc = subprocess.run([sys.executable, "-c", code],
                              capture_output=True, text=True, env=env)
        arm = f"arch={arch},mesh={mesh},grads={grads},ring={ring}"
        if proc.returncode != 0:
            failures.append(f"[{arm}] audit crashed:\n"
                            + proc.stderr.strip())
            continue
        import json
        violations = json.loads(proc.stdout.strip().splitlines()[-1])
        failures += violations
        print(f"  audited {arm}: "
              f"{'FAIL' if violations else 'ok'}")
    return _cli.report(
        "HLO audit (repro.analysis layer 2)", failures,
        ok=f"HLO audit OK ({len(HLO_ARMS)} preset arms: train halves + "
           "fused serve + recompile hazard)",
        hint="the lowering violated a placement/dtype/collective "
             "invariant — see docs/ARCHITECTURE.md 'Static analysis'")


def rules_main() -> int:
    from repro.analysis import ALL_RULES
    width = max(map(len, ALL_RULES))
    for name in sorted(ALL_RULES):
        print(f"  {name:<{width}}  {ALL_RULES[name]}")
    return 0


def main() -> int:
    ap = _cli.make_parser(__doc__)
    ap.add_argument("paths", nargs="*",
                    help=f"lint roots (default: {', '.join(LINT_ROOTS)})")
    ap.add_argument("--update", action="store_true",
                    help="rewrite the ratchet baseline from current "
                         "findings")
    ap.add_argument("--check-baseline", action="store_true",
                    help="explicit alias of the default compare mode "
                         "(what CI runs)")
    ap.add_argument("--hlo", action="store_true",
                    help="run the Layer 2 HLO invariant audit (slow; "
                         "imports JAX, forces devices in subprocesses)")
    ap.add_argument("--rules", action="store_true",
                    help="print the rule catalogue and exit")
    args = ap.parse_args()
    if args.rules:
        return rules_main()
    if args.hlo:
        return hlo_main(args)
    return lint_main(args)


if __name__ == "__main__":
    sys.exit(main())
