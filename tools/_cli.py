"""Shared idiom for the repo's gate scripts (``tools/*.py``).

Every gate follows the same contract — stdlib-only startup, ``src/`` on
the path before any ``repro`` import, an argparse front end whose help
text is the module docstring, and a FAIL/OK report that exits 1 on any
failure with a hint about the intentional-change escape hatch
(``--update`` and friends).  This module is that contract, so
``check_plan_snapshot.py``, ``check_test_delta.py`` and ``lint.py``
cannot drift apart in exit-code or output conventions.
"""
from __future__ import annotations

import argparse
import pathlib
import sys

__all__ = ["repo_root", "ensure_src", "tool_file", "make_parser", "report"]


def repo_root() -> pathlib.Path:
    return pathlib.Path(__file__).resolve().parents[1]


def ensure_src() -> None:
    """Put ``src/`` on ``sys.path`` (gates run from a checkout, not an
    installed package)."""
    src = str(repo_root() / "src")
    if src not in sys.path:
        sys.path.insert(0, src)


def tool_file(name: str) -> pathlib.Path:
    """A data file living next to the gate scripts (golden snapshots,
    baselines)."""
    return repo_root() / "tools" / name


def make_parser(doc: str | None) -> argparse.ArgumentParser:
    """The gates' argparse front end: module docstring as help, shown
    verbatim."""
    return argparse.ArgumentParser(
        description=doc, formatter_class=argparse.RawDescriptionHelpFormatter)


def report(title: str, failures: list[str], *, ok: str,
           hint: str | None = None) -> int:
    """Print the gate verdict and return its exit code (1 on any
    failure).  ``hint`` names the intentional-change escape hatch."""
    if failures:
        print(f"--- {title}: FAIL ---")
        for f in failures:
            print(f"  {f}")
        if hint:
            print(f"({hint})")
        return 1
    print(ok)
    return 0
