#!/usr/bin/env python
"""Golden-snapshot gate for placement plans.

Builds the paper-scale placement plan (the ``lightgcn-full`` preset's
§2.1 profile set, greedy policy, 30%-of-footprint fast-tier budget)
under EVERY registered ``TierTopology`` preset — in TWO storage arms,
fp32 and int8 embedding tables (``CompressionCfg.embed_store``), the
latter snapshotted under ``<topology>@int8`` keys — and compares the
result — tensor→tier assignments, per-tier usage, estimated step
penalty, and the plan-emitted write-policy table — against the
committed golden JSON (``tools/plan_snapshots.json``).

A placement regression (a tensor silently changing tiers, a penalty
shifting, a new topology preset without a snapshot, quantized byte
pricing drifting) fails ``make test`` and CI the same way a test-count
regression does.

    python tools/check_plan_snapshot.py            # compare (CI gate)
    python tools/check_plan_snapshot.py --update   # regenerate golden

``--update`` rewrites ``plan_snapshots.json`` in place covering every
registered topology × {fp32, int8} arm; rerun it after any intentional
change to profiles, policies, topologies, or quantized pricing, and
commit the regenerated file alongside the code change.
"""
from __future__ import annotations

import json
import sys

import _cli

_cli.ensure_src()

SNAPSHOT_PATH = _cli.tool_file("plan_snapshots.json")


def build_snapshots() -> dict:
    from repro.api import get_preset
    from repro.memory import (get_policy, get_topology, gnn_recsys_profiles,
                              topology_names)
    spec = get_preset("lightgcn-full")
    arms = {store: gnn_recsys_profiles(
        spec.data.n_users, spec.data.n_items, spec.data.edges,
        spec.model.embed_dim, spec.model.n_layers, embed_store=store)
        for store in ("fp32", "int8")}
    total = sum(p.nbytes for p in arms["fp32"])
    # NGCF arms: the paper-scale NGCF profile set with and without the
    # fused Hadamard-SpMM route.  Fused drops the per-layer [E, D]
    # message streams entirely; both arms run against the SAME budget
    # (30% of the UNFUSED footprint) so the snapshot pins the placement
    # shift the reclaimed capacity buys, not a budget artifact.
    nspec = get_preset("ngcf-full")
    ngcf_arms = {"ngcf": gnn_recsys_profiles(
        nspec.data.n_users, nspec.data.n_items, nspec.data.edges,
        nspec.model.embed_dim, nspec.model.n_layers),
        "ngcf-fused": gnn_recsys_profiles(
        nspec.data.n_users, nspec.data.n_items, nspec.data.edges,
        nspec.model.embed_dim, nspec.model.n_layers, fused_messages=True)}
    ngcf_total = sum(p.nbytes for p in ngcf_arms["ngcf"])
    out = {"_profile": {
        "preset": "lightgcn-full",
        "n_tensors": len(arms["fp32"]),
        "total_bytes": int(total),
        "fast_budget_fraction": 0.3,
        "storage_arms": ["fp32", "int8"],
        "ngcf_preset": "ngcf-full",
        "ngcf_n_tensors": {k: len(v) for k, v in ngcf_arms.items()},
        "ngcf_total_bytes": int(ngcf_total),
        "ngcf_arms": sorted(ngcf_arms),
    }}
    for name in topology_names():
        topo = get_topology(name)
        budgets = {topo.fast.name: int(total * 0.3),
                   topo.slow.name: max(topo.slow.capacity, total)}
        for store, profiles in arms.items():
            plan = get_policy("greedy")(profiles, topo, budgets=budgets)
            key = name if store == "fp32" else f"{name}@int8"
            out[key] = plan.to_dict()
        nbudgets = {topo.fast.name: int(ngcf_total * 0.3),
                    topo.slow.name: max(topo.slow.capacity, ngcf_total)}
        for arm, profiles in ngcf_arms.items():
            plan = get_policy("greedy")(profiles, topo, budgets=nbudgets)
            out[f"{name}@{arm}"] = plan.to_dict()
    return out


def main() -> int:
    ap = _cli.make_parser(__doc__)
    ap.add_argument("--update", action="store_true",
                    help="rewrite the golden snapshot file")
    args = ap.parse_args()
    got = build_snapshots()
    if args.update:
        SNAPSHOT_PATH.write_text(json.dumps(got, indent=2, sort_keys=True)
                                 + "\n")
        print(f"wrote {SNAPSHOT_PATH} ({len(got) - 1} topology/storage "
              "plans)")
        return 0
    if not SNAPSHOT_PATH.exists():
        print(f"FAIL: no golden snapshot at {SNAPSHOT_PATH}; run "
              f"`python {sys.argv[0]} --update` and commit the result")
        return 1
    want = json.loads(SNAPSHOT_PATH.read_text())
    failures = []
    for topo in sorted(set(got) | set(want)):
        if topo not in want:
            failures.append(f"topology {topo!r} has no golden snapshot "
                            "(new preset? run --update)")
            continue
        if topo not in got:
            failures.append(f"golden topology {topo!r} is no longer "
                            "registered")
            continue
        if got[topo] != want[topo]:
            diffs = _diff(want[topo], got[topo])
            failures.append(f"topology {topo!r} drifted: " + "; ".join(diffs))
    return _cli.report(
        "placement-plan snapshot check", failures,
        ok=f"placement-plan snapshots OK ({len(got) - 1} topologies, "
           f"{got['_profile']['n_tensors']} tensors)",
        hint="intentional change? rerun with --update and commit")


def _diff(want, got, prefix="") -> list[str]:
    if not isinstance(want, dict) or not isinstance(got, dict):
        return [f"{prefix or 'value'}: {want!r} -> {got!r}"]
    out = []
    for k in sorted(set(want) | set(got)):
        path = f"{prefix}.{k}" if prefix else str(k)
        if k not in want:
            out.append(f"{path}: (new) {got[k]!r}")
        elif k not in got:
            out.append(f"{path}: (gone, was {want[k]!r})")
        elif want[k] != got[k]:
            out.extend(_diff(want[k], got[k], path))
    return out


if __name__ == "__main__":
    sys.exit(main())
