#!/usr/bin/env python
"""Run the tier-1 suite and print the pass/fail delta vs the recorded
seed baseline (tools/seed_baseline.json).

``make test`` routes through this so every run shows at a glance whether
the suite grew, shrank, or regressed relative to the seed.  Extra args
are forwarded to pytest (e.g. ``python tools/check_test_delta.py -m
"not slow"``).  Exit code is pytest's.
"""
from __future__ import annotations

import json
import re
import subprocess
import sys

import _cli

BASELINE_PATH = _cli.tool_file("seed_baseline.json")
FIELDS = ("passed", "failed", "skipped", "error")


def parse_summary(output: str) -> dict[str, int]:
    """Counts from pytest's final summary line (absent fields -> 0)."""
    counts = dict.fromkeys(FIELDS, 0)
    for line in reversed(output.strip().splitlines()):
        found = {word: int(n) for n, word in
                 re.findall(r"(\d+) (passed|failed|skipped|error)s?", line)}
        if found:
            for field in FIELDS:
                counts[field] = found.get(field, 0)
            break
    return counts


def main() -> int:
    proc = subprocess.run(
        [sys.executable, "-m", "pytest", "-q", *sys.argv[1:]],
        capture_output=True, text=True)
    sys.stdout.write(proc.stdout)
    sys.stderr.write(proc.stderr)

    baseline = json.loads(BASELINE_PATH.read_text())
    counts = parse_summary(proc.stdout)
    print("\n--- delta vs seed baseline "
          f"({baseline['passed']} passed / {baseline['failed']} failed / "
          f"{baseline['skipped']} skipped) ---")
    for field in FIELDS:
        d = counts[field] - int(baseline.get(field, 0))
        print(f"  {field:8s} {counts[field]:4d}  ({d:+d})")
    if counts["failed"] > int(baseline.get("failed", 0)) \
            or counts["error"] > int(baseline.get("error", 0)):
        print("  REGRESSION: more failures/errors than the seed baseline")
    elif counts["passed"] < int(baseline.get("passed", 0)):
        print("  WARNING: fewer passing tests than the seed baseline")
    else:
        print("  OK: no worse than the seed baseline")
    return proc.returncode


if __name__ == "__main__":
    sys.exit(main())
